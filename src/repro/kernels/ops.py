"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU —
the BlockSpecs/grids are written for TPU VMEM tiling and validated on CPU
via the interpreter against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.params import DeviceParams
from repro.kernels.bitline_mac import bitline_mac_pallas
from repro.kernels.llg_rk4 import CELL_TILE, ROWS, llg_rk4_pallas
from repro.kernels.xnor_gemm import xnor_gemm_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# p is static: the kernel closes over the device constants at compile time
@functools.partial(jax.jit, static_argnames=("p", "dt", "n_steps", "switch_threshold"))
def llg_rk4(state, p: DeviceParams, dt: float, n_steps: int,
            switch_threshold: float = 0.9):
    """Advance a (8, cells) state block n_steps; see llg_rk4.py for layout."""
    return llg_rk4_pallas(state, p, dt, n_steps, switch_threshold,
                          interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=(
    "p", "dt", "n_steps", "switch_threshold", "chunk"))
def llg_rk4_thermal(state, seeds, p: DeviceParams, dt: float, n_steps: int,
                    thermal_sigma, switch_threshold: float = 0.9,
                    step_budget=None, chunk: int = 0, lane_params=None):
    """Thermal (Langevin) variant: per-cell counter-RNG streams in ``seeds``
    ((cells,) uint32, see kernels/noise.cell_seeds).  Brown's sigma is
    *traced data* — a scalar or a (cells,) per-lane row — so campaigns
    spanning several temperatures (or write-verify retry rounds at any
    seed) share one compile.  ``step_budget`` (traced, per-lane) caps each
    lane's horizon below the compiled ``n_steps``; ``chunk > 0`` (static)
    turns on chunked early exit — see kernels/llg_rk4.py.  ``lane_params``
    ((3, cells) f32: alpha, B_k, g_scale — also traced) switches on the
    per-lane device-variation plane (DESIGN.md §9)."""
    return llg_rk4_pallas(state, p, dt, n_steps, switch_threshold,
                          interpret=_default_interpret(),
                          thermal_sigma=thermal_sigma, seeds=seeds,
                          step_budget=step_budget, chunk=chunk,
                          lane_params=lane_params)


def pack_states(m0: jnp.ndarray, voltages: jnp.ndarray) -> jnp.ndarray:
    """(cells, 2, 3) initial states + (cells,) drives -> (8, cells) SoA."""
    assert m0.ndim == 3 and m0.shape[1] == 2, (
        f"SoA layout is dual-sublattice (AFMTJ) only, got {m0.shape}; "
        "single-sublattice (FM/MTJ) states pack via repro.campaign.grid."
        "pack_soa and ride the engine's scan tile instead of this kernel")
    cells = m0.shape[0]
    pad = (-cells) % CELL_TILE
    m0 = jnp.pad(m0, ((0, pad), (0, 0), (0, 0)))
    voltages = jnp.pad(voltages, (0, pad))
    rows = [m0[:, 0, 0], m0[:, 0, 1], m0[:, 0, 2],
            m0[:, 1, 0], m0[:, 1, 1], m0[:, 1, 2],
            voltages, jnp.zeros_like(voltages)]
    return jnp.stack(rows).astype(jnp.float32)


def unpack_states(state: jnp.ndarray, cells: int):
    m = jnp.stack([state[0:3, :cells].T, state[3:6, :cells].T], axis=1)
    crossing_step = state[7, :cells]
    return m, crossing_step


@functools.partial(jax.jit, static_argnames=("adc_bits", "i_max"))
def bitline_mac(v, g, adc_bits: int = 0, i_max: float = 1.0):
    return bitline_mac_pallas(v, g, adc_bits, i_max,
                              interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("binarize", "tie"))
def xnor_gemm(a, w, binarize: bool = False, tie: int = 1):
    return xnor_gemm_pallas(a, w, binarize, tie=tie,
                            interpret=_default_interpret())

"""Pallas TPU kernel: fused fake-analog MVM (program->IR-drop->ADC in one pass).

The full device path (``imc.analog_pipeline``) materializes a programmed
conductance pair per weight matrix on the host — ``program_weights`` reduces
to Python floats (w_scale, att_mean, g_rms) and ``kernel_operands`` rounds
the ADC full scale through a *string*, so every surface point pays host
syncs plus a fresh ``_mvm_sharded`` compile (``i_max`` is a jit static).
That is fine for one projection; it is intractable for (layers x batch x
surface-points) model sweeps.

This kernel is the batched fast path: the differential-conductance
construction is replayed *inside* the matmul tile loop from the normalized
weights, so programming never materializes and the whole chain is traced —
one compile per (shape, adc_bits), sweep points are data.  Per (BK, BN)
tile, in order (bit-matching ``program_weights``):

  1. targets      — tp/tn = G_AP + max(+-wn, 0) * G_FS
  2. corner FET   — push through the access FET, scale the junction by the
                    systematic corner factor, come forward again (skipped
                    when no variation spec, exactly like the device path)
  3. write errors — failed cells drop to the G_AP floor (mask operand)
  4. IR drop      — per-column attenuation planes (precomputed column sums;
                    an (N,) reduction cannot live inside the K grid loop)
  5. MAC + ADC    — att_p*tp - att_n*tn, one MXU dot per tile, f32
                    accumulator scratch; epilogue quantizes through the
                    *shared* ``adc_quantize`` and applies the decode scale.

Scalars (ADC full scale, decode gain, device constants) ride in an (8, N)
aux plane so they stay traced data, not compile keys.  Zero-padding is
exact: padded K rows see v = 0 (no current), padded N columns carry att = 0
(g_diff = 0).  Numerical parity vs the device path is pinned in
``tests/test_analog_pipeline.py``; the jnp oracle is ``ref.ref_fake_analog``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitline_mac import BM, BN, BK, _pad2, adc_quantize

# aux plane row layout (8, N) — per-column planes first, broadcast scalars
# (stored across the full row) after
ROW_ATT_POS = 0     # per-column IR attenuation, positive array
ROW_ATT_NEG = 1     # per-column IR attenuation, negative array
ROW_I_MAX = 2       # ADC full-scale current [A]
ROW_DECODE = 3      # decode gain back to weight/activation units
ROW_G_AP = 4        # effective AP-state conductance (G_AP floor) [S]
ROW_G_FS = 5        # unit-weight differential conductance G_P - G_AP [S]
ROW_G_SCALE = 6     # systematic corner junction conductance factor 1/r_f
ROW_R_ACCESS = 7    # access transistor on-resistance [Ohm]
AUX_ROWS = 8

# ``fail``-plane bit codes.  The plane is f32 (it rides the same operand
# layout as the weight tile) carrying a bit-OR of small powers of two —
# exact in f32 up to 127.  Bits 1/2 are the PR-3 write-verify fail masks;
# bits 4..64 are the hard-fault codes drawn by ``imc.faults`` (stuck-at and
# dead-line defects are *data*, not compile keys).
FAIL_POS = 1        # write-verify fail: positive cell at the G_AP floor
FAIL_NEG = 2        # write-verify fail: negative cell at the G_AP floor
FAULT_POS_OFF = 4   # hard stuck-at-G_off: positive cell pinned at G_AP
FAULT_NEG_OFF = 8   # hard stuck-at-G_off: negative cell pinned at G_AP
FAULT_POS_ON = 16   # hard stuck-at-G_on: positive cell pinned at G_AP+G_FS
FAULT_NEG_ON = 32   # hard stuck-at-G_on: negative cell pinned at G_AP+G_FS
FAULT_DEAD = 64     # dead differential pair (dead row driver / repair mask)
FAIL_CODE_MAX = 127


def fail_bit(code, bit):
    """True where integer bit ``bit`` is set in the f32 ``fail`` code plane.

    Pure f32 arithmetic (floor/mod) so it lowers identically inside the
    Pallas tile, the jnp oracle, and the traced preamble."""
    return jnp.floor(code * (1.0 / bit)) % 2.0 >= 1.0


def pos_neg_conductance(wn, fail, g_ap, g_fs, g_scale, r_access, *,
                        apply_fet: bool, use_fail: bool):
    """Per-cell (g_pos, g_neg) pre-IR-drop conductances — the fused replay of
    ``program_weights`` steps 1-3.  Shared by the kernel tile, the jnp
    oracle, and the traced preamble that reduces the column sums for the IR
    planes (``imc.model_analog``), so the cell math cannot drift."""
    tp = g_ap + jnp.maximum(wn, 0.0) * g_fs
    tn = g_ap + jnp.maximum(-wn, 0.0) * g_fs
    if apply_fet:
        def fet(t):
            g_j = (t / (1.0 - r_access * t)) * g_scale
            return g_j / (1.0 + r_access * g_j)

        tp, tn = fet(tp), fet(tn)
    if use_fail:
        # Decode order fixes the fault priority: G_AP floors (write-verify
        # fails + stuck-off), then stuck-on overrides, then dead pairs kill
        # the cell outright.  For legacy codes {0,1,2,3} this is bit-for-bit
        # the old two-way decode (bit 1 <-> fail in {1,3}; bit 2 <-> >= 2).
        g_ap_b = jnp.broadcast_to(g_ap, tp.shape)
        g_on_b = jnp.broadcast_to(g_ap + g_fs, tp.shape)
        tp = jnp.where(fail_bit(fail, FAIL_POS) | fail_bit(fail, FAULT_POS_OFF),
                       g_ap_b, tp)
        tn = jnp.where(fail_bit(fail, FAIL_NEG) | fail_bit(fail, FAULT_NEG_OFF),
                       g_ap_b, tn)
        tp = jnp.where(fail_bit(fail, FAULT_POS_ON), g_on_b, tp)
        tn = jnp.where(fail_bit(fail, FAULT_NEG_ON), g_on_b, tn)
        dead = fail_bit(fail, FAULT_DEAD)
        tp = jnp.where(dead, 0.0, tp)
        tn = jnp.where(dead, 0.0, tn)
    return tp, tn


def _tile_g_diff(wn, fail, aux, *, apply_fet: bool, use_fail: bool):
    """(BK, BN) differential conductance tile from the aux-plane scalars."""
    tp, tn = pos_neg_conductance(
        wn, fail,
        aux[ROW_G_AP:ROW_G_AP + 1, :1],
        aux[ROW_G_FS:ROW_G_FS + 1, :1],
        aux[ROW_G_SCALE:ROW_G_SCALE + 1, :1],
        aux[ROW_R_ACCESS:ROW_R_ACCESS + 1, :1],
        apply_fet=apply_fet, use_fail=use_fail)
    att_p = aux[ROW_ATT_POS:ROW_ATT_POS + 1, :]
    att_n = aux[ROW_ATT_NEG:ROW_ATT_NEG + 1, :]
    return att_p * tp - att_n * tn


def _fake_kernel(v_ref, w_ref, fail_ref, aux_ref, o_ref, acc_ref, *, nk: int,
                 adc_bits: int, apply_fet: bool, use_fail: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g_diff = _tile_g_diff(w_ref[...], fail_ref[...], aux_ref[...],
                          apply_fet=apply_fet, use_fail=use_fail)
    acc_ref[...] += jnp.dot(
        v_ref[...], g_diff, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        aux = aux_ref[...]
        i_max = aux[ROW_I_MAX:ROW_I_MAX + 1, :]
        dec = aux[ROW_DECODE:ROW_DECODE + 1, :]
        i_bl = adc_quantize(acc_ref[...], adc_bits, i_max)
        o_ref[...] = (i_bl * dec).astype(o_ref.dtype)


def fake_analog_mac_pallas(
    v: jnp.ndarray,               # (M, K) read voltages (batch x rows)
    wn: jnp.ndarray,              # (K, N) normalized weights in [-1, 1]
    fail: jnp.ndarray,            # (K, N) f32 fail/fault bit codes [0, 127]
    aux: jnp.ndarray,             # (8, N) f32 aux plane (ROW_* layout)
    adc_bits: int = 0,
    apply_fet: bool = False,
    use_fail: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    M, K = v.shape
    K2, N = wn.shape
    assert K == K2, (v.shape, wn.shape)
    assert fail.shape == wn.shape, (fail.shape, wn.shape)
    assert aux.shape == (AUX_ROWS, N), (aux.shape, N)
    assert adc_bits == 0 or adc_bits >= 2, adc_bits
    from jax.experimental.pallas import tpu as pltpu

    v = _pad2(v, BM, BK)
    wn = _pad2(wn, BK, BN)
    fail = _pad2(fail, BK, BN)
    aux = _pad2(aux, AUX_ROWS, BN)
    mp, kp = v.shape
    _, np_ = wn.shape
    nk = kp // BK
    kern = functools.partial(_fake_kernel, nk=nk, adc_bits=adc_bits,
                             apply_fet=apply_fet, use_fail=use_fail)
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=(mp // BM, np_ // BN, nk),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
            pl.BlockSpec((AUX_ROWS, BN), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(v, wn, fail, aux)
    if (mp, np_) != (M, N):
        out = out[:M, :N]
    return out

"""Pure-jnp oracles for every kernel (the allclose targets in tests/).

``ref_llg_rk4`` reuses the *production* physics from ``repro.core`` — the
kernel must agree with the same code the device layer runs, not a private
re-implementation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import llg, tmr
from repro.core.integrator import rk4_step
from repro.core.params import DeviceParams
from repro.kernels import noise


def ref_llg_rk4(
    state: jnp.ndarray,           # (8, cells) SoA layout (see llg_rk4.py)
    p: DeviceParams,
    dt: float,
    n_steps: int,
    switch_threshold: float = 0.9,
    thermal_sigma=0.0,            # scalar or (cells,) per-lane Brown sigma
    seeds: jnp.ndarray | None = None,   # (cells,) uint32 per-lane streams
    step_budget=None,             # optional (cells,) f32 per-lane step budget
    chunk: int = 0,               # >0: early-exit chunk size (steps)
    lane_params=None,             # optional (3, cells) f32 variation rows:
                                  # alpha, B_k [T], g_scale (DESIGN.md §9)
) -> jnp.ndarray:
    """Both device families: ``p.n_sublattices`` picks dual-sublattice
    (AFMTJ — the Pallas kernel's allclose target) or single-sublattice
    (FM/MTJ — the campaign engine's production tile; rows 3:6 stay zero
    and only the first thermal triple of each per-lane counter is drawn,
    so padded lanes and RNG streams behave identically across kinds).

    Mirrors the kernel's campaign contract (same chunked early exit, same
    per-lane sigma/budget semantics): a lane past ``step_budget`` is frozen
    and records no crossings; with ``chunk > 0`` the whole block exits as
    soon as every lane is done.  Crossing rows are bit-identical to the
    fixed-horizon path either way.

    ``lane_params`` mirrors the kernel's variation plane by replacing the
    scalar ``p.alpha`` / ``p.b_aniso`` with ``(cells, 1, 1)`` rows inside
    the *production* ``llg.llg_rhs`` (broadcasting does the rest) and
    scaling the self-consistent drive by the per-lane junction conductance
    factor — same ops, same order, so the per-lane kernel stays
    allclose-testable against this oracle."""
    cells = state.shape[1]
    n_sub = p.n_sublattices
    g_scale = None
    p_lane = p
    if lane_params is not None:
        lp = jnp.asarray(lane_params, jnp.float32)
        assert lp.shape == (3, cells), (lp.shape, cells)
        p_lane = dataclasses.replace(
            p, alpha=lp[0].reshape(cells, 1, 1),
            b_aniso=lp[1].reshape(cells, 1, 1))
        g_scale = lp[2]
    if n_sub == 1:
        m = state[0:3].T[:, None, :]               # (cells, 1, 3)
    else:
        m = jnp.stack(
            [state[0:3].T, state[3:6].T], axis=1
        )                          # (cells, 2, 3)
    v = state[6]
    use_noise = seeds is not None
    if use_noise:
        seeds = seeds.reshape(cells).astype(jnp.uint32)
        sigma = jnp.broadcast_to(
            jnp.asarray(thermal_sigma, jnp.float32), (cells,)
        ).reshape(cells, 1, 1)
    else:
        assert isinstance(thermal_sigma, (int, float)) and thermal_sigma == 0.0, \
            "thermal path needs per-cell stream seeds"
    budget = None
    if step_budget is not None or chunk > 0:
        budget = (jnp.full((cells,), float(n_steps), jnp.float32)
                  if step_budget is None else
                  jnp.broadcast_to(jnp.asarray(step_budget, jnp.float32),
                                   (cells,)))

    def step(i, m, crossed):
        nz = llg.order_parameter_z(m)
        g = tmr.conductance_from_cos(nz, p)
        aj = p.stt_prefactor * v * g / p.area
        if g_scale is not None:
            aj = aj * g_scale
        if use_noise:
            # identical stream to the Pallas kernel: (cells, n_sub, 3) field
            # from the same per-lane counters (see kernels/noise.py)
            d1, d2 = noise.thermal_draws(seeds, i)
            triples = [jnp.stack(d1, axis=-1), jnp.stack(d2, axis=-1)]
            b_th = sigma * jnp.stack(triples[:n_sub], axis=1)
        else:
            b_th = None
        m_next = rk4_step(lambda mm, tt: llg.llg_rhs(mm, p_lane, aj, b_th),
                          m, 0.0, dt)
        nz_new = llg.order_parameter_z(m_next)
        newly = (nz_new < -switch_threshold) & (crossed >= float(n_steps))
        if budget is not None:
            active = jnp.asarray(i, jnp.float32) < budget
            newly = newly & active
            m_next = jnp.where(active[:, None, None], m_next, m)
        crossed = jnp.where(newly, jnp.asarray(i + 1, jnp.float32), crossed)
        return m_next, crossed

    crossed0 = jnp.full((cells,), float(n_steps), jnp.float32)
    if chunk <= 0:
        def body(carry, i):
            m, crossed = carry
            return step(i, m, crossed), None

        (m, crossed), _ = jax.lax.scan(body, (m, crossed0),
                                       jnp.arange(n_steps))
    else:
        n_chunks = -(-n_steps // chunk)

        def cond(carry):
            c, m, crossed = carry
            done = (crossed < float(n_steps)) | (
                jnp.asarray(c * chunk, jnp.float32) >= budget)
            return (c < n_chunks) & ~jnp.all(done)

        def chunk_body(carry):
            c, m, crossed = carry

            def inner(j, mc):
                return step(c * chunk + j, *mc)

            m, crossed = jax.lax.fori_loop(0, chunk, inner, (m, crossed))
            return c + 1, m, crossed

        _, m, crossed = jax.lax.while_loop(cond, chunk_body,
                                           (0, m, crossed0))
    sub2 = m[:, 1, :].T if n_sub == 2 else jnp.zeros_like(m[:, 0, :].T)
    return jnp.concatenate(
        [m[:, 0, :].T, sub2, v[None], crossed[None]], axis=0
    )


def ref_bitline_mac(v, g, adc_bits: int = 0, i_max: float = 1.0):
    from repro.kernels.bitline_mac import adc_quantize

    i_bl = v.astype(jnp.float32) @ g.astype(jnp.float32)
    return adc_quantize(i_bl, adc_bits, i_max)


def ref_fake_analog(v, wn, fail, aux, adc_bits: int = 0,
                    apply_fet: bool = False, use_fail: bool = False):
    """jnp oracle for ``fake_analog.fake_analog_mac_pallas``: same fused
    conductance replay (shared ``_tile_g_diff`` — the tile math cannot
    drift), full-array dot, shared ADC, decode gain."""
    from repro.kernels.bitline_mac import adc_quantize
    from repro.kernels.fake_analog import ROW_DECODE, ROW_I_MAX, _tile_g_diff

    g_diff = _tile_g_diff(jnp.asarray(wn, jnp.float32),
                          jnp.asarray(fail, jnp.float32),
                          jnp.asarray(aux, jnp.float32),
                          apply_fet=apply_fet, use_fail=use_fail)
    i_bl = v.astype(jnp.float32) @ g_diff
    i_max = aux[ROW_I_MAX:ROW_I_MAX + 1, :]
    return adc_quantize(i_bl, adc_bits, i_max) * aux[ROW_DECODE:ROW_DECODE + 1, :]


def ref_xnor_gemm(a, w, binarize: bool = False, tie: int = 1):
    from repro.kernels.xnor_gemm import binarize_acc

    out = a.astype(jnp.float32) @ w.astype(jnp.float32)
    if binarize:
        out = binarize_acc(out, tie)
    return out


def ref_xnor_popcount(a_bits: jnp.ndarray, w_bits: jnp.ndarray):
    """Bit-domain identity check: a,w in {0,1}; result == pm1 dot product."""
    K = a_bits.shape[-1]
    xnor = 1 - jnp.bitwise_xor(a_bits[:, None, :], w_bits.T[None, :, :])
    pop = jnp.sum(xnor, axis=-1)
    return 2 * pop - K

"""Pallas TPU kernel: XNOR-popcount GEMM (the paper's *bnn* workload).

out[m, n] = sum_k xnor(a[m,k], w[k,n]) counted over +-1 encodings
          = K - 2 * popcount(a XOR w)  ==  dot(a_pm1, w_pm1)

The +-1 dot-product identity lets the MXU do the popcount: inputs are +-1
(stored bf16), the accumulator is f32, and the epilogue optionally
re-binarizes (sign) — exactly the functional behavior of the AFMTJ
XNOR array + popcount tree modeled in repro.imc.

Tie convention: with even K the popcount can land exactly on zero, and the
sense amp must break the tie one way.  ``tie`` (+1 default, matching the
seed's ``acc >= 0 -> +1``) selects the output for acc == 0; it is threaded
through the jnp oracle (``ref.ref_xnor_gemm``) so kernel and reference agree
bit-for-bit at ties.

Non-128-multiple operands are zero-padded (a 0 contributes nothing to the
+-1 dot product) and the result is sliced back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitline_mac import _pad2

BM = BN = BK = 128


def binarize_acc(acc: jnp.ndarray, tie: int) -> jnp.ndarray:
    """Sign with an explicit tie convention for acc == 0 (shared with ref)."""
    sign = jnp.where(acc > 0.0, 1.0, -1.0)
    return jnp.where(acc == 0.0, float(tie), sign)


def _xnor_kernel(a_ref, w_ref, o_ref, acc_ref, *, nk: int, binarize: bool,
                 tie: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        acc = acc_ref[...]
        if binarize:
            acc = binarize_acc(acc, tie)
        o_ref[...] = acc.astype(o_ref.dtype)


def xnor_gemm_pallas(
    a: jnp.ndarray,               # (M, K) in {-1, +1}
    w: jnp.ndarray,               # (K, N) in {-1, +1}
    binarize: bool = False,
    tie: int = 1,                 # sign assigned to an exact popcount tie
    interpret: bool = False,
) -> jnp.ndarray:
    M, K = a.shape
    K2, N = w.shape
    assert K == K2, (a.shape, w.shape)
    assert tie in (1, -1), tie
    from jax.experimental.pallas import tpu as pltpu

    a = _pad2(a, BM, BK)
    w = _pad2(w, BK, BN)
    mp, kp = a.shape
    _, np_ = w.shape
    nk = kp // BK
    kern = functools.partial(_xnor_kernel, nk=nk, binarize=binarize, tie=tie)
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=(mp // BM, np_ // BN, nk),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(a, w)
    if (mp, np_) != (M, N):
        out = out[:M, :N]
    return out

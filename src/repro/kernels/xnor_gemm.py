"""Pallas TPU kernel: XNOR-popcount GEMM (the paper's *bnn* workload).

out[m, n] = sum_k xnor(a[m,k], w[k,n]) counted over +-1 encodings
          = K - 2 * popcount(a XOR w)  ==  dot(a_pm1, w_pm1)

The +-1 dot-product identity lets the MXU do the popcount: inputs are +-1
(stored bf16), the accumulator is f32, and the epilogue optionally
re-binarizes (sign) — exactly the functional behavior of the AFMTJ
XNOR array + popcount tree modeled in repro.imc.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = BN = BK = 128


def _xnor_kernel(a_ref, w_ref, o_ref, acc_ref, *, nk: int, binarize: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        acc = acc_ref[...]
        if binarize:
            acc = jnp.where(acc >= 0.0, 1.0, -1.0)
        o_ref[...] = acc.astype(o_ref.dtype)


def xnor_gemm_pallas(
    a: jnp.ndarray,               # (M, K) in {-1, +1}
    w: jnp.ndarray,               # (K, N) in {-1, +1}
    binarize: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    M, K = a.shape
    K2, N = w.shape
    assert K == K2 and M % BM == 0 and N % BN == 0 and K % BK == 0
    from jax.experimental.pallas import tpu as pltpu

    nk = K // BK
    kern = functools.partial(_xnor_kernel, nk=nk, binarize=binarize)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        grid=(M // BM, N // BN, nk),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(a, w)

"""Pallas TPU kernel: dual-sublattice LLG RK4 array simulation.

The paper's hot loop — integrating the coupled sublattice ODEs for every
cell of a subarray (and every Monte-Carlo sample) — restructured for TPU:

* SoA layout ``(8, cells)``: rows 0-2 = m1, rows 3-5 = m2, row 6 = per-cell
  drive voltage, row 7 = first-crossing step (written by the kernel).
  Lane dimension = cells (multiples of 128), so every vector op in the RK4
  update is a full-width VPU op.
* One grid step owns a ``(8, CELL_TILE)`` VMEM-resident tile and advances it
  up to ``n_steps`` — HBM traffic is O(cells), compute O(cells * steps):
  arithmetic intensity ~ 60 flops/step/cell keeps the tile compute-bound
  for any realistic step count.
* Device constants (gamma, alpha, B_E, B_k, RK4 dt, transport constants for
  the self-consistent a_J(theta) drive) are closed over as compile-time
  scalars by default — fixed per device kind.  With a **variation plane**
  (``lane_params``, DESIGN.md §9) the aux input grows from ``(2, cells)``
  to ``(5, cells)`` and per-lane alpha / B_k / junction-conductance-scale
  rows override the scalars: process corners and D2D parameter draws are
  then campaign *data*, so an (corner x temperature x voltage x sample)
  grid rides one launch with one compile.
* Thermal field (``seeds`` given): Brown's Langevin term, sampled per step
  per sublattice component from the stateless counter-based generator in
  ``kernels/noise.py``.  Each lane carries its own uint32 stream seed and
  its own **per-lane sigma** (second input plane, row 0) — temperature is
  campaign *data*, not a compile-time scalar, so a whole
  (temperature x voltage x sample) grid rides one launch with one compile.
* Per-lane **step budget** (second input plane, row 1): lane ``i``
  integrates only while ``step < budget[i]`` — past its budget a lane is
  frozen (state held, no crossings recorded).  Padded lanes get budget 0
  and cost nothing; campaigns whose true horizon is shorter than the
  compiled ``n_steps`` (shape-bucketed launches) stop at the budget.
* Chunked early exit (``chunk > 0``): the step loop is a ``while_loop``
  over chunks of ``chunk`` steps; after each chunk the tile exits as soon
  as every lane is done (crossed or out of budget).  Crossing-step results
  are bit-identical to the fixed-horizon path (the per-step update order
  is unchanged — early exit only skips steps no lane needed), which
  ``tests/test_fused_engine.py`` pins against the ref oracle.

Hardware adaptation note (DESIGN.md §2, §8): this replaces the scalar SPICE
inner loop; the physics is bit-identical to ``repro.core`` (ref.py is the
pure-jnp oracle and tests sweep shapes/dtypes against it, including the
thermal stream at a fixed seed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.params import GAMMA, DeviceParams
from repro.kernels import noise

CELL_TILE = 512
ROWS = 8
AUX_ROWS = 2     # aux plane: row 0 = per-lane sigma [T], row 1 = step budget
# Variation plane (DESIGN.md §9): the aux input grows three per-lane device
# parameter rows, so process corners and D2D draws are campaign *data* —
# rows 2-4 = Gilbert alpha, anisotropy B_k [T], junction conductance factor
# g_scale (= 1/r_factor; scales the self-consistent a_J drive).  Exchange
# B_E, the field-like ratio and the transport prefactor stay compile-time
# (not varied — see core.params.ProcessCorner).
VAR_ROWS = 3
VAR_AUX_ROWS = AUX_ROWS + VAR_ROWS


def _rhs(m1, m2, aj, p: DeviceParams, bth1=None, bth2=None,
         alpha=None, bk=None):
    """Vectorized dual-sublattice LLG RHS on (3, n) component stacks.

    ``bth1``/``bth2``: optional per-sublattice thermal field component
    triples [T], added to the deterministic effective field (Brown's
    Langevin term, held constant across the RK4 substages of one step —
    same convention as ``core.montecarlo``).

    ``alpha``/``bk``: optional per-lane rows overriding the compile-time
    device constants (the variation plane).  ``None`` keeps the scalar
    closure — the legacy compiled graph, bit-for-bit.
    """
    be, beta = p.b_exchange, p.beta_flt
    alpha = p.alpha if alpha is None else alpha
    bk = p.b_aniso if bk is None else bk

    def cross(a, b):
        return (
            a[1] * b[2] - a[2] * b[1],
            a[2] * b[0] - a[0] * b[2],
            a[0] * b[1] - a[1] * b[0],
        )

    def one(m, mo, sign, bth):
        # B_eff = B_k m_z z_hat - B_E m_other (+ B_thermal)
        b = (-be * mo[0], -be * mo[1], bk * m[2] - be * mo[2])
        if bth is not None:
            b = tuple(bc + tc for bc, tc in zip(b, bth))
        # p_i = sign * z_hat (staggered Neel STT)
        pvec = (jnp.zeros_like(m[0]), jnp.zeros_like(m[0]),
                jnp.full_like(m[0], sign))
        t_prec = tuple(-GAMMA * c for c in cross(m, b))
        mxp = cross(m, pvec)
        mxmxp = cross(m, mxp)
        t_stt = tuple(GAMMA * aj * c for c in mxmxp)
        t_flt = tuple(-GAMMA * beta * aj * c for c in mxp)
        t = tuple(a + b_ + c for a, b_, c in zip(t_prec, t_stt, t_flt))
        mxt = cross(m, t)
        return tuple((a + alpha * b_) / (1.0 + alpha**2) for a, b_ in zip(t, mxt))

    d1 = one(m1, m2, 1.0, bth1)
    d2 = one(m2, m1, -1.0, bth2)
    return d1, d2


def _renorm(m):
    inv = jax.lax.rsqrt(m[0] * m[0] + m[1] * m[1] + m[2] * m[2])
    return (m[0] * inv, m[1] * inv, m[2] * inv)


def _aj_from_v(v, nz, p: DeviceParams, g_scale=None):
    """Self-consistent STT drive: a_J = pref * V * G(n_z) / A (Julliere).

    ``g_scale``: optional per-lane junction conductance factor (RA/TMR
    resistance corner, variation plane row 4)."""
    g_p = 1.0 / p.r_parallel
    g_ap = 1.0 / p.r_antiparallel
    g = 0.5 * (g_p + g_ap) + 0.5 * (g_p - g_ap) * nz
    aj = p.stt_prefactor * v * g / p.area
    return aj if g_scale is None else aj * g_scale


def _make_body(p: DeviceParams, dt: float, n_steps: int,
               switch_threshold: float, sigma, seeds, v, budget=None,
               lane_params=None):
    """Build the per-step body; ``seeds`` is None for the deterministic
    path (keeps the compiled graph identical to the pre-thermal kernel).
    ``sigma`` is a scalar or per-lane row; ``budget`` (per-lane step
    budget, f32) masks updates for lanes past their horizon — with
    ``budget == n_steps`` everywhere the masked graph computes the exact
    same values as the unmasked one.  ``lane_params`` is the optional
    (alpha, B_k, g_scale) row triple of the variation plane."""
    alpha = bk = g_scale = None
    if lane_params is not None:
        alpha, bk, g_scale = lane_params

    def body(i, carry):
        m1, m2, crossed = carry
        nz = 0.5 * (m1[2] - m2[2])
        aj = _aj_from_v(v, nz, p, g_scale)

        if seeds is not None:
            d1, d2 = noise.thermal_draws(seeds, i)
            bth1 = tuple(sigma * c for c in d1)
            bth2 = tuple(sigma * c for c in d2)
        else:
            bth1 = bth2 = None

        def f(m1, m2):
            return _rhs(m1, m2, aj, p, bth1, bth2, alpha=alpha, bk=bk)

        k1a, k1b = f(m1, m2)
        m1h = tuple(a + 0.5 * dt * k for a, k in zip(m1, k1a))
        m2h = tuple(a + 0.5 * dt * k for a, k in zip(m2, k1b))
        k2a, k2b = f(m1h, m2h)
        m1h = tuple(a + 0.5 * dt * k for a, k in zip(m1, k2a))
        m2h = tuple(a + 0.5 * dt * k for a, k in zip(m2, k2b))
        k3a, k3b = f(m1h, m2h)
        m1f = tuple(a + dt * k for a, k in zip(m1, k3a))
        m2f = tuple(a + dt * k for a, k in zip(m2, k3b))
        k4a, k4b = f(m1f, m2f)
        m1n = tuple(
            a + dt / 6.0 * (x + 2 * y + 2 * z + w)
            for a, x, y, z, w in zip(m1, k1a, k2a, k3a, k4a)
        )
        m2n = tuple(
            a + dt / 6.0 * (x + 2 * y + 2 * z + w)
            for a, x, y, z, w in zip(m2, k1b, k2b, k3b, k4b)
        )
        m1n = _renorm(m1n)
        m2n = _renorm(m2n)
        nz_new = 0.5 * (m1n[2] - m2n[2])
        newly = (nz_new < -switch_threshold) & (crossed >= float(n_steps))
        if budget is not None:
            active = jnp.asarray(i, jnp.float32) < budget
            newly = newly & active
            m1n = tuple(jnp.where(active, a, b) for a, b in zip(m1n, m1))
            m2n = tuple(jnp.where(active, a, b) for a, b in zip(m2n, m2))
        crossed = jnp.where(newly, jnp.asarray(i + 1, jnp.float32), crossed)
        return m1n, m2n, crossed

    return body


def _llg_kernel(state_ref, out_ref, *, p: DeviceParams, dt: float,
                n_steps: int, switch_threshold: float):
    s = state_ref[...]
    m1 = (s[0], s[1], s[2])
    m2 = (s[3], s[4], s[5])
    v = s[6]
    crossed = jnp.full_like(v, float(n_steps))  # first-crossing step (f32)

    body = _make_body(p, dt, n_steps, switch_threshold, 0.0, None, v)
    m1, m2, crossed = jax.lax.fori_loop(0, n_steps, body, (m1, m2, crossed))
    out = jnp.stack([m1[0], m1[1], m1[2], m2[0], m2[1], m2[2], v, crossed])
    out_ref[...] = out


def _llg_thermal_kernel(state_ref, seeds_ref, aux_ref, out_ref, *,
                        p: DeviceParams, dt: float, n_steps: int,
                        switch_threshold: float, chunk: int,
                        variation: bool = False):
    """Thermal kernel: per-lane sigma (aux row 0), per-lane step budget
    (aux row 1), optional chunked early exit (``chunk > 0``).  With
    ``variation`` the aux plane carries three more per-lane device rows
    (2 = alpha, 3 = B_k, 4 = g_scale) and the RK4 body reads those instead
    of the compile-time scalars — process corners become launch data."""
    s = state_ref[...]
    m1 = (s[0], s[1], s[2])
    m2 = (s[3], s[4], s[5])
    v = s[6]
    seeds = seeds_ref[0]
    sigma = aux_ref[0]
    budget = aux_ref[1]
    lane_params = ((aux_ref[2], aux_ref[3], aux_ref[4]) if variation
                   else None)
    crossed = jnp.full_like(v, float(n_steps))

    body = _make_body(p, dt, n_steps, switch_threshold, sigma, seeds, v,
                      budget=budget, lane_params=lane_params)
    if chunk <= 0:
        m1, m2, crossed = jax.lax.fori_loop(0, n_steps, body,
                                            (m1, m2, crossed))
    else:
        n_chunks = -(-n_steps // chunk)

        def cond(carry):
            c, m1, m2, crossed = carry
            done = (crossed < float(n_steps)) | (
                jnp.asarray(c * chunk, jnp.float32) >= budget)
            return (c < n_chunks) & ~jnp.all(done)

        def chunk_body(carry):
            c, m1, m2, crossed = carry

            def inner(j, cc):
                return body(c * chunk + j, cc)

            m1, m2, crossed = jax.lax.fori_loop(0, chunk, inner,
                                                (m1, m2, crossed))
            return c + 1, m1, m2, crossed

        _, m1, m2, crossed = jax.lax.while_loop(
            cond, chunk_body, (0, m1, m2, crossed))
    out = jnp.stack([m1[0], m1[1], m1[2], m2[0], m2[1], m2[2], v, crossed])
    out_ref[...] = out


def llg_rk4_pallas(
    state: jnp.ndarray,           # (8, cells) f32 — see module docstring
    p: DeviceParams,
    dt: float,
    n_steps: int,
    switch_threshold: float = 0.9,
    interpret: bool = False,
    thermal_sigma=0.0,            # scalar or (cells,) f32 per-lane Brown sigma
    seeds: jnp.ndarray | None = None,   # (cells,) or (1, cells) uint32
    step_budget=None,             # optional (cells,) f32 per-lane step budget
    chunk: int = 0,               # >0: early-exit chunk size (steps)
    lane_params=None,             # optional (VAR_ROWS, cells) f32 rows:
                                  # alpha, B_k [T], g_scale — the variation
                                  # plane (DESIGN.md §9)
) -> jnp.ndarray:
    rows, cells = state.shape
    assert rows == ROWS and cells % CELL_TILE == 0, state.shape

    if seeds is None:
        # deterministic path: no noise inputs, fixed horizon — the compiled
        # graph is identical to the pre-thermal kernel
        assert isinstance(thermal_sigma, (int, float)) and thermal_sigma == 0.0, \
            "thermal path needs per-cell stream seeds"
        assert step_budget is None, "step budgets ride the thermal kernel"
        assert lane_params is None, "the variation plane rides the thermal kernel"
        kern = functools.partial(
            _llg_kernel, p=p, dt=dt, n_steps=n_steps,
            switch_threshold=switch_threshold,
        )
        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((ROWS, cells), jnp.float32),
            grid=(cells // CELL_TILE,),
            in_specs=[pl.BlockSpec((ROWS, CELL_TILE), lambda i: (0, i))],
            out_specs=pl.BlockSpec((ROWS, CELL_TILE), lambda i: (0, i)),
            interpret=interpret,
        )(state)

    seeds = seeds.reshape(1, cells).astype(jnp.uint32)
    sigma = jnp.broadcast_to(
        jnp.asarray(thermal_sigma, jnp.float32), (cells,))
    if step_budget is None:
        budget = jnp.full((cells,), float(n_steps), jnp.float32)
    else:
        budget = jnp.broadcast_to(
            jnp.asarray(step_budget, jnp.float32), (cells,))
    variation = lane_params is not None
    if variation:
        lp = jnp.asarray(lane_params, jnp.float32)
        assert lp.shape == (VAR_ROWS, cells), (lp.shape, cells)
        aux = jnp.concatenate([jnp.stack([sigma, budget]), lp])
        aux_rows = VAR_AUX_ROWS
    else:
        aux = jnp.stack([sigma, budget])                 # (AUX_ROWS, cells)
        aux_rows = AUX_ROWS
    kern = functools.partial(
        _llg_thermal_kernel, p=p, dt=dt, n_steps=n_steps,
        switch_threshold=switch_threshold, chunk=int(chunk),
        variation=variation,
    )
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((ROWS, cells), jnp.float32),
        grid=(cells // CELL_TILE,),
        in_specs=[
            pl.BlockSpec((ROWS, CELL_TILE), lambda i: (0, i)),
            pl.BlockSpec((1, CELL_TILE), lambda i: (0, i)),
            pl.BlockSpec((aux_rows, CELL_TILE), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((ROWS, CELL_TILE), lambda i: (0, i)),
        interpret=interpret,
    )(state, seeds, aux)

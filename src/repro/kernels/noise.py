"""Counter-based Gaussian noise shared by the Pallas kernel and the oracle.

The thermal field inside ``llg_rk4.py`` cannot use ``jax.random`` (threefry
needs key state threaded through the fori_loop and is ~20x the flops of the
RK4 update itself), so we use a stateless counter-based generator: every
draw is ``mix(cell_seed + counter)`` where ``mix`` is a full-avalanche
32-bit integer hash (lowbias32 constants) and the counter encodes
(step, draw-index).  Properties that matter here:

* **stateless** — noise at step ``i`` is a pure function of (seed, i), so
  the kernel's ``fori_loop`` carries no RNG state and the pure-jnp oracle in
  ``ref.py`` can reproduce the *identical* stream: thermal trajectories are
  testable with ``allclose`` at a fixed seed, not just statistically.
* **per-lane independent** — each cell (lane) owns a distinct uint32 seed
  (``cell_seeds``), so every Monte-Carlo sample in a packed campaign tile is
  an independent thermal realization.
* **cheap on the VPU** — a normal pair costs 2 integer hashes (~12 int ops)
  + one Box-Muller (log/sqrt/sincos), all element-wise 32-bit ops, vs
  threefry's 20 rounds + key management.

Statistical quality: lowbias32 passes full-avalanche tests; this is thermal
noise for a Langevin integrator, not cryptography — what matters is that
per-(seed, counter) outputs are decorrelated, which a full-avalanche mixer
guarantees to well below the sigma of the physics.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_GOLD = np.uint32(0x9E3779B9)       # 2^32 / phi — Weyl counter increment
_M1 = np.uint32(0x21F0AAAD)         # lowbias32 (Degski / TheIronBorn) v2
_M2 = np.uint32(0x735A2D97)
_TWO_PI = 6.283185307179586
_INV_2_24 = float(2.0**-24)


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """Full-avalanche 32-bit mixer (lowbias32). x: uint32 array."""
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 15)
    x = x * _M2
    x = x ^ (x >> 15)
    return x


def cell_seeds(base_seed: int, cells: int) -> jnp.ndarray:
    """(cells,) uint32 — one independent stream seed per cell/lane.

    splitmix-style: mix a Weyl sequence off the base seed so consecutive
    cells land in decorrelated regions of counter space.
    """
    idx = jnp.arange(cells, dtype=jnp.uint32)
    return mix32(mix32(np.uint32(base_seed & 0xFFFFFFFF) + idx * _GOLD))


_SLICE_GOLD = 0x9E3779B1        # odd Weyl constants: campaign seed ...
_SLICE_OFF = 0x85EB_CA6B        # ... and per-temperature-slice offset


def slice_seeds(base_seed: int, slice_index: int, cells: int) -> jnp.ndarray:
    """(cells,) uint32 streams for slice ``slice_index`` of a campaign.

    Offsets the base seed by a per-slice Weyl constant before the per-lane
    split, so (for the campaign engine) the temperature slices of a fused
    (T x V x S) plane never share counters — and a fused launch consumes
    exactly the streams the old per-temperature launches did (the packing
    bit-compat ``tests/test_fused_engine.py`` pins)."""
    base = (base_seed * _SLICE_GOLD + slice_index * _SLICE_OFF) & 0xFFFFFFFF
    return cell_seeds(base, cells)


def _uniform24(h: jnp.ndarray) -> jnp.ndarray:
    """uint32 hash -> f32 uniform in (0, 1] using the top 24 bits."""
    return ((h >> np.uint32(8)).astype(jnp.float32) + 1.0) * _INV_2_24


def normal_pair(seed: jnp.ndarray, counter: jnp.ndarray):
    """Two independent standard normals per lane via Box-Muller.

    seed: (n,) uint32 per-lane stream seeds; counter: scalar uint32 draw
    counter (same for all lanes).  Returns (z0, z1) f32 arrays of shape (n,).

    The counter is avalanche-mixed *before* combining with the lane seed:
    with a plain Weyl offset (``seed + counter*GOLD``), two lanes whose
    seeds differ by k*GOLD would consume time-shifted copies of the same
    stream.  Hashing the counter first makes persistent cross-lane overlap
    require mix32 collisions, not arithmetic coincidence.
    """
    base = seed ^ mix32(counter * _GOLD + np.uint32(1))
    h1 = mix32(base)
    h2 = mix32(base ^ _M2)
    u1 = _uniform24(h1)
    u2 = _uniform24(h2)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    ang = _TWO_PI * u2
    return r * jnp.cos(ang), r * jnp.sin(ang)


def thermal_draws(seed: jnp.ndarray, step: jnp.ndarray):
    """Six standard normals per lane for one LLG step.

    Returns ((x1, y1, z1), (x2, y2, z2)) — the per-component thermal field
    directions for sublattice 1 and 2 (scale by sigma at the call site).
    ``step`` may be a traced loop index (any integer dtype).
    """
    step_u = (jnp.asarray(step).astype(jnp.uint32)) * np.uint32(3)
    a0, b0 = normal_pair(seed, step_u)
    a1, b1 = normal_pair(seed, step_u + np.uint32(1))
    a2, b2 = normal_pair(seed, step_u + np.uint32(2))
    return (a0, a1, a2), (b0, b1, b2)

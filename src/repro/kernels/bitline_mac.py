"""Pallas TPU kernel: analog bit-line MAC (in-memory GEMV/GEMM) with ADC.

Functional model of the paper's multi-row charge-sharing compute: activated
word-lines drive read voltages V (batch, rows);每 column's bit-line sums the
cell currents I = V @ G (G = per-cell conductance from the stored bit and
the device TMR); a flash ADC quantizes the analog column current.

Shaped as a tiled MXU matmul with an epilogue:
  grid (M/BM, N/BN, K/BK); f32 VMEM accumulator scratch; on the last K step
  the accumulator passes through the ADC model (clip + uniform quantize)
  and is written out.  BM=BN=BK=128 keeps the MXU dims hardware-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = BN = BK = 128


def _mac_kernel(v_ref, g_ref, o_ref, acc_ref, *, nk: int, adc_bits: int,
                i_max: float):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        v_ref[...], g_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        i_bl = acc_ref[...]
        if adc_bits > 0:
            levels = float(2**adc_bits - 1)
            x = jnp.clip(i_bl / i_max, 0.0, 1.0)
            i_bl = jnp.round(x * levels) / levels * i_max
        o_ref[...] = i_bl.astype(o_ref.dtype)


def bitline_mac_pallas(
    v: jnp.ndarray,               # (M, K) read voltages (batch x rows)
    g: jnp.ndarray,               # (K, N) cell conductances (rows x cols)
    adc_bits: int = 0,            # 0 = ideal (no quantization)
    i_max: float = 1.0,           # ADC full-scale current
    interpret: bool = False,
) -> jnp.ndarray:
    M, K = v.shape
    K2, N = g.shape
    assert K == K2 and M % BM == 0 and N % BN == 0 and K % BK == 0, (v.shape, g.shape)
    from jax.experimental.pallas import tpu as pltpu

    nk = K // BK
    kern = functools.partial(_mac_kernel, nk=nk, adc_bits=adc_bits, i_max=i_max)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        grid=(M // BM, N // BN, nk),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(v, g)

"""Pallas TPU kernel: analog bit-line MAC (in-memory GEMV/GEMM) with ADC.

Functional model of the paper's multi-row charge-sharing compute: activated
word-lines drive read voltages V (batch, rows);每 column's bit-line sums the
cell currents I = V @ G (G = per-cell conductance from the stored bit and
the device TMR); a flash ADC quantizes the analog column current.

ADC transfer function: a *signed* symmetric mid-tread quantizer.  With the
differential 2-cell weight encoding (``imc.analog_pipeline``) the sense node
sees I+ - I-, which is negative for negative partial sums, so the full scale
is [-i_max, +i_max] with 2^(bits-1)-1 levels per side (one code is shared by
+-0).  Currents beyond the full scale clip — choosing ``i_max`` is part of
the read-driver co-design (see DESIGN.md §6).

Shaped as a tiled MXU matmul with an epilogue:
  grid (M/BM, N/BN, K/BK); f32 VMEM accumulator scratch; on the last K step
  the accumulator passes through the ADC model (clip + uniform quantize)
  and is written out.  BM=BN=BK=128 keeps the MXU dims hardware-aligned;
  non-128-multiple operands are zero-padded (zero voltage drives no current,
  so padding is exact) and the result is sliced back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = BN = BK = 128


def adc_quantize(i_bl: jnp.ndarray, adc_bits: int, i_max: float) -> jnp.ndarray:
    """Signed symmetric mid-tread ADC: clip to [-i_max, i_max], quantize to
    2^(bits-1)-1 uniform levels per side.  Shared by the kernel epilogue and
    the jnp oracle (``ref.ref_bitline_mac``) so they cannot drift."""
    if adc_bits <= 0:
        return i_bl
    assert adc_bits >= 2, f"signed ADC needs >= 2 bits, got {adc_bits}"
    half = float(2 ** (adc_bits - 1) - 1)
    x = jnp.clip(i_bl / i_max, -1.0, 1.0)
    return jnp.round(x * half) / half * i_max


def _pad2(x: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    pm, pn = -x.shape[0] % m, -x.shape[1] % n
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _mac_kernel(v_ref, g_ref, o_ref, acc_ref, *, nk: int, adc_bits: int,
                i_max: float):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        v_ref[...], g_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        i_bl = adc_quantize(acc_ref[...], adc_bits, i_max)
        o_ref[...] = i_bl.astype(o_ref.dtype)


def bitline_mac_pallas(
    v: jnp.ndarray,               # (M, K) read voltages (batch x rows)
    g: jnp.ndarray,               # (K, N) cell conductances (rows x cols)
    adc_bits: int = 0,            # 0 = ideal (no quantization)
    i_max: float = 1.0,           # ADC full-scale current (per side)
    interpret: bool = False,
) -> jnp.ndarray:
    M, K = v.shape
    K2, N = g.shape
    assert K == K2, (v.shape, g.shape)
    assert adc_bits == 0 or adc_bits >= 2, adc_bits
    from jax.experimental.pallas import tpu as pltpu

    v = _pad2(v, BM, BK)
    g = _pad2(g, BK, BN)
    mp, kp = v.shape
    _, np_ = g.shape
    nk = kp // BK
    kern = functools.partial(_mac_kernel, nk=nk, adc_bits=adc_bits, i_max=i_max)
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=(mp // BM, np_ // BN, nk),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(v, g)
    if (mp, np_) != (M, N):
        out = out[:M, :N]
    return out

"""WER-margined write pulses — the campaign engine's IMC client.

The seed model sized the array write pulse from the *mean* deterministic
switching time (``simulate_write`` x a 2% margin).  That is optimistic: at
300 K the thermal tail of the switching-time distribution is what sets the
pulse a pipelined controller must schedule (paper Sec. III-B — writes hide
behind logic ops only if the pulse actually covers the tail).  This module
turns a write-error-rate target into a pulse width by querying a thermal
Monte-Carlo campaign over a pulse ladder, and feeds it to the subarray
timing model (``circuit.subarray.make_subarray(..., wer_target=...)``).

Campaign results are cached on disk (content-keyed), so hierarchy builds
after the first pay only the cache read.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

from repro.core.params import (AFMTJ_PARAMS, MTJ_PARAMS, DeviceParams,
                               VariationSpec)

# Pulse ladders bracketing each device's thermal switching tail; the solver
# returns the smallest rung with WER <= target, so rung spacing is the
# pulse-width quantization of the margin (controller clock granularity).
_LADDERS = {
    "afmtj": tuple(x * 1e-12 for x in (120, 160, 200, 250, 300, 400, 600)),
    "mtj": tuple(x * 1e-12 for x in (800, 1200, 1600, 2200, 3000, 4500, 6000)),
}
# Per-device campaign time steps (MTJ reversal is ~10x slower, so a coarser
# step keeps its much longer integration horizons tractable).
DEVICE_DT = {"afmtj": 0.1e-12, "mtj": 0.2e-12}


def _params_for(kind: str) -> DeviceParams:
    return AFMTJ_PARAMS if kind == "afmtj" else MTJ_PARAMS


@functools.lru_cache(maxsize=None)
def wer_margined_pulse(
    kind: str,
    v_write: float = 1.0,
    wer_target: float = 1e-2,
    n_samples: int = 128,
    seed: int = 0,
    use_cache: bool = True,
    ladder: Optional[Tuple[float, ...]] = None,
    temperatures: Optional[Tuple[float, ...]] = None,
    variation: Optional[VariationSpec] = None,
) -> float:
    """Smallest ladder pulse [s] with WER <= ``wer_target`` at ``v_write``.

    One campaign covers the whole ladder for either device kind: the pulse
    axis is first-crossing post-processing (``campaign.grid``), so the
    engine integrates once to the longest rung.  The MTJ baseline rides the
    engine's single-sublattice scan tile (``kernels.ref.ref_llg_rk4``) — same
    grids, caching and reductions, no per-rung re-integration (the old
    ``write_error_rate_scan`` ladder walk paid one integration per rung).
    Resolution of the WER estimate is 1/n_samples, so ask for more samples
    when targeting rates below ~1e-2.  Raises ValueError when no ladder
    rung meets the target.

    ``temperatures`` margins the pulse over an *operating range* (the
    variation-resilient drivers of the companion Choudhary & Adegbija
    paper schedule against corner temperatures, not just nominal): the
    whole (T x pulse-ladder) grid rides one fused engine launch
    (temperature is a per-lane kernel input, DESIGN.md §8) and the
    returned pulse is the smallest rung meeting the WER target at *every*
    temperature.  Default: the device's nominal temperature only.

    ``variation`` widens the worst case over *process corners* too
    (DESIGN.md §9): the (corner x T x pulse-ladder) grid still rides one
    fused launch — corners are per-lane kernel data — and the returned
    pulse is the smallest rung meeting the WER target at every (corner,
    temperature) cell, the margin the companion paper's variation-
    resilient write drivers actually schedule.
    """
    # lazy: keep `import repro.imc` free of the campaign/kernels stack
    # (closed-form consumers never pay for Pallas at package-import time)
    from repro.campaign.engine import run_campaign
    from repro.campaign.grid import CampaignGrid

    p = _params_for(kind)
    pulses = ladder or _LADDERS[kind]
    temps = (tuple(float(t) for t in temperatures) if temperatures
             else (p.temperature,))

    grid = CampaignGrid(voltages=(float(v_write),), pulse_widths=pulses,
                        temperatures=temps, n_samples=n_samples,
                        dt=DEVICE_DT[kind], seed=seed, variation=variation)
    res = run_campaign(p, grid, use_cache=use_cache)
    # corner_index=None -> worst corner at each pulse (no-op when the grid
    # has no variation axis); the outer max covers the temperature range
    return max(res.pulse_for_wer(wer_target, t_index=ti, v_index=0,
                                 corner_index=None)
               for ti in range(len(temps)))

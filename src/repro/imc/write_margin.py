"""WER-margined write pulses — the campaign engine's IMC client.

The seed model sized the array write pulse from the *mean* deterministic
switching time (``simulate_write`` x a 2% margin).  That is optimistic: at
300 K the thermal tail of the switching-time distribution is what sets the
pulse a pipelined controller must schedule (paper Sec. III-B — writes hide
behind logic ops only if the pulse actually covers the tail).  This module
turns a write-error-rate target into a pulse width by querying a thermal
Monte-Carlo campaign over a pulse ladder, and feeds it to the subarray
timing model (``circuit.subarray.make_subarray(..., wer_target=...)``).

Campaign results are cached on disk (content-keyed), so hierarchy builds
after the first pay only the cache read.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

from repro.core.params import AFMTJ_PARAMS, MTJ_PARAMS, DeviceParams

# Pulse ladders bracketing each device's thermal switching tail; the solver
# returns the smallest rung with WER <= target, so rung spacing is the
# pulse-width quantization of the margin (controller clock granularity).
_LADDERS = {
    "afmtj": tuple(x * 1e-12 for x in (120, 160, 200, 250, 300, 400, 600)),
    "mtj": tuple(x * 1e-12 for x in (800, 1200, 1600, 2200, 3000, 4500, 6000)),
}
_DT = {"afmtj": 0.1e-12, "mtj": 0.2e-12}


def _params_for(kind: str) -> DeviceParams:
    return AFMTJ_PARAMS if kind == "afmtj" else MTJ_PARAMS


@functools.lru_cache(maxsize=None)
def wer_margined_pulse(
    kind: str,
    v_write: float = 1.0,
    wer_target: float = 1e-2,
    n_samples: int = 128,
    seed: int = 0,
    use_cache: bool = True,
    ladder: Optional[Tuple[float, ...]] = None,
) -> float:
    """Smallest ladder pulse [s] with WER <= ``wer_target`` at ``v_write``.

    AFMTJ: one campaign covers the whole ladder (the pulse axis is free —
    see ``campaign.grid``).  MTJ: the campaign kernel is dual-sublattice
    only, so the single-FM device walks the ladder through the
    ``write_error_rate_scan`` path instead — correct physics, but one
    integration per rung (minutes cold; in-process lru-cached).  Resolution
    of the WER estimate is 1/n_samples either way, so ask for more samples
    when targeting rates below ~1e-2.  Raises ValueError when no ladder
    rung meets the target.
    """
    p = _params_for(kind)
    pulses = ladder or _LADDERS[kind]

    if p.n_sublattices != 2:
        from repro.core.montecarlo import write_error_rate_scan

        for pulse in sorted(pulses):
            w = float(write_error_rate_scan(p, float(v_write), float(pulse),
                                            n_samples=n_samples, dt=_DT[kind],
                                            seed=seed))
            if w <= wer_target:
                return float(pulse)
        raise ValueError(
            f"no {kind} ladder pulse meets WER<={wer_target:g} at "
            f"{v_write} V; widen the ladder or raise the voltage")

    from repro.campaign.engine import run_campaign
    from repro.campaign.grid import CampaignGrid

    grid = CampaignGrid(voltages=(float(v_write),), pulse_widths=pulses,
                        temperatures=(p.temperature,), n_samples=n_samples,
                        dt=_DT[kind], seed=seed)
    res = run_campaign(p, grid, use_cache=use_cache)
    return res.pulse_for_wer(wer_target, t_index=0, v_index=0)

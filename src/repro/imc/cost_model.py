"""Device cost model: price every serving token in AFMTJ/MTJ/CPU time.

The serving subsystem (DESIGN.md §11) replaces wall-clock with a *simulated
device clock*: the engine reports what a prefill/decode step computed (weight
MACs, KV-cache element reads/writes — ``StepCounts``) and a
``DeviceCostModel`` converts those op counts into seconds and joules on one
technology.  Three technologies share the interface:

* ``afmtj`` / ``mtj`` — per-unit prices derived from the measured IMC
  hierarchy (``imc.hierarchy.build_hierarchy`` -> MM-level
  ``SubarrayTimings``), the same crossbar mapping ``imc.mapping`` uses for
  the archmap bench: weight GEMVs run in crossbar mode (a whole XBARxXBAR
  tile per ``t_read + ADC_T``), KV-cache appends are row-serial writes
  (``t_write`` per XBAR-wide row across the parallel arrays).  The measured
  ``wer_target`` / ``write_percentile`` / ``read_percentile`` /
  ``offset_sigma`` knobs from DESIGN.md §7/§9/§10 ride through to
  ``build_hierarchy`` untouched, and an optional ``RefreshPolicy`` charges
  the scrub duty cycle as a bandwidth tax on every op plus a standing
  energy rate.
* ``cpu`` — the A72 baseline (``imc.cpu_model``): each per-token term is
  priced at its own roofline bottleneck (DRAM stream vs SIMD issue), the
  decode-GEMV model of ``imc.mapping.map_arch_decode``.

Because a decode token's cost is affine in its context position
(weights + KV-append are constant, attention KV reads grow linearly), every
model also exposes ``token_prices`` — the ``(t_tok, t_pos)`` coefficients
the event-driven serving simulator (``launch.simulate``) integrates in
closed form over millions of requests.

This module imports no JAX at module scope (the hierarchy build is lazy),
so the scheduler/traffic/simulator stack stays importable without it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Optional, Sequence

if TYPE_CHECKING:  # pragma: no cover - import cycle / lazy-JAX guard
    from repro.configs.base import ArchConfig
    from repro.imc.faults import FaultSpec, RepairPolicy
    from repro.imc.read_path import RefreshPolicy

TECHNOLOGIES = ("afmtj", "mtj", "cpu")


# --------------------------------------------------------------------------
# op counts: what one engine step computed (technology-independent)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TokenCounts:
    """Per-token op counts of one architecture (its serving signature).

    ``mac_weights``: weight MACs per token = active parameters (every active
    param multiplies the token's activation once — the weight-stationary
    GEMV the crossbar performs natively).  ``kv_elems``: KV-cache elements
    appended per token (2 x n_kv_heads x d_head per attention layer); each
    *prior* token's KV entry is read back once per generated token
    (causal attention), which is the position-linear term.
    """

    mac_weights: float
    kv_elems: float


@dataclasses.dataclass(frozen=True)
class StepCounts:
    """Op counts of one engine step (prefill wave or decode step)."""

    tokens: int              # tokens produced (live slots)
    mac_weights: float       # weight MACs executed
    kv_write_elems: float    # KV elements appended
    kv_read_elems: float     # KV elements read (attention over history)

    def __add__(self, o: "StepCounts") -> "StepCounts":
        return StepCounts(self.tokens + o.tokens,
                          self.mac_weights + o.mac_weights,
                          self.kv_write_elems + o.kv_write_elems,
                          self.kv_read_elems + o.kv_read_elems)


ZERO_COUNTS = StepCounts(0, 0.0, 0.0, 0.0)


def per_token_counts(cfg: "ArchConfig") -> TokenCounts:
    """Serving signature of an architecture.

    SSM mixers keep constant state (no growing KV); their state update is
    folded into the weight-MAC term via ``active_param_count`` — the model
    deliberately charges no position-linear cost for them, which is exactly
    the long-context argument for those architectures (DESIGN.md §3).
    Cross-attention KV (encdec) is static per request and also not grown.
    """
    reps = cfg.n_pattern_repeats
    attn_layers = sum(reps for mixer, _ in cfg.pattern
                      if mixer.startswith("attn"))
    kv = 2.0 * cfg.n_kv_heads * cfg.d_head * attn_layers
    return TokenCounts(mac_weights=float(cfg.active_param_count()),
                       kv_elems=float(kv))


def prefill_step_counts(tc: TokenCounts,
                        hist_lens: Sequence[int]) -> StepCounts:
    """One recompute-on-join prefill wave over the live slots' histories.

    Every history token runs the full weight GEMV and writes its KV entry;
    token ``i`` of a length-``L`` history attends to its ``i`` predecessors
    (the ``L*(L-1)/2`` triangle).  The wave's output token per slot is the
    argmax of the last position — it costs nothing extra here; its own
    forward is the next step.
    """
    toks = sum(int(h) for h in hist_lens)
    tri = sum(int(h) * (int(h) - 1) / 2.0 for h in hist_lens)
    return StepCounts(tokens=len(list(hist_lens)),
                      mac_weights=tc.mac_weights * toks,
                      kv_write_elems=tc.kv_elems * toks,
                      kv_read_elems=tc.kv_elems * tri)


def decode_step_counts(tc: TokenCounts,
                       positions: Sequence[int]) -> StepCounts:
    """One decode step: each live slot forwards one token whose attention
    reads the slot's current history length (``positions``) of KV entries."""
    live = len(list(positions))
    pos_sum = float(sum(int(p) for p in positions))
    return StepCounts(tokens=live,
                      mac_weights=tc.mac_weights * live,
                      kv_write_elems=tc.kv_elems * live,
                      kv_read_elems=tc.kv_elems * pos_sum)


# --------------------------------------------------------------------------
# the cost model proper
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StepCost:
    t: float                 # simulated seconds
    e: float                 # joules


@dataclasses.dataclass(frozen=True)
class TokenPrices:
    """Affine per-token pricing for one (technology, architecture) pair.

    A decode token at context position ``p`` costs ``t_tok + t_pos * p``
    seconds (``e_tok + e_pos * p`` joules); a prefill over a length-``L``
    history costs ``L * t_tok + t_pos * L*(L-1)/2``.  These are exactly
    ``step_cost`` of the counting helpers above — the closed forms the
    event-driven simulator integrates per decode segment.
    """

    technology: str
    t_tok: float
    t_pos: float
    e_tok: float
    e_pos: float

    def decode_token(self, position: int) -> StepCost:
        return StepCost(self.t_tok + self.t_pos * position,
                        self.e_tok + self.e_pos * position)

    def prefill(self, hist_len: int) -> StepCost:
        tri = hist_len * (hist_len - 1) / 2.0
        return StepCost(self.t_tok * hist_len + self.t_pos * tri,
                        self.e_tok * hist_len + self.e_pos * tri)


@dataclasses.dataclass(frozen=True)
class DeviceCostModel:
    """Per-unit op prices for one technology (architecture-independent).

    ``step_cost`` prices an engine step's measured op counts;
    ``token_prices`` folds an architecture's ``TokenCounts`` into the
    affine per-token coefficients.  ``e_standing_rate`` is a standing
    power draw (refresh/scrub energy) charged per simulated second.
    """

    kind: str
    t_mac: float
    e_mac: float
    t_kv_write: float
    e_kv_write: float
    t_kv_read: float
    e_kv_read: float
    e_standing_rate: float = 0.0        # [W] scrub power, charged per second
    # provenance (reporting only): the hierarchy write-stage numbers behind
    # the prices, mirroring SystemResult's write provenance fields
    t_write_op: float = 0.0
    write_attempts: float = 1.0
    refresh_interval: float = math.inf
    array_yield: float = 1.0            # P(array usable) under fault/repair

    def step_cost(self, c: StepCounts) -> StepCost:
        t = (c.mac_weights * self.t_mac
             + c.kv_write_elems * self.t_kv_write
             + c.kv_read_elems * self.t_kv_read)
        e = (c.mac_weights * self.e_mac
             + c.kv_write_elems * self.e_kv_write
             + c.kv_read_elems * self.e_kv_read
             + t * self.e_standing_rate)
        return StepCost(t, e)

    def token_prices(self, tc: TokenCounts) -> TokenPrices:
        one = self.step_cost(StepCounts(1, tc.mac_weights, tc.kv_elems, 0.0))
        per_pos = self.step_cost(StepCounts(0, 0.0, 0.0, tc.kv_elems))
        return TokenPrices(self.kind, one.t, per_pos.t, one.e, per_pos.e)


def cpu_cost_model(cpu=None) -> DeviceCostModel:
    """A72 decode-GEMV pricing: each term at its own roofline bottleneck.

    Weights stream 1 B/MAC (int8) from DRAM vs SIMD MAC issue; KV entries
    stream 1 B/element.  Energy: DRAM line energy per byte + per-MAC core
    energy — the constants of ``imc.mapping.map_arch_decode``.
    """
    from repro.imc.cpu_model import CORTEX_A72

    cpu = cpu or CORTEX_A72
    t_byte = 1.0 / cpu.bw_dram
    t_mac_compute = 0.125 / (cpu.ipc * cpu.freq_hz)   # 16-lane SIMD int8
    e_byte = cpu.e_dram_line / cpu.line_bytes
    e_mac = 0.02e-12
    return DeviceCostModel(
        kind="cpu",
        t_mac=max(t_byte, t_mac_compute), e_mac=e_byte + e_mac,
        t_kv_write=t_byte, e_kv_write=e_byte,
        t_kv_read=max(t_byte, t_mac_compute), e_kv_read=e_byte + e_mac,
    )


def imc_cost_model(
    kind: str,
    v_write: float = 1.0,
    wer_target: Optional[float] = None,
    write_percentile: Optional[float] = None,
    read_percentile: Optional[float] = None,
    offset_sigma: float = 0.0,
    refresh: Optional["RefreshPolicy"] = None,
    resident_bytes: Optional[float] = None,
    faults: Optional["FaultSpec"] = None,
    repair: Optional["RepairPolicy"] = None,
) -> DeviceCostModel:
    """AFMTJ/MTJ crossbar pricing from the measured hierarchy timings.

    Weight MACs run in crossbar mode: an XBAR x XBAR tile GEMV costs one
    analog read + ADC conversion, with activation write-back pipelined at
    the 10% shadow (``imc.mapping``'s decode model); 8-bit weights occupy
    ``CELLS_PER_WEIGHT_8B`` cells.  KV appends are row-serial writes — one
    XBAR-wide row per ``t_write`` across ``IMC_PARALLEL_ARRAYS`` — which is
    where MTJ's nanosecond writes meet every generated token's KV entry and
    AFMTJ's picosecond writes hide.  KV reads are crossbar attention MACs,
    priced like weight MACs.

    ``refresh`` (+ ``resident_bytes``, the programmed footprint) charges a
    measured scrub policy (DESIGN.md §10): every op is stretched by the
    scrub duty cycle and the scrub pass energy becomes a standing rate.

    ``faults`` (+ optional ``repair``) charges the hard-defect model
    (DESIGN.md §13) the same way: arrays whose defects exceed the repair
    capacity are fused out, so effective parallelism shrinks by the array
    yield (latency x overhead/yield) and every op pays the spare-line/ECC
    cell overhead in area->energy.  Defaults off keep nominal bit-for-bit.
    """
    from repro.imc.hierarchy import build_hierarchy
    from repro.imc.mapping import (ADC_E_PER_COL, ADC_T, CELLS_PER_WEIGHT_8B,
                                   IMC_PARALLEL_ARRAYS, XBAR,
                                   fault_cost_factors)

    hier = build_hierarchy(kind, v_write=v_write, wer_target=wer_target,
                           write_percentile=write_percentile,
                           read_percentile=read_percentile,
                           offset_sigma=offset_sigma)
    tm = hier.levels["MM"].timings
    cells = float(CELLS_PER_WEIGHT_8B)
    par = float(XBAR * IMC_PARALLEL_ARRAYS)

    # crossbar-mode MAC: tiles = macs*cells/XBAR^2, waves = tiles/PARALLEL
    t_mac = cells * (tm.t_read + ADC_T + 0.1 * tm.t_write) / (XBAR * par)
    e_mac = (cells * tm.e_read_bit
             + cells / XBAR * ADC_E_PER_COL
             + cells / XBAR * tm.e_write_bit * 0.02)
    # row-serial KV append: 8 cells/element, XBAR*PARALLEL cells per t_write
    t_kv_write = cells * tm.t_write / par
    e_kv_write = cells * tm.e_write_bit
    # crossbar attention MAC over the KV arrays
    t_kv_read = cells * (tm.t_read + ADC_T) / (XBAR * par)
    e_kv_read = cells * tm.e_read_bit + cells / XBAR * ADC_E_PER_COL

    duty_stretch, e_rate, interval = 1.0, 0.0, math.inf
    if refresh is not None and math.isfinite(refresh.interval):
        if resident_bytes is None:
            raise ValueError("refresh pricing needs resident_bytes "
                             "(the programmed footprint the scrub walks)")
        interval = refresh.interval
        rows_per_array = resident_bytes * 8.0 / par
        t_pass = rows_per_array * (tm.t_read + tm.t_write)
        duty = min(t_pass / interval, 0.95)
        duty_stretch = 1.0 / (1.0 - duty)
        e_pass = resident_bytes * 8.0 * (tm.e_read_bit + tm.e_write_bit)
        e_rate = e_pass / interval

    array_yield, cell_ovh, fault_stretch = fault_cost_factors(faults, repair)

    return DeviceCostModel(
        kind=kind,
        t_mac=t_mac * duty_stretch * fault_stretch, e_mac=e_mac * cell_ovh,
        t_kv_write=t_kv_write * duty_stretch * fault_stretch,
        e_kv_write=e_kv_write * cell_ovh,
        t_kv_read=t_kv_read * duty_stretch * fault_stretch,
        e_kv_read=e_kv_read * cell_ovh,
        e_standing_rate=e_rate,
        t_write_op=tm.t_write, write_attempts=tm.write_attempts,
        refresh_interval=interval, array_yield=array_yield,
    )


def device_cost_model(kind: str, **kw) -> DeviceCostModel:
    """One entry point over the three technologies.

    ``kind`` in ``TECHNOLOGIES``; keyword knobs are forwarded to
    ``imc_cost_model`` (ignored for ``cpu``, which takes only ``cpu=``).
    """
    if kind == "cpu":
        return cpu_cost_model(cpu=kw.get("cpu"))
    if kind not in ("afmtj", "mtj"):
        raise ValueError(f"unknown technology {kind!r}; "
                         f"choose from {TECHNOLOGIES}")
    kw.pop("cpu", None)
    return imc_cost_model(kind, **kw)

"""End-to-end functional analog MVM through the Pallas bitline/XNOR kernels.

This is the read-path counterpart of the write-path campaign engine
(``repro.campaign``): instead of *timing* the crossbar GEMV with closed-form
algebra (``imc.mapping``), it actually **computes** one — programming a
weight matrix into per-cell conductances from the device TMR, driving the
word lines with activation-scaled read voltages, accumulating bit-line
currents in the Pallas MXU kernel (``kernels.bitline_mac``), attenuating
per-column for IR drop (``circuit.bitline.column_ir_drop``), and quantizing
through the signed ADC — so the repo can answer "is the computed result
numerically usable", not just "how fast is it".

Signal chain (DESIGN.md §6):

  1. **Programming** — differential 2-cell encoding.  Weights are normalized
     to [-1, 1] by ``w_scale = max|w|`` and mapped linearly onto the
     *effective* cell conductance span [G_AP, G_P] (junction through the
     access transistor): the positive cell stores max(w, 0), the negative
     cell max(-w, 0), both riding on the G_AP floor.  Programming is
     write-verify pre-compensated (the linear map targets effective
     conductance), so device-to-device variation — a single-corner
     ``core.params.VariationSpec`` whose junction resistance factor
     perturbs the programmed conductance (``g_sigma`` survives as a
     deprecated alias that constructs the equivalent spec) — is the
     residual programming error; cells whose
     write-verify attempt budget ran out (``write_ber``, measured by
     ``imc.write_path`` — DESIGN.md §7) stay at the erased G_AP floor.
  2. **IR drop** — each differential line attenuates by its own column
     factor (heavier-loaded columns sag more).  The *mean* factor is a
     one-point gain calibration (divided out at decode); the per-column and
     pos/neg spread remains as gain error.
  3. **MVM** — I = V @ G_diff on the MXU, where G_diff = G+ - G- is the
     differential conductance the sense node sees (linearity makes one
     kernel pass over G_diff exact for the two-array subtraction).
  4. **ADC** — signed symmetric quantizer, full scale auto-sized to
     ``full_scale_sigmas`` column-current standard deviations (the
     read-driver co-design knob: too small clips, too large wastes codes).

The batch (word-line drive) axis is embarrassingly parallel, so ``cells``
shards across devices with ``shard_map`` exactly like the campaign engine —
weights replicated (they are *resident* in the arrays), activations split.

The 1-bit path (``binary_matmul``) binarizes both operands to +-1 and runs
the XNOR-popcount kernel (``kernels.xnor_gemm``) with per-column |w| scales
— the paper's *bnn* mode applied to a projection.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.circuit.bitline import BitlineParams, cell_conductance, column_ir_drop
from repro.core.params import (AFMTJ_PARAMS, MTJ_PARAMS, DeviceParams,
                               VariationSpec)
from repro.imc import faults as hard_faults
from repro.imc.faults import FaultSpec, RepairPolicy
from repro.kernels.bitline_mac import bitline_mac_pallas
from repro.kernels.ops import _default_interpret
from repro.kernels.xnor_gemm import xnor_gemm_pallas


@dataclasses.dataclass(frozen=True)
class AnalogConfig:
    """Read/write-path non-ideality knobs (the accuracy surface axes)."""

    adc_bits: int = 6              # 0 = ideal ADC (no quantization)
    tmr: Optional[float] = None    # device TMR override (None = device default)
    v_read: float = 0.1            # DAC full-scale read voltage [V]
    g_sigma: float = 0.0           # DEPRECATED alias: lognormal D2D junction
                                   # conductance sigma — internally rewritten
                                   # to ``VariationSpec.from_g_sigma`` (with a
                                   # DeprecationWarning); set ``variation``
    ir_drop: bool = True           # per-column bit-line IR attenuation
    full_scale_sigmas: float = 4.0 # ADC full scale in column-current sigmas
    seed: int = 0                  # programming-variation draw
    write_ber: float = 0.0         # residual write-error rate: probability a
                                   # cell's write-verify budget ran out and it
                                   # still sits at the erased G_AP floor
                                   # (measured by ``imc.write_path``)
    # Single source of truth for D2D / process-corner draws (DESIGN.md §9):
    # a single-corner VariationSpec whose junction resistance factor
    # (systematic r_factor x lognormal sigma_r) perturbs the programmed
    # junction conductance — same spec, same counter-RNG streams as the
    # write-path and campaign-engine variation planes.
    variation: Optional[VariationSpec] = None
    # Hard-defect model (DESIGN.md §13): stuck-at / dead-line / wear fault
    # planes drawn by ``imc.faults`` — presence of a spec switches the
    # fault machinery on (an all-zero-rate spec is the empty defect map,
    # bit-identical to ``None``), and the optional repair policy transforms
    # the defect map the way the array's repair controller would.
    faults: Optional[FaultSpec] = None
    repair: Optional[RepairPolicy] = None


@dataclasses.dataclass(frozen=True)
class ProgrammedArray:
    """A weight matrix resident in a differential crossbar pair."""

    g_diff: jnp.ndarray      # (K, N) effective differential conductance [S]
    w_scale: float           # |w|_max used for normalization
    g_fs: float              # unit-weight differential conductance G_P-G_AP [S]
    att_mean: float          # mean IR-drop factor (decode gain calibration)
    g_rms: float             # rms of g_diff (ADC full-scale sizing)
    dev: DeviceParams
    bl: BitlineParams
    cfg: AnalogConfig

    @property
    def shape(self) -> Tuple[int, int]:
        return self.g_diff.shape


def _device_for(kind: str, cfg: AnalogConfig) -> DeviceParams:
    dev = AFMTJ_PARAMS if kind == "afmtj" else MTJ_PARAMS
    if cfg.tmr is not None:
        dev = dataclasses.replace(dev, tmr=float(cfg.tmr))
    return dev


def _resolved_variation(cfg: AnalogConfig) -> Optional[VariationSpec]:
    """The D2D spec programming actually uses: ``cfg.variation``, or the
    deprecated ``g_sigma`` rewritten to its equivalent spec (the reciprocal
    of the spec's mean-conductance-preserving lognormal resistance draw is
    exactly the old mean-preserving lognormal on the conductance)."""
    if cfg.variation is not None:
        assert cfg.g_sigma == 0.0, (
            "set either AnalogConfig.variation or the deprecated g_sigma, "
            "not both — fold the D2D sigma into the spec's sigma_r")
        assert cfg.variation.n_corners == 1, (
            "read-path programming models one corner's array; sweep corners "
            "by programming one AnalogConfig per corner (spec.at_corner)")
        return cfg.variation
    if cfg.g_sigma > 0.0:
        warnings.warn(
            "AnalogConfig.g_sigma is deprecated; pass variation="
            "VariationSpec.from_g_sigma(g_sigma, seed) instead (single "
            "source of truth for D2D draws, DESIGN.md §9)",
            DeprecationWarning, stacklevel=3)
        return VariationSpec.from_g_sigma(cfg.g_sigma, seed=cfg.seed)
    return None


def program_weights(
    w: jnp.ndarray,                  # (K, N) float weights
    kind: str = "afmtj",
    cfg: AnalogConfig = AnalogConfig(),
    bl: Optional[BitlineParams] = None,
) -> ProgrammedArray:
    """Program ``w`` into a differential conductance pair (steps 1-2 above)."""
    assert w.ndim == 2, w.shape
    k_rows = w.shape[0]
    dev = _device_for(kind, cfg)
    bl = bl or BitlineParams(rows=k_rows)

    g_p_eff = float(cell_conductance(jnp.asarray(1.0 / dev.r_parallel), bl))
    g_ap_eff = float(cell_conductance(jnp.asarray(1.0 / dev.r_antiparallel), bl))
    g_fs = g_p_eff - g_ap_eff

    w = jnp.asarray(w, jnp.float32)
    w_scale = float(jnp.max(jnp.abs(w)))
    if w_scale == 0.0:
        w_scale = 1.0
    wn = w / w_scale
    tgt_pos = g_ap_eff + jnp.maximum(wn, 0.0) * g_fs
    tgt_neg = g_ap_eff + jnp.maximum(-wn, 0.0) * g_fs

    spec = _resolved_variation(cfg)
    if spec is not None:
        # variation lives on the junction (DESIGN.md §9): push the
        # write-verify target back through the access FET, apply the
        # spec's per-junction resistance factor (systematic corner x D2D
        # draw, same counter-RNG streams as the write path), come forward
        # again.  Streams 0/1 decorrelate the pos/neg array.
        corner = spec.corners[0]

        def perturb(tgt, stream):
            g_j = tgt / (1.0 - bl.r_access * tgt)
            r_f = spec.lane_factors(corner, tgt.size, stream=stream)[3]
            g_scale = jnp.asarray(
                (1.0 / r_f).reshape(tgt.shape), jnp.float32)
            return cell_conductance(g_j * g_scale, bl)

        g_pos, g_neg = perturb(tgt_pos, 0), perturb(tgt_neg, 1)
    else:
        g_pos, g_neg = tgt_pos, tgt_neg

    if cfg.faults is not None and cfg.faults.drift_sigma > 0.0:
        # slow conductance relaxation of the programmed targets; hard fault
        # codes and write-verify floors override it below
        g_pos = g_pos * hard_faults.drift_factors(
            cfg.faults, w.shape[0], w.shape[1], negative=False)
        g_neg = g_neg * hard_faults.drift_factors(
            cfg.faults, w.shape[0], w.shape[1], negative=True)

    if cfg.write_ber > 0.0:
        # residual write errors (imc.write_path, DESIGN.md §7): a cell whose
        # write-verify attempt budget ran out never left the erased state,
        # so it reads back at the G_AP floor instead of its target.  The
        # fold_in constant keeps the g_sigma draw stream unchanged.
        kber = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), 0x5EB)
        kb1, kb2 = jax.random.split(kber)
        fail_pos = jax.random.bernoulli(kb1, cfg.write_ber, tgt_pos.shape)
        fail_neg = jax.random.bernoulli(kb2, cfg.write_ber, tgt_neg.shape)
        g_pos = jnp.where(fail_pos, g_ap_eff, g_pos)
        g_neg = jnp.where(fail_neg, g_ap_eff, g_neg)

    col_ok = None
    if cfg.faults is not None:
        # hard defects (DESIGN.md §13), applied *before* IR drop so stuck-on
        # shorts load their columns and dead pairs unload theirs — exactly
        # mirroring the fused fake-analog decode order (floor -> stuck-on
        # -> dead inside ``pos_neg_conductance``)
        code, col_ok = cfg.faults.planes(w.shape[0], w.shape[1])
        if cfg.repair is not None:
            code, col_ok = hard_faults.apply_repair(code, col_ok, cfg.repair)
        g_pos, g_neg = hard_faults.apply_cell_faults(
            code, g_pos, g_neg, g_off=g_ap_eff, g_on=g_ap_eff + g_fs)

    att_mean = 1.0
    if cfg.ir_drop:
        att_pos = column_ir_drop(jnp.sum(g_pos, axis=0), bl)
        att_neg = column_ir_drop(jnp.sum(g_neg, axis=0), bl)
        g_pos = g_pos * att_pos[None, :]
        g_neg = g_neg * att_neg[None, :]
        att_mean = float(0.5 * (jnp.mean(att_pos) + jnp.mean(att_neg)))

    if col_ok is not None:
        # dead bit-line drivers: their columns read zero on both arrays and
        # the decode gain calibrates over *live* columns only
        g_pos = g_pos * col_ok[None, :]
        g_neg = g_neg * col_ok[None, :]
        if cfg.ir_drop:
            # same association as the no-fault mean so an all-live plane is
            # bit-identical: 0.5 * (sum_p/live + sum_n/live)
            live = max(float(jnp.sum(col_ok)), 1.0)
            att_mean = float(0.5 * (jnp.sum(att_pos * col_ok) / live
                                    + jnp.sum(att_neg * col_ok) / live))

    g_diff = g_pos - g_neg
    g_rms = float(jnp.sqrt(jnp.mean(g_diff * g_diff)))
    return ProgrammedArray(g_diff=g_diff, w_scale=w_scale, g_fs=g_fs,
                           att_mean=att_mean, g_rms=g_rms, dev=dev, bl=bl,
                           cfg=cfg)


def _usable_devices(m: int, devices: Optional[int]) -> int:
    n = jax.device_count() if devices is None else min(devices, jax.device_count())
    return max(min(n, m), 1)


@functools.partial(jax.jit, static_argnames=(
    "adc_bits", "i_max", "interpret", "n_dev"))
def _mvm_sharded(v, g, *, adc_bits: int, i_max: float, interpret: bool,
                 n_dev: int):
    """V @ G through the bitline kernel, batch rows sharded over devices."""

    def tile(vv, gg):
        return bitline_mac_pallas(vv, gg, adc_bits, i_max, interpret=interpret)

    if n_dev == 1:
        return tile(v, g)
    mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("batch",))
    # check_rep=False: shard_map has no replication rule for pallas_call
    fn = shard_map(tile, mesh=mesh, in_specs=(P("batch", None), P(None, None)),
                   out_specs=P("batch", None), check_rep=False)
    return fn(v, g)


def kernel_operands(
    arr: ProgrammedArray, x: jnp.ndarray
) -> Tuple[jnp.ndarray, float, float]:
    """The exact (v, i_max, x_scale) ``analog_matmul`` feeds the kernel —
    exposed so parity checks (``benchmarks.run`` mvm) reconstruct the same
    operands instead of copying the derivation.

    Activations map to bipolar word-line read voltages (``v_read`` full
    scale).  The ADC full scale comes from column-current statistics (an
    independence estimate), rounded to 2 significant digits to bound
    jit-cache churn across sweeps.
    """
    cfg = arr.cfg
    x = jnp.asarray(x, jnp.float32)
    x_scale = float(jnp.max(jnp.abs(x)))
    if x_scale == 0.0:
        x_scale = 1.0
    v = cfg.v_read * x / x_scale
    v_rms = float(jnp.sqrt(jnp.mean(v * v)))
    i_sigma = v_rms * arr.g_rms * math.sqrt(x.shape[1])
    i_max = float(f"{max(cfg.full_scale_sigmas * i_sigma, 1e-30):.2g}")
    return v, i_max, x_scale


def analog_matmul(
    arr: ProgrammedArray,
    x: jnp.ndarray,                  # (M, K) activations (signed)
    devices: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Run ``x @ w`` through the programmed crossbar (steps 3-4).

    The ADC result is decoded back to weight/activation units via the
    programming scales and the mean IR-drop calibration factor.
    """
    assert x.ndim == 2 and x.shape[1] == arr.g_diff.shape[0], (
        x.shape, arr.g_diff.shape)
    cfg = arr.cfg
    m = x.shape[0]
    v, i_max, x_scale = kernel_operands(arr, x)

    n_dev = _usable_devices(m, devices)
    pad = -m % n_dev
    if pad:
        v = jnp.pad(v, ((0, pad), (0, 0)))
    interp = _default_interpret() if interpret is None else interpret
    i_out = _mvm_sharded(v, arr.g_diff, adc_bits=cfg.adc_bits, i_max=i_max,
                         interpret=interp, n_dev=n_dev)
    if pad:
        i_out = i_out[:m]
    return i_out * (x_scale * arr.w_scale) / (
        cfg.v_read * arr.g_fs * arr.att_mean)


def binary_matmul(
    x: jnp.ndarray,                  # (M, K) float activations
    w: jnp.ndarray,                  # (K, N) float weights
    tie: int = 1,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """1-bit (XNOR-popcount) projection: sign-binarize both operands, run the
    XNOR kernel, rescale by per-column mean |w| and scalar mean |x| (the
    standard BNN first-order correction)."""
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    from repro.kernels.xnor_gemm import binarize_acc

    xb = binarize_acc(x, tie)
    wb = binarize_acc(w, tie)
    interp = _default_interpret() if interpret is None else interpret
    pops = xnor_gemm_pallas(xb, wb, binarize=False, tie=tie, interpret=interp)
    alpha_w = jnp.mean(jnp.abs(w), axis=0)      # (N,)
    alpha_x = jnp.mean(jnp.abs(x))
    return pops * alpha_w[None, :] * alpha_x


@dataclasses.dataclass(frozen=True)
class AccuracyReport:
    """Output error of one analog MVM vs the f32 matmul oracle."""

    arch: str
    kind: str
    mode: str                      # "analog" (bitline+ADC) | "bnn" (xnor)
    adc_bits: int
    tmr: float
    g_sigma: float
    m: int
    k: int
    n: int
    mse: float
    nmse: float                    # mse / mean(y_ref^2)
    cosine: float
    max_abs_err: float
    write_ber: float = 0.0         # injected residual write-error rate


def _report(y, y_ref, *, arch, kind, mode, cfg: AnalogConfig, tmr: float
            ) -> AccuracyReport:
    y = np.asarray(y, np.float64)
    y_ref = np.asarray(y_ref, np.float64)
    err = y - y_ref
    mse = float(np.mean(err**2))
    ref_pw = float(np.mean(y_ref**2))
    cos = float(np.sum(y * y_ref) /
                max(np.linalg.norm(y) * np.linalg.norm(y_ref), 1e-30))
    return AccuracyReport(
        arch=arch, kind=kind, mode=mode, adc_bits=cfg.adc_bits, tmr=tmr,
        g_sigma=cfg.g_sigma, m=y.shape[0], k=0, n=y.shape[1], mse=mse,
        nmse=mse / max(ref_pw, 1e-30), cosine=cos,
        max_abs_err=float(np.max(np.abs(err))), write_ber=cfg.write_ber)


def mvm_accuracy(
    w: jnp.ndarray,
    x: jnp.ndarray,
    kind: str = "afmtj",
    cfg: AnalogConfig = AnalogConfig(),
    mode: str = "analog",
    arch: str = "",
    devices: Optional[int] = None,
) -> AccuracyReport:
    """Program ``w``, run ``x`` through the kernel path, score vs f32."""
    y_ref = jnp.asarray(x, jnp.float32) @ jnp.asarray(w, jnp.float32)
    if mode == "analog":
        arr = program_weights(w, kind, cfg)
        y = analog_matmul(arr, x, devices=devices)
        tmr = arr.dev.tmr
    elif mode == "bnn":
        y = binary_matmul(x, w)
        tmr = _device_for(kind, cfg).tmr
    else:
        raise ValueError(f"unknown mode {mode!r}")
    rep = _report(y, y_ref, arch=arch, kind=kind, mode=mode, cfg=cfg, tmr=tmr)
    return dataclasses.replace(rep, k=int(w.shape[0]))

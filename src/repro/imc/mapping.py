"""Beyond-paper: map LM-architecture inference onto the AFMTJ IMC hierarchy.

The paper evaluates six micro-kernels; this module extends the same
methodology to the 10 assigned architectures.  Decode-step inference is
dominated by weight-stationary GEMVs (every active parameter = one MAC), the
operation the AFMTJ crossbar performs natively: weights are programmed as
conductances once (amortized), activations drive read word-lines, bit-line
charge sharing computes the analog dot product (`kernels/bitline_mac` is the
functional simulator), and per-column ADCs digitize.

Three execution targets per arch:
  cpu        — A72 streaming GEMV (DRAM-bandwidth-bound at 8-bit weights)
  imc (mtj)  — crossbar MACs with MTJ write/read costs for activations
  imc (afmtj)— same with AFMTJ costs
plus a 1-bit (BNN/XNOR) variant of each IMC target — the paper's *bnn* mode
applied to a whole transformer (weights binarized, XNOR-popcount arrays).

Latency model per decode token: the arch's active params are tiled over
512x512 crossbars; arrays operate in parallel up to the level's concurrency;
each tile GEMV costs one analog read (t_read) + activation write-back of its
output row (t_write amortized over 512 columns).  Energy: per-MAC read
energy + per-row ADC/peripheral + activation writes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig
from repro.imc.cpu_model import CORTEX_A72, CPUModel
from repro.imc.hierarchy import IMCHierarchy, build_hierarchy

XBAR = 512                      # crossbar dimension (MM-level subarrays)
IMC_PARALLEL_ARRAYS = 1024      # arrays operating concurrently at MM (PiM)
ADC_E_PER_COL = 2.0e-12         # 6-bit column ADC energy [J]
ADC_T = 0.5e-9                  # per-tile conversion time (pipelined) [s]


@dataclasses.dataclass(frozen=True)
class ArchMapResult:
    arch: str
    t_cpu: float
    e_cpu: float
    t_imc: float
    e_imc: float
    t_imc_bnn: float
    e_imc_bnn: float

    @property
    def speedup(self):
        return self.t_cpu / self.t_imc

    @property
    def energy_saving(self):
        return self.e_cpu / self.e_imc


def map_arch_decode(cfg: ArchConfig, hier: IMCHierarchy,
                    cpu: CPUModel = CORTEX_A72) -> ArchMapResult:
    n = cfg.active_param_count()
    tm = hier.levels["MM"].timings

    # --- CPU baseline: memory-bound GEMV stream (int8 weights) -------------
    t_cpu = max(n * 1.0 / cpu.bw_dram,                      # 1 B/param traffic
                n * 0.125 / (cpu.ipc * cpu.freq_hz))        # SIMD MACs
    e_cpu = (n / cpu.line_bytes) * cpu.e_dram_line + n * 0.02e-12

    # --- AFMTJ/MTJ crossbar: tiles of XBAR x XBAR MACs ----------------------
    tiles = n / (XBAR * XBAR)
    waves = tiles / IMC_PARALLEL_ARRAYS                     # sequential waves
    t_tile = tm.t_read + ADC_T                              # analog GEMV + ADC
    # activation write-back: one XBAR-wide row per tile-column group
    t_wb = tm.t_write
    t_imc = waves * (t_tile + t_wb * 0.1)                   # writes pipelined
    e_mac = tm.e_read_bit                                   # per-cell read
    e_imc = (n * e_mac
             + tiles * XBAR * ADC_E_PER_COL                 # column ADCs
             + tiles * XBAR * tm.e_write_bit * 0.02)        # activation writes

    # --- 1-bit (XNOR) variant: 8x denser tiles, no ADC (sense-amp sign) ----
    tiles_b = tiles                                          # 1 cell / weight
    waves_b = tiles_b / IMC_PARALLEL_ARRAYS
    t_imc_bnn = waves_b * (tm.t_logic2 + tm.t_write * 0.1)
    e_imc_bnn = n * tm.e_logic_bit + tiles_b * XBAR * tm.e_write_bit * 0.02

    return ArchMapResult(cfg.name, t_cpu, e_cpu, t_imc, e_imc,
                         t_imc_bnn, e_imc_bnn)


def map_all(archs: Dict[str, ArchConfig]) -> Dict[str, Dict[str, ArchMapResult]]:
    out = {}
    for kind in ("afmtj", "mtj"):
        hier = build_hierarchy(kind)
        out[kind] = {name: map_arch_decode(cfg, hier)
                     for name, cfg in archs.items()}
    return out

"""Beyond-paper: map LM-architecture inference onto the AFMTJ IMC hierarchy.

The paper evaluates six micro-kernels; this module extends the same
methodology to the 10 assigned architectures.  Decode-step inference is
dominated by weight-stationary GEMVs (every active parameter = one MAC), the
operation the AFMTJ crossbar performs natively: weights are programmed as
conductances once (amortized), activations drive read word-lines, bit-line
charge sharing computes the analog dot product (`kernels/bitline_mac` is the
functional simulator), and per-column ADCs digitize.

Three execution targets per arch:
  cpu        — A72 streaming GEMV (DRAM-bandwidth-bound at 8-bit weights)
  imc (mtj)  — crossbar MACs with MTJ write/read costs for activations
  imc (afmtj)— same with AFMTJ costs
plus a 1-bit (BNN/XNOR) variant of each IMC target — the paper's *bnn* mode
applied to a whole transformer (weights binarized, XNOR-popcount arrays).

Latency model per decode token: the arch's active params are tiled over
512x512 crossbars; arrays operate in parallel up to the level's concurrency;
each tile GEMV costs one analog read (t_read) + activation write-back of its
output row (t_write amortized over 512 columns).  Energy: per-MAC read
energy + per-row ADC/peripheral + activation writes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Dict, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig
from repro.imc.cpu_model import CORTEX_A72, CPUModel
from repro.imc.hierarchy import IMCHierarchy, build_hierarchy

if TYPE_CHECKING:  # pure-math yield model below; no jnp import at runtime
    from repro.imc.faults import FaultSpec, RepairPolicy

XBAR = 512                      # crossbar dimension (MM-level subarrays)
IMC_PARALLEL_ARRAYS = 1024      # arrays operating concurrently at MM (PiM)
ADC_E_PER_COL = 2.0e-12         # 6-bit column ADC energy [J]
ADC_T = 0.5e-9                  # per-tile conversion time (pipelined) [s]
CELLS_PER_WEIGHT_8B = 8         # bit-sliced int8: one cell per weight bit


@dataclasses.dataclass(frozen=True)
class ArchMapResult:
    arch: str
    t_cpu: float
    e_cpu: float
    t_imc: float
    e_imc: float
    t_imc_bnn: float
    e_imc_bnn: float
    tiles: float = 0.0           # XBAR^2 crossbar tiles, 8-bit mapping
    tiles_bnn: float = 0.0       # tiles for the binarized (1 cell/weight) map

    @property
    def speedup(self):
        return self.t_cpu / self.t_imc

    @property
    def energy_saving(self):
        return self.e_cpu / self.e_imc


def map_arch_decode(cfg: ArchConfig, hier: IMCHierarchy,
                    cpu: CPUModel = CORTEX_A72) -> ArchMapResult:
    n = cfg.active_param_count()
    tm = hier.levels["MM"].timings

    # --- CPU baseline: memory-bound GEMV stream (int8 weights) -------------
    t_cpu = max(n * 1.0 / cpu.bw_dram,                      # 1 B/param traffic
                n * 0.125 / (cpu.ipc * cpu.freq_hz))        # SIMD MACs
    e_cpu = (n / cpu.line_bytes) * cpu.e_dram_line + n * 0.02e-12

    # --- AFMTJ/MTJ crossbar: tiles of XBAR x XBAR cells ---------------------
    # 8-bit weights are bit-sliced over CELLS_PER_WEIGHT_8B cells, so the
    # 8-bit map occupies 8x the cells (and reads 8 cells per weight MAC).
    tiles = n * CELLS_PER_WEIGHT_8B / (XBAR * XBAR)
    waves = tiles / IMC_PARALLEL_ARRAYS                     # sequential waves
    t_tile = tm.t_read + ADC_T                              # analog GEMV + ADC
    # activation write-back: one XBAR-wide row per tile-column group
    t_wb = tm.t_write
    t_imc = waves * (t_tile + t_wb * 0.1)                   # writes pipelined
    e_mac = tm.e_read_bit                                   # per-cell read
    e_imc = (n * CELLS_PER_WEIGHT_8B * e_mac
             + tiles * XBAR * ADC_E_PER_COL                 # column ADCs
             + tiles * XBAR * tm.e_write_bit * 0.02)        # activation writes

    # --- 1-bit (XNOR) variant: 1 cell/weight -> 8x fewer tiles, no ADC
    # (sense-amp sign readout) ------------------------------------------------
    tiles_b = n / (XBAR * XBAR)
    waves_b = tiles_b / IMC_PARALLEL_ARRAYS
    t_imc_bnn = waves_b * (tm.t_logic2 + tm.t_write * 0.1)
    e_imc_bnn = n * tm.e_logic_bit + tiles_b * XBAR * tm.e_write_bit * 0.02

    return ArchMapResult(cfg.name, t_cpu, e_cpu, t_imc, e_imc,
                         t_imc_bnn, e_imc_bnn, tiles=tiles, tiles_bnn=tiles_b)


def map_all(archs: Dict[str, ArchConfig]) -> Dict[str, Dict[str, ArchMapResult]]:
    out = {}
    for kind in ("afmtj", "mtj"):
        hier = build_hierarchy(kind)
        out[kind] = {name: map_arch_decode(cfg, hier)
                     for name, cfg in archs.items()}
    return out


# --- hard-fault repair: capacity yield model + area/energy overheads --------
#
# ``imc.faults`` draws the defect planes the functional paths compute with;
# this block is the closed-form companion the *cost* model charges
# (DESIGN.md §13): the probability an XBAR x XBAR array's defects fit the
# repair capacity (arrays that don't are fused out — their work re-runs on
# survivors, stretching latency by 1/yield), and the spare-line / ECC cell
# overheads every array pays whether or not it uses them.

def _poisson_cdf(k: int, lam: float) -> float:
    """P(X <= k) for X ~ Poisson(lam) — iterative, no scipy."""
    if lam <= 0.0:
        return 1.0
    term = math.exp(-lam)
    total = term
    for i in range(1, int(k) + 1):
        term *= lam / i
        total += term
    return min(total, 1.0)


def repair_yield(faults: "FaultSpec", policy: Optional["RepairPolicy"] = None,
                 xbar: int = XBAR) -> float:
    """P(an XBAR x XBAR differential array is usable under ``policy``).

    A row is defective if its word-line driver is dead or it holds more
    stuck differential pairs than the row can absorb (ECC corrects up to
    ``ecc_cells_per_row``; pair masking absorbs the rest at bounded
    accuracy cost — without masking, ONE uncorrected stuck pair condemns
    the row, which is why the no-repair yield collapses).  Defective
    row/column counts are Poisson-approximated and must fit the spare
    capacity; the array yield is the product of both fits.
    """
    from repro.imc.faults import REPAIR_NONE

    pol = policy or REPAIR_NONE
    p_cell = min(faults.cell_fault_rate, 1.0)
    p_pair = 1.0 - (1.0 - p_cell) ** 2
    if pol.mask_pairs:
        p_row_cells = 0.0          # masked pairs never condemn a row
    else:
        lam_pair = xbar * p_pair
        p_row_cells = 1.0 - _poisson_cdf(pol.ecc_cells_per_row, lam_pair)
    p_row = min(faults.dead_row_rate
                + (1.0 - faults.dead_row_rate) * p_row_cells, 1.0)
    y_rows = _poisson_cdf(pol.spare_rows, xbar * p_row)
    y_cols = _poisson_cdf(pol.spare_cols, xbar * faults.dead_col_rate)
    return y_rows * y_cols


def repair_cell_overhead(policy: Optional["RepairPolicy"] = None,
                         xbar: int = XBAR) -> float:
    """Cell/area factor a repaired array pays: spare lines plus the ECC
    side-table (9 cells per correctable entry: 8-bit value + valid flag)."""
    from repro.imc.faults import REPAIR_NONE

    pol = policy or REPAIR_NONE
    area = (1.0 + pol.spare_rows / xbar) * (1.0 + pol.spare_cols / xbar)
    ecc = 1.0 + 9.0 * pol.ecc_cells_per_row / xbar
    return area * ecc


def fault_cost_factors(faults: Optional["FaultSpec"],
                       policy: Optional["RepairPolicy"] = None,
                       xbar: int = XBAR) -> Tuple[float, float, float]:
    """(array_yield, cell_overhead, latency_stretch) for the cost model.

    Latency stretches by overhead/yield: dead arrays drop out of the
    parallel pool and their tiles re-run on survivors; the yield floor
    (1e-3) caps the stretch at 1000x so a hopeless (rate, policy) point
    reports a finite — obviously unusable — number instead of inf.
    """
    if faults is None or not faults.any_faults:
        return 1.0, 1.0, 1.0
    y = repair_yield(faults, policy, xbar)
    ovh = repair_cell_overhead(policy, xbar)
    return y, ovh, ovh / max(y, 1e-3)


# --- functional read path: run the decode GEMV through the Pallas kernels ---
#
# The latency/energy model above is closed-form; the functions below actually
# COMPUTE a decode-step projection through ``imc.analog_pipeline`` (bitline
# MAC kernel + IR drop + signed ADC) and score the output against the f32
# matmul — the accuracy axis of the paper's accuracy-vs-nonideality claim.

def decode_projection_shapes(cfg: ArchConfig, cap_k: int = 512,
                             cap_n: int = 512) -> Tuple[int, int]:
    """The arch's decode-dominant GEMV (d_model -> FFN fan-out), capped so
    interpret-mode Pallas sweeps stay tractable on CPU."""
    k = min(cfg.d_model, cap_k)
    n_full = cfg.d_ff if cfg.d_ff else 2 * cfg.d_model
    if cfg.moe is not None:
        n_full = cfg.moe.d_expert
    return k, min(n_full, cap_n)


def decode_projection_accuracy(
    cfg: ArchConfig,
    kind: str = "afmtj",
    analog_cfg: Optional["AnalogConfig"] = None,
    mode: str = "analog",
    batch: int = 8,
    cap_k: int = 512,
    cap_n: int = 512,
    seed: Optional[int] = None,
    devices: Optional[int] = None,
) -> "AccuracyReport":
    """One real decode-step projection of ``cfg`` through the analog path.

    ``seed=None`` derives the projection draw from the arch name, so two
    archs whose capped shapes coincide still get distinct weights."""
    import zlib

    import jax
    import jax.numpy as jnp

    from repro.imc.analog_pipeline import AnalogConfig, mvm_accuracy

    analog_cfg = analog_cfg or AnalogConfig()
    k, n = decode_projection_shapes(cfg, cap_k, cap_n)
    if seed is None:
        seed = zlib.crc32(cfg.name.encode()) & 0x7FFFFFFF
    kw, kx = jax.random.split(jax.random.PRNGKey(seed))
    # init-scaled projection weights + unit-normal decode activations
    w = jax.random.normal(kw, (k, n), jnp.float32) / (k ** 0.5)
    x = jax.random.normal(kx, (batch, k), jnp.float32)
    return mvm_accuracy(w, x, kind=kind, cfg=analog_cfg, mode=mode,
                        arch=cfg.name, devices=devices)


def accuracy_surface(
    cfg: ArchConfig,
    kind: str = "afmtj",
    adc_bits: Sequence[int] = (4, 6, 8),
    tmrs: Sequence[float] = (0.8, 5.0),
    g_sigma: float = 0.0,
    variation=None,
    model: Optional[str] = None,
    **kw,
) -> Dict[Tuple[int, float], "AccuracyReport"]:
    """Accuracy-vs-``adc_bits``-vs-TMR surface for one arch: the functional
    companion of ``map_arch_decode``'s latency/energy point.  ``variation``
    (a single-corner ``core.params.VariationSpec``) is the D2D /
    process-corner knob; ``g_sigma`` is its deprecated conductance-only
    alias (DESIGN.md §9).

    ``model=`` switches from the single decode-projection score to the
    *model-level* surface (``imc.model_analog``, DESIGN.md §12): every
    linear of the arch's forward routed through the analog MVM, values are
    ``ModelAccuracyReport`` (logits KL / token match / perplexity) instead
    of ``AccuracyReport``.  Pass an execution mode — "fake" (fused Pallas
    fast path), "device" (full programming chain) or "bnn" — and optionally
    a single-corner ``variation`` spec for the systematic corner axis."""
    from repro.imc.analog_pipeline import AnalogConfig

    if model is not None:
        from repro.imc.model_analog import model_accuracy_surface

        assert g_sigma == 0.0, "model-level surface takes corners, not g_sigma"
        corner = variation.corners[0].name if variation is not None else "tt"
        reports = model_accuracy_surface(
            arch=cfg.name, kind=kind, mode=model, adc_bits=tuple(adc_bits),
            tmrs=tuple(tmrs), corners=(corner,), **kw)
        return {(r.adc_bits, r.tmr): r for r in reports}

    out = {}
    for bits in adc_bits:
        for tmr in tmrs:
            acfg = AnalogConfig(adc_bits=bits, tmr=tmr, g_sigma=g_sigma,
                                variation=variation)
            out[(bits, tmr)] = decode_projection_accuracy(
                cfg, kind=kind, analog_cfg=acfg, **kw)
    return out


# --- functional write path: accuracy vs the measured cost of writing -------
#
# The read surface above varies read-side non-idealities at perfect weights;
# the write surface varies how much latency/energy the write-verify
# scheduler (``imc.write_path``, DESIGN.md §7) is allowed to spend and
# injects the *resulting* residual bit-error rate into the programming step
# — accuracy-vs-(WER target, write energy), the co-design trade the
# companion write-driver work (PAPERS.md, arXiv 2602.11614) optimizes.

@dataclasses.dataclass(frozen=True)
class WriteAccuracyPoint:
    """One (WER target) operating point of the write/accuracy trade."""

    wer_target: float
    attempts_budget: int       # verify retries allotted to reach the target
    write_ber: float           # residual BER injected into programming
    e_write_bit: float         # measured mean write energy per cell [J]
    t_write_mean: float        # measured mean per-cell write latency [s]
    attempts_mean: float       # measured mean pulses per cell
    report: "AccuracyReport"   # decode-projection accuracy at that BER


def write_energy_accuracy_surface(
    cfg: ArchConfig,
    kind: str = "afmtj",
    wer_targets: Sequence[float] = (3e-1, 1e-1, 1e-2, 1e-4),
    v_write: float = 1.0,
    policy: Optional["WritePolicy"] = None,
    n_cells: int = 512,
    analog_cfg: Optional["AnalogConfig"] = None,
    max_attempt_budget: int = 64,
    **kw,
) -> Dict[float, WriteAccuracyPoint]:
    """Accuracy-vs-write-energy surface for one arch.

    For each residual-WER target: size the verify attempt budget from the
    measured single-pulse WER (attempts are geometric — DESIGN.md §7), run
    the write-verify scheduler under that budget to *measure* energy,
    latency and the residual bit-error rate, then push the residual errors
    through the analog read path (``AnalogConfig.write_ber``) and score the
    arch's decode projection.  Tighter WER targets buy accuracy with write
    energy; loose targets leave stuck-at-floor cells the MVM has to eat.
    ``policy`` defaults to the device-nominal pulse x margin — pass a
    shorter pulse (e.g. ``pulse_margin < 1``) to widen the visible trade.
    ``max_attempt_budget`` bounds the sized budget: at operating points
    where the pulse essentially never switches (``wer1`` near 1) the
    geometric sizing would otherwise schedule thousands of sequential
    rounds — the point lands at the ceiling's residual BER instead.
    """
    from repro.imc.analog_pipeline import AnalogConfig
    from repro.imc.write_path import WritePolicy, write_verify

    pol = policy or WritePolicy(v_write=v_write)
    probe = write_verify(kind, n_cells, dataclasses.replace(pol,
                                                            max_attempts=1))
    wer1 = probe.single_pulse_wer
    out = {}
    for target in wer_targets:
        if 0.0 < wer1 < 1.0:
            k = max(1, math.ceil(math.log(target) / math.log(wer1)))
            k = min(k, int(max_attempt_budget))
        else:
            k = 1 if wer1 == 0.0 else int(max_attempt_budget)
        r = write_verify(kind, n_cells,
                         dataclasses.replace(pol, max_attempts=k))
        # finite-sample floor: when every sampled cell verified, fall back
        # to the geometric estimate of the residual
        ber = r.residual_ber if r.residual_ber > 0.0 else float(wer1 ** k)
        acfg = dataclasses.replace(analog_cfg or AnalogConfig(),
                                   write_ber=float(ber))
        rep = decode_projection_accuracy(cfg, kind=kind, analog_cfg=acfg,
                                         **kw)
        out[float(target)] = WriteAccuracyPoint(
            wer_target=float(target), attempts_budget=k,
            write_ber=float(ber), e_write_bit=r.energy_mean(),
            t_write_mean=float(r.latency.mean()),
            attempts_mean=r.attempts_mean, report=rep)
    return out

"""Measured read path: read disturb, retention, sense-margin yield.

PRs 3-5 made the *write* path measured (write-verify retries through
thermal LLG transients); reads were still free of device physics.  This
module closes that gap with three measured scenario families, all riding
the fused campaign engine (one launch per campaign — temperature, voltage
and process corners on the lanes, pulse width as first-crossing
post-processing).  See DESIGN.md §10.

**Read disturb** (``read_disturb_campaign``): a read pulse is a
sub-threshold STT drive — thermally-assisted switching during the sense
window corrupts the stored bit.  The campaign is the write campaign at
read-scale voltages: disturb-flip probability vs (read voltage, pulse
width, T, corner) falls out of the same first-crossing rows
(``disturb probability = 1 - WER``: here a "switch" IS the error).  At
operating bias the per-read probability is far below Monte-Carlo
resolution, so the module also fits an *accelerated* disturb model
(``fit_disturb_model``): on a barrier-scaled corner the sub-threshold
voltage dependence is measurable, and the read-bias barrier suppression
``Delta_eff(V) = Delta * (1 - V/V_c)^beta`` is fitted there and
transferred to the real barrier (V_c is set by the exchange-dominated
Neel-STT threshold ``a_th ~ alpha * B_E`` — independent of B_k, so the
*shape* survives barrier scaling; the standard accelerated-stress
assumption, stated not hidden).  ``accumulated_disturb`` /
``reads_between_refresh`` turn the per-read probability into an N-read
budget.

**Retention** (``retention_campaign``): at the design point (Delta = 40)
a bit retains for years — directly unobservable in any feasible
integration horizon.  Retention is therefore measured by *accelerated
stress*: acceleration corners scale ``b_aniso_factor`` down until thermal
escape is observable (Delta_eff ~ 2-6) within a log-spaced horizon ladder
(``campaign.grid.log_pulses`` + the engine's ``horizon="log"`` bucket, so
decade sweeps don't recompile), every (real corner x acceleration x T)
combination riding ONE fused launch.  Escape times reduce by
censored-exponential MLE (tau = total observed time / escapes), the free
Arrhenius fit ``ln tau = ln tau0 + b * Delta_eff`` cross-checks the
closed-form Delta (slope b ~ 1), and the operating-point extrapolation
pins the slope to the theoretical 1 (the attempt time tau0 is the fitted
quantity): ``tau_op = tau0 * exp(Delta_op)``.

**Sense-margin yield** (``sense_margin_yield``): the previously-dead
``SenseAmpParams.offset_sigma`` becomes a vectorized Monte-Carlo over
input-referred SA offset (``circuit.senseamp.sa_offsets``) plus per-lane
junction resistance variation (``VariationSpec`` draws — common random
numbers across corners and read-voltage ladder points, so comparisons are
paired per lane).  A read fails when the offset pushes the bit-line
differential across the reference (wrong sign) or the latch regeneration
time past the timing budget; ``size_read_drive`` walks a read-voltage /
transimpedance ladder per corner the way PR 5 sized write pulses.

System threading: ``measured_read_timings`` feeds
``circuit.subarray.make_subarray(..., read_percentile=...)`` (the read
analog of ``measured_write_timings``), and ``derive_refresh_policy``
turns measured retention + the disturb budget into the scrub interval
``imc.evaluate`` charges into the Fig. 4 comparison.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.params import (AFMTJ_PARAMS, CORNER_FF, CORNER_SS, CORNER_TT,
                               KB, MTJ_PARAMS, DeviceParams, VariationSpec)
from repro.imc.write_margin import DEVICE_DT


def _params_for(kind: str) -> DeviceParams:
    assert kind in ("afmtj", "mtj"), kind
    return AFMTJ_PARAMS if kind == "afmtj" else MTJ_PARAMS


def _delta_at(p: DeviceParams, temperature: float,
              b_factor: float = 1.0, v_factor: float = 1.0) -> float:
    """Closed-form thermal stability Delta = E_b/kT of a corner device."""
    e_b = 0.5 * p.b_aniso * b_factor * p.ms * p.volume * v_factor
    return e_b / (KB * float(temperature))


# --------------------------------------------------------------------------
# Read disturb: measured flip-probability surfaces near the onset, plus the
# accumulated-disturb algebra for N reads between refreshes.

# Default disturb ladder: brackets the AFMTJ Neel-STT onset (~0.19 V) from
# the operating read bias (0.1 V) up into the measurable thermally-assisted
# regime.  Below onset the measured probability is 0 at any feasible sample
# count — that zero is the physics, and ``p1_upper`` bounds it honestly.
DISTURB_VOLTAGES = (0.10, 0.15, 0.20, 0.24)
DISTURB_PULSES = (0.2e-9, 0.8e-9, 2.0e-9)


@dataclasses.dataclass(frozen=True)
class ReadDisturbResult:
    """Disturb-flip probability surfaces from one fused campaign."""

    kind: str
    result: "object"        # campaign.engine.CampaignResult

    @property
    def grid(self):
        return self.result.grid

    @property
    def n_launches(self) -> int:
        return self.result.n_launches

    def disturb_surface(self) -> np.ndarray:
        """(..., n_T, n_V, n_P) per-read disturb-flip probability (leading
        corner axis on variation grids).  A lane that crosses within the
        read pulse IS the error here, so this is 1 - WER."""
        return 1.0 - self.result.wer_surface()

    def p1(self, v_index: int = 0, p_index: int = -1, t_index: int = 0,
           corner_index: Optional[int] = None) -> float:
        """Measured per-read disturb probability at one operating point
        (worst corner by default on variation grids)."""
        s = self.disturb_surface()
        if s.ndim == 4:
            s = s.max(axis=0) if corner_index is None else s[corner_index]
        return float(s[t_index, v_index, p_index])

    def p1_upper(self, v_index: int = 0, p_index: int = -1, t_index: int = 0,
                 corner_index: Optional[int] = None) -> float:
        """Resolution-floor upper bound on the per-read probability: the
        measured estimate plus the rule-of-three 95% bound ``3/n`` — a
        measured zero means "below 3/n_samples", never "zero"."""
        return (self.p1(v_index, p_index, t_index, corner_index)
                + 3.0 / self.grid.n_samples)


def accumulated_disturb(p1: float, n_reads: float) -> float:
    """P(bit corrupted after ``n_reads`` independent reads) = 1-(1-p1)^N."""
    if p1 <= 0.0:
        return 0.0
    if p1 >= 1.0:
        return 1.0
    return float(-math.expm1(n_reads * math.log1p(-p1)))


def reads_between_refresh(p1: float, ber_budget: float) -> float:
    """Largest N with accumulated disturb <= ``ber_budget``."""
    if p1 <= 0.0:
        return math.inf
    if p1 >= 1.0:
        return 0.0
    return math.log1p(-ber_budget) / math.log1p(-p1)


def read_disturb_campaign(
    kind: str = "afmtj",
    voltages: Tuple[float, ...] = DISTURB_VOLTAGES,
    pulses: Tuple[float, ...] = DISTURB_PULSES,
    temperatures: Tuple[float, ...] = (300.0, 400.0),
    n_samples: int = 256,
    variation: Optional[VariationSpec] = None,
    seed: int = 0,
    backend: str = "pallas",
    use_cache: bool = True,
) -> ReadDisturbResult:
    """Disturb-flip probability vs (read voltage, pulse, T, corner).

    One fused launch: the whole grid rides the campaign engine exactly as
    a write campaign does — only the drive ladder sits at read-scale
    voltages and a first crossing now counts as a *failure*.  The stored
    bit starts in its Boltzmann-tilted well (the idle state a read finds),
    so the measured flip rate includes the thermally-assisted tail, not
    just the deterministic over-threshold onset.
    """
    from repro.campaign.engine import run_campaign
    from repro.campaign.grid import CampaignGrid

    p = _params_for(kind)
    grid = CampaignGrid(
        voltages=tuple(float(v) for v in voltages),
        pulse_widths=tuple(float(t) for t in pulses),
        temperatures=tuple(float(t) for t in temperatures),
        n_samples=int(n_samples), dt=DEVICE_DT[kind], seed=seed,
        variation=variation)
    res = run_campaign(p, grid, backend=backend, use_cache=use_cache)
    return ReadDisturbResult(kind=kind, result=res)


# --------------------------------------------------------------------------
# Accelerated disturb model: fit the read-bias barrier suppression where it
# is measurable (a barrier-scaled corner) and transfer the shape to the
# real barrier.

@dataclasses.dataclass(frozen=True)
class DisturbModel:
    """Fitted read-bias barrier suppression Delta_eff(V) = Delta * s(V),
    s(V) = (1 - V/V_c)^beta for V < V_c (0 above).

    Fitted on an acceleration corner (``accel_factor`` x the nominal
    barrier) where sub-threshold escape is measurable; V_c tracks the
    exchange-dominated Neel-STT threshold, which barrier scaling leaves
    untouched — the documented shape-transfer assumption."""

    kind: str
    v_c: float                    # fitted critical voltage [V]
    beta: float                   # fitted suppression exponent
    accel_factor: float           # barrier scale the fit ran at
    delta_acc: float              # closed-form Delta of the fit corner
    tau0_acc: float               # zero-bias attempt time of the fit [s]
    voltages: Tuple[float, ...]   # fit ladder
    tau_meas: Tuple[float, ...]   # measured escape times per rung [s]
    sse: float                    # fit residual (sum sq. error in ln s)

    def suppression(self, v: float) -> float:
        if v >= self.v_c:
            return 0.0
        return (1.0 - v / self.v_c) ** self.beta

    def tau_disturb(self, v: float, delta_op: float, tau0: float) -> float:
        """Escape time under read bias ``v`` for a real device with
        zero-bias barrier ``delta_op`` and attempt time ``tau0`` (both from
        the retention fit)."""
        return tau0 * math.exp(delta_op * self.suppression(v))

    def p1(self, v: float, t_read: float, delta_op: float, tau0: float
           ) -> float:
        """Per-read disturb probability: P(escape within one read pulse)."""
        tau = self.tau_disturb(v, delta_op, tau0)
        return float(-math.expm1(-t_read / tau))


def _censored_tau(ct: np.ndarray, horizon: float) -> Tuple[float, int]:
    """Censored-exponential MLE on first-crossing times: tau = total
    observed time / escapes (inf when nothing escaped)."""
    flips = int((ct <= horizon).sum())
    total = float(np.minimum(ct, horizon).sum())
    return (total / flips if flips else math.inf), flips


def fit_disturb_model(
    kind: str = "afmtj",
    accel_factor: float = 0.1,
    voltages: Tuple[float, ...] = (0.0, 0.05, 0.10, 0.15),
    horizon: float = 4.0e-9,
    n_samples: int = 256,
    temperature: Optional[float] = None,
    seed: int = 11,
    backend: str = "pallas",
    use_cache: bool = True,
) -> DisturbModel:
    """Fit (V_c, beta) on a barrier-accelerated corner — one fused launch.

    The voltage ladder must include 0 (anchors ``tau0_acc`` through the
    closed-form accelerated Delta) and at least two sub-threshold rungs
    with observed escapes.  Raises ValueError when the campaign observed
    too few escapes to fit — widen the horizon or lower ``accel_factor``
    rather than fitting noise.
    """
    from repro.campaign.engine import run_campaign
    from repro.campaign.grid import CampaignGrid

    assert 0.0 in voltages, "ladder must anchor the zero-bias escape time"
    p = _params_for(kind)
    temp = float(temperature if temperature is not None else p.temperature)
    corner = dataclasses.replace(CORNER_TT, name=f"tt~{accel_factor:g}",
                                 b_aniso_factor=float(accel_factor))
    grid = CampaignGrid(
        voltages=tuple(float(v) for v in voltages),
        pulse_widths=(float(horizon),), temperatures=(temp,),
        n_samples=int(n_samples), dt=DEVICE_DT[kind], seed=seed,
        variation=VariationSpec(corners=(corner,)))
    res = run_campaign(p, grid, backend=backend, use_cache=use_cache,
                       horizon="log")

    taus, flips = [], []
    for vi in range(len(grid.voltages)):
        tau, n = _censored_tau(res.crossing_time[0, 0, vi], float(horizon))
        taus.append(tau)
        flips.append(n)
    v0 = grid.voltages.index(0.0)
    if not math.isfinite(taus[v0]):
        raise ValueError(
            f"no zero-bias escapes at accel={accel_factor:g} within "
            f"{horizon * 1e9:g} ns; lower accel_factor or widen the horizon")
    delta_acc = _delta_at(p, temp, b_factor=accel_factor)
    tau0_acc = taus[v0] / math.exp(delta_acc)

    # suppression samples at the biased rungs with observed escapes
    pts = [(v, math.log(tau / tau0_acc) / delta_acc)
           for v, tau, n in zip(grid.voltages, taus, flips)
           if v > 0.0 and n >= 3 and math.isfinite(tau)]
    pts = [(v, s) for v, s in pts if s > 1e-3]
    if len(pts) < 2:
        raise ValueError(
            "fewer than 2 biased rungs with escapes; widen the ladder or "
            "the horizon")
    vs = np.array([v for v, _ in pts])
    ln_s = np.log(np.clip([s for _, s in pts], 1e-6, 1.0))

    # for a fixed V_c, beta is closed-form least squares in
    # ln s = beta * ln(1 - V/V_c); scan V_c over a fine ladder above the
    # largest measured rung and keep the minimum-SSE pair
    best = None
    for v_c in np.linspace(vs.max() * 1.05, 0.6, 120):
        x = np.log1p(-vs / v_c)
        beta = float((ln_s * x).sum() / (x * x).sum())
        sse = float(((beta * x - ln_s) ** 2).sum())
        if best is None or sse < best[2]:
            best = (float(v_c), beta, sse)
    v_c, beta, sse = best
    return DisturbModel(kind=kind, v_c=v_c, beta=beta,
                        accel_factor=float(accel_factor),
                        delta_acc=delta_acc, tau0_acc=tau0_acc,
                        voltages=grid.voltages,
                        tau_meas=tuple(taus), sse=sse)


# --------------------------------------------------------------------------
# Retention: accelerated-stress escape-time campaigns, Arrhenius
# cross-check, operating-point extrapolation.

# Acceleration ladder: Delta_eff = 40 * f in the cleanly-measurable 2-6
# window (escape times ~ns-100ns at 300 K).
ACCEL_FACTORS = (0.05, 0.10, 0.15)

# Arrhenius-consistency band for the free-fit slope: the Kramers attempt
# time itself depends on the (scaled) anisotropy, so the apparent slope
# over a 2-6 Delta_eff window deviates from the asymptotic 1 — the
# cross-check asserts activated-escape scaling, not the asymptote.
ARRHENIUS_SLOPE_BAND = (0.6, 1.8)


def default_retention_spec(seed: int = 0) -> VariationSpec:
    """The real process corners retention is signed off against (no D2D —
    the closed-form Delta used for extrapolation is a corner quantity)."""
    return VariationSpec(corners=(CORNER_TT, CORNER_SS, CORNER_FF),
                         seed=seed)


@dataclasses.dataclass(frozen=True)
class RetentionResult:
    """Measured accelerated-stress retention per (real corner, T)."""

    kind: str
    spec: VariationSpec                 # the REAL corners
    accel_factors: Tuple[float, ...]
    result: "object"                    # composed-corner CampaignResult
    min_flips: int = 3                  # rungs below this don't enter fits

    @property
    def grid(self):
        return self.result.grid

    @property
    def n_launches(self) -> int:
        return self.result.n_launches

    @property
    def temperatures(self) -> Tuple[float, ...]:
        return self.grid.temperatures

    @property
    def shape(self) -> Tuple[int, int, int]:
        return (self.spec.n_corners, len(self.temperatures),
                len(self.accel_factors))

    @functools.cached_property
    def _mle(self) -> Tuple[np.ndarray, np.ndarray]:
        """(tau, n_flips), each (n_corners, n_T, n_accel): censored-
        exponential escape-time MLE per composed slice."""
        n_c, n_t, n_f = self.shape
        horizon = float(max(self.grid.pulse_widths))
        tau = np.empty((n_c, n_t, n_f))
        flips = np.empty((n_c, n_t, n_f), dtype=np.int64)
        ct = self.result.crossing_time      # (n_c*n_f, n_T, 1, n_S)
        for ci in range(n_c):
            for fi in range(n_f):
                for ti in range(n_t):
                    t, n = _censored_tau(ct[ci * n_f + fi, ti, 0], horizon)
                    tau[ci, ti, fi] = t
                    flips[ci, ti, fi] = n
        return tau, flips

    @property
    def tau_acc(self) -> np.ndarray:
        """(n_corners, n_T, n_accel) measured escape times [s]."""
        return self._mle[0]

    @property
    def n_flips(self) -> np.ndarray:
        return self._mle[1]

    def delta_eff(self) -> np.ndarray:
        """(n_corners, n_T, n_accel) closed-form Delta of each composed
        acceleration corner (corner factors only; D2D sigmas, if any, are
        deliberately outside the extrapolation)."""
        p = _params_for(self.kind)
        n_c, n_t, n_f = self.shape
        out = np.empty((n_c, n_t, n_f))
        for ci, corner in enumerate(self.spec.corners):
            for ti, temp in enumerate(self.temperatures):
                for fi, f in enumerate(self.accel_factors):
                    out[ci, ti, fi] = _delta_at(
                        p, temp, b_factor=corner.b_aniso_factor * f,
                        v_factor=corner.volume_factor)
        return out

    def delta_op(self) -> np.ndarray:
        """(n_corners, n_T) closed-form operating-point Delta."""
        p = _params_for(self.kind)
        return np.array([
            [_delta_at(p, temp, b_factor=c.b_aniso_factor,
                       v_factor=c.volume_factor)
             for temp in self.temperatures]
            for c in self.spec.corners])

    def _valid(self, ci: int, ti: int) -> np.ndarray:
        tau, flips = self._mle
        return (flips[ci, ti] >= self.min_flips) & np.isfinite(tau[ci, ti])

    def arrhenius_fit(self, corner_index: int = 0, t_index: int = 0
                      ) -> Tuple[float, float]:
        """Free weighted fit ``ln tau = ln tau0 + slope * Delta_eff`` over
        the measurable acceleration rungs — the cross-check against the
        closed-form Delta (slope inside ``ARRHENIUS_SLOPE_BAND`` means the
        measured escapes scale as activated barrier hopping).  Returns
        (slope, ln_tau0); NaNs when fewer than 2 rungs are measurable."""
        ok = self._valid(corner_index, t_index)
        if ok.sum() < 2:
            return math.nan, math.nan
        tau, flips = self._mle
        x = self.delta_eff()[corner_index, t_index][ok]
        y = np.log(tau[corner_index, t_index][ok])
        w = flips[corner_index, t_index][ok].astype(float)
        xm, ym = np.average(x, weights=w), np.average(y, weights=w)
        slope = float(np.average((x - xm) * (y - ym), weights=w)
                      / np.average((x - xm) ** 2, weights=w))
        return slope, float(ym - slope * xm)

    def tau0(self, corner_index: int = 0, t_index: int = 0) -> float:
        """Attempt time [s] with the Arrhenius slope pinned to the
        theoretical 1 — the stable quantity to extrapolate with (a free
        slope fitted over Delta_eff 2-6 amplifies to absurdity at
        Delta = 40; the free fit stays a cross-check)."""
        ok = self._valid(corner_index, t_index)
        if not ok.any():
            return math.nan
        tau, flips = self._mle
        ln_tau0 = (np.log(tau[corner_index, t_index][ok])
                   - self.delta_eff()[corner_index, t_index][ok])
        return float(math.exp(np.average(
            ln_tau0, weights=flips[corner_index, t_index][ok].astype(float))))

    def tau_op(self) -> np.ndarray:
        """(n_corners, n_T) extrapolated operating-point escape time [s]:
        tau0 * exp(Delta_op)."""
        d_op = self.delta_op()
        n_c, n_t = d_op.shape
        return np.array([[self.tau0(ci, ti) * math.exp(d_op[ci, ti])
                          for ti in range(n_t)] for ci in range(n_c)])

    def retention_percentiles(self, qs=(1e-9, 1e-6, 0.01)) -> np.ndarray:
        """(n_corners, n_T, len(qs)) time [s] by which a fraction ``q`` of
        bits has flipped: t_q = -tau_op * ln(1 - q) (~ tau_op * q for the
        small failure fractions a memory budget is written in)."""
        tau = self.tau_op()[..., None]
        q = np.asarray(qs, dtype=float)
        return -tau * np.log1p(-q)

    def worst_tau_op(self) -> float:
        """Smallest extrapolated escape time over (corner, T) — the number
        a refresh policy must cover."""
        return float(np.nanmin(self.tau_op()))


def retention_horizons(kind: str = "afmtj") -> Tuple[float, ...]:
    """Default log-spaced survival-time ladder [s] for the acceleration
    window: covers fast escapes at Delta_eff ~ 2 and reaches far enough to
    observe the Delta_eff ~ 6 tail."""
    from repro.campaign.grid import log_pulses

    hi = 4.0e-9 if kind == "afmtj" else 8.0e-9
    return log_pulses(hi / 20.0, hi, per_decade=3)


def retention_campaign(
    kind: str = "afmtj",
    accel_factors: Tuple[float, ...] = ACCEL_FACTORS,
    temperatures: Tuple[float, ...] = (300.0,),
    horizons: Optional[Tuple[float, ...]] = None,
    n_samples: int = 256,
    variation: Optional[VariationSpec] = None,
    v_hold: float = 0.0,
    seed: int = 5,
    backend: str = "pallas",
    use_cache: bool = True,
) -> RetentionResult:
    """Accelerated-stress retention: one fused launch over every
    (real corner x acceleration x T) combination.

    Acceleration corners compose multiplicatively onto the real corners'
    own ``b_aniso_factor`` (a slow-corner device is accelerated *from its
    corner barrier*, preserving corner ordering), packed corner-major into
    the variation plane — acceleration is campaign data, not a compile
    key.  ``v_hold`` models a biased standby rail (default 0: true idle
    retention).  The horizon ladder is log-spaced and the compiled horizon
    rides the ``"log"`` bucket ladder, so widening the window costs ~2
    compiles per decade instead of a recompile per horizon.
    """
    from repro.campaign.engine import run_campaign
    from repro.campaign.grid import CampaignGrid

    p = _params_for(kind)
    spec = variation if variation is not None else default_retention_spec()
    accel = tuple(float(f) for f in accel_factors)
    assert all(0.0 < f <= 1.0 for f in accel), accel
    composed = tuple(
        dataclasses.replace(c, name=f"{c.name}~{f:g}",
                            b_aniso_factor=c.b_aniso_factor * f)
        for c in spec.corners for f in accel)
    horizons = (tuple(float(h) for h in horizons) if horizons is not None
                else retention_horizons(kind))
    grid = CampaignGrid(
        voltages=(float(v_hold),), pulse_widths=horizons,
        temperatures=tuple(float(t) for t in temperatures),
        n_samples=int(n_samples), dt=DEVICE_DT[kind], seed=seed,
        variation=dataclasses.replace(spec, corners=composed))
    res = run_campaign(p, grid, backend=backend, use_cache=use_cache,
                       horizon="log")
    return RetentionResult(kind=kind, spec=spec, accel_factors=accel,
                           result=res)


# --------------------------------------------------------------------------
# Sense-margin yield: vectorized circuit Monte-Carlo over SA offset +
# junction variation (no kernel launch — the read path's closed-form MC).

# D2D junction-resistance spread the read margin is signed off against
# (the write path pre-compensates mean conductance; the *spread* is what
# eats sense margin).
READ_D2D_SIGMA_R = 0.05
DEFAULT_OFFSET_SIGMA = 5e-3       # input-referred SA offset std [V]


def default_read_spec(seed: int = 0) -> VariationSpec:
    """tt/ss/ff corners with the read-path D2D resistance spread."""
    return VariationSpec(corners=tuple(
        dataclasses.replace(c, sigma_r=READ_D2D_SIGMA_R)
        for c in (CORNER_TT, CORNER_SS, CORNER_FF)), seed=seed)


@dataclasses.dataclass(frozen=True)
class SenseYieldResult:
    """Monte-Carlo read yield over (corner x read-voltage ladder)."""

    kind: str
    v_reads: Tuple[float, ...]
    corner_names: Tuple[str, ...]
    r_trans: float
    offset_sigma: float
    n_samples: int
    percentile: float
    yield_surface: np.ndarray      # (n_corners, n_V) fraction read correctly
    t_sense: np.ndarray            # (n_corners, n_V) [s] at ``percentile``
    margin_min: np.ndarray         # (n_corners, n_V) [V] worst lane margin
                                   # (negative = that lane reads wrong)

    def v_read_for_yield(self, target: float,
                         corner_index: Optional[int] = None) -> float:
        """Smallest ladder read voltage with yield >= target (worst corner
        by default).  Raises when no rung qualifies."""
        y = (self.yield_surface.min(axis=0) if corner_index is None
             else self.yield_surface[corner_index])
        ok = np.nonzero(y >= target)[0]
        if not ok.size:
            raise ValueError(
                f"no ladder v_read reaches yield {target:g} (best "
                f"{y.max():.6g}); widen the ladder or raise r_trans")
        return float(self.v_reads[ok[0]])


def sense_margin_yield(
    kind: str = "afmtj",
    v_reads: Tuple[float, ...] = (0.05, 0.1, 0.15, 0.2),
    sa=None,
    bl=None,
    variation: Optional[VariationSpec] = None,
    n_samples: int = 4096,
    seed: int = 0,
    t_budget: Optional[float] = None,
    percentile: float = 99.0,
    ref_trim: str = "corner",
) -> SenseYieldResult:
    """Read-yield Monte-Carlo: per-lane junction draw + SA offset draw.

    Per lane: the stored junction's conductance carries its own D2D
    resistance draw (``VariationSpec.lane_factors`` — CRN: the same lanes
    across corners and ladder rungs) and the SA adds its input-referred
    offset (``sa_offsets`` — one mismatch population for the whole sweep).
    A read is correct when both stored states resolve with the right sign
    (and within ``t_budget``, when given); ``t_sense`` is the
    ``percentile`` regeneration time over lanes of the slower state — the
    measured read timing ``measured_read_timings`` hands the subarray
    model.

    ``ref_trim`` places the reference column: ``"corner"`` (default) trims
    it to each corner's own mid-point — the wafer-level reference trim the
    companion driver paper co-designs, leaving only D2D spread + offset as
    yield loss; ``"nominal"`` pins it to the nominal device's mid-point,
    which exposes the untrimmed failure mode — a systematic corner shift
    walks part of the D2D tail across the reference, a *sign* error no
    read voltage can buy back (the measured case for keeping trim).
    """
    import jax.numpy as jnp

    from repro.circuit.bitline import BitlineParams, cell_conductance
    from repro.circuit.senseamp import SenseAmpParams, sa_offsets, sense_delay

    assert ref_trim in ("corner", "nominal"), ref_trim
    p = _params_for(kind)
    sa = sa if sa is not None else SenseAmpParams(
        offset_sigma=DEFAULT_OFFSET_SIGMA)
    bl = bl if bl is not None else BitlineParams()
    spec = variation if variation is not None else default_read_spec()
    n = int(n_samples)
    offsets = np.asarray(sa_offsets(sa, n, seed=seed), np.float64)

    g_p, g_ap = 1.0 / p.r_parallel, 1.0 / p.r_antiparallel
    gc = lambda g: np.asarray(cell_conductance(jnp.asarray(g), bl),
                              np.float64)

    n_c, n_v = spec.n_corners, len(v_reads)
    yld = np.empty((n_c, n_v))
    t_s = np.empty((n_c, n_v))
    mrg = np.empty((n_c, n_v))
    for ci, corner in enumerate(spec.corners):
        # junction draw: CRN across corners (salted by stream, not corner)
        g_scale = 1.0 / spec.lane_factors(corner, n, stream=0)[3]
        gp_eff = gc(g_p * g_scale)
        gap_eff = gc(g_ap * g_scale)
        # reference column at the trim target's level mid-point
        f_ref = 1.0 if ref_trim == "nominal" else 1.0 / corner.r_factor
        g_ref = 0.5 * (gc(g_p * f_ref) + gc(g_ap * f_ref))
        for vi, v in enumerate(v_reads):
            di_p = v * (gp_eff - g_ref)         # must resolve positive
            di_ap = v * (gap_eff - g_ref)       # must resolve negative
            dv_p = di_p * sa.r_trans + offsets
            dv_ap = di_ap * sa.r_trans + offsets
            correct = (dv_p > 0.0) & (dv_ap < 0.0)
            t_p = np.asarray(sense_delay(jnp.asarray(di_p), sa,
                                         offset=jnp.asarray(offsets)),
                             np.float64)
            t_ap = np.asarray(sense_delay(jnp.asarray(di_ap), sa,
                                          offset=jnp.asarray(offsets)),
                              np.float64)
            t_lane = np.maximum(t_p, t_ap)
            if t_budget is not None:
                correct &= t_lane <= t_budget
            yld[ci, vi] = correct.mean()
            t_s[ci, vi] = np.percentile(t_lane, percentile)
            mrg[ci, vi] = min(dv_p.min(), -dv_ap.max())
    return SenseYieldResult(
        kind=kind, v_reads=tuple(float(v) for v in v_reads),
        corner_names=spec.corner_names, r_trans=float(sa.r_trans),
        offset_sigma=float(sa.offset_sigma), n_samples=n,
        percentile=float(percentile), yield_surface=yld, t_sense=t_s,
        margin_min=mrg)


@dataclasses.dataclass(frozen=True)
class SizedRead:
    """Per-corner read drive sizing (the read analog of the WER-margined
    write pulse)."""

    v_read: float
    r_trans: float
    read_yield: float
    t_sense: float        # [s] at the sizing percentile


def size_read_drive(
    kind: str = "afmtj",
    yield_target: float = 0.999,
    v_reads: Tuple[float, ...] = (0.05, 0.1, 0.15, 0.2),
    r_trans_ladder: Optional[Tuple[float, ...]] = None,
    sa=None,
    variation: Optional[VariationSpec] = None,
    n_samples: int = 4096,
    seed: int = 0,
    t_budget: Optional[float] = None,
) -> Dict[str, SizedRead]:
    """Smallest (v_read, r_trans) per corner meeting the yield target.

    Walks the read-voltage ladder (lowest disturb exposure first) and,
    per rung, the transimpedance ladder — sized per corner on common
    random numbers, like PR 5's per-corner write pulses.  Corners that
    never reach the target get the best available point (read_yield tells
    the caller it missed).
    """
    import dataclasses as _dc

    from repro.circuit.senseamp import SenseAmpParams

    sa = sa if sa is not None else SenseAmpParams(
        offset_sigma=DEFAULT_OFFSET_SIGMA)
    rt_ladder = (tuple(float(r) for r in r_trans_ladder)
                 if r_trans_ladder is not None else (sa.r_trans,))
    spec = variation if variation is not None else default_read_spec()
    results = {}
    for rt in sorted(rt_ladder):
        sy = sense_margin_yield(
            kind, v_reads=v_reads, sa=_dc.replace(sa, r_trans=rt),
            variation=spec, n_samples=n_samples, seed=seed,
            t_budget=t_budget)
        for ci, name in enumerate(sy.corner_names):
            if name in results and results[name].read_yield >= yield_target:
                continue
            y = sy.yield_surface[ci]
            ok = np.nonzero(y >= yield_target)[0]
            vi = int(ok[0]) if ok.size else int(np.argmax(y))
            cand = SizedRead(v_read=sy.v_reads[vi], r_trans=rt,
                             read_yield=float(y[vi]),
                             t_sense=float(sy.t_sense[ci, vi]))
            if name not in results or cand.read_yield > results[name].read_yield:
                results[name] = cand
    return results


# --------------------------------------------------------------------------
# Measured subarray read timings — the circuit-layer client
# (``circuit.subarray.make_subarray(..., read_percentile=...)``), the read
# analog of ``write_path.measured_write_timings``.

@dataclasses.dataclass(frozen=True)
class MeasuredRead:
    """Distribution summary the subarray timing model consumes."""

    t_sense: float        # regeneration time at ``percentile``, worst corner
    read_yield: float     # worst-corner fraction of correct resolutions
    margin_min: float     # worst-lane margin [V] (negative: failing lane)
    v_read: float
    offset_sigma: float
    percentile: float


@functools.lru_cache(maxsize=None)
def measured_read_timings(
    kind: str,
    v_read: float = 0.1,
    percentile: float = 99.0,
    sa=None,
    bl=None,
    variation: Optional[VariationSpec] = None,
    n_samples: int = 4096,
    seed: int = 0,
) -> MeasuredRead:
    """Measured sense timing at the controller percentile, worst corner.

    One closed-form Monte-Carlo at the single operating read voltage:
    offset + junction draws exactly as ``sense_margin_yield``, reduced to
    the worst-corner ``percentile`` regeneration time and yield.  The
    frozen-dataclass arguments keep the whole signature hashable
    (lru-cached across hierarchy builds, like the write path)."""
    sy = sense_margin_yield(kind, v_reads=(float(v_read),), sa=sa, bl=bl,
                            variation=variation, n_samples=n_samples,
                            seed=seed, percentile=percentile)
    worst = int(np.argmax(sy.t_sense[:, 0]))
    return MeasuredRead(
        t_sense=float(sy.t_sense[worst, 0]),
        read_yield=float(sy.yield_surface.min()),
        margin_min=float(sy.margin_min.min()),
        v_read=float(v_read),
        offset_sigma=float(sy.offset_sigma),
        percentile=float(percentile))


# --------------------------------------------------------------------------
# Refresh/scrub policy: measured retention + disturb budget -> the interval
# the system model charges (imc.evaluate).

@dataclasses.dataclass(frozen=True)
class RefreshPolicy:
    """Scrub schedule derived from measured read-path reliability.  Pure
    data (hashable): ``imc.evaluate`` charges the row refresh cost from
    the level's own timings, so one policy serves every level."""

    interval: float              # [s] scrub period (inf = never)
    limited_by: str              # "retention" | "disturb" | "none"
    tau_retention: float         # worst-corner extrapolated escape time [s]
    p1_read: float               # per-read disturb prob. at the read bias
    reads_max: float             # disturb-limited reads between scrubs
    ber_budget: float
    reads_per_cell_s: float


@functools.lru_cache(maxsize=None)
def derive_refresh_policy(
    kind: str = "afmtj",
    ber_budget: float = 1e-9,
    reads_per_cell_s: float = 1e6,
    v_read: float = 0.05,
    t_read: float = 0.5e-9,
    n_samples: int = 256,
    seed: int = 5,
    backend: str = "pallas",
    use_cache: bool = True,
) -> RefreshPolicy:
    """Scrub interval from measured physics: the tighter of

    * retention-limited: t with P(flip) <= budget under worst-corner
      extrapolated tau (``retention_campaign``), and
    * disturb-limited: N_max budget-compliant reads (accelerated disturb
      model at the operating read bias) / the cell read rate.

    The default read bias is *derated* to 0.05 V: at the circuit layer's
    nominal 0.1 V (half the ~0.19 V switching threshold) the fitted disturb
    model gives p1 ~ 1e-5/read, which no scrub schedule can absorb at a
    1e-9 budget — the disturb/sense-margin tension quantified in
    EXPERIMENTS.md §Retention.
    """
    ret = retention_campaign(kind, n_samples=n_samples, seed=seed,
                             backend=backend, use_cache=use_cache)
    tau_w = ret.worst_tau_op()
    t_ret = -tau_w * math.log1p(-ber_budget)

    model = fit_disturb_model(kind, n_samples=n_samples, seed=seed + 6,
                              backend=backend, use_cache=use_cache)
    # worst corner for disturb = smallest extrapolated barrier
    d_op = ret.delta_op()
    ci, ti = np.unravel_index(np.argmin(d_op), d_op.shape)
    p1 = model.p1(float(v_read), float(t_read), float(d_op[ci, ti]),
                  ret.tau0(int(ci), int(ti)))
    n_max = reads_between_refresh(p1, ber_budget)
    t_dist = n_max / float(reads_per_cell_s)

    interval = min(t_ret, t_dist)
    limited = ("retention" if t_ret <= t_dist else "disturb")
    if math.isinf(interval):
        limited = "none"
    return RefreshPolicy(interval=float(interval), limited_by=limited,
                         tau_retention=float(tau_w), p1_read=float(p1),
                         reads_max=float(n_max),
                         ber_budget=float(ber_budget),
                         reads_per_cell_s=float(reads_per_cell_s))

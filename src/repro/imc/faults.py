"""Hard-fault injection for AFMTJ crossbars: stuck-at cells, dead lines,
endurance wear-out, and the repair policies that contain them (DESIGN.md §13).

PR 5/6 model *parametric* non-idealities (corners, disturb, retention); this
module models *hard* defects — the failure modes a production memory ships
defect maps and spare rows for:

  stuck-at-G_off  cell pinned at the G_AP floor (electrode void, open)
  stuck-at-G_on   cell pinned at G_AP + G_FS (dielectric short): the nasty
                  one — a full-scale wrong weight, not a missing weight
  dead row/col    word-line / bit-line driver failures killing a whole line
  endurance wear  per-write-cycle Bernoulli wear-out that folds into an
                  effective stuck-off rate (cells die open as they cycle)
  drift           slow lognormal conductance relaxation (device path only,
                  like D2D sigma — the fake path raises)

Everything is drawn by the same stateless counter-RNG discipline as the
variation planes (``kernels/noise.py``): a draw depends only on
(seed, stream, lane), never on the fault *rate* or the repair policy, so

  * rates are **data** — the fake-analog path feeds them as traced scalars
    and a whole fault-rate sweep reuses ONE XLA compile (pinned in the
    ``fault`` bench), and raising the rate only *adds* defects (monotone
    coupling: the u <= rate threshold test shares uniforms across rates);
  * repair policies are CRN-paired — ``apply_repair`` transforms the same
    defect map, so policy A vs policy B comparisons see identical defects.

Fault codes are bit-ORs (``kernels/fake_analog.FAULT_*``) riding the
existing ``fail`` operand of the fused kernel; dead columns ride the aux
attenuation rows.  Masks are planes of data, not compile keys.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels import noise
from repro.kernels.fake_analog import (
    FAULT_DEAD,
    FAULT_NEG_OFF,
    FAULT_NEG_ON,
    FAULT_POS_OFF,
    FAULT_POS_ON,
    fail_bit,
)

# stream ids of the per-lane uniform draws (disjoint by construction)
_STREAM_POS = 0      # positive-cell defect class
_STREAM_NEG = 1      # negative-cell defect class
_STREAM_ROW = 2      # dead row drivers
_STREAM_COL = 3      # dead column drivers
_STREAM_DRIFT_P = 4  # conductance drift, positive array (device path)
_STREAM_DRIFT_N = 5  # conductance drift, negative array (device path)

_FAULT_GOLD = np.uint32(0x9E3779B1)
_FAULT_STREAM = 0xC2B2AE35


def _lane_seeds(seed, stream: int, count: int) -> jnp.ndarray:
    """(count,) uint32 stream seeds; ``seed`` may be traced (uint32 scalar).

    Mirrors ``noise.cell_seeds`` salted like ``VariationSpec._normals`` —
    but in pure jnp so the fake path can feed the seed as data.
    """
    base = (jnp.asarray(seed).astype(jnp.uint32) * _FAULT_GOLD
            + np.uint32(((stream + 1) * _FAULT_STREAM) & 0xFFFFFFFF))
    idx = jnp.arange(count, dtype=jnp.uint32)
    return noise.mix32(noise.mix32(base + idx * np.uint32(0x9E3779B9)))


def _lane_uniforms(seed, stream: int, count: int) -> jnp.ndarray:
    """(count,) f32 uniforms in (0, 1] — ``u <= rate`` at rate 0 is never
    true, so a zero-rate plane is exactly the empty defect map."""
    return noise._uniform24(_lane_seeds(seed, stream, count))


def fault_code_plane(rows: int, cols: int, *, seed, stuck_on, stuck_off,
                     dead_row) -> jnp.ndarray:
    """(rows, cols) f32 bit-code defect plane.

    ``seed`` and the three rates may be traced scalars (the fake-analog
    path passes them as data) or concrete floats (the device path).  One
    uniform per cell is split into disjoint [0, p_off] stuck-off and
    (p_off, p_off + p_on] stuck-on intervals, so the defect *positions*
    are a pure function of (seed, stream, lane) — CRN across rates and
    repair policies.
    """
    p_off = jnp.asarray(stuck_off, jnp.float32)
    p_on = jnp.asarray(stuck_on, jnp.float32)
    u_pos = _lane_uniforms(seed, _STREAM_POS, rows * cols).reshape(rows, cols)
    u_neg = _lane_uniforms(seed, _STREAM_NEG, rows * cols).reshape(rows, cols)
    u_row = _lane_uniforms(seed, _STREAM_ROW, rows)
    dead = (u_row <= jnp.asarray(dead_row, jnp.float32))[:, None]
    code = ((u_pos <= p_off) * float(FAULT_POS_OFF)
            + (u_neg <= p_off) * float(FAULT_NEG_OFF)
            + ((u_pos > p_off) & (u_pos <= p_off + p_on)) * float(FAULT_POS_ON)
            + ((u_neg > p_off) & (u_neg <= p_off + p_on)) * float(FAULT_NEG_ON)
            + dead * float(FAULT_DEAD))
    return code.astype(jnp.float32)


def column_ok_plane(cols: int, *, seed, dead_col) -> jnp.ndarray:
    """(cols,) f32 column-health plane: 1.0 healthy, 0.0 dead driver."""
    u = _lane_uniforms(seed, _STREAM_COL, cols)
    return (u > jnp.asarray(dead_col, jnp.float32)).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Hard-fault model knobs.  Hashable (rides ``AnalogConfig`` and cache
    keys); all rates are per-cell/per-line Bernoulli probabilities."""

    stuck_on_rate: float = 0.0    # cell pinned at G_on = G_AP + G_FS
    stuck_off_rate: float = 0.0   # cell pinned at the G_AP floor
    dead_row_rate: float = 0.0    # word-line driver dead (whole row)
    dead_col_rate: float = 0.0    # bit-line driver dead (whole column)
    wear_per_cycle: float = 0.0   # per-write-cycle wear-out Bernoulli
    write_cycles: float = 0.0     # cycles endured -> folds into stuck-off
    drift_sigma: float = 0.0      # lognormal conductance drift (device only)
    seed: int = 0
    rate: float = 0.0             # headline knob that sized the component
    #                               rates via ``at_rate`` (reporting only)

    @property
    def wear_rate(self) -> float:
        """P(cell has worn out open) after ``write_cycles`` cycles."""
        if self.wear_per_cycle <= 0.0 or self.write_cycles <= 0.0:
            return 0.0
        return 1.0 - (1.0 - self.wear_per_cycle) ** self.write_cycles

    @property
    def stuck_off_effective(self) -> float:
        """Stuck-off rate with endurance wear folded in (independent OR)."""
        return 1.0 - (1.0 - self.stuck_off_rate) * (1.0 - self.wear_rate)

    @property
    def cell_fault_rate(self) -> float:
        return self.stuck_on_rate + self.stuck_off_effective

    @property
    def any_faults(self) -> bool:
        return (self.cell_fault_rate > 0.0 or self.dead_row_rate > 0.0
                or self.dead_col_rate > 0.0 or self.drift_sigma > 0.0)

    @classmethod
    def at_rate(cls, rate: float, *, seed: int = 0,
                drift_sigma: float = 0.0) -> "FaultSpec":
        """Canonical single-knob mix used by the degradation sweeps:
        35% stuck-on, 35% stuck-off, 20% dead rows, 10% dead columns."""
        r = float(rate)
        return cls(stuck_on_rate=0.35 * r, stuck_off_rate=0.35 * r,
                   dead_row_rate=0.20 * r, dead_col_rate=0.10 * r,
                   drift_sigma=drift_sigma, seed=seed, rate=r)

    def planes(self, rows: int, cols: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Concrete (code, col_ok) defect planes for one array."""
        code = fault_code_plane(
            rows, cols, seed=np.uint32(self.seed & 0xFFFFFFFF),
            stuck_on=self.stuck_on_rate, stuck_off=self.stuck_off_effective,
            dead_row=self.dead_row_rate)
        col_ok = column_ok_plane(
            cols, seed=np.uint32(self.seed & 0xFFFFFFFF),
            dead_col=self.dead_col_rate)
        return code, col_ok


@dataclasses.dataclass(frozen=True)
class RepairPolicy:
    """Array repair knobs.  Hashable — the policy is a *compile key* (it
    restructures the trace); the fault rates stay data."""

    name: str = "none"
    spare_rows: int = 0          # remap capacity: worst rows -> spares
    spare_cols: int = 0          # revive capacity: dead columns -> spares
    mask_pairs: bool = False     # differential-pair-aware masking
    ecc_cells_per_row: int = 0   # lightweight ECC: stuck cells corrected/row


REPAIR_NONE = RepairPolicy()
REPAIR_SPARE = RepairPolicy(name="spare", spare_rows=8, spare_cols=8,
                            mask_pairs=True)
REPAIR_SPARE_ECC = RepairPolicy(name="spare+ecc", spare_rows=8, spare_cols=8,
                                mask_pairs=True, ecc_cells_per_row=1)
REPAIR_POLICIES = (REPAIR_NONE, REPAIR_SPARE, REPAIR_SPARE_ECC)


def apply_repair(code: jnp.ndarray, col_ok: jnp.ndarray,
                 policy: Optional[RepairPolicy]):
    """Transform the defect map the way the repair controller would.

    Fully traced (policy capacities are static ints), and draw-free: repair
    never consumes RNG, so the underlying defect map is identical across
    policies (CRN invariance, pinned in tests/test_faults.py).  Order:

      1. ECC side-table corrects up to ``ecc_cells_per_row`` stuck (not
         dead) pairs per row — their codes clear entirely.
      2. Differential-pair masking converts remaining stuck-ON pairs to
         dead pairs: a short contributes a full-scale wrong weight, a
         masked pair only loses |w| — bounded error.
      3. Spare-row remap clears the ``spare_rows`` worst faulty rows.
      4. Spare columns revive up to ``spare_cols`` dead columns.
    """
    if policy is None or policy == REPAIR_NONE:
        return code, col_ok
    rows = code.shape[0]
    dead = fail_bit(code, FAULT_DEAD)
    if policy.ecc_cells_per_row > 0:
        stuck = (code > 0.0) & ~dead
        cum = jnp.cumsum(stuck.astype(jnp.float32), axis=1)
        clear = stuck & (cum <= float(policy.ecc_cells_per_row))
        code = jnp.where(clear, 0.0, code)
    if policy.mask_pairs:
        stuck_on = ((fail_bit(code, FAULT_POS_ON)
                     | fail_bit(code, FAULT_NEG_ON)) & ~dead)
        code = jnp.where(stuck_on, float(FAULT_DEAD), code)
    if policy.spare_rows > 0:
        row_bad = jnp.sum((code > 0.0).astype(jnp.float32), axis=1)
        sel = jnp.argsort(-row_bad)[: policy.spare_rows]
        is_spare = jnp.zeros((rows,), bool).at[sel].set(True)
        is_spare = is_spare & (row_bad > 0.0)
        code = jnp.where(is_spare[:, None], 0.0, code)
    if policy.spare_cols > 0:
        dead_c = col_ok < 0.5
        cum_c = jnp.cumsum(dead_c.astype(jnp.float32))
        revive = dead_c & (cum_c <= float(policy.spare_cols))
        col_ok = jnp.where(revive, 1.0, col_ok)
    return code, col_ok


def apply_cell_faults(code: jnp.ndarray, g_pos: jnp.ndarray,
                      g_neg: jnp.ndarray, *, g_off, g_on):
    """Overwrite programmed conductances with the stuck/dead fault codes —
    the device-path twin of the decode inside ``pos_neg_conductance``
    (same priority: floor, then stuck-on, then dead)."""
    g_pos = jnp.where(fail_bit(code, FAULT_POS_OFF), g_off, g_pos)
    g_neg = jnp.where(fail_bit(code, FAULT_NEG_OFF), g_off, g_neg)
    g_pos = jnp.where(fail_bit(code, FAULT_POS_ON), g_on, g_pos)
    g_neg = jnp.where(fail_bit(code, FAULT_NEG_ON), g_on, g_neg)
    dead = fail_bit(code, FAULT_DEAD)
    g_pos = jnp.where(dead, 0.0, g_pos)
    g_neg = jnp.where(dead, 0.0, g_neg)
    return g_pos, g_neg


def drift_factors(spec: FaultSpec, rows: int, cols: int, *,
                  negative: bool) -> jnp.ndarray:
    """(rows, cols) mean-preserving lognormal drift multipliers,
    exp(sigma*z - sigma^2/2).  Device path only — the fused fake path
    raises on drift_sigma > 0 (same contract as D2D sigma)."""
    stream = _STREAM_DRIFT_N if negative else _STREAM_DRIFT_P
    lanes = _lane_seeds(np.uint32(spec.seed & 0xFFFFFFFF), stream,
                        rows * cols)
    z, _ = noise.normal_pair(lanes, jnp.uint32(0))
    s = float(spec.drift_sigma)
    return jnp.exp(s * z - 0.5 * s * s).reshape(rows, cols)

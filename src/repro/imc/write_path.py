"""Stochastic write path: write-verify programming through LLG transients.

The read path got functional in PR 2 (``imc.analog_pipeline``); this module
does the same for *writes* — the side the paper's headline claims are about
(~8x lower write latency, ~9x lower write energy than MTJs).  Instead of
assuming every write succeeds in one nominal pulse (the closed-form
``t_write``/``e_write`` constants in ``circuit.subarray``), a write-verify
scheduler programs arrays through actual thermal LLG transients:

  1. issue one fixed-width pulse per cell (a single-point Monte-Carlo
     campaign through ``campaign.run_campaign`` — the Pallas thermal kernel
     for the AFMTJ, the engine's FM scan tile for the MTJ baseline),
  2. read switching success back from the kernel's first-crossing row
     (crossed within the pulse <=> the verify read sees the new state),
  3. re-pulse only the failed cells (bit-selective rewrite: per-column
     write drivers mask passing bits) with fresh thermal samples, up to
     ``max_attempts`` rounds.

What comes out is *measured*: per-cell write latency / energy
distributions (mean + tail percentiles), retry histograms, and residual
bit-error rates as a function of pulse voltage, width and temperature —
the quantities a pipelined IMC controller actually schedules against
(``circuit.subarray.make_subarray(..., write_percentile=...)`` consumes
them; ``imc.mapping.write_energy_accuracy_surface`` turns the residual
BER into an accuracy-vs-write-energy surface).  See DESIGN.md §7.

Modeling conventions (documented, not hidden):

* **Independent attempts** — the verify interval re-thermalizes a failed
  cell inside its unswitched well, so each retry is an independent thermal
  trial (fresh Boltzmann initial tilt + fresh noise stream per round).
  Attempt counts are then geometric in the single-pulse WER, which the
  retry tests pin.
* **Two-state energy** — per-attempt energy integrates V^2 G(t) with the
  junction at G_P until the recorded crossing and at G_AP for the pulse
  remainder (failed attempts: G_P for the full pulse), plus the driver
  line-charge overhead ``t_rc`` at G_P.  This reproduces the deterministic
  ``simulate_write`` energies to a few percent (the reversal itself is fast
  compared to the incubation) and needs only the first-crossing row.
* **Verify cost** — ``t_verify``/``e_verify`` default to 0: in the
  pipelined controller the verify sense overlaps the next attempt's line
  charge (paper Sec. III-B).  Both are explicit policy knobs for
  non-pipelined accounting.

Process variation (DESIGN.md §9): ``WritePolicy.variation`` programs the
array as *sampled devices* — a single-corner ``VariationSpec`` draws each
cell's alpha/B_k/volume/RA once, holds the draw across that cell's
retries (a retry re-pulses the same junction with fresh thermal history),
and scales both the STT drive and the energy accounting by the cell's own
conductance; ``write_verify_corners`` sweeps a multi-corner spec into
per-corner measured distributions on paired random numbers.

Performance note (DESIGN.md §8): retry rounds are recompile-free.  The
engine pads each round's shrinking cell set to a power-of-two shape bucket
(``campaign.bucket_cells`` — extra lanes carry a zero step budget and cost
nothing), the per-round seed and Brown sigma are traced kernel inputs, and
the pulse horizon rides the per-lane step-budget row under a
power-of-two-quantized compiled horizon — so a ``max_attempts``-round
schedule compiles O(log cells) times, not once per round, and a
``write_surface`` sweep over (temperature x voltage x pulse) reuses those
same compiles across its whole grid.  ``ArrayWriteResult.rounds`` records
the rounds actually run; ``benchmarks/run.py --only write`` reports rounds
vs XLA compiles.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.campaign.engine import EARLY_EXIT_CHUNK, run_campaign, run_ensemble
from repro.campaign.grid import CampaignGrid
from repro.core.params import (AFMTJ_PARAMS, MTJ_PARAMS, DeviceParams,
                               VariationSpec)
from repro.imc.write_margin import DEVICE_DT


def _params_for(kind: str) -> DeviceParams:
    assert kind in ("afmtj", "mtj"), kind
    return AFMTJ_PARAMS if kind == "afmtj" else MTJ_PARAMS


@functools.lru_cache(maxsize=None)
def nominal_pulse(kind: str, v_write: float = 1.0) -> float:
    """Device-nominal per-attempt pulse [s]: the deterministic mean switching
    time x the 2% pulse margin (``circuit.subarray._characterize_write``).
    Thermal retries cover the tail the deterministic solve cannot see."""
    from repro.circuit.subarray import _characterize_write

    t_sw, _ = _characterize_write(kind, float(v_write))
    return float(t_sw)


@dataclasses.dataclass(frozen=True)
class WritePolicy:
    """Write-verify scheduling knobs (hashable -> usable as a cache key)."""

    v_write: float = 1.0
    pulse: Optional[float] = None     # per-attempt pulse [s]; None = nominal
    pulse_margin: float = 1.5         # x nominal when pulse is None: per-
                                      # attempt thermal margin (wer1 ~5% for
                                      # the AFMTJ at 1 V; retries mop up the
                                      # tail instead of a 2x worst-case pulse)
    max_attempts: int = 8
    t_rc: float = 40e-12              # driver line-charge overhead / attempt
    t_verify: float = 0.0             # verify read latency / attempt
    e_verify: float = 0.0             # verify read energy / attempt [J]
    temperature: Optional[float] = None   # None = device default (300 K)
    dt: Optional[float] = None        # None = per-device campaign step
    seed: int = 0
    backend: str = "pallas"
    use_cache: bool = True
    # Optional single-corner process-variation spec (DESIGN.md §9): D2D
    # parameter draws are per *device* and persist across retry rounds — a
    # retry re-pulses the same junction with fresh thermal history.  Use
    # ``write_verify_corners`` to sweep the corners of a multi-corner spec.
    variation: Optional[VariationSpec] = None
    # Donate each round's state block to its launch (DESIGN.md §14): retry
    # rounds then alias instead of accumulating per-round blocks, cutting
    # peak device memory across the schedule.  Deterministic, but the
    # alias-constrained compile may differ by +-1 step on rare lanes
    # (see engine._integrate_donated) — off by default so nominal write
    # ratios and every compile/bit pin keep the undonated jit.
    donate: bool = False

    def resolved_pulse(self, kind: str) -> float:
        if self.pulse is not None:
            return float(self.pulse)
        return float(nominal_pulse(kind, self.v_write) * self.pulse_margin)

    def resolved_dt(self, kind: str) -> float:
        return float(self.dt if self.dt is not None else DEVICE_DT[kind])

    @property
    def cycle_overhead(self) -> float:
        return self.t_rc + self.t_verify


@dataclasses.dataclass(frozen=True)
class ArrayWriteResult:
    """Measured write-verify statistics for one batch of cell writes."""

    kind: str
    policy: WritePolicy
    pulse: float                  # resolved per-attempt pulse [s]
    dt: float
    attempts: np.ndarray          # (cells,) pulses issued (1..max_attempts)
    success: np.ndarray           # (cells,) bool — verified within budget
    crossing_time: np.ndarray     # (cells,) [s] within the successful
                                  # attempt; NaN where the cell never wrote
    energy: np.ndarray            # (cells,) total write energy [J]
    elapsed_s: float              # simulation wall-clock
    rounds: int = 0               # retry rounds actually integrated

    @property
    def cycle(self) -> float:
        """One attempt's latency slot: line charge + pulse + verify."""
        return self.policy.cycle_overhead + self.pulse

    @property
    def latency(self) -> np.ndarray:
        """(cells,) total per-cell write latency [s]."""
        return self.attempts * self.cycle

    @property
    def attempts_mean(self) -> float:
        return float(self.attempts.mean()) if self.attempts.size else 0.0

    @property
    def residual_ber(self) -> float:
        """Fraction of cells still holding the wrong state after the
        attempt budget — the bit-error rate the read path inherits.
        Zero-cell batches (nothing to flip) report 0 errors, not NaN."""
        return float(1.0 - self.success.mean()) if self.success.size else 0.0

    @property
    def single_pulse_wer(self) -> float:
        """First-attempt failure fraction (the per-pulse WER the geometric
        retry statistics are built on).  Counts cells that did *not* verify
        on their first pulse — robust at any attempt budget (with
        ``max_attempts == 1`` a failed cell still shows ``attempts == 1``)."""
        if not self.success.size:
            return 0.0
        return float(1.0 - (self.success & (self.attempts == 1)).mean())

    def latency_percentile(self, q) -> np.ndarray:
        return np.percentile(self.latency, q)

    def energy_mean(self) -> float:
        return float(self.energy.mean()) if self.energy.size else 0.0

    def retry_histogram(self) -> np.ndarray:
        """(max_attempts + 1,) count of cells by attempts used (index 0
        unused — every written cell takes at least one pulse)."""
        return np.bincount(self.attempts,
                           minlength=self.policy.max_attempts + 1)

    def row_attempts(self, cols: int) -> np.ndarray:
        """(rows,) attempts a *row-granular* controller pays per row: failed
        bits re-pulse bit-selectively, but the row op retires only when its
        slowest bit verifies — the row cost is the max over its cells."""
        cells = self.attempts.size
        assert cells % cols == 0, (cells, cols)
        return self.attempts.reshape(cells // cols, cols).max(axis=1)

    def row_latency_percentile(self, cols: int, q: float) -> float:
        """Row write time [s] at percentile ``q`` over sampled rows — the
        stage time a pipelined controller should schedule (resolution is
        limited by the number of sampled rows)."""
        return float(np.percentile(self.row_attempts(cols), q) * self.cycle)


def write_verify(kind: str, n_cells: int,
                 policy: WritePolicy = WritePolicy()) -> ArrayWriteResult:
    """Write ``n_cells`` cells (P -> AP) through the retry scheduler.

    Each round is one single-point campaign over the still-unwritten cells:
    fresh Boltzmann initial states and fresh counter-RNG thermal streams
    (``CampaignGrid.seed`` folds in the round index), horizon = one pulse.
    Success is read off the first-crossing row; failures re-enter the next
    round.  Deterministic at a fixed ``policy.seed``.

    With ``policy.variation`` (a single-corner spec) each cell is a
    *sampled device*: corner/D2D parameter rows ride the kernel's
    variation plane, stay fixed across that cell's retries, and scale the
    two-state energy accounting by the cell's own conductance — slow-
    corner arrays retry more and pay more energy per attempt
    (``_write_verify_variation``).
    """
    if policy.variation is not None:
        return _write_verify_variation(kind, n_cells, policy)
    p = _params_for(kind)
    v = float(policy.v_write)
    pulse = policy.resolved_pulse(kind)
    dt = policy.resolved_dt(kind)
    temp = float(policy.temperature if policy.temperature is not None
                 else p.temperature)
    g_p = 1.0 / p.r_parallel
    g_ap = 1.0 / p.r_antiparallel
    e_rc = v * v * g_p * policy.t_rc

    attempts = np.zeros(n_cells, dtype=np.int64)
    success = np.zeros(n_cells, dtype=bool)
    crossing = np.full(n_cells, np.nan)
    energy = np.zeros(n_cells)
    remaining = np.arange(n_cells)

    t0 = time.time()
    rounds = 0
    for rnd in range(policy.max_attempts):
        if remaining.size == 0:
            break
        rounds += 1
        grid = CampaignGrid(
            voltages=(v,), pulse_widths=(pulse,), temperatures=(temp,),
            n_samples=int(remaining.size), dt=dt,
            seed=policy.seed * 1009 + rnd)
        res = run_campaign(p, grid, backend=policy.backend,
                           use_cache=policy.use_cache, donate=policy.donate)
        ct = res.crossing_time[0, 0]                  # (remaining,)
        ok = ct <= pulse

        attempts[remaining] += 1
        # two-state energy: G_P up to the crossing, G_AP for the remainder;
        # failed attempts sit at G_P the whole pulse
        e_att = np.where(ok,
                         v * v * (g_p * ct + g_ap * (pulse - ct)),
                         v * v * g_p * pulse)
        energy[remaining] += e_att + e_rc + policy.e_verify
        done = remaining[ok]
        success[done] = True
        crossing[done] = ct[ok]
        remaining = remaining[~ok]
    elapsed = time.time() - t0

    return ArrayWriteResult(kind=kind, policy=policy, pulse=pulse, dt=dt,
                            attempts=attempts, success=success,
                            crossing_time=crossing, energy=energy,
                            elapsed_s=elapsed, rounds=rounds)


def _write_verify_variation(kind: str, n_cells: int,
                            policy: WritePolicy) -> ArrayWriteResult:
    """Write-verify under per-device process variation (DESIGN.md §9).

    One D2D draw up front fixes every cell's device sample (alpha, B_k,
    volume -> Brown sigma / Boltzmann tilt, and the RA factor -> drive and
    energy conductances); each retry round then integrates the surviving
    cells through ``run_ensemble`` with the sampled rows on the kernel's
    variation plane — the lanes renumber per round but index back into the
    same per-device rows, so a cell's parameters persist across its
    retries while its thermal history is fresh (round-folded seed).
    Rounds stay recompile-free exactly like the nominal path: shape
    buckets + pow2-quantized horizon under a per-lane budget.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import llg

    p = _params_for(kind)
    spec = policy.variation
    assert spec is not None and spec.n_corners == 1, (
        "write_verify programs one corner's array; sweep corners with "
        "write_verify_corners")
    v = float(policy.v_write)
    pulse = policy.resolved_pulse(kind)
    dt = policy.resolved_dt(kind)
    temp = float(policy.temperature if policy.temperature is not None
                 else p.temperature)
    # horizon: one step past the pulse so the never-crossed sentinel
    # strictly exceeds it (same rule as CampaignGrid.n_steps)
    n_steps = int(math.ceil(pulse / dt)) + 1

    rows = spec.lane_rows(p, spec.corners[0], n_cells, dt, temperature=temp)
    kernel_rows = rows.kernel_rows                      # (3, n_cells) f32
    g_p = (1.0 / p.r_parallel) * rows.g_scale           # per-cell [S]
    g_ap = (1.0 / p.r_antiparallel) * rows.g_scale
    e_rc = v * v * g_p * policy.t_rc

    attempts = np.zeros(n_cells, dtype=np.int64)
    success = np.zeros(n_cells, dtype=bool)
    crossing = np.full(n_cells, np.nan)
    energy = np.zeros(n_cells)
    remaining = np.arange(n_cells)

    t0 = time.time()
    rounds = 0
    for rnd in range(policy.max_attempts):
        if remaining.size == 0:
            break
        rounds += 1
        m = int(remaining.size)
        seed_r = policy.seed * 1009 + rnd
        # fresh Boltzmann tilt per round, scaled by each survivor's own
        # theta0 (mirrors grid._plane_tilt_draws at t_index 0)
        key = jax.random.fold_in(jax.random.PRNGKey(seed_r), 0)
        k_th, k_ph = jax.random.split(key)
        zs = jnp.abs(jax.random.normal(k_th, (m,)))
        ph = jax.random.uniform(k_ph, (m,), maxval=2 * jnp.pi)
        th = zs * jnp.asarray(rows.theta0[remaining], jnp.float32) + 0.01
        m0 = jax.vmap(lambda t, f: llg.initial_state(p, t, f))(th, ph)
        res = run_ensemble(
            p, m0, jnp.full((m,), v, jnp.float32), dt, n_steps,
            seed=seed_r, backend=policy.backend, chunk=EARLY_EXIT_CHUNK,
            lane_params=kernel_rows[:, remaining],
            sigma_lanes=rows.sigma[remaining], donate=policy.donate)
        ct = res.crossing_time                          # (m,) [s]
        ok = ct <= pulse

        attempts[remaining] += 1
        gp_r, gap_r = g_p[remaining], g_ap[remaining]
        e_att = np.where(ok,
                         v * v * (gp_r * ct + gap_r * (pulse - ct)),
                         v * v * gp_r * pulse)
        energy[remaining] += e_att + e_rc[remaining] + policy.e_verify
        done = remaining[ok]
        success[done] = True
        crossing[done] = ct[ok]
        remaining = remaining[~ok]
    elapsed = time.time() - t0

    return ArrayWriteResult(kind=kind, policy=policy, pulse=pulse, dt=dt,
                            attempts=attempts, success=success,
                            crossing_time=crossing, energy=energy,
                            elapsed_s=elapsed, rounds=rounds)


def write_verify_corners(
    kind: str, n_cells: int,
    policy: WritePolicy = WritePolicy(),
    spec: Optional[VariationSpec] = None,
) -> Dict[str, ArrayWriteResult]:
    """Measured per-corner write distributions: one retry schedule per
    process corner of ``spec`` (default: ``policy.variation``).

    Corners share D2D draws and per-round tilt/thermal streams (common
    random numbers — ``VariationSpec.lane_factors`` is salted by stream,
    not corner position), so corner-to-corner retry/latency/energy deltas
    are paired per cell.  Returns ``{corner_name: ArrayWriteResult}``.
    """
    spec = spec if spec is not None else policy.variation
    assert spec is not None, "write_verify_corners needs a VariationSpec"
    return {
        corner.name: write_verify(
            kind, n_cells,
            dataclasses.replace(policy, variation=spec.at_corner(ci)))
        for ci, corner in enumerate(spec.corners)
    }


def program_bits(target: np.ndarray, kind: str = "afmtj",
                 policy: WritePolicy = WritePolicy(),
                 current: Optional[np.ndarray] = None,
                 ) -> Tuple[ArrayWriteResult, np.ndarray]:
    """Program a (rows, cols) bit matrix; returns the write statistics of
    the flipped cells plus the residual bit-error map.

    Only cells whose target differs from ``current`` (default: all-zeros
    erased array) get pulses; both switching directions are modeled by the
    same P -> AP transient (symmetric wells to first order).  The error map
    marks cells still holding stale data after ``policy.max_attempts`` —
    the map ``imc.analog_pipeline`` injects into weight programming.
    """
    target = np.asarray(target)
    assert target.ndim == 2, target.shape
    cur = (np.zeros_like(target) if current is None
           else np.asarray(current))
    flip = target != cur
    res = write_verify(kind, int(flip.sum()), policy)
    error_map = np.zeros(target.shape, dtype=bool)
    error_map[flip] = ~res.success
    return res, error_map


# --------------------------------------------------------------------------
# Measured subarray write timings — the circuit-layer client
# (``circuit.subarray.make_subarray(..., write_percentile=...)``).

@dataclasses.dataclass(frozen=True)
class MeasuredWrite:
    """Distribution summary the subarray timing model consumes."""

    t_write: float            # row write time at ``percentile`` [s]
    e_write_bit: float        # mean per-cell write energy [J]
    attempts_mean: float      # per-cell mean pulses
    attempts_row_mean: float  # mean over rows of the per-row max
    single_pulse_wer: float
    residual_ber: float
    pulse: float              # per-attempt pulse [s]
    percentile: float


@functools.lru_cache(maxsize=None)
def measured_write_timings(
    kind: str,
    v_write: float = 1.0,
    cols: int = 256,
    percentile: float = 99.0,
    t_rc: float = 40e-12,
    pulse: Optional[float] = None,
    max_attempts: int = 8,
    n_rows: int = 16,
    seed: int = 0,
    use_cache: bool = True,
    variation: Optional[VariationSpec] = None,
) -> MeasuredWrite:
    """Row-granular write timing from the measured retry distribution.

    Samples ``n_rows`` rows of ``cols`` cells through ``write_verify`` and
    reduces to the ``percentile`` row write time (max-over-row attempts x
    cycle) and the mean per-bit energy.  lru-cached in process; the
    underlying campaigns hit the on-disk cache, so hierarchy rebuilds pay
    only the reduction.  Percentile resolution is bounded by ``n_rows``.
    ``variation`` (hashable, single-corner) sizes the timings against a
    process corner's measured distribution instead of the nominal device.
    """
    policy = WritePolicy(v_write=float(v_write), pulse=pulse, t_rc=float(t_rc),
                         max_attempts=int(max_attempts), seed=int(seed),
                         use_cache=use_cache, variation=variation)
    res = write_verify(kind, int(cols) * int(n_rows), policy)
    row_att = res.row_attempts(int(cols))
    return MeasuredWrite(
        t_write=res.row_latency_percentile(int(cols), float(percentile)),
        e_write_bit=res.energy_mean(),
        attempts_mean=res.attempts_mean,
        attempts_row_mean=float(row_att.mean()),
        single_pulse_wer=res.single_pulse_wer,
        residual_ber=res.residual_ber,
        pulse=res.pulse,
        percentile=float(percentile),
    )


# --------------------------------------------------------------------------
# Sweep helper: residual-BER / latency / energy surfaces over the write
# operating point (pulse voltage, width, temperature).

@dataclasses.dataclass(frozen=True)
class WriteSurface:
    """Measured write statistics over (temperature x voltage x pulse)."""

    kind: str
    voltages: Tuple[float, ...]
    pulses: Tuple[float, ...]
    temperatures: Tuple[float, ...]
    residual_ber: np.ndarray     # (n_T, n_V, n_P)
    attempts_mean: np.ndarray    # (n_T, n_V, n_P)
    latency_mean: np.ndarray     # (n_T, n_V, n_P) [s]
    energy_mean: np.ndarray      # (n_T, n_V, n_P) [J]


def write_surface(
    kind: str,
    voltages: Tuple[float, ...] = (1.0,),
    pulses: Optional[Tuple[float, ...]] = None,
    temperatures: Optional[Tuple[float, ...]] = None,
    n_cells: int = 256,
    policy: WritePolicy = WritePolicy(),
) -> WriteSurface:
    """Residual bit-error / retry / cost maps vs the write operating point.

    ``pulses=None`` uses the device-nominal pulse only; axes ride the
    scheduler cell-by-cell (one retry ladder per grid point), so keep the
    grid small on CPU-interpret runs.
    """
    p = _params_for(kind)
    pulses = tuple(float(x) for x in (
        pulses if pulses is not None else (policy.resolved_pulse(kind),)))
    temperatures = tuple(float(x) for x in (
        temperatures if temperatures is not None else (p.temperature,)))
    voltages = tuple(float(x) for x in voltages)
    shape = (len(temperatures), len(voltages), len(pulses))
    ber = np.zeros(shape)
    att = np.zeros(shape)
    lat = np.zeros(shape)
    en = np.zeros(shape)
    for ti, temp in enumerate(temperatures):
        for vi, v in enumerate(voltages):
            for pi, pw in enumerate(pulses):
                pol = dataclasses.replace(policy, v_write=v, pulse=pw,
                                          temperature=temp)
                r = write_verify(kind, n_cells, pol)
                ber[ti, vi, pi] = r.residual_ber
                att[ti, vi, pi] = r.attempts_mean
                lat[ti, vi, pi] = float(r.latency.mean())
                en[ti, vi, pi] = r.energy_mean()
    return WriteSurface(kind=kind, voltages=voltages, pulses=pulses,
                        temperatures=temperatures, residual_ber=ber,
                        attempts_mean=att, latency_mean=lat, energy_mean=en)

"""Model-level analog accuracy: whole transformer forwards through the
AFMTJ differential-conductance MVM (DESIGN.md §12).

PR 2's ``imc.analog_pipeline`` scores one decode projection at a time; the
paper's case-study claim only matters if the analog path preserves accuracy
at the *model* level.  This module routes **every linear layer** of a real
architecture forward (``models/model.py``) through the analog MVM via the
``models.common.linear`` interception hook, and measures logits KL,
token-match rate, and task perplexity against the exact f32 forward across
the (adc_bits x TMR x process corner x residual write BER) surface.

Three execution modes per linear:

  * ``fake``   — the fused fake-analog Pallas kernel
                 (``kernels.fake_analog``): programming replayed inside the
                 matmul tiles, everything traced, one compile per
                 (shape, adc_bits); sweep axes (TMR, corner, BER, seed) are
                 plain data.  This is the tractable surface path.
  * ``device`` — the full ``program_weights`` + ``analog_matmul`` chain,
                 host-synced and compile-keyed per ADC full scale; the
                 ground truth the fake path is parity-pinned against, sped
                 up by the content-keyed weight-programming cache below.
  * ``bnn``    — the paper's 1-cell/weight XNOR mode
                 (``analog_pipeline.binary_matmul``), fully traced.

The forward here is *eagerly unrolled* over layers (stacked block params
indexed per repeat) instead of ``lax.scan``: the device path reduces to
Python floats during programming, which cannot live under a scan; the fake
and bnn paths are traced end-to-end and jitted whole-forward, so the unroll
costs only compile-time linear in depth at smoke sizes.

Weight-programming cache: ``program_weights`` is content-keyed on
(weight-array hash, programming-relevant AnalogConfig axes, corner, seed,
bitline) through ``campaign.cache``'s named-array store — an ``adc_bits``
or ``full_scale_sigmas`` sweep re-programs nothing, a TMR/corner/BER sweep
re-programs only the axis that changed.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.campaign import cache as _cache
from repro.circuit.bitline import BitlineParams, cell_conductance, column_ir_drop
from repro.configs.base import ArchConfig
from repro.configs.registry import get_arch, smoke_config
from repro.core.params import PROCESS_CORNERS, VariationSpec
from repro.imc import faults as hard_faults
from repro.imc.analog_pipeline import (AnalogConfig, ProgrammedArray,
                                       _device_for, _resolved_variation,
                                       analog_matmul, binary_matmul,
                                       program_weights)
from repro.imc.faults import FaultSpec, RepairPolicy
from repro.kernels.fake_analog import (ROW_ATT_NEG, ROW_ATT_POS, ROW_DECODE,
                                       ROW_G_AP, ROW_G_FS, ROW_G_SCALE,
                                       ROW_I_MAX, ROW_R_ACCESS, AUX_ROWS,
                                       fake_analog_mac_pallas,
                                       pos_neg_conductance)
from repro.kernels.ops import _default_interpret
from repro.models import model as model_mod
from repro.models.common import intercept_linears, rms_norm

# bumped when the programming chain changes numerically — stale cache
# entries then simply never match (same policy as campaign KERNEL_VERSION)
PROGRAMMING_VERSION = 1


# ---------------------------------------------------------------------------
# fake-analog fast path (single projection)
# ---------------------------------------------------------------------------
def _round_2sig(v: jnp.ndarray) -> jnp.ndarray:
    """Traceable equivalent of the device path's ``float(f"{v:.2g}")`` ADC
    full-scale rounding (2 significant digits).  Decimal-vs-binary half-way
    ties can differ in the last digit — parity tests pass an explicit
    ``i_max`` where exactness matters."""
    e = jnp.floor(jnp.log10(v))
    p = 10.0 ** (e - 1.0)
    return jnp.round(v / p) * p


def _fake_mvm_body(x, w, bl: BitlineParams, scal: Dict[str, jnp.ndarray], *,
                   adc_bits: int, apply_fet: bool, use_fail: bool,
                   ir_drop: bool, has_imax: bool, decode: bool,
                   interpret: bool, use_faults: bool = False,
                   repair: Optional[RepairPolicy] = None):
    """Traced fake-analog ``x @ w``: operand preamble + fused kernel.

    Everything numeric mirrors ``program_weights`` / ``kernel_operands`` /
    ``analog_matmul`` step for step, with host floats replaced by traced
    scalars (``scal``) so the whole chain jits."""
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    k_rows, n_cols = w.shape
    g_ap, g_fs = scal["g_ap"], scal["g_fs"]

    w_scale = jnp.max(jnp.abs(w))
    w_scale = jnp.where(w_scale == 0.0, 1.0, w_scale)
    wn = w / w_scale

    if use_fail:
        # identical draw stream to program_weights' residual write errors
        kber = jax.random.fold_in(jax.random.PRNGKey(scal["seed"]), 0x5EB)
        kb1, kb2 = jax.random.split(kber)
        fail = (jax.random.bernoulli(kb1, scal["ber"], wn.shape)
                .astype(jnp.float32)
                + 2.0 * jax.random.bernoulli(kb2, scal["ber"], wn.shape)
                .astype(jnp.float32))
    else:
        fail = jnp.zeros_like(wn)

    col_ok = None
    if use_faults:
        # hard-defect planes (DESIGN.md §13): rates + seed arrive as traced
        # scalars, so a fault-rate sweep is pure data — 0 new compiles.  The
        # repair policy IS a compile key (it restructures the trace).  Fault
        # bits are disjoint from the write-ber bits, so + is bitwise OR.
        code = hard_faults.fault_code_plane(
            k_rows, n_cols, seed=scal["f_seed"], stuck_on=scal["f_on"],
            stuck_off=scal["f_off"], dead_row=scal["f_drow"])
        col_ok = hard_faults.column_ok_plane(
            n_cols, seed=scal["f_seed"], dead_col=scal["f_dcol"])
        code, col_ok = hard_faults.apply_repair(code, col_ok, repair)
        fail = fail + code

    # column statistics (IR planes, ADC sizing) reduce over the same cell
    # conductances the kernel replays — shared helper, fused reductions
    tp, tn = pos_neg_conductance(wn, fail, g_ap, g_fs, scal["g_scale"],
                                 scal["r_access"], apply_fet=apply_fet,
                                 use_fail=use_fail or use_faults)
    if ir_drop:
        att_p = column_ir_drop(jnp.sum(tp, axis=0), bl)
        att_n = column_ir_drop(jnp.sum(tn, axis=0), bl)
        if col_ok is None:
            att_mean = 0.5 * (jnp.mean(att_p) + jnp.mean(att_n))
        else:
            # dead bit lines read zero; the decode gain calibrates over
            # live columns only (same association as the device path so an
            # all-live plane stays bit-identical to the no-fault trace)
            att_p = att_p * col_ok
            att_n = att_n * col_ok
            live = jnp.maximum(jnp.sum(col_ok), 1.0)
            att_mean = 0.5 * (jnp.sum(att_p) / live + jnp.sum(att_n) / live)
    else:
        ok = jnp.float32(1.0) if col_ok is None else col_ok
        att_p = jnp.ones((n_cols,), jnp.float32) * ok
        att_n = jnp.ones((n_cols,), jnp.float32) * ok
        att_mean = jnp.float32(1.0)

    x_scale = jnp.max(jnp.abs(x))
    x_scale = jnp.where(x_scale == 0.0, 1.0, x_scale)
    v = scal["v_read"] * x / x_scale

    if has_imax:
        i_max = scal["i_max"]
    else:
        g_diff = att_p[None, :] * tp - att_n[None, :] * tn
        g_rms = jnp.sqrt(jnp.mean(g_diff * g_diff))
        v_rms = jnp.sqrt(jnp.mean(v * v))
        i_sigma = v_rms * g_rms * math.sqrt(k_rows)
        i_max = _round_2sig(jnp.maximum(scal["fs_sigmas"] * i_sigma, 1e-30))
    dec = ((x_scale * w_scale) / (scal["v_read"] * g_fs * att_mean)
           if decode else jnp.float32(1.0))

    full = functools.partial(jnp.full, (n_cols,), dtype=jnp.float32)
    rows = [None] * AUX_ROWS
    rows[ROW_ATT_POS], rows[ROW_ATT_NEG] = att_p, att_n
    rows[ROW_I_MAX], rows[ROW_DECODE] = full(i_max), full(dec)
    rows[ROW_G_AP], rows[ROW_G_FS] = full(g_ap), full(g_fs)
    rows[ROW_G_SCALE], rows[ROW_R_ACCESS] = (full(scal["g_scale"]),
                                             full(scal["r_access"]))
    aux = jnp.stack(rows)
    return fake_analog_mac_pallas(v, wn, fail, aux, adc_bits=adc_bits,
                                  apply_fet=apply_fet,
                                  use_fail=use_fail or use_faults,
                                  interpret=interpret)


@functools.lru_cache(maxsize=None)
def _jitted_fake_mvm(adc_bits: int, apply_fet: bool, use_fail: bool,
                     ir_drop: bool, has_imax: bool, decode: bool,
                     interpret: bool, use_faults: bool = False,
                     repair: Optional[RepairPolicy] = None):
    body = functools.partial(_fake_mvm_body, adc_bits=adc_bits,
                             apply_fet=apply_fet, use_fail=use_fail,
                             ir_drop=ir_drop, has_imax=has_imax,
                             decode=decode, interpret=interpret,
                             use_faults=use_faults, repair=repair)
    return jax.jit(body)


def _fake_faults_mode(cfg: AnalogConfig) -> bool:
    """Whether the fused path should trace the fault machinery in.  Presence
    of a spec switches it on (an all-zero-rate spec is the empty defect map,
    pinned bit-identical to ``faults=None``); drift is device-path only —
    same contract as D2D sigma in ``_systematic_g_scale``."""
    if cfg.faults is None:
        return False
    if cfg.faults.drift_sigma > 0.0:
        raise NotImplementedError(
            "fake-analog path models hard fault codes only; conductance "
            "drift draws per-cell host-side factors — use mode='device'")
    return True


def _systematic_g_scale(cfg: AnalogConfig) -> Tuple[bool, float]:
    """(apply_fet, 1/r_factor) for the fake path — systematic corners only.
    D2D spreads draw per-cell host-side factors (``spec.lane_factors``) the
    fused kernel deliberately does not model; use mode="device" for those."""
    spec = _resolved_variation(cfg)
    if spec is None:
        return False, 1.0
    c = spec.corners[0]
    if c.sigma_alpha or c.sigma_b_aniso or c.sigma_volume or c.sigma_r:
        raise NotImplementedError(
            "fake-analog path models systematic process corners only; "
            "per-cell D2D spreads need the device path (mode='device')")
    return True, 1.0 / c.r_factor


def _fake_scalars(kind: str, cfg: AnalogConfig, bl: BitlineParams,
                  g_scale: float, i_max: Optional[float]
                  ) -> Dict[str, jnp.ndarray]:
    """The traced-scalar pack: same f32 roundings as ``program_weights``."""
    dev = _device_for(kind, cfg)
    fs = cfg.faults
    g_p_eff = float(cell_conductance(jnp.asarray(1.0 / dev.r_parallel), bl))
    g_ap_eff = float(cell_conductance(jnp.asarray(1.0 / dev.r_antiparallel), bl))
    return {
        "g_ap": jnp.float32(g_ap_eff),
        "g_fs": jnp.float32(g_p_eff - g_ap_eff),
        "g_scale": jnp.float32(g_scale),
        "r_access": jnp.float32(bl.r_access),
        "v_read": jnp.float32(cfg.v_read),
        "fs_sigmas": jnp.float32(cfg.full_scale_sigmas),
        "ber": jnp.float32(cfg.write_ber),
        "seed": jnp.int32(cfg.seed),
        "i_max": jnp.float32(0.0 if i_max is None else i_max),
        # hard-fault plane knobs (DESIGN.md §13) — data, not compile keys,
        # so a fault-rate sweep reuses one executable; zeros when no spec
        "f_seed": jnp.uint32(0 if fs is None else fs.seed & 0xFFFFFFFF),
        "f_on": jnp.float32(0.0 if fs is None else fs.stuck_on_rate),
        "f_off": jnp.float32(0.0 if fs is None else fs.stuck_off_effective),
        "f_drow": jnp.float32(0.0 if fs is None else fs.dead_row_rate),
        "f_dcol": jnp.float32(0.0 if fs is None else fs.dead_col_rate),
    }


def fake_analog_matmul(
    w: jnp.ndarray,                  # (K, N) float weights
    x: jnp.ndarray,                  # (M, K) activations (signed)
    kind: str = "afmtj",
    cfg: AnalogConfig = AnalogConfig(),
    bl: Optional[BitlineParams] = None,
    i_max: Optional[float] = None,   # explicit ADC full scale (parity pins)
    decode: bool = True,             # False: raw quantized currents
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """``x @ w`` through the fused fake-analog kernel — the fast,
    fully-traced equivalent of ``program_weights`` + ``analog_matmul``,
    parity-pinned in ``tests/test_analog_pipeline.py``."""
    assert w.ndim == 2 and x.ndim == 2 and x.shape[1] == w.shape[0], (
        x.shape, w.shape)
    bl = bl or BitlineParams(rows=w.shape[0])
    apply_fet, g_scale = _systematic_g_scale(cfg)
    scal = _fake_scalars(kind, cfg, bl, g_scale, i_max)
    interp = _default_interpret() if interpret is None else interpret
    fn = _jitted_fake_mvm(cfg.adc_bits, apply_fet, cfg.write_ber > 0.0,
                          cfg.ir_drop, i_max is not None, decode, interp,
                          _fake_faults_mode(cfg), cfg.repair)
    return fn(x, w, bl, scal)


# ---------------------------------------------------------------------------
# weight-programming cache (device path)
# ---------------------------------------------------------------------------
def _array_digest(a) -> str:
    a = np.ascontiguousarray(np.asarray(a, np.float32))
    h = hashlib.sha256(a.tobytes())
    h.update(str(a.shape).encode())
    return h.hexdigest()


def param_tree_hash(tree: Any) -> str:
    """Content hash of a parameter pytree, stable under dict-key insertion
    order (leaves are keyed by their canonical tree path)."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload = sorted((jax.tree_util.keystr(path), _array_digest(leaf))
                     for path, leaf in leaves)
    return _cache.content_key({"params": payload})


def programming_key(w, kind: str, cfg: AnalogConfig,
                    bl: BitlineParams) -> str:
    """Content key over the *programming-relevant* axes only: sweeping
    ``adc_bits`` / ``full_scale_sigmas`` / ``v_read`` (pure read-out knobs)
    hits the cache; TMR / corner / BER / seed / IR-drop re-program."""
    spec = _resolved_variation(cfg)
    return _cache.content_key({
        "v": PROGRAMMING_VERSION,
        "kind": kind,
        "w": _array_digest(w),
        "tmr": cfg.tmr,
        "ir_drop": cfg.ir_drop,
        "seed": cfg.seed,
        "write_ber": cfg.write_ber,
        "variation": None if spec is None else {
            "corners": [dataclasses.asdict(c) for c in spec.corners],
            "seed": spec.seed,
            "distribution": spec.distribution,
        },
        "faults": (None if cfg.faults is None
                   else dataclasses.asdict(cfg.faults)),
        "repair": (None if cfg.repair is None
                   else dataclasses.asdict(cfg.repair)),
        "bitline": dataclasses.asdict(bl),
    })


def program_weights_cached(
    w: jnp.ndarray,
    kind: str = "afmtj",
    cfg: AnalogConfig = AnalogConfig(),
    bl: Optional[BitlineParams] = None,
    cache_dir: Optional[str] = None,
) -> ProgrammedArray:
    """``program_weights`` behind the content-keyed store: a cache hit
    returns the identical conductance plane + calibration scalars without
    touching the programming chain."""
    bl = bl or BitlineParams(rows=w.shape[0])
    key = programming_key(w, kind, cfg, bl)
    hit = _cache.load_arrays(key, cache_dir)
    if hit is not None and "g_diff" in hit:
        s = hit["scalars"]
        return ProgrammedArray(
            g_diff=jnp.asarray(hit["g_diff"], jnp.float32),
            w_scale=float(s[0]), g_fs=float(s[1]), att_mean=float(s[2]),
            g_rms=float(s[3]), dev=_device_for(kind, cfg), bl=bl, cfg=cfg)
    arr = program_weights(w, kind, cfg, bl)
    _cache.store_arrays(
        key,
        {"g_diff": np.asarray(arr.g_diff, np.float32),
         "scalars": np.asarray([arr.w_scale, arr.g_fs, arr.att_mean,
                                arr.g_rms], np.float64)},
        {"kind": kind, "shape": list(arr.g_diff.shape), "tmr": cfg.tmr,
         "seed": cfg.seed, "write_ber": cfg.write_ber, "key": key},
        cache_dir)
    return arr


# ---------------------------------------------------------------------------
# unrolled model forward + interception hooks
# ---------------------------------------------------------------------------
def _forward_unrolled(params, cfg: ArchConfig, tokens: jnp.ndarray):
    """Full-sequence logits via an eager layer unroll (no lax.scan — the
    device-path hook reduces to host floats, which cannot cross a scan).
    Decoder-only: same blocks as ``forward_train``, full logits returned."""
    assert cfg.n_encoder_layers == 0, "analog routing covers decoder-only"
    x = model_mod._embed(params, cfg, tokens)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    for rep in range(cfg.n_pattern_repeats):
        lp = jax.tree_util.tree_map(lambda a: a[rep], params["blocks"])
        for i, (mixer, f) in enumerate(cfg.pattern):
            x, _ = model_mod._run_block(lp[f"pos{i}"], x, cfg, mixer, f,
                                        positions)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return model_mod._logits(params, cfg, x)


def model_forward_logits(params, cfg: ArchConfig, tokens, hook=None):
    """Eager unrolled forward; ``hook(x2d, w, tag)`` intercepts every
    linear (None = exact f32 reference)."""
    if hook is None:
        return _forward_unrolled(params, cfg, tokens)
    with intercept_linears(hook):
        return _forward_unrolled(params, cfg, tokens)


@functools.lru_cache(maxsize=None)
def _jitted_ref_forward(cfg: ArchConfig):
    return jax.jit(lambda params, tokens: _forward_unrolled(params, cfg,
                                                            tokens))


@functools.lru_cache(maxsize=None)
def _jitted_fake_forward(cfg: ArchConfig, adc_bits: int, apply_fet: bool,
                         use_fail: bool, ir_drop: bool, interpret: bool,
                         use_faults: bool = False,
                         repair: Optional[RepairPolicy] = None):
    """Whole forward jitted with the fake-analog hook traced in: one XLA
    executable per (arch, adc_bits[, repair policy]) — TMR/corner/BER/seed
    and the fault rates arrive as data."""
    body = functools.partial(_fake_mvm_body, adc_bits=adc_bits,
                             apply_fet=apply_fet, use_fail=use_fail,
                             ir_drop=ir_drop, has_imax=False, decode=True,
                             interpret=interpret, use_faults=use_faults,
                             repair=repair)

    @jax.jit
    def run(params, tokens, scal):
        # rows = K of each site, like the device path's per-layer
        # BitlineParams — shapes are static at trace time, so every site
        # bakes its own IR line length into the one executable
        def hook(x2, w, tag):
            return body(x2, w, BitlineParams(rows=w.shape[0]), scal)

        with intercept_linears(hook):
            return _forward_unrolled(params, cfg, tokens)

    return run


@functools.lru_cache(maxsize=None)
def _jitted_bnn_forward(cfg: ArchConfig, tie: int, interpret: bool):
    @jax.jit
    def run(params, tokens):
        with intercept_linears(
                lambda x2, w, tag: binary_matmul(x2, w, tie=tie,
                                                 interpret=interpret)):
            return _forward_unrolled(params, cfg, tokens)

    return run


def analog_model_logits(
    params, cfg: ArchConfig, tokens,
    acfg: AnalogConfig = AnalogConfig(),
    kind: str = "afmtj",
    mode: str = "fake",              # fake | device | bnn
    tie: int = 1,
    cache_dir: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Full-sequence logits with every linear routed through the analog MVM."""
    interp = _default_interpret() if interpret is None else interpret
    if mode == "fake":
        apply_fet, g_scale = _systematic_g_scale(acfg)
        fn = _jitted_fake_forward(cfg, acfg.adc_bits, apply_fet,
                                  acfg.write_ber > 0.0, acfg.ir_drop, interp,
                                  _fake_faults_mode(acfg), acfg.repair)
        # device constants are rows-independent (the FET series combination
        # has no wire term), so one scalar pack serves every layer
        scal = _fake_scalars(kind, acfg, BitlineParams(), g_scale, None)
        return fn(params, tokens, scal)
    if mode == "bnn":
        return _jitted_bnn_forward(cfg, tie, interp)(params, tokens)
    if mode == "device":
        def hook(x2, w, tag):
            arr = program_weights_cached(w, kind, acfg,
                                         BitlineParams(rows=w.shape[0]),
                                         cache_dir)
            return analog_matmul(arr, x2, interpret=interp)

        return model_forward_logits(params, cfg, tokens, hook)
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# accuracy metrics + surfaces
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelAccuracyReport:
    """Model-level accuracy of one analog configuration point."""

    arch: str
    kind: str
    mode: str                      # fake | device | bnn
    adc_bits: int
    tmr: float
    corner: str                    # systematic process corner name
    write_ber: float
    kl: float                      # mean KL(ref || analog) over positions
    token_match: float             # greedy-argmax agreement rate
    ppl_analog: float              # next-token perplexity, analog logits
    ppl_ref: float                 # next-token perplexity, exact logits
    batch: int
    seq_len: int
    fault_rate: float = 0.0        # headline hard-fault rate (FaultSpec.rate)
    repair: str = "none"           # repair policy name


def logit_metrics(ref_logits, ana_logits, tokens
                  ) -> Tuple[float, float, float, float]:
    """(kl, token_match, ppl_analog, ppl_ref) from two (B, S, V) logit sets."""
    lr = jax.nn.log_softmax(jnp.asarray(ref_logits, jnp.float32), axis=-1)
    la = jax.nn.log_softmax(jnp.asarray(ana_logits, jnp.float32), axis=-1)
    p = jnp.exp(lr)
    kl = float(jnp.mean(jnp.sum(p * (lr - la), axis=-1)))
    match = float(jnp.mean(
        (jnp.argmax(la, axis=-1) == jnp.argmax(lr, axis=-1))
        .astype(jnp.float32)))

    def ppl(lp):
        gold = jnp.take_along_axis(lp[:, :-1],
                                   tokens[:, 1:][..., None], axis=-1)
        return float(jnp.exp(-jnp.mean(gold)))

    return kl, match, ppl(la), ppl(lr)


def _arch_config(arch: str, smoke: bool) -> ArchConfig:
    return smoke_config(arch) if smoke else get_arch(arch)


def _setup(arch: str, smoke: bool, batch: int, seq_len: int, seed: int):
    """(cfg, params, tokens, ref_logits) shared across surface points."""
    cfg = _arch_config(arch, smoke)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq_len)),
                         jnp.int32)
    ref_logits = _jitted_ref_forward(cfg)(params, tokens)
    return cfg, params, tokens, ref_logits


def _corner_spec(corner: str, seed: int) -> Optional[VariationSpec]:
    if corner in ("", "tt"):
        # tt is the all-1.0 nominal corner: identical conductances with or
        # without the FET round trip, so skip the spec (and the recompile)
        return None
    return VariationSpec(corners=(PROCESS_CORNERS[corner],), seed=seed)


def model_accuracy(
    arch: str = "qwen2-0.5b",
    acfg: AnalogConfig = AnalogConfig(),
    kind: str = "afmtj",
    mode: str = "fake",
    corner: str = "tt",
    batch: int = 2,
    seq_len: int = 64,
    seed: int = 0,
    smoke: bool = True,
    tie: int = 1,
    cache_dir: Optional[str] = None,
    _setup_state=None,
) -> ModelAccuracyReport:
    """One surface point: route the forward through the analog path, score
    against the exact f32 logits on synthetic token sequences."""
    if _setup_state is None:
        _setup_state = _setup(arch, smoke, batch, seq_len, seed)
    cfg, params, tokens, ref_logits = _setup_state
    spec = _corner_spec(corner, acfg.seed)
    if spec is not None:
        acfg = dataclasses.replace(acfg, variation=spec)
    ana = analog_model_logits(params, cfg, tokens, acfg, kind=kind,
                              mode=mode, tie=tie, cache_dir=cache_dir)
    kl, match, ppl_a, ppl_r = logit_metrics(ref_logits, ana, tokens)
    tmr = acfg.tmr if acfg.tmr is not None else _device_for(kind, acfg).tmr
    fspec = acfg.faults
    frate = 0.0 if fspec is None else (fspec.rate or fspec.cell_fault_rate)
    return ModelAccuracyReport(
        arch=arch, kind=kind, mode=mode, adc_bits=acfg.adc_bits,
        tmr=float(tmr), corner=corner, write_ber=acfg.write_ber, kl=kl,
        token_match=match, ppl_analog=ppl_a, ppl_ref=ppl_r, batch=batch,
        seq_len=seq_len, fault_rate=float(frate),
        repair="none" if acfg.repair is None else acfg.repair.name)


def model_accuracy_surface(
    arch: str = "qwen2-0.5b",
    kind: str = "afmtj",
    mode: str = "fake",
    adc_bits: Sequence[int] = (4, 6, 8),
    tmrs: Sequence[Optional[float]] = (None,),
    corners: Sequence[str] = ("tt",),
    write_bers: Sequence[float] = (0.0,),
    fault_rates: Sequence[float] = (0.0,),
    repair: Optional[RepairPolicy] = None,
    batch: int = 2,
    seq_len: int = 64,
    seed: int = 0,
    smoke: bool = True,
    cache_dir: Optional[str] = None,
) -> Tuple[ModelAccuracyReport, ...]:
    """The model-level accuracy surface: full outer product of the
    non-ideality axes, model/params/reference set up once.  The default
    ``fault_rates=(0.0,)`` keeps the fault machinery out of the trace
    entirely (bit-identical to pre-fault surfaces)."""
    state = _setup(arch, smoke, batch, seq_len, seed)
    out = []
    for fr in fault_rates:
        fspec = None if fr == 0.0 else FaultSpec.at_rate(float(fr), seed=seed)
        for ber in write_bers:
            for corner in corners:
                for tmr in tmrs:
                    for bits in adc_bits:
                        acfg = AnalogConfig(
                            adc_bits=bits, tmr=tmr, write_ber=ber, seed=seed,
                            faults=fspec,
                            repair=repair if fspec is not None else None)
                        out.append(model_accuracy(
                            arch, acfg, kind=kind, mode=mode, corner=corner,
                            batch=batch, seq_len=seq_len, seed=seed,
                            smoke=smoke, cache_dir=cache_dir,
                            _setup_state=state))
    return tuple(out)


def model_degradation_curves(
    arch: str = "qwen2-0.5b",
    kind: str = "afmtj",
    rates: Sequence[float] = (0.0, 1e-3, 3e-3, 1e-2, 3e-2),
    policies: Sequence[Optional[RepairPolicy]] = (None,
                                                 hard_faults.REPAIR_SPARE),
    adc_bits: int = 6,
    mode: str = "fake",
    batch: int = 2,
    seq_len: int = 64,
    seed: int = 0,
    smoke: bool = True,
    cache_dir: Optional[str] = None,
) -> Tuple[ModelAccuracyReport, ...]:
    """Graceful-degradation curves: model accuracy vs fault rate x repair
    policy (DESIGN.md §13).  A ``FaultSpec`` is present at every point —
    including rate 0 — so each policy's whole rate sweep shares ONE XLA
    executable (rates are data; pinned in the ``fault`` bench), and the
    counter-RNG keeps the defect maps CRN-paired across policies."""
    state = _setup(arch, smoke, batch, seq_len, seed)
    out = []
    for pol in policies:
        for r in rates:
            acfg = AnalogConfig(
                adc_bits=adc_bits, seed=seed,
                faults=FaultSpec.at_rate(float(r), seed=seed), repair=pol)
            out.append(model_accuracy(
                arch, acfg, kind=kind, mode=mode, batch=batch,
                seq_len=seq_len, seed=seed, smoke=smoke, cache_dir=cache_dir,
                _setup_state=state))
    return tuple(out)


def degradation_knee(reports: Sequence[ModelAccuracyReport],
                     min_token_match: float = 0.8) -> Dict[str, float]:
    """Per repair policy, the largest swept fault rate still meeting the
    accuracy bar — the knee where remapping stops saving accuracy.  (The
    CRN monotone coupling makes accuracy-vs-rate monotone per policy, so
    max-passing-rate is the knee.)"""
    knees: Dict[str, float] = {}
    for r in reports:
        knees.setdefault(r.repair, 0.0)
        if r.token_match >= min_token_match:
            knees[r.repair] = max(knees[r.repair], r.fault_rate)
    return knees

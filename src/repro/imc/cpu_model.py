"""Analytical ARM Cortex-A72 baseline (paper Sec. IV-A).

2 GHz, 32 KB L1 / 1 MB L2 / 8 GB DRAM.  Workload kernels are modeled as
NEON-vectorized streaming loops: per-element cost = max(compute-bound,
memory-bound) where the compute term comes from the kernel's instruction
mix (scalar instructions / 128-bit SIMD lanes) and the memory term from the
level the working set streams out of.

Energy: per-instruction core energy + per-access cache/DRAM energy, with
constants in the range published for A72-class cores at 16 nm (core
~30 pJ/instr incl. pipeline overheads; L1 ~15 pJ, L2 ~60 pJ per 64 B
line; LPDDR4X-class DRAM ~0.3 nJ per 64 B line ~ 4.7 pJ/B active energy —
the A72 baseline is a mobile SoC).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CPUModel:
    freq_hz: float = 2.0e9
    ipc: float = 2.0                  # sustained on streaming kernels
    simd_lanes_8b: int = 16           # 128-bit NEON
    e_instr: float = 30e-12           # core energy / instruction [J]
    # memory system
    l1_bytes: int = 32 * 1024
    l2_bytes: int = 1 * 1024 * 1024
    bw_l1: float = 32e9               # sustained stream bandwidth [B/s]
    bw_l2: float = 20e9
    bw_dram: float = 10e9
    e_l1_line: float = 15e-12         # energy / 64B line
    e_l2_line: float = 60e-12
    e_dram_line: float = 0.3e-9       # LPDDR4X-class mobile DRAM
    line_bytes: int = 64

    def stream_level(self, footprint_bytes: int) -> str:
        if footprint_bytes <= self.l1_bytes:
            return "L1"
        if footprint_bytes <= self.l2_bytes:
            return "L2"
        return "DRAM"

    def kernel_time_energy(
        self,
        n_elems: int,
        instrs_per_elem: float,
        simd_fraction: float,
        bytes_per_elem: float,
        footprint_bytes: int,
    ):
        """Return (seconds, joules) for a streaming kernel.

        instrs_per_elem: scalar-equivalent instruction count per element.
        simd_fraction:   fraction of those instructions that vectorize
                         across ``simd_lanes_8b`` lanes.
        bytes_per_elem:  memory traffic per element (read+write).
        """
        eff_instrs = n_elems * (
            instrs_per_elem * (1.0 - simd_fraction)
            + instrs_per_elem * simd_fraction / self.simd_lanes_8b
        )
        t_compute = eff_instrs / (self.ipc * self.freq_hz)

        level = self.stream_level(footprint_bytes)
        bw = {"L1": self.bw_l1, "L2": self.bw_l2, "DRAM": self.bw_dram}[level]
        traffic = n_elems * bytes_per_elem
        t_memory = traffic / bw

        t = max(t_compute, t_memory)

        e_line = {
            "L1": self.e_l1_line,
            "L2": self.e_l1_line + self.e_l2_line,
            "DRAM": self.e_l1_line + self.e_l2_line + self.e_dram_line,
        }[level]
        e = eff_instrs * self.e_instr + (traffic / self.line_bytes) * e_line
        return t, e


CORTEX_A72 = CPUModel()

"""Hierarchical in-memory-computing architecture model (paper Sec. III/IV).

  hierarchy — L1/L2/main-memory AFMTJ subarray organization (CHIME-style)
  cpu_model — ARM Cortex-A72 analytical baseline (2 GHz, 32KB L1/1MB L2/8GB)
  workloads — the paper's six kernels as op traces (bnn, img-grayscale,
              img-threshold, mac, mat_add, rmse)
  evaluate  — system-level latency/energy vs the CPU baseline (Fig. 4)
  mapping   — beyond-paper: mapping LM-architecture inference onto the IMC
  write_margin — WER-targeted write-pulse sizing via the campaign engine
  write_path — stochastic write path: write-verify retry scheduler over
              thermal LLG transients, measured latency/energy/retry
              distributions and residual bit-error rates (DESIGN.md §7)
  analog_pipeline — functional analog MVM through the Pallas bitline/XNOR
              kernels: conductance programming, IR drop, signed ADC
              (DESIGN.md §6)
  read_path — read-disturb / retention / sense-margin scenario family
              through the fused campaign engine, measured read timings and
              the retention+disturb-derived refresh policy (DESIGN.md §10)
  model_analog — model-level analog accuracy: whole transformer forwards
              routed through the analog MVM via the linear-interception
              hook, fused fake-analog fast path + weight-programming cache
              (DESIGN.md §12)
  faults    — hard-fault injection: stuck-at / dead-line / endurance-wear
              defect planes via the counter-RNG (rates are data, not
              compile keys), repair policies (spare lines, pair masking,
              ECC) and CRN-paired degradation studies (DESIGN.md §13)
"""
from repro.imc.cpu_model import CPUModel, CORTEX_A72  # noqa: F401
from repro.imc.workloads import WORKLOADS, Workload  # noqa: F401

# Everything touching the circuit stack re-exports lazily (PEP 562): the
# hierarchy/evaluate chain imports JAX and the campaign engine pulls
# shard_map + Pallas — costs that JAX-free consumers (the serving
# scheduler/traffic/simulator stack, ``imc.cost_model`` at import time)
# must not pay at package-import time.
_HIERARCHY_EXPORTS = ("IMCHierarchy", "build_hierarchy")
_EVALUATE_EXPORTS = ("evaluate_system", "SystemResult")
_WRITE_MARGIN_EXPORTS = ("wer_margined_pulse",)
_ANALOG_EXPORTS = ("AnalogConfig", "AccuracyReport", "ProgrammedArray",
                   "analog_matmul", "binary_matmul", "mvm_accuracy",
                   "program_weights", "kernel_operands")
_WRITE_PATH_EXPORTS = ("WritePolicy", "ArrayWriteResult", "MeasuredWrite",
                       "WriteSurface", "write_verify", "program_bits",
                       "measured_write_timings", "write_surface",
                       "nominal_pulse")
_MODEL_ANALOG_EXPORTS = ("ModelAccuracyReport", "fake_analog_matmul",
                         "program_weights_cached", "programming_key",
                         "param_tree_hash", "model_forward_logits",
                         "analog_model_logits", "model_accuracy",
                         "model_accuracy_surface", "logit_metrics")
_FAULTS_EXPORTS = ("FaultSpec", "RepairPolicy", "REPAIR_NONE", "REPAIR_SPARE",
                   "REPAIR_SPARE_ECC", "REPAIR_POLICIES", "apply_repair",
                   "fault_code_plane", "column_ok_plane")
_READ_PATH_EXPORTS = ("ReadDisturbResult", "DisturbModel", "RetentionResult",
                      "SenseYieldResult", "SizedRead", "MeasuredRead",
                      "RefreshPolicy", "read_disturb_campaign",
                      "fit_disturb_model", "accumulated_disturb",
                      "reads_between_refresh", "retention_campaign",
                      "retention_horizons", "sense_margin_yield",
                      "size_read_drive", "measured_read_timings",
                      "derive_refresh_policy")


def __getattr__(name):
    if name in _HIERARCHY_EXPORTS:
        from repro.imc import hierarchy

        return getattr(hierarchy, name)
    if name in _EVALUATE_EXPORTS:
        from repro.imc import evaluate

        return getattr(evaluate, name)
    if name in _WRITE_MARGIN_EXPORTS:
        from repro.imc import write_margin

        return getattr(write_margin, name)
    if name in _ANALOG_EXPORTS:
        from repro.imc import analog_pipeline

        return getattr(analog_pipeline, name)
    if name in _WRITE_PATH_EXPORTS:
        from repro.imc import write_path

        return getattr(write_path, name)
    if name in _FAULTS_EXPORTS:
        from repro.imc import faults

        return getattr(faults, name)
    if name in _READ_PATH_EXPORTS:
        from repro.imc import read_path

        return getattr(read_path, name)
    if name in _MODEL_ANALOG_EXPORTS:
        from repro.imc import model_analog

        return getattr(model_analog, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

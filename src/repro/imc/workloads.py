"""The paper's six workloads as device-independent op traces (Sec. IV-A).

Each workload is characterized on two targets:

* CPU (Cortex-A72): scalar-equivalent instructions / element, the fraction
  that NEON-vectorizes, memory traffic and footprint (picks the stream level).
* IMC: bit-serial in-array op counts per element — 2-row logic (XOR/NAND...),
  3-row majority (the carry primitive), row writes and reads.  Counts follow
  the standard Pinatubo/MAGIC-style bit-serial arithmetic decompositions:
    8-bit add       : per bit 2x XOR + 1x MAJ + 2 writes (sum, carry)
    8-bit multiply  : 8 shifted partial-product adds => ~16x the add counts
    8-bit compare   : borrow-chain subtract, 1-bit output
  BNN layers use the native XNOR + popcount path (the paper's headline
  workload — binary weights stay resident, only activations are written
  back, but EVERY output bit is a fresh in-array write => write-intensive).
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    n_elems: int
    # CPU side
    cpu_instrs_per_elem: float
    cpu_simd_fraction: float
    cpu_bytes_per_elem: float
    footprint_bytes: int
    # IMC side (per element)
    logic2: float
    logic3: float
    writes: float
    reads: float
    bits_per_elem: float = 8.0   # 1.0 for binary (bnn) elements


def _mb(x: float) -> int:
    return int(x * 1024 * 1024)


# 8-bit add: 16 logic2 + 8 maj + 17 writes; 8-bit mul ~ 8 partial adds.
_ADD = dict(logic2=16.0, logic3=8.0, writes=17.0, reads=2.0)
_MUL = dict(logic2=128.0, logic3=64.0, writes=136.0, reads=8.0)

WORKLOADS: Dict[str, Workload] = {
    # Binarized NN layer: 1M binary MACs; weights resident in-array.
    # CPU must pack bits / popcount per word; IMC XNORs whole rows and
    # writes back binarized activations + popcount partials (write-heavy).
    "bnn": Workload(
        "bnn", n_elems=1 << 18,
        cpu_instrs_per_elem=0.8, cpu_simd_fraction=0.75,
        cpu_bytes_per_elem=0.25, footprint_bytes=_mb(0.0625),
        logic2=1.0, logic3=2.0, writes=3.0, reads=0.25,
        bits_per_elem=1.0,
    ),
    # RGB -> gray: y = (77r + 150g + 29b) >> 8 per pixel.
    "img-grayscale": Workload(
        "img-grayscale", n_elems=1 << 19,
        cpu_instrs_per_elem=8.0, cpu_simd_fraction=0.9,
        cpu_bytes_per_elem=4.0, footprint_bytes=_mb(2),
        logic2=3 * 16.0, logic3=3 * 8.0, writes=3 * 17.0, reads=4.0,
    ),
    # Per-pixel compare against a constant threshold.
    "img-threshold": Workload(
        "img-threshold", n_elems=1 << 19,
        cpu_instrs_per_elem=3.0, cpu_simd_fraction=0.95,
        cpu_bytes_per_elem=2.0, footprint_bytes=_mb(1),
        logic2=16.0, logic3=8.0, writes=9.0, reads=2.0,
    ),
    # Multiply-accumulate streams: c += a*b (8-bit x 8-bit -> 16-bit acc).
    "mac": Workload(
        "mac", n_elems=1 << 18,
        cpu_instrs_per_elem=2.0, cpu_simd_fraction=0.9,
        cpu_bytes_per_elem=6.0, footprint_bytes=_mb(1.5),
        logic2=_MUL["logic2"] + 2 * 16.0, logic3=_MUL["logic3"] + 2 * 8.0,
        writes=_MUL["writes"] + 2 * 17.0, reads=_MUL["reads"],
    ),
    # Elementwise matrix addition (the paper's write-intensive example).
    "mat_add": Workload(
        "mat_add", n_elems=1 << 20,
        cpu_instrs_per_elem=3.0, cpu_simd_fraction=0.9,
        cpu_bytes_per_elem=3.0, footprint_bytes=_mb(3),
        **_ADD,
    ),
    # Root-mean-square error: (a-b)^2 accumulated, sqrt once at the end.
    "rmse": Workload(
        "rmse", n_elems=1 << 19,
        cpu_instrs_per_elem=4.0, cpu_simd_fraction=0.9,
        cpu_bytes_per_elem=2.0, footprint_bytes=_mb(1.5),
        logic2=16.0 + _MUL["logic2"] + 2 * 16.0,
        logic3=8.0 + _MUL["logic3"] + 2 * 8.0,
        writes=17.0 + _MUL["writes"] + 2 * 17.0,
        reads=_MUL["reads"],
    ),
}

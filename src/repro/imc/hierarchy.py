"""Hierarchical IMC organization (paper Fig. 2, after CHIME [19]).

AFMTJ (or MTJ) subarrays are embedded at L1, L2 and main memory; each level
contributes concurrently-operating subarrays (the paper's C1..C6 blocks,
"processing in cache" + "processing in memory").  A lightweight controller
pipelines row-granular operations: at steady state a level retires one row
op per ``t_op`` across its active subarrays.

Level geometry follows the paper's baseline system (32 KB L1, 1 MB L2, 8 GB
main memory).  Bigger levels have longer lines (higher RC) but more
subarrays; the controller exploits AFMTJ's picosecond switching to pipeline
writes behind logic ops (paper Sec. III-B).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Literal

from repro.circuit.bitline import BitlineParams
from repro.circuit.senseamp import SenseAmpParams
from repro.circuit.subarray import SubarrayTimings, make_subarray


@dataclasses.dataclass(frozen=True)
class LevelSpec:
    name: str
    capacity_bytes: int
    rows: int
    cols: int
    n_active_subarrays: int     # concurrently operating compute subarrays
    c_per_cell_scale: float     # line-capacitance scale vs the L1 baseline
    e_periph_row_op: float      # decoder+driver+controller energy / row op [J]


# The paper's hierarchy: PiC at L1+L2, PiM at main memory.  Active-subarray
# counts are the concurrency the CHIME-style controller sustains per level.
LEVELS = (
    LevelSpec("L1", 32 * 1024, 256, 256, 2, 1.0, 1.2e-12),
    LevelSpec("L2", 1 * 1024 * 1024, 256, 256, 4, 1.3, 1.8e-12),
    LevelSpec("MM", 8 * 1024 * 1024 * 1024, 512, 512, 16, 2.0, 3.6e-12),
)


@dataclasses.dataclass(frozen=True)
class IMCLevel:
    spec: LevelSpec
    timings: SubarrayTimings

    @property
    def row_bits(self) -> int:
        return self.spec.cols * self.spec.n_active_subarrays


@dataclasses.dataclass(frozen=True)
class IMCHierarchy:
    kind: str                       # "afmtj" | "mtj"
    levels: Dict[str, IMCLevel]

    def level_for_footprint(self, n_bytes: int) -> IMCLevel:
        """Smallest level whose capacity holds the working set (PiC first)."""
        for lv in LEVELS:
            if n_bytes <= lv.capacity_bytes // 2:   # half for data, half compute
                return self.levels[lv.name]
        return self.levels["MM"]


def build_hierarchy(
    kind: Literal["afmtj", "mtj"],
    v_write: float = 1.0,
    wer_target: float | None = None,
    write_percentile: float | None = None,
    read_percentile: float | None = None,
    offset_sigma: float = 0.0,
) -> IMCHierarchy:
    """``wer_target`` switches write-pulse sizing from the mean switching
    time to a thermal-tail (Monte-Carlo campaign) margin — see
    ``imc.write_margin``.  ``write_percentile`` (e.g. 99.0) goes further:
    per-level write timings are *measured* from the write-verify retry
    scheduler (``imc.write_path``, DESIGN.md §7) at that row-time
    percentile.  ``read_percentile`` does the same for the read side
    (``imc.read_path``, DESIGN.md §10): per-level sense times come from the
    worst process corner's (D2D x SA-offset) Monte-Carlo at that percentile,
    with ``offset_sigma`` [V] setting the sense-amp input-referred offset
    spread.  None/None keeps the seed deterministic timing."""
    levels = {}
    sa = SenseAmpParams(offset_sigma=offset_sigma)
    for spec in LEVELS:
        bl = BitlineParams(
            c_per_cell=0.03e-15 * spec.c_per_cell_scale,
            rows=spec.rows,
        )
        sub = make_subarray(kind, rows=spec.rows, cols=spec.cols,
                            v_write=v_write, bl=bl, sa=sa,
                            wer_target=wer_target,
                            write_percentile=write_percentile,
                            read_percentile=read_percentile)
        levels[spec.name] = IMCLevel(spec=spec, timings=sub.timings)
    return IMCHierarchy(kind=kind, levels=levels)

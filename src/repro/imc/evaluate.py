"""System-level evaluation: IMC hierarchy vs CPU baseline (paper Fig. 4).

Latency: the controller retires row-granular ops; each op processes
``row_bits`` elements-worth of bits across the level's active subarrays in
parallel.  Logic ops and write-backs pipeline (the paper: "a lightweight
controller ... exploiting AFMTJ's picosecond switching for pipelined
execution"), so per-stage time is max(logic, write) rather than the sum —
with MTJs the slow writes dominate the pipe, with AFMTJs they hide.

Energy: device energies per bit (from the circuit layer) + per-row-op
peripheral energy (decoder/driver/controller) + CPU-side dispatch.

Refresh (DESIGN.md §10): a ``RefreshPolicy`` (``imc.read_path``, derived
from measured retention + read-disturb budgets) makes the scrub controller
a steady-state bandwidth tax: every ``interval`` seconds each resident data
row is read and rewritten.  ``evaluate_workload(..., refresh=...)`` charges
that duty cycle into ``t_imc``/``e_imc`` and surfaces it as
``t_refresh``/``e_refresh`` in the ``SystemResult`` — so the Fig. 4
comparison can show the refresh overhead explicitly instead of assuming
non-volatile means free retention.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Dict, Optional

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (read_path -> circuit)
    from repro.imc.faults import FaultSpec, RepairPolicy
    from repro.imc.read_path import RefreshPolicy

from repro.imc.cpu_model import CORTEX_A72, CPUModel
from repro.imc.hierarchy import IMCHierarchy, build_hierarchy
from repro.imc.workloads import WORKLOADS, Workload


@dataclasses.dataclass(frozen=True)
class SystemResult:
    workload: str
    t_cpu: float
    e_cpu: float
    t_imc: float
    e_imc: float
    # write-stage provenance: the per-row-op write time the pipelined stage
    # model actually used, and the retry statistics behind it (1.0 mean
    # attempts when the closed-form single-pulse timing was in effect).
    # Threading these through is what lets the Fig. 4 comparison show MTJ
    # retry inflation instead of silently assuming one pulse per write.
    t_write_op: float = 0.0
    write_attempts: float = 1.0
    write_residual_ber: float = 0.0
    # refresh/scrub provenance (0.0 / inf when no RefreshPolicy is active):
    # steady-state scrub time folded into t_imc, scrub energy folded into
    # e_imc, and the policy interval that produced them.
    t_refresh: float = 0.0
    e_refresh: float = 0.0
    refresh_interval: float = math.inf
    # hard-fault provenance (DESIGN.md §13): fraction of arrays the repair
    # budget salvages.  1.0 when no FaultSpec is active (inert default).
    array_yield: float = 1.0

    @property
    def speedup(self) -> float:
        return self.t_cpu / self.t_imc

    @property
    def energy_saving(self) -> float:
        return self.e_cpu / self.e_imc


def evaluate_workload(
    w: Workload, hier: IMCHierarchy, cpu: CPUModel = CORTEX_A72,
    refresh: Optional["RefreshPolicy"] = None,
    faults: Optional["FaultSpec"] = None,
    repair: Optional["RepairPolicy"] = None,
) -> SystemResult:
    t_cpu, e_cpu = cpu.kernel_time_energy(
        w.n_elems,
        w.cpu_instrs_per_elem,
        w.cpu_simd_fraction,
        w.cpu_bytes_per_elem,
        w.footprint_bytes,
    )

    level = hier.level_for_footprint(w.footprint_bytes)
    tm = level.timings
    elems_per_op = level.row_bits / w.bits_per_elem  # row-parallel elements

    n = w.n_elems / elems_per_op                     # row-op batches
    t_logic = n * (w.logic2 * tm.t_logic2 + w.logic3 * tm.t_logic3
                   + w.reads * tm.t_read)
    t_write = n * w.writes * tm.t_write
    # pipelined execution: logic (sense phase) overlaps write-back
    t_imc = max(t_logic, t_write) + min(t_logic, t_write) * 0.1

    # op counts are per *element*; each bit-serial op touches one bit-cell
    # per element, so cell energy = n_elems * count * per-bit energy.
    # 3-row majority conducts through three cells (e_logic3_bit), not two.
    e_cells = w.n_elems * (
        w.logic2 * tm.e_logic_bit
        + w.logic3 * tm.e_logic3_bit
        + w.writes * tm.e_write_bit
        + w.reads * tm.e_read_bit
    )
    n_row_ops = n * (w.logic2 + w.logic3 + w.writes + w.reads)
    e_periph = n_row_ops * level.spec.e_periph_row_op
    e_imc = e_cells + e_periph

    # --- refresh/scrub overhead (DESIGN.md §10) ----------------------------
    # Every `interval` the scrub controller reads + rewrites each resident
    # data row.  Steady state: scrubbing steals a `duty` fraction of row-op
    # bandwidth, stretching the workload by duty/(1-duty); scrub energy is
    # one full read+write pass per interval over the footprint.
    t_refresh = e_refresh = 0.0
    interval = math.inf
    if refresh is not None and math.isfinite(refresh.interval):
        interval = refresh.interval
        data_rows = max(1.0, w.footprint_bytes * 8.0 / level.row_bits)
        duty = min(data_rows * (tm.t_read + tm.t_write) / interval, 0.95)
        t_refresh = t_imc * duty / (1.0 - duty)
        t_imc = t_imc + t_refresh
        bits = data_rows * level.row_bits
        e_pass = (bits * (tm.e_read_bit + tm.e_write_bit)
                  + 2.0 * data_rows * level.spec.e_periph_row_op)
        e_refresh = (t_imc / interval) * e_pass
        e_imc = e_imc + e_refresh

    # --- hard-fault / repair overhead (DESIGN.md §13) ----------------------
    # Repair policies cost spare-line area + ECC cells (energy overhead on
    # every cell access) and the residual defective-array fraction stretches
    # latency: work mapped to condemned arrays must be re-run on survivors.
    array_yield = 1.0
    if faults is not None:
        from repro.imc.mapping import fault_cost_factors

        array_yield, cell_ovh, fault_stretch = fault_cost_factors(
            faults, repair)
        t_imc = t_imc * fault_stretch
        e_imc = e_imc * cell_ovh

    return SystemResult(w.name, t_cpu, e_cpu, t_imc, e_imc,
                        t_write_op=tm.t_write,
                        write_attempts=tm.write_attempts,
                        write_residual_ber=tm.write_residual_ber,
                        t_refresh=t_refresh, e_refresh=e_refresh,
                        refresh_interval=interval, array_yield=array_yield)


def evaluate_system(kind: str = "afmtj", v_write: float = 1.0,
                    wer_target: float | None = None,
                    write_percentile: float | None = None,
                    read_percentile: float | None = None,
                    offset_sigma: float = 0.0,
                    refresh: Optional["RefreshPolicy"] = None,
                    faults: Optional["FaultSpec"] = None,
                    repair: Optional["RepairPolicy"] = None,
                    ) -> Dict[str, SystemResult]:
    """``wer_target`` (e.g. 1e-2) sizes write pulses from the thermal-tail
    Monte-Carlo campaign instead of the mean switching time;
    ``write_percentile`` (e.g. 99.0) replaces the single-pulse write stage
    time with the measured write-verify retry distribution's row time at
    that percentile (``imc.write_path``) — with MTJs the retry-inflated
    write stage dominates the pipe even harder than the nominal pulse.
    ``read_percentile``/``offset_sigma`` do the same for the read side
    (``imc.read_path``, DESIGN.md §10), and ``refresh`` charges a measured
    retention/disturb-derived scrub policy into the comparison.
    ``faults``/``repair`` (DESIGN.md §13) charge the hard-fault repair
    yield/overhead model into ``t_imc``/``e_imc``.  All defaults off keeps
    the nominal Fig. 4 numbers bit-for-bit."""
    hier = build_hierarchy(kind, v_write=v_write, wer_target=wer_target,
                           write_percentile=write_percentile,
                           read_percentile=read_percentile,
                           offset_sigma=offset_sigma)
    return {name: evaluate_workload(w, hier, refresh=refresh,
                                    faults=faults, repair=repair)
            for name, w in WORKLOADS.items()}


def summarize(results: Dict[str, SystemResult]):
    """Arithmetic-mean (speedup, energy_saving) across workloads — the
    paper's headline aggregation; dominated by the largest ratios."""
    import statistics

    sp = statistics.mean(r.speedup for r in results.values())
    es = statistics.mean(r.energy_saving for r in results.values())
    return sp, es


def summarize_geomean(results: Dict[str, SystemResult]):
    """Geometric-mean (speedup, energy_saving) across workloads.

    The standard aggregation for ratios (SPEC-style): symmetric under
    inversion and not dominated by a single large-speedup workload, which
    the arithmetic ``summarize`` is.  Both are reported side by side."""
    import statistics

    sp = statistics.geometric_mean(r.speedup for r in results.values())
    es = statistics.geometric_mean(r.energy_saving for r in results.values())
    return sp, es

"""Deterministic sharded data pipeline.

Sources:
  * synthetic — seeded zipfian token stream (offline container default);
  * memmap    — packed uint16/uint32 token files (production path), sliced
                per host so each data-parallel rank reads only its shard.

Determinism contract: batch content is a pure function of (seed, step,
host_rank) — restart-safe (checkpoint stores the step; resume regenerates
the identical stream position) and elastic-safe (rank remapping reshuffles
cleanly because rank enters the fold only through the slice offset).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    microbatches: int = 1
    seed: int = 0
    source: str = "synthetic"          # synthetic | memmap
    path: Optional[str] = None         # token file for memmap
    host_rank: int = 0
    host_count: int = 1
    frontend_positions: int = 0        # vlm/audio stub embeddings
    d_model: int = 0
    encoder_frames: bool = False


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    """Zipf-ish distribution over the vocab (more LM-like than uniform)."""
    u = rng.random(shape)
    ranks = np.floor(np.exp(u * np.log(vocab))).astype(np.int64)
    return np.clip(vocab - ranks, 0, vocab - 1).astype(np.int32)


class _Memmap:
    def __init__(self, path: str, vocab: int):
        p = Path(path)
        dtype = np.uint32 if vocab > 65535 else np.uint16
        self.tokens = np.memmap(p, dtype=dtype, mode="r")

    def slice(self, start: int, n: int) -> np.ndarray:
        start = start % max(len(self.tokens) - n - 1, 1)
        return np.asarray(self.tokens[start:start + n], dtype=np.int32)


def make_pipeline(cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    """Yields batches shaped (microbatches, per_host_batch, seq_len)."""
    assert cfg.global_batch % (cfg.host_count * cfg.microbatches) == 0
    per_host = cfg.global_batch // cfg.host_count
    per_mb = per_host // cfg.microbatches
    mm = _Memmap(cfg.path, cfg.vocab) if cfg.source == "memmap" else None

    step = 0
    while True:
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_rank])
        )
        shape = (cfg.microbatches, per_mb, cfg.seq_len + 1)
        if mm is None:
            toks = _zipf_tokens(rng, shape, cfg.vocab)
        else:
            n = int(np.prod(shape))
            base = (cfg.seed + step * cfg.host_count + cfg.host_rank) * n
            toks = mm.slice(base, n).reshape(shape)
        batch = {
            "tokens": toks[..., :-1],
            "labels": toks[..., 1:],
        }
        if cfg.frontend_positions:
            fe = rng.standard_normal(
                (cfg.microbatches, per_mb, cfg.frontend_positions, cfg.d_model),
                dtype=np.float32,
            )
            key = "encoder_frames" if cfg.encoder_frames else "frontend_embeds"
            batch[key] = fe
        yield batch
        step += 1

"""Serving driver: batched prefill + decode with continuous batching.

``python -m repro.launch.serve --arch qwen2-0.5b --requests 16``

A minimal production-shaped server loop with true slot-freeing: a request
queue feeds a fixed number of decode *slots*; a sequence finishes on EOS
(``--eos-id``) or ``--max-new``, frees its slot, and the next queued request
joins at the following step boundary.  Joins use prefill-on-join continuous
batching: every slot's token history (right-aligned into a fixed
``prompt_len + max_new`` window, so the prefill compiles once) is re-prefilled
as one batch, then decoding resumes — the recompute-on-join variant of
continuous batching, chosen because the decode cache keeps a single shared
position scalar.  Decode tokens are counted only for live slots; finished
sequences cost nothing.

On this container it runs the reduced (smoke) configs; the same code path
lowers at the production mesh in the dry-run (prefill_32k / decode_32k /
long_500k cells).  ``main`` returns a stats dict (served counts, per-request
completions, token totals) so the smoke test can pin the accounting.
"""
from __future__ import annotations

import argparse
import collections
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import smoke_config
from repro.models import model as M

PAD_ID = 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="token id that finishes a sequence (-1: disabled)")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch)
    window = args.prompt_len + args.max_new          # fixed prefill width
    max_seq = window + cfg.frontend_positions + args.max_new + 2
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    frontend_key = ("encoder_frames" if cfg.n_encoder_layers else
                    "frontend_embeds" if cfg.frontend_positions else None)

    def draw_frontend():
        """One request's frontend conditioning — drawn once at admission and
        kept for the request's whole lifetime (re-prefills must not change
        the 'image' a sequence is conditioned on)."""
        return rng.standard_normal(
            (cfg.frontend_positions, cfg.d_model)).astype(np.float32)

    prefill = jax.jit(lambda p, b: M.serve_prefill(p, cfg, b, max_seq=max_seq))
    decode = jax.jit(lambda p, c, t: M.serve_step(p, cfg, c, t))

    # --- request queue + slot state ----------------------------------------
    queue = collections.deque(
        (rid, rng.integers(1, cfg.vocab, args.prompt_len).astype(np.int32))
        for rid in range(args.requests))
    slot_req = [None] * args.batch       # request id per slot (None = idle)
    slot_hist = [np.zeros(0, np.int32)] * args.batch   # prompt + generated
    slot_gen = [0] * args.batch          # generated-token count per slot
    slot_front = [None] * args.batch     # per-request frontend conditioning
    completions = {}                     # rid -> list of generated tokens

    def admit_and_prefill():
        """Fill idle slots from the queue and (re)prefill the whole batch."""
        for s in range(args.batch):
            if slot_req[s] is None and queue:
                rid, prompt = queue.popleft()
                slot_req[s], slot_hist[s], slot_gen[s] = rid, prompt, 0
                if frontend_key:
                    slot_front[s] = draw_frontend()
        hist = np.full((args.batch, window), PAD_ID, np.int32)
        for s in range(args.batch):
            h = slot_hist[s][-window:]
            if h.size:
                hist[s, window - h.size:] = h     # right-aligned
        batch = {"tokens": jnp.asarray(hist)}
        if frontend_key:
            batch[frontend_key] = jnp.asarray(np.stack([
                f if f is not None else
                np.zeros((cfg.frontend_positions, cfg.d_model), np.float32)
                for f in slot_front]))
        logits, cache = prefill(params, batch)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return cache, tok

    served = 0
    total_tokens = 0
    prefills = 0
    t0 = time.time()
    while served < args.requests:
        cache, tok = admit_and_prefill()
        prefills += 1
        # decode until a slot frees with work still queued (then re-join),
        # or until every live slot finishes (drain)
        while True:
            freed = False
            tok_np = np.asarray(tok)
            for s in range(args.batch):
                if slot_req[s] is None:
                    continue                      # dead slot: not counted
                t = int(tok_np[s])
                slot_hist[s] = np.append(slot_hist[s], np.int32(t))
                slot_gen[s] += 1
                total_tokens += 1
                done = (t == args.eos_id) or (slot_gen[s] >= args.max_new)
                if done:
                    completions[slot_req[s]] = (
                        slot_hist[s][-slot_gen[s]:].tolist())
                    slot_req[s] = None
                    served += 1
                    freed = True
            if served >= args.requests or (freed and queue):
                break
            logits, cache = decode(params, cache, tok[:, None])
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        print(f"served {served}/{args.requests} requests "
              f"({total_tokens} decode tokens, {prefills} prefill waves)")
    dt = time.time() - t0
    print(f"throughput: {total_tokens/dt:.1f} decode tok/s "
          f"(smoke config on CPU; production numbers come from the dry-run)")
    return {
        "served": served,
        "decode_tokens": total_tokens,
        "prefills": prefills,
        "completions": [completions[r] for r in sorted(completions)],
        "elapsed_s": dt,
    }


if __name__ == "__main__":
    main()

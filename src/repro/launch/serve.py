"""Serving driver: batched prefill + decode with continuous batching.

``python -m repro.launch.serve --arch qwen2-0.5b --requests 16``

A minimal production-shaped server loop: a request queue feeds fixed-size
decode batches; finished sequences (EOS or max-len) free their slot, and the
next queued request is prefilled into it.  On this container it runs the
reduced (smoke) configs; the same code path lowers at the production mesh in
the dry-run (prefill_32k / decode_32k / long_500k cells).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.configs.registry import smoke_config
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch)
    max_seq = args.prompt_len + cfg.frontend_positions + args.max_new
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    def make_batch(rng):
        b = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
        if cfg.frontend_positions and not cfg.n_encoder_layers:
            b["frontend_embeds"] = jnp.asarray(
                rng.standard_normal(
                    (args.batch, cfg.frontend_positions, cfg.d_model)),
                jnp.float32)
        if cfg.n_encoder_layers:
            b["encoder_frames"] = jnp.asarray(
                rng.standard_normal(
                    (args.batch, cfg.frontend_positions, cfg.d_model)),
                jnp.float32)
        return b

    prefill = jax.jit(lambda p, b: M.serve_prefill(p, cfg, b, max_seq=max_seq))
    decode = jax.jit(lambda p, c, t: M.serve_step(p, cfg, c, t))

    rng = np.random.default_rng(0)
    served = 0
    total_tokens = 0
    t0 = time.time()
    while served < args.requests:
        batch = make_batch(rng)
        logits, cache = prefill(params, batch)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for _ in range(args.max_new):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            total_tokens += args.batch
        served += args.batch
        print(f"served {served}/{args.requests} requests "
              f"({total_tokens} decode tokens)")
    dt = time.time() - t0
    print(f"throughput: {total_tokens/dt:.1f} decode tok/s "
          f"(smoke config on CPU; production numbers come from the dry-run)")


if __name__ == "__main__":
    main()

"""Serving driver: wiring for the engine / scheduler / cost-model stack.

``python -m repro.launch.serve --arch qwen2-0.5b --requests 16``

This module is deliberately thin (DESIGN.md §11): it parses arguments and
wires together the serving subsystem's layers —

* ``launch.engine.ServeEngine`` — params, jitted fixed-window prefill +
  single-token decode, KV cache; returns next tokens plus per-step op counts,
* ``launch.scheduler.ContinuousBatchScheduler`` — slots, queue, FIFO
  admission with the prefill-on-join recompute policy, token accounting,
* ``imc.cost_model.DeviceCostModel`` — prices every step's op counts in
  simulated AFMTJ / MTJ / CPU time and energy, replacing wall-clock as the
  serving clock.

Every step the real model executes is charged to each requested technology's
simulated clock, so one smoke-sized run yields per-technology TTFT/TPOT
percentiles alongside the functional token accounting.  For million-request
load studies use ``launch.simulate`` (pure cost-model fast path — no model
forwards); this driver is the fidelity anchor that runs actual forwards.

``main`` returns a stats dict: the scheduler's accounting (served counts,
prefill/decode token split, per-request completions) plus a ``device`` map
of per-technology simulated-clock reports.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.registry import smoke_config
from repro.imc.cost_model import TECHNOLOGIES, device_cost_model
from repro.launch.engine import ServeEngine
from repro.launch.report import build_report
from repro.launch.scheduler import ContinuousBatchScheduler, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="token id that finishes a sequence (-1: disabled)")
    ap.add_argument("--technologies", default=",".join(TECHNOLOGIES),
                    help="comma list of device clocks to charge "
                         f"(default: {','.join(TECHNOLOGIES)})")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch)
    engine = ServeEngine(cfg, args.prompt_len, args.max_new, args.batch)
    sched = ContinuousBatchScheduler(args.batch, args.max_new,
                                     eos_id=args.eos_id)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        sched.submit(Request(
            rid=rid,
            prompt=rng.integers(1, cfg.vocab, args.prompt_len).astype(
                np.int32),
            frontend=engine.draw_frontend(rng)))

    techs = [t for t in args.technologies.split(",") if t]
    models = {t: device_cost_model(t) for t in techs}
    clock = {t: 0.0 for t in techs}
    energy = {t: 0.0 for t in techs}
    ttft = {t: np.full(args.requests, np.nan) for t in techs}
    finish = {t: np.full(args.requests, np.nan) for t in techs}

    def charge(counts):
        for t, m in models.items():
            c = m.step_cost(counts)
            clock[t] += c.t
            energy[t] += c.e

    t0 = time.time()
    while not sched.finished:
        sched.admit()
        tok, counts = engine.prefill(sched.histories(), sched.frontends())
        charge(counts)
        while True:
            out = sched.commit(tok)
            for t in techs:
                for rid in out.first_tokens:
                    ttft[t][rid] = clock[t]
                for rid in out.finished:
                    finish[t][rid] = clock[t]
            if sched.finished or (out.freed and sched.has_waiting()):
                break
            tok, counts = engine.decode_step(tok, sched.slot_positions())
            charge(counts)
        print(f"served {sched.served}/{args.requests} requests "
              f"({sched.prefill_tokens} prefill + {sched.decode_tokens} "
              f"decode tokens, {sched.waves} prefill waves)")
    dt = time.time() - t0

    stats = sched.stats()
    stats["elapsed_s"] = dt
    olen = np.array([len(c) for c in stats["completions"]], np.float64)
    stats["device"] = {}
    for t in techs:
        with np.errstate(invalid="ignore", divide="ignore"):
            tpot = np.where(olen > 1.0,
                            (finish[t] - ttft[t]) / np.maximum(olen - 1.0, 1.0),
                            np.nan)
        rep = build_report(t, ttft[t], tpot, clock[t], energy[t],
                           stats["prefill_tokens"], stats["decode_tokens"])
        stats["device"][t] = rep.row_dict()
        print(f"[{t}] simulated {clock[t]:.3e} s, {energy[t]:.3e} J, "
              f"p99 TTFT {rep.ttft_p99_s:.3e} s, "
              f"p99 TPOT {rep.tpot_p99_s:.3e} s")
    if stats["generated_tokens"] and dt > 0:
        print(f"wall throughput: {stats['generated_tokens']/dt:.1f} tok/s "
              f"(smoke config on CPU; device numbers above are simulated)")
    return stats


if __name__ == "__main__":
    main()

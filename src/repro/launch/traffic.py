"""Arrival processes and request-shape distributions for serving studies.

Generates the traffic the serving simulator (``launch.simulate``) replays:
Poisson arrivals (or a trace file) with mixed prompt/output-length
distributions, vectorized in numpy so millions of requests materialize in
milliseconds (DESIGN.md §11).

A ``Trace`` is three parallel arrays — arrival time [s, sorted], prompt
tokens, output tokens — the only contract the simulator, the scheduler
driver, and the report layer share.  ``Trace.save``/``Trace.load`` round-trip
``.npz`` (bulk) and ``.jsonl`` (hand-editable) files, so measured
production traces slot in where the synthetic generator was.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LengthMixture:
    """Mixture of clipped lognormal length components.

    ``components``: ``(weight, median_tokens, log_sigma)`` triples — e.g.
    short chat turns mixed with long document prompts.  Weights are
    normalized; samples are rounded and clipped to ``[lo, hi]``.
    """

    components: Tuple[Tuple[float, float, float], ...]
    lo: int = 1
    hi: int = 8192

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        w = np.array([c[0] for c in self.components], np.float64)
        idx = rng.choice(len(self.components), size=n, p=w / w.sum())
        med = np.array([c[1] for c in self.components])[idx]
        sig = np.array([c[2] for c in self.components])[idx]
        out = np.rint(med * np.exp(sig * rng.standard_normal(n)))
        return np.clip(out, self.lo, self.hi).astype(np.int64)

    def mean(self) -> float:
        """Analytic mean (unclipped lognormal): E = median * exp(sigma^2/2)."""
        w = np.array([c[0] for c in self.components], np.float64)
        w = w / w.sum()
        med = np.array([c[1] for c in self.components])
        sig = np.array([c[2] for c in self.components])
        return float(np.sum(w * med * np.exp(sig ** 2 / 2.0)))

    def mean_sq(self) -> float:
        """Analytic second moment: E[L^2] = median^2 * exp(2 sigma^2).

        The quadratic (position-linear attention) cost terms scale with
        E[L^2], not E[L]^2 — for heavy-tailed length mixtures the variance
        contribution dominates, so capacity estimates built from first
        moments alone saturate early."""
        w = np.array([c[0] for c in self.components], np.float64)
        w = w / w.sum()
        med = np.array([c[1] for c in self.components])
        sig = np.array([c[2] for c in self.components])
        return float(np.sum(w * med ** 2 * np.exp(2.0 * sig ** 2)))


# chat-plus-documents defaults: mostly short prompts with a heavy long tail,
# short-to-medium generations
CHAT_PROMPTS = LengthMixture(((0.8, 64.0, 0.6), (0.2, 512.0, 0.5)), lo=4,
                             hi=4096)
CHAT_OUTPUTS = LengthMixture(((0.7, 32.0, 0.7), (0.3, 128.0, 0.5)), lo=1,
                             hi=1024)


@dataclasses.dataclass(frozen=True)
class Trace:
    """Arrival times [s, ascending] + per-request prompt/output lengths."""

    arrival_s: np.ndarray
    prompt_tokens: np.ndarray
    output_tokens: np.ndarray

    def __post_init__(self):
        n = len(self.arrival_s)
        assert len(self.prompt_tokens) == n and len(self.output_tokens) == n
        if n > 1:
            assert np.all(np.diff(self.arrival_s) >= 0), "arrivals unsorted"

    def __len__(self) -> int:
        return len(self.arrival_s)

    @property
    def total_output_tokens(self) -> int:
        return int(self.output_tokens.sum())

    def save(self, path) -> None:
        path = Path(path)
        if path.suffix == ".jsonl":
            with open(path, "w") as f:
                for a, p, o in zip(self.arrival_s, self.prompt_tokens,
                                   self.output_tokens):
                    f.write(json.dumps({"arrival_s": float(a),
                                        "prompt_tokens": int(p),
                                        "output_tokens": int(o)}) + "\n")
        else:
            np.savez_compressed(path, arrival_s=self.arrival_s,
                                prompt_tokens=self.prompt_tokens,
                                output_tokens=self.output_tokens)

    @staticmethod
    def load(path) -> "Trace":
        path = Path(path)
        if path.suffix == ".jsonl":
            rows = [json.loads(line) for line in open(path) if line.strip()]
            return Trace(
                np.array([r["arrival_s"] for r in rows], np.float64),
                np.array([r["prompt_tokens"] for r in rows], np.int64),
                np.array([r["output_tokens"] for r in rows], np.int64))
        with np.load(path) as z:
            return Trace(z["arrival_s"].astype(np.float64),
                         z["prompt_tokens"].astype(np.int64),
                         z["output_tokens"].astype(np.int64))


@dataclasses.dataclass(frozen=True)
class PoissonTraffic:
    """Homogeneous Poisson arrivals at ``rate`` requests/simulated-second
    with mixture-distributed prompt/output lengths."""

    rate: float
    n_requests: int
    prompts: LengthMixture = CHAT_PROMPTS
    outputs: LengthMixture = CHAT_OUTPUTS
    seed: int = 0

    def trace(self) -> Trace:
        rng = np.random.default_rng(self.seed)
        gaps = rng.exponential(1.0 / self.rate, self.n_requests)
        return Trace(np.cumsum(gaps),
                     self.prompts.sample(rng, self.n_requests),
                     self.outputs.sample(rng, self.n_requests))


def mean_request_time(prices, prompts: LengthMixture,
                      outputs: LengthMixture,
                      n_slots: int = 1) -> float:
    """Expected device time one request costs the system under
    ``TokenPrices``: the prefill of its prompt, each generated token at its
    growing context position, and — when ``n_slots > 1`` — the
    recompute-on-join tax its admission levies on the batch (the join
    re-prefills every other live slot's history, mean length ≈ prompt plus
    half the output).  Queueing delay is excluded — this is the service-time
    scale the capacity estimate divides by, not the loaded latency.

    Quadratic terms use second moments (``mean_sq``): with heavy-tailed
    length mixtures ``E[L^2] >> E[L]^2`` and the position-linear attention
    cost is driven by the tail, not the typical request."""
    p, o = prompts.mean(), outputs.mean()
    p2, o2 = prompts.mean_sq(), outputs.mean_sq()
    t_prefill = p * prices.t_tok + prices.t_pos * (p2 - p) / 2.0
    # decode tokens 2..o run at positions p+1 .. p+o-1
    n_dec = max(o - 1.0, 0.0)
    t_decode = n_dec * prices.t_tok + prices.t_pos * (
        n_dec * p + max(o2 - o, 0.0) / 2.0)
    # recompute-on-join: each admission re-prefills the other live slots;
    # a live history is its prompt plus a uniform fraction of its output
    # (h = p + u*o, u ~ U[0,1] => E[h] = p + o/2, E[h^2] below)
    h = p + o / 2.0
    h2 = p2 + p * o + o2 / 3.0
    t_join = (n_slots - 1) * (h * prices.t_tok
                              + prices.t_pos * (h2 - h) / 2.0)
    return t_prefill + t_decode + t_join


def rate_for_load(prices, rho: float, n_slots: int,
                  prompts: LengthMixture = CHAT_PROMPTS,
                  outputs: LengthMixture = CHAT_OUTPUTS) -> float:
    """Arrival rate [req/s] giving offered load ``rho`` for a technology
    priced by ``prices``: ``rho`` = 1 saturates the estimated capacity
    ``1 / E[service time]``.  The device clock is *serial* — every slot's
    ops are charged to the same device — so slot count does not multiply
    capacity; it only sets the recompute-on-join tax (which dominates the
    per-request service time at wide batches).  Offered load is defined
    relative to each technology's *own* capacity, so the same ``rho`` is
    comparable across afmtj/mtj/cpu."""
    return rho / mean_request_time(prices, prompts, outputs, n_slots=n_slots)


def poisson_at_load(prices, rho: float, n_requests: int, n_slots: int,
                    prompts: LengthMixture = CHAT_PROMPTS,
                    outputs: LengthMixture = CHAT_OUTPUTS,
                    seed: int = 0) -> PoissonTraffic:
    """Convenience: Poisson traffic at normalized offered load ``rho``."""
    return PoissonTraffic(rate_for_load(prices, rho, n_slots, prompts,
                                        outputs),
                          n_requests, prompts, outputs, seed)

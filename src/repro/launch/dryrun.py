import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this records, to results/dryrun/<arch>__<shape>__<mesh>.json:
  * compiled.memory_analysis()  — bytes/device proof-of-fit
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline
  * per-collective operand bytes parsed from the optimized (post-SPMD) HLO
  * lowering + compile wall time

Single-pod mesh = (data=16, model=16) = 256 chips; multi-pod = (pod=2, 16,
16) = 512.  The run is resumable: existing JSONs are skipped unless
--force.  See EXPERIMENTS.md §Dry-run for the result tables.
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import LONG_CONTEXT_ARCHS, SHAPES, shape_for
from repro.configs.registry import ARCHS, TRAIN_MICROBATCHES, get_arch
from repro.launch import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.models import model as M

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "c64": 8, "c128": 16,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _line_bytes(lhs: str) -> int:
    nbytes = 0
    for m in _SHAPE_RE.finditer(lhs):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * _DTYPE_BYTES[dt]
    return nbytes


def _split_computations(hlo_text: str):
    comps, name, buf = {}, None, []
    for line in hlo_text.splitlines():
        m = _COMP_HEADER.match(line.strip()) if "{" in line else None
        if m and ("->" in line or line.strip().startswith("ENTRY")):
            if name is not None:
                comps[name] = buf
            name, buf = m.group(2), []
            if m.group(1):
                comps["__entry__"] = None
                comps.setdefault("__entry_name__", name)
        elif name is not None:
            buf.append(line)
    if name is not None:
        comps[name] = buf
    return comps


def collective_bytes(hlo_text: str):
    """Sum collective result bytes in the optimized HLO, multiplying ops
    inside while bodies by their trip counts (XLA cost analysis visits loop
    bodies once; our scan-over-layers / microbatch loops would otherwise be
    undercounted by n_layers x microbatches)."""
    comps = _split_computations(hlo_text)
    entry = comps.pop("__entry_name__", None)
    comps.pop("__entry__", None)

    def trip_count(cond_name: str) -> int:
        consts = [int(c) for line in comps.get(cond_name, [])
                  for c in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    # per-computation raw collective tallies + nested whiles/calls
    raw = {}
    whiles = {}
    calls = {}
    call_re = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
    for cname, lines in comps.items():
        tall = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
        subs, sub_calls = [], []
        for line in lines or []:
            wm = _WHILE_RE.search(line)
            if wm:
                subs.append((wm.group(1), wm.group(2)))
                continue
            hit = False
            for op in _COLLECTIVES:
                if f" {op}(" in line or f" {op}-start(" in line:
                    lhs = line.split(f" {op}")[0]
                    tall[op]["count"] += 1
                    tall[op]["bytes"] += _line_bytes(lhs)
                    hit = True
                    break
            if not hit:
                cm = call_re.search(line)
                if cm:
                    sub_calls.append(cm.group(1))
        raw[cname] = tall
        whiles[cname] = subs
        calls[cname] = sub_calls

    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}

    def accumulate(cname: str, mult: int, depth=0):
        if cname not in raw or depth > 12:
            return
        for op, t in raw[cname].items():
            out[op]["count"] += t["count"] * mult
            out[op]["bytes"] += t["bytes"] * mult
        for cond, body in whiles[cname]:
            accumulate(body, mult * trip_count(cond), depth + 1)
        for sub in calls[cname]:
            accumulate(sub, mult, depth + 1)

    if entry:
        accumulate(entry, 1)
    else:  # fallback: flat count
        for cname in raw:
            accumulate(cname, 1)
    return out


def build_cell(arch_name: str, shape_name: str, multi_pod: bool):
    cfg = get_arch(arch_name)
    mb = TRAIN_MICROBATCHES[arch_name] if shape_name == "train_4k" else None
    if os.environ.get("REPRO_TRAIN_MICROBATCHES") and shape_name == "train_4k":
        mb = int(os.environ["REPRO_TRAIN_MICROBATCHES"])
    shape = shape_for(cfg, shape_name, microbatches=mb)
    mesh = make_production_mesh(multi_pod=multi_pod)
    SH.activation_policy(mesh, cfg, shape)

    aparams = M.abstract_params(cfg)
    axes = M.logical_axes(cfg)
    p_shard = SH.param_shardings(cfg, mesh, axes, aparams, kind=shape.kind)
    batch = ST.input_specs(cfg, shape)
    b_shard = SH.batch_shardings(mesh, shape, batch)

    if shape.kind == "train":
        step_fn = ST.make_train_step(cfg, shape, param_shardings=p_shard)
        m_shard = p_shard
        scalar = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        astep = jax.ShapeDtypeStruct((), jax.numpy.int32)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, m_shard, m_shard, scalar, b_shard),
            out_shardings=(p_shard, m_shard, m_shard, scalar, None),
            donate_argnums=(0, 1, 2),
        )
        aopt = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jax.numpy.dtype(cfg.opt_state_dtype)),
            aparams)
        args = (aparams, aopt, aopt, astep, batch)
    elif shape.kind == "prefill":
        step_fn = ST.make_prefill_step(cfg, shape)
        acache = ST.abstract_cache(cfg, shape)
        c_shard = SH.cache_shardings(mesh, cfg, shape, acache)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, b_shard),
            out_shardings=(None, c_shard),
        )
        args = (aparams, batch)
    else:  # decode
        step_fn = ST.make_decode_step(cfg, shape)
        acache = ST.abstract_cache(cfg, shape)
        c_shard = SH.cache_shardings(mesh, cfg, shape, acache)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, c_shard, b_shard["tokens"]),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )
        args = (aparams, acache, batch["tokens"])
    return cfg, shape, mesh, jitted, step_fn, args


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, verbose=True):
    t0 = time.time()
    cfg, shape, mesh, jitted, step_fn, args = build_cell(arch_name, shape_name, multi_pod)
    from repro.launch.flops_audit import audit_step_flops

    flops_global = audit_step_flops(step_fn, *args)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        mem_d[f] = getattr(mem, f, None)
    if verbose:
        print(f"  memory_analysis: {mem_d}")
    cost = compiled.cost_analysis()
    cost_d = {k: float(v) for k, v in cost.items()
              if isinstance(v, (int, float)) and k in
              ("flops", "bytes accessed", "transcendentals",
               "bytes accessed output", "utilization operand 0")}
    if verbose:
        print(f"  cost_analysis: flops={cost_d.get('flops'):.3e} "
              f"bytes={cost_d.get('bytes accessed'):.3e}")
    coll = collective_bytes(compiled.as_text())

    n_dev = mesh.devices.size
    result = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "multipod_2x16x16" if multi_pod else "pod_16x16",
        "n_devices": int(n_dev),
        "kind": shape.kind,
        "microbatches": shape.microbatches,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "flops_audit_global": flops_global,
        "flops_audit_per_device": flops_global / n_dev,
        "memory": mem_d,
        "cost": cost_d,
        "collectives": coll,
        "t_lower_s": t_lower,
        "t_compile_s": t_compile,
    }
    return result


def cells(multi_pod: bool):
    for a in ARCHS:
        for s in SHAPES:
            if s == "long_500k" and a not in LONG_CONTEXT_ARCHS:
                continue  # sanctioned skip: pure full-attention archs
            yield a, s, multi_pod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    todo = []
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    if args.all:
        for mp in meshes:
            todo += list(cells(mp))
    else:
        assert args.arch and args.shape
        todo = [(args.arch, args.shape, mp) for mp in meshes]

    failures = []
    for arch, shp, mp in todo:
        tag = f"{arch}__{shp}__{'multipod' if mp else 'pod'}"
        out = RESULTS / f"{tag}.json"
        if out.exists() and not args.force:
            print(f"[skip] {tag}")
            continue
        print(f"[run ] {tag}", flush=True)
        try:
            res = run_cell(arch, shp, mp)
            out.write_text(json.dumps(res, indent=1))
            print(f"[ ok ] {tag}  lower={res['t_lower_s']:.1f}s "
                  f"compile={res['t_compile_s']:.1f}s", flush=True)
        except Exception as e:
            failures.append((tag, repr(e)))
            (RESULTS / f"{tag}.FAILED").write_text(traceback.format_exc())
            print(f"[FAIL] {tag}: {e}", flush=True)

    print(f"\ndone; {len(failures)} failures")
    for tag, e in failures:
        print(f"  {tag}: {e[:200]}")


if __name__ == "__main__":
    main()

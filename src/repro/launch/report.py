"""Serving-report layer: tail latency, efficiency, and SLO attainment.

Turns the raw per-request arrays a serving run produces (time-to-first-token,
finish time, token counts — wherever they came from: the event-driven
simulator, the scheduler driver, or the real engine loop) into the numbers
the case study reports per technology (DESIGN.md §11):

* p50/p99 time-to-first-token (TTFT) and per-output-token latency (TPOT),
* throughput (tokens / simulated second) and energy efficiency
  (tokens / joule),
* SLO attainment — the fraction of requests meeting a (TTFT, TPOT) bound —
  as a function of offered load.

SLOs are expressed as multiples of the serving policy's *structural* cost
under each technology's token prices (``SLO.normalized``): the admission
wave for TTFT and the saturated per-token service time for TPOT.  A "1.5x"
bound then means the same thing for a CPU and an AFMTJ array even though
their absolute clocks differ by orders of magnitude, and attainment
measures queueing degradation — the quantity that collapses past offered
load 1.  Absolute-seconds SLOs are also supported for cross-technology
floors.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request service-level objective: TTFT and per-token bounds [s]."""

    ttft_s: float
    tpot_s: float

    @staticmethod
    def normalized(prices, prompts, outputs, n_slots: int,
                   ttft_mult: float = 1.5, tpot_mult: float = 1.5) -> "SLO":
        """Bounds as multiples of the serving policy's *structural* cost
        under ``TokenPrices`` — what a request pays even with no queue:

        * TTFT baseline: one full admission wave — the recompute-on-join
          policy re-prefills every live history (``n_slots`` of mean
          steady-state length) before the joiner's first token can exist.
        * TPOT baseline: the saturated per-token service time — the
          request's share of total device work (own tokens + join tax)
          spread over its output.

        Multiples of these measure *queueing* degradation, which is the
        quantity that collapses past ``rho = 1``; normalizing instead to a
        single unloaded prefill would put the bar below the policy floor
        and report zero attainment at every load."""
        from repro.launch.traffic import mean_request_time

        p, o = prompts.mean(), outputs.mean()
        h = int(round(p + o / 2.0))
        base_ttft = n_slots * prices.prefill(h).t
        base_tpot = mean_request_time(prices, prompts, outputs,
                                      n_slots=n_slots) / max(o, 1.0)
        return SLO(ttft_s=ttft_mult * base_ttft, tpot_s=tpot_mult * base_tpot)


@dataclasses.dataclass(frozen=True)
class ServingReport:
    """One (technology, offered load) cell of the serving study."""

    technology: str
    n_requests: int
    offered_load: Optional[float]
    sim_time_s: float                # simulated clock at last completion
    energy_j: float
    prefill_tokens: int
    decode_tokens: int
    ttft_p50_s: float
    ttft_p99_s: float
    tpot_p50_s: float
    tpot_p99_s: float
    slo_attainment: Optional[float] = None
    utilization: Optional[float] = None  # busy device time / sim time

    @property
    def generated_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    @property
    def throughput_tok_s(self) -> float:
        return self.generated_tokens / self.sim_time_s if self.sim_time_s \
            else 0.0

    @property
    def tokens_per_joule(self) -> float:
        return self.generated_tokens / self.energy_j if self.energy_j \
            else math.inf

    def row_dict(self) -> Dict[str, float]:
        """Flat dict for BENCH.json-style emission."""
        d = {
            "requests": self.n_requests,
            "sim_time_s": self.sim_time_s,
            "energy_j": self.energy_j,
            "ttft_p50_s": self.ttft_p50_s,
            "ttft_p99_s": self.ttft_p99_s,
            "tpot_p50_s": self.tpot_p50_s,
            "tpot_p99_s": self.tpot_p99_s,
            "throughput_tok_s": self.throughput_tok_s,
            "tokens_per_joule": self.tokens_per_joule,
        }
        if self.offered_load is not None:
            d["offered_load"] = self.offered_load
        if self.slo_attainment is not None:
            d["slo_attainment"] = self.slo_attainment
        if self.utilization is not None:
            d["utilization"] = self.utilization
        return d


def build_report(technology: str, ttft_s: np.ndarray, tpot_s: np.ndarray,
                 sim_time_s: float, energy_j: float, prefill_tokens: int,
                 decode_tokens: int, offered_load: Optional[float] = None,
                 slo: Optional[SLO] = None,
                 busy_s: Optional[float] = None) -> ServingReport:
    """Percentile + SLO reduction over per-request arrays.

    ``tpot_s`` entries may be NaN for single-token requests (no decode
    phase); they are excluded from TPOT percentiles but still SLO-checked
    on TTFT alone."""
    ttft = np.asarray(ttft_s, np.float64)
    tpot = np.asarray(tpot_s, np.float64)
    has_tpot = np.isfinite(tpot)
    p50t, p99t = (np.percentile(ttft, (50.0, 99.0)) if ttft.size
                  else (math.nan, math.nan))
    p50d, p99d = (np.percentile(tpot[has_tpot], (50.0, 99.0))
                  if has_tpot.any() else (math.nan, math.nan))
    att = None
    if slo is not None and ttft.size:
        ok = ttft <= slo.ttft_s
        ok &= np.where(has_tpot, tpot <= slo.tpot_s, True)
        att = float(ok.mean())
    return ServingReport(
        technology=technology, n_requests=int(ttft.size),
        offered_load=offered_load, sim_time_s=float(sim_time_s),
        energy_j=float(energy_j), prefill_tokens=int(prefill_tokens),
        decode_tokens=int(decode_tokens),
        ttft_p50_s=float(p50t), ttft_p99_s=float(p99t),
        tpot_p50_s=float(p50d), tpot_p99_s=float(p99d),
        slo_attainment=att,
        utilization=(float(busy_s / sim_time_s)
                     if busy_s is not None and sim_time_s else None))

"""Mesh construction: model meshes + the campaign cells mesh.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — required for the dry-run's
``xla_force_host_platform_device_count`` trick to work, and for smoke tests
to keep seeing a single device.

The campaign half (DESIGN.md §14) describes the Monte-Carlo engine's
topology: a flat 1-D ``cells`` axis over the local devices of every
process in the job.  ``build_campaign_mesh`` is jax.distributed-aware —
on a real multi-host fleet ``jax.distributed.initialize`` sets the
process topology and each process shards its launches over its own local
devices; in single-process CI the same code path runs with
``process_count == 1`` and ``xla_force_host_platform_device_count``
providing the multi-device axis (``host_device_flag``).  Cross-process
coordination never uses collectives: processes rendezvous only through
the content-addressed campaign store (``campaign.cache`` claims), so a
mesh of hosts needs nothing but a shared cache directory.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: (data=16, model=16) = 256 chips; multi-pod adds a
    leading pod axis (2 pods = 512 chips) for cross-pod data parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Debug mesh over whatever devices exist (tests use 1-8 host devices)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The axes that act as data parallel (pod folded into data)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ------------------------------------------------------------- campaigns

def host_device_flag(n: int) -> str:
    """The XLA flag that splits one host CPU into ``n`` devices — the CI /
    smoke-test stand-in for a real accelerator mesh (must be in XLA_FLAGS
    before the first jax import of the target process)."""
    return f"--xla_force_host_platform_device_count={int(n)}"


@dataclasses.dataclass(frozen=True)
class CampaignMesh:
    """Topology of one multi-device / multi-process campaign run.

    ``n_devices`` local devices shard the cells plane inside each launch
    (``engine._integrate_sharded``); ``process_index``/``process_count``
    partition whole launches across processes, which dedupe and exchange
    results through the content-addressed store (claims + slice
    checkpoints — DESIGN.md §14).  ``claim_ttl_s`` bounds how long a
    process waits on a peer's claimed launch before presuming the peer
    dead and stealing the work; ``poll_s`` is the store poll interval.
    """

    n_devices: int
    process_index: int = 0
    process_count: int = 1
    claim_ttl_s: float = 60.0
    poll_s: float = 0.05

    def __post_init__(self):
        assert self.n_devices >= 1, self.n_devices
        assert self.process_count >= 1, self.process_count
        assert 0 <= self.process_index < self.process_count, (
            self.process_index, self.process_count)
        assert self.claim_ttl_s > 0 and self.poll_s > 0


def build_campaign_mesh(
    devices: Optional[int] = None,
    process_index: Optional[int] = None,
    process_count: Optional[int] = None,
    *,
    elastic_from: Optional[int] = None,
    claim_ttl_s: float = 60.0,
    poll_s: float = 0.05,
) -> CampaignMesh:
    """The campaign mesh of this process, jax.distributed-aware.

    Process topology defaults to ``jax.process_index()`` /
    ``jax.process_count()`` — populated by ``jax.distributed.initialize``
    on multi-host fleets, 1/1 otherwise — and the device axis to every
    local device.  ``elastic_from=N`` marks a resume of a campaign that
    was checkpointed on ``N`` local devices: the device count then routes
    through ``runtime.elastic.plan_campaign_devices`` so a degraded host
    lands on a plan-blessed count (slice checkpoints are device-count-
    independent, so the resume stays bit-identical either way — the plan
    only keeps the shard shapes on the compile-cache-friendly ladder).
    """
    pi = jax.process_index() if process_index is None else int(process_index)
    pc = jax.process_count() if process_count is None else int(process_count)
    n = jax.local_device_count() if devices is None else min(
        int(devices), jax.local_device_count())
    if elastic_from is not None:
        from repro.runtime.elastic import plan_campaign_devices

        n = plan_campaign_devices(n, old_devices=int(elastic_from)).mesh_shape[0]
    return CampaignMesh(n_devices=n, process_index=pi, process_count=pc,
                        claim_ttl_s=claim_ttl_s, poll_s=poll_s)

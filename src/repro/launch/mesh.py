"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — required for the dry-run's
``xla_force_host_platform_device_count`` trick to work, and for smoke tests
to keep seeing a single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: (data=16, model=16) = 256 chips; multi-pod adds a
    leading pod axis (2 pods = 512 chips) for cross-pod data parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Debug mesh over whatever devices exist (tests use 1-8 host devices)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The axes that act as data parallel (pod folded into data)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)

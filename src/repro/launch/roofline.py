"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) cell on the single-pod mesh:

  compute term    = per-device HLO FLOPs / 197 TFLOP/s       (v5e bf16 peak)
  memory term     = per-device HLO bytes / 819 GB/s          (HBM BW)
  collective term = per-device collective bytes / 50 GB/s    (ICI per link)

``cost_analysis()`` on the SPMD-partitioned module reports *per-device*
FLOPs/bytes; collective bytes are summed from the optimized HLO's collective
result shapes (per-device traffic through the ring).  MODEL_FLOPS uses
6*N_active*D for training and 2*N_active*D for inference steps; the ratio
MODEL/HLO exposes remat + dispatch overhead.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline           # table to stdout
  PYTHONPATH=src python -m repro.launch.roofline --md results/roofline.md
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, get_arch

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def model_flops_per_device(arch: str, shape_name: str, n_devices: int) -> float:
    cfg = get_arch(arch)
    s = SHAPES[shape_name]
    n = cfg.active_param_count()
    if s.kind == "train":
        tokens = s.global_batch * s.seq_len
        total = 6.0 * n * tokens
    elif s.kind == "prefill":
        tokens = s.global_batch * s.seq_len
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n * s.global_batch
    return total / n_devices


def load_cells(mesh: str = "pod") -> List[Dict]:
    out = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        out.append(json.loads(f.read_text()))
    return out


def analyze(cell: Dict) -> Dict:
    # jaxpr-audited flops (exact scan trip counts); raw cost_analysis flops
    # kept in the JSON for reference (XLA visits while bodies once).
    flops = cell.get("flops_audit_per_device") or cell["cost"]["flops"]
    byts = cell["cost"]["bytes accessed"]
    coll = sum(v["bytes"] for v in cell["collectives"].values())
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    mf = model_flops_per_device(cell["arch"], cell["shape"], cell["n_devices"])
    bound = max(t_c, t_m, t_x)
    # roofline fraction: useful model FLOPs per device over what the chip
    # could have done in the bound time (the MFU-analog for a dry run)
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        **cell,
        "t_compute": t_c,
        "t_memory": t_m,
        "t_collective": t_x,
        "dominant": dom,
        "model_flops_dev": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_frac": frac,
        "coll_bytes": coll,
    }


def fmt_table(cells: List[Dict]) -> str:
    rows = [
        "| arch | shape | Tcomp (ms) | Tmem (ms) | Tcoll (ms) | dominant | "
        "MODEL/HLO | roofline frac | bytes/dev (GB) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = {k: i for i, k in enumerate(ARCHS)}
    cells = sorted(cells, key=lambda c: (order.get(c["arch"], 99), c["shape"]))
    for c in cells:
        mem_gb = (c["memory"]["argument_size_in_bytes"]
                  + c["memory"]["temp_size_in_bytes"]) / 1e9
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['t_compute']*1e3:.3f} | "
            f"{c['t_memory']*1e3:.3f} | {c['t_collective']*1e3:.3f} | "
            f"{c['dominant']} | {c['useful_ratio']:.2f} | "
            f"{c['roofline_frac']*100:.1f}% | {mem_gb:.2f} |")
    return "\n".join(rows)


def pick_hillclimb(cells: List[Dict]) -> Dict[str, Dict]:
    """worst roofline fraction / most collective-bound / most representative
    (largest simulated-system training cell — the paper-technique host)."""
    train = [c for c in cells if c["kind"] == "train"]
    worst = min(cells, key=lambda c: c["roofline_frac"])
    coll = max(cells, key=lambda c: c["t_collective"] /
               max(c["t_compute"], c["t_memory"], 1e-12))
    rep = max(train, key=lambda c: c["params_total"])
    return {"worst_fraction": worst, "most_collective": coll,
            "representative": rep}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--md", default=None)
    ap.add_argument("--mesh", default="pod")
    args = ap.parse_args()
    cells = [analyze(c) for c in load_cells(args.mesh)]
    table = fmt_table(cells)
    picks = pick_hillclimb(cells)
    lines = [f"## Roofline ({args.mesh} mesh, {cells[0]['n_devices']} chips)",
             "", table, "", "### Hillclimb picks", ""]
    for k, c in picks.items():
        lines.append(f"- **{k}**: {c['arch']} x {c['shape']} "
                     f"(frac {c['roofline_frac']*100:.1f}%, dominant "
                     f"{c['dominant']})")
    text = "\n".join(lines)
    print(text)
    if args.md:
        Path(args.md).write_text(text + "\n")


if __name__ == "__main__":
    main()

"""Continuous-batching scheduler: slots, queue, admission — no JAX.

``ContinuousBatchScheduler`` owns the request queue, the decode slots, and
the per-request accounting that used to live inline in ``launch/serve.py``
(DESIGN.md §11).  It is pure Python/numpy so every admission edge case is
unit-testable without compiling a model: the engine (real or stub) only
turns histories into next tokens.

Admission policy: *prefill-on-join recompute* (the PR 2 monolith's policy,
now the one pluggable policy hook): idle slots are filled FIFO from the
arrived queue, then the **whole** live batch is re-prefilled as one wave —
every live slot's next token comes from that wave, and joins happen only at
wave boundaries (a slot must free with work waiting, or the system must
drain, before the next wave).  The serve loop is::

    while not sched.finished:
        sched.admit(now)                       # fill idle slots (FIFO)
        tok = engine.prefill(sched.histories(), sched.frontends())
        while True:
            out = sched.commit(tok, now)       # append + count + free slots
            if sched.finished or (out.freed and sched.has_waiting(now)):
                break
            tok = engine.decode_step(tok, sched.positions())

Token accounting is split at commit time: a request's **first** generated
token is produced by the prefill wave (``prefill_tokens``); everything after
is a decode token (``decode_tokens``) — the split the monolith conflated
into one ``total_tokens`` counter.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request.  ``prompt`` is the token array (or any sized
    sequence — the stub engine only needs its length); ``max_new`` caps the
    generated tokens (falls back to the scheduler default); ``frontend`` is
    per-request conditioning drawn once at admission time by the caller."""

    rid: int
    prompt: np.ndarray
    arrival: float = 0.0
    max_new: Optional[int] = None
    frontend: Any = None


@dataclasses.dataclass(frozen=True)
class CommitOutcome:
    freed: bool                      # did any slot free this step?
    finished: List[int]              # rids completed this step
    first_tokens: List[int]          # rids whose FIRST token just committed


class ContinuousBatchScheduler:
    """Slot/queue state machine for continuous batching (no JAX)."""

    def __init__(self, n_slots: int, max_new: int, eos_id: int = -1):
        assert n_slots >= 1
        self.n_slots = n_slots
        self.max_new = max_new
        self.eos_id = eos_id
        self.queue: collections.deque[Request] = collections.deque()
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_hist: List[np.ndarray] = [np.zeros(0, np.int32)] * n_slots
        self.slot_gen: List[int] = [0] * n_slots
        # accounting
        self.submitted = 0
        self.served = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self.waves = 0                      # prefill waves (joins included)
        self.completions: Dict[int, List[int]] = {}
        self.admission_order: List[int] = []

    # ---- queue -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        """FIFO enqueue.  Requests must be submitted in arrival order."""
        if self.queue and req.arrival < self.queue[-1].arrival:
            raise ValueError("submit() out of arrival order")
        self.queue.append(req)
        self.submitted += 1

    def has_waiting(self, now: float = math.inf) -> bool:
        """Is an *arrived* request waiting for a slot?"""
        return bool(self.queue) and self.queue[0].arrival <= now

    def next_arrival(self) -> Optional[float]:
        return self.queue[0].arrival if self.queue else None

    @property
    def live(self) -> List[int]:
        return [s for s in range(self.n_slots) if self.slot_req[s] is not None]

    @property
    def finished(self) -> bool:
        return not self.queue and not self.live

    # ---- admission (the prefill-on-join policy) --------------------------
    def admit(self, now: float = math.inf) -> List[int]:
        """Fill idle slots FIFO from the arrived queue; returns the slots
        that joined.  The caller must follow any non-empty join with a
        prefill wave over ``histories()`` (`commit(..., wave start)` counts
        it)."""
        joined = []
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.has_waiting(now):
                req = self.queue.popleft()
                self.slot_req[s] = req
                self.slot_hist[s] = np.asarray(req.prompt, np.int32)
                self.slot_gen[s] = 0
                self.admission_order.append(req.rid)
                joined.append(s)
        if joined:
            self.waves += 1
        return joined

    # ---- batch views for the engine --------------------------------------
    def histories(self) -> List[np.ndarray]:
        """Per-slot token history (prompt + generated); empty for idle."""
        return [self.slot_hist[s] if self.slot_req[s] is not None
                else np.zeros(0, np.int32) for s in range(self.n_slots)]

    def frontends(self) -> List[Any]:
        return [r.frontend if r is not None else None for r in self.slot_req]

    def positions(self) -> List[int]:
        """Live slots' history lengths (decode-step attention spans)."""
        return [len(self.slot_hist[s]) for s in self.live]

    def slot_positions(self) -> List[int]:
        """Per-slot history lengths, 0 for idle slots (engine decode view)."""
        return [len(self.slot_hist[s]) if self.slot_req[s] is not None else 0
                for s in range(self.n_slots)]

    # ---- token commit ----------------------------------------------------
    def commit(self, tokens: Sequence[int], now: float = 0.0) -> CommitOutcome:
        """Commit one wave/step's next token per live slot: append to the
        history, split the prefill/decode count, and free finished slots
        (EOS or the request's ``max_new`` cap — both checked on the same
        step, completing exactly once)."""
        tok = np.asarray(tokens)
        freed, finished, first = False, [], []
        for s in range(self.n_slots):
            req = self.slot_req[s]
            if req is None:
                continue                      # dead slot: not counted
            t = int(tok[s])
            self.slot_hist[s] = np.append(self.slot_hist[s], np.int32(t))
            self.slot_gen[s] += 1
            if self.slot_gen[s] == 1:         # produced by the prefill wave
                self.prefill_tokens += 1
                first.append(req.rid)
            else:
                self.decode_tokens += 1
            cap = req.max_new if req.max_new is not None else self.max_new
            if t == self.eos_id or self.slot_gen[s] >= cap:
                self.completions[req.rid] = (
                    self.slot_hist[s][-self.slot_gen[s]:].tolist())
                finished.append(req.rid)
                self.slot_req[s] = None
                self.served += 1
                freed = True
        return CommitOutcome(freed=freed, finished=finished,
                             first_tokens=first)

    # ---- stats -----------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        return {
            "served": self.served,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "generated_tokens": self.prefill_tokens + self.decode_tokens,
            "prefills": self.waves,
            "completions": [self.completions[r]
                            for r in sorted(self.completions)],
        }

"""Simulated-clock serving: millions of requests priced in device time.

The scale path of the serving subsystem (DESIGN.md §11): replay a traffic
``Trace`` (``launch.traffic``) through the continuous-batching policy of
``launch.scheduler`` with every token priced by a technology's
``TokenPrices`` (``imc.cost_model``) — no model forwards, no JAX, pure
bookkeeping — and return per-request TTFT / per-token latencies plus total
simulated time and energy.

Two interchangeable methods:

* ``events`` (default) — the fast path.  Between scheduler events
  (admission waves, completions, drain-to-arrival jumps) a decode segment's
  cost is integrated in closed form: per-token cost is affine in context
  position, so ``k`` steps over ``L`` live slots with position sum ``S``
  cost exactly ``k*L*t_tok + t_pos*(k*S + L*k*(k-1)/2)``.  One Python
  iteration per *event* (~2 per request) instead of per token — this is
  what serves 1e6+ Poisson requests per technology in the full benchmark.
* ``steps`` — the reference path: drives the **real**
  ``ContinuousBatchScheduler`` with a ``StubEngine`` one step at a time,
  pricing each step individually.  Token-for-token the same policy; the
  equivalence test pins ``events`` against it so the closed forms can never
  drift from the scheduler's actual semantics.

Policy (both methods, identical to the serve loop): FIFO admission into
idle slots, whole-batch re-prefill on join (recompute policy), joins only
at wave boundaries — a slot must free with arrived work waiting, or the
system must drain to the next arrival, before a new wave starts.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.launch.scheduler import ContinuousBatchScheduler, Request
from repro.launch.traffic import Trace


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Raw per-request outcome of one simulated serving run."""

    technology: str
    ttft_s: np.ndarray          # first-token latency per request [s]
    tpot_s: np.ndarray          # mean per-output-token latency (NaN if 1 tok)
    finish_s: np.ndarray        # completion clock per request [s]
    sim_time_s: float           # clock at last completion
    busy_s: float               # device time actually charged (no idle gaps)
    energy_j: float
    prefill_tokens: int
    decode_tokens: int
    waves: int                  # prefill waves (joins included)
    wave_tokens: int            # history tokens reprocessed across all waves


def _tpot(trace: Trace, ttft: np.ndarray, finish: np.ndarray) -> np.ndarray:
    olen = trace.output_tokens.astype(np.float64)
    first = trace.arrival_s + ttft
    with np.errstate(invalid="ignore", divide="ignore"):
        tpot = (finish - first) / (olen - 1.0)
    return np.where(olen > 1.0, tpot, np.nan)


def _simulate_events(prices, trace: Trace, n_slots: int) -> SimResult:
    n = len(trace)
    arr = trace.arrival_s.tolist()
    plen = trace.prompt_tokens.tolist()
    olen = trace.output_tokens.tolist()
    t_tok, t_pos = prices.t_tok, prices.t_pos
    e_tok, e_pos = prices.e_tok, prices.e_pos

    ttft = np.full(n, np.nan)
    finish = np.full(n, np.nan)
    slot_rid = [-1] * n_slots
    slot_pos = [0] * n_slots        # history length (prompt + generated)
    slot_rem = [0] * n_slots        # tokens still to produce
    slot_first = [False] * n_slots  # next committed token is the first one
    clock = busy = energy = 0.0
    nxt = completed = 0
    waves = wave_tokens = decode_tokens = 0

    while completed < n:
        live = [s for s in range(n_slots) if slot_rid[s] >= 0]
        if not live:
            if nxt >= n:
                break
            clock = max(clock, arr[nxt])
        # ---- admission: fill idle slots FIFO with arrived requests -------
        for s in range(n_slots):
            if slot_rid[s] < 0 and nxt < n and arr[nxt] <= clock:
                slot_rid[s], slot_pos[s] = nxt, plen[nxt]
                slot_rem[s], slot_first[s] = olen[nxt], True
                nxt += 1
        live = [s for s in range(n_slots) if slot_rid[s] >= 0]
        # ---- prefill wave: recompute every live history ------------------
        waves += 1
        tw = ew = 0.0
        for s in live:
            h = slot_pos[s]
            tri = h * (h - 1) / 2.0
            tw += h * t_tok + t_pos * tri
            ew += h * e_tok + e_pos * tri
            wave_tokens += h
        clock += tw
        busy += tw
        energy += ew
        # wave commit: one token per live slot
        freed = False
        for s in live:
            slot_pos[s] += 1
            slot_rem[s] -= 1
            if slot_first[s]:
                slot_first[s] = False
                ttft[slot_rid[s]] = clock - arr[slot_rid[s]]
            else:
                decode_tokens += 1
            if slot_rem[s] == 0:
                finish[slot_rid[s]] = clock
                slot_rid[s] = -1
                completed += 1
                freed = True
        if completed >= n:
            break
        if freed and nxt < n and arr[nxt] <= clock:
            continue                          # re-join at the wave boundary
        # ---- decode segments: closed-form between events -----------------
        while True:
            live = [s for s in range(n_slots) if slot_rid[s] >= 0]
            if not live:
                break                         # drain -> next arrival (outer)
            k = min(slot_rem[s] for s in live)
            ln = len(live)
            ssum = sum(slot_pos[s] for s in live)
            steps = k * ssum + ln * k * (k - 1) / 2.0
            dt = k * ln * t_tok + t_pos * steps
            clock += dt
            busy += dt
            energy += k * ln * e_tok + e_pos * steps
            decode_tokens += k * ln
            freed = False
            for s in live:
                slot_pos[s] += k
                slot_rem[s] -= k
                if slot_rem[s] == 0:
                    finish[slot_rid[s]] = clock
                    slot_rid[s] = -1
                    completed += 1
                    freed = True
            if completed >= n:
                break
            if freed and nxt < n and arr[nxt] <= clock:
                break                         # -> admission wave
    return SimResult(prices.technology, ttft, _tpot(trace, ttft, finish),
                     finish, clock, busy, energy, n, decode_tokens, waves,
                     wave_tokens)


def _simulate_steps(prices, trace: Trace, n_slots: int,
                    engine=None) -> SimResult:
    """Reference path: the real scheduler + a stub engine, step by step."""
    from repro.launch.engine import StubEngine

    n = len(trace)
    engine = engine or StubEngine()
    sched = ContinuousBatchScheduler(n_slots=n_slots, max_new=1)
    for rid in range(n):
        sched.submit(Request(rid=rid,
                             prompt=np.zeros(int(trace.prompt_tokens[rid]),
                                             np.int32),
                             arrival=float(trace.arrival_s[rid]),
                             max_new=int(trace.output_tokens[rid])))
    ttft = np.full(n, np.nan)
    finish = np.full(n, np.nan)
    clock = busy = energy = 0.0
    wave_tokens = 0

    while not sched.finished:
        if not sched.live and not sched.has_waiting(clock):
            clock = max(clock, sched.next_arrival())
        sched.admit(clock)
        hist_lens = sched.positions()
        wave_tokens += sum(hist_lens)
        for h in hist_lens:
            c = prices.prefill(h)
            clock += c.t
            busy += c.t
            energy += c.e
        tok, _ = engine.prefill(sched.histories(), sched.frontends())
        while True:
            out = sched.commit(tok, clock)
            for rid in out.first_tokens:
                ttft[rid] = clock - trace.arrival_s[rid]
            for rid in out.finished:
                finish[rid] = clock
            # leave the wave when done, when the system drains (remaining
            # arrivals are in the future -- the outer loop jumps the clock),
            # or when a freed slot has arrived work to join
            if sched.finished or not sched.live or (
                    out.freed and sched.has_waiting(clock)):
                break
            pos = sched.slot_positions()
            for p in pos:
                if p > 0:
                    c = prices.decode_token(p)
                    clock += c.t
                    busy += c.t
                    energy += c.e
            tok, _ = engine.decode_step(tok, pos)
    return SimResult(prices.technology, ttft, _tpot(trace, ttft, finish),
                     finish, clock, busy, energy, sched.prefill_tokens,
                     sched.decode_tokens, sched.waves, wave_tokens)


def simulate_serving(prices, trace: Trace, n_slots: int = 8,
                     method: str = "events",
                     engine=None) -> SimResult:
    """Serve ``trace`` on ``n_slots`` slots under ``prices``.

    ``method='events'`` is the closed-form fast path; ``method='steps'``
    drives the real scheduler one step at a time (small traces / tests).
    """
    if method == "events":
        return _simulate_events(prices, trace, n_slots)
    if method == "steps":
        return _simulate_steps(prices, trace, n_slots, engine=engine)
    raise ValueError(f"unknown method {method!r}; 'events' or 'steps'")


# --- graceful degradation: SLO attainment vs hard-fault rate ----------------

@dataclasses.dataclass(frozen=True)
class FaultSLOPoint:
    """One (repair policy, fault rate) cell of the serving degradation
    curve (DESIGN.md §13): the *healthy* device's trace and SLO served at
    the faulty device's token prices."""

    technology: str
    fault_rate: float
    repair: str                  # policy name ("none" when unrepaired)
    slo_attainment: float
    array_yield: float
    ttft_p99_s: float
    tpot_p99_s: float
    tokens_per_joule: float


def fault_slo_curve(kind: str = "afmtj",
                    rates=(0.0, 1e-3, 3e-3, 1e-2),
                    policies=(None,), *, arch: str = "qwen2-0.5b",
                    rho: float = 0.7, n_requests: int = 2000,
                    n_slots: int = 8, seed: int = 0) -> list:
    """Serving SLO attainment vs fault rate × repair policy.

    The offered load, the Poisson trace, and the SLO are all fixed at the
    *healthy* device's prices — the question is how much of the committed
    service level a degrading part can still honor, not how a re-provisioned
    system would behave.  Each (policy, rate) point then re-prices the SAME
    trace with the fault-charged cost model (``imc_cost_model(faults=...)``:
    repair-yield latency stretch + ECC/spare energy overhead) and replays it
    through the event-driven simulator.  Rate 0 is bit-identical to the
    healthy run for every policy (``fault_cost_factors`` is (1,1,1) when no
    fault plane is active), so each curve starts at the same attainment.

    Imports stay local: the serving stack is JAX-free until a fault spec
    actually enters the picture.
    """
    from repro.configs.registry import ARCHS
    from repro.imc.cost_model import device_cost_model, per_token_counts
    from repro.imc.faults import FaultSpec
    from repro.launch.report import SLO, build_report
    from repro.launch.traffic import (CHAT_OUTPUTS, CHAT_PROMPTS,
                                      poisson_at_load)

    tc = per_token_counts(ARCHS[arch])
    healthy = device_cost_model(kind).token_prices(tc)
    trace = poisson_at_load(healthy, rho, n_requests, n_slots,
                            seed=seed).trace()
    slo = SLO.normalized(healthy, CHAT_PROMPTS, CHAT_OUTPUTS, n_slots)
    points = []
    for pol in policies:
        for r in rates:
            spec = FaultSpec.at_rate(float(r), seed=seed)
            model = device_cost_model(kind, faults=spec, repair=pol)
            res = simulate_serving(model.token_prices(tc), trace,
                                   n_slots=n_slots)
            rep = build_report(kind, res.ttft_s, res.tpot_s, res.sim_time_s,
                               res.energy_j, res.prefill_tokens,
                               res.decode_tokens, offered_load=rho, slo=slo,
                               busy_s=res.busy_s)
            points.append(FaultSLOPoint(
                technology=kind, fault_rate=float(r),
                repair="none" if pol is None else pol.name,
                slo_attainment=float(rep.slo_attainment),
                array_yield=float(model.array_yield),
                ttft_p99_s=rep.ttft_p99_s, tpot_p99_s=rep.tpot_p99_s,
                tokens_per_joule=rep.tokens_per_joule))
    return points

"""Step builders shared by the dry-run, the trainer and the server.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation) — the
dry-run lowers against these; the trainer/server feed real arrays of the
same shapes.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_update, wsd_schedule


def _text_len(cfg: ArchConfig, shape: ShapeConfig) -> int:
    if cfg.n_encoder_layers:
        return shape.seq_len
    return shape.seq_len - cfg.frontend_positions


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract input batch for the given (arch, shape) cell."""
    B = shape.global_batch
    S = _text_len(cfg, shape)
    F = cfg.frontend_positions
    i32 = jnp.int32
    cdt = jnp.dtype(cfg.compute_dtype)

    if shape.kind == "train":
        mb = shape.microbatches
        assert B % mb == 0, (B, mb)
        Bm = B // mb
        batch = {
            "tokens": jax.ShapeDtypeStruct((mb, Bm, S), i32),
            "labels": jax.ShapeDtypeStruct((mb, Bm, S), i32),
        }
        if F and not cfg.n_encoder_layers:
            batch["frontend_embeds"] = jax.ShapeDtypeStruct((mb, Bm, F, cfg.d_model), cdt)
        if cfg.n_encoder_layers:
            batch["encoder_frames"] = jax.ShapeDtypeStruct((mb, Bm, F, cfg.d_model), cdt)
        return batch

    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if F and not cfg.n_encoder_layers:
            batch["frontend_embeds"] = jax.ShapeDtypeStruct((B, F, cfg.d_model), cdt)
        if cfg.n_encoder_layers:
            batch["encoder_frames"] = jax.ShapeDtypeStruct((B, F, cfg.d_model), cdt)
        return batch

    # decode: one new token against a full KV/SSM cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


def abstract_cache(cfg: ArchConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len)
    )


def make_train_step(cfg: ArchConfig, shape: ShapeConfig,
                    opt: AdamWConfig = AdamWConfig(), total_steps: int = 10000,
                    param_shardings=None):
    """(params, m, v, step, batch) -> (params, m, v, step, metrics).

    Microbatched gradient accumulation via lax.scan when
    shape.microbatches > 1; accumulation dtype = cfg.opt_state_dtype
    (bf16 for the big-MoE archs, fp32 otherwise).

    ``param_shardings`` pins the grad-accumulation scan carry to the
    parameter sharding — without it GSPMD may leave the carry replicated and
    emit a full-size grad all-reduce per microbatch (measured 2.5 TB/device
    per step on llama4 train_4k; see EXPERIMENTS.md §Perf iteration 1).
    """
    n_micro = shape.microbatches

    def loss_fn(params, mb):
        loss, metrics = M.forward_train(params, cfg, mb)
        return loss, metrics

    def _pin(tree):
        if param_shardings is None:
            return tree
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, tree, param_shardings)

    def train_step(params, m, v, step, batch):
        if n_micro == 1:
            mb = jax.tree_util.tree_map(lambda x: x[0], batch)
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            grads = _pin(grads)
        else:
            acc_dt = jnp.dtype(cfg.opt_state_dtype)
            g0 = _pin(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params))

            def body(g_acc, mb):
                (l, mt), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = _pin(jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g))
                return g_acc, l

            grads, losses = jax.lax.scan(body, g0, batch)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
            loss = jnp.mean(losses)
            metrics = {}
        lr = wsd_schedule(step, opt.lr, total=total_steps)
        params, m, v, gn = adamw_update(params, grads, m, v, step, opt, lr)
        out_metrics = {"loss": loss, "grad_norm": gn, "lr": lr}
        return params, m, v, step + 1, out_metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, shape: ShapeConfig):
    def prefill(params, batch):
        return M.serve_prefill(params, cfg, batch, max_seq=shape.seq_len)

    return prefill


def make_decode_step(cfg: ArchConfig, shape: ShapeConfig):
    def decode(params, cache, tokens):
        return M.serve_step(params, cfg, cache, tokens)

    return decode

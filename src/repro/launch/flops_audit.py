"""Exact FLOPs audit of a step function from its closed jaxpr.

XLA's ``HloCostAnalysis`` visits while-loop bodies ONCE, so ``lax.scan``-
heavy programs (scan-over-layers, grad-accumulation microbatches, flash
KV-chunk scans) under-report FLOPs by the product of trip counts.  The
jaxpr, in contrast, retains every scan's static ``length`` — walking it and
multiplying nested trip counts gives exact matmul/conv FLOPs, including the
remat recompute (checkpoint regions appear inline in the VJP jaxpr).

Counted: dot_general (2*M*N*K*batch), conv. Elementwise flops are ignored
(<2% of any of our cells).  Returns GLOBAL flops — divide by device count
for the per-chip roofline term.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np


def _dot_flops(eqn) -> float:
    (lhs, rhs) = (v.aval for v in eqn.invars[:2])
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    batch = int(np.prod([lhs.shape[i] for i in lb])) if lb else 1
    contract = int(np.prod([lhs.shape[i] for i in lc])) if lc else 1
    m = int(np.prod([lhs.shape[i] for i in range(lhs.ndim)
                     if i not in lc and i not in lb]))
    n = int(np.prod([rhs.shape[i] for i in range(rhs.ndim)
                     if i not in rc and i not in rb]))
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # 2 * output_elems * (kernel contraction size)
    feature_group = eqn.params.get("feature_group_count", 1)
    k_elems = int(np.prod(rhs.shape)) / max(rhs.shape[-1], 1)  # per out-chan
    return 2.0 * int(np.prod(out.shape)) * k_elems / max(feature_group, 1)


_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr",
                    "fun_jaxpr", "branches")


def count_jaxpr_flops(jaxpr) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += _dot_flops(eqn)
        elif name.startswith("conv_general"):
            total += _conv_flops(eqn)
        elif name == "scan":
            inner = count_jaxpr_flops(eqn.params["jaxpr"].jaxpr)
            total += inner * eqn.params["length"]
        elif name == "while":
            # adaptive loops only (not used in step functions); count once
            total += count_jaxpr_flops(eqn.params["body_jaxpr"].jaxpr)
        else:
            for pname in _SUBJAXPR_PARAMS:
                if pname in eqn.params:
                    sub = eqn.params[pname]
                    subs = sub if isinstance(sub, (list, tuple)) else [sub]
                    for s in subs:
                        j = getattr(s, "jaxpr", s)
                        if hasattr(j, "eqns"):
                            total += count_jaxpr_flops(j)
    return total


def audit_step_flops(fn, *abstract_args) -> float:
    """Global (all-device) matmul FLOPs of one step of ``fn``."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    return count_jaxpr_flops(closed.jaxpr)

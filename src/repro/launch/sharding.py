"""Sharding rules: logical parameter/activation axes -> mesh axes.

MaxText-style rules table + divisibility-aware resolution: a logical axis
maps to its mesh axes only when the dimension divides evenly (otherwise that
axis is dropped for the tensor — e.g. seamless's vocab 256206 stays
replicated over `model`), so every arch lowers on every mesh without uneven
-sharding surprises.

Two parameter policies:
  tp    — weights sharded over `model` only (small archs; params fit HBM)
  fsdp  — weights *also* sharded over `data` on the embed axis (ZeRO-3-ish;
          GSPMD inserts per-layer all-gathers inside the scan) — required
          for >=8B archs, and what makes 400B params fit 256 chips.
Optimizer moments always shard exactly like their parameter.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import sharding_hooks

import os

FSDP_ARCHS = (
    "internlm2-20b",
    "qwen3-8b",
    "llama4-maverick-400b-a17b",
    "jamba-1.5-large-398b",
)

# ---- perf-experiment knobs (EXPERIMENTS.md §Perf) --------------------------
# REPRO_ATTN_DP_ARCHS: csv of archs whose attention projections go
#   data-parallel (replicated weights).  Fixes the heads%tp!=0 pathology
#   (qwen2-0.5b: 14 heads over 16-way TP all-reduces full score chunks).
# REPRO_SERVE_WEIGHT_AXES: "2d" (default; embed over data for FSDP archs)
#   or "tp" (serve-time weights TP-only — no per-token weight gathers).
def _attn_dp_archs() -> Tuple[str, ...]:
    return tuple(x for x in os.environ.get("REPRO_ATTN_DP_ARCHS", "").split(",") if x)


def _full_dp_archs() -> Tuple[str, ...]:
    # REPRO_FULL_DP_ARCHS: pure data parallelism (all weights replicated) —
    # the right layout for sub-1B models where TP collectives dwarf compute.
    return tuple(x for x in os.environ.get("REPRO_FULL_DP_ARCHS", "").split(",") if x)


def plan_cell_tiles(tiles: int, n_dev: int) -> Tuple[int, int]:
    """Even tiles-per-device plan for the campaign's 1-D ``cells`` mesh.

    Returns ``(tiles_per_dev, padded_tiles)`` with ``padded_tiles`` the
    smallest multiple of ``n_dev`` >= ``tiles``.  The campaign engine pads
    the launch with budget-0 lanes up to ``padded_tiles`` instead of
    demoting the device count — the pre-PR-10 ``_usable_devices`` walked
    ``n`` down until ``tiles % n == 0``, which silently serialized 3-, 5-
    and 6-device meshes onto 1-2 devices whenever the pow2 tile bucket
    didn't divide (tests/test_scale.py pins the fix).  Padding cost is at
    most ``n_dev - 1`` frozen tiles that exit on their first early-exit
    chunk.
    """
    assert tiles > 0 and n_dev > 0, (tiles, n_dev)
    per = -(-tiles // n_dev)
    return per, per * n_dev


def param_rules(cfg: ArchConfig, mesh: Mesh, kind: str = "train") -> Dict[str, Tuple[str, ...]]:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    fsdp = cfg.name in FSDP_ARCHS or cfg.name.startswith(tuple(FSDP_ARCHS))
    if kind != "train" and os.environ.get("REPRO_SERVE_WEIGHT_AXES") == "tp":
        fsdp = False
    emb = dp if fsdp else ()
    attn_spec = () if cfg.name in _attn_dp_archs() else ("model",)
    if cfg.name in _full_dp_archs():
        return {k: () for k in ("vocab", "embed", "q_proj", "kv_proj", "heads",
                                "ffn", "experts", "expert_ffn", "layers", "conv")}
    return {
        "vocab": ("model",),
        "embed": emb,              # fsdp: ZeRO-shard the embed dim over data
        "q_proj": attn_spec,
        "kv_proj": attn_spec,
        "heads": ("model",),
        "ffn": ("model",),
        "experts": ("model",),     # expert parallelism
        "expert_ffn": dp,          # REPRO_MOE_2D: expert f-dim over data —
                                   # 2D expert sharding, no FSDP weight gathers
        "layers": (),              # scan axis — never sharded
        "conv": (),
    }


def resolve_pspec(
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    rules: Dict[str, Tuple[str, ...]],
    mesh: Mesh,
) -> P:
    """Map logical axes to mesh axes, dropping any that don't divide evenly
    or that are already used by another dim of the same tensor."""
    used: set = set()
    out = []
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, ax in zip(shape, axes):
        spec: Tuple[str, ...] = ()
        if ax is not None:
            cand = tuple(a for a in rules.get(ax, ()) if a not in used)
            total = int(np.prod([sizes[a] for a in cand])) if cand else 1
            if cand and dim % total == 0:
                spec = cand
                used.update(cand)
        out.append(spec if len(spec) != 1 else spec[0])
    out = [s if s != () else None for s in out]
    return P(*out)


def param_shardings(cfg: ArchConfig, mesh: Mesh, specs_axes: Any, specs_shapes: Any,
                    kind: str = "train"):
    """NamedSharding pytree for the parameter tree (and its moments)."""
    rules = param_rules(cfg, mesh, kind)

    def mk(axes, sds):
        return NamedSharding(mesh, resolve_pspec(sds.shape, axes, rules, mesh))

    return jax.tree_util.tree_map(
        mk, specs_axes, specs_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


# --------------------------------------------------------------------------
# activations
# --------------------------------------------------------------------------
def activation_policy(mesh: Mesh, cfg: ArchConfig, shape: ShapeConfig):
    """Install the with_sharding_constraint policy used inside model code."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    seq_sharded = shape.name == "long_500k"          # batch=1: shard sequence

    def policy(x, kind: str):
        if kind == "act_btd":
            if seq_sharded:
                # long_500k decodes one token (B=1, S=1): constraining the
                # activation to a seq-sharded spec makes GSPMD gather weights
                # instead of all-reducing tiny partial activations (measured
                # 7 GB/token on jamba; §Perf iteration).  Leave activations
                # unconstrained; the 500k KV cache keeps its seq sharding.
                return x
            spec = P(dp, None, None)
        elif kind == "logits":
            spec = P(dp, None, "model")
        elif kind == "decode_scores" and seq_sharded:
            # (B, kvh, g, 1, Skv): partial attention over the seq-sharded KV
            spec = P(None, None, None, None, dp)
        elif kind == "cache_kv" and seq_sharded:
            spec = P(None, dp, None, None)   # per-layer (B, S, kvh, hd)
        else:
            return x
        try:
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        except ValueError:
            return x

    sharding_hooks.set_policy(policy)


def batch_shardings(mesh: Mesh, shape: ShapeConfig, batch_tree: Any):
    """Shardings for input batches: batch dim over data axes (replicated when
    the batch doesn't divide, e.g. long_500k's global_batch=1)."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_total = int(np.prod([sizes[a] for a in dp]))

    def mk(x):
        nd = len(x.shape)
        bdim = 1 if (shape.kind == "train" and nd >= 2) else 0
        spec = [None] * nd
        if x.shape[bdim] % dp_total == 0:
            spec[bdim] = dp
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(mk, batch_tree)


def cache_shardings(mesh: Mesh, cfg: ArchConfig, shape: ShapeConfig, cache: Any):
    """Decode-cache shardings.

    decode_32k: batch over data, head_dim (attn) / heads (ssm) over model.
    long_500k (batch=1): KV sequence over data — the 500k cache is the
    dominant tensor and must not be replicated 16x.
    """
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = sizes.get("model", 1)
    long_ctx = shape.name == "long_500k"

    def mk(path, x):
        nd = len(x.shape)
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if nd == 0:
            return NamedSharding(mesh, P())
        if key in ("k", "v") and nd == 5:           # (layers, B, S, H, D)
            if long_ctx:
                spec = [None, None, dp, None, None]
            else:
                spec = [None, dp, None, None,
                        "model" if x.shape[4] % model_n == 0 else None]
            return NamedSharding(mesh, P(*spec))
        if key == "ssm" and nd == 5:                 # (layers, B, H, P, N)
            spec = [None, None if long_ctx else dp,
                    "model" if x.shape[2] % model_n == 0 else None, None, None]
            return NamedSharding(mesh, P(*spec))
        if key == "conv" and nd == 4:                # (layers, B, K-1, C)
            spec = [None, None if long_ctx else dp, None,
                    "model" if x.shape[3] % model_n == 0 else None]
            return NamedSharding(mesh, P(*spec))
        if nd == 5:                                  # cross K/V (layers,B,S,H,D)
            return NamedSharding(
                mesh, P(None, dp, None, None,
                        "model" if x.shape[4] % model_n == 0 else None))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(mk, cache)

"""Serving engines: model execution behind a counts-reporting interface.

``ServeEngine`` owns everything JAX about serving (DESIGN.md §11): params,
the jitted fixed-window prefill and single-token decode, the KV cache, and
per-request frontend conditioning.  Each call returns the batch's next
tokens (numpy) **plus** the step's op counts (``imc.cost_model.StepCounts``)
so the serve loop can run on a simulated device clock instead of wall time.

``StubEngine`` mirrors the same interface with a deterministic token
function and the same analytic op counts, importing no JAX — it is what the
scheduler edge-case tests and the step-granular serving simulator drive.

JAX is imported lazily (inside ``ServeEngine``) so importing this module —
and everything the scheduler/traffic/simulator stack needs — stays JAX-free.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.imc.cost_model import (StepCounts, TokenCounts, decode_step_counts,
                                  per_token_counts, prefill_step_counts)

PAD_ID = 0


class ServeEngine:
    """Jitted prefill + decode over a fixed token window.

    The window (``prompt_len + max_new``) is fixed so the re-prefill of
    continuous batching compiles once; histories are right-aligned into it
    (the recompute-on-join policy — the decode cache keeps a single shared
    position scalar, see ``launch.scheduler``)."""

    def __init__(self, cfg, prompt_len: int, max_new: int, batch: int,
                 seed: int = 0):
        import jax

        from repro.models import model as M

        self.cfg = cfg
        self.batch = batch
        self.window = prompt_len + max_new
        self.max_seq = self.window + cfg.frontend_positions + max_new + 2
        self.token_counts: TokenCounts = per_token_counts(cfg)
        self.frontend_key = ("encoder_frames" if cfg.n_encoder_layers else
                             "frontend_embeds" if cfg.frontend_positions
                             else None)
        self.params = M.init_params(cfg, jax.random.PRNGKey(seed))
        self._prefill = jax.jit(
            lambda p, b: M.serve_prefill(p, cfg, b, max_seq=self.max_seq))
        self._decode = jax.jit(
            lambda p, c, t: M.serve_step(p, cfg, c, t))
        self._cache = None
        self._jnp = __import__("jax.numpy", fromlist=["numpy"])

    def draw_frontend(self, rng: np.random.Generator):
        """One request's frontend conditioning — drawn once at admission and
        kept for the request's whole lifetime (re-prefills must not change
        the 'image' a sequence is conditioned on)."""
        if self.frontend_key is None:
            return None
        return rng.standard_normal(
            (self.cfg.frontend_positions, self.cfg.d_model)).astype(np.float32)

    def prefill(self, histories: Sequence[np.ndarray],
                frontends: Sequence[Any]) -> Tuple[np.ndarray, StepCounts]:
        """Re-prefill the whole batch from right-aligned histories; returns
        (next token per slot, op counts over the live histories)."""
        jnp = self._jnp
        hist = np.full((self.batch, self.window), PAD_ID, np.int32)
        for s, h in enumerate(histories):
            h = np.asarray(h)[-self.window:]
            if h.size:
                hist[s, self.window - h.size:] = h     # right-aligned
        batch = {"tokens": jnp.asarray(hist)}
        if self.frontend_key:
            batch[self.frontend_key] = jnp.asarray(np.stack([
                f if f is not None else
                np.zeros((self.cfg.frontend_positions, self.cfg.d_model),
                         np.float32)
                for f in frontends]))
        logits, self._cache = self._prefill(self.params, batch)
        tok = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        counts = prefill_step_counts(
            self.token_counts,
            [min(len(np.asarray(h)), self.window)
             for h in histories if len(np.asarray(h))])
        return tok, counts

    def decode_step(self, tokens: np.ndarray,
                    slot_positions: Sequence[int]
                    ) -> Tuple[np.ndarray, StepCounts]:
        """One decode step from the cached state; ``slot_positions`` are the
        per-slot history lengths (0 = idle slot) for attention-span op
        counting only — dead slots ride the batch compute for free."""
        jnp = self._jnp
        tok = jnp.asarray(np.asarray(tokens, np.int32))[:, None]
        logits, self._cache = self._decode(self.params, self._cache, tok)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        return nxt, decode_step_counts(self.token_counts,
                                       [p for p in slot_positions if p > 0])


class StubEngine:
    """Engine-shaped deterministic token source (no JAX anywhere).

    ``token_fn(slot, hist_len) -> int`` decides the next token from the
    slot index and the slot's current history length (default: a cheap
    deterministic hash, always positive).  Op counts use the same analytic
    formulas as the real engine, so a scheduler loop driven by a stub
    prices identically to one driven by a model."""

    def __init__(self, token_counts: Optional[TokenCounts] = None,
                 token_fn: Optional[Callable[[int, int], int]] = None,
                 window: Optional[int] = None):
        self.token_counts = token_counts or TokenCounts(1.0, 1.0)
        self.token_fn = token_fn or (lambda s, n: (7 * n + s) % 97 + 1)
        self.window = window

    def draw_frontend(self, rng) -> None:
        return None

    def _clip(self, n: int) -> int:
        return min(n, self.window) if self.window else n

    def prefill(self, histories: Sequence[np.ndarray],
                frontends: Sequence[Any]) -> Tuple[np.ndarray, StepCounts]:
        toks = np.array([self.token_fn(s, len(np.asarray(h)))
                         for s, h in enumerate(histories)], np.int32)
        counts = prefill_step_counts(
            self.token_counts,
            [self._clip(len(np.asarray(h)))
             for h in histories if len(np.asarray(h))])
        return toks, counts

    def decode_step(self, tokens: np.ndarray,
                    slot_positions: Sequence[int]
                    ) -> Tuple[np.ndarray, StepCounts]:
        toks = np.array([self.token_fn(s, int(p))
                         for s, p in enumerate(slot_positions)], np.int32)
        return toks, decode_step_counts(self.token_counts,
                                        [p for p in slot_positions if p > 0])

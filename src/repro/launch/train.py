"""Training driver: ``python -m repro.launch.train --arch qwen2-0.5b --preset smoke``.

Presets:
  smoke  — reduced config, tiny batch, runs on this CPU container in minutes
  full   — the arch's real config at the production mesh (TPU pod)

Wires together every substrate: config registry -> model -> sharding rules ->
data pipeline -> AdamW -> fault-tolerant loop (checkpoint/resume, SIGTERM
preemption save, straggler watchdog).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_arch, smoke_config
from repro.data import DataConfig, make_pipeline
from repro.launch import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import FaultTolerantLoop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    if args.preset == "smoke":
        cfg = smoke_config(args.arch)
        mesh = make_local_mesh()
        shape = ShapeConfig("custom", "train", args.seq, args.batch,
                            microbatches=args.microbatches)
    else:
        cfg = get_arch(args.arch)
        mesh = make_production_mesh()
        shape = ShapeConfig("train_4k", "train", 4096, 256,
                            microbatches=args.microbatches)

    SH.activation_policy(mesh, cfg, shape)
    aparams = M.abstract_params(cfg)
    axes = M.logical_axes(cfg)
    p_shard = SH.param_shardings(cfg, mesh, axes, aparams)

    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"active={cfg.active_param_count()/1e6:.1f}M mesh={mesh.devices.shape}")

    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    params = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), params, p_shard)
    m, v = adamw_init(params, cfg.opt_state_dtype)
    step0 = jnp.zeros((), jnp.int32)

    opt_cfg = AdamWConfig(lr=args.lr)
    train_step = ST.make_train_step(cfg, shape, opt_cfg, total_steps=args.steps)
    batch_spec = ST.input_specs(cfg, shape)
    b_shard = SH.batch_shardings(mesh, shape, batch_spec)
    jitted = jax.jit(
        train_step,
        in_shardings=(p_shard, p_shard, p_shard, None, b_shard),
        out_shardings=(p_shard, p_shard, p_shard, None, None),
        donate_argnums=(0, 1, 2),
    )

    data_cfg = DataConfig(
        vocab=cfg.vocab, seq_len=shape.seq_len if not cfg.frontend_positions
        or cfg.n_encoder_layers else shape.seq_len - cfg.frontend_positions,
        global_batch=shape.global_batch, microbatches=shape.microbatches,
        frontend_positions=cfg.frontend_positions, d_model=cfg.d_model,
        encoder_frames=bool(cfg.n_encoder_layers),
    )
    pipeline = make_pipeline(data_cfg)

    ckpt = Checkpointer(Path(args.ckpt_dir) / cfg.name)
    loop = FaultTolerantLoop(ckpt, save_every=args.save_every)
    loop.install_sigterm()

    # resume if a checkpoint exists
    latest = ckpt.latest_step()
    start = 0
    if latest is not None:
        state_like = {"params": params, "m": m, "v": v,
                      "step": jnp.zeros((), jnp.int32)}
        restored = ckpt.restore(latest, state_like)
        params, m, v, step0 = (restored["params"], restored["m"],
                               restored["v"], restored["step"])
        start = latest
        print(f"resumed from checkpoint step {latest}")

    history = []

    def step_fn(state, batch):
        params, m, v, step = state
        batch = jax.tree_util.tree_map(jnp.asarray, batch)
        params, m, v, step, metrics = jitted(params, m, v, step, batch)
        return (params, m, v, step), metrics

    def get_batch(_):
        return next(pipeline)

    def log(step, metrics, dt):
        if step % args.log_every == 0 or metrics.get("straggler"):
            loss = float(metrics["loss"])
            history.append((step, loss))
            print(f"step {step:5d} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms"
                  + (" STRAGGLER" if metrics.get("straggler") else ""))

    t0 = time.time()
    state = (params, m, v, step0)

    # adapt to FaultTolerantLoop's (state, tree) checkpoint format
    class _StateCkpt:
        def save(self, step, state, blocking=False):
            params, m, v, s = state
            ckpt.save(step, {"params": params, "m": m, "v": v, "step": s},
                      blocking=blocking)

        def wait(self):
            ckpt.wait()

    loop.ckpt = _StateCkpt()
    state, final_step, watchdog = loop.run(
        state, step_fn, get_batch, start, args.steps, log)
    print(f"trained to step {final_step} in {time.time()-t0:.1f}s; "
          f"stragglers={len(watchdog.straggler_steps)}")
    if len(history) >= 2:
        print(f"loss: {history[0][1]:.4f} -> {history[-1][1]:.4f}")
    return history


if __name__ == "__main__":
    main()

"""Model zoo: the 10 assigned architectures as pattern-scanned pure functions."""
from repro.models.model import (  # noqa: F401
    abstract_params,
    forward_train,
    init_cache,
    init_params,
    logical_axes,
    param_specs,
    serve_prefill,
    serve_step,
)

"""Model assembly: pattern-scanned decoder stacks for all 10 architectures.

Layers are grouped by the arch's repeating *pattern* (e.g. gemma2 =
[local, global], jamba = [attn + 7x mamba]); parameters for each pattern
position are stacked with a leading ``layers`` axis and the stack is
traversed with ``lax.scan`` — compact HLO (compile time ~ pattern length,
not n_layers) and the natural place for scan-over-layers remat.

Public API (all pure functions of (params, cfg, ...)):
  param_specs / init_params / abstract_params / logical_axes
  forward_train   — logits-free CE loss via seq-chunked softmax
  serve_prefill   — full-sequence forward, returns last-token logits + cache
  serve_step      — one decode token with threaded cache
  init_cache      — decode-cache pytree (ShapeDtypeStruct-able)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    ParamSpec,
    abstract_params as _abstract,
    init_params as _init,
    linear,
    logical_axes as _axes,
    rms_norm,
    softcap,
)
from repro.models.sharding_hooks import constrain

LOSS_CHUNK = 1024


# --------------------------------------------------------------------------
# specs
# --------------------------------------------------------------------------
def _block_specs(cfg: ArchConfig, mixer: str, ffn: str, cross: bool) -> Dict[str, Any]:
    sp: Dict[str, Any] = {"ln1": ParamSpec((cfg.d_model,), ("embed",), "zeros")}
    if mixer.startswith("attn"):
        sp["attn"] = attn.attn_specs(cfg)
    elif mixer == "mamba":
        sp["mamba"] = ssm_mod.mamba_specs(cfg)
    else:
        raise ValueError(mixer)
    if cfg.post_norms:
        sp["post_ln1"] = ParamSpec((cfg.d_model,), ("embed",), "zeros")
    if cross:
        sp["ln_cross"] = ParamSpec((cfg.d_model,), ("embed",), "zeros")
        sp["cross"] = attn.attn_specs(cfg, cross=True)
    if ffn != "none":
        sp["ln2"] = ParamSpec((cfg.d_model,), ("embed",), "zeros")
        sp["ffn"] = ffn_mod.moe_specs(cfg) if ffn == "moe" else ffn_mod.dense_ffn_specs(cfg)
        if cfg.post_norms:
            sp["post_ln2"] = ParamSpec((cfg.d_model,), ("embed",), "zeros")
    return sp


def _stack_specs(specs: Any, n: int) -> Any:
    """Add a leading stacked-layers axis to every ParamSpec."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    n_rep = cfg.n_pattern_repeats
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), "embed"),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    cross = cfg.n_encoder_layers > 0
    blocks = {}
    for i, (mixer, f) in enumerate(cfg.pattern):
        blocks[f"pos{i}"] = _stack_specs(_block_specs(cfg, mixer, f, cross), n_rep)
    specs["blocks"] = blocks
    if cross:
        enc_cfg = cfg
        enc = _stack_specs(_block_specs(enc_cfg, "attn", "dense", False),
                           cfg.n_encoder_layers)
        specs["encoder"] = {"blocks": enc,
                            "final_norm": ParamSpec((cfg.d_model,), ("embed",), "zeros")}
    return specs


def init_params(cfg: ArchConfig, key: jax.Array):
    return _init(param_specs(cfg), cfg, key)


def abstract_params(cfg: ArchConfig):
    return _abstract(param_specs(cfg), cfg)


def logical_axes(cfg: ArchConfig):
    return _axes(param_specs(cfg))


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------
def _maybe_post(p, name, y, cfg):
    if cfg.post_norms:
        return rms_norm(y, p[name], cfg.norm_eps)
    return y


def _run_block(
    p,
    x,
    cfg: ArchConfig,
    mixer: str,
    ffn: str,
    positions,
    mem_kv=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence block (train/prefill).  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer.startswith("attn"):
        y = attn.self_attention(p["attn"], h, cfg, positions, mixer)
    else:
        y = ssm_mod.mamba_forward(p["mamba"], h, cfg)
    x = x + _maybe_post(p, "post_ln1", y, cfg)
    x = constrain(x, "act_btd")
    if mem_kv is not None:
        h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        x = x + attn.cross_attention(p["cross"], h, mem_kv[0], mem_kv[1], cfg)
    if ffn != "none":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if ffn == "moe":
            y, a = ffn_mod.moe_ffn(p["ffn"], h, cfg)
            aux = aux + a
        else:
            y = ffn_mod.dense_ffn(p["ffn"], h, cfg)
        x = x + _maybe_post(p, "post_ln2", y, cfg)
        x = constrain(x, "act_btd")
    return x, aux


def _scan_pattern(params_blocks, x, cfg: ArchConfig, positions, mem_kv=None,
                  remat: bool = True):
    """Scan the repeating pattern over its stacked parameters."""
    aux_total = jnp.zeros((), jnp.float32)

    def body(carry, layer_params):
        x, aux = carry
        for i, (mixer, f) in enumerate(cfg.pattern):
            x, a = _run_block(layer_params[f"pos{i}"], x, cfg, mixer, f,
                              positions, mem_kv)
            aux = aux + a
        return (x, aux), None

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux_total), _ = jax.lax.scan(body_fn, (x, aux_total), params_blocks)
    return x, aux_total


# --------------------------------------------------------------------------
# embedding / heads
# --------------------------------------------------------------------------
def _embed(params, cfg: ArchConfig, tokens, frontend_embeds=None):
    dt = jnp.dtype(cfg.compute_dtype)
    e = params["embed"]
    x = jnp.take(e, tokens, axis=0).astype(dt) * jnp.sqrt(float(cfg.d_model))
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(dt), x], axis=1)
    return constrain(x, "act_btd")


def _unembed_matrix(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def _logits(params, cfg: ArchConfig, h):
    w = _unembed_matrix(params, cfg)
    logits = linear(h, w.astype(h.dtype), "unembed")
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return constrain(logits, "logits")


# --------------------------------------------------------------------------
# encoder (enc-dec archs)
# --------------------------------------------------------------------------
def _encode(params, cfg: ArchConfig, frame_embeds):
    dt = jnp.dtype(cfg.compute_dtype)
    x = constrain(frame_embeds.astype(dt), "act_btd")
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(carry, layer_params):
        x, = carry
        h = rms_norm(x, layer_params["ln1"], cfg.norm_eps)
        x = x + attn.encoder_attention(layer_params["attn"], h, cfg, positions)
        h = rms_norm(x, layer_params["ln2"], cfg.norm_eps)
        x = x + ffn_mod.dense_ffn(layer_params["ffn"], h, cfg)
        return (constrain(x, "act_btd"),), None

    (x,), _ = jax.lax.scan(jax.checkpoint(body), (x,), params["encoder"]["blocks"])
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def _cross_kv(params, cfg: ArchConfig, enc_out):
    """Per-pattern-position stacked cross K/V from the encoder output."""
    out = {}
    for i in range(len(cfg.pattern)):
        blk = params["blocks"][f"pos{i}"]["cross"]
        k, v = jax.vmap(
            lambda wk, wv: attn.project_memory_kv({"wk": wk, "wv": wv}, enc_out, cfg)
        )(blk["wk"], blk["wv"])
        out[f"pos{i}"] = (k, v)
    return out


# --------------------------------------------------------------------------
# training forward (chunked CE loss; no [B,S,V] materialization)
# --------------------------------------------------------------------------
def forward_train(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]):
    """Returns (loss, metrics).  batch: tokens (B,S), labels (B,S) [-1 = pad],
    optional frontend_embeds (B,F,d) / encoder frames."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    fe = batch.get("frontend_embeds")
    mem_kv = None
    if cfg.n_encoder_layers:
        enc_out = _encode(params, cfg, batch["encoder_frames"])
        # cross K/V are shared across scanned layers per pattern position
        mem_kv = None  # computed inside block scan via stacked params
        x = _embed(params, cfg, tokens)
    else:
        x = _embed(params, cfg, tokens, fe)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    if cfg.n_encoder_layers:
        # Simpler faithful path: scan with cross-attn recomputing K/V per
        # layer from enc_out (cheap relative to decoder self-attn at S=4k).
        aux_total = jnp.zeros((), jnp.float32)

        def body(carry, layer_params):
            x, aux = carry
            for i, (mixer, f) in enumerate(cfg.pattern):
                lp = layer_params[f"pos{i}"]
                kv = attn.project_memory_kv(lp["cross"], enc_out, cfg)
                x, a = _run_block(lp, x, cfg, mixer, f, positions, kv)
                aux = aux + a
            return (x, aux), None

        (x, aux), _ = jax.lax.scan(jax.checkpoint(body), (x, aux_total),
                                   params["blocks"])
    else:
        x, aux = _scan_pattern(params["blocks"], x, cfg, positions)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)

    # strip frontend positions from the loss (labels cover text tokens only)
    if fe is not None:
        x = x[:, fe.shape[1]:]

    w = _unembed_matrix(params, cfg)
    S_txt = x.shape[1]
    n_chunks = max(1, S_txt // LOSS_CHUNK)
    xc = x.reshape(B, n_chunks, S_txt // n_chunks, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, S_txt // n_chunks).transpose(1, 0, 2)

    def ce_chunk(carry, xs_):
        h, lab = xs_
        logits = softcap((h @ w.astype(h.dtype)).astype(jnp.float32),
                         cfg.final_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1
        )[..., 0]
        valid = (lab >= 0).astype(jnp.float32)
        ce = jnp.sum((logz - gold) * valid)
        return carry + jnp.stack([ce, jnp.sum(valid)]), None

    # checkpoint: recompute the chunk logits in the backward pass instead of
    # saving [B, chunk, V]-sized softmax residuals for every chunk
    totals, _ = jax.lax.scan(jax.checkpoint(ce_chunk), jnp.zeros(2), (xc, lc))
    loss = totals[0] / jnp.maximum(totals[1], 1.0) + aux
    return loss, {"ce": totals[0] / jnp.maximum(totals[1], 1.0), "aux": aux,
                  "tokens": totals[1]}


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.compute_dtype)
    n_rep = cfg.n_pattern_repeats
    blocks = {}
    for i, (mixer, f) in enumerate(cfg.pattern):
        if mixer.startswith("attn"):
            c = attn.init_kv_cache(cfg, batch, max_seq, dt)
        else:
            c = ssm_mod.init_mamba_cache(cfg, batch, dt)
        blocks[f"pos{i}"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_rep,) + a.shape), c
        )
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32), "blocks": blocks}
    if cfg.n_encoder_layers:
        kv, hd = cfg.n_kv_heads, cfg.d_head
        cache["cross"] = {
            f"pos{i}": (
                jnp.zeros((n_rep, batch, cfg.frontend_positions, kv, hd), dt),
                jnp.zeros((n_rep, batch, cfg.frontend_positions, kv, hd), dt),
            )
            for i in range(len(cfg.pattern))
        }
    return cache


def serve_prefill(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
                  max_seq: int):
    """Prefill: full forward; returns (last_logits, populated cache)."""
    tokens = batch["tokens"]
    fe = batch.get("frontend_embeds")
    x = _embed(params, cfg, tokens, fe)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cache = init_cache(cfg, B, max_seq)

    enc_out = None
    if cfg.n_encoder_layers:
        enc_out = _encode(params, cfg, batch["encoder_frames"])

    aux0 = jnp.zeros((), jnp.float32)

    def body(carry, layer_params):
        x, aux = carry
        ys = {}
        for i, (mixer, f) in enumerate(cfg.pattern):
            lp = layer_params[f"pos{i}"]
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            if mixer.startswith("attn"):
                q, k, v = attn._project_qkv(lp["attn"], h, cfg, positions)
                fn = (attn.chunked_attention if S > attn.CHUNK_THRESHOLD
                      else attn.full_attention)
                window = cfg.attn.sliding_window if mixer == "attn_local" else None
                o = fn(q, k, v, cfg, causal=True, window=window)
                y = attn._merge_heads(lp["attn"], o, cfg)
                pad = max_seq - S
                ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                ys[f"pos{i}"] = {"k": ck, "v": cv}
                x = x + _maybe_post(lp, "post_ln1", y, cfg)
            else:
                # prefill the mamba states by running the recurrence to S
                y = ssm_mod.mamba_forward(lp["mamba"], h, cfg)
                st = _mamba_state_after(lp["mamba"], h, cfg)
                ys[f"pos{i}"] = st
                x = x + _maybe_post(lp, "post_ln1", y, cfg)
            x = constrain(x, "act_btd")
            if enc_out is not None:
                hc = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
                kc, vc = attn.project_memory_kv(lp["cross"], enc_out, cfg)
                x = x + attn.cross_attention(lp["cross"], hc, kc, vc, cfg)
                ys.setdefault("_cross", {})[f"pos{i}"] = (kc, vc)
            if f != "none":
                h = rms_norm(x, lp["ln2"], cfg.norm_eps)
                if f == "moe":
                    y, a = ffn_mod.moe_ffn(lp["ffn"], h, cfg)
                    aux = aux + a
                else:
                    y = ffn_mod.dense_ffn(lp["ffn"], h, cfg)
                x = x + _maybe_post(lp, "post_ln2", y, cfg)
                x = constrain(x, "act_btd")
        return (x, aux), ys

    (x, _), stacked = jax.lax.scan(body, (x, aux0), params["blocks"])

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, x[:, -1:, :])

    cache["pos"] = jnp.asarray(S, jnp.int32)
    cross = stacked.pop("_cross", None)
    cache["blocks"] = stacked
    if cross is not None:
        cache["cross"] = cross
    return logits, cache


def _mamba_state_after(p, x, cfg: ArchConfig):
    """Final (conv, ssm) state after processing sequence x — decode handoff.
    Handles non-chunk-multiple L like mamba_forward (dt-masked padding)."""
    d_in, H, P, N, K = ssm_mod._dims(cfg)
    B, L_real, _ = x.shape
    Q = cfg.ssm.chunk
    pad = (-L_real) % Q
    xbc_raw = x @ p["w_xbc"].astype(x.dtype)
    conv_state = xbc_raw[:, L_real - (K - 1):L_real, :]
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    L = L_real + pad
    nC = L // Q
    xbc = x @ p["w_xbc"].astype(x.dtype)
    xbc_c = jax.nn.silu(ssm_mod._causal_conv(xbc, p["conv_w"].astype(x.dtype)))
    xs, Bs, Cs = jnp.split(xbc_c, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus((x @ p["w_dt"].astype(x.dtype)).astype(jnp.float32)
                         + p["dt_bias"])
    if pad:
        valid = (jnp.arange(L) < L_real)[None, :, None]
        dt = dt * valid
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(B, nC, Q, H, P)
    Bc = Bs.reshape(B, nC, Q, N)
    dtc = dt.reshape(B, nC, Q, H)
    da = dtc * A
    seg = jnp.cumsum(da, axis=2)
    seg_last = seg[:, :, -1:, :]
    decay_out = jnp.exp(seg_last - seg)
    chunk_state = jnp.einsum(
        "bcqh,bcqn,bcqhp->bchpn", (decay_out * dtc).astype(jnp.float32),
        Bc.astype(jnp.float32), xh.astype(jnp.float32))
    chunk_decay = jnp.exp(seg_last[:, :, 0, :])

    def scan_body(s_prev, xs_):
        cs, cd = xs_
        return s_prev * cd[:, :, None, None] + cs, None

    s_final, _ = jax.lax.scan(
        scan_body, jnp.zeros((B, H, P, N), jnp.float32),
        (chunk_state.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    return {"conv": conv_state, "ssm": s_final}


def serve_step(params, cfg: ArchConfig, cache: Dict[str, Any],
               tokens: jnp.ndarray):
    """One decode step.  tokens: (B, 1) int32.  Returns (logits, cache)."""
    x = _embed(params, cfg, tokens)
    pos = cache["pos"]
    aux0 = jnp.zeros((), jnp.float32)

    has_cross = "cross" in cache
    xs_in = (params["blocks"], cache["blocks"]) + (
        (cache["cross"],) if has_cross else ()
    )

    def body(carry, xs_):
        x, = carry
        if has_cross:
            layer_params, layer_cache, layer_cross = xs_
        else:
            layer_params, layer_cache = xs_
            layer_cross = None
        new_cache = {}
        for i, (mixer, f) in enumerate(cfg.pattern):
            lp = layer_params[f"pos{i}"]
            lc = layer_cache[f"pos{i}"]
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            if mixer.startswith("attn"):
                y, nc = attn.decode_self_attention(lp["attn"], h, lc, pos, cfg, mixer)
            else:
                y, nc = ssm_mod.mamba_decode_step(lp["mamba"], h, lc, cfg)
            new_cache[f"pos{i}"] = nc
            x = x + _maybe_post(lp, "post_ln1", y, cfg)
            if layer_cross is not None:
                hc = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
                kc, vc = layer_cross[f"pos{i}"]
                x = x + attn.cross_attention(lp["cross"], hc, kc, vc, cfg)
            if f != "none":
                h = rms_norm(x, lp["ln2"], cfg.norm_eps)
                if f == "moe":
                    y, _ = ffn_mod.moe_ffn(lp["ffn"], h, cfg)
                else:
                    y = ffn_mod.dense_ffn(lp["ffn"], h, cfg)
                x = x + _maybe_post(lp, "post_ln2", y, cfg)
        return (x,), new_cache

    (x,), new_blocks = jax.lax.scan(body, (x,), xs_in)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, x)
    new_cache = dict(cache)
    new_cache["blocks"] = new_blocks
    new_cache["pos"] = pos + 1
    return logits, new_cache

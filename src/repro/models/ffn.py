"""Feed-forward layers: gated dense (SwiGLU/GeGLU) and Mixture-of-Experts.

MoE is GShard-style grouped dispatch with capacity factor — the
TPU-canonical dropless-ish formulation: tokens are grouped, each group
computes a (Tg, E, C) one-hot combine tensor via a position-in-expert
cumsum, and dispatch/return are einsums that GSPMD turns into all-to-alls
when experts are sharded over the `model` mesh axis (expert parallelism).
Aux losses (Switch load-balance + router z-loss) are returned to the
training loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamSpec, act_fn, linear

MOE_GROUP = 1024          # tokens per dispatch group
CAPACITY_FACTOR = 1.25


def dense_ffn_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("embed", "ffn")),
        "w_up": ParamSpec((d, f), ("embed", "ffn")),
        "w_down": ParamSpec((f, d), ("ffn", "embed")),
    }


def dense_ffn(p, x, cfg: ArchConfig):
    g = act_fn(linear(x, p["w_gate"].astype(x.dtype), "w_gate"), cfg.act)
    u = linear(x, p["w_up"].astype(x.dtype), "w_up")
    return linear(g * u, p["w_down"].astype(x.dtype), "w_down")


def moe_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    assert cfg.moe is not None
    import os

    d, e, f = cfg.d_model, cfg.moe.num_experts, cfg.moe.d_expert
    # REPRO_MOE_2D: shard the expert hidden dim over `data` instead of
    # ZeRO-gathering expert weights (perf knob; EXPERIMENTS.md §Perf).
    fax = "expert_ffn" if os.environ.get("REPRO_MOE_2D") else "ffn"
    emb = None if os.environ.get("REPRO_MOE_2D") else "embed"
    sp = {
        "router": ParamSpec((d, e), ("embed", "experts"), dtype="float32"),
        "w_gate": ParamSpec((e, d, f), ("experts", emb, fax)),
        "w_up": ParamSpec((e, d, f), ("experts", emb, fax)),
        "w_down": ParamSpec((e, f, d), ("experts", fax, emb)),
    }
    if cfg.moe.shared_expert:
        sp["shared"] = {
            "w_gate": ParamSpec((d, f), ("embed", "ffn")),
            "w_up": ParamSpec((d, f), ("embed", "ffn")),
            "w_down": ParamSpec((f, d), ("ffn", "embed")),
        }
    return sp


def moe_ffn(p, x, cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output, aux_loss).  x: (B, S, d)."""
    B, S, d = x.shape
    e = cfg.moe.num_experts
    k = cfg.moe.top_k
    tg = min(MOE_GROUP, B * S)
    assert (B * S) % tg == 0, (B, S, tg)
    G = (B * S) // tg
    if tg <= 64:
        cap = tg * k            # tiny groups (decode/smoke): fully dropless
    else:
        cap = max(4, int(tg * k * CAPACITY_FACTOR / e))

    xt = x.reshape(G, tg, d)
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k selection -> per-token (expert, gate) pairs
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                     # (G,Tg,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # position-in-expert via cumsum over the flattened (token, k) choices
    sel = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)              # (G,Tg,k,E)
    sel_flat = sel.reshape(G, tg * k, e)
    pos = jnp.cumsum(sel_flat, axis=1) - sel_flat                       # (G,Tg*k,E)
    pos = jnp.sum(pos * sel_flat, axis=-1).reshape(G, tg, k)            # (G,Tg,k)
    keep = pos < cap
    gate_vals = gate_vals * keep

    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    # combine[g,t,e,c] = gate for token t's slot c of expert e
    combine = jnp.einsum("gtke,gtkc->gtec", sel, pos_oh * gate_vals[..., None])
    dispatch = (combine > 0.0).astype(x.dtype)

    xe = jnp.einsum("gtec,gtd->egcd", dispatch, xt)                     # (E,G,C,d)
    h_g = act_fn(jnp.einsum("egcd,edf->egcf", xe, p["w_gate"].astype(x.dtype)), cfg.act)
    h_u = jnp.einsum("egcd,edf->egcf", xe, p["w_up"].astype(x.dtype))
    ye = jnp.einsum("egcf,efd->egcd", h_g * h_u, p["w_down"].astype(x.dtype))
    y = jnp.einsum("egcd,gtec->gtd", ye, combine.astype(x.dtype))
    y = y.reshape(B, S, d)

    # Switch load-balance loss + router z-loss
    me = jnp.mean(probs, axis=1)                                        # (G,E)
    ce = jnp.mean(sel.sum(axis=2), axis=1)                              # (G,E)
    lb = e * jnp.mean(jnp.sum(me * ce, axis=-1))
    zl = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = 0.01 * lb + 0.001 * zl

    if cfg.moe.shared_expert:
        y = y + dense_ffn(p["shared"], x, cfg)
    return y, aux

"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: intra-chunk quadratic
(attention-like) term + inter-chunk recurrent state passing via lax.scan —
O(L·Q) work, O(L/Q) sequential steps, MXU-friendly einsums throughout.
Decode is the O(1) recurrence on the (H, P, N) state.

ngroups = 1 (B/C shared across heads), depthwise causal conv(4) over the
[x, B, C] bundle, gated RMSNorm before out-projection — faithful to the
reference Mamba-2 block.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamSpec, rms_norm


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.headdim
    return d_in, n_heads, s.headdim, s.d_state, s.d_conv


def mamba_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    d_in, H, P, N, K = _dims(cfg)
    conv_ch = d_in + 2 * N
    return {
        "w_z": ParamSpec((d, d_in), ("embed", "ffn")),
        "w_xbc": ParamSpec((d, conv_ch), ("embed", "ffn")),
        "w_dt": ParamSpec((d, H), ("embed", "heads")),
        "dt_bias": ParamSpec((H,), ("heads",), "zeros"),
        "a_log": ParamSpec((H,), ("heads",), "ones"),
        "d_skip": ParamSpec((H,), ("heads",), "ones"),
        "conv_w": ParamSpec((K, conv_ch), (None, "ffn")),
        "norm": ParamSpec((d_in,), ("ffn",), "zeros"),
        "w_out": ParamSpec((d_in, d), ("ffn", "embed")),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv via K shifted adds (K=4: cheap, fusion-friendly)."""
    K = w.shape[0]
    out = xbc * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, : xbc.shape[1]]
        out = out + shifted * w[K - 1 - i]
    return out


def mamba_forward(p, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """(B, L, d) -> (B, L, d) via chunked SSD.  L may be any length: the
    sequence is zero-padded to a chunk multiple with dt masked to 0 on the
    padding (decay=1, zero input), which leaves real positions untouched."""
    B, L, d = x.shape
    d_in, H, P, N, K = _dims(cfg)
    Q = cfg.ssm.chunk
    L_real = L
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        L = L + pad
    nC = L // Q

    z = x @ p["w_z"].astype(x.dtype)
    xbc = _causal_conv(x @ p["w_xbc"].astype(x.dtype), p["conv_w"].astype(x.dtype))
    xbc = jax.nn.silu(xbc)
    xs, Bs, Cs = jnp.split(xbc, [d_in, d_in + N], axis=-1)      # (B,L,*)
    dt = jax.nn.softplus(
        (x @ p["w_dt"].astype(x.dtype)).astype(jnp.float32) + p["dt_bias"]
    )                                                            # (B,L,H)
    if pad:
        valid = (jnp.arange(L) < L_real)[None, :, None]
        dt = dt * valid
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                 # (H,)

    xh = xs.reshape(B, nC, Q, H, P)
    Bc = Bs.reshape(B, nC, Q, N)
    Cc = Cs.reshape(B, nC, Q, N)
    dtc = dt.reshape(B, nC, Q, H)
    da = dtc * A                                                 # (B,nC,Q,H)
    seg = jnp.cumsum(da, axis=2)                                 # within-chunk

    # ---- intra-chunk (quadratic in Q) ----------------------------------
    # decay(i,j) = exp(seg_i - seg_j) for i >= j.  Mask BEFORE the exp:
    # masked (i<j) differences are positive and overflow, and inf*0 inside
    # a where poisons the backward pass (the classic where-grad trap).
    diff = seg[:, :, :, None, :] - seg[:, :, None, :, :]          # (B,nC,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool))[None, None, :, :, None]
    decay = jnp.exp(jnp.where(mask, diff, -1e30))
    cb = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    w_intra = cb[..., None] * decay * dtc[:, :, None, :, :]       # (B,nC,Q,Q,H)
    y = jnp.einsum("bcijh,bcjhp->bcihp", w_intra, xh.astype(jnp.float32))

    # ---- inter-chunk state passing --------------------------------------
    seg_last = seg[:, :, -1:, :]                                  # (B,nC,1,H)
    decay_out = jnp.exp(seg_last - seg)                           # (B,nC,Q,H)
    chunk_state = jnp.einsum(
        "bcqh,bcqn,bcqhp->bchpn",
        (decay_out * dtc).astype(jnp.float32),
        Bc.astype(jnp.float32),
        xh.astype(jnp.float32),
    )                                                             # (B,nC,H,P,N)
    chunk_decay = jnp.exp(seg_last[:, :, 0, :])                   # (B,nC,H)

    def scan_body(s_prev, xs_):
        cs, cd = xs_
        s_new = s_prev * cd[:, :, None, None] + cs
        return s_new, s_prev

    s0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, s_before = jax.lax.scan(
        scan_body,
        s0,
        (chunk_state.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    s_before = s_before.transpose(1, 0, 2, 3, 4)                  # (B,nC,H,P,N)
    decay_in = jnp.exp(seg)                                       # (B,nC,Q,H)
    y = y + jnp.einsum(
        "bcqn,bcqh,bchpn->bcqhp", Cc.astype(jnp.float32), decay_in, s_before
    )

    y = y + p["d_skip"][None, None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, L, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = y @ p["w_out"].astype(x.dtype)
    return out[:, :L_real] if pad else out


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------
def init_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    d_in, H, P, N, K = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, K - 1, d_in + 2 * N), dtype),
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def mamba_decode_step(
    p, x: jnp.ndarray, cache: Dict[str, jnp.ndarray], cfg: ArchConfig
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, 1, d) -> (B, 1, d); O(1) state update."""
    B = x.shape[0]
    d_in, H, P, N, K = _dims(cfg)
    z = x @ p["w_z"].astype(x.dtype)                              # (B,1,d_in)
    xbc_new = x @ p["w_xbc"].astype(x.dtype)                      # (B,1,C)
    window = jnp.concatenate([cache["conv"], xbc_new], axis=1)    # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(x.dtype))
    xbc = jax.nn.silu(conv_out)[:, None, :]                       # (B,1,C)
    xs, Bs, Cs = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(
        (x @ p["w_dt"].astype(x.dtype)).astype(jnp.float32) + p["dt_bias"]
    )[:, 0]                                                       # (B,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                       # (B,H)
    xh = xs[:, 0].reshape(B, H, P).astype(jnp.float32)
    Bn = Bs[:, 0].astype(jnp.float32)                             # (B,N)
    Cn = Cs[:, 0].astype(jnp.float32)
    s_new = cache["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, Bn, xh
    )
    y = jnp.einsum("bn,bhpn->bhp", Cn, s_new) + p["d_skip"][None, :, None] * xh
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = y @ p["w_out"].astype(x.dtype)
    new_cache = {"conv": window[:, 1:], "ssm": s_new}
    return out, new_cache

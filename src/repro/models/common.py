"""Model substrate: parameter specs, norms, rotary embeddings, activations.

Parameter handling is spec-first (MaxText-style logical axes):

  * each model defines ``param_specs(cfg) -> pytree[ParamSpec]``
  * ``init_params``     — concrete arrays (smoke tests / real training)
  * ``abstract_params`` — ShapeDtypeStructs (dry-run lowering: no allocation)
  * ``logical_axes``    — pytree of logical-axis tuples; the sharding rules
                          table (launch/sharding.py) maps these to mesh axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]       # logical axis names
    init: str = "normal"                  # normal | zeros | ones | embed
    dtype: Optional[str] = None           # override cfg.param_dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaf_is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs: Any, cfg: ArchConfig, key: jax.Array) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=_leaf_is_spec)
    keys = jax.random.split(key, len(leaves))

    def mk(spec: ParamSpec, k):
        dtype = jnp.dtype(spec.dtype or cfg.param_dtype)
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[-1], 1)
        if spec.init == "embed":
            # N(0, 1/d): inputs are rescaled by sqrt(d) at lookup, and tied
            # unembedding then yields O(1) logits at init (Gemma scheme).
            scale = 1.0 / jnp.sqrt(spec.shape[-1])
        else:
            scale = 1.0 / jnp.sqrt(fan_in)
        return (jax.random.normal(k, spec.shape) * scale).astype(dtype)

    return jax.tree_util.tree_unflatten(treedef, [mk(s, k) for s, k in zip(leaves, keys)])


def abstract_params(specs: Any, cfg: ArchConfig) -> Any:
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or cfg.param_dtype)),
        specs,
        is_leaf=_leaf_is_spec,
    )


def logical_axes(specs: Any) -> Any:
    return jax.tree_util.tree_map(lambda s: s.axes, specs, is_leaf=_leaf_is_spec)


# --------------------------------------------------------------------------
# linear-layer interception (analog IMC routing — DESIGN.md §12)
# --------------------------------------------------------------------------
# All crossbar-mappable GEMMs in the model stack funnel through ``linear``
# so `imc.model_analog` can reroute them through the differential-conductance
# MVM without forking the forward code.  The hook is a plain module global
# (not a context-local): model_analog's unrolled forward is eager and
# single-threaded, and a global keeps the default path free of any overhead
# beyond one ``is None`` check.
_LINEAR_HOOK = None


def linear(x: jnp.ndarray, w: jnp.ndarray, tag: str = "") -> jnp.ndarray:
    """``x @ w`` with optional interception.

    ``x`` may have any number of leading dims; ``w`` is 2-D (K, N).  The hook
    (if installed) receives a 2-D ``(M, K)`` view plus the site tag and must
    return ``(M, N)``.
    """
    if _LINEAR_HOOK is None:
        return x @ w
    lead = x.shape[:-1]
    y = _LINEAR_HOOK(x.reshape(-1, x.shape[-1]), w, tag)
    return y.reshape(*lead, w.shape[-1])


class intercept_linears:
    """Context manager installing ``hook(x2d, w, tag) -> y2d`` on ``linear``."""

    def __init__(self, hook):
        self.hook = hook

    def __enter__(self):
        global _LINEAR_HOOK
        self._prev = _LINEAR_HOOK
        _LINEAR_HOOK = self.hook
        return self

    def __exit__(self, *exc):
        global _LINEAR_HOOK
        _LINEAR_HOOK = self._prev
        return False


# --------------------------------------------------------------------------
# numerics
# --------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def act_fn(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# --------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head // 2, dtype=jnp.float32) / (d_head // 2)))


def apply_rope(
    x: jnp.ndarray,               # (B, S, H, D)
    positions: jnp.ndarray,       # (B, S) or (3, B, S) for M-RoPE
    theta: float,
    mrope_sections: Optional[Tuple[int, int, int]] = None,
) -> jnp.ndarray:
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    if mrope_sections is None:
        if positions.ndim == 3:
            positions = positions[0]
        angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    else:
        # M-RoPE: frequency dims split into (temporal, height, width)
        # sections, each rotated by its own position stream.
        if positions.ndim == 2:
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        parts = []
        start = 0
        for sec, pos in zip(mrope_sections, positions):
            parts.append(pos[..., None].astype(jnp.float32) * freqs[start:start + sec])
            start += sec
        angles = jnp.concatenate(parts, axis=-1)       # (B,S,D/2)
    cos = jnp.cos(angles)[..., None, :]                # (B,S,1,D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)

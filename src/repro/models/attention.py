"""Grouped-query attention: full/chunked (flash-style) + decode-with-cache.

Supports the pool's feature set: GQA (kv_heads <= heads), sliding-window
(local) layers, logit softcapping (gemma2), per-head q/k RMSNorm (qwen3),
QKV bias (qwen2), RoPE / M-RoPE (qwen2-vl), cross-attention (seamless).

The prefill path switches to a chunked online-softmax formulation
(``chunked_attention``) above ``CHUNK_THRESHOLD`` so 32k-token prefill never
materializes an S x S score matrix — lax.scan over KV chunks carrying
(m, l, acc), the standard flash recurrence, which GSPMD shards cleanly.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamSpec, apply_rope, linear, rms_norm, softcap
from repro.models.sharding_hooks import constrain

# Above this query length the flash path is used even in training — a 4k x 4k
# fp32 score tensor per layer would blow HBM at production batch sizes.
CHUNK_THRESHOLD = 2048
KV_CHUNK = 1024


def attn_specs(cfg: ArchConfig, cross: bool = False) -> Dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    sp = {
        "wq": ParamSpec((d, h * hd), ("embed", "q_proj")),
        "wk": ParamSpec((d, kv * hd), ("embed", "kv_proj")),
        "wv": ParamSpec((d, kv * hd), ("embed", "kv_proj")),
        "wo": ParamSpec((h * hd, d), ("q_proj", "embed")),
    }
    if cfg.attn.qkv_bias and not cross:
        sp["bq"] = ParamSpec((h * hd,), ("q_proj",), "zeros")
        sp["bk"] = ParamSpec((kv * hd,), ("kv_proj",), "zeros")
        sp["bv"] = ParamSpec((kv * hd,), ("kv_proj",), "zeros")
    if cfg.attn.qk_norm:
        sp["q_norm"] = ParamSpec((hd,), (None,), "zeros")
        sp["k_norm"] = ParamSpec((hd,), (None,), "zeros")
    return sp


def _project_qkv(p, x, cfg: ArchConfig, positions, rope: bool = True):
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = linear(x, p["wq"].astype(x.dtype), "wq")
    k = linear(x, p["wk"].astype(x.dtype), "wk")
    v = linear(x, p["wv"].astype(x.dtype), "wv")
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, h, hd)
    k = k.reshape(B, S, kv, hd)
    v = v.reshape(B, S, kv, hd)
    if cfg.attn.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.attn.rope_theta, cfg.attn.mrope_sections)
        k = apply_rope(k, positions, cfg.attn.rope_theta, cfg.attn.mrope_sections)
    return q, k, v


def _merge_heads(p, o, cfg: ArchConfig):
    B, S = o.shape[:2]
    o = o.reshape(B, S, cfg.n_heads * cfg.d_head)
    return linear(o, p["wo"].astype(o.dtype), "wo")


def _mask_full(S: int, Skv: int, causal: bool, window: Optional[int], offset: int = 0):
    """(S, Skv) additive mask. offset = index of query 0 within kv timeline."""
    qi = jnp.arange(S)[:, None] + offset
    ki = jnp.arange(Skv)[None, :]
    ok = jnp.ones((S, Skv), dtype=bool)
    if causal:
        ok = ok & (ki <= qi)
    if window is not None:
        ok = ok & (ki > qi - window)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def full_attention(q, k, v, cfg: ArchConfig, causal: bool, window, offset: int = 0):
    """Materialized-scores path (seq <= CHUNK_THRESHOLD)."""
    B, S, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(B, S, kvh, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = softcap(scores, cfg.attn.logit_softcap)
    scores = scores + _mask_full(S, k.shape[1], causal, window, offset)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return o.reshape(B, S, h, hd)


def chunked_attention(q, k, v, cfg: ArchConfig, causal: bool, window, offset: int = 0):
    """Flash-style online-softmax over KV chunks (no S x Skv materialization)."""
    B, S, h, hd = q.shape
    kvh = k.shape[2]
    Skv = k.shape[1]
    g = h // kvh
    qg = q.reshape(B, S, kvh, g, hd)
    n_chunks = (Skv + KV_CHUNK - 1) // KV_CHUNK
    pad = n_chunks * KV_CHUNK - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, KV_CHUNK, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, KV_CHUNK, kvh, hd).transpose(1, 0, 2, 3, 4)

    qi = jnp.arange(S)[:, None] + offset

    def body(carry, xs):
        m, l, acc = carry
        ci, kci, vci = xs
        ki = ci * KV_CHUNK + jnp.arange(KV_CHUNK)[None, :]
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kci).astype(jnp.float32)
        s = s / jnp.sqrt(hd).astype(jnp.float32)
        s = softcap(s, cfg.attn.logit_softcap)
        ok = ki < Skv
        if causal:
            ok = ok & (ki <= qi)
        if window is not None:
            ok = ok & (ki > qi - window)
        s = s + jnp.where(ok, 0.0, -1e30)[None, None, None, :, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(pexp, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", pexp.astype(q.dtype), vci
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, kvh, g, S), -1e30, dtype=jnp.float32)
    l0 = jnp.zeros((B, kvh, g, S), dtype=jnp.float32)
    a0 = jnp.zeros((B, kvh, g, S, hd), dtype=jnp.float32)
    # checkpoint: FlashAttention semantics — recompute chunk scores in the
    # backward instead of saving [*, S, KV_CHUNK] residuals per chunk.
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0), (jnp.arange(n_chunks), kc, vc)
    )
    o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, h, hd)


def self_attention(p, x, cfg: ArchConfig, positions, mixer: str):
    """Training/prefill self-attention."""
    window = cfg.attn.sliding_window if mixer == "attn_local" else None
    q, k, v = _project_qkv(p, x, cfg, positions)
    S = x.shape[1]
    fn = chunked_attention if S > CHUNK_THRESHOLD else full_attention
    o = fn(q, k, v, cfg, causal=True, window=window)
    return _merge_heads(p, o, cfg)


def encoder_attention(p, x, cfg: ArchConfig, positions):
    q, k, v = _project_qkv(p, x, cfg, positions)
    o = full_attention(q, k, v, cfg, causal=False, window=None)
    return _merge_heads(p, o, cfg)


def cross_attention(p, x, mem_k, mem_v, cfg: ArchConfig):
    """Decoder cross-attention over precomputed encoder K/V."""
    B, S, _ = x.shape
    h, hd = cfg.n_heads, cfg.d_head
    q = linear(x, p["wq"].astype(x.dtype), "wq").reshape(B, S, h, hd)
    o = full_attention(q, mem_k, mem_v, cfg, causal=False, window=None)
    return _merge_heads(p, o, cfg)


def project_memory_kv(p, mem, cfg: ArchConfig):
    B, S, _ = mem.shape
    kv, hd = cfg.n_kv_heads, cfg.d_head
    k = linear(mem, p["wk"].astype(mem.dtype), "wk").reshape(B, S, kv, hd)
    v = linear(mem, p["wv"].astype(mem.dtype), "wv").reshape(B, S, kv, hd)
    return k, v


# --------------------------------------------------------------------------
# decode (single token, KV cache)
# --------------------------------------------------------------------------
def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> Dict[str, jnp.ndarray]:
    kv, hd = cfg.n_kv_heads, cfg.d_head
    return {
        "k": jnp.zeros((batch, max_seq, kv, hd), dtype),
        "v": jnp.zeros((batch, max_seq, kv, hd), dtype),
    }


def decode_self_attention(
    p,
    x: jnp.ndarray,               # (B, 1, d)
    cache: Dict[str, jnp.ndarray],
    pos: jnp.ndarray,             # scalar int32: current position
    cfg: ArchConfig,
    mixer: str,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B = x.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    positions = jnp.broadcast_to(pos, (B, 1))
    q, k, v = _project_qkv(p, x, cfg, positions)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
    ck = constrain(ck, "cache_kv")      # keep long-context caches seq-sharded
    cv = constrain(cv, "cache_kv")
    Skv = ck.shape[1]
    g = h // kvh
    qg = q.reshape(B, 1, kvh, g, hd)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, ck).astype(jnp.float32)
    # keep partial scores sharded like the (possibly seq-sharded) KV cache —
    # otherwise GSPMD all-gathers the full 500k cache per decoded token
    # (measured 2.3 GB/token on jamba long_500k; EXPERIMENTS.md §Perf).
    s = constrain(s, "decode_scores")
    s = s / jnp.sqrt(hd).astype(jnp.float32)
    s = softcap(s, cfg.attn.logit_softcap)
    ki = jnp.arange(Skv)[None, :]
    ok = ki <= pos
    window = cfg.attn.sliding_window if mixer == "attn_local" else None
    if window is not None:
        ok = ok & (ki > pos - window)
    s = s + jnp.where(ok, 0.0, -1e30)[None, None, None]
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", w, cv).reshape(B, 1, h, hd)
    return _merge_heads(p, o, cfg), {"k": ck, "v": cv}

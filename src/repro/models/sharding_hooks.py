"""Pluggable activation-sharding hooks.

Models call ``constrain(x, kind)`` at layer boundaries; by default this is a
no-op (single-device smoke tests).  The launcher installs a policy that maps
``kind`` to a PartitionSpec under the active mesh (GSPMD constraint points).
Keeping the hook here avoids a models -> launch dependency.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

_POLICY: Optional[Callable[[jnp.ndarray, str], jnp.ndarray]] = None


def set_policy(fn: Optional[Callable[[jnp.ndarray, str], jnp.ndarray]]) -> None:
    global _POLICY
    _POLICY = fn


def constrain(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if _POLICY is None:
        return x
    return _POLICY(x, kind)

"""Thermal Monte-Carlo campaign engine (DESIGN.md §5, §8, §9).

Packs (corner x temperature x voltage x pulse x sample) reliability grids
into the Pallas thermal LLG kernel's ``(8, cells)`` SoA layout —
temperature rides the lanes as a per-lane Brown sigma and process corners
as per-lane device-parameter rows (``CampaignGrid.variation``), so a
whole campaign is one launch with one compile — shards cell tiles across
devices, and reduces first-crossing steps into WER / latency surfaces
with on-disk result caching.

  grid    — CampaignGrid axes + SoA packing (fused-CT plane, shape buckets)
  engine  — run_campaign / run_ensemble + surface reductions + early exit
            + streaming on-device reduction / donation / multi-process
            mesh launch partitioning (DESIGN.md §14)
  cache   — content-addressed npz result cache + lockless work claims
"""
from repro.campaign.cache import campaign_key  # noqa: F401
from repro.campaign.engine import (  # noqa: F401
    EARLY_EXIT_CHUNK,
    CampaignResult,
    EnsembleResult,
    brown_sigma,
    run_campaign,
    run_ensemble,
)
from repro.campaign.grid import (  # noqa: F401
    CampaignGrid,
    bucket_cells,
    log_horizon_bucket,
    log_pulses,
    pack_campaign,
    pack_plane,
    pack_soa,
    pack_variation,
)

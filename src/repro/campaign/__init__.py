"""Thermal Monte-Carlo campaign engine (DESIGN.md §5).

Packs (voltage x pulse x temperature x sample) reliability grids into the
Pallas thermal LLG kernel's ``(8, cells)`` SoA layout, shards cell tiles
across devices, and reduces first-crossing steps into WER / latency
surfaces with on-disk result caching.

  grid    — CampaignGrid axes + SoA packing
  engine  — run_campaign / run_ensemble + surface reductions
  cache   — content-addressed npz result cache
"""
from repro.campaign.cache import campaign_key  # noqa: F401
from repro.campaign.engine import (  # noqa: F401
    CampaignResult,
    EnsembleResult,
    brown_sigma,
    run_campaign,
    run_ensemble,
)
from repro.campaign.grid import CampaignGrid, pack_plane, pack_soa  # noqa: F401

"""Campaign grids: (voltage x pulse x temperature x sample) -> SoA tiles.

A *campaign* is the Monte-Carlo experiment the paper's reliability story
needs: sweep write voltage, pulse width and temperature, run many thermal
samples per point, and reduce to WER / latency-percentile surfaces.

Key packing insights (DESIGN.md §8):

* Pulse width does **not** need its own simulation axis.  The kernel
  records the *first-crossing step* per cell, so one integration to
  ``max(pulse)/dt`` steps yields WER at every shorter pulse by
  thresholding the crossing time — the pulse axis is pure post-processing.
* Temperature does **not** need its own launch axis either.  Brown's sigma
  is a per-lane kernel input (aux plane row 0), so the whole
  (temperature x voltage x sample) grid packs into the cells plane:
  ``cells = n_T * n_V * n_S`` lanes, each an independent thermal stream
  (per-lane counter-RNG seed), one launch, one compile
  (``pack_campaign``).
* Process corners don't either (DESIGN.md §9).  Per-lane device-parameter
  rows (alpha, B_k, junction conductance factor — plus sigma/tilt derived
  from the varied volume) ride the kernel's variation plane, so a
  ``VariationSpec``'s corner axis packs corner-major ahead of the
  temperature slices: ``cells = n_C * n_T * n_V * n_S``, still one launch
  (``pack_variation``), corners sharing the nominal packing's thermal
  streams and tilt draws (common random numbers).
* Lane counts are padded to **shape buckets** — power-of-two multiples of
  ``CELL_TILE`` (``bucket_cells``) — so ragged workloads (write-verify
  retry rounds over a shrinking cell set) re-land on a handful of compiled
  shapes instead of one XLA compile per round.  Padded lanes carry a step
  budget of 0 (aux plane row 1): they are frozen before the first step and
  the early-exit loop skips them entirely.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import llg
from repro.core.device import thermal_theta0
from repro.core.params import DeviceParams, VariationSpec
from repro.kernels import noise
from repro.kernels.llg_rk4 import CELL_TILE
from repro.kernels.ops import pack_states


@dataclasses.dataclass(frozen=True)
class CampaignGrid:
    """Axes of one Monte-Carlo campaign (all hashable -> usable as jit
    statics and as the on-disk cache key).

    ``variation`` adds the process-corner axis (DESIGN.md §9): each corner
    of the spec gets its own group of temperature slices in the packed
    cells plane, with per-lane device-parameter rows carrying the corner
    factors and D2D draws — corner count and values are campaign *data*
    (they never enter a compile key), and the corner axis shares thermal
    streams and tilt draws with the other corners (common random numbers,
    so corner comparisons are paired per lane)."""

    voltages: Tuple[float, ...]
    pulse_widths: Tuple[float, ...]          # [s], post-processing axis
    temperatures: Tuple[float, ...] = (300.0,)
    n_samples: int = 64
    dt: float = 0.1e-12
    seed: int = 0
    switch_threshold: float = 0.9
    variation: Optional[VariationSpec] = None

    def __post_init__(self):
        object.__setattr__(self, "voltages", tuple(float(v) for v in self.voltages))
        # pulse axis is normalized ascending: it is pure post-processing
        # (surfaces index through grid.pulse_widths) and pulse_for_wer's
        # "smallest qualifying pulse" contract depends on the order
        object.__setattr__(self, "pulse_widths",
                           tuple(sorted(float(t) for t in self.pulse_widths)))
        object.__setattr__(self, "temperatures",
                           tuple(float(t) for t in self.temperatures))
        assert self.voltages and self.pulse_widths and self.temperatures
        assert self.n_samples > 0

    @property
    def n_steps(self) -> int:
        """Integration length covering the longest pulse, plus one step so
        the kernel's never-crossed sentinel (crossing_step == n_steps, i.e.
        crossing_time == n_steps*dt) strictly exceeds every pulse width —
        otherwise lanes that never switch would satisfy ``crossing_time <=
        max(pulse)`` and be miscounted as successful writes."""
        return int(math.ceil(max(self.pulse_widths) / self.dt)) + 1

    @property
    def cells(self) -> int:
        """Real (unpadded) lanes in the packed (voltage x sample) plane."""
        return len(self.voltages) * self.n_samples

    @property
    def shape(self) -> Tuple[int, int, int, int]:
        """(n_T, n_V, n_P, n_S) — the result surface axes (the optional
        corner axis, ``n_corners``, prepends these for variation grids)."""
        return (len(self.temperatures), len(self.voltages),
                len(self.pulse_widths), self.n_samples)

    @property
    def n_corners(self) -> int:
        return 1 if self.variation is None else self.variation.n_corners


def next_pow2(n: int) -> int:
    """Smallest power of two >= ``n`` — the shared rounding rule behind
    both lane bucketing (``bucket_cells``) and the engine's compiled-horizon
    quantization (``engine._quantize_steps``); tune them together."""
    assert n > 0, n
    return 1 << (n - 1).bit_length()


def bucket_cells(cells: int) -> int:
    """Smallest power-of-two multiple of ``CELL_TILE`` >= ``cells``.

    The campaign engine pads every launch to a bucket so ragged cell counts
    (write-verify retry rounds, arbitrary ensembles) reuse a logarithmic
    number of compiled shapes.  Bucket-pad lanes ride with a step budget of
    0, so the extra lanes are frozen at step 0 and (being SIMD lanes of
    otherwise-occupied tiles, or whole tiles that exit before their first
    chunk) cost essentially nothing.
    """
    assert cells > 0, cells
    return CELL_TILE * next_pow2(-(-cells // CELL_TILE))


HORIZON_RUNGS_PER_DECADE = 2


def log_horizon_bucket(n_steps: int,
                       per_decade: int = HORIZON_RUNGS_PER_DECADE) -> int:
    """Smallest rung of a geometric step-count ladder >= ``n_steps``.

    Rungs sit at ``round(10**(k/per_decade))`` for integer k >= 0.  The
    pow2 quantizer (``next_pow2``) is right for write campaigns, whose
    horizons span at most a factor of a few — but retention sweeps span
    *decades* of integration horizon, and pow2 rungs would cost ~3.3
    compiles per decade.  A log ladder caps that at ``per_decade`` compiles
    per decade while never over-integrating by more than one rung (the
    per-lane budget row stops real lanes at the true horizon either way, so
    crossing rows are unaffected — only compile-cache granularity changes).

    Monotone by construction (minimal k with rung >= n_steps), which the
    grid property tests pin alongside ``bucket_cells``.
    """
    assert n_steps > 0, n_steps
    assert per_decade > 0, per_decade
    k = max(0, math.ceil(per_decade * math.log10(n_steps)))
    while k > 0 and round(10 ** ((k - 1) / per_decade)) >= n_steps:
        k -= 1
    while round(10 ** (k / per_decade)) < n_steps:
        k += 1
    return int(round(10 ** (k / per_decade)))


def log_pulses(t_min: float, t_max: float, per_decade: int = 4
               ) -> Tuple[float, ...]:
    """Log-spaced pulse-width ladder [s], endpoints included.

    The natural pulse axis for retention campaigns: the first-crossing row
    gives the survival fraction at *every* rung from one integration, so a
    decade-spanning ladder is free once the horizon covers ``t_max``.
    """
    assert 0 < t_min < t_max, (t_min, t_max)
    n = max(2, int(round(per_decade * math.log10(t_max / t_min))) + 1)
    return tuple(float(t) for t in np.geomspace(t_min, t_max, n))


def pack_soa(m0: jnp.ndarray, voltages: jnp.ndarray) -> jnp.ndarray:
    """(cells, n_sub, 3) states + (cells,) drives -> padded ``(8, cells)`` SoA.

    Dual-sublattice states go through ``kernels.ops.pack_states`` (the Pallas
    kernel's layout contract).  Single-sublattice (FM/MTJ) states keep rows
    0-2 for m and zero rows 3-5 — the engine routes those tiles through the
    ``kernels.ref.ref_llg_rk4`` scan path, never the Pallas kernel, but the
    campaign semantics (padding, seeds, first-crossing row 7) are
    identical.  Lane padding goes to the ``bucket_cells`` shape bucket, not
    just the next ``CELL_TILE`` multiple — see the module docstring.
    """
    cells = m0.shape[0]
    target = bucket_cells(cells)
    if m0.shape[1] == 2:
        state = pack_states(m0, jnp.asarray(voltages, jnp.float32))
        extra = target - state.shape[1]
        if extra:
            state = jnp.pad(state, ((0, 0), (0, extra)))
        return state
    assert m0.shape[1] == 1, m0.shape
    pad = target - cells
    m0 = jnp.pad(m0, ((0, pad), (0, 0), (0, 0)))
    v = jnp.pad(jnp.asarray(voltages, jnp.float32), (0, pad))
    z = jnp.zeros_like(v)
    rows = [m0[:, 0, 0], m0[:, 0, 1], m0[:, 0, 2], z, z, z, v, z]
    return jnp.stack(rows).astype(jnp.float32)


def pack_plane(grid: CampaignGrid, p: DeviceParams, t_index: int):
    """Pack the (voltage x sample) plane for one temperature slice.

    Returns ``(state, seeds)``: the ``(8, cells_padded)`` SoA block and the
    matching ``(cells_padded,)`` uint32 per-lane thermal stream seeds.
    Sample ``s`` of voltage ``v_i`` lands at lane ``i * n_samples + s``.

    Initial states follow ``core.montecarlo``: |N(0,1)| * theta_eq + 0.01
    tilt, uniform azimuth — the Boltzmann spread of the idle cell.  The tilt
    RNG is ``jax.random`` off ``grid.seed`` (host-side, once per campaign);
    the *per-step* thermal field streams are counter-RNG seeds derived from
    ``grid.seed`` and the temperature index so every (T, V, S) lane is an
    independent realization.
    """
    n_v, n_s = len(grid.voltages), grid.n_samples
    cells = n_v * n_s
    zs, ph = _plane_tilt_draws(grid, t_index, cells)
    th = zs * thermal_theta0(p) + 0.01
    m0 = jax.vmap(lambda t, f: llg.initial_state(p, t, f))(th, ph)
    v = jnp.repeat(jnp.asarray(grid.voltages, jnp.float32), n_s)

    state = pack_soa(m0, v)                         # pads to bucket_cells
    padded = state.shape[1]
    # distinct stream block per temperature slice: offset the base seed so
    # T=0 and T=1 lanes never share counters (kernels.noise.slice_seeds)
    seeds = noise.slice_seeds(grid.seed, t_index, padded)
    return state, seeds


def _plane_tilt_draws(grid: CampaignGrid, t_index: int, cells: int):
    """The Boltzmann tilt normals and azimuths of one (V x S) plane —
    shared by ``pack_plane`` and the variation packer, so a variation
    campaign's slices reuse exactly the draws the nominal packing would
    (the per-lane tilt then differs only through the corner's own
    ``theta0``: common random numbers across corners)."""
    key = jax.random.fold_in(jax.random.PRNGKey(grid.seed), t_index)
    k_th, k_ph = jax.random.split(key)
    zs = jnp.abs(jax.random.normal(k_th, (cells,)))
    ph = jax.random.uniform(k_ph, (cells,), maxval=2 * jnp.pi)
    return zs, ph


def pack_campaign(grid: CampaignGrid, p: DeviceParams):
    """Fuse the temperature axis into the cells plane: one SoA block for the
    whole (T x V x S) grid.

    Each temperature slice is packed exactly as ``pack_plane`` would pack it
    standalone — same initial-state draws, same per-lane counter-RNG
    streams, same bucket padding — and the padded slices are concatenated
    along the cells axis.  A fused launch therefore produces *bit-identical*
    crossing rows to the old one-launch-per-temperature loop (pinned by
    ``tests/test_fused_engine.py``); what changes is that Brown's sigma
    becomes a per-lane row (slice ``ti`` carries ``thermal_sigma(p @ T_ti,
    dt)``) and the padded lanes carry a step budget of 0.

    Returns ``(state, seeds, sigma, budget, spans)``: the ``(8, cells)``
    SoA block, per-lane uint32 streams, per-lane sigma row [T], per-lane
    step-budget row (``grid.n_steps`` on real lanes, 0 on padding), and
    ``spans[ti] = (start, stop)`` — the real-lane slice of temperature
    ``ti`` in the packed plane.
    """
    from repro.core.montecarlo import thermal_sigma

    n_steps = float(grid.n_steps)
    states, seed_rows, sigma_rows, budget_rows, spans = [], [], [], [], []
    offset = 0
    for ti, temp in enumerate(grid.temperatures):
        p_t = (p if temp == p.temperature
               else dataclasses.replace(p, temperature=float(temp)))
        st, sd = pack_plane(grid, p_t, ti)
        padded = st.shape[1]
        lane = jnp.arange(padded)
        states.append(st)
        seed_rows.append(sd)
        sigma_rows.append(jnp.full((padded,), thermal_sigma(p_t, grid.dt),
                                   jnp.float32))
        budget_rows.append(
            jnp.where(lane < grid.cells, n_steps, 0.0).astype(jnp.float32))
        spans.append((offset, offset + grid.cells))
        offset += padded
    return (jnp.concatenate(states, axis=1),
            jnp.concatenate(seed_rows),
            jnp.concatenate(sigma_rows),
            jnp.concatenate(budget_rows),
            spans)


def pack_variation(grid: CampaignGrid, p: DeviceParams):
    """Fuse the process-corner axis into the cells plane alongside
    temperature: one SoA block for the whole (corner x T x V x S) grid
    (DESIGN.md §9).

    Layout is corner-major: slice ``ci * n_T + ti`` holds corner ``ci`` at
    temperature ``ti``, packed exactly as a single-corner campaign would
    pack it — same tilt normals (``_plane_tilt_draws``), same thermal
    streams (``noise.slice_seeds(seed, ti)``, *shared across corners*:
    common random numbers make corner comparisons paired per lane and the
    fused launch bit-identical to per-corner launches), and D2D parameter
    draws from the spec's own counter streams (salted by temperature index,
    not corner position — ``VariationSpec.lane_factors``).

    Returns ``(state, seeds, sigma, budget, lane_params, spans)``: the
    ``(8, cells)`` SoA block, per-lane uint32 streams, per-lane Brown sigma
    [T] (now a function of the varied alpha/volume), per-lane step budgets,
    the ``(3, cells)`` variation rows (alpha, B_k, g_scale) the kernel's
    aux plane carries, and ``spans[ci * n_T + ti] = (start, stop)`` real-
    lane slices.  Bucket-pad lanes carry nominal parameter rows (never NaN
    physics), sigma 0 and budget 0.
    """
    spec = grid.variation
    assert spec is not None, "pack_variation needs grid.variation"
    n_t = len(grid.temperatures)
    n_steps = float(grid.n_steps)
    cells = grid.cells
    states, seed_rows, sigma_rows, budget_rows, lane_rows_, spans = (
        [], [], [], [], [], [])
    offset = 0
    for corner in spec.corners:
        for ti, temp in enumerate(grid.temperatures):
            rows = spec.lane_rows(p, corner, cells, grid.dt,
                                  temperature=temp, stream=ti)
            zs, ph = _plane_tilt_draws(grid, ti, cells)
            th = zs * jnp.asarray(rows.theta0, jnp.float32) + 0.01
            m0 = jax.vmap(lambda t, f: llg.initial_state(p, t, f))(th, ph)
            v = jnp.repeat(jnp.asarray(grid.voltages, jnp.float32),
                           grid.n_samples)
            st = pack_soa(m0, v)
            padded = st.shape[1]
            pad = padded - cells

            def _row(vals, fill):
                return np.pad(np.asarray(vals, np.float64), (0, pad),
                              constant_values=fill).astype(np.float32)

            states.append(st)
            seed_rows.append(noise.slice_seeds(grid.seed, ti, padded))
            sigma_rows.append(_row(rows.sigma, 0.0))
            budget_rows.append(_row(np.full(cells, n_steps), 0.0))
            lane_rows_.append(np.stack([
                _row(rows.alpha, p.alpha),
                _row(rows.b_aniso, p.b_aniso),
                _row(rows.g_scale, 1.0),
            ]))
            spans.append((offset, offset + cells))
            offset += padded
    return (jnp.concatenate(states, axis=1),
            jnp.concatenate(seed_rows),
            jnp.asarray(np.concatenate(sigma_rows)),
            jnp.asarray(np.concatenate(budget_rows)),
            jnp.asarray(np.concatenate(lane_rows_, axis=1)),
            spans)

"""On-disk campaign result cache (content-addressed npz).

A campaign is expensive (minutes of kernel time for production grids) and
perfectly reproducible: the result is a pure function of (device params,
grid axes, backend, kernel version).  So results are cached under a sha256
content key — re-running a benchmark or re-building an IMC hierarchy with
WER-margined pulses hits the cache instead of re-integrating.

Layout: ``<cache_dir>/<key>.npz`` holding the crossing-time tensor plus a
json header echoing the inputs (for `ls`-ability / debugging).  Writes are
atomic (tmp + rename) so concurrent campaign processes never observe a
torn file.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Optional

import numpy as np

from repro.core.params import DeviceParams

# bump when the kernel's noise stream or integration scheme changes — old
# cached surfaces are then silently invalidated (different key).
# v3: fused-temperature launch layout (per-lane sigma + step-budget aux
# plane, bucketed lane padding, chunked early exit).  Crossing tensors are
# designed to be bit-identical to v2 (the per-lane streams and per-step
# update order are unchanged — tests/test_fused_engine.py pins the fused
# vs per-T equality), but the launch layout changed enough that a
# conservative invalidation is cheaper than any risk of a stale surface.
# v4: per-lane device-variation plane (DESIGN.md §9) — grids grew an
# optional ``variation`` axis (``CampaignGrid.variation`` lands in the
# key payload via asdict) and variation results store a 4-D
# (corner x T x V x S) tensor.  Nominal grids are numerically unchanged,
# but v3 entries were keyed without the variation field, so they are
# orphaned rather than risked: a v3 file simply never matches a v4 key
# (the version is in the hash) and loads of malformed/stale files stay
# misses — tests/test_variation.py pins the ignored-not-crashed behavior.
KERNEL_VERSION = 4
# covered by the key so future packing changes (lane order, bucket rule)
# can invalidate independently of the physics version
CELLS_LAYOUT = "fused-CT/bucket-pow2"

DEFAULT_CACHE_DIR = os.environ.get(
    "REPRO_CAMPAIGN_CACHE", os.path.join(os.path.expanduser("~"),
                                         ".cache", "repro-campaigns"))


# ---------------------------------------------------------------- generic
# Content-keyed named-array store — the campaign crossing-time cache below
# and the analog weight-programming cache (``imc.model_analog``) are both
# thin layers over these three primitives.

def content_key(payload: dict) -> str:
    """sha256 content key of a json-able payload (sorted keys, so dict
    insertion order never leaks into the key)."""
    blob = json.dumps(payload, sort_keys=True, default=float)
    return hashlib.sha256(blob.encode()).hexdigest()[:32]


def load_arrays(key: str, cache_dir: Optional[str] = None
                ) -> Optional[dict]:
    """All named arrays of a cached entry (header excluded), or None on
    miss.  Corrupt / torn / stale-format files are misses, never errors."""
    path = Path(cache_dir or DEFAULT_CACHE_DIR) / f"{key}.npz"
    if not path.exists():
        return None
    try:
        with np.load(path) as z:
            return {k: z[k] for k in z.files if k != "header"}
    except (OSError, KeyError, ValueError):
        return None                      # corrupt entry == miss


def gc_stale_tmp(cache_dir: Optional[str] = None,
                 max_age_s: float = 86400.0) -> int:
    """Remove ``*.tmp`` droppings older than ``max_age_s`` seconds.

    A process SIGKILLed mid-``store_arrays`` leaves its mkstemp file behind
    (the atomic rename never ran, so no ``.npz`` is ever torn — but the tmp
    bytes still occupy disk).  The age guard keeps the sweep safe against
    *live* writers in other processes: a concurrent store's tmp file is
    seconds old, far under any sane ``max_age_s``.  Returns the number of
    files removed; every error is best-effort-ignored (a racing writer may
    rename or unlink first).
    """
    import time

    d = Path(cache_dir or DEFAULT_CACHE_DIR)
    if not d.is_dir():
        return 0
    cutoff = time.time() - max_age_s
    removed = 0
    for tmp in d.glob("*.tmp"):
        try:
            if tmp.stat().st_mtime <= cutoff:
                tmp.unlink()
                removed += 1
        except OSError:
            continue
    return removed


def store_arrays(key: str, arrays: dict, header: dict,
                 cache_dir: Optional[str] = None) -> Path:
    """Atomically persist named arrays + a json header under ``key``."""
    assert "header" not in arrays, "reserved entry name"
    d = Path(cache_dir or DEFAULT_CACHE_DIR)
    d.mkdir(parents=True, exist_ok=True)
    gc_stale_tmp(cache_dir, max_age_s=86400.0)
    final = d / f"{key}.npz"
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez_compressed(
                f, **arrays,
                header=np.frombuffer(
                    json.dumps(header, default=float).encode(), dtype=np.uint8),
            )
        os.replace(tmp, final)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return final


def drop_arrays(key: str, cache_dir: Optional[str] = None) -> bool:
    """Remove a cached entry (best-effort); True if a file was deleted.
    Used by the campaign engine to retire per-slice resume checkpoints
    once the whole-campaign entry is durable."""
    path = Path(cache_dir or DEFAULT_CACHE_DIR) / f"{key}.npz"
    try:
        path.unlink()
        return True
    except OSError:
        return False


# ----------------------------------------------------------------- claims
# Lockless work claims over the content-addressed store (DESIGN.md §14).
# A fleet of campaign processes sharing one cache directory dedupes work
# by *claiming* a content key before integrating it: ``O_CREAT | O_EXCL``
# on ``<key>.claim`` is atomic on every POSIX filesystem (including NFS
# for local excl semantics we rely on), so exactly one process wins each
# key without any lock server.  A claim is advisory — the npz store stays
# last-writer-wins-atomic regardless — its only job is to keep N processes
# from integrating the same slice N times.  Crashed claimants are handled
# by age: a claim older than ``ttl_s`` is presumed orphaned and may be
# *stolen* (unlinked + re-claimed); the store's atomicity makes a rare
# double-compute after a steal merely wasteful, never wrong.

def claim_path(key: str, cache_dir: Optional[str] = None) -> Path:
    return Path(cache_dir or DEFAULT_CACHE_DIR) / f"{key}.claim"


def try_claim(key: str, cache_dir: Optional[str] = None,
              owner: str = "") -> bool:
    """Atomically claim ``key`` for this process; False if already claimed."""
    d = Path(cache_dir or DEFAULT_CACHE_DIR)
    d.mkdir(parents=True, exist_ok=True)
    try:
        fd = os.open(claim_path(key, cache_dir),
                     os.O_CREAT | os.O_EXCL | os.O_WRONLY)
    except FileExistsError:
        return False
    with os.fdopen(fd, "w") as f:
        f.write(json.dumps({"pid": os.getpid(), "owner": owner}))
    return True


def release_claim(key: str, cache_dir: Optional[str] = None) -> bool:
    """Drop this (or any) claim on ``key`` — best-effort, True on unlink."""
    try:
        claim_path(key, cache_dir).unlink()
        return True
    except OSError:
        return False


def claim_age_s(key: str, cache_dir: Optional[str] = None) -> Optional[float]:
    """Seconds since ``key`` was claimed, or None when unclaimed."""
    import time

    try:
        return max(0.0, time.time() - claim_path(key, cache_dir).stat().st_mtime)
    except OSError:
        return None


def steal_claim(key: str, ttl_s: float, cache_dir: Optional[str] = None,
                owner: str = "") -> bool:
    """Take over a claim older than ``ttl_s`` (a crashed claimant).

    Unlink-then-reclaim: two stealers can both unlink, but only one wins
    the ``O_EXCL`` re-create — the loser retreats to polling the store.
    """
    age = claim_age_s(key, cache_dir)
    if age is None or age < ttl_s:
        return False
    release_claim(key, cache_dir)
    return try_claim(key, cache_dir, owner=owner)


def gc_stale_claims(cache_dir: Optional[str] = None,
                    max_age_s: float = 3600.0) -> int:
    """Sweep orphaned ``*.claim`` files older than ``max_age_s`` (claims of
    processes that died without ``release_claim``); returns files removed."""
    import time

    d = Path(cache_dir or DEFAULT_CACHE_DIR)
    if not d.is_dir():
        return 0
    cutoff = time.time() - max_age_s
    removed = 0
    for c in d.glob("*.claim"):
        try:
            if c.stat().st_mtime <= cutoff:
                c.unlink()
                removed += 1
        except OSError:
            continue
    return removed


# --------------------------------------------------------------- campaigns
def campaign_key(p: DeviceParams, grid, backend: str) -> str:
    """Content hash of everything the crossing-time tensor depends on."""
    return content_key({
        "v": KERNEL_VERSION,
        "layout": CELLS_LAYOUT,
        "params": dataclasses.asdict(p),
        "grid": dataclasses.asdict(grid),
        "backend": backend,
    })


def load(key: str, cache_dir: Optional[str] = None) -> Optional[np.ndarray]:
    """Cached (n_T, n_V, n_S) crossing-time tensor, or None on miss."""
    arrays = load_arrays(key, cache_dir)
    if arrays is None or "crossing_time" not in arrays:
        return None
    return arrays["crossing_time"]


def store(key: str, crossing_time: np.ndarray, header: dict,
          cache_dir: Optional[str] = None) -> Path:
    return store_arrays(key, {"crossing_time": crossing_time}, header,
                        cache_dir)

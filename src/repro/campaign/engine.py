"""Monte-Carlo campaign engine: one kernel launch for the whole campaign.

Replaces the per-sample host-visible scan in ``core.montecarlo`` (O(steps)
XLA while-loop per sample, threefry keys split per step) with the Pallas
thermal LLG kernel — and packs *every* campaign axis that isn't pure
post-processing into the kernel's cells plane:

* voltage x sample ride the lanes (PR 1);
* pulse width falls out of the recorded first-crossing steps (PR 1);
* temperature rides the lanes too: Brown's sigma is a per-lane kernel
  input (aux plane), so a (T x V x S) grid is **one launch, one compile**
  instead of a host-level loop with one sigma-specialized recompile per
  temperature (``grid.pack_campaign``);
* process corners ride the lanes as well (DESIGN.md §9): per-lane
  alpha / B_k / conductance-factor rows on the kernel's variation plane
  make a (corner x T x V x S) grid one launch too, with corner count and
  values as pure data (``grid.pack_variation``).

No wasted steps either: the kernel integrates in chunks and exits a tile
as soon as every lane has crossed or exhausted its per-lane step budget
(``EARLY_EXIT_CHUNK``), and the compiled horizon is quantized to a power
of two (``_quantize_steps``) so campaigns with different pulse ladders
share compiles — the per-lane budget row stops the integration at the
*true* horizon, and crossing rows stay bit-identical to a fixed-horizon
run (``tests/test_fused_engine.py`` pins this).

Scaling: the cells axis is embarrassingly parallel, so the engine shards
cell tiles across every visible device with ``shard_map`` — each device
integrates its own ``cells / n_dev`` lanes (a multiple of the kernel's
CELL_TILE, padded with budget-0 lanes when the tiles don't divide the
mesh — ``_device_plan``), no cross-device communication at all.  Launches
above ``max_cells_per_launch`` split along temperature-slice boundaries
and are all dispatched asynchronously before the first
``block_until_ready`` — the host never serializes device work against
transfers.  Results are reduced host-side into WER / latency-percentile
surfaces and cached on disk (``cache.py``) keyed by the full campaign
content hash.

Past one host (DESIGN.md §14): ``reduce="stream"`` keeps even the
reduction on device — each launch returns exact WER counts and a
first-crossing histogram instead of its dense lane plane, so host
transfers are O(grid points) regardless of sample count; ``donate=True``
donates the state block to the launch so retry rounds reuse device
memory; and a ``launch.mesh.CampaignMesh`` partitions whole launches
across processes, which rendezvous lockless-ly through the
content-addressed store (claims + slice checkpoints in ``cache.py``) —
no collectives, so a mesh of hosts needs nothing but a shared cache
directory.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.campaign import cache as _cache
from repro.campaign.grid import (CampaignGrid, log_horizon_bucket, next_pow2,
                                 pack_campaign, pack_soa, pack_variation)
from repro.core.montecarlo import thermal_sigma
from repro.core.params import DeviceParams
from repro.kernels import noise, ref
from repro.kernels.llg_rk4 import CELL_TILE, llg_rk4_pallas
from repro.kernels.ops import _default_interpret

# Early-exit granularity [steps]: the kernel checks "is every lane done?"
# once per chunk.  Small enough that a finished tile wastes < chunk steps,
# large enough that the all-lane reduction is noise next to the ~60
# flops/step/lane RK4 body.
EARLY_EXIT_CHUNK = 64


def brown_sigma(p: DeviceParams, dt: float, temperature: Optional[float] = None
                ) -> float:
    """Brown's thermal-field std per component per step [T] — canonical
    formula lives in ``core.montecarlo.thermal_sigma``."""
    if temperature is not None and temperature != p.temperature:
        p = dataclasses.replace(p, temperature=float(temperature))
    return thermal_sigma(p, dt)


def _quantize_steps(n_steps: int, horizon: str = "pow2") -> int:
    """Round the compiled horizon up to a shared rung.

    The per-lane step-budget row stops every lane at the *true* horizon,
    and the chunked loop exits a tile within one chunk of its slowest
    lane's budget — so the masked tail costs ~nothing at runtime while
    campaigns over different pulse ladders (write-verify sweeps, margin
    ladders) land on a logarithmic number of compiled step counts.

    ``horizon`` picks the ladder: ``"pow2"`` (default — every existing
    write-path compile pin) or ``"log"`` — the geometric
    ``grid.log_horizon_bucket`` ladder, ~2 rungs per decade, for retention
    campaigns whose horizons span decades (DESIGN.md §10).
    """
    if horizon == "log":
        return log_horizon_bucket(n_steps)
    assert horizon == "pow2", horizon
    return next_pow2(n_steps)


def _integrate_impl(state, seeds, sigma, budget, lane_params=None, *,
                    p: DeviceParams, dt: float, n_steps: int,
                    switch_threshold: float, backend: str, n_dev: int,
                    chunk: int):
    """Advance a (8, cells) block on ``n_dev`` devices (cells sharded).

    Everything that varies *within* a campaign — or between retry rounds
    of a write-verify schedule — is traced data: per-lane Brown sigma,
    per-lane step budgets, per-lane RNG stream seeds, initial states,
    drive voltages, and (variation campaigns, DESIGN.md §9) the per-lane
    device-parameter rows ``lane_params`` — so process-corner count and
    values never recompile.  The only compile keys left are the nominal
    device physics ``p``, the step size, the (quantized) horizon, the
    launch shape (bucketed by ``grid.bucket_cells``), and whether the
    variation plane is present at all.
    """

    def tile_fn(st, sd, sg, bd, lp=None):
        # the SoA Pallas kernel is dual-sublattice by construction
        # (staggered Neel STT); single-sublattice FM/MTJ devices integrate
        # the same production physics through the oracle's lane-vectorized
        # scan — same grids, padding, RNG streams, first-crossing row 7
        if p.n_sublattices == 1 or backend == "ref":
            return ref.ref_llg_rk4(st, p, dt, n_steps, switch_threshold,
                                   thermal_sigma=sg, seeds=sd,
                                   step_budget=bd, chunk=chunk,
                                   lane_params=lp)
        return llg_rk4_pallas(st, p, dt, n_steps, switch_threshold,
                              interpret=_default_interpret(),
                              thermal_sigma=sg, seeds=sd,
                              step_budget=bd, chunk=chunk, lane_params=lp)

    if n_dev == 1:
        return tile_fn(state, seeds, sigma, budget, lane_params)
    mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("cells",))
    # check_rep=False: shard_map has no replication rule for pallas_call;
    # every output is fully sharded along cells anyway
    specs = (P(None, "cells"), P("cells"), P("cells"), P("cells"))
    if lane_params is None:
        fn = shard_map(tile_fn, mesh=mesh, in_specs=specs,
                       out_specs=P(None, "cells"), check_rep=False)
        return fn(state, seeds, sigma, budget)
    fn = shard_map(tile_fn, mesh=mesh, in_specs=specs + (P(None, "cells"),),
                   out_specs=P(None, "cells"), check_rep=False)
    return fn(state, seeds, sigma, budget, lane_params)


_INTEGRATE_STATICS = ("p", "dt", "n_steps", "switch_threshold", "backend",
                      "n_dev", "chunk")
_integrate_sharded = jax.jit(_integrate_impl,
                             static_argnames=_INTEGRATE_STATICS)
# Donated variant (DESIGN.md §14): XLA aliases the (8, cells) state input
# to the same-shaped output, so retry rounds (write-verify schedules, the
# engine's own error retries) reuse device memory instead of holding both
# blocks live.  A *separate* jit object, so every compile-count pin on
# ``_integrate_sharded`` keeps counting only the default path.  NOTE:
# aliasing constrains XLA's buffer assignment, and the re-scheduled
# executable may associate f32 arithmetic differently — observed as rare
# +-1-step crossing differences vs the undonated compile (deterministic
# run-to-run; tests/test_scale.py pins repeatability and the statistical
# envelope).  Donation is therefore opt-in and never the default under a
# bit-exactness pin.
_integrate_donated = jax.jit(_integrate_impl,
                             static_argnames=_INTEGRATE_STATICS,
                             donate_argnums=(0,))


def _device_plan(span_cells: int, devices: Optional[int]) -> Tuple[int, int]:
    """Device count + padded lane width for one launch span.

    Never demotes the device count: when the span's CELL_TILE tiles don't
    divide the requested count (pow2 shape buckets vs 3/5/6-device
    meshes), the span is padded with budget-0 lanes up to the next
    tiles-per-device boundary (``launch.sharding.plan_cell_tiles``).  The
    pre-PR-10 ``_usable_devices`` instead walked ``n`` down until the
    tiles divided — silently serializing exactly the uneven meshes
    multi-host fleets produce (tests/test_scale.py pins the fix at 3, 5
    and 6 host devices).  Pad lanes are frozen at step 0 (budget 0) and
    trimmed before any reduction, so crossing rows stay bit-identical to
    the 1-device launch."""
    n = (jax.device_count() if devices is None
         else max(1, min(int(devices), jax.device_count())))
    tiles = -(-span_cells // CELL_TILE)
    from repro.launch.sharding import plan_cell_tiles

    _, padded_tiles = plan_cell_tiles(tiles, n)
    return n, padded_tiles * CELL_TILE


def _pad_lanes(st, sd, sg, bd, lp, pad: int, p: DeviceParams):
    """Append ``pad`` frozen lanes (zero state/seed/sigma/budget, nominal
    variation rows) so a span fills its device plan exactly."""
    if pad == 0:
        return st, sd, sg, bd, lp
    st = jnp.pad(st, ((0, 0), (0, pad)))
    sd = jnp.pad(sd, (0, pad))
    sg = jnp.pad(sg, (0, pad))
    bd = jnp.pad(bd, (0, pad))
    if lp is not None:
        fill = np.broadcast_to(
            np.array([[p.alpha], [p.b_aniso], [1.0]], np.float32), (3, pad))
        lp = jnp.concatenate([lp, jnp.asarray(fill)], axis=1)
    return st, sd, sg, bd, lp


# ------------------------------------------------- streaming reduction
# DESIGN.md §14: billion-sample campaigns cannot round-trip dense lane
# planes to the host (32 B/lane for the (8, cells) block).  In streaming
# mode every launch is reduced ON DEVICE to exactly what the surfaces
# need — WER counts per (slice, V, pulse) and a fixed-bin first-crossing
# histogram per (slice, V) — so the host transfer per launch is O(grid
# points), independent of the sample count.  WER counts are *bit-exact*
# by construction: the dense surface compares f64(crossing_step)*dt >
# pulse, and ``_wer_threshold_steps`` precomputes (in f64, on the host)
# the smallest integer step satisfying that per pulse, so the device
# only ever runs an exact integer comparison.  Latency percentiles come
# from the histogram: exact (bit-identical reconstruction of
# np.nanpercentile's linear interpolation) while bins resolve single
# steps, within two bin widths otherwise — the sketch-error budget
# ``CampaignResult.sketch_tolerance`` documents and tests pin.

# WER campaigns record crossing steps in the kernel's f32 row — exact
# integers only below 2**24, which streaming mode relies on for its
# integer compares (dense mode has the same representational limit).
_STREAM_MAX_STEPS = 1 << 24


def _wer_threshold_steps(pulse_widths, dt: float, n_steps: int) -> np.ndarray:
    """Smallest integer step count per pulse with ``f64(k)*dt > pulse`` —
    counting ``crossing_step >= k`` on device then reproduces the dense
    f64 comparison bit-for-bit."""
    out = []
    for pl in pulse_widths:
        k = int(math.ceil(pl / dt))
        while np.float64(k) * dt <= pl:
            k += 1
        while k > 0 and np.float64(k - 1) * dt > pl:
            k -= 1
        assert k <= n_steps, (k, n_steps, pl)   # grid.n_steps covers pulses
        out.append(k)
    return np.asarray(out, np.int32)


def _hist_step_values(n_steps: int, n_bins: int) -> np.ndarray:
    """Lower-edge crossing *step* of every histogram bin (f64).  With
    ``n_bins >= n_steps`` a bin is a single step and reconstruction is
    exact; otherwise bin ``b`` spans steps ``[ceil(b*n_steps/n_bins),
    ceil((b+1)*n_steps/n_bins))`` and its lower edge stands in for every
    sample inside."""
    if n_bins >= n_steps:
        return np.arange(n_bins, dtype=np.float64)
    return np.ceil(np.arange(n_bins, dtype=np.float64) * n_steps / n_bins)


@functools.partial(jax.jit, static_argnames=(
    "n_slices", "slice_cells", "n_v", "n_s", "n_steps", "n_bins"))
def _reduce_rows(out, kmin, *, n_slices: int, slice_cells: int, n_v: int,
                 n_s: int, n_steps: int, n_bins: int):
    """On-device reduction of one launch's crossing row.

    Returns ``(wer_counts, hist)``: int32 ``(n_slices, n_v, n_p)`` counts
    of samples NOT switched by each pulse (exact — see module comment)
    and the int32 ``(n_slices, n_v, n_bins)`` first-crossing histogram
    over *switched* samples.  Only these reduced tensors ever reach the
    host; bucket padding, device-plan padding and never-crossed sentinels
    are all excluded on device."""
    row7 = out[7, : n_slices * slice_cells].reshape(n_slices, slice_cells)
    ki = jnp.minimum(row7[:, : n_v * n_s], float(n_steps)).astype(jnp.int32)
    ki = ki.reshape(n_slices, n_v, n_s)
    wer = (ki[:, :, None, :] >= kmin[None, None, :, None]).sum(
        axis=-1).astype(jnp.int32)
    switched = ki < n_steps
    if n_bins >= n_steps:                       # one bin per step: exact
        bins = ki
    else:
        # f32 scale can misplace a boundary value by one bin — covered by
        # the two-bin sketch_tolerance
        bins = jnp.floor(ki.astype(jnp.float32)
                         * (float(n_bins) / float(n_steps))).astype(jnp.int32)
        bins = jnp.clip(bins, 0, n_bins - 1)
    cell = jnp.arange(n_slices * n_v, dtype=jnp.int32).reshape(
        n_slices, n_v, 1)
    flat = jnp.where(switched, cell * n_bins + bins,
                     n_slices * n_v * n_bins)   # unswitched -> spill bin
    hist = jnp.zeros((n_slices * n_v * n_bins + 1,), jnp.int32
                     ).at[flat.reshape(-1)].add(1)
    return wer, hist[:-1].reshape(n_slices, n_v, n_bins)


def _percentiles_from_hist(hist: np.ndarray, values: np.ndarray,
                           qs) -> np.ndarray:
    """Percentiles over switched samples from per-bin counts — the exact
    linear-interpolation rule ``np.nanpercentile`` applies to the sorted
    dense samples, reconstructed from cumulative counts (the sorted array
    is fully determined by them).  All-unswitched cells report NaN, like
    the dense all-NaN slice."""
    qs = np.asarray(qs, dtype=float)
    flat = hist.reshape(-1, hist.shape[-1])
    out = np.full((flat.shape[0], len(qs)), np.nan)
    for i, h in enumerate(flat):
        n = int(h.sum())
        if n == 0:
            continue
        cum = np.cumsum(h)
        pos = (qs / 100.0) * (n - 1)
        lo = np.floor(pos).astype(int)
        hi = np.ceil(pos).astype(int)
        v_lo = values[np.searchsorted(cum, lo, side="right")]
        v_hi = values[np.searchsorted(cum, hi, side="right")]
        # np.percentile's _lerp flips the anchor at t >= 0.5 (monotonicity
        # fix-up); reproduce it exactly or single-ULP drift breaks the
        # bit-identity claim for per-step bins
        t = pos - lo
        lerp = v_lo + t * (v_hi - v_lo)
        flip = t >= 0.5
        lerp[flip] = v_hi[flip] - (v_hi[flip] - v_lo[flip]) * (1 - t[flip])
        out[i] = lerp
    return out.reshape(hist.shape[:-1] + (len(qs),))


@dataclasses.dataclass(frozen=True)
class EnsembleResult:
    """One thermal ensemble integration (a single campaign tile)."""
    final_state: np.ndarray      # (8, cells) SoA at loop exit
    crossing_steps: np.ndarray   # (cells,) first crossing (== n_steps: none)
    n_steps: int
    dt: float
    elapsed_s: float

    @property
    def crossing_time(self) -> np.ndarray:
        return self.crossing_steps * self.dt

    @property
    def switched(self) -> np.ndarray:
        return self.crossing_steps < self.n_steps


def run_ensemble(
    p: DeviceParams,
    m0: jnp.ndarray,                 # (cells, n_sub, 3) initial states
    voltages: jnp.ndarray,           # (cells,) per-cell drive
    dt: float,
    n_steps: int,
    *,
    seed: int = 0,
    temperature: Optional[float] = None,
    backend: str = "pallas",
    switch_threshold: float = 0.9,
    devices: Optional[int] = None,
    chunk: int = 0,
    lane_params=None,                # optional (3, cells) variation rows
    sigma_lanes=None,                # optional (cells,) per-lane Brown sigma
    horizon: str = "pow2",           # compiled-horizon ladder (chunk > 0)
    donate: bool = False,            # donate the state block to the launch
) -> EnsembleResult:
    """Integrate an arbitrary thermal ensemble through the kernel path.

    The general entry point (used by ``examples/array_mc_sim.py`` for
    per-cell IR-drop voltage maps); ``run_campaign`` packs structured
    (T x V x S) grids on top of the same kernel.  ``temperature=None``
    uses ``p.temperature``; ``temperature=0`` (or alpha/volume making
    sigma 0) zeroes the per-lane thermal field (numerically identical to
    the deterministic kernel).  Single-sublattice devices
    (``p.n_sublattices == 1``, the MTJ baseline) integrate through the
    ``kernels.ref.ref_llg_rk4`` scan — same API, grids and reductions, no
    Pallas kernel (the SoA kernel is dual-sublattice only).

    ``chunk > 0`` turns on chunked early exit: crossing rows are
    bit-identical to the fixed-horizon default, but ``final_state`` then
    holds the at-exit state (lanes stop within one chunk of the last
    crossing) rather than the state after the full horizon — and the
    *compiled* horizon is quantized to a power of two (the per-lane budget
    row stops real lanes at the true ``n_steps``), so callers sweeping
    horizons (write-verify retry rounds) share compiles.

    ``lane_params`` ((3, cells): alpha, B_k, g_scale) switches on the
    kernel's per-lane device-variation plane; ``sigma_lanes`` overrides
    the scalar Brown sigma with a per-lane row (the two usually travel
    together — a varied alpha/volume changes sigma; see
    ``VariationSpec.lane_rows``).

    Never-switched lanes report ``crossing_steps == n_steps`` (so
    ``crossing_time == n_steps*dt``); when thresholding crossings against a
    pulse width, choose ``n_steps`` with ``n_steps*dt`` strictly beyond the
    longest pulse (``CampaignGrid`` does this automatically).
    """
    cells = m0.shape[0]
    state = pack_soa(m0, jnp.asarray(voltages, jnp.float32))
    padded = state.shape[1]
    if sigma_lanes is not None:
        sigma = jnp.pad(jnp.asarray(sigma_lanes, jnp.float32),
                        (0, padded - cells))
    else:
        sigma_t = brown_sigma(p, dt, temperature)
        sigma = jnp.full((padded,), float(sigma_t), jnp.float32)
    budget = jnp.where(jnp.arange(padded) < cells, float(n_steps),
                       0.0).astype(jnp.float32)
    if lane_params is not None:
        lp = np.asarray(lane_params, np.float64)
        assert lp.shape == (3, cells), (lp.shape, cells)
        fill = np.array([[p.alpha], [p.b_aniso], [1.0]])
        lane_params = jnp.asarray(np.concatenate(
            [lp, np.broadcast_to(fill, (3, padded - cells))],
            axis=1).astype(np.float32))
    seeds = noise.cell_seeds(seed, padded)
    n_dev, plan_cols = _device_plan(padded, devices)
    state, seeds, sigma, budget, lane_params = _pad_lanes(
        state, seeds, sigma, budget, lane_params, plan_cols - padded, p)
    n_static = _quantize_steps(n_steps, horizon) if chunk > 0 else n_steps

    t0 = time.time()
    fn = _integrate_donated if donate else _integrate_sharded
    out = fn(
        state, seeds, sigma, budget, lane_params, p=p, dt=dt,
        n_steps=n_static, switch_threshold=float(switch_threshold),
        backend=backend, n_dev=n_dev, chunk=int(chunk))
    out = np.asarray(jax.block_until_ready(out))
    elapsed = time.time() - t0
    return EnsembleResult(
        final_state=out[:, :cells],
        crossing_steps=np.minimum(out[7, :cells].astype(np.float64),
                                  float(n_steps)),
        n_steps=n_steps, dt=dt, elapsed_s=elapsed)


@dataclasses.dataclass(frozen=True)
class CampaignResult:
    """WER / latency surfaces over the (T, V, pulse) axes of a grid — with
    a leading process-corner axis when the grid carries a
    ``VariationSpec`` (``crossing_time`` is then (n_C, n_T, n_V, n_S) and
    every surface reduction grows the same leading axis).

    ``reduced=True`` is the streaming-reduction variant (DESIGN.md §14):
    ``crossing_time`` is None — the dense lane planes never left the
    devices — and the surfaces come from ``wer_counts`` (bit-exact) and
    the ``latency_hist`` sketch (exact while bins resolve single steps,
    within ``sketch_tolerance`` otherwise)."""
    grid: CampaignGrid
    backend: str
    crossing_time: Optional[np.ndarray]  # (n_T, n_V, n_S) s; variation
                                         # grids prepend the corner axis
                                         # (n_C, ...); None when reduced
    elapsed_s: float                 # integration wall-clock (0 on cache hit)
    from_cache: bool = False
    n_launches: int = 1              # kernel launches this result took
    n_resumed: int = 0               # launches restored from slice checkpoints
    reduced: bool = False            # streaming on-device reduction ran
    wer_counts: Optional[np.ndarray] = None    # (..., n_T, n_V, n_P) int64
    latency_hist: Optional[np.ndarray] = None  # (..., n_T, n_V, n_bins)
    hist_values: Optional[np.ndarray] = None   # (n_bins,) bin lower edge [s]
    host_bytes: int = 0              # result bytes transferred device->host
    n_computed: int = 0              # launches integrated by THIS process

    @property
    def n_samples_total(self) -> int:
        if self.crossing_time is not None:
            return int(self.crossing_time.size)
        n_t, n_v, _, n_s = self.grid.shape
        return self.grid.n_corners * n_t * n_v * n_s

    @property
    def sketch_tolerance(self) -> float:
        """Latency-percentile error bound of the streaming sketch [s]: 0
        when bins resolve single steps (the histogram then determines the
        sorted sample array exactly), else two bin widths — one for the
        floor quantization onto bin lower edges, one for the f32 bin-index
        rounding (``_reduce_rows``).  Dense results are exact."""
        if not self.reduced:
            return 0.0
        n_bins = self.latency_hist.shape[-1]
        if n_bins >= self.grid.n_steps:
            return 0.0
        return 2.0 * self.grid.n_steps * self.grid.dt / n_bins

    @property
    def corners(self) -> Optional[Tuple[str, ...]]:
        """Corner names of the leading axis (None for nominal grids)."""
        return (None if self.grid.variation is None
                else self.grid.variation.corner_names)

    def wer_surface(self) -> np.ndarray:
        """(..., n_T, n_V, n_P) write-error rate: fraction of thermal
        samples NOT switched by the end of each pulse width (leading axis =
        process corners for variation grids).  Identical — bit-for-bit —
        between dense and reduced results: the on-device counts use the
        host-precomputed integer thresholds of ``_wer_threshold_steps``,
        and an exact integer count divided by ``n_samples`` in f64 is the
        same number the dense boolean ``.mean`` produces."""
        if self.reduced:
            return (self.wer_counts.astype(np.float64)
                    / np.float64(self.grid.n_samples))
        pulses = np.asarray(self.grid.pulse_widths)
        # crossing_time == n_steps*dt marks "never crossed" and exceeds
        # every pulse in the grid by construction
        ct = self.crossing_time[..., None, :]             # (..., V, 1, S)
        return (ct > pulses[:, None]).mean(axis=-1)

    def wer(self, t_index: int = 0, corner_index: int = 0) -> np.ndarray:
        """(n_V, n_P) slice at one temperature (and corner, if any)."""
        w = self.wer_surface()
        return w[corner_index, t_index] if w.ndim == 4 else w[t_index]

    def latency_percentiles(self, qs: Sequence[float] = (50.0, 99.0)
                            ) -> np.ndarray:
        """(..., n_T, n_V, len(qs)) switching-latency percentiles over
        *switched* samples (NaN where no sample switched; leading corner
        axis for variation grids).  One masked ``np.nanpercentile`` over
        the whole tensor — never-crossed samples become NaN and drop out
        per (T, V) cell."""
        if self.reduced:
            return _percentiles_from_hist(self.latency_hist,
                                          self.hist_values, qs)
        horizon = self.grid.n_steps * self.grid.dt
        ct = np.where(self.crossing_time < horizon, self.crossing_time,
                      np.nan)
        with warnings.catch_warnings():
            # (T, V) cells where nothing switched are *expected* to be NaN
            warnings.filterwarnings("ignore", "All-NaN slice encountered")
            out = np.nanpercentile(ct, np.asarray(qs, dtype=float), axis=-1)
        return np.moveaxis(out, 0, -1)

    def pulse_for_wer(self, wer_target: float, t_index: int = 0,
                      v_index: Optional[int] = None,
                      corner_index: Optional[int] = None) -> float:
        """Smallest grid pulse width whose WER <= target (the write-margin
        query the IMC controller binds against).  ``v_index=None`` (default)
        evaluates at the *lowest* grid voltage — the worst-case drive, so a
        controller pulse sized from the default covers every cell — not at
        whatever voltage happens to be listed last.  On a variation grid,
        ``corner_index=None`` (default) takes the worst corner at every
        pulse — the margined pulse then covers the whole process spread.
        Raises if no grid pulse qualifies — callers must widen the grid
        rather than silently build timing models on a pulse that misses
        the WER target."""
        if v_index is None:
            v_index = int(np.argmin(self.grid.voltages))
        surface = self.wer_surface()
        if surface.ndim == 4:
            surface = (surface.max(axis=0) if corner_index is None
                       else surface[corner_index])
        w = surface[t_index][v_index]
        pulses = np.asarray(self.grid.pulse_widths)
        ok = np.nonzero(w <= wer_target)[0]
        if not ok.size:
            raise ValueError(
                f"no grid pulse meets WER<={wer_target:g} (best WER "
                f"{w.min():.3g} at {pulses[-1]*1e12:.0f} ps); widen "
                "pulse_widths or raise the drive voltage")
        return float(pulses[ok[0]])


def _launch_spans(n_slices: int, slice_cells: int,
                  max_cells: Optional[int]) -> List[Tuple[int, int]]:
    """Group whole temperature slices into launches of <= max_cells lanes
    (one launch when ``max_cells`` is None)."""
    if max_cells is None:
        return [(0, n_slices)]
    per = max(1, int(max_cells) // slice_cells)
    return [(a, min(a + per, n_slices)) for a in range(0, n_slices, per)]


def _slice_key(key: str, a: int, b: int, chunk: int, horizon: str,
               kind: str = "slice-row7") -> str:
    """Content key of one launch span's checkpoint payload (resume
    protocol, DESIGN.md §13): derived from the whole-campaign key plus
    everything that shapes the launch decomposition, so a resume with a
    different split/horizon never matches a stale slice.  ``kind`` keeps
    payload flavors apart — ``"slice-row7"`` is the dense raw crossing
    row (unchanged since PR 9, so existing checkpoints stay resumable);
    streaming launches store ``"slice-reduced-<n_bins>"`` entries."""
    return _cache.content_key({"campaign": key, "span": [int(a), int(b)],
                               "chunk": int(chunk), "horizon": horizon,
                               "kind": kind})


def run_campaign(
    p: DeviceParams,
    grid: CampaignGrid,
    *,
    backend: str = "pallas",
    use_cache: bool = True,
    cache_dir: Optional[str] = None,
    devices: Optional[int] = None,
    chunk: int = EARLY_EXIT_CHUNK,
    max_cells_per_launch: Optional[int] = None,
    horizon: str = "pow2",
    checkpoint: Optional[bool] = None,
    max_retries: int = 2,
    retry_backoff_s: float = 0.25,
    on_slice_complete=None,
    reduce: str = "dense",
    n_bins: int = 512,
    donate: bool = False,
    mesh=None,
) -> CampaignResult:
    """Run (or cache-load) a full Monte-Carlo campaign.

    The whole (temperature x voltage x sample) grid rides the packed cells
    plane of **one** kernel launch (per-lane sigma carries the temperature
    axis); pulse width is post-processing.  ``backend`` is "pallas"
    (production) or "ref" (pure-jnp oracle — same noise streams, used for
    parity checks and throughput baselines).

    ``chunk`` sets the early-exit granularity (0 disables early exit and
    step quantization — the exact fixed-horizon launch); ``horizon``
    selects the compiled-horizon ladder ("pow2" default, "log" for
    decade-spanning retention sweeps — see ``_quantize_steps``).  Crossing
    rows are ladder-independent (the budget row stops real lanes at the
    true horizon), so results cache under the same key.  Campaigns larger
    than ``max_cells_per_launch`` lanes split along (corner x temperature)
    slice boundaries into multiple launches, all dispatched before the
    first device sync, so transfers overlap integration.

    With ``grid.variation`` set, the process-corner axis fuses into the
    cells plane too (DESIGN.md §9): per-lane device-parameter rows ride
    the kernel's variation plane, the whole (corner x T x V x S) grid is
    still one launch, and the returned ``crossing_time`` grows a leading
    corner axis.  Single-launch variation campaigns additionally pad the
    *total* plane to a power-of-two bucket, so the corner count enters
    the compile key only through that logarithmic bucket.

    Crash resume (DESIGN.md §13): multi-launch campaigns checkpoint each
    completed launch's raw crossing row through the content-keyed cache
    (``checkpoint=None`` means "on whenever caching is on and there is
    more than one launch"), so a killed process re-runs only the launches
    it never finished — and because the stored row is the kernel's f32
    output verbatim, the resumed assembly is bit-identical to an
    uninterrupted run.  Slice checkpoints are retired once the
    whole-campaign entry is durable.  A launch that fails to dispatch or
    sync is retried up to ``max_retries`` times with exponential backoff
    (``retry_backoff_s`` base).  ``on_slice_complete(i, n_launches)`` fires
    after each freshly-integrated launch is checkpointed — the hook the
    kill/resume tests use to die at a deterministic point.

    Scaling knobs (DESIGN.md §14):

    * ``reduce="stream"`` turns on the streaming on-device reduction: each
      launch is reduced to WER counts + a first-crossing histogram on the
      devices (``_reduce_rows``) and only those O(grid-points) tensors
      reach the host — ``CampaignResult.reduced`` is then True, WER
      surfaces are bit-identical to dense mode and latency percentiles are
      within ``sketch_tolerance`` (exact when ``n_bins >= grid.n_steps``).
      Streaming results cache under their own derived key, so dense and
      reduced entries never shadow each other.
    * ``donate=True`` routes launches through ``_integrate_donated``: the
      (8, cells) state block is donated to XLA, halving peak device
      residency across retry rounds (write-verify schedules).  A retry
      whose donated input was consumed re-packs the block from the grid's
      deterministic draws.  Donated runs are deterministic and
      statistically identical, but the alias-constrained executable may
      round rare lanes' crossings one step differently than the default
      compile (see ``_integrate_donated``) — keep the default for
      bit-exactness pins.
    * ``mesh`` (a ``launch.mesh.CampaignMesh``) scales past one process:
      ``mesh.n_devices`` shards each launch's cells plane, and with
      ``mesh.process_count > 1`` whole launches are partitioned across
      processes through the content-addressed store — each process claims
      launches lockless-ly (``cache.try_claim``), polls peers' slice
      checkpoints, and steals claims older than ``mesh.claim_ttl_s`` from
      dead peers.  Requires ``use_cache`` (the store is the rendezvous);
      every process returns the identical assembled result.
    """
    assert backend in ("pallas", "ref"), backend
    assert reduce in ("dense", "stream"), reduce
    streaming = reduce == "stream"
    if mesh is not None:
        devices = mesh.n_devices
    multi = mesh is not None and mesh.process_count > 1
    spec = grid.variation
    n_t, n_v, n_p, n_s = grid.shape
    n_c = grid.n_corners
    expect_shape = ((n_c, n_t, n_v, n_s) if spec is not None
                    else (n_t, n_v, n_s))
    key = _cache.campaign_key(p, grid, backend)
    n_steps = grid.n_steps
    if streaming:
        assert int(n_bins) >= 1, n_bins
        assert n_steps <= _STREAM_MAX_STEPS, (
            "streaming WER relies on exact integer steps in the kernel's "
            f"f32 crossing row: n_steps={n_steps} > {_STREAM_MAX_STEPS}")
        # streaming entries live under their own derived key: the payload
        # is a different tensor family (counts + histogram, n_bins-shaped)
        # and must never shadow — or be shadowed by — a dense entry
        red_key = _cache.content_key({"campaign": key, "kind": "reduced",
                                      "n_bins": int(n_bins), "v": 1})
        lead = (n_c, n_t) if spec is not None else (n_t,)
        expect_wer = lead + (n_v, n_p)
        expect_hist = lead + (n_v, int(n_bins))
        hist_values = _hist_step_values(n_steps, int(n_bins)) * grid.dt
        kmin_dev = jnp.asarray(
            _wer_threshold_steps(grid.pulse_widths, grid.dt, n_steps))

        def _reduced_result(wer, hist, **kw):
            return CampaignResult(
                grid=grid, backend=backend, crossing_time=None,
                reduced=True, wer_counts=np.asarray(wer).astype(np.int64),
                latency_hist=np.asarray(hist), hist_values=hist_values,
                **kw)

    def _load_whole():
        """This mode's durable whole-campaign entry, or None on miss."""
        if streaming:
            hit = _cache.load_arrays(red_key, cache_dir)
            if (hit is not None and "wer" in hit and "hist" in hit
                    and hit["wer"].shape == expect_wer
                    and hit["hist"].shape == expect_hist):
                return hit
            return None
        hit = _cache.load(key, cache_dir)
        return hit if (hit is not None and hit.shape == expect_shape) else None

    if use_cache:
        whole = _load_whole()
        if whole is not None:
            if streaming:
                return _reduced_result(whole["wer"], whole["hist"],
                                       elapsed_s=0.0, from_cache=True,
                                       n_launches=0)
            return CampaignResult(grid=grid, backend=backend,
                                  crossing_time=whole, elapsed_s=0.0,
                                  from_cache=True, n_launches=0)

    n_static = _quantize_steps(n_steps, horizon) if chunk > 0 else n_steps

    def _pack_inputs():
        """(Re-)pack the campaign's device inputs — once up front, and
        again when a donated launch consumed the block before a retry
        (the draws are deterministic, so a rebuilt block is bit-identical
        to the consumed one)."""
        if spec is None:
            st, sd, sg, bd, sp = pack_campaign(grid, p)
            lp = None
        else:
            st, sd, sg, bd, lp, sp = pack_variation(grid, p)
        return st, sd, sg, bd, lp, sp

    def _bucket_pad(st, sd, sg, bd, lp):
        # total-plane pow2 bucket: corner count reaches the compile key
        # only through this logarithmic bucket (3 vs 4 corners usually
        # share a compiled shape; pinned by tests/test_variation.py)
        from repro.campaign.grid import bucket_cells
        total = st.shape[1]
        pad = bucket_cells(total) - total
        if pad:
            st = jnp.pad(st, ((0, 0), (0, pad)))
            sd = jnp.pad(sd, (0, pad))
            sg = jnp.pad(sg, (0, pad))
            bd = jnp.pad(bd, (0, pad))
            fill = np.broadcast_to(
                np.array([[p.alpha], [p.b_aniso], [1.0]], np.float32),
                (3, pad))
            lp = jnp.concatenate([lp, jnp.asarray(fill)], axis=1)
        return st, sd, sg, bd, lp

    state, seeds, sigma, budget, lane_params, spans = _pack_inputs()
    n_slices = n_c * n_t
    slice_cells = state.shape[1] // n_slices
    launches = _launch_spans(n_slices, slice_cells, max_cells_per_launch)
    single_variation = spec is not None and len(launches) == 1
    if single_variation:
        state, seeds, sigma, budget, lane_params = _bucket_pad(
            state, seeds, sigma, budget, lane_params)
        launches = [(0, n_slices)]

    ckpt = ((use_cache and len(launches) > 1) if checkpoint is None
            else bool(checkpoint))
    if multi:
        assert use_cache, ("multi-process campaigns rendezvous through the "
                           "content-addressed store; use_cache=False has "
                           "no channel to exchange slices")
        ckpt = True                # slice entries ARE the exchange channel
    skind = f"slice-reduced-{int(n_bins)}" if streaming else "slice-row7"

    def span_cols(a: int, b: int) -> Tuple[int, int]:
        c0, c1 = a * slice_cells, b * slice_cells
        if single_variation:
            c1 = state.shape[1]              # include the total-bucket pad
        return c0, c1

    def dispatch(a: int, b: int):
        c0, c1 = span_cols(a, b)
        n_dev, plan_cols = _device_plan(c1 - c0, devices)
        st, sd, sg, bd, lp = _pad_lanes(
            state[:, c0:c1], seeds[c0:c1], sigma[c0:c1], budget[c0:c1],
            None if lane_params is None else lane_params[:, c0:c1],
            plan_cols - (c1 - c0), p)
        fn = _integrate_donated if donate else _integrate_sharded
        out = fn(st, sd, sg, bd, lp, p=p, dt=grid.dt, n_steps=n_static,
                 switch_threshold=float(grid.switch_threshold),
                 backend=backend, n_dev=n_dev, chunk=int(chunk))
        if not streaming:
            return out
        return _reduce_rows(out, kmin_dev, n_slices=b - a,
                            slice_cells=slice_cells, n_v=n_v, n_s=n_s,
                            n_steps=n_steps, n_bins=int(n_bins))

    host_bytes = 0
    n_computed = 0

    def _fetch(out, a: int, b: int) -> Dict[str, np.ndarray]:
        """Sync one launch and pull its payload to host — the ONLY
        device-to-host transfer of the campaign, which ``host_bytes``
        meters (dense: the full (8, cells) block; streaming: the reduced
        counts + histogram, O(grid points))."""
        nonlocal host_bytes
        c0, c1 = span_cols(a, b)
        if streaming:
            wer_d, hist_d = out
            wer = np.asarray(jax.block_until_ready(wer_d))
            hist = np.asarray(jax.block_until_ready(hist_d))
            host_bytes += wer.nbytes + hist.nbytes
            return {"wer": wer, "hist": hist}
        blk = np.asarray(jax.block_until_ready(out))
        host_bytes += blk.nbytes
        return {"row7": blk[7][: c1 - c0]}   # trim any device-plan pad

    def _payload_ok(hit, a: int, b: int) -> bool:
        if hit is None:
            return False
        if streaming:
            return ("wer" in hit and "hist" in hit
                    and hit["wer"].shape == (b - a, n_v, n_p)
                    and hit["hist"].shape == (b - a, n_v, int(n_bins)))
        c0, c1 = span_cols(a, b)
        return "row7" in hit and hit["row7"].shape == (c1 - c0,)

    def _store_slice(a: int, b: int, payload) -> None:
        _cache.store_arrays(
            _slice_key(key, a, b, chunk, horizon, skind), payload,
            header={"campaign": key, "span": [int(a), int(b)],
                    "kind": skind},
            cache_dir=cache_dir)

    def _compute(a: int, b: int, out=None) -> Dict[str, np.ndarray]:
        """Dispatch (if not already in flight) + sync one launch, with the
        retry ladder.  Donation can have consumed the packed inputs by the
        time a retry needs them — detected via ``is_deleted`` and repaired
        by re-packing (bit-identical by construction)."""
        nonlocal state, seeds, sigma, budget, lane_params, n_computed
        attempt = 0
        while True:
            try:
                if out is None:
                    if donate and state.is_deleted():
                        state, seeds, sigma, budget, lane_params, _ = (
                            _pack_inputs())
                        if single_variation:
                            state, seeds, sigma, budget, lane_params = (
                                _bucket_pad(state, seeds, sigma, budget,
                                            lane_params))
                    out = dispatch(a, b)
                payload = _fetch(out, a, b)
                n_computed += 1
                return payload
            except Exception:
                out = None
                if attempt >= max_retries:
                    raise
                time.sleep(retry_backoff_s * (2.0 ** attempt))
                attempt += 1

    t0 = time.time()
    payloads: List[Optional[Dict[str, np.ndarray]]] = [None] * len(launches)
    n_resumed = 0
    whole = None

    if not multi:
        # dispatch every launch before syncing on any of them: jax dispatch
        # is async, so device compute and D2H transfers pipeline across
        # launches.  Checkpointed launches restore their stored payload
        # instead of dispatching at all; a failed dispatch is deferred to
        # the sync loop's retry ladder rather than aborting the other
        # launches' overlap.
        outs: List[Optional[object]] = [None] * len(launches)
        for i, (a, b) in enumerate(launches):
            if ckpt:
                hit = _cache.load_arrays(
                    _slice_key(key, a, b, chunk, horizon, skind), cache_dir)
                if _payload_ok(hit, a, b):
                    payloads[i] = hit
                    n_resumed += 1
                    continue
            try:
                outs[i] = dispatch(a, b)
            except Exception:                # retried in the sync loop
                outs[i] = None
        for i, (a, b) in enumerate(launches):
            if payloads[i] is not None:
                continue
            payloads[i] = _compute(a, b, out=outs[i])
            if ckpt:
                _store_slice(a, b, payloads[i])
            if on_slice_complete is not None:
                on_slice_complete(i, len(launches))
    else:
        owner = f"proc{mesh.process_index}"
        skeys = [_slice_key(key, a, b, chunk, horizon, skind)
                 for a, b in launches]

        def _claim_and_run(i: int) -> None:
            # holding the claim, re-check the whole-campaign entry: a peer
            # that already assembled retires the slice checkpoints, and
            # retirement is strictly ordered AFTER its whole store — so a
            # vanished slice is always covered by this check and a launch
            # is never integrated twice (absent a TTL steal)
            nonlocal whole
            whole = _load_whole()
            if whole is not None:
                _cache.release_claim(skeys[i], cache_dir)
                return
            a, b = launches[i]
            try:
                payload = _compute(a, b)
            except Exception:
                _cache.release_claim(skeys[i], cache_dir)
                raise
            _store_slice(a, b, payload)
            _cache.release_claim(skeys[i], cache_dir)
            payloads[i] = payload
            if on_slice_complete is not None:
                on_slice_complete(i, len(launches))

        # pass A: each process walks the launch ring from its own offset,
        # claiming and integrating whatever no peer has started — with P
        # processes over L launches the fleet first-touches disjoint arcs,
        # so claims rarely collide and work splits ~L/P per process.
        start = (len(launches) * mesh.process_index) // mesh.process_count
        for j in range(len(launches)):
            if whole is not None:
                break
            i = (start + j) % len(launches)
            a, b = launches[i]
            hit = _cache.load_arrays(skeys[i], cache_dir)
            if _payload_ok(hit, a, b):
                payloads[i] = hit
                n_resumed += 1
            elif _cache.try_claim(skeys[i], cache_dir, owner=owner):
                _claim_and_run(i)

        # pass B: poll the store for peers' slices; steal claims older
        # than the mesh TTL (dead peer — the store's atomicity makes a
        # double-compute after a steal wasteful, never wrong); bail to the
        # whole-campaign entry if a peer already assembled and retired the
        # slice checkpoints (the retirement race, DESIGN.md §14).
        deadline = time.time() + max(10.0 * mesh.claim_ttl_s, 30.0)
        while whole is None and any(pl is None for pl in payloads):
            whole = _load_whole()
            if whole is not None:
                break
            for i, (a, b) in enumerate(launches):
                if whole is not None or payloads[i] is not None:
                    continue
                hit = _cache.load_arrays(skeys[i], cache_dir)
                if _payload_ok(hit, a, b):
                    payloads[i] = hit
                    n_resumed += 1
                elif _cache.claim_age_s(skeys[i], cache_dir) is None:
                    if _cache.try_claim(skeys[i], cache_dir, owner=owner):
                        _claim_and_run(i)
                elif _cache.steal_claim(skeys[i], mesh.claim_ttl_s,
                                        cache_dir, owner=owner):
                    _claim_and_run(i)
            if whole is None and any(pl is None for pl in payloads):
                if time.time() > deadline:
                    raise RuntimeError(
                        f"campaign {key[:12]}: timed out waiting on peer "
                        f"slices (ttl {mesh.claim_ttl_s}s)")
                time.sleep(mesh.poll_s)
    elapsed = time.time() - t0

    if whole is not None:
        # a peer won the assembly; adopt its durable entry verbatim
        common = dict(elapsed_s=elapsed, from_cache=True,
                      n_launches=len(launches), n_resumed=n_resumed,
                      host_bytes=host_bytes, n_computed=n_computed)
        if streaming:
            return _reduced_result(whole["wer"], whole["hist"], **common)
        return CampaignResult(grid=grid, backend=backend,
                              crossing_time=whole, **common)

    if streaming:
        wer_cat = np.concatenate([pl["wer"] for pl in payloads])
        hist_cat = np.concatenate([pl["hist"] for pl in payloads])
        if spec is not None:
            wer_cat = wer_cat.reshape(n_c, n_t, n_v, n_p)
            hist_cat = hist_cat.reshape(n_c, n_t, n_v, int(n_bins))
        if use_cache:
            _cache.store_arrays(
                red_key, {"wer": wer_cat, "hist": hist_cat},
                header={"campaign": key, "kind": "reduced",
                        "n_bins": int(n_bins), "backend": backend},
                cache_dir=cache_dir)
        if ckpt:
            for a, b in launches:
                _cache.drop_arrays(
                    _slice_key(key, a, b, chunk, horizon, skind), cache_dir)
        return _reduced_result(wer_cat, hist_cat, elapsed_s=elapsed,
                               n_launches=len(launches),
                               n_resumed=n_resumed, host_bytes=host_bytes,
                               n_computed=n_computed)

    # clip the quantized-horizon sentinel (n_static) back to the grid's
    # horizon: real crossings are <= budget == n_steps and pass unchanged.
    # float64 before the dt multiply — in f32 the sentinel n_steps*dt
    # rounds below the f64 horizon and never-crossed lanes would leak into
    # the switched-only latency reductions
    row7 = np.minimum(
        np.concatenate([pl["row7"] for pl in payloads]).astype(np.float64),
        float(n_steps))
    crossing = np.empty(expect_shape)
    for si, (lo, hi) in enumerate(spans):
        plane = row7[lo:hi].reshape(n_v, n_s) * grid.dt
        if spec is None:
            crossing[si] = plane
        else:
            crossing[si // n_t, si % n_t] = plane

    if use_cache:
        _cache.store(key, crossing,
                     header={"params": dataclasses.asdict(p),
                             "grid": dataclasses.asdict(grid),
                             "backend": backend},
                     cache_dir=cache_dir)
    if ckpt:
        # the whole-campaign entry is durable (or caching is off and the
        # result is in hand) — retire the per-slice resume checkpoints
        for a, b in launches:
            _cache.drop_arrays(_slice_key(key, a, b, chunk, horizon, skind),
                               cache_dir)
    return CampaignResult(grid=grid, backend=backend, crossing_time=crossing,
                          elapsed_s=elapsed, n_launches=len(launches),
                          n_resumed=n_resumed, host_bytes=host_bytes,
                          n_computed=n_computed)

"""Monte-Carlo campaign engine: one kernel launch for the whole campaign.

Replaces the per-sample host-visible scan in ``core.montecarlo`` (O(steps)
XLA while-loop per sample, threefry keys split per step) with the Pallas
thermal LLG kernel — and packs *every* campaign axis that isn't pure
post-processing into the kernel's cells plane:

* voltage x sample ride the lanes (PR 1);
* pulse width falls out of the recorded first-crossing steps (PR 1);
* temperature rides the lanes too: Brown's sigma is a per-lane kernel
  input (aux plane), so a (T x V x S) grid is **one launch, one compile**
  instead of a host-level loop with one sigma-specialized recompile per
  temperature (``grid.pack_campaign``);
* process corners ride the lanes as well (DESIGN.md §9): per-lane
  alpha / B_k / conductance-factor rows on the kernel's variation plane
  make a (corner x T x V x S) grid one launch too, with corner count and
  values as pure data (``grid.pack_variation``).

No wasted steps either: the kernel integrates in chunks and exits a tile
as soon as every lane has crossed or exhausted its per-lane step budget
(``EARLY_EXIT_CHUNK``), and the compiled horizon is quantized to a power
of two (``_quantize_steps``) so campaigns with different pulse ladders
share compiles — the per-lane budget row stops the integration at the
*true* horizon, and crossing rows stay bit-identical to a fixed-horizon
run (``tests/test_fused_engine.py`` pins this).

Scaling: the cells axis is embarrassingly parallel, so the engine shards
cell tiles across every visible device with ``shard_map`` — each device
integrates its own ``cells / n_dev`` lanes (a multiple of the kernel's
CELL_TILE), no cross-device communication at all.  Launches above
``max_cells_per_launch`` split along temperature-slice boundaries and are
all dispatched asynchronously before the first ``block_until_ready`` —
the host never serializes device work against transfers.  Results are
reduced host-side into WER / latency-percentile surfaces and cached on
disk (``cache.py``) keyed by the full campaign content hash.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.campaign import cache as _cache
from repro.campaign.grid import (CampaignGrid, log_horizon_bucket, next_pow2,
                                 pack_campaign, pack_soa, pack_variation)
from repro.core.montecarlo import thermal_sigma
from repro.core.params import DeviceParams
from repro.kernels import noise, ref
from repro.kernels.llg_rk4 import CELL_TILE, llg_rk4_pallas
from repro.kernels.ops import _default_interpret

# Early-exit granularity [steps]: the kernel checks "is every lane done?"
# once per chunk.  Small enough that a finished tile wastes < chunk steps,
# large enough that the all-lane reduction is noise next to the ~60
# flops/step/lane RK4 body.
EARLY_EXIT_CHUNK = 64


def brown_sigma(p: DeviceParams, dt: float, temperature: Optional[float] = None
                ) -> float:
    """Brown's thermal-field std per component per step [T] — canonical
    formula lives in ``core.montecarlo.thermal_sigma``."""
    if temperature is not None and temperature != p.temperature:
        p = dataclasses.replace(p, temperature=float(temperature))
    return thermal_sigma(p, dt)


def _quantize_steps(n_steps: int, horizon: str = "pow2") -> int:
    """Round the compiled horizon up to a shared rung.

    The per-lane step-budget row stops every lane at the *true* horizon,
    and the chunked loop exits a tile within one chunk of its slowest
    lane's budget — so the masked tail costs ~nothing at runtime while
    campaigns over different pulse ladders (write-verify sweeps, margin
    ladders) land on a logarithmic number of compiled step counts.

    ``horizon`` picks the ladder: ``"pow2"`` (default — every existing
    write-path compile pin) or ``"log"`` — the geometric
    ``grid.log_horizon_bucket`` ladder, ~2 rungs per decade, for retention
    campaigns whose horizons span decades (DESIGN.md §10).
    """
    if horizon == "log":
        return log_horizon_bucket(n_steps)
    assert horizon == "pow2", horizon
    return next_pow2(n_steps)


@functools.partial(jax.jit, static_argnames=(
    "p", "dt", "n_steps", "switch_threshold", "backend", "n_dev", "chunk"))
def _integrate_sharded(state, seeds, sigma, budget, lane_params=None, *,
                       p: DeviceParams, dt: float, n_steps: int,
                       switch_threshold: float, backend: str, n_dev: int,
                       chunk: int):
    """Advance a (8, cells) block on ``n_dev`` devices (cells sharded).

    Everything that varies *within* a campaign — or between retry rounds
    of a write-verify schedule — is traced data: per-lane Brown sigma,
    per-lane step budgets, per-lane RNG stream seeds, initial states,
    drive voltages, and (variation campaigns, DESIGN.md §9) the per-lane
    device-parameter rows ``lane_params`` — so process-corner count and
    values never recompile.  The only compile keys left are the nominal
    device physics ``p``, the step size, the (quantized) horizon, the
    launch shape (bucketed by ``grid.bucket_cells``), and whether the
    variation plane is present at all.
    """

    def tile_fn(st, sd, sg, bd, lp=None):
        # the SoA Pallas kernel is dual-sublattice by construction
        # (staggered Neel STT); single-sublattice FM/MTJ devices integrate
        # the same production physics through the oracle's lane-vectorized
        # scan — same grids, padding, RNG streams, first-crossing row 7
        if p.n_sublattices == 1 or backend == "ref":
            return ref.ref_llg_rk4(st, p, dt, n_steps, switch_threshold,
                                   thermal_sigma=sg, seeds=sd,
                                   step_budget=bd, chunk=chunk,
                                   lane_params=lp)
        return llg_rk4_pallas(st, p, dt, n_steps, switch_threshold,
                              interpret=_default_interpret(),
                              thermal_sigma=sg, seeds=sd,
                              step_budget=bd, chunk=chunk, lane_params=lp)

    if n_dev == 1:
        return tile_fn(state, seeds, sigma, budget, lane_params)
    mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("cells",))
    # check_rep=False: shard_map has no replication rule for pallas_call;
    # every output is fully sharded along cells anyway
    specs = (P(None, "cells"), P("cells"), P("cells"), P("cells"))
    if lane_params is None:
        fn = shard_map(tile_fn, mesh=mesh, in_specs=specs,
                       out_specs=P(None, "cells"), check_rep=False)
        return fn(state, seeds, sigma, budget)
    fn = shard_map(tile_fn, mesh=mesh, in_specs=specs + (P(None, "cells"),),
                   out_specs=P(None, "cells"), check_rep=False)
    return fn(state, seeds, sigma, budget, lane_params)


def _usable_devices(cells_padded: int, devices: Optional[int]) -> int:
    """Largest device count (<= requested/visible) whose per-shard slice is
    a whole number of CELL_TILE tiles."""
    n = jax.device_count() if devices is None else min(devices, jax.device_count())
    tiles = cells_padded // CELL_TILE
    while n > 1 and tiles % n != 0:
        n -= 1
    return max(n, 1)


@dataclasses.dataclass(frozen=True)
class EnsembleResult:
    """One thermal ensemble integration (a single campaign tile)."""
    final_state: np.ndarray      # (8, cells) SoA at loop exit
    crossing_steps: np.ndarray   # (cells,) first crossing (== n_steps: none)
    n_steps: int
    dt: float
    elapsed_s: float

    @property
    def crossing_time(self) -> np.ndarray:
        return self.crossing_steps * self.dt

    @property
    def switched(self) -> np.ndarray:
        return self.crossing_steps < self.n_steps


def run_ensemble(
    p: DeviceParams,
    m0: jnp.ndarray,                 # (cells, n_sub, 3) initial states
    voltages: jnp.ndarray,           # (cells,) per-cell drive
    dt: float,
    n_steps: int,
    *,
    seed: int = 0,
    temperature: Optional[float] = None,
    backend: str = "pallas",
    switch_threshold: float = 0.9,
    devices: Optional[int] = None,
    chunk: int = 0,
    lane_params=None,                # optional (3, cells) variation rows
    sigma_lanes=None,                # optional (cells,) per-lane Brown sigma
    horizon: str = "pow2",           # compiled-horizon ladder (chunk > 0)
) -> EnsembleResult:
    """Integrate an arbitrary thermal ensemble through the kernel path.

    The general entry point (used by ``examples/array_mc_sim.py`` for
    per-cell IR-drop voltage maps); ``run_campaign`` packs structured
    (T x V x S) grids on top of the same kernel.  ``temperature=None``
    uses ``p.temperature``; ``temperature=0`` (or alpha/volume making
    sigma 0) zeroes the per-lane thermal field (numerically identical to
    the deterministic kernel).  Single-sublattice devices
    (``p.n_sublattices == 1``, the MTJ baseline) integrate through the
    ``kernels.ref.ref_llg_rk4`` scan — same API, grids and reductions, no
    Pallas kernel (the SoA kernel is dual-sublattice only).

    ``chunk > 0`` turns on chunked early exit: crossing rows are
    bit-identical to the fixed-horizon default, but ``final_state`` then
    holds the at-exit state (lanes stop within one chunk of the last
    crossing) rather than the state after the full horizon — and the
    *compiled* horizon is quantized to a power of two (the per-lane budget
    row stops real lanes at the true ``n_steps``), so callers sweeping
    horizons (write-verify retry rounds) share compiles.

    ``lane_params`` ((3, cells): alpha, B_k, g_scale) switches on the
    kernel's per-lane device-variation plane; ``sigma_lanes`` overrides
    the scalar Brown sigma with a per-lane row (the two usually travel
    together — a varied alpha/volume changes sigma; see
    ``VariationSpec.lane_rows``).

    Never-switched lanes report ``crossing_steps == n_steps`` (so
    ``crossing_time == n_steps*dt``); when thresholding crossings against a
    pulse width, choose ``n_steps`` with ``n_steps*dt`` strictly beyond the
    longest pulse (``CampaignGrid`` does this automatically).
    """
    cells = m0.shape[0]
    state = pack_soa(m0, jnp.asarray(voltages, jnp.float32))
    padded = state.shape[1]
    if sigma_lanes is not None:
        sigma = jnp.pad(jnp.asarray(sigma_lanes, jnp.float32),
                        (0, padded - cells))
    else:
        sigma_t = brown_sigma(p, dt, temperature)
        sigma = jnp.full((padded,), float(sigma_t), jnp.float32)
    budget = jnp.where(jnp.arange(padded) < cells, float(n_steps),
                       0.0).astype(jnp.float32)
    if lane_params is not None:
        lp = np.asarray(lane_params, np.float64)
        assert lp.shape == (3, cells), (lp.shape, cells)
        fill = np.array([[p.alpha], [p.b_aniso], [1.0]])
        lane_params = jnp.asarray(np.concatenate(
            [lp, np.broadcast_to(fill, (3, padded - cells))],
            axis=1).astype(np.float32))
    seeds = noise.cell_seeds(seed, padded)
    n_dev = _usable_devices(padded, devices)
    n_static = _quantize_steps(n_steps, horizon) if chunk > 0 else n_steps

    t0 = time.time()
    out = _integrate_sharded(
        state, seeds, sigma, budget, lane_params, p=p, dt=dt,
        n_steps=n_static, switch_threshold=float(switch_threshold),
        backend=backend, n_dev=n_dev, chunk=int(chunk))
    out = np.asarray(jax.block_until_ready(out))
    elapsed = time.time() - t0
    return EnsembleResult(
        final_state=out[:, :cells],
        crossing_steps=np.minimum(out[7, :cells].astype(np.float64),
                                  float(n_steps)),
        n_steps=n_steps, dt=dt, elapsed_s=elapsed)


@dataclasses.dataclass(frozen=True)
class CampaignResult:
    """WER / latency surfaces over the (T, V, pulse) axes of a grid — with
    a leading process-corner axis when the grid carries a
    ``VariationSpec`` (``crossing_time`` is then (n_C, n_T, n_V, n_S) and
    every surface reduction grows the same leading axis)."""
    grid: CampaignGrid
    backend: str
    crossing_time: np.ndarray        # (n_T, n_V, n_S) s; variation grids
                                     # prepend the corner axis (n_C, ...)
    elapsed_s: float                 # integration wall-clock (0 on cache hit)
    from_cache: bool = False
    n_launches: int = 1              # kernel launches this result took
    n_resumed: int = 0               # launches restored from slice checkpoints

    @property
    def n_samples_total(self) -> int:
        return int(self.crossing_time.size)

    @property
    def corners(self) -> Optional[Tuple[str, ...]]:
        """Corner names of the leading axis (None for nominal grids)."""
        return (None if self.grid.variation is None
                else self.grid.variation.corner_names)

    def wer_surface(self) -> np.ndarray:
        """(..., n_T, n_V, n_P) write-error rate: fraction of thermal
        samples NOT switched by the end of each pulse width (leading axis =
        process corners for variation grids)."""
        pulses = np.asarray(self.grid.pulse_widths)
        # crossing_time == n_steps*dt marks "never crossed" and exceeds
        # every pulse in the grid by construction
        ct = self.crossing_time[..., None, :]             # (..., V, 1, S)
        return (ct > pulses[:, None]).mean(axis=-1)

    def wer(self, t_index: int = 0, corner_index: int = 0) -> np.ndarray:
        """(n_V, n_P) slice at one temperature (and corner, if any)."""
        w = self.wer_surface()
        return w[corner_index, t_index] if w.ndim == 4 else w[t_index]

    def latency_percentiles(self, qs: Sequence[float] = (50.0, 99.0)
                            ) -> np.ndarray:
        """(..., n_T, n_V, len(qs)) switching-latency percentiles over
        *switched* samples (NaN where no sample switched; leading corner
        axis for variation grids).  One masked ``np.nanpercentile`` over
        the whole tensor — never-crossed samples become NaN and drop out
        per (T, V) cell."""
        horizon = self.grid.n_steps * self.grid.dt
        ct = np.where(self.crossing_time < horizon, self.crossing_time,
                      np.nan)
        with warnings.catch_warnings():
            # (T, V) cells where nothing switched are *expected* to be NaN
            warnings.filterwarnings("ignore", "All-NaN slice encountered")
            out = np.nanpercentile(ct, np.asarray(qs, dtype=float), axis=-1)
        return np.moveaxis(out, 0, -1)

    def pulse_for_wer(self, wer_target: float, t_index: int = 0,
                      v_index: Optional[int] = None,
                      corner_index: Optional[int] = None) -> float:
        """Smallest grid pulse width whose WER <= target (the write-margin
        query the IMC controller binds against).  ``v_index=None`` (default)
        evaluates at the *lowest* grid voltage — the worst-case drive, so a
        controller pulse sized from the default covers every cell — not at
        whatever voltage happens to be listed last.  On a variation grid,
        ``corner_index=None`` (default) takes the worst corner at every
        pulse — the margined pulse then covers the whole process spread.
        Raises if no grid pulse qualifies — callers must widen the grid
        rather than silently build timing models on a pulse that misses
        the WER target."""
        if v_index is None:
            v_index = int(np.argmin(self.grid.voltages))
        surface = self.wer_surface()
        if surface.ndim == 4:
            surface = (surface.max(axis=0) if corner_index is None
                       else surface[corner_index])
        w = surface[t_index][v_index]
        pulses = np.asarray(self.grid.pulse_widths)
        ok = np.nonzero(w <= wer_target)[0]
        if not ok.size:
            raise ValueError(
                f"no grid pulse meets WER<={wer_target:g} (best WER "
                f"{w.min():.3g} at {pulses[-1]*1e12:.0f} ps); widen "
                "pulse_widths or raise the drive voltage")
        return float(pulses[ok[0]])


def _launch_spans(n_slices: int, slice_cells: int,
                  max_cells: Optional[int]) -> List[Tuple[int, int]]:
    """Group whole temperature slices into launches of <= max_cells lanes
    (one launch when ``max_cells`` is None)."""
    if max_cells is None:
        return [(0, n_slices)]
    per = max(1, int(max_cells) // slice_cells)
    return [(a, min(a + per, n_slices)) for a in range(0, n_slices, per)]


def _slice_key(key: str, a: int, b: int, chunk: int, horizon: str) -> str:
    """Content key of one launch span's raw crossing row (resume protocol,
    DESIGN.md §13): derived from the whole-campaign key plus everything
    that shapes the launch decomposition, so a resume with a different
    split/horizon never matches a stale slice."""
    return _cache.content_key({"campaign": key, "span": [int(a), int(b)],
                               "chunk": int(chunk), "horizon": horizon,
                               "kind": "slice-row7"})


def run_campaign(
    p: DeviceParams,
    grid: CampaignGrid,
    *,
    backend: str = "pallas",
    use_cache: bool = True,
    cache_dir: Optional[str] = None,
    devices: Optional[int] = None,
    chunk: int = EARLY_EXIT_CHUNK,
    max_cells_per_launch: Optional[int] = None,
    horizon: str = "pow2",
    checkpoint: Optional[bool] = None,
    max_retries: int = 2,
    retry_backoff_s: float = 0.25,
    on_slice_complete=None,
) -> CampaignResult:
    """Run (or cache-load) a full Monte-Carlo campaign.

    The whole (temperature x voltage x sample) grid rides the packed cells
    plane of **one** kernel launch (per-lane sigma carries the temperature
    axis); pulse width is post-processing.  ``backend`` is "pallas"
    (production) or "ref" (pure-jnp oracle — same noise streams, used for
    parity checks and throughput baselines).

    ``chunk`` sets the early-exit granularity (0 disables early exit and
    step quantization — the exact fixed-horizon launch); ``horizon``
    selects the compiled-horizon ladder ("pow2" default, "log" for
    decade-spanning retention sweeps — see ``_quantize_steps``).  Crossing
    rows are ladder-independent (the budget row stops real lanes at the
    true horizon), so results cache under the same key.  Campaigns larger
    than ``max_cells_per_launch`` lanes split along (corner x temperature)
    slice boundaries into multiple launches, all dispatched before the
    first device sync, so transfers overlap integration.

    With ``grid.variation`` set, the process-corner axis fuses into the
    cells plane too (DESIGN.md §9): per-lane device-parameter rows ride
    the kernel's variation plane, the whole (corner x T x V x S) grid is
    still one launch, and the returned ``crossing_time`` grows a leading
    corner axis.  Single-launch variation campaigns additionally pad the
    *total* plane to a power-of-two bucket, so the corner count enters
    the compile key only through that logarithmic bucket.

    Crash resume (DESIGN.md §13): multi-launch campaigns checkpoint each
    completed launch's raw crossing row through the content-keyed cache
    (``checkpoint=None`` means "on whenever caching is on and there is
    more than one launch"), so a killed process re-runs only the launches
    it never finished — and because the stored row is the kernel's f32
    output verbatim, the resumed assembly is bit-identical to an
    uninterrupted run.  Slice checkpoints are retired once the
    whole-campaign entry is durable.  A launch that fails to dispatch or
    sync is retried up to ``max_retries`` times with exponential backoff
    (``retry_backoff_s`` base).  ``on_slice_complete(i, n_launches)`` fires
    after each freshly-integrated launch is checkpointed — the hook the
    kill/resume tests use to die at a deterministic point.
    """
    assert backend in ("pallas", "ref"), backend
    spec = grid.variation
    n_t, n_v, _, n_s = grid.shape
    n_c = grid.n_corners
    expect_shape = ((n_c, n_t, n_v, n_s) if spec is not None
                    else (n_t, n_v, n_s))
    key = _cache.campaign_key(p, grid, backend)
    if use_cache:
        hit = _cache.load(key, cache_dir)
        if hit is not None and hit.shape == expect_shape:
            return CampaignResult(grid=grid, backend=backend,
                                  crossing_time=hit, elapsed_s=0.0,
                                  from_cache=True, n_launches=0)

    n_steps = grid.n_steps
    n_static = _quantize_steps(n_steps, horizon) if chunk > 0 else n_steps
    if spec is None:
        state, seeds, sigma, budget, spans = pack_campaign(grid, p)
        lane_params = None
    else:
        state, seeds, sigma, budget, lane_params, spans = pack_variation(
            grid, p)
    n_slices = n_c * n_t
    slice_cells = state.shape[1] // n_slices
    launches = _launch_spans(n_slices, slice_cells, max_cells_per_launch)
    if spec is not None and len(launches) == 1:
        # total-plane pow2 bucket: corner count reaches the compile key
        # only through this logarithmic bucket (3 vs 4 corners usually
        # share a compiled shape; pinned by tests/test_variation.py)
        from repro.campaign.grid import bucket_cells
        total = state.shape[1]
        pad = bucket_cells(total) - total
        if pad:
            state = jnp.pad(state, ((0, 0), (0, pad)))
            seeds = jnp.pad(seeds, (0, pad))
            sigma = jnp.pad(sigma, (0, pad))
            budget = jnp.pad(budget, (0, pad))
            fill = np.broadcast_to(
                np.array([[p.alpha], [p.b_aniso], [1.0]], np.float32),
                (3, pad))
            lane_params = jnp.concatenate(
                [lane_params, jnp.asarray(fill)], axis=1)
        launches = [(0, n_slices)]

    ckpt = ((use_cache and len(launches) > 1) if checkpoint is None
            else bool(checkpoint))

    def span_cols(a: int, b: int) -> Tuple[int, int]:
        c0, c1 = a * slice_cells, b * slice_cells
        if spec is not None and len(launches) == 1:
            c1 = state.shape[1]              # include the total-bucket pad
        return c0, c1

    def dispatch(a: int, b: int):
        c0, c1 = span_cols(a, b)
        return _integrate_sharded(
            state[:, c0:c1], seeds[c0:c1], sigma[c0:c1], budget[c0:c1],
            None if lane_params is None else lane_params[:, c0:c1],
            p=p, dt=grid.dt, n_steps=n_static,
            switch_threshold=float(grid.switch_threshold), backend=backend,
            n_dev=_usable_devices(c1 - c0, devices), chunk=int(chunk))

    # dispatch every launch before syncing on any of them: jax dispatch is
    # async, so device compute and D2H transfers pipeline across launches.
    # Checkpointed launches restore their raw f32 crossing row instead of
    # dispatching at all; a failed dispatch is deferred to the sync loop's
    # retry ladder rather than aborting the other launches' overlap.
    t0 = time.time()
    rows: List[Optional[np.ndarray]] = [None] * len(launches)
    outs: List[Optional[object]] = [None] * len(launches)
    n_resumed = 0
    for i, (a, b) in enumerate(launches):
        if ckpt:
            c0, c1 = span_cols(a, b)
            hit = _cache.load_arrays(_slice_key(key, a, b, chunk, horizon),
                                     cache_dir)
            if (hit is not None and "row7" in hit
                    and hit["row7"].shape == (c1 - c0,)):
                rows[i] = hit["row7"]
                n_resumed += 1
                continue
        try:
            outs[i] = dispatch(a, b)
        except Exception:                    # retried in the sync loop
            outs[i] = None
    for i, (a, b) in enumerate(launches):
        if rows[i] is not None:
            continue
        attempt = 0
        while True:
            try:
                if outs[i] is None:
                    outs[i] = dispatch(a, b)
                rows[i] = np.asarray(jax.block_until_ready(outs[i]))[7]
                break
            except Exception:
                outs[i] = None
                if attempt >= max_retries:
                    raise
                time.sleep(retry_backoff_s * (2.0 ** attempt))
                attempt += 1
        if ckpt:
            _cache.store_arrays(
                _slice_key(key, a, b, chunk, horizon), {"row7": rows[i]},
                header={"campaign": key, "span": [int(a), int(b)],
                        "kind": "slice-row7"},
                cache_dir=cache_dir)
        if on_slice_complete is not None:
            on_slice_complete(i, len(launches))
    elapsed = time.time() - t0

    # clip the quantized-horizon sentinel (n_static) back to the grid's
    # horizon: real crossings are <= budget == n_steps and pass unchanged.
    # float64 before the dt multiply — in f32 the sentinel n_steps*dt
    # rounds below the f64 horizon and never-crossed lanes would leak into
    # the switched-only latency reductions
    row7 = np.minimum(np.concatenate(rows).astype(np.float64),
                      float(n_steps))
    crossing = np.empty(expect_shape)
    for si, (lo, hi) in enumerate(spans):
        plane = row7[lo:hi].reshape(n_v, n_s) * grid.dt
        if spec is None:
            crossing[si] = plane
        else:
            crossing[si // n_t, si % n_t] = plane

    if use_cache:
        _cache.store(key, crossing,
                     header={"params": dataclasses.asdict(p),
                             "grid": dataclasses.asdict(grid),
                             "backend": backend},
                     cache_dir=cache_dir)
    if ckpt:
        # the whole-campaign entry is durable (or caching is off and the
        # result is in hand) — retire the per-slice resume checkpoints
        for a, b in launches:
            _cache.drop_arrays(_slice_key(key, a, b, chunk, horizon),
                               cache_dir)
    return CampaignResult(grid=grid, backend=backend, crossing_time=crossing,
                          elapsed_s=elapsed, n_launches=len(launches),
                          n_resumed=n_resumed)

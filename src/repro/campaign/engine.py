"""Monte-Carlo campaign engine: one kernel launch per (temperature) tile.

Replaces the per-sample host-visible scan in ``core.montecarlo`` (O(steps)
XLA while-loop per sample, threefry keys split per step) with the Pallas
thermal LLG kernel: the whole (voltage x sample) plane rides in one
``(8, cells)`` SoA launch, per-lane counter-RNG streams supply the thermal
field in-kernel, and the pulse-width axis falls out of the recorded
first-crossing steps for free (see ``grid.py``).

Scaling: the cells axis is embarrassingly parallel, so the engine shards
cell tiles across every visible device with ``shard_map`` — each device
integrates its own ``cells / n_dev`` lanes (a multiple of the kernel's
CELL_TILE), no cross-device communication at all.  Results are reduced
host-side into WER / latency-percentile surfaces and cached on disk
(``cache.py``) keyed by the full campaign content hash.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.campaign import cache as _cache
from repro.campaign.grid import CampaignGrid, pack_plane, pack_soa
from repro.core.montecarlo import thermal_sigma
from repro.core.params import DeviceParams
from repro.kernels import noise, ref
from repro.kernels.llg_rk4 import CELL_TILE, llg_rk4_pallas
from repro.kernels.ops import _default_interpret


def brown_sigma(p: DeviceParams, dt: float, temperature: Optional[float] = None
                ) -> float:
    """Brown's thermal-field std per component per step [T] — canonical
    formula lives in ``core.montecarlo.thermal_sigma``."""
    if temperature is not None and temperature != p.temperature:
        p = dataclasses.replace(p, temperature=float(temperature))
    return thermal_sigma(p, dt)


@functools.partial(jax.jit, static_argnames=(
    "p", "dt", "n_steps", "sigma", "switch_threshold", "backend", "n_dev"))
def _integrate_sharded(state, seeds, *, p: DeviceParams, dt: float,
                       n_steps: int, sigma: float, switch_threshold: float,
                       backend: str, n_dev: int):
    """Advance a (8, cells) block on ``n_dev`` devices (cells sharded)."""

    def tile_fn(st, sd):
        # the SoA Pallas kernel is dual-sublattice by construction
        # (staggered Neel STT); single-sublattice FM/MTJ devices integrate
        # the same production physics through the oracle's lane-vectorized
        # scan — same grids, padding, RNG streams, first-crossing row 7
        if p.n_sublattices == 1 or backend == "ref":
            return ref.ref_llg_rk4(st, p, dt, n_steps, switch_threshold,
                                   thermal_sigma=sigma, seeds=sd)
        return llg_rk4_pallas(st, p, dt, n_steps, switch_threshold,
                              interpret=_default_interpret(),
                              thermal_sigma=sigma, seeds=sd)

    if n_dev == 1:
        return tile_fn(state, seeds)
    mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("cells",))
    # check_rep=False: shard_map has no replication rule for pallas_call;
    # every output is fully sharded along cells anyway
    fn = shard_map(tile_fn, mesh=mesh,
                   in_specs=(P(None, "cells"), P("cells")),
                   out_specs=P(None, "cells"), check_rep=False)
    return fn(state, seeds)


def _usable_devices(cells_padded: int, devices: Optional[int]) -> int:
    """Largest device count (<= requested/visible) whose per-shard slice is
    a whole number of CELL_TILE tiles."""
    n = jax.device_count() if devices is None else min(devices, jax.device_count())
    tiles = cells_padded // CELL_TILE
    while n > 1 and tiles % n != 0:
        n -= 1
    return max(n, 1)


@dataclasses.dataclass(frozen=True)
class EnsembleResult:
    """One thermal ensemble integration (a single campaign tile)."""
    final_state: np.ndarray      # (8, cells) SoA after n_steps
    crossing_steps: np.ndarray   # (cells,) first crossing (== n_steps: none)
    n_steps: int
    dt: float
    elapsed_s: float

    @property
    def crossing_time(self) -> np.ndarray:
        return self.crossing_steps * self.dt

    @property
    def switched(self) -> np.ndarray:
        return self.crossing_steps < self.n_steps


def run_ensemble(
    p: DeviceParams,
    m0: jnp.ndarray,                 # (cells, n_sub, 3) initial states
    voltages: jnp.ndarray,           # (cells,) per-cell drive
    dt: float,
    n_steps: int,
    *,
    seed: int = 0,
    temperature: Optional[float] = None,
    backend: str = "pallas",
    switch_threshold: float = 0.9,
    devices: Optional[int] = None,
) -> EnsembleResult:
    """Integrate an arbitrary thermal ensemble through the kernel path.

    The general entry point (used by ``examples/array_mc_sim.py`` for
    per-cell IR-drop voltage maps and by ``imc.write_path`` for write-verify
    rounds); ``run_campaign`` packs structured (V x S) grids on top of it.
    ``temperature=None`` uses ``p.temperature``; ``temperature=0`` (or
    alpha/volume making sigma 0) falls back to the deterministic kernel.
    Single-sublattice devices (``p.n_sublattices == 1``, the MTJ baseline)
    integrate through the ``kernels.ref.ref_llg_rk4`` scan — same API,
    grids and reductions, no Pallas kernel (the SoA kernel is
    dual-sublattice only).

    Never-switched lanes report ``crossing_steps == n_steps`` (so
    ``crossing_time == n_steps*dt``); when thresholding crossings against a
    pulse width, choose ``n_steps`` with ``n_steps*dt`` strictly beyond the
    longest pulse (``CampaignGrid`` does this automatically).
    """
    cells = m0.shape[0]
    state = pack_soa(m0, jnp.asarray(voltages, jnp.float32))
    padded = state.shape[1]
    sigma = brown_sigma(p, dt, temperature)
    seeds = noise.cell_seeds(seed, padded)
    n_dev = _usable_devices(padded, devices)

    t0 = time.time()
    out = _integrate_sharded(
        state, seeds, p=p, dt=dt, n_steps=n_steps, sigma=float(sigma),
        switch_threshold=float(switch_threshold), backend=backend,
        n_dev=n_dev)
    out = np.asarray(jax.block_until_ready(out))
    elapsed = time.time() - t0
    return EnsembleResult(
        final_state=out[:, :cells], crossing_steps=out[7, :cells],
        n_steps=n_steps, dt=dt, elapsed_s=elapsed)


@dataclasses.dataclass(frozen=True)
class CampaignResult:
    """WER / latency surfaces over the (T, V, pulse) axes of a grid."""
    grid: CampaignGrid
    backend: str
    crossing_time: np.ndarray        # (n_T, n_V, n_S) seconds
    elapsed_s: float                 # integration wall-clock (0 on cache hit)
    from_cache: bool = False

    @property
    def n_samples_total(self) -> int:
        n_t, n_v, _, n_s = self.grid.shape
        return n_t * n_v * n_s

    def wer_surface(self) -> np.ndarray:
        """(n_T, n_V, n_P) write-error rate: fraction of thermal samples NOT
        switched by the end of each pulse width."""
        pulses = np.asarray(self.grid.pulse_widths)
        # crossing_time == n_steps*dt marks "never crossed" and exceeds
        # every pulse in the grid by construction
        ct = self.crossing_time[:, :, None, :]            # (T, V, 1, S)
        return (ct > pulses[None, None, :, None]).mean(axis=-1)

    def wer(self, t_index: int = 0) -> np.ndarray:
        """(n_V, n_P) slice at one temperature."""
        return self.wer_surface()[t_index]

    def latency_percentiles(self, qs: Sequence[float] = (50.0, 99.0)
                            ) -> np.ndarray:
        """(n_T, n_V, len(qs)) switching-latency percentiles over *switched*
        samples (NaN where no sample switched)."""
        n_t, n_v, _, _ = self.grid.shape
        horizon = self.grid.n_steps * self.grid.dt
        out = np.full((n_t, n_v, len(qs)), np.nan)
        for t in range(n_t):
            for v in range(n_v):
                ct = self.crossing_time[t, v]
                ok = ct < horizon
                if ok.any():
                    out[t, v] = np.percentile(ct[ok], qs)
        return out

    def pulse_for_wer(self, wer_target: float, t_index: int = 0,
                      v_index: Optional[int] = None) -> float:
        """Smallest grid pulse width whose WER <= target (the write-margin
        query the IMC controller binds against).  ``v_index=None`` (default)
        evaluates at the *lowest* grid voltage — the worst-case drive, so a
        controller pulse sized from the default covers every cell — not at
        whatever voltage happens to be listed last.  Raises if no grid
        pulse qualifies — callers must widen the grid rather than silently
        build timing models on a pulse that misses the WER target."""
        if v_index is None:
            v_index = int(np.argmin(self.grid.voltages))
        w = self.wer(t_index)[v_index]
        pulses = np.asarray(self.grid.pulse_widths)
        ok = np.nonzero(w <= wer_target)[0]
        if not ok.size:
            raise ValueError(
                f"no grid pulse meets WER<={wer_target:g} (best WER "
                f"{w.min():.3g} at {pulses[-1]*1e12:.0f} ps); widen "
                "pulse_widths or raise the drive voltage")
        return float(pulses[ok[0]])


def run_campaign(
    p: DeviceParams,
    grid: CampaignGrid,
    *,
    backend: str = "pallas",
    use_cache: bool = True,
    cache_dir: Optional[str] = None,
    devices: Optional[int] = None,
) -> CampaignResult:
    """Run (or cache-load) a full Monte-Carlo campaign.

    One thermal-kernel launch per temperature slice; voltage and sample ride
    the packed cells axis, pulse width is post-processing.  ``backend`` is
    "pallas" (production) or "ref" (pure-jnp oracle — same noise streams,
    used for parity checks and throughput baselines).
    """
    assert backend in ("pallas", "ref"), backend
    key = _cache.campaign_key(p, grid, backend)
    if use_cache:
        hit = _cache.load(key, cache_dir)
        if hit is not None and hit.shape == (
                len(grid.temperatures), len(grid.voltages), grid.n_samples):
            return CampaignResult(grid=grid, backend=backend,
                                  crossing_time=hit, elapsed_s=0.0,
                                  from_cache=True)

    n_t, n_v, _, n_s = grid.shape
    crossing = np.empty((n_t, n_v, n_s))
    elapsed = 0.0
    n_steps = grid.n_steps
    for ti, temp in enumerate(grid.temperatures):
        p_t = dataclasses.replace(p, temperature=float(temp))
        state, seeds = pack_plane(grid, p_t, ti)
        sigma = brown_sigma(p_t, grid.dt)
        n_dev = _usable_devices(state.shape[1], devices)
        t0 = time.time()
        out = _integrate_sharded(
            state, seeds, p=p_t, dt=grid.dt, n_steps=n_steps,
            sigma=float(sigma), switch_threshold=float(grid.switch_threshold),
            backend=backend, n_dev=n_dev)
        out = np.asarray(jax.block_until_ready(out))
        elapsed += time.time() - t0
        crossing[ti] = out[7, :grid.cells].reshape(n_v, n_s) * grid.dt

    if use_cache:
        _cache.store(key, crossing,
                     header={"params": dataclasses.asdict(p),
                             "grid": dataclasses.asdict(grid),
                             "backend": backend},
                     cache_dir=cache_dir)
    return CampaignResult(grid=grid, backend=backend, crossing_time=crossing,
                          elapsed_s=elapsed)

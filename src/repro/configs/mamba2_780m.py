"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.
48L d_model=1536 d_ff=0 vocab=50280 ssm_state=128 [arXiv:2405.21060]

Mamba-2 block: d_inner = 2*d_model = 3072, headdim 64 (48 heads), d_state
128, depthwise conv4, gated RMSNorm before out_proj.  No separate FFN
(d_ff=0): the block IS the layer.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, d_conv=4),
    pattern=(("mamba", "none"),),
    tie_embeddings=True,
)

"""olmoe-1b-7b [moe] — 64 experts top-8, all-MoE layers.
16L d_model=2048 16H (kv=16) d_ff(expert)=1024 vocab=50304 [arXiv:2409.02060; hf]"""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1024,
    vocab=50304,
    attn=AttnConfig(qk_norm=True, rope_theta=10000.0),
    moe=MoEConfig(num_experts=64, top_k=8, d_expert=1024),
    pattern=(("attn", "moe"),),
)

"""qwen2-0.5b [dense] — GQA kv=2, QKV bias, tied embeddings.
24L d_model=896 14H d_ff=4864 vocab=151936 [arXiv:2407.10671; hf]"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_head=64,
    d_ff=4864,
    vocab=151936,
    attn=AttnConfig(qkv_bias=True, rope_theta=1000000.0),
    pattern=(("attn", "dense"),),
    tie_embeddings=True,
)

"""qwen3-8b [dense] — qk_norm, GQA kv=8.
36L d_model=4096 32H d_ff=12288 vocab=151936 [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=12288,
    vocab=151936,
    attn=AttnConfig(qk_norm=True, rope_theta=1000000.0),
    pattern=(("attn", "dense"),),
)

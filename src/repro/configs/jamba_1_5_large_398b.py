"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536 [arXiv:2403.19887; hf]

Period-8 pattern: 1 attention layer + 7 Mamba layers; MoE FFN every second
layer (dense otherwise).  Long-context capable: only the 9 attention layers
hold a KV cache — the long_500k cell runs for this arch (DESIGN.md §3).
Big-MoE memory posture: bf16 params + bf16 optimizer moments.
"""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab=65536,
    attn=AttnConfig(rope_theta=10000.0),
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576, interleave=2),
    ssm=SSMConfig(d_state=128, headdim=128, expand=2, d_conv=4),
    pattern=(
        ("attn", "dense"), ("mamba", "moe"), ("mamba", "dense"), ("mamba", "moe"),
        ("mamba", "dense"), ("mamba", "moe"), ("mamba", "dense"), ("mamba", "moe"),
    ),
    param_dtype="bfloat16",
    opt_state_dtype="bfloat16",
)

"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.
24+24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206 [arXiv:2308.11596; hf]

Backbone only: the w2v-BERT speech frontend is a STUB; ``input_specs()``
provides precomputed frame embeddings for the encoder (per the assignment).
"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,                 # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=8192,
    vocab=256206,
    attn=AttnConfig(rope_theta=10000.0),
    pattern=(("attn", "dense"),),
    frontend_positions=1024,     # encoder frame embeddings per sample
    act="gelu",
)

"""llama4-maverick-400b-a17b [moe] — interleaved MoE, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1
[hf:meta-llama/Llama-4; unverified]

MoE on every *second* layer (the real Maverick interleave) + one always-on
shared expert: with the listed dims this yields ~400 B total / ~17 B active
parameters, matching the model name; an all-MoE stack would be ~780 B (see
DESIGN.md §3).  Early fusion = token-space multimodal fusion; the modality
frontend is a stub providing precomputed patch embeddings.

Big-MoE memory posture: bf16 parameters and bf16 optimizer moments so
param+state fits a 16 GB/chip pod at 256 chips (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=202048,
    attn=AttnConfig(rope_theta=500000.0),
    moe=MoEConfig(num_experts=128, top_k=1, d_expert=8192, interleave=2,
                  shared_expert=True),
    pattern=(("attn", "dense"), ("attn", "moe")),
    frontend_positions=256,
    param_dtype="bfloat16",
    opt_state_dtype="bfloat16",
)

"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (frontend stubbed).
28L d_model=1536 12H (kv=2) d_ff=8960 vocab=151936 [arXiv:2409.12191; hf]

Backbone only per the assignment: ``input_specs()`` provides precomputed
patch embeddings (the ViT frontend is a stub); M-RoPE splits the rotary
dims into (temporal, height, width) = (16, 24, 24) sections of head_dim/2.
"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_head=128,
    d_ff=8960,
    vocab=151936,
    attn=AttnConfig(
        qkv_bias=True, rope_theta=1000000.0, mrope_sections=(16, 24, 24)
    ),
    pattern=(("attn", "dense"),),
    frontend_positions=256,    # precomputed vision-patch embeddings per sample
    tie_embeddings=True,
)

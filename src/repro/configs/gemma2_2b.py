"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000 [arXiv:2408.00118; hf]
head_dim=256, sliding window 4096, attn softcap 50, final softcap 30, GeGLU,
pre+post RMSNorm, tied embeddings.
"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=9216,
    vocab=256000,
    attn=AttnConfig(
        sliding_window=4096,
        local_global_period=2,
        logit_softcap=50.0,
        rope_theta=10000.0,
    ),
    pattern=(("attn_local", "dense"), ("attn_global", "dense")),
    tie_embeddings=True,
    final_softcap=30.0,
    act="gelu",
    post_norms=True,
)

"""Architecture + shape configuration system (``--arch``, ``--shape``)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN width
    interleave: int = 1           # MoE every Nth layer (1 = every layer)
    shared_expert: bool = False   # llama4-style always-on shared expert


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256              # SSD chunk length


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    sliding_window: Optional[int] = None     # local window (gemma2 local layers)
    local_global_period: int = 0             # 2 => alternate local/global
    logit_softcap: Optional[float] = None    # gemma2: 50.0
    qk_norm: bool = False                    # qwen3
    qkv_bias: bool = False                   # qwen2
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    attn: AttnConfig = AttnConfig()
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # layer-pattern description: list of (mixer, ffn) strings, repeated to
    # reach n_layers.  mixer in {attn, attn_local, attn_global, mamba};
    # ffn in {dense, moe, geglu_dense}.
    pattern: Tuple[Tuple[str, str], ...] = (("attn", "dense"),)
    # encoder-decoder
    n_encoder_layers: int = 0
    # frontends (vlm/audio stubs): number of precomputed embedding positions
    frontend_positions: int = 0
    tie_embeddings: bool = False
    final_softcap: Optional[float] = None    # gemma2: 30.0
    act: str = "silu"                        # silu | gelu
    post_norms: bool = False                 # gemma2 pre+post block norms
    norm_eps: float = 1e-6
    # dtypes: big-MoE models run bf16 optimizer state (see DESIGN.md §4)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"

    @property
    def n_pattern_repeats(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by "
            f"pattern length {len(self.pattern)}"
        )
        return self.n_layers // len(self.pattern)

    def param_count(self) -> int:
        """Approximate total parameter count (for 6ND roofline math)."""
        c = self
        emb = c.vocab * c.d_model * (1 if c.tie_embeddings else 2)
        per_attn = c.d_model * c.d_head * (c.n_heads + 2 * c.n_kv_heads) + (
            c.n_heads * c.d_head * c.d_model
        )
        per_dense_ffn = 3 * c.d_model * c.d_ff
        per_mamba = 0
        if c.ssm is not None:
            d_in = c.ssm.expand * c.d_model
            per_mamba = (
                c.d_model * (2 * d_in + 2 * c.ssm.d_state)  # in_proj(z,x,B,C)
                + d_in * c.d_model                          # out_proj
                + d_in * c.ssm.d_conv                       # conv
            )
        total = emb
        reps = self.n_pattern_repeats
        for mixer, ffn in c.pattern:
            if mixer.startswith("attn"):
                total += reps * per_attn
            elif mixer == "mamba":
                total += reps * per_mamba
            if ffn == "dense":
                total += reps * per_dense_ffn
            elif ffn == "moe":
                assert c.moe is not None
                e = c.moe.num_experts * 3 * c.d_model * c.moe.d_expert
                if c.moe.shared_expert:
                    e += 3 * c.d_model * c.moe.d_expert
                e += c.d_model * c.moe.num_experts  # router
                total += reps * e
        if c.n_encoder_layers:
            # encoder layers + decoder cross-attention
            total += c.n_encoder_layers * (per_attn + per_dense_ffn)
            total += c.n_layers * per_attn  # cross-attn in each decoder layer
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        c = self
        full_moe = c.moe.num_experts * 3 * c.d_model * c.moe.d_expert
        act_moe = c.moe.top_k * 3 * c.d_model * c.moe.d_expert
        n_moe_layers = sum(
            self.n_pattern_repeats for _, ffn in c.pattern if ffn == "moe"
        )
        return self.param_count() - n_moe_layers * (full_moe - act_moe)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatches: int = 1        # grad-accumulation steps (train only)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

# Archs whose long_500k cell RUNS (constant-state SSM / sparse-KV hybrid
# families); every pure full-attention arch skips it — the sanctioned skip
# list; see DESIGN.md §3.
LONG_CONTEXT_ARCHS = ("mamba2-780m", "jamba-1.5-large-398b")


def shape_for(arch: ArchConfig, shape_name: str, microbatches: int = None) -> ShapeConfig:
    s = SHAPES[shape_name]
    if microbatches is not None:
        s = dataclasses.replace(s, microbatches=microbatches)
    return s

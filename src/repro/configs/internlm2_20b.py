"""internlm2-20b [dense] — GQA. 48L d_model=6144 48H (kv=8) d_ff=16384
vocab=92544 [arXiv:2403.17297; hf]"""
from repro.configs.base import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab=92544,
    attn=AttnConfig(rope_theta=1000000.0),
    pattern=(("attn", "dense"),),
)

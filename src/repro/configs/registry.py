"""Architecture registry: ``--arch <id>`` lookup + reduced smoke configs."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, MoEConfig, SSMConfig
from repro.configs import (  # noqa: F401
    gemma2_2b,
    internlm2_20b,
    jamba_1_5_large_398b,
    llama4_maverick_400b_a17b,
    mamba2_780m,
    olmoe_1b_7b,
    qwen2_0_5b,
    qwen2_vl_2b,
    qwen3_8b,
    seamless_m4t_large_v2,
)

ARCHS: Dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        gemma2_2b,
        internlm2_20b,
        qwen2_0_5b,
        qwen3_8b,
        qwen2_vl_2b,
        llama4_maverick_400b_a17b,
        olmoe_1b_7b,
        seamless_m4t_large_v2,
        mamba2_780m,
        jamba_1_5_large_398b,
    )
}

# Recommended grad-accumulation microbatch counts for train_4k at the
# (data=16, model=16) production mesh, sized so saved activations fit HBM
# with scan-over-layers remat (see DESIGN.md §4 + EXPERIMENTS.md §Dry-run).
TRAIN_MICROBATCHES: Dict[str, int] = {
    "gemma2-2b": 4,
    "internlm2-20b": 8,
    "qwen2-0.5b": 2,
    "qwen3-8b": 4,
    "qwen2-vl-2b": 2,
    "llama4-maverick-400b-a17b": 8,
    "olmoe-1b-7b": 2,
    "seamless-m4t-large-v2": 2,
    "mamba2-780m": 2,
    "jamba-1.5-large-398b": 16,
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests: small widths/layers,
    tiny vocab, few experts — same pattern & feature flags as the original."""
    c = get_arch(name)
    kw = dict(
        name=c.name + "-smoke",
        n_layers=len(c.pattern) * (2 if len(c.pattern) <= 4 else 1),
        d_model=64,
        n_heads=4 if c.n_heads else 0,
        n_kv_heads=min(c.n_kv_heads, 2) if c.n_kv_heads else 0,
        d_head=16 if c.n_heads else 0,
        d_ff=128 if c.d_ff else 0,
        vocab=512,
        n_encoder_layers=2 if c.n_encoder_layers else 0,
        frontend_positions=8 if c.frontend_positions else 0,
        param_dtype="float32",
        opt_state_dtype="float32",
        compute_dtype="float32",
    )
    if c.moe is not None:
        kw["moe"] = MoEConfig(
            num_experts=4,
            top_k=min(c.moe.top_k, 2),
            d_expert=64,
            interleave=c.moe.interleave,
            shared_expert=c.moe.shared_expert,
        )
    if c.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, headdim=16, expand=2, d_conv=4, chunk=8)
    if c.attn.mrope_sections is not None:
        kw["attn"] = dataclasses.replace(c.attn, mrope_sections=(2, 3, 3))
    if c.attn.sliding_window is not None:
        att = kw.get("attn", c.attn)
        kw["attn"] = dataclasses.replace(att, sliding_window=8)
    return dataclasses.replace(c, **kw)

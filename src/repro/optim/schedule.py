"""Warmup-stable-decay learning-rate schedule (pure function of step)."""
from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(step, base_lr: float, warmup: int = 100, total: int = 10000,
                 decay_frac: float = 0.2, min_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
    decay_start = total * (1.0 - decay_frac)
    frac = jnp.clip((step - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
    decay = 1.0 - (1.0 - min_frac) * frac
    return warm * decay

"""AdamW with global-norm clipping, pytree-native (no optax dependency).

Moments are stored in ``cfg.opt_state_dtype`` — bf16 for the big-MoE archs
(llama4/jamba) so param+state fits HBM (see DESIGN.md §4); the update math
always runs in fp32.  State shards exactly like the parameters (the
launcher's sharding rules apply to the whole (params, m, v) triple), which
is ZeRO-style state sharding for free under GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params: Any, dtype: str = "float32") -> Tuple[Any, Any]:
    dt = jnp.dtype(dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return jax.tree_util.tree_map(zeros, params), jax.tree_util.tree_map(zeros, params)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    params: Any,
    grads: Any,
    m: Any,
    v: Any,
    step: jnp.ndarray,
    cfg: AdamWConfig,
    lr: jnp.ndarray | float | None = None,
):
    lr = cfg.lr if lr is None else lr
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(p, g, m_, v_):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m_.astype(jnp.float32) + (1.0 - cfg.b1) * g
        v_new = cfg.b2 * v_.astype(jnp.float32) + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m_.dtype), v_new.astype(v_.dtype)

    out = jax.tree_util.tree_map(upd, params, grads, m, v)
    new_p = jax.tree_util.tree_map(lambda x: x[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda x: x[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda x: x[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, new_m, new_v, gn

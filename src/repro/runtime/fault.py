"""Fault tolerance: step watchdog (straggler detection) + restartable loop.

At 1000+ nodes the common failure modes are (a) a host dying (handled by
checkpoint/restart — the loop below), (b) a *straggler* silently slowing the
whole synchronous step.  The watchdog keeps an EWMA of step time and flags
steps exceeding ``threshold x`` the moving average; the trainer logs and
exports these so an external orchestrator can evict the slow host.  A
SIGTERM handler requests a final checkpoint so preemptions (spot/maintenance
events) resume losslessly.
"""
from __future__ import annotations

import signal
import time
from typing import Callable, List, Optional


class StepWatchdog:
    def __init__(self, threshold: float = 2.0, alpha: float = 0.1):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.straggler_steps: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler event."""
        if self.ewma is None:
            self.ewma = dt
            return False
        is_slow = dt > self.threshold * self.ewma
        if is_slow:
            self.straggler_steps.append(step)
        # slow steps do not poison the average
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * min(
            dt, self.threshold * self.ewma
        )
        return is_slow


class FaultTolerantLoop:
    """Wraps a step function with checkpoint/resume + preemption handling."""

    def __init__(self, checkpointer, save_every: int = 100):
        self.ckpt = checkpointer
        self.save_every = save_every
        self.preempted = False
        self._old_handler = None
        self._installed = False

    def install_sigterm(self):
        def handler(signum, frame):
            self.preempted = True

        self._old_handler = signal.signal(signal.SIGTERM, handler)
        self._installed = True

    def uninstall_sigterm(self):
        """Restore the SIGTERM disposition that ``install_sigterm`` replaced.

        Without this, a loop that finishes (or a test that installs a
        handler) leaves the process's SIGTERM behavior permanently pointing
        at a dead loop object — the next preemption flips a flag nobody
        reads instead of terminating the process."""
        if self._installed:
            signal.signal(signal.SIGTERM, self._old_handler)
            self._old_handler = None
            self._installed = False

    def __enter__(self):
        self.install_sigterm()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.uninstall_sigterm()
        return False

    def run(
        self,
        state,
        step_fn: Callable,
        get_batch: Callable[[int], dict],
        start_step: int,
        total_steps: int,
        log: Callable[[int, dict, float], None] = lambda *a: None,
    ):
        watchdog = StepWatchdog()
        step = start_step
        while step < total_steps and not self.preempted:
            t0 = time.time()
            batch = get_batch(step)
            state, metrics = step_fn(state, batch)
            dt = time.time() - t0
            slow = watchdog.observe(step, dt)
            if slow:
                metrics = dict(metrics)
                metrics["straggler"] = True
            log(step, metrics, dt)
            step += 1
            if step % self.save_every == 0:
                self.ckpt.save(step, state)
        if self.preempted:
            self.ckpt.save(step, state, blocking=True)
        self.ckpt.wait()
        return state, step, watchdog

from repro.runtime.fault import StepWatchdog, FaultTolerantLoop  # noqa: F401
from repro.runtime.elastic import (plan_elastic_remesh,  # noqa: F401
                                   plan_campaign_devices)
from repro.runtime import xla_flags  # noqa: F401

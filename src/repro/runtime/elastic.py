"""Elastic re-meshing: plan a degraded mesh after losing hosts.

When a pod loses chips, the job restarts on the survivors.  The plan keeps
the `model` axis intact when possible (TP re-sharding is the expensive
direction: every weight moves) and shrinks the `data` axis (pure DP ranks
are stateless beyond optimizer shards, which the checkpointer re-places via
device_put).  Global batch is preserved by raising grad-accumulation
microbatches, so training dynamics are unchanged across the resize.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    microbatch_scale: int          # multiply microbatches by this
    note: str


def plan_elastic_remesh(
    n_available: int,
    model_axis: int = 16,
    old_data_axis: int = 16,
    pods: int = 1,
) -> Optional[ElasticPlan]:
    """Largest (data' x model) mesh fitting n_available chips, data' | data."""
    if n_available >= pods * old_data_axis * model_axis:
        shape = ((pods, old_data_axis, model_axis) if pods > 1
                 else (old_data_axis, model_axis))
        names = ("pod", "data", "model") if pods > 1 else ("data", "model")
        return ElasticPlan(shape, names, 1, "full mesh healthy")
    data_axis = old_data_axis
    while data_axis > 1:
        data_axis //= 2
        if n_available >= data_axis * model_axis:
            scale = old_data_axis // data_axis
            return ElasticPlan(
                (data_axis, model_axis),
                ("data", "model"),
                scale,
                f"degraded: data {old_data_axis}->{data_axis}, "
                f"microbatches x{scale} preserves global batch",
            )
    return None


def plan_campaign_devices(n_available: int,
                          old_devices: int) -> ElasticPlan:
    """Elastic remesh for the Monte-Carlo campaign's 1-D cells mesh.

    A campaign checkpointed at ``old_devices`` local devices resumes on
    whatever survives: slice checkpoints are keyed by (campaign, span,
    chunk, horizon) — never by device count — and the cells axis is
    embarrassingly parallel, so *any* device count reassembles the same
    crossing rows bit-for-bit (tests/test_scale.py pins a kill-at-4 /
    resume-at-2 run).  The plan's only real job is keeping the per-launch
    shard count on the same halving ladder ``plan_elastic_remesh`` uses
    for training meshes, so a degraded fleet reuses compiled shapes
    instead of inventing one-off shard widths; ``microbatch_scale``
    doubles as the wall-clock stretch factor the scheduler should expect
    per launch.  Campaigns are model_axis=1 by construction (no tensor
    parallelism over cells), hence the delegation below.
    """
    assert old_devices >= 1, old_devices
    if n_available >= old_devices:
        return ElasticPlan((old_devices,), ("cells",), 1, "full mesh healthy")
    plan = plan_elastic_remesh(n_available, model_axis=1,
                               old_data_axis=old_devices)
    if plan is None:                      # < 1 device asked for: serialize
        return ElasticPlan((1,), ("cells",), old_devices,
                           f"degraded to 1 device, launches x{old_devices}")
    return ElasticPlan((plan.mesh_shape[0],), ("cells",),
                       plan.microbatch_scale, plan.note)

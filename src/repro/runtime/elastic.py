"""Elastic re-meshing: plan a degraded mesh after losing hosts.

When a pod loses chips, the job restarts on the survivors.  The plan keeps
the `model` axis intact when possible (TP re-sharding is the expensive
direction: every weight moves) and shrinks the `data` axis (pure DP ranks
are stateless beyond optimizer shards, which the checkpointer re-places via
device_put).  Global batch is preserved by raising grad-accumulation
microbatches, so training dynamics are unchanged across the resize.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    microbatch_scale: int          # multiply microbatches by this
    note: str


def plan_elastic_remesh(
    n_available: int,
    model_axis: int = 16,
    old_data_axis: int = 16,
    pods: int = 1,
) -> Optional[ElasticPlan]:
    """Largest (data' x model) mesh fitting n_available chips, data' | data."""
    if n_available >= pods * old_data_axis * model_axis:
        shape = ((pods, old_data_axis, model_axis) if pods > 1
                 else (old_data_axis, model_axis))
        names = ("pod", "data", "model") if pods > 1 else ("data", "model")
        return ElasticPlan(shape, names, 1, "full mesh healthy")
    data_axis = old_data_axis
    while data_axis > 1:
        data_axis //= 2
        if n_available >= data_axis * model_axis:
            scale = old_data_axis // data_axis
            return ElasticPlan(
                (data_axis, model_axis),
                ("data", "model"),
                scale,
                f"degraded: data {old_data_axis}->{data_axis}, "
                f"microbatches x{scale} preserves global batch",
            )
    return None

"""Opt-in XLA tuning profiles (DESIGN.md §14).

Campaign launches at fleet scale are dominated by two things XLA controls
but does not default well for collective-heavy programs: how eagerly the
scheduler hides collective latency under compute, and how aggressively
small collectives are combined into fewer, larger ones.  The MLPerf-style
recipes in SNIPPETS.md §2 tune exactly those knobs; this module packages
them as *named profiles* so a campaign driver (or a bench child process)
opts in with one env merge instead of a hand-maintained flag string.

XLA reads ``XLA_FLAGS`` once, at backend initialization — so profiles are
applied to the environment of a *future* process (benchmarks spawn
children; fleet launchers export before exec), never mutated into a live
one.  ``apply_profile`` refuses (warns and returns the env unchanged)
when JAX is already initialized in-process, because the flags would
silently not take effect.

Profiles:

* ``gpu-scaling`` — the SNIPPETS.md §2 set: latency-hiding scheduler,
  per-collective combine thresholds, pipelined all-gather/reduce-scatter/
  all-reduce, while-loop double buffering.  GPU-backend flags parse (and
  no-op) on CPU builds, so the same profile string is safe to stage in CI.
* ``host-devices`` — the CI / smoke stand-in for a device mesh:
  ``--xla_force_host_platform_device_count=N`` (``n=`` format key).
"""
from __future__ import annotations

import os
import sys
import warnings
from typing import Dict, Optional, Tuple

# flag tuples, not strings, so tests can assert per-flag and callers can
# subset; combine thresholds follow SNIPPETS.md §2 (all-reduce 128 MiB,
# all-gather 1 GiB, reduce-scatter 32 MiB)
PROFILES: Dict[str, Tuple[str, ...]] = {
    "gpu-scaling": (
        "--xla_gpu_enable_latency_hiding_scheduler=true",
        "--xla_gpu_enable_highest_priority_async_stream=true",
        "--xla_gpu_all_reduce_combine_threshold_bytes=134217728",
        "--xla_gpu_all_gather_combine_threshold_bytes=1073741824",
        "--xla_gpu_reduce_scatter_combine_threshold_bytes=33554432",
        "--xla_gpu_enable_pipelined_all_gather=true",
        "--xla_gpu_enable_pipelined_reduce_scatter=true",
        "--xla_gpu_enable_pipelined_all_reduce=true",
        "--xla_gpu_enable_while_loop_double_buffering=true",
        "--xla_gpu_enable_all_gather_combine_by_dim=false",
        "--xla_gpu_enable_reduce_scatter_combine_by_dim=false",
    ),
    "host-devices": (
        "--xla_force_host_platform_device_count={n}",
    ),
}


def flags_for(profile: str, **fmt) -> str:
    """The profile's flag string (space-joined), with ``{key}`` format
    fields substituted (``host-devices`` needs ``n=...``)."""
    if profile not in PROFILES:
        raise KeyError(
            f"unknown XLA profile {profile!r}; have {sorted(PROFILES)}")
    return " ".join(f.format(**fmt) for f in PROFILES[profile])


def jax_initialized() -> bool:
    """Whether this process's JAX backend is already up (flags applied now
    would be ignored).  Checked without importing jax: an un-imported jax
    trivially hasn't initialized."""
    jax = sys.modules.get("jax")
    if jax is None:
        return False
    try:
        from jax._src import xla_bridge

        return xla_bridge.backends_are_initialized()
    except Exception:
        return True                      # can't prove it's safe: assume up


def apply_profile(profile: str, env: Optional[Dict[str, str]] = None,
                  **fmt) -> Dict[str, str]:
    """Merge a profile into ``env``'s ``XLA_FLAGS`` and return the env.

    ``env=None`` copies ``os.environ`` — the common case of building a
    child-process environment.  Existing ``XLA_FLAGS`` content is kept
    (profile flags append, so an explicit user flag still wins XLA's
    last-one-parses semantics for duplicated options).  Mutating the
    *current* process after JAX initialized is a silent no-op at the XLA
    level, so that case warns and returns the env unmerged.
    """
    if env is None:
        if jax_initialized():
            warnings.warn(
                f"XLA profile {profile!r} not applied: jax is already "
                "initialized in this process; spawn a child with this env "
                "instead", RuntimeWarning, stacklevel=2)
            return dict(os.environ)
        env = dict(os.environ)
    else:
        env = dict(env)
    new = flags_for(profile, **fmt)
    old = env.get("XLA_FLAGS", "").strip()
    env["XLA_FLAGS"] = f"{old} {new}".strip() if old else new
    return env

"""Tunneling magnetoresistance readout model (paper Sec. II, validation IIA).

Conductance follows the Julliere-type angular form used by the UMN model,

    G(theta) = G_P * (1 + cos(theta)) / 2 + G_AP * (1 - cos(theta)) / 2,

where theta is the angle between the free-layer order parameter and the
reference layer.  For the AFMTJ the role of the magnetization is played by
the Neel vector (Shao & Tsymbal 2024: the momentum-resolved spin polarization
of the AFM electrode tracks the Neel order), so the same expression applies
with n_z in place of m_z.  TMR = (R_AP - R_P)/R_P; the paper validates ~80%
against fabricated AFMTJs [13]-[15] (up to 500% theoretically [2]).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.llg import order_parameter_z
from repro.core.params import DeviceParams


def conductance_from_cos(cos_theta: jnp.ndarray, p: DeviceParams) -> jnp.ndarray:
    g_p = 1.0 / p.r_parallel
    g_ap = 1.0 / p.r_antiparallel
    return 0.5 * (g_p + g_ap) + 0.5 * (g_p - g_ap) * cos_theta


def conductance(m: jnp.ndarray, p: DeviceParams) -> jnp.ndarray:
    """Instantaneous junction conductance [S] from the state (..., n_sub, 3)."""
    return conductance_from_cos(order_parameter_z(m), p)


def resistance(m: jnp.ndarray, p: DeviceParams) -> jnp.ndarray:
    return 1.0 / conductance(m, p)


def tmr_ratio(p: DeviceParams) -> float:
    """(R_AP - R_P)/R_P as modeled — should equal p.tmr by construction."""
    return (p.r_antiparallel - p.r_parallel) / p.r_parallel


def read_margin(p: DeviceParams, v_read: float = 0.1) -> float:
    """Sense current differential Delta_I = V (G_P - G_AP) at read voltage."""
    return v_read * (1.0 / p.r_parallel - 1.0 / p.r_antiparallel)

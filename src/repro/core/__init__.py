"""Core AFMTJ/MTJ compact device model (the paper's primary contribution).

Layers:
  params      — physical constants + calibrated DeviceParams (Table II)
  llg         — dual-sublattice LLG right-hand side + state helpers
  integrator  — fixed-step RK4 (scan) + adaptive step-doubling RK4 (while)
  tmr         — Julliere-type angular conductance / TMR readout
  device      — write/read operations with self-consistent STT drive
  montecarlo  — thermal ensembles (write-error rate, retention)
"""
from repro.core.params import AFMTJ_PARAMS, MTJ_PARAMS, DeviceParams  # noqa: F401
from repro.core.device import simulate_write, write_sweep, simulate_read  # noqa: F401
from repro.core.llg import llg_rhs, neel_vector, initial_state  # noqa: F401
from repro.core.tmr import conductance, resistance, tmr_ratio  # noqa: F401

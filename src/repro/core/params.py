"""Physical constants and device parameter sets (paper Table II).

Units: SI throughout. Fields are magnetic *flux densities* in Tesla
(B = mu0 * H); magnetizations in A/m; lengths in meters; time in seconds.

Calibration provenance
----------------------
The paper gives Table II (P0=0.8, alpha=0.01, Ms0=600 emu/cm^3, J_AF=5e-3,
45x45x0.45 nm free layer) but leaves J_AF's units and the RA product
unspecified.  Two constants are therefore *calibrated* against the paper's
own reported anchor points (Fig. 3):

* ``ra_product`` — fixed by energy/latency consistency: the paper reports
  (164 ps, 55.7 fJ) at 1.0 V for AFMTJ and (~1400 ps, ~480 fJ) for MTJ.
  E = V^2/R * t  =>  R = V^2 t / E ~ 2.94 kOhm for *both* devices, i.e.
  RA ~ 5.97 Ohm um^2 on a 45x45 nm pillar — the same barrier for both, which
  matches the paper's "dimensions consistent with the UMN MTJ model" note.
* ``b_exchange`` — the inter-sublattice exchange field implied by J_AF.
  We interpret J_AF = 5e-3 J/m^2 as the interfacial exchange energy areal
  density normalized over the sublattice-pair stack (six 0.45 nm planes,
  Fig. 1 shows a multilayer AFM electrode): B_E = J_AF / (Ms * 6 t_f) =
  5e-3 / (6e5 * 2.7e-9) = 3.09 T — the strong synthetic-AFM / weak-AFM
  regime.  The paper's own data selects this normalization: the staggered
  Neel-STT instability threshold is a_th ~ alpha*B_E, and with the
  single-plane normalization (18.5 T) the threshold voltage would be
  ~1.1 V, inconsistent with the paper's reported switching at 0.5 V
  (Fig. 3); with B_E = 3.09 T the threshold sits at ~0.19 V and the
  simulated write latency reproduces the paper's 164 ps @ 1.0 V anchor.

The MTJ baseline uses UMN-model CoFeB defaults (Ms=1050 emu/cm^3,
t_f=1.3 nm, P=0.6) per paper refs [5], [11].
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax

# --- physical constants (SI) -------------------------------------------------
GAMMA = 1.760859630e11     # gyromagnetic ratio [rad / (s T)]
MU0 = 1.25663706212e-6     # vacuum permeability [T m / A]
KB = 1.380649e-23          # Boltzmann [J / K]
HBAR = 1.054571817e-34     # reduced Planck [J s]
QE = 1.602176634e-19       # elementary charge [C]

EMU_PER_CC_TO_A_PER_M = 1.0e3   # 1 emu/cm^3 == 1e3 A/m


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeviceParams:
    """Compact-model parameters for one junction (AFMTJ or MTJ).

    All fields are floats so the dataclass is a JAX pytree of scalars and can
    be passed straight through jit/vmap/grad.
    """

    # -- magnetics ------------------------------------------------------------
    ms: float            # saturation magnetization per sublattice [A/m]
    alpha: float         # Gilbert damping
    polarization: float  # spin polarization P0
    b_aniso: float       # effective uniaxial PMA field (2Ku_eff/Ms) [T]
    b_exchange: float    # inter-sublattice exchange field B_E [T]; 0 => FM/MTJ
    # 2 (AFMTJ) or 1 (MTJ); static pytree metadata (not traced)
    n_sublattices: int = dataclasses.field(default=2, metadata=dict(static=True))
    # -- geometry ---------------------------------------------------------
    lx: float = 45e-9
    ly: float = 45e-9
    lz: float = 0.45e-9      # free-layer thickness t_f
    # -- transport ----------------------------------------------------------
    ra_product: float = 5.97e-12   # resistance-area product [Ohm m^2]
    tmr: float = 0.8               # TMR ratio (R_AP - R_P) / R_P
    # -- spin torque ----------------------------------------------------------
    beta_flt: float = 0.05         # field-like torque ratio b_J = beta * a_J
    # -- thermal ----------------------------------------------------------
    temperature: float = 300.0     # K

    # ---- derived (python-level, cheap) ------------------------------------
    @property
    def area(self) -> float:
        return self.lx * self.ly

    @property
    def volume(self) -> float:
        return self.lx * self.ly * self.lz

    @property
    def r_parallel(self) -> float:
        return self.ra_product / self.area

    @property
    def r_antiparallel(self) -> float:
        return self.r_parallel * (1.0 + self.tmr)

    @property
    def stt_prefactor(self) -> float:
        """a_J per unit current density: a_J = pref * J  [T per A/m^2]."""
        return HBAR * self.polarization / (2.0 * QE * self.ms * self.lz)

    @property
    def thermal_stability(self) -> float:
        """Delta = E_b / kT with E_b = (1/2) B_k Ms V (per sublattice)."""
        e_b = 0.5 * self.b_aniso * self.ms * self.volume
        return e_b / (KB * self.temperature)


def _afmtj_params() -> DeviceParams:
    ms = 600.0 * EMU_PER_CC_TO_A_PER_M          # Table II: Ms0 = 600 emu/cm^3
    lz = 0.45e-9
    # J_AF = 5e-3 J/m^2 normalized over the 6-plane sublattice stack (2.7 nm):
    # B_E = 3.09 T.  See module docstring for why the paper's own Fig. 3 data
    # selects this normalization.
    j_af = 5e-3
    b_exchange = j_af / (ms * 6.0 * lz)
    # Thermal stability target Delta ~ 40 at 300 K per sublattice pair.
    volume = 45e-9 * 45e-9 * lz
    b_aniso = 2.0 * 40.0 * KB * 300.0 / (ms * volume)
    return DeviceParams(
        ms=ms,
        alpha=0.01,              # Table II
        polarization=0.8,        # Table II
        b_aniso=b_aniso,
        b_exchange=b_exchange,
        n_sublattices=2,
        lz=lz,
    )


def _mtj_params() -> DeviceParams:
    # UMN MTJ model defaults (CoFeB/MgO, refs [5],[11]): Ms=1050 emu/cm^3,
    # t_f=1.3nm, P=0.6, Delta ~ 45.
    ms = 1050.0 * EMU_PER_CC_TO_A_PER_M
    lz = 1.3e-9
    volume = 45e-9 * 45e-9 * lz
    b_aniso = 2.0 * 45.0 * KB * 300.0 / (ms * volume)
    return DeviceParams(
        ms=ms,
        alpha=0.01,
        polarization=0.6,
        b_aniso=b_aniso,
        b_exchange=0.0,
        n_sublattices=1,
        lz=lz,
        tmr=1.0,                 # Table I: MTJ TMR 80-120% -> 100%
    )


AFMTJ_PARAMS: DeviceParams = _afmtj_params()
MTJ_PARAMS: DeviceParams = _mtj_params()

# Fig. 3 anchor points from the paper (voltage -> (write latency [s], energy [J]))
PAPER_FIG3_AFMTJ: Tuple[Tuple[float, float, float], ...] = (
    (1.0, 164e-12, 55.7e-15),
)
PAPER_FIG3_MTJ: Tuple[Tuple[float, float, float], ...] = (
    (1.0, 1400e-12, 480e-15),
)
# "Switching latency drops from 65 ps at 0.5 V to 20 ps at 1.2 V" (intrinsic
# sublattice reorientation time, excluding circuit RC):
PAPER_INTRINSIC_SWITCH: Tuple[Tuple[float, float], ...] = ((0.5, 65e-12), (1.2, 20e-12))

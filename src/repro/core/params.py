"""Physical constants and device parameter sets (paper Table II).

Units: SI throughout. Fields are magnetic *flux densities* in Tesla
(B = mu0 * H); magnetizations in A/m; lengths in meters; time in seconds.

Calibration provenance
----------------------
The paper gives Table II (P0=0.8, alpha=0.01, Ms0=600 emu/cm^3, J_AF=5e-3,
45x45x0.45 nm free layer) but leaves J_AF's units and the RA product
unspecified.  Two constants are therefore *calibrated* against the paper's
own reported anchor points (Fig. 3):

* ``ra_product`` — fixed by energy/latency consistency: the paper reports
  (164 ps, 55.7 fJ) at 1.0 V for AFMTJ and (~1400 ps, ~480 fJ) for MTJ.
  E = V^2/R * t  =>  R = V^2 t / E ~ 2.94 kOhm for *both* devices, i.e.
  RA ~ 5.97 Ohm um^2 on a 45x45 nm pillar — the same barrier for both, which
  matches the paper's "dimensions consistent with the UMN MTJ model" note.
* ``b_exchange`` — the inter-sublattice exchange field implied by J_AF.
  We interpret J_AF = 5e-3 J/m^2 as the interfacial exchange energy areal
  density normalized over the sublattice-pair stack (six 0.45 nm planes,
  Fig. 1 shows a multilayer AFM electrode): B_E = J_AF / (Ms * 6 t_f) =
  5e-3 / (6e5 * 2.7e-9) = 3.09 T — the strong synthetic-AFM / weak-AFM
  regime.  The paper's own data selects this normalization: the staggered
  Neel-STT instability threshold is a_th ~ alpha*B_E, and with the
  single-plane normalization (18.5 T) the threshold voltage would be
  ~1.1 V, inconsistent with the paper's reported switching at 0.5 V
  (Fig. 3); with B_E = 3.09 T the threshold sits at ~0.19 V and the
  simulated write latency reproduces the paper's 164 ps @ 1.0 V anchor.

The MTJ baseline uses UMN-model CoFeB defaults (Ms=1050 emu/cm^3,
t_f=1.3 nm, P=0.6) per paper refs [5], [11].
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np

# --- physical constants (SI) -------------------------------------------------
GAMMA = 1.760859630e11     # gyromagnetic ratio [rad / (s T)]
MU0 = 1.25663706212e-6     # vacuum permeability [T m / A]
KB = 1.380649e-23          # Boltzmann [J / K]
HBAR = 1.054571817e-34     # reduced Planck [J s]
QE = 1.602176634e-19       # elementary charge [C]

EMU_PER_CC_TO_A_PER_M = 1.0e3   # 1 emu/cm^3 == 1e3 A/m


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeviceParams:
    """Compact-model parameters for one junction (AFMTJ or MTJ).

    All fields are floats so the dataclass is a JAX pytree of scalars and can
    be passed straight through jit/vmap/grad.
    """

    # -- magnetics ------------------------------------------------------------
    ms: float            # saturation magnetization per sublattice [A/m]
    alpha: float         # Gilbert damping
    polarization: float  # spin polarization P0
    b_aniso: float       # effective uniaxial PMA field (2Ku_eff/Ms) [T]
    b_exchange: float    # inter-sublattice exchange field B_E [T]; 0 => FM/MTJ
    # 2 (AFMTJ) or 1 (MTJ); static pytree metadata (not traced)
    n_sublattices: int = dataclasses.field(default=2, metadata=dict(static=True))
    # -- geometry ---------------------------------------------------------
    lx: float = 45e-9
    ly: float = 45e-9
    lz: float = 0.45e-9      # free-layer thickness t_f
    # -- transport ----------------------------------------------------------
    ra_product: float = 5.97e-12   # resistance-area product [Ohm m^2]
    tmr: float = 0.8               # TMR ratio (R_AP - R_P) / R_P
    # -- spin torque ----------------------------------------------------------
    beta_flt: float = 0.05         # field-like torque ratio b_J = beta * a_J
    # -- thermal ----------------------------------------------------------
    temperature: float = 300.0     # K

    # ---- derived (python-level, cheap) ------------------------------------
    @property
    def area(self) -> float:
        return self.lx * self.ly

    @property
    def volume(self) -> float:
        return self.lx * self.ly * self.lz

    @property
    def r_parallel(self) -> float:
        return self.ra_product / self.area

    @property
    def r_antiparallel(self) -> float:
        return self.r_parallel * (1.0 + self.tmr)

    @property
    def stt_prefactor(self) -> float:
        """a_J per unit current density: a_J = pref * J  [T per A/m^2]."""
        return HBAR * self.polarization / (2.0 * QE * self.ms * self.lz)

    @property
    def thermal_stability(self) -> float:
        """Delta = E_b / kT with E_b = (1/2) B_k Ms V (per sublattice)."""
        e_b = 0.5 * self.b_aniso * self.ms * self.volume
        return e_b / (KB * self.temperature)


def _afmtj_params() -> DeviceParams:
    ms = 600.0 * EMU_PER_CC_TO_A_PER_M          # Table II: Ms0 = 600 emu/cm^3
    lz = 0.45e-9
    # J_AF = 5e-3 J/m^2 normalized over the 6-plane sublattice stack (2.7 nm):
    # B_E = 3.09 T.  See module docstring for why the paper's own Fig. 3 data
    # selects this normalization.
    j_af = 5e-3
    b_exchange = j_af / (ms * 6.0 * lz)
    # Thermal stability target Delta ~ 40 at 300 K per sublattice pair.
    volume = 45e-9 * 45e-9 * lz
    b_aniso = 2.0 * 40.0 * KB * 300.0 / (ms * volume)
    return DeviceParams(
        ms=ms,
        alpha=0.01,              # Table II
        polarization=0.8,        # Table II
        b_aniso=b_aniso,
        b_exchange=b_exchange,
        n_sublattices=2,
        lz=lz,
    )


def _mtj_params() -> DeviceParams:
    # UMN MTJ model defaults (CoFeB/MgO, refs [5],[11]): Ms=1050 emu/cm^3,
    # t_f=1.3nm, P=0.6, Delta ~ 45.
    ms = 1050.0 * EMU_PER_CC_TO_A_PER_M
    lz = 1.3e-9
    volume = 45e-9 * 45e-9 * lz
    b_aniso = 2.0 * 45.0 * KB * 300.0 / (ms * volume)
    return DeviceParams(
        ms=ms,
        alpha=0.01,
        polarization=0.6,
        b_aniso=b_aniso,
        b_exchange=0.0,
        n_sublattices=1,
        lz=lz,
        tmr=1.0,                 # Table I: MTJ TMR 80-120% -> 100%
    )


AFMTJ_PARAMS: DeviceParams = _afmtj_params()
MTJ_PARAMS: DeviceParams = _mtj_params()


# --- process variation (DESIGN.md §9) ----------------------------------------
#
# The companion driver-co-design paper (Choudhary & Adegbija, "Device-Circuit
# Co-Design of Variation-Resilient Read and Write Drivers for AFMTJ Memories")
# sizes drivers, margins and WER targets against *process variation*, not the
# nominal device.  A ``VariationSpec`` describes that scenario space: a tuple
# of named process corners (systematic wafer-level shifts, multiplicative on
# the Table II constants) plus per-corner device-to-device (D2D) sigmas for
# the within-array lognormal/normal spread.  Every draw is a pure function of
# (spec.seed, stream, parameter, lane) through the stateless counter-RNG in
# ``kernels.noise`` — reproducible, hashable, and therefore usable as a jit
# static and as part of the on-disk campaign cache key.

# counter-RNG draw ids, one decorrelated stream per varied parameter
_PID_ALPHA, _PID_B_ANISO, _PID_VOLUME, _PID_R = 0, 1, 2, 3
# Weyl salts folding (seed, stream) into a 32-bit stream base
_VAR_GOLD = 0x9E3779B1
_VAR_STREAM = 0xC2B2AE35


@dataclasses.dataclass(frozen=True)
class ProcessCorner:
    """One systematic process corner: multiplicative factors on the nominal
    magnetics/transport constants, plus the D2D sigmas of the within-array
    spread *around* that corner.

    Factor conventions (all 1.0 / 0.0 = nominal):

    * ``alpha_factor``   — Gilbert damping (raises the Neel-STT threshold
      a_th ~ alpha·B_E and Brown's sigma).
    * ``b_aniso_factor`` — uniaxial anisotropy B_k (barrier height: thermal
      stability Delta and the Boltzmann tilt of the idle state).
    * ``volume_factor``  — free-layer volume; drives Brown's sigma
      (~ 1/sqrt(V)) and Delta (~ V) jointly, transport deliberately
      untouched (barrier area variation is the ``r_factor``'s job).
    * ``r_factor``       — RA/TMR resistance factor on the junction: scales
      R_P and R_AP together, so the STT drive current (and a_J) scales by
      ``1/r_factor``.

    D2D sigmas are lognormal shape parameters (``VariationSpec.distribution
    == "lognormal"``, the usual geometry/RA model) or relative normal sigmas.
    The resistance draw is normalized to preserve the *mean conductance*
    (E[1/r] = 1/r_factor — exact for the lognormal, to O(sigma^4) for the
    normal) — the write-verify target the analog read path pre-compensates
    to; the magnetics draws preserve the parameter mean.
    """

    name: str = "tt"
    alpha_factor: float = 1.0
    b_aniso_factor: float = 1.0
    volume_factor: float = 1.0
    r_factor: float = 1.0
    sigma_alpha: float = 0.0
    sigma_b_aniso: float = 0.0
    sigma_volume: float = 0.0
    sigma_r: float = 0.0

    @property
    def is_nominal(self) -> bool:
        return (self.alpha_factor == self.b_aniso_factor ==
                self.volume_factor == self.r_factor == 1.0 and
                self.sigma_alpha == self.sigma_b_aniso ==
                self.sigma_volume == self.sigma_r == 0.0)


# Named corners: TT nominal; SS "slow" writes (damping + barrier + RA all
# against the write driver); FF "fast" (the retention-risk corner).  The
# ±10-15% spreads follow the MRAM compact-model corner convention the
# companion paper's drivers are sized against.
CORNER_TT = ProcessCorner("tt")
CORNER_SS = ProcessCorner("ss", alpha_factor=1.15, b_aniso_factor=1.10,
                          volume_factor=0.95, r_factor=1.15)
CORNER_FF = ProcessCorner("ff", alpha_factor=0.87, b_aniso_factor=0.91,
                          volume_factor=1.05, r_factor=0.87)
PROCESS_CORNERS = {c.name: c for c in (CORNER_TT, CORNER_SS, CORNER_FF)}


@dataclasses.dataclass(frozen=True)
class LaneRows:
    """Per-lane device-parameter rows one (corner, stream) slice packs into
    the kernel's variation plane (host-side numpy, float64)."""

    alpha: np.ndarray       # (n,) Gilbert damping
    b_aniso: np.ndarray     # (n,) anisotropy field B_k [T]
    g_scale: np.ndarray     # (n,) junction conductance factor (= 1/r_factor)
    volume: np.ndarray      # (n,) free-layer volume [m^3]
    sigma: np.ndarray       # (n,) Brown thermal-field std per step [T]
    theta0: np.ndarray      # (n,) Boltzmann tilt scale sqrt(1/(2 Delta))

    @property
    def kernel_rows(self) -> np.ndarray:
        """(3, n) f32 block for the kernel's aux rows 2-4
        (``kernels/llg_rk4.py`` layout: alpha, B_k, g_scale)."""
        return np.stack([self.alpha, self.b_aniso,
                         self.g_scale]).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class DeviceSample:
    """One sampled device for the scalar (single-junction) paths: corner- and
    D2D-adjusted ``DeviceParams`` plus the two knobs that do not live on the
    dataclass — the junction conductance factor and the volume factor (which
    scales Delta/sigma but deliberately not transport, matching the kernel's
    variation-plane semantics)."""

    params: DeviceParams
    g_scale: float = 1.0
    volume_factor: float = 1.0

    @property
    def thermal_stability(self) -> float:
        return self.params.thermal_stability * self.volume_factor


@dataclasses.dataclass(frozen=True)
class VariationSpec:
    """Hashable description of a process-variation Monte-Carlo scenario.

    ``corners`` is the systematic axis (one packed campaign slice group per
    corner — corner *count and values are campaign data*, not compile keys);
    each corner's D2D sigmas set the within-slice per-lane spread.  Draws
    come from the stateless counter generator, salted by ``(seed, stream,
    parameter)`` but **not** by corner position: all corners of one spec (and
    a spec reduced to a single corner via ``at_corner``) consume the *same*
    standard-normal draws — common random numbers, so corner-to-corner and
    fused-vs-separate comparisons are paired sample-by-sample and the fused
    campaign is bit-identical to per-corner launches
    (``tests/test_variation.py`` pins this).
    """

    corners: Tuple[ProcessCorner, ...] = (CORNER_TT,)
    seed: int = 0
    distribution: str = "lognormal"     # "lognormal" | "normal"

    def __post_init__(self):
        object.__setattr__(self, "corners", tuple(self.corners))
        assert self.corners, "VariationSpec needs at least one corner"
        assert self.distribution in ("lognormal", "normal"), self.distribution

    @property
    def n_corners(self) -> int:
        return len(self.corners)

    @property
    def corner_names(self) -> Tuple[str, ...]:
        return tuple(c.name for c in self.corners)

    @property
    def is_nominal(self) -> bool:
        return all(c.is_nominal for c in self.corners)

    def at_corner(self, index: int) -> "VariationSpec":
        """Single-corner view (same seed/distribution — same D2D draws)."""
        return dataclasses.replace(self, corners=(self.corners[index],))

    @classmethod
    def from_g_sigma(cls, g_sigma: float, seed: int = 0) -> "VariationSpec":
        """The spec equivalent of the legacy ``AnalogConfig.g_sigma``
        conductance-only lognormal: a nominal corner whose junction
        resistance spread reproduces a mean-preserving lognormal on the
        conductance (1/r of a lognormal is a lognormal with the same
        sigma)."""
        return cls(corners=(dataclasses.replace(CORNER_TT, name="tt/d2d",
                                                sigma_r=float(g_sigma)),),
                   seed=seed)

    # -- draws ---------------------------------------------------------------
    def _normals(self, param_id: int, n: int, stream: int) -> np.ndarray:
        """(n,) standard normals for one varied parameter — pure function of
        (seed, stream, param_id, lane)."""
        from repro.kernels import noise   # lazy: keep params import-light

        import jax.numpy as jnp

        base = (int(self.seed) * _VAR_GOLD +
                (int(stream) + 1) * _VAR_STREAM) & 0xFFFFFFFF
        lanes = noise.cell_seeds(base, n)
        # jnp (not numpy) counter: uint32 wraparound in the mixer is the
        # point, and numpy scalars warn on it
        z, _ = noise.normal_pair(lanes, jnp.uint32(param_id))
        return np.asarray(z, np.float64)

    def _factor(self, center: float, sigma: float, param_id: int, n: int,
                stream: int, mean_preserving_reciprocal: bool = False
                ) -> np.ndarray:
        """(n,) multiplicative factors ~ D2D(center, sigma)."""
        if sigma == 0.0:
            return np.full(n, float(center))
        z = self._normals(param_id, n, stream)
        if self.distribution == "normal":
            f = np.maximum(center * (1.0 + sigma * z), 0.05 * center)
            if mean_preserving_reciprocal:
                # E[1/(1+sigma z)] ~ 1 + sigma^2: rescale so the drawn
                # resistance keeps E[1/r] ~ 1/center to O(sigma^4)
                f = f * (1.0 + sigma * sigma)
            return f
        if mean_preserving_reciprocal:
            # resistance: E[1/r] = 1/center, so the conductance the
            # write-verify loop targets keeps its mean
            return center * np.exp(sigma * z + 0.5 * sigma * sigma)
        return center * np.exp(sigma * z - 0.5 * sigma * sigma)

    def lane_factors(self, corner: ProcessCorner, n: int, stream: int = 0
                     ) -> np.ndarray:
        """(4, n) float64 factors (alpha, b_aniso, volume, r) for ``n`` lanes
        of one packed slice.  ``stream`` decorrelates independent slices
        (the campaign packer passes the temperature index; the analog
        programmer uses 0/1 for the pos/neg array)."""
        return np.stack([
            self._factor(corner.alpha_factor, corner.sigma_alpha,
                         _PID_ALPHA, n, stream),
            self._factor(corner.b_aniso_factor, corner.sigma_b_aniso,
                         _PID_B_ANISO, n, stream),
            self._factor(corner.volume_factor, corner.sigma_volume,
                         _PID_VOLUME, n, stream),
            self._factor(corner.r_factor, corner.sigma_r, _PID_R, n, stream,
                         mean_preserving_reciprocal=True),
        ])

    def lane_rows(self, p: DeviceParams, corner: ProcessCorner, n: int,
                  dt: float, temperature: Optional[float] = None,
                  stream: int = 0) -> LaneRows:
        """Per-lane physical rows for one campaign slice: varied device
        constants plus the derived Brown sigma and Boltzmann tilt scale
        (volume and damping drive sigma; volume and anisotropy drive
        Delta)."""
        t = float(p.temperature if temperature is None else temperature)
        f = self.lane_factors(corner, n, stream)
        alpha = p.alpha * f[0]
        b_aniso = p.b_aniso * f[1]
        volume = p.volume * f[2]
        g_scale = 1.0 / f[3]
        sigma = np.sqrt(2.0 * alpha * KB * t / (GAMMA * p.ms * volume * dt))
        delta = 0.5 * b_aniso * p.ms * volume / (KB * t)
        theta0 = np.sqrt(1.0 / (2.0 * np.maximum(delta, 1.0)))
        return LaneRows(alpha=alpha, b_aniso=b_aniso, g_scale=g_scale,
                        volume=volume, sigma=sigma, theta0=theta0)

    def sample_device(self, p: DeviceParams, corner_index: int = 0,
                      lane: int = 0, stream: int = 0) -> DeviceSample:
        """One sampled device (lane ``lane`` of the D2D draw) for the scalar
        single-junction paths — ``core.device.simulate_write`` accepts it, so
        the single-device baseline and the campaign engine share one
        definition of what a corner means (parity at variation=0 is exact:
        every factor is then literally 1.0)."""
        f = self.lane_factors(self.corners[corner_index], lane + 1,
                              stream)[:, lane]
        return DeviceSample(
            params=dataclasses.replace(p, alpha=float(p.alpha * f[0]),
                                       b_aniso=float(p.b_aniso * f[1])),
            g_scale=float(1.0 / f[3]),
            volume_factor=float(f[2]),
        )

# Fig. 3 anchor points from the paper (voltage -> (write latency [s], energy [J]))
PAPER_FIG3_AFMTJ: Tuple[Tuple[float, float, float], ...] = (
    (1.0, 164e-12, 55.7e-15),
)
PAPER_FIG3_MTJ: Tuple[Tuple[float, float, float], ...] = (
    (1.0, 1400e-12, 480e-15),
)
# "Switching latency drops from 65 ps at 0.5 V to 20 ps at 1.2 V" (intrinsic
# sublattice reorientation time, excluding circuit RC):
PAPER_INTRINSIC_SWITCH: Tuple[Tuple[float, float], ...] = ((0.5, 65e-12), (1.2, 20e-12))

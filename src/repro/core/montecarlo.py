"""Thermal Monte-Carlo ensembles: write-error rate and retention checks.

Brown's thermal field: per-component std  sigma_B = sqrt(2 alpha k_B T /
(gamma Ms V dt))  [T] — large for the paper's 45x45x0.45 nm cell, which is
why write pulses need margin: WER(pulse) is the MRAM reliability metric a
controller binds against (the paper's pipelined controller assumes a pulse
that covers the thermal tail).

``write_error_rate`` routes through the campaign engine
(``repro.campaign``): the whole thermal ensemble rides one Pallas kernel
launch with in-kernel counter-RNG noise instead of a per-sample
scan-over-steps.  The original pure-jnp path is kept as
``write_error_rate_scan`` — it is the statistical baseline the engine is
benchmarked against (``benchmarks/run.py --only wer``) and a second,
independently-seeded implementation of the same physics.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import llg
from repro.core.device import a_j_from_voltage, thermal_theta0
from repro.core.integrator import rk4_step
from repro.core.params import GAMMA, KB, DeviceParams


def thermal_sigma(p: DeviceParams, dt: float) -> float:
    import math

    return math.sqrt(
        2.0 * p.alpha * KB * p.temperature / (GAMMA * p.ms * p.volume * dt)
    )


def write_error_rate(
    p: DeviceParams,
    voltage: float,
    pulse_s: float,
    n_samples: int = 64,
    dt: float = 0.1e-12,
    n_steps: int = None,
    seed: int = 0,
    backend: str = "pallas",
    use_cache: bool = False,
) -> float:
    """Fraction of thermal samples NOT switched by the end of the pulse.

    Thin wrapper over the campaign engine: builds a single-point (V, pulse)
    grid and reads the WER surface.  ``use_cache=True`` makes repeated
    margin queries (e.g. the IMC write-margin solver) hit the on-disk
    campaign cache.
    """
    # lazy import: campaign builds on core + kernels, so core must not
    # import it at module scope
    from repro.campaign.engine import run_campaign
    from repro.campaign.grid import CampaignGrid

    pulse = float(pulse_s if n_steps is None else n_steps * dt)
    grid = CampaignGrid(voltages=(float(voltage),), pulse_widths=(pulse,),
                        temperatures=(p.temperature,), n_samples=n_samples,
                        dt=dt, seed=seed)
    res = run_campaign(p, grid, backend=backend, use_cache=use_cache)
    return float(res.wer_surface()[0, 0, 0])


@partial(jax.jit, static_argnames=("p", "pulse_s", "n_steps", "n_samples", "dt"))
def write_error_rate_scan(
    p: DeviceParams,
    voltage: float,
    pulse_s: float,
    n_samples: int = 64,
    dt: float = 0.1e-12,
    n_steps: int = None,
    seed: int = 0,
):
    """Reference scan path: per-sample vmap over a scan-over-steps with
    ``jax.random`` (threefry) thermal draws.  O(steps) sequential work per
    sample and ~20x the RNG flops of the kernel's counter-RNG — kept as the
    baseline the campaign engine is measured against, and as an
    independently-seeded cross-check of the WER statistics."""
    n_steps = int(pulse_s / dt) if n_steps is None else n_steps
    sigma = thermal_sigma(p, dt)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_samples)

    def one(key):
        k0, k1, kr = jax.random.split(key, 3)
        th = jnp.abs(jax.random.normal(k0)) * thermal_theta0(p) + 0.01
        ph = jax.random.uniform(k1, maxval=2 * jnp.pi)
        m0 = llg.initial_state(p, theta0=th, phi0=ph)

        def body(carry, step_key):
            m, sw = carry
            aj = a_j_from_voltage(voltage, m, p)
            b_th = sigma * jax.random.normal(step_key, m.shape)
            m = rk4_step(lambda mm, tt: llg.llg_rhs(mm, p, aj, b_th), m, 0.0, dt)
            sw = jnp.logical_or(sw, llg.order_parameter_z(m) < -0.9)
            return (m, sw), None

        (m, sw), _ = jax.lax.scan(body, (m0, jnp.asarray(False)),
                                  jax.random.split(kr, n_steps))
        return sw

    switched = jax.vmap(one)(keys)
    return 1.0 - jnp.mean(switched.astype(jnp.float32))

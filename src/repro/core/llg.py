"""Dual-sublattice Landau-Lifshitz-Gilbert dynamics (paper Sec. II).

State convention: ``m`` has shape ``(..., n_sub, 3)`` — unit magnetization
vectors for each sublattice (n_sub == 2 for AFMTJ, 1 for MTJ).  All functions
broadcast over leading batch/cell dimensions, so the same code runs a single
junction, a subarray, or a Monte-Carlo ensemble.

The paper's equation (per sublattice i):

    dM_i/dt = -gamma M_i x H_eff,i + alpha M_i x dM_i/dt + tau_STT,i + tau_ex,i

with tau_ex,1 = -J_AF M_1 x M_2.  We solve the implicit Gilbert form exactly:
collect every explicit torque T (precession + STT + field-like), then

    dm/dt = (T + alpha m x T) / (1 + alpha^2),

which is algebraically identical to the usual explicit Landau-Lifshitz form
(uses |m| = 1).  The exchange torque is folded into the effective field as
B_ex,i = -B_E m_j, so it participates in both precession and damping — this
is what produces exchange-enhanced (ps-scale) reversal for the AFMTJ.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.params import GAMMA, DeviceParams

# Spin polarization direction: along the easy axis z for both device types.
#
# MTJ: the usual uniform Slonczewski torque on the single FM layer.
#
# AFMTJ: the tunneling current in an all-antiferromagnetic junction carries a
# *Neel spin current* — the momentum-resolved spin polarization tracks the
# staggered order (Shao & Tsymbal, npj Spintronics 2024, paper ref [2]), so
# sublattice i feels polarization s_i * p with s = (+1, -1).  This staggered
# antidamping acts on the Neel mode at linear order; the restoring torque of
# the mode is exchange-stiffened, giving the exchange-enhanced instability
# (growth rate ~ gamma a_J * sqrt(B_E/B_A), threshold ~ 2 alpha sqrt(B_E B_A))
# that produces picosecond reversal — the paper's Table I physics.
P_AXIS = jnp.array([0.0, 0.0, 1.0])


def stt_signs(p: "DeviceParams") -> jnp.ndarray:
    """Per-sublattice STT polarization sign (staggered for the AFMTJ)."""
    if p.n_sublattices == 1:
        return jnp.ones((1, 1))
    return jnp.array([[1.0], [-1.0]])


def cross(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.cross(a, b)


def effective_field(
    m: jnp.ndarray,
    p: DeviceParams,
    b_thermal: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """B_eff per sublattice: anisotropy + inter-sublattice exchange (+ thermal).

    m: (..., n_sub, 3).  Returns same shape, in Tesla.
    """
    ez = jnp.zeros_like(m).at[..., 2].set(1.0)
    # Uniaxial PMA (demag folded into b_aniso as an *effective* field, the
    # standard macrospin treatment):  B_k * m_z * z_hat
    b_anis = p.b_aniso * m[..., 2:3] * ez
    # Inter-sublattice exchange: B_ex,i = -B_E * m_j  (antiparallel coupling).
    # flip(axis=-2) swaps sublattice 1<->2; for n_sub==1 it is the identity,
    # but b_exchange==0 for MTJs so the term vanishes there.
    b_ex = -p.b_exchange * jnp.flip(m, axis=-2)
    b = b_anis + b_ex
    if b_thermal is not None:
        b = b + b_thermal
    return b


def llg_rhs(
    m: jnp.ndarray,
    p: DeviceParams,
    a_j: jnp.ndarray,
    b_thermal: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """dm/dt for every sublattice.  a_j: damping-like STT magnitude [T]
    (scalar or broadcastable to m[..., 0]); sign = current direction.

    For the AFMTJ, the Neel-type STT acts on *both* sublattices with the same
    sign (Cheng et al., PRB 91, 064423) — the staggered torque is what drives
    coherent Neel-vector reversal at picosecond timescales.
    """
    b = effective_field(m, p, b_thermal)
    a_j = jnp.asarray(a_j)[..., None, None]          # broadcast over (n_sub, 3)
    pvec = jnp.broadcast_to(stt_signs(p) * P_AXIS, m.shape)
    # Explicit torques (rad/s):
    t_prec = -GAMMA * cross(m, b)
    t_stt = GAMMA * a_j * cross(m, cross(m, pvec))   # damping-like (Slonczewski)
    t_flt = -GAMMA * (p.beta_flt * a_j) * cross(m, pvec)  # field-like
    t = t_prec + t_stt + t_flt
    # Implicit Gilbert term solved exactly: dm/dt = (T + alpha m x T)/(1+a^2)
    return (t + p.alpha * cross(m, t)) / (1.0 + p.alpha**2)


def neel_vector(m: jnp.ndarray) -> jnp.ndarray:
    """Neel (staggered) vector n = (m1 - m2)/2 for AFMTJ; = m for MTJ."""
    if m.shape[-2] == 1:
        return m[..., 0, :]
    return 0.5 * (m[..., 0, :] - m[..., 1, :])


def net_moment(m: jnp.ndarray) -> jnp.ndarray:
    """Net magnetization (m1 + m2)/2 — near zero for a compensated AFM."""
    return jnp.mean(m, axis=-2)


def order_parameter_z(m: jnp.ndarray) -> jnp.ndarray:
    """z-component of the order parameter used for switching detection."""
    return neel_vector(m)[..., 2]


def initial_state(
    p: DeviceParams,
    theta0: float = 0.0,
    phi0: float = 0.0,
    up: bool = True,
) -> jnp.ndarray:
    """Equilibrium-ish initial state tilted by theta0 from the easy axis.

    AFMTJ: sublattice 1 at +z (tilted), sublattice 2 antiparallel.
    Returns (n_sub, 3).
    """
    s = 1.0 if up else -1.0
    m1 = jnp.array(
        [
            jnp.sin(theta0) * jnp.cos(phi0),
            jnp.sin(theta0) * jnp.sin(phi0),
            s * jnp.cos(theta0),
        ]
    )
    if p.n_sublattices == 1:
        return m1[None, :]
    # Exactly antiparallel partner (Neel-mode tilt, m2 = -m1): the thermal
    # seed tilts the *Neel vector* without injecting exchange energy.
    return jnp.stack([m1, -m1])


def renormalize(m: jnp.ndarray) -> jnp.ndarray:
    """Project back to |m|=1 (RK integrators drift at O(h^5))."""
    return m / jnp.linalg.norm(m, axis=-1, keepdims=True)

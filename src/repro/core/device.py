"""Junction-level operations: write / read (paper Sec. III-B, Fig. 3).

``simulate_write`` integrates the coupled transport+dynamics system: the
instantaneous conductance G(theta(t)) sets the current density, which sets
the STT amplitude a_J(t) — the self-consistent coupling a SPICE testbench
provides.  Switching time is the first crossing of the order parameter below
-0.9; write latency adds the bit-line RC settle time (circuit layer); write
energy is the integral of V^2 G dt over the pulse.

Everything is jit/vmap-friendly; voltage sweeps are a single vmap.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import llg, tmr
from repro.core.integrator import BASE_DT, Trace, integrate_fixed
from repro.core.params import DeviceParams, DeviceSample

# Default thermal tilt of the initial state: theta_0 = sqrt(1/(2 Delta)),
# the equilibrium Boltzmann spread for a macrospin with barrier Delta kT.
def thermal_theta0(p: DeviceParams) -> jnp.ndarray:
    return jnp.sqrt(1.0 / (2.0 * jnp.maximum(p.thermal_stability, 1.0)))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WriteResult:
    t_switch: jnp.ndarray        # intrinsic magnetization reversal time [s]
    write_latency: jnp.ndarray   # t_switch * margin + t_rc  [s]
    energy: jnp.ndarray          # dynamic write energy [J]
    switched: jnp.ndarray        # bool
    final_state: jnp.ndarray


def a_j_from_voltage(v, m: jnp.ndarray, p: DeviceParams) -> jnp.ndarray:
    """Self-consistent STT amplitude [T]: a_J = pref * J = pref * V G(m)/A."""
    g = tmr.conductance(m, p)
    j_density = v * g / p.area
    return p.stt_prefactor * j_density


def simulate_write(
    p: DeviceParams,
    voltage,
    n_steps: int = 30000,
    dt: float = BASE_DT,
    theta0: Optional[float] = None,
    t_rc: float = 40e-12,      # bit-line RC + driver + SA settle (circuit layer)
    pulse_margin: float = 1.02,
    down: bool = True,
    thermal_sigma: float = 0.0,
    rng: Optional[jax.Array] = None,
    variation: Optional[DeviceSample] = None,
) -> WriteResult:
    """Write (switch P -> AP, i.e. order parameter +z -> -z) at ``voltage``.

    The STT amplitude is evaluated self-consistently from the instantaneous
    conductance at every RK4 stage via the time-dependent drive hook below.

    ``variation`` is one sampled device from a process-corner draw
    (``core.params.VariationSpec.sample_device``): its corner/D2D-adjusted
    ``DeviceParams`` replace ``p``, the junction conductance factor scales
    the self-consistent drive, and the default Boltzmann tilt uses the
    volume-adjusted thermal stability — exactly the semantics the campaign
    engine's per-lane variation plane applies (DESIGN.md §9), so the
    scalar baseline and the engine agree on what a corner means.  At the
    nominal corner every factor is literally 1.0 and the result is
    bit-identical to ``variation=None``.
    """
    g_scale = 1.0
    if variation is not None:
        p = variation.params
        g_scale = variation.g_scale
        if theta0 is None:
            theta0 = float(jnp.sqrt(1.0 / (2.0 * jnp.maximum(
                variation.thermal_stability, 1.0))))
    return _simulate_write(p, voltage, g_scale, n_steps=n_steps, dt=dt,
                           theta0=theta0, t_rc=t_rc,
                           pulse_margin=pulse_margin, down=down,
                           thermal_sigma=thermal_sigma, rng=rng)


# thermal_sigma is static: it gates the noise branch with python control
# flow (the wrapper above always forwards it explicitly, so it would
# otherwise be traced — unlike in the pre-variation signature where the
# unpassed default stayed a concrete python float)
@partial(jax.jit, static_argnames=("n_steps", "down", "thermal_sigma"))
def _simulate_write(
    p: DeviceParams,
    voltage,
    g_scale,
    n_steps: int = 30000,
    dt: float = BASE_DT,
    theta0: Optional[float] = None,
    t_rc: float = 40e-12,
    pulse_margin: float = 1.02,
    down: bool = True,
    thermal_sigma: float = 0.0,
    rng: Optional[jax.Array] = None,
) -> WriteResult:
    th0 = thermal_theta0(p) if theta0 is None else theta0
    m0 = llg.initial_state(p, theta0=th0, phi0=0.3, up=down)

    # Self-consistency: fold conductance into the rhs by recomputing a_J from
    # the *current* state each step.  integrate_fixed takes a per-step a_J
    # series; instead we wrap its single-step structure with a custom scan to
    # keep a_J state-dependent.
    def body(carry, key):
        m, t, t_sw, sw, en = carry
        a_j = a_j_from_voltage(voltage, m, p) * g_scale
        if thermal_sigma > 0.0:
            b_th = thermal_sigma * jax.random.normal(key, m.shape)
        else:
            b_th = None
        from repro.core.integrator import rk4_step  # local to avoid cycle

        m_next = rk4_step(lambda mm, tt: llg.llg_rhs(mm, p, a_j, b_th), m, t, dt)
        opz = llg.order_parameter_z(m_next)
        crossed = opz < -0.9 if down else opz > 0.9
        newly = jnp.logical_and(crossed, jnp.logical_not(sw))
        t_sw = jnp.where(newly, t + dt, t_sw)
        sw = jnp.logical_or(sw, crossed)
        g = tmr.conductance(m_next, p) * g_scale
        en = en + jnp.where(sw, 0.0, jnp.asarray(voltage) ** 2 * g * dt)
        return (m_next, t + dt, t_sw, sw, en), None

    if rng is None:
        rng = jax.random.PRNGKey(0)
    keys = jax.random.split(rng, n_steps)
    init = (
        m0,
        jnp.zeros(()),
        jnp.asarray(jnp.inf),
        jnp.asarray(False),
        jnp.zeros(()),
    )
    (m_f, _, t_sw, sw, en), _ = jax.lax.scan(body, init, keys)

    # Write pulse = switching time * margin; energy already integrated up to
    # switch, add the margin tail at the post-switch conductance.
    g_final = tmr.conductance(m_f, p) * g_scale
    tail = (pulse_margin - 1.0) * t_sw
    tail = jnp.where(jnp.isfinite(tail), tail, 0.0)
    # Energy over the full write window: RC/driver overhead at the initial
    # (parallel-state) conductance + the switching pulse + the margin tail.
    g0 = tmr.conductance(m0, p) * g_scale
    energy = (
        en
        + jnp.asarray(voltage) ** 2 * g_final * tail
        + jnp.asarray(voltage) ** 2 * g0 * t_rc
    )
    latency = t_sw * pulse_margin + t_rc
    return WriteResult(
        t_switch=t_sw,
        write_latency=latency,
        energy=energy,
        switched=sw,
        final_state=m_f,
    )


def write_sweep(p: DeviceParams, voltages: jnp.ndarray, **kw) -> WriteResult:
    """Vectorized voltage sweep (paper Fig. 3)."""
    return jax.vmap(lambda v: simulate_write(p, v, **kw))(voltages)


@partial(jax.jit, static_argnames=())
def simulate_read(p: DeviceParams, m: jnp.ndarray, v_read: float = 0.1):
    """Read op: sense current at v_read; returns (current, resistance)."""
    g = tmr.conductance(m, p)
    return v_read * g, 1.0 / g


def read_energy(p: DeviceParams, t_read: float = 1e-9, v_read: float = 0.1) -> float:
    """Worst-case (parallel-state) read energy."""
    return v_read**2 / p.r_parallel * t_read

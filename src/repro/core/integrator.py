"""RK4 integrators for the LLG system (paper: adaptive RK4, 0.1 ps base step).

Two implementations with identical physics:

* ``integrate_fixed`` — fixed-step RK4 under ``lax.scan``.  Regular control
  flow, TPU-native, used by the Pallas kernel and all sweeps.
* ``integrate_adaptive`` — step-doubling adaptive RK4 under ``lax.while_loop``
  (the paper's "adaptive fourth-order Runge-Kutta, 0.1 ps base step").  Used
  to validate that 0.1 ps fixed stepping is converged (see tests).

Both renormalize |m| after every step (the LLG flow conserves |m| exactly;
RK4 drifts at O(h^5)).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.llg import llg_rhs, order_parameter_z, renormalize
from repro.core.params import DeviceParams

BASE_DT = 0.1e-12  # 0.1 ps (paper)

RHS = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]  # (m, t) -> dm/dt


def rk4_step(rhs: RHS, m: jnp.ndarray, t: jnp.ndarray, dt) -> jnp.ndarray:
    k1 = rhs(m, t)
    k2 = rhs(m + 0.5 * dt * k1, t + 0.5 * dt)
    k3 = rhs(m + 0.5 * dt * k2, t + 0.5 * dt)
    k4 = rhs(m + dt * k3, t + dt)
    return renormalize(m + (dt / 6.0) * (k1 + 2.0 * k2 + 2.0 * k3 + k4))


class Trace(NamedTuple):
    """Per-step observables accumulated during integration."""

    t_switch: jnp.ndarray      # first time order parameter crossed -thresh [s]
    switched: jnp.ndarray      # bool
    energy: jnp.ndarray        # integral of V^2 * G(theta) dt  [J]
    final_m: jnp.ndarray       # state at t_end


@partial(jax.jit, static_argnames=("n_steps", "record_trajectory"))
def integrate_fixed(
    m0: jnp.ndarray,
    p: DeviceParams,
    a_j_of_t: jnp.ndarray,        # (n_steps,) or scalar: STT field vs time [T]
    dt: float = BASE_DT,
    n_steps: int = 2000,
    conductance_fn=None,          # optional: (m) -> G [S], for energy integral
    voltage: float = 0.0,
    switch_threshold: float = 0.9,
    record_trajectory: bool = False,
    thermal_sigma: float = 0.0,
    rng: Optional[jax.Array] = None,
) -> Tuple[Trace, Optional[jnp.ndarray]]:
    """Fixed-step RK4 for n_steps.  Broadcasts over leading dims of m0."""
    a_j_of_t = jnp.broadcast_to(jnp.asarray(a_j_of_t), (n_steps,))
    batch_shape = m0.shape[:-2]

    if rng is None:
        rng = jax.random.PRNGKey(0)
    step_keys = jax.random.split(rng, n_steps)

    def rhs_at(m, a_j, b_th):
        return llg_rhs(m, p, a_j, b_th)

    def body(carry, xs):
        m, t, t_sw, sw, en = carry
        a_j, key = xs
        if thermal_sigma > 0.0:
            b_th = thermal_sigma * jax.random.normal(key, m.shape)
        else:
            b_th = None
        # Thermal field held constant across the RK4 substeps (Stratonovich
        # midpoint-ish treatment; standard for LLG+RK4 at dt << 1/f_FMR).
        m_next = rk4_step(lambda mm, tt: rhs_at(mm, a_j, b_th), m, t, dt)
        opz = order_parameter_z(m_next)
        crossed = opz < -switch_threshold
        newly = jnp.logical_and(crossed, jnp.logical_not(sw))
        t_sw = jnp.where(newly, t + dt, t_sw)
        sw = jnp.logical_or(sw, crossed)
        if conductance_fn is not None:
            g = conductance_fn(m_next)
            # stop accumulating energy once the pulse would be cut (post-switch
            # margin handled by the caller); here we integrate the full window
            # gated on "not yet switched" + one step.
            en = en + jnp.where(sw, 0.0, voltage**2 * g * dt)
        out = m_next if record_trajectory else None
        return (m_next, t + dt, t_sw, sw, en), out

    init = (
        m0,
        jnp.zeros(()),
        jnp.full(batch_shape, jnp.inf),
        jnp.zeros(batch_shape, dtype=bool),
        jnp.zeros(batch_shape),
    )
    (m_f, _, t_sw, sw, en), traj = jax.lax.scan(
        body, init, (a_j_of_t, step_keys)
    )
    return Trace(t_switch=t_sw, switched=sw, energy=en, final_m=m_f), traj


@partial(jax.jit, static_argnames=())
def integrate_adaptive(
    m0: jnp.ndarray,
    p: DeviceParams,
    a_j: jnp.ndarray,
    t_end: float,
    dt0: float = BASE_DT,
    rtol: float = 1e-6,
    dt_min: float = 1e-15,
    dt_max: float = 2e-12,
    switch_threshold: float = 0.9,
) -> Trace:
    """Step-doubling adaptive RK4 (single junction; constant drive).

    Error estimate: one full step vs two half steps; local error ~ |y2-y1|/15;
    step accepted when err < rtol, new step = h * clip((rtol/err)^(1/5)).
    """

    def rhs(m, t):
        return llg_rhs(m, p, a_j, None)

    def cond(carry):
        m, t, h, t_sw, sw = carry
        return t < t_end

    def body(carry):
        m, t, h, t_sw, sw = carry
        h = jnp.minimum(h, t_end - t)
        y1 = rk4_step(rhs, m, t, h)
        yh = rk4_step(rhs, m, t, 0.5 * h)
        y2 = rk4_step(rhs, yh, t + 0.5 * h, 0.5 * h)
        err = jnp.max(jnp.abs(y2 - y1)) / 15.0
        accept = err < rtol
        # PI-free step controller with safety 0.9
        scale = 0.9 * (rtol / jnp.maximum(err, 1e-30)) ** 0.2
        h_new = jnp.clip(h * jnp.clip(scale, 0.2, 5.0), dt_min, dt_max)
        m_next = jnp.where(accept, y2, m)
        t_next = jnp.where(accept, t + h, t)
        opz = order_parameter_z(m_next)
        crossed = opz < -switch_threshold
        newly = jnp.logical_and(jnp.logical_and(accept, crossed), jnp.logical_not(sw))
        t_sw = jnp.where(newly, t_next, t_sw)
        sw = jnp.logical_or(sw, jnp.logical_and(accept, crossed))
        return (m_next, t_next, h_new, t_sw, sw)

    init = (m0, jnp.zeros(()), jnp.asarray(dt0), jnp.asarray(jnp.inf), jnp.asarray(False))
    m_f, t_f, _, t_sw, sw = jax.lax.while_loop(cond, body, init)
    return Trace(t_switch=t_sw, switched=sw, energy=jnp.zeros(()), final_m=m_f)

"""Bit-line RC transient model.

A bit line with total capacitance C_bl is precharged to V_pre and discharges
through the parallel conductance of the activated cells (each cell: access
transistor R_on in series with the junction R_j).  The transient is the
classic single-pole exponential

    V_bl(t) = V_pre * exp(-t * G_eff / C_bl),

so settle/charge times are analytic — no netlist solve needed.  Multi-row
activation (the paper's charge-sharing logic) sums activated-cell
conductances; the sense amplifier classifies the resulting current level.

Capacitance scales with the number of rows hanging off the line
(C_bl = rows * c_cell + c_wire_fixed), which is how the hierarchy levels
(L1 subarrays vs main-memory subarrays) get different RC constants.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.params import DeviceParams


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BitlineParams:
    c_per_cell: float = 0.03e-15   # drain + wire capacitance per attached cell [F]
    c_fixed: float = 2.0e-15       # SA input + periphery capacitance [F]
    r_access: float = 1.0e3        # access transistor on-resistance [Ohm]
    r_driver: float = 200.0        # write-driver output resistance [Ohm]
    r_wire_per_cell: float = 0.5   # bit-line wire resistance per row segment [Ohm]
    t_wl_setup: float = 20e-12     # word-line decode/assert overhead [s]
    v_precharge: float = 1.0       # precharge level [V]
    v_read: float = 0.1            # read voltage across the cell [V]
    rows: int = dataclasses.field(default=256, metadata=dict(static=True))

    @property
    def c_total(self) -> float:
        return self.rows * self.c_per_cell + self.c_fixed


def cell_conductance(g_junction: jnp.ndarray, bl: BitlineParams) -> jnp.ndarray:
    """Series combination of access transistor and junction."""
    return g_junction / (1.0 + bl.r_access * g_junction)


def bitline_settle_time(
    g_junction: jnp.ndarray, bl: BitlineParams, settle_frac: float = 0.95
) -> jnp.ndarray:
    """Time for the bit line to settle to within (1-settle_frac) of final value.

    t = ln(1/(1-frac)) * C_bl / G_eff — the RC component of read and of the
    write-path charge-up (the `t_rc` consumed by core.device.simulate_write).
    """
    g_eff = cell_conductance(g_junction, bl)
    return jnp.log(1.0 / (1.0 - settle_frac)) * bl.c_total / g_eff


def write_path_rc(bl: BitlineParams, settle_frac: float = 0.95) -> float:
    """Write-path overhead: the driver (not the cell) charges the bit line."""
    import math

    return math.log(1.0 / (1.0 - settle_frac)) * bl.r_driver * bl.c_total + bl.t_wl_setup


def multi_row_current(
    bits: jnp.ndarray, dev: DeviceParams, bl: BitlineParams
) -> jnp.ndarray:
    """Aggregate read current for multi-row activation (charge sharing).

    bits: (..., n_rows) in {0,1}; bit==1 -> cell in parallel (low-R) state.
    Returns total bit-line current at v_read [A].  This is the analog quantity
    the sense amp classifies into logic outcomes.
    """
    g_p = 1.0 / dev.r_parallel
    g_ap = 1.0 / dev.r_antiparallel
    g_cells = jnp.where(bits > 0, g_p, g_ap)
    g_eff = cell_conductance(g_cells, bl)
    return bl.v_read * jnp.sum(g_eff, axis=-1)


def column_ir_drop(g_column_total: jnp.ndarray, bl: BitlineParams) -> jnp.ndarray:
    """Per-column IR-drop attenuation factor for multi-row analog MVM.

    With every word line driven, the column's aggregate cell current flows
    through the bit-line wire; lumping the distributed line as the average
    cell seeing half the total wire resistance gives the classic one-segment
    approximation

        v_eff / v_drive = 1 / (1 + R_line * G_col),   R_line = r_wire * rows/2.

    ``g_column_total`` is the summed *effective* cell conductance hanging off
    the column (after ``cell_conductance``).  Heavily-loaded columns (more
    low-resistance cells) attenuate more, which is what makes IR drop a
    *column-dependent gain error* rather than a global scale: the mean factor
    calibrates out (one-point ADC gain trim), the spread does not — see
    ``imc.analog_pipeline``.
    """
    r_line = bl.r_wire_per_cell * bl.rows / 2.0
    return 1.0 / (1.0 + r_line * g_column_total)


def logic_current_levels(n_rows: int, dev: DeviceParams, bl: BitlineParams):
    """The n_rows+1 distinct current levels for k parallel-state cells
    (k = 0..n_rows) — used to place sense-amp references."""
    g_p = cell_conductance(jnp.asarray(1.0 / dev.r_parallel), bl)
    g_ap = cell_conductance(jnp.asarray(1.0 / dev.r_antiparallel), bl)
    k = jnp.arange(n_rows + 1)
    return bl.v_read * (k * g_p + (n_rows - k) * g_ap)

"""Latch-type sense amplifier behavioral model.

Delay follows the standard latch regeneration law

    t_sa = tau_latch * ln(V_logic / |dV_in|) + t_setup,

so small input differentials (near-reference currents) sense slower — this is
what makes multi-row logic slightly slower than single-row reads.  Dual
references implement XOR/XNOR (output = current between the two refs), per
Pinatubo-style bit-line computing; single references give (N)AND / (N)OR /
MAJ.

MC mode (DESIGN.md §10): a latch SA has an input-referred offset from
transistor mismatch, ~N(0, ``offset_sigma``).  ``sa_offsets`` draws a
per-lane offset vector from the same stateless counter-RNG the kernels
use (CRN: a fixed seed gives the *same* offsets across corners and
read-voltage ladder points, so yield comparisons are paired per lane);
``sense_delay`` / ``resolve_logic`` accept it as an optional ``offset``
argument.  ``offset=None`` (default) and ``offset_sigma=0`` are both
bit-identical to the deterministic path (pinned by
``tests/test_read_path.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.circuit.bitline import BitlineParams, logic_current_levels, multi_row_current
from repro.core.params import DeviceParams
from repro.kernels import noise


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SenseAmpParams:
    tau_latch: float = 20e-12     # regeneration time constant [s]
    t_setup: float = 20e-12       # precharge/strobe overhead [s]
    v_logic: float = 1.0          # full-swing output [V]
    r_trans: float = 5.0e3        # current->voltage transimpedance [Ohm]
    e_per_sense: float = 2.0e-15  # energy per sense operation [J]
    offset_sigma: float = 0.0     # input-referred offset std [V] (MC mode:
                                  # sa_offsets / sense_delay(offset=...))


# counter-RNG draw index for SA offsets — disjoint from the thermal-field
# counters (kernels.noise.thermal_draws uses step*3 + {0,1,2}; drawing at a
# fixed large counter on a dedicated seed stream keeps streams independent)
_OFFSET_STREAM = 0x5A0FF5E7


def sa_offsets(sa: SenseAmpParams, n: int, seed: int = 0) -> jnp.ndarray:
    """(n,) input-referred offset draws [V] ~ N(0, offset_sigma).

    Stateless counter-RNG (``kernels.noise``), salted only by ``seed`` and
    lane index — never by corner or ladder position — so sweeps reuse the
    same mismatch population (common random numbers).  ``offset_sigma == 0``
    returns exact zeros: the deterministic path.
    """
    if sa.offset_sigma == 0.0:
        return jnp.zeros((n,), jnp.float32)
    lanes = noise.cell_seeds(seed ^ _OFFSET_STREAM, n)
    z, _ = noise.normal_pair(lanes, jnp.uint32(0))
    return (sa.offset_sigma * z).astype(jnp.float32)


def sense_delay(di: jnp.ndarray, sa: SenseAmpParams,
                offset: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Sense time for a current differential di [A] from the reference.

    ``offset`` (optional, [V], broadcast against ``di``) shifts the latch
    input differential: an offset toward the reference slows regeneration
    (and past it, flips the decision — ``resolve_logic`` models that part).
    ``offset=None`` is bit-identical to a zero offset: |di*r + 0| == |di|*r
    exactly in IEEE arithmetic.
    """
    if offset is None:
        dv = jnp.abs(di) * sa.r_trans
    else:
        dv = jnp.abs(di * sa.r_trans + offset)
    dv = jnp.maximum(dv, 1e-6)
    return sa.tau_latch * jnp.log(sa.v_logic / jnp.minimum(dv, sa.v_logic)) + sa.t_setup


def _refs_for(op: str, n_rows: int, dev: DeviceParams, bl: BitlineParams):
    """Reference current(s) placed between the k-parallel-cell levels."""
    lv = logic_current_levels(n_rows, dev, bl)
    mid = lambda a, b: 0.5 * (lv[a] + lv[b])
    if op in ("and", "nand"):       # true when ALL k bits are 1
        return (mid(n_rows - 1, n_rows),)
    if op in ("or", "nor"):         # true when ANY bit is 1
        return (mid(0, 1),)
    if op in ("xor", "xnor"):       # true when exactly one of two bits is 1
        assert n_rows == 2, "xor/xnor uses 2-row activation"
        return (mid(0, 1), mid(1, 2))
    if op == "maj":                 # majority of 3
        assert n_rows == 3
        return (mid(1, 2),)
    raise ValueError(f"unknown logic op {op}")


def resolve_logic(
    bits: jnp.ndarray,
    op: str,
    dev: DeviceParams,
    bl: BitlineParams,
    sa: SenseAmpParams,
    offset: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full circuit path for an in-array logic op on ``bits`` (..., n_rows).

    Returns (boolean output, sense delay).  The output is derived from the
    *analog* current level — i.e. the logic emerges from the device TMR +
    circuit thresholds, not from a lookup table.

    ``offset`` (optional, [V], broadcast against the bit-line current)
    is the SA's input-referred offset (MC mode, ``sa_offsets``): referred
    back to the current domain through ``r_trans`` and added *before* the
    threshold comparison, so a large-enough offset flips the decision —
    that is exactly the sense-yield failure mode the read path measures.
    ``offset=None`` is bit-identical to the deterministic path.
    """
    n_rows = bits.shape[-1]
    i_bl = multi_row_current(bits, dev, bl)
    if offset is not None:
        i_bl = i_bl + offset / sa.r_trans
    refs = _refs_for(op, n_rows, dev, bl)
    if op in ("and", "or", "maj"):
        out = i_bl > refs[0]
        di = i_bl - refs[0]
    elif op in ("nand", "nor"):
        out = i_bl < refs[0]
        di = i_bl - refs[0]
    elif op == "xor":
        out = jnp.logical_and(i_bl > refs[0], i_bl < refs[1])
        di = jnp.minimum(jnp.abs(i_bl - refs[0]), jnp.abs(i_bl - refs[1]))
    elif op == "xnor":
        out = jnp.logical_or(i_bl < refs[0], i_bl > refs[1])
        di = jnp.minimum(jnp.abs(i_bl - refs[0]), jnp.abs(i_bl - refs[1]))
    else:
        raise ValueError(op)
    return out, sense_delay(di, sa)

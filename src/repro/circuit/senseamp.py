"""Latch-type sense amplifier behavioral model.

Delay follows the standard latch regeneration law

    t_sa = tau_latch * ln(V_logic / |dV_in|) + t_setup,

so small input differentials (near-reference currents) sense slower — this is
what makes multi-row logic slightly slower than single-row reads.  Dual
references implement XOR/XNOR (output = current between the two refs), per
Pinatubo-style bit-line computing; single references give (N)AND / (N)OR /
MAJ.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.circuit.bitline import BitlineParams, logic_current_levels, multi_row_current
from repro.core.params import DeviceParams


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SenseAmpParams:
    tau_latch: float = 20e-12     # regeneration time constant [s]
    t_setup: float = 20e-12       # precharge/strobe overhead [s]
    v_logic: float = 1.0          # full-swing output [V]
    r_trans: float = 5.0e3        # current->voltage transimpedance [Ohm]
    e_per_sense: float = 2.0e-15  # energy per sense operation [J]
    offset_sigma: float = 0.0     # input-referred offset [V] (MC mode)


def sense_delay(di: jnp.ndarray, sa: SenseAmpParams) -> jnp.ndarray:
    """Sense time for a current differential di [A] from the reference."""
    dv = jnp.abs(di) * sa.r_trans
    dv = jnp.maximum(dv, 1e-6)
    return sa.tau_latch * jnp.log(sa.v_logic / jnp.minimum(dv, sa.v_logic)) + sa.t_setup


def _refs_for(op: str, n_rows: int, dev: DeviceParams, bl: BitlineParams):
    """Reference current(s) placed between the k-parallel-cell levels."""
    lv = logic_current_levels(n_rows, dev, bl)
    mid = lambda a, b: 0.5 * (lv[a] + lv[b])
    if op in ("and", "nand"):       # true when ALL k bits are 1
        return (mid(n_rows - 1, n_rows),)
    if op in ("or", "nor"):         # true when ANY bit is 1
        return (mid(0, 1),)
    if op in ("xor", "xnor"):       # true when exactly one of two bits is 1
        assert n_rows == 2, "xor/xnor uses 2-row activation"
        return (mid(0, 1), mid(1, 2))
    if op == "maj":                 # majority of 3
        assert n_rows == 3
        return (mid(1, 2),)
    raise ValueError(f"unknown logic op {op}")


def resolve_logic(
    bits: jnp.ndarray,
    op: str,
    dev: DeviceParams,
    bl: BitlineParams,
    sa: SenseAmpParams,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full circuit path for an in-array logic op on ``bits`` (..., n_rows).

    Returns (boolean output, sense delay).  The output is derived from the
    *analog* current level — i.e. the logic emerges from the device TMR +
    circuit thresholds, not from a lookup table.
    """
    n_rows = bits.shape[-1]
    i_bl = multi_row_current(bits, dev, bl)
    refs = _refs_for(op, n_rows, dev, bl)
    if op in ("and", "or", "maj"):
        out = i_bl > refs[0]
        di = i_bl - refs[0]
    elif op in ("nand", "nor"):
        out = i_bl < refs[0]
        di = i_bl - refs[0]
    elif op == "xor":
        out = jnp.logical_and(i_bl > refs[0], i_bl < refs[1])
        di = jnp.minimum(jnp.abs(i_bl - refs[0]), jnp.abs(i_bl - refs[1]))
    elif op == "xnor":
        out = jnp.logical_or(i_bl < refs[0], i_bl > refs[1])
        di = jnp.minimum(jnp.abs(i_bl - refs[0]), jnp.abs(i_bl - refs[1]))
    else:
        raise ValueError(op)
    return out, sense_delay(di, sa)

"""Circuit-level behavioral models (paper Sec. III-B, IV-A).

Replaces the HSPICE netlist with TPU-friendly behavioral physics:
  bitline   — RC transients of precharge/discharge through device conductances
  senseamp  — latch-type sense amplifier: delay vs differential, dual-reference
  subarray  — rows x cols 1T1J array: read / write / multi-row bit-line logic
"""
from repro.circuit.bitline import BitlineParams, bitline_settle_time, multi_row_current  # noqa: F401
from repro.circuit.senseamp import SenseAmpParams, sa_offsets, sense_delay, resolve_logic  # noqa: F401
from repro.circuit.subarray import Subarray, SubarrayTimings, make_subarray  # noqa: F401

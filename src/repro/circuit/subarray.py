"""AFMTJ/MTJ subarray model: rows x cols 1T1J array + periphery.

``make_subarray`` runs the *device* simulation once at the array's write
voltage to extract write latency/energy (the expensive LLG solve), and the
*circuit* models for read/logic timing — producing a ``SubarrayTimings``
record that the IMC hierarchy consumes.  Functional state (the stored bits)
lives in a plain jnp array so whole-array logic ops are vectorized.

Latency model per op (row-granular, all columns in parallel):
  read   : t_bl_settle + t_sa
  logic  : t_bl_settle + t_sa(multi-row differential)  [2-3 activated rows]
  write  : t_write(V) from the LLG device model (incl. bit-line RC); with
           ``write_percentile`` set, the *measured* row write time at that
           percentile of the write-verify retry distribution
           (``imc.write_path``, DESIGN.md §7)
Energy per op = per-column device/SA energy * active columns + driver overhead.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.circuit.bitline import BitlineParams, bitline_settle_time, write_path_rc
from repro.circuit.senseamp import SenseAmpParams, resolve_logic, sense_delay
from repro.core.device import read_energy, simulate_write
from repro.core.params import AFMTJ_PARAMS, MTJ_PARAMS, DeviceParams


@dataclasses.dataclass(frozen=True)
class SubarrayTimings:
    """Per-operation latency [s] / energy-per-bit [J] for one subarray.

    ``t_write``/``e_write_bit`` come from the closed-form single-pulse model
    by default; with ``make_subarray(..., write_percentile=...)`` they are
    *measured* from the write-verify retry distribution (DESIGN.md §7) and
    the ``write_*`` fields carry the retry statistics (1.0 / 0.0 in the
    closed-form case — one pulse, no residual errors by assumption).
    """

    t_read: float
    t_write: float
    t_logic2: float          # 2-row ops (nand/nor/and/or/xor)
    t_logic3: float          # 3-row (majority — the adder carry primitive)
    e_read_bit: float
    e_write_bit: float
    e_logic_bit: float       # 2-row logic: two cells conduct per column
    e_logic3_bit: float      # 3-row logic: three cells conduct per column
    rows: int
    cols: int
    write_attempts: float = 1.0        # mean pulses per cell write
    write_residual_ber: float = 0.0    # bit-error rate left after retries
    write_percentile: float | None = None  # None = closed-form single pulse
    read_yield: float = 1.0            # worst-corner MC sense yield
    read_percentile: float | None = None   # None = deterministic sense time

    @property
    def row_bits(self) -> int:
        return self.cols


@dataclasses.dataclass
class Subarray:
    """Functional + timed subarray."""

    dev: DeviceParams
    bl: BitlineParams
    sa: SenseAmpParams
    timings: SubarrayTimings
    state: jnp.ndarray  # (rows, cols) uint8 bits

    # ---- functional ops (used by tests & the BNN example) -----------------
    def write_row(self, row: int, bits: jnp.ndarray) -> "Subarray":
        self.state = self.state.at[row].set(bits.astype(jnp.uint8))
        return self

    def read_row(self, row: int) -> jnp.ndarray:
        return self.state[row]

    def logic(self, rows: tuple, op: str) -> jnp.ndarray:
        """In-array logic across the given rows, resolved through the analog
        bit-line + sense-amp path (per-column)."""
        bits = self.state[jnp.asarray(rows)]            # (k, cols)
        out, _ = resolve_logic(bits.T, op, self.dev, self.bl, self.sa)
        return out.astype(jnp.uint8)


import functools


@functools.lru_cache(maxsize=None)
def _characterize_write(kind: str, v_write: float):
    """Pure-device write cost (t_rc = 0), cached across subarray builds."""
    dev = AFMTJ_PARAMS if kind == "afmtj" else MTJ_PARAMS
    n_steps, dt = (16000, 0.05e-12) if kind == "afmtj" else (40000, 0.1e-12)
    wr = simulate_write(dev, v_write, n_steps=n_steps, dt=dt, t_rc=0.0)
    return float(wr.write_latency), float(wr.energy)


def _worst_case_logic_delay(op_rows: int, dev, bl, sa) -> float:
    """Max sense delay across all input combinations of a k-row op."""
    combos = np.array(
        [[(i >> b) & 1 for b in range(op_rows)] for i in range(2**op_rows)],
        dtype=np.float32,
    )
    op = "and" if op_rows != 3 else "maj"
    _, delays = resolve_logic(jnp.asarray(combos), op, dev, bl, sa)
    return float(jnp.max(delays))


def make_subarray(
    kind: Literal["afmtj", "mtj"],
    rows: int = 256,
    cols: int = 256,
    v_write: float = 1.0,
    bl: BitlineParams | None = None,
    sa: SenseAmpParams | None = None,
    wer_target: float | None = None,
    write_percentile: float | None = None,
    read_percentile: float | None = None,
) -> Subarray:
    dev = AFMTJ_PARAMS if kind == "afmtj" else MTJ_PARAMS
    bl = bl or BitlineParams(rows=rows)
    sa = sa or SenseAmpParams()

    # --- device-level write characterization (the LLG solve, cached) -------
    t_rc = write_path_rc(bl)
    w_attempts, w_ber = 1.0, 0.0
    if write_percentile is not None:
        # measured stochastic write path (DESIGN.md §7): row write time at
        # the controller percentile of the write-verify retry distribution,
        # mean per-bit energy over issued pulses.  Per-attempt pulse: the
        # WER-ladder pulse when wer_target is also given, device-nominal x
        # thermal margin otherwise.  t_rc rides inside every attempt cycle,
        # so nothing is added on top here.
        from repro.imc.write_path import measured_write_timings

        pulse = None
        if wer_target is not None:
            from repro.imc.write_margin import wer_margined_pulse

            pulse = wer_margined_pulse(kind, v_write, wer_target)
        mw = measured_write_timings(kind, v_write=v_write, cols=cols,
                                    percentile=write_percentile, t_rc=t_rc,
                                    pulse=pulse)
        t_write, e_write = mw.t_write, mw.e_write_bit
        w_attempts, w_ber = mw.attempts_mean, mw.residual_ber
    else:
        t_sw, e_sw = _characterize_write(kind, v_write)
        if wer_target is not None:
            # thermal-tail margin: size the pulse so WER <= target via the
            # Monte-Carlo campaign engine instead of the mean switching time
            from repro.imc.write_margin import wer_margined_pulse

            t_pulse = wer_margined_pulse(kind, v_write, wer_target)
            t_pulse = max(t_pulse, t_sw)
            # the post-switch tail of the pulse burns energy at the written
            # (antiparallel) state's conductance
            e_sw = e_sw + v_write**2 / dev.r_antiparallel * (t_pulse - t_sw)
            t_sw = t_pulse
        # t_rc enters additively (driver charges the line, then the pulse
        # runs); overhead energy at the parallel-state conductance.
        t_write = t_sw + t_rc
        e_write = e_sw + v_write**2 / dev.r_parallel * t_rc

    # --- circuit-level read/logic characterization --------------------------
    g_worst = jnp.asarray(1.0 / dev.r_antiparallel)
    t_settle = float(bitline_settle_time(g_worst, bl))
    r_yield = 1.0
    if read_percentile is not None:
        # measured read path (DESIGN.md §10): percentile sense time over the
        # per-lane (corner x D2D x SA-offset) Monte-Carlo at the worst
        # process corner, plus the worst-corner sense yield.
        from repro.imc.read_path import measured_read_timings

        mr = measured_read_timings(kind, v_read=bl.v_read,
                                   percentile=read_percentile, sa=sa, bl=bl)
        t_sense = mr.t_sense
        r_yield = mr.read_yield
    else:
        i_p = bl.v_read / dev.r_parallel
        i_ap = bl.v_read / dev.r_antiparallel
        t_sense = float(sense_delay(jnp.asarray((i_p - i_ap) / 2.0), sa))
    t_read = t_settle + t_sense
    t_logic2 = t_settle + _worst_case_logic_delay(2, dev, bl, sa)
    t_logic3 = t_settle + _worst_case_logic_delay(3, dev, bl, sa)

    e_read = read_energy(dev, t_read=t_read, v_read=bl.v_read) + sa.e_per_sense
    # k-row logic draws read current through k activated cells for the
    # (slightly longer) k-row sense window
    e_logic = 2.0 * read_energy(dev, t_read=t_logic2, v_read=bl.v_read) + sa.e_per_sense
    e_logic3 = 3.0 * read_energy(dev, t_read=t_logic3, v_read=bl.v_read) + sa.e_per_sense

    timings = SubarrayTimings(
        t_read=t_read,
        t_write=t_write,
        t_logic2=t_logic2,
        t_logic3=t_logic3,
        e_read_bit=e_read,
        e_write_bit=e_write,
        e_logic_bit=e_logic,
        e_logic3_bit=e_logic3,
        rows=rows,
        cols=cols,
        write_attempts=w_attempts,
        write_residual_ber=w_ber,
        write_percentile=write_percentile,
        read_yield=r_yield,
        read_percentile=read_percentile,
    )
    state = jnp.zeros((rows, cols), dtype=jnp.uint8)
    return Subarray(dev=dev, bl=bl, sa=sa, timings=timings, state=state)

"""Sharded, atomic, async checkpointing (no orbax dependency).

Layout: <dir>/step_<N>/
  manifest.json            — step, pytree structure, leaf metadata, host count
  host<k>.msgpack.zst      — this host's addressable shards, zstd-compressed

Properties needed at 1000-node scale:
  * per-host shard files — each host writes only its addressable data
    (O(bytes/host) I/O, no gather);
  * atomic publish — write to step_<N>.tmp, fsync, rename; readers only see
    complete checkpoints, so a node failure mid-save never corrupts state;
  * async — serialization happens on a background thread off the train loop
    (device->host copy is synchronous, the disk write is not);
  * elastic restore — ``restore(..., mesh)`` reshards to whatever mesh the
    restart came up with (e.g. 256 -> 192 chips after losing a node), since
    leaves are stored unsharded per host and re-placed via device_put.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

# zstandard is gated: absent (e.g. minimal containers) we fall back to
# uncompressed msgpack shards (.msgpack instead of .msgpack.zst) — restore
# picks whichever extension exists, so checkpoints stay readable either way
try:
    import zstandard
except ImportError:
    zstandard = None


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", k)) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, host_rank: int = 0, host_count: int = 1,
                 keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.host_rank = host_rank
        self.host_count = host_count
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        self.wait()
        paths, leaves, _ = _flatten_with_paths(tree)
        # device -> host copy must be synchronous (the train loop will donate
        # these buffers on the next step)
        host_leaves = [np.asarray(l) for l in leaves]

        def _write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {
                "step": step,
                "host_count": self.host_count,
                "leaves": [
                    {"path": p, "shape": list(a.shape), "dtype": str(a.dtype)}
                    for p, a in zip(paths, host_leaves)
                ],
            }
            payload = {
                p: (a.tobytes(), str(a.dtype), list(a.shape))
                for p, a in zip(paths, host_leaves)
            }
            blob = msgpack.packb(payload, use_bin_type=True)
            if zstandard is not None:
                blob = zstandard.ZstdCompressor(level=3).compress(blob)
                (tmp / f"host{self.host_rank}.msgpack.zst").write_bytes(blob)
            else:
                (tmp / f"host{self.host_rank}.msgpack").write_bytes(blob)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def steps(self):
        return [
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if p.is_dir() and not p.name.endswith(".tmp")
        ]

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return max(s) if s else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        d = self.dir / f"step_{step}"
        zst = d / f"host{self.host_rank}.msgpack.zst"
        if zst.exists():
            if zstandard is None:
                raise ModuleNotFoundError(
                    "checkpoint was written zstd-compressed; pip install "
                    "-r requirements-dev.txt to restore it")
            blob = zstandard.ZstdDecompressor().decompress(zst.read_bytes())
        else:
            blob = (d / f"host{self.host_rank}.msgpack").read_bytes()
        payload = msgpack.unpackb(blob, raw=False)
        paths, leaves, treedef = _flatten_with_paths(like)
        out = []
        shard_flat = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None else
            [None] * len(leaves)
        )
        for p, ref, sh in zip(paths, leaves, shard_flat):
            raw, dtype, shape = payload[p]
            arr = np.frombuffer(raw, dtype=np.dtype(dtype)).reshape(shape)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

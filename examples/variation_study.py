"""Process-variation study: WER and margined write pulse vs D2D sigma,
AFMTJ vs MTJ (DESIGN.md §9).

Reproduces the qualitative result of the companion driver-co-design paper
(Choudhary & Adegbija, "Device-Circuit Co-Design of Variation-Resilient
Read and Write Drivers for AFMTJ Memories"): device-to-device variation —
not the nominal device — sizes the write pulse a controller must
schedule.  Each D2D sigma level rides as its own process "corner" of one
``VariationSpec``, so the whole (sigma x T x pulse-ladder) scenario space
for a device kind is **one fused campaign launch** (corners are per-lane
kernel data); the reported margin is taken at the worst (T, corner) cell.

Run:  PYTHONPATH=src python examples/variation_study.py [--quick]
"""
import argparse
import dataclasses

from repro.campaign import CampaignGrid, run_campaign
from repro.core.params import (AFMTJ_PARAMS, CORNER_SS, MTJ_PARAMS,
                               VariationSpec)

TEMPS = (300.0, 340.0)
WER_TARGET = 5e-2
# per-kind pulse ladders bracketing the thermal tail, dense enough that
# the sigma-driven margin growth resolves to a rung (MTJ reversal ~10x
# slower; coarser step keeps its horizon tractable on CPU interpret mode)
LADDERS = {
    "afmtj": (tuple(x * 1e-12 for x in
                    (200, 225, 250, 275, 300, 350, 400, 500)), 0.1e-12),
    "mtj": (tuple(x * 1e-12 for x in
                  (1800, 2000, 2200, 2500, 2800, 3200, 3600)), 0.2e-12),
}


def corner_sweep(sigmas):
    """One 'corner' per D2D sigma level, all centered on the slow (ss)
    process corner — the cell the drivers must actually cover."""
    return tuple(
        dataclasses.replace(CORNER_SS, name=f"ss/d2d={s:g}", sigma_alpha=s,
                            sigma_b_aniso=s, sigma_volume=s, sigma_r=s)
        for s in sigmas)


def study(kind, params, sigmas, n_samples):
    pulses, dt = LADDERS[kind]
    spec = VariationSpec(corners=corner_sweep(sigmas))
    grid = CampaignGrid(voltages=(1.0,), pulse_widths=pulses,
                        temperatures=TEMPS, n_samples=n_samples, dt=dt,
                        seed=0, variation=spec)
    res = run_campaign(params, grid)
    wer = res.wer_surface()                        # (n_sigma, n_T, 1, n_P)
    print(f"\n{kind}: {len(sigmas)} sigma levels x {len(TEMPS)} T x "
          f"{n_samples} samples, {len(pulses)}-rung ladder -> "
          f"{res.n_launches} launch(es), {res.elapsed_s:.1f}s"
          f"{' (cache)' if res.from_cache else ''}")
    print(f"  {'D2D sigma':>10} {'WER@' + format(pulses[0]*1e12, '.0f') + 'ps':>12} "
          f"{'margined pulse':>15}")
    out = {}
    for ci, s in enumerate(sigmas):
        worst_wer = wer[ci, :, 0, 0].max()         # shortest rung, worst T
        try:
            pulse = max(res.pulse_for_wer(WER_TARGET, t_index=ti,
                                          corner_index=ci)
                        for ti in range(len(TEMPS)))
            ptxt = f"{pulse*1e12:9.0f} ps"
        except ValueError:
            pulse = float("nan")
            ptxt = "  > ladder"
        out[s] = pulse
        print(f"  {s:>10g} {worst_wer:>12.3f} {ptxt:>15}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer samples / sigma levels (fast sanity run)")
    args = ap.parse_args()
    sigmas = (0.0, 0.2) if args.quick else (0.0, 0.1, 0.2)
    n_samples = 32 if args.quick else 64

    print("WER-margined write pulse vs device-to-device sigma at the slow "
          f"process corner (worst T in {TEMPS} K, WER <= {WER_TARGET:g})")
    margins = {}
    for kind, params in (("afmtj", AFMTJ_PARAMS), ("mtj", MTJ_PARAMS)):
        margins[kind] = study(kind, params, sigmas, n_samples)

    base = {k: margins[k][sigmas[0]] for k in margins}
    print("\nmargin cost of D2D spread (vs the same device at sigma=0):")
    for s in sigmas[1:]:
        row = []
        for k in ("afmtj", "mtj"):
            d = (margins[k][s] - base[k]) * 1e12
            g = margins[k][s] / base[k]
            row.append(f"{k} +{d:.0f} ps ({g:.2f}x)" if g == g
                       else f"{k} n/a")
        print(f"  sigma={s:g}: " + "   ".join(row))
    print("\nBoth devices widen their pulse with D2D spread, but the "
          "AFMTJ's ps-scale exchange-enhanced reversal pays tens of "
          "picoseconds of variation margin where the MTJ pays hundreds — "
          "the nominal ~8x write-latency advantage survives at the worst "
          "(T, corner) cell, which is the headroom the companion paper's "
          "variation-resilient drivers exploit (DESIGN.md §9).")


if __name__ == "__main__":
    main()

"""End-to-end training driver example.

Smoke (CPU, ~2 min): trains a reduced qwen2-family model for 200 steps with
checkpointing + fault-tolerant loop; loss drops from ~6.2 to <4.

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --arch mamba2-780m --steps 50
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", default="200")
    ap.add_argument("--batch", default="8")
    ap.add_argument("--seq", default="128")
    args = ap.parse_args()
    train_main([
        "--arch", args.arch, "--preset", "smoke",
        "--steps", args.steps, "--batch", args.batch, "--seq", args.seq,
        "--lr", "3e-3", "--log-every", "10",
        "--ckpt-dir", "checkpoints/example",
    ])


if __name__ == "__main__":
    main()

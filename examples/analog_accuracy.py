"""Analog read-path accuracy sweep: output error of real decode-step GEMVs
run through the Pallas bitline kernel, across ADC resolution and device TMR.

For each arch the decode-dominant projection (d_model -> FFN fan-out, capped
for interpret-mode CPU runs) is programmed into a differential AFMTJ
crossbar (``imc.analog_pipeline``) and driven with signed activations; the
table reports MSE / normalized MSE / cosine vs the f32 matmul — the paper's
accuracy-under-nonideality axis (TMR ratio, IR drop, ADC resolution) that
the closed-form latency/energy model (``examples/imc_case_study.py``)
cannot see.  The 1-bit XNOR row is the *bnn*-mode floor for comparison.

    PYTHONPATH=src python examples/analog_accuracy.py
"""
from repro.configs.registry import ARCHS
from repro.core.params import VariationSpec
from repro.imc.analog_pipeline import AnalogConfig
from repro.imc.mapping import (accuracy_surface, decode_projection_accuracy,
                               decode_projection_shapes)

SWEEP_ARCHS = ("gemma2-2b", "qwen3-8b", "mamba2-780m")
ADC_BITS = (4, 6, 8)
TMRS = (0.8, 5.0)       # validated ~80% and the theoretical-limit regime
G_SIGMA = 0.05          # 5% lognormal D2D junction-resistance variation,
                        # as a VariationSpec (DESIGN.md §9)
VARIATION = VariationSpec.from_g_sigma(G_SIGMA)
CAPS = dict(cap_k=384, cap_n=256, batch=8)


def main():
    print("=== Analog MVM accuracy vs ADC bits x TMR "
          f"(D2D sigma_r={G_SIGMA}, IR drop on) ===\n")
    for name in SWEEP_ARCHS:
        cfg = ARCHS[name]
        k, n = decode_projection_shapes(cfg, CAPS["cap_k"], CAPS["cap_n"])
        print(f"--- {name}  (decode GEMV {CAPS['batch']}x{k}x{n})")
        print(f"  {'adc_bits':>8} {'tmr':>5} {'mse':>10} {'nmse':>10} "
              f"{'cosine':>8}")
        surf = accuracy_surface(cfg, kind="afmtj", adc_bits=ADC_BITS,
                                tmrs=TMRS, variation=VARIATION, **CAPS)
        for (bits, tmr), r in sorted(surf.items()):
            print(f"  {bits:8d} {tmr:5.1f} {r.mse:10.2e} {r.nmse:10.2e} "
                  f"{r.cosine:8.5f}")
        bnn = decode_projection_accuracy(cfg, kind="afmtj", mode="bnn", **CAPS)
        print(f"  {'bnn(1b)':>8} {'-':>5} {bnn.mse:10.2e} {bnn.nmse:10.2e} "
              f"{bnn.cosine:8.5f}\n")
    print("reading the surface: nmse falls with adc_bits until the IR-drop /"
          "\nvariation floor; higher TMR widens the conductance span, so the"
          "\nsame variation costs relatively less.  The bnn row is the 1-bit"
          "\nquantization floor the paper's XNOR mode accepts for 8x density.")


if __name__ == "__main__":
    main()

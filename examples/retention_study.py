"""Retention study: accelerated-barrier retention, read-disturb and the
refresh policy they imply (DESIGN.md §10).

At the operating barrier (Delta ~ 40 at 300 K) a thermal escape virtually
never happens inside a simulable LLG horizon, so — like a real reliability
lab bakes parts at elevated temperature — the campaign *accelerates* the
physics: composed process corners scale ``b_aniso_factor`` down until
Delta_eff sits in a measurable 2-6 window, escape times are measured per
rung of a log-spaced horizon ladder (ONE fused launch for the whole
(corner x accel x horizon x sample) grid), and an Arrhenius fit

    ln tau = slope * Delta_eff + ln tau0

cross-checks the exponential barrier law before the slope-pinned
extrapolation projects tau back to the operating barrier.  The same
acceleration trick fits the read-disturb suppression Delta_eff(V) =
Delta * (1 - V/V_c)^beta, and the two measurements together set the scrub
interval the system model charges into the Fig. 4 comparison.

Run:  PYTHONPATH=src python examples/retention_study.py [--quick]
"""
import argparse

from repro.campaign.grid import log_pulses
from repro.core.params import CORNER_TT, VariationSpec
from repro.imc.evaluate import evaluate_system, summarize
from repro.imc.read_path import (derive_refresh_policy, fit_disturb_model,
                                 retention_campaign)

SECONDS_PER_YEAR = 3.156e7


def retention_part(quick):
    kw = {}
    if quick:
        kw = dict(accel_factors=(0.05, 0.10), temperatures=(300.0,),
                  horizons=log_pulses(0.15e-9, 1.2e-9, per_decade=3),
                  n_samples=96,
                  variation=VariationSpec(corners=(CORNER_TT,)))
    res = retention_campaign("afmtj", **kw)
    print(f"accelerated retention: {len(res.spec.corners)} corners x "
          f"{len(res.accel_factors)} accel factors x "
          f"{len(res.temperatures)} T -> {res.result.n_launches} launch(es)")
    d_eff = res.delta_eff()
    print(f"  {'corner':>8} {'T[K]':>5} {'Delta_eff':>22} {'tau_acc [ns]':>26} "
          f"{'slope':>6} {'tau_op [s]':>11}")
    tau_op = res.tau_op()
    for ci, c in enumerate(res.spec.corners):
        for ti, temp in enumerate(res.temperatures):
            slope, _ = res.arrhenius_fit(ci, ti)
            taus = "/".join(
                f"{t*1e9:.1f}" if t == t else "-"
                for t in res.tau_acc[ci, ti])
            deffs = "/".join(f"{d:.1f}" for d in d_eff[ci, ti])
            print(f"  {c.name:>8} {temp:5.0f} {deffs:>22} {taus:>26} "
                  f"{slope:6.2f} {tau_op[ci, ti]:11.2e}")
    w = res.worst_tau_op()
    print(f"  worst-corner tau_op {w:.2e} s (~{w/SECONDS_PER_YEAR:.2f} "
          "years); Arrhenius slope ~1 confirms exponential barrier "
          "scaling (Kramers prefactor folds into tau0)")
    return res


def disturb_part(quick, res):
    kw = dict(n_samples=128, horizon=2.5e-9) if quick else {}
    model = fit_disturb_model("afmtj", **kw)
    print(f"\nread-disturb suppression fit (accel x{model.accel_factor:g}, "
          f"Delta_acc {model.delta_acc:.1f}):")
    print(f"  V_c = {model.v_c:.3f} V, beta = {model.beta:.2f} "
          f"(switching threshold ~0.19 V)")
    tau0 = res.tau0(0, 0)
    print(f"  {'V_read':>7} {'Delta_eff':>9} {'p1/read @0.5ns':>14}")
    for v in (0.02, 0.05, 0.10, 0.15):
        d = 40.0 * model.suppression(v)
        p1 = model.p1(v, 0.5e-9, 40.0, tau0)
        print(f"  {v:7.2f} {d:9.1f} {p1:14.2e}")
    print("  the nominal 0.1 V read bias sits too close to V_c: disturb "
        "forces either a derated read bias or an aggressive scrub schedule")


def refresh_part(quick):
    if quick:
        print("\n(refresh-policy derivation needs the full-size campaigns; "
              "rerun without --quick)")
        return
    pol = derive_refresh_policy("afmtj")
    print(f"\nrefresh policy @ {pol.ber_budget:g} BER budget, "
          f"{pol.reads_per_cell_s:g} reads/s/cell:")
    print(f"  retention-limited tau {pol.tau_retention:.2e} s, "
          f"disturb p1 {pol.p1_read:.2e} -> {pol.reads_max:.1f} reads max")
    print(f"  scrub every {pol.interval*1e6:.2f} us ({pol.limited_by}-limited)")
    base = evaluate_system("afmtj")
    wref = evaluate_system("afmtj", refresh=pol)
    sp0, es0 = summarize(base)
    sp1, es1 = summarize(wref)
    print(f"  Fig. 4 avg speedup {sp0:.1f}x -> {sp1:.1f}x, "
          f"energy saving {es0:.1f}x -> {es1:.1f}x with scrub charged")
    for name in ("bnn", "mat_add"):
        r = wref[name]
        print(f"    {name:8s}: refresh {100*r.t_refresh/r.t_imc:.1f}% of "
              f"t_imc, {100*r.e_refresh/r.e_imc:.1f}% of e_imc")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small accelerated grids (fast sanity run)")
    args = ap.parse_args()
    res = retention_part(args.quick)
    disturb_part(args.quick, res)
    refresh_part(args.quick)


if __name__ == "__main__":
    main()

"""IMC case study: the paper's Fig. 4 system-level evaluation + the
beyond-paper mapping of the 10 LM architectures onto the AFMTJ hierarchy.

    PYTHONPATH=src python examples/imc_case_study.py
"""
from repro.configs.registry import ARCHS
from repro.imc.evaluate import evaluate_system, summarize
from repro.imc.mapping import map_all


def main():
    print("=== Hierarchical IMC vs ARM Cortex-A72 (paper Fig. 4) ===\n")
    for kind in ("afmtj", "mtj"):
        res = evaluate_system(kind)
        print(f"--- {kind.upper()}-based IMC")
        for name, r in res.items():
            print(f"  {name:14s} speedup {r.speedup:6.1f}x   "
                  f"energy saving {r.energy_saving:6.1f}x")
        sp, es = summarize(res)
        print(f"  {'AVERAGE':14s} speedup {sp:6.1f}x   energy saving {es:6.1f}x\n")
    print("paper: AFMTJ 17.5x / 19.9x (bnn 55.4x, mat_add 16.5x); MTJ 6x / 2.3x\n")

    print("=== Beyond paper: LM decode on the AFMTJ crossbar hierarchy ===\n")
    out = map_all(ARCHS)
    print(f"{'arch':28s} {'afmtj speedup':>14} {'afmtj energy':>13} "
          f"{'mtj speedup':>12}")
    for name in ARCHS:
        a, m = out["afmtj"][name], out["mtj"][name]
        print(f"{name:28s} {a.speedup:13.1f}x {a.energy_saving:12.1f}x "
              f"{m.speedup:11.1f}x")


if __name__ == "__main__":
    main()

"""Quickstart: simulate AFMTJ vs MTJ write operations (paper Fig. 3).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core.device import simulate_write, write_sweep
from repro.core.params import AFMTJ_PARAMS, MTJ_PARAMS
from repro.core.tmr import tmr_ratio


def main():
    print("=== AFMTJ vs MTJ write characteristics (dual-sublattice LLG) ===\n")
    print(f"AFMTJ: B_exchange={AFMTJ_PARAMS.b_exchange:.2f} T, "
          f"TMR={tmr_ratio(AFMTJ_PARAMS)*100:.0f}%, "
          f"R_P={AFMTJ_PARAMS.r_parallel:.0f} Ohm")
    print(f"MTJ:   single FM layer, TMR={tmr_ratio(MTJ_PARAMS)*100:.0f}%\n")

    voltages = jnp.asarray([0.5, 0.8, 1.0, 1.2])
    a = write_sweep(AFMTJ_PARAMS, voltages, n_steps=16000, dt=0.05e-12)
    m = write_sweep(MTJ_PARAMS, voltages, n_steps=60000, dt=0.1e-12)

    print(f"{'V':>5} | {'AFMTJ lat':>10} {'AFMTJ E':>9} | "
          f"{'MTJ lat':>10} {'MTJ E':>9} | {'speedup':>7}")
    for i, v in enumerate(voltages):
        print(f"{float(v):5.1f} | {float(a.write_latency[i])*1e12:8.0f}ps "
              f"{float(a.energy[i])*1e15:7.1f}fJ | "
              f"{float(m.write_latency[i])*1e12:8.0f}ps "
              f"{float(m.energy[i])*1e15:7.1f}fJ | "
              f"{float(m.write_latency[i]/a.write_latency[i]):6.1f}x")

    r = simulate_write(AFMTJ_PARAMS, 1.0, n_steps=16000, dt=0.05e-12)
    print(f"\n@1.0V: {float(r.write_latency)*1e12:.0f} ps / "
          f"{float(r.energy)*1e15:.1f} fJ  (paper: 164 ps / 55.7 fJ)")
    print("Neel vector reversed:", bool(r.switched))


if __name__ == "__main__":
    main()

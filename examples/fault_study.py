"""Fault study: graceful degradation under hard faults, with and without
repair (DESIGN.md §13).

Injects stuck-at / dead-line defect planes (``FaultSpec``) at increasing
cell-fault rates through three layers of the stack and prints:

1. array yield and cell-area overhead per repair policy (the Poisson
   repair-capacity model — why bare differential arrays are hopeless),
2. model-level accuracy degradation curves (KL and greedy token match of
   a whole analog-routed transformer forward) vs rate × repair policy,
   with the knee where remapping stops saving accuracy,
3. serving SLO attainment on a fixed Poisson trace re-priced under each
   (policy, rate) — the device-time stretch surfacing as tail latency,
4. a crash-resume demonstration: a multi-launch campaign aborted after
   its first launch resumes from slice checkpoints bit-identically.

The whole rate sweep in (2) shares ONE XLA executable per repair policy —
fault rates and seeds ride the kernel's aux operand as data.

Run:  PYTHONPATH=src python examples/fault_study.py [--quick]
"""
import argparse
import tempfile

import numpy as np

RATES = (0.0, 1e-3, 3e-3, 1e-2, 3e-2)
SLO_RATES = (0.0, 1e-4, 3e-4, 1e-3)


def yield_table(rate):
    from repro.imc.faults import (FaultSpec, REPAIR_SPARE, REPAIR_SPARE_ECC)
    from repro.imc.mapping import fault_cost_factors

    spec = FaultSpec.at_rate(rate, seed=0)
    print(f"\n== repair-capacity yield at cell-fault rate {rate:g} ==")
    print(f"{'policy':10s} {'yield':>12s} {'cell_ovh':>9s} {'t_stretch':>10s}")
    for name, pol in (("none", None), ("spare", REPAIR_SPARE),
                      ("spare+ecc", REPAIR_SPARE_ECC)):
        y, ovh, stretch = fault_cost_factors(spec, pol)
        print(f"{name:10s} {y:12.3e} {ovh:9.3f} {stretch:10.3g}")
    print("one uncorrected stuck pair condemns a row: without spares the "
          "Poisson capacity model collapses the yield")


def degradation_table(arch, rates, batch, seq_len):
    from repro.imc.faults import REPAIR_SPARE
    from repro.imc.model_analog import (degradation_knee,
                                        model_degradation_curves)

    print(f"\n== model degradation: {arch} smoke forward, "
          f"batch {batch} x seq {seq_len} ==")
    reports = model_degradation_curves(arch, rates=rates,
                                       policies=(None, REPAIR_SPARE),
                                       batch=batch, seq_len=seq_len)
    by_pol = {}
    for r in reports:
        by_pol.setdefault(r.repair, []).append(r)
    print(f"{'rate':>8s}" + "".join(
        f" {p + '.kl':>10s} {p + '.match':>9s}" for p in by_pol))
    for i, rate in enumerate(rates):
        row = f"{rate:8g}"
        for rs in by_pol.values():
            row += f" {rs[i].kl:10.4f} {rs[i].token_match:9.3f}"
        print(row)
    bar = 0.8 * by_pol["none"][0].token_match
    knees = degradation_knee(reports, min_token_match=bar)
    print(f"knee (largest rate with token match >= {bar:.2f}): "
          + ", ".join(f"{p}={k:g}" for p, k in sorted(knees.items())))
    return reports


def slo_table(rates, n_requests):
    from repro.imc.faults import REPAIR_SPARE
    from repro.launch.simulate import fault_slo_curve

    print(f"\n== serving SLO attainment vs fault rate "
          f"({n_requests} Poisson requests, fixed trace + healthy SLO) ==")
    pts = fault_slo_curve("afmtj", rates=rates,
                          policies=(None, REPAIR_SPARE),
                          n_requests=n_requests)
    print(f"{'policy':8s} {'rate':>8s} {'yield':>10s} {'SLO':>6s} "
          f"{'tpot_p99':>10s} {'tok/J':>10s}")
    for p in pts:
        print(f"{p.repair:8s} {p.fault_rate:8g} {p.array_yield:10.3e} "
              f"{p.slo_attainment:6.3f} {p.tpot_p99_s:10.3e} "
              f"{p.tokens_per_joule:10.3e}")


def resume_demo():
    from repro.campaign.engine import run_campaign
    from repro.campaign.grid import CampaignGrid, bucket_cells
    from repro.core.params import AFMTJ_PARAMS

    print("\n== crash-resumable campaign ==")
    grid = CampaignGrid(voltages=(0.6, 1.2), pulse_widths=(120e-12,),
                        temperatures=(300.0, 350.0), n_samples=16,
                        dt=0.1e-12, seed=0)
    per = bucket_cells(grid.cells)

    class Abort(Exception):
        pass

    def die_early(i, n):
        print(f"  launch {i + 1}/{n} checkpointed ... simulated crash")
        if i == 0:
            raise Abort

    fresh = run_campaign(AFMTJ_PARAMS, grid, backend="ref", use_cache=False,
                         max_cells_per_launch=per)
    with tempfile.TemporaryDirectory() as td:
        try:
            run_campaign(AFMTJ_PARAMS, grid, backend="ref", cache_dir=td,
                         max_cells_per_launch=per, on_slice_complete=die_early)
        except Abort:
            pass
        res = run_campaign(AFMTJ_PARAMS, grid, backend="ref", cache_dir=td,
                           max_cells_per_launch=per)
    same = np.array_equal(np.asarray(res.crossing_time),
                          np.asarray(fresh.crossing_time))
    print(f"  resumed: {res.n_resumed}/{res.n_launches} launches from "
          f"checkpoints, crossing tensor bit-identical={same}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--quick", action="store_true",
                    help="smaller forward + fewer requests (seconds)")
    args = ap.parse_args()
    batch, seq_len = (1, 32) if args.quick else (2, 64)
    rates = (0.0, 3e-3, 1e-2, 3e-2) if args.quick else RATES
    n_requests = 600 if args.quick else 4000

    yield_table(1e-3)
    degradation_table(args.arch, rates, batch, seq_len)
    slo_table(SLO_RATES, n_requests)
    resume_demo()
    print("\nReading the curves: spare-row/col remap + differential-pair "
          "masking extends the accuracy knee by roughly a decade of fault "
          "rate for a few percent cell overhead; past the spare capacity "
          "the curves converge — remapping stops saving accuracy. On the "
          "serving side bare arrays miss SLO almost immediately (the "
          "yield-capped time stretch), while repaired arrays hold "
          "attainment through 1e-3.")


if __name__ == "__main__":
    main()

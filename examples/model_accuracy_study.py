"""Model-level analog accuracy study: whole transformer forwards routed
through the differential AFMTJ MVM (DESIGN.md §12).

Where ``examples/analog_accuracy.py`` scores ONE decode projection, this
study intercepts EVERY linear layer of real (smoke-sized) architectures —
QKV/output projections, the FFN triple, the unembedding — and runs the full
forward on the analog path, so quantization error, IR-drop attenuation and
write faults *compound through depth* the way they would in a deployed
accelerator.  Per surface point the table reports logits KL(ref || analog),
greedy token-match rate and next-token perplexity vs the exact f32 forward,
across adc_bits x TMR x process corner x residual write BER.

Two analog modes ride the same interception hook:

  fake — the fused fake-analog Pallas kernel (program -> IR drop -> ADC in
         one traced pass; >= 10x faster than the device loop, parity pinned
         in tests/test_analog_pipeline.py) — used for the sweep.
  bnn  — every linear through the XNOR popcount path: the 1-bit floor.

    PYTHONPATH=src python examples/model_accuracy_study.py
"""
from repro.imc.analog_pipeline import AnalogConfig
from repro.imc.model_analog import model_accuracy, model_accuracy_surface

SWEEP_ARCHS = ("qwen2-0.5b", "gemma2-2b")   # smoke-sized, real block wiring
ADC_BITS = (4, 6, 8)
TMRS = (0.8, 5.0)          # validated ~80% and the theoretical-limit regime
CORNERS = ("tt", "ss")     # nominal + slow systematic process corner
WRITE_BERS = (0.0, 1e-2)   # perfect programming vs 1% residual write faults
BATCH, SEQ_LEN = 2, 64


def _row(label, r):
    print(f"  {label:>8} {r.tmr:5.1f} {r.corner:>6} {r.write_ber:8.0e} "
          f"{r.kl:9.4f} {r.token_match:7.3f} {r.ppl_analog:9.1f}")


def main():
    print("=== Model-level analog accuracy: full forwards through the "
          "AFMTJ MVM ===\n")
    for arch in SWEEP_ARCHS:
        print(f"--- {arch} (smoke config, batch={BATCH}, seq={SEQ_LEN})")
        print(f"  {'adc_bits':>8} {'tmr':>5} {'corner':>6} {'w_ber':>8} "
              f"{'kl':>9} {'match':>7} {'ppl':>9}")
        surf = model_accuracy_surface(
            arch, adc_bits=ADC_BITS, tmrs=TMRS, corners=CORNERS,
            write_bers=WRITE_BERS, batch=BATCH, seq_len=SEQ_LEN)
        for r in surf:
            _row(str(r.adc_bits), r)
        print(f"  (ppl_ref {surf[0].ppl_ref:.1f})")
        bnn = model_accuracy(arch, AnalogConfig(), mode="bnn",
                             batch=BATCH, seq_len=SEQ_LEN)
        _row("bnn(1b)", bnn)
        print()
    print("reading the surface: KL falls monotonically with adc_bits (the"
          "\ntests/test_model_analog.py golden pin); higher TMR widens the"
          "\nconductance span so the same ADC step costs less; the ss corner"
          "\nshifts every cell systematically and the shared decode gain"
          "\nabsorbs most of it; write faults dominate once BER ~ 1e-2."
          "\nThe bnn row is the 1-bit floor — depth compounds what a single"
          "\nprojection sweep (examples/analog_accuracy.py) understates.")


if __name__ == "__main__":
    main()

"""Serving study: offered load vs tail latency and SLO attainment,
AFMTJ vs MTJ vs CPU (DESIGN.md §11).

Sweeps Poisson offered load through the event-driven serving simulator —
the continuous-batching policy of ``launch.scheduler`` with every token
priced by each technology's ``DeviceCostModel`` — and prints, per
(technology, load) cell: p50/p99 time-to-first-token, p50/p99 per-token
latency, throughput per joule, device utilization, and the fraction of
requests meeting a policy-normalized SLO.

Offered load is normalized to each technology's *own* estimated capacity
(``traffic.rate_for_load``), so the curves are comparable across clocks
that differ by orders of magnitude: every technology shows the same
queueing collapse past its capacity knee; what differs is the absolute
clock — and the case-study point that each generated token's KV append
rides the write path, where AFMTJ's picosecond switching beats MTJ.

Run:  PYTHONPATH=src python examples/serving_study.py [--quick]
"""
import argparse

from repro.configs.registry import ARCHS
from repro.imc.cost_model import device_cost_model, per_token_counts
from repro.launch.report import SLO, build_report
from repro.launch.simulate import simulate_serving
from repro.launch.traffic import CHAT_OUTPUTS, CHAT_PROMPTS, poisson_at_load

TECHS = ("afmtj", "mtj", "cpu")
N_SLOTS = 8


def study(arch, loads, n_requests):
    tc = per_token_counts(ARCHS[arch])
    print(f"arch {arch}: {tc.mac_weights:.3g} weight MACs + "
          f"{tc.kv_elems:.0f} KV elems per token, {N_SLOTS} slots, "
          f"{n_requests} requests per cell")
    header = (f"{'tech':6s} {'load':>5s} {'ttft_p50':>10s} {'ttft_p99':>10s} "
              f"{'tpot_p50':>10s} {'tpot_p99':>10s} {'tok/J':>10s} "
              f"{'util':>5s} {'SLO':>6s}")
    for tech in TECHS:
        prices = device_cost_model(tech).token_prices(tc)
        slo = SLO.normalized(prices, CHAT_PROMPTS, CHAT_OUTPUTS, N_SLOTS)
        print(f"\n[{tech}] t_tok={prices.t_tok:.3e} s  "
              f"t_pos={prices.t_pos:.3e} s/ctx  "
              f"SLO: ttft<={slo.ttft_s:.2e} s tpot<={slo.tpot_s:.2e} s")
        print(header)
        for rho in loads:
            trace = poisson_at_load(prices, rho, n_requests, N_SLOTS,
                                    seed=5).trace()
            res = simulate_serving(prices, trace, n_slots=N_SLOTS)
            rep = build_report(tech, res.ttft_s, res.tpot_s, res.sim_time_s,
                               res.energy_j, res.prefill_tokens,
                               res.decode_tokens, offered_load=rho, slo=slo,
                               busy_s=res.busy_s)
            print(f"{tech:6s} {rho:5.2f} {rep.ttft_p50_s:10.3e} "
                  f"{rep.ttft_p99_s:10.3e} {rep.tpot_p50_s:10.3e} "
                  f"{rep.tpot_p99_s:10.3e} {rep.tokens_per_joule:10.3e} "
                  f"{rep.utilization:5.2f} {rep.slo_attainment:6.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b",
                    help=f"architecture (choices: {sorted(ARCHS)})")
    ap.add_argument("--quick", action="store_true",
                    help="fewer requests and loads (seconds, not minutes)")
    args = ap.parse_args()
    loads = (0.5, 0.95, 2.0) if args.quick else (0.3, 0.5, 0.8, 0.95, 1.1,
                                                 1.5, 2.0)
    n_requests = 5_000 if args.quick else 100_000
    study(args.arch, loads, n_requests)


if __name__ == "__main__":
    main()

"""Array-scale thermal Monte-Carlo write simulation via the campaign engine.

Simulates every cell of an AFMTJ subarray (with per-cell voltage variation
from IR drop *and* 300 K thermal noise in-kernel) through the dual-sublattice
LLG dynamics in one Pallas launch — the TPU-native replacement for the
paper's per-cell SPICE runs.  Reports the write-latency distribution, the
worst-case cell, and the WER(pulse) curve the array controller binds
against (``repro.campaign`` reduces first-crossing steps, so every pulse
width is read off the same integration).

    PYTHONPATH=src python examples/array_mc_sim.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.campaign import run_ensemble
from repro.core import llg
from repro.core.device import thermal_theta0
from repro.core.params import AFMTJ_PARAMS
from repro.imc.write_margin import wer_margined_pulse

ROWS, COLS = 64, 64
DT = 0.1e-12
N_STEPS = 4100          # horizon > the longest WER pulse below (400 ps), so
                        # never-switched cells can't alias a 400 ps success


def main():
    n = ROWS * COLS
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)

    # thermal spread of initial angles + IR-drop voltage gradient down rows
    th0 = float(thermal_theta0(AFMTJ_PARAMS))
    theta = jnp.abs(jax.random.normal(k1, (n,))) * th0 + 0.02
    phi = jax.random.uniform(k2, (n,), maxval=2 * jnp.pi)
    m0 = jax.vmap(lambda t, f: llg.initial_state(AFMTJ_PARAMS, t, f))(theta, phi)
    row = jnp.arange(n) // COLS
    v = 1.0 - 0.15 * (row / ROWS)          # 1.0 V driver, 15% IR drop

    # one engine call: per-cell drives + in-kernel 300 K Langevin field,
    # sharded across however many devices are visible (first call pays the
    # jit compile, so warm before quoting throughput)
    run_ensemble(AFMTJ_PARAMS, m0, v, DT, N_STEPS, seed=0)
    res = run_ensemble(AFMTJ_PARAMS, m0, v, DT, N_STEPS, seed=0)

    t_sw = res.crossing_time * 1e12
    switched = res.switched
    print(f"array {ROWS}x{COLS} @300K: {switched.mean()*100:.1f}% switched "
          f"within {N_STEPS*DT*1e12:.0f} ps  "
          f"({res.elapsed_s*1e6/n:.0f} us/cell, one kernel launch)")
    ok = t_sw[switched]
    print(f"t_switch: mean {ok.mean():.0f} ps, p50 {np.percentile(ok,50):.0f}, "
          f"p99 {np.percentile(ok,99):.0f}, max {ok.max():.0f} ps")

    # WER(pulse) for the whole array falls out of the same first crossings
    print("\npulse_ps  array_WER")
    for pulse in (250e-12, 300e-12, 350e-12, 400e-12):
        wer = float((res.crossing_time > pulse).mean())
        print(f"{pulse*1e12:8.0f}  {wer:.4f}")

    # size the controller pulse at the WORST cell: the far row only sees
    # ~0.85 V after IR drop, and WER rises as drive falls — a margin taken
    # at the 1.0 V driver voltage would under-cover those cells
    v_worst = float(jnp.min(v))
    pulse = wer_margined_pulse("afmtj", v_write=round(v_worst, 2),
                               wer_target=1e-2)
    print(f"\n=> controller pulse for WER<=1e-2 at the worst IR-drop cell "
          f"({v_worst:.2f} V): {pulse*1e12:.0f} ps (campaign-engine margin)")


if __name__ == "__main__":
    main()

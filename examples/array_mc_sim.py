"""Array-scale Monte-Carlo write simulation using the Pallas LLG kernel.

Simulates every cell of an AFMTJ subarray (with per-cell voltage variation
from IR drop) through the dual-sublattice LLG dynamics in one kernel launch
— the TPU-native replacement for the paper's per-cell SPICE runs.  Reports
the write-latency distribution and worst-case cell (what sets the array's
pulse width + write-error margin).

    PYTHONPATH=src python examples/array_mc_sim.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import llg
from repro.core.params import AFMTJ_PARAMS
from repro.kernels import ops

ROWS, COLS = 64, 64
DT = 0.1e-12
N_STEPS = 4000


def main():
    n = ROWS * COLS
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)

    # thermal spread of initial angles + IR-drop voltage gradient down rows
    theta = jnp.abs(jax.random.normal(k1, (n,))) * 0.112 + 0.02
    phi = jax.random.uniform(k2, (n,), maxval=2 * jnp.pi)
    m0 = jax.vmap(lambda t, f: llg.initial_state(AFMTJ_PARAMS, t, f))(theta, phi)
    row = jnp.arange(n) // COLS
    v = 1.0 - 0.15 * (row / ROWS)          # 1.0 V driver, 15% IR drop

    state = ops.pack_states(m0, v)
    out = ops.llg_rk4(state, AFMTJ_PARAMS, DT, N_STEPS)
    _, cross = ops.unpack_states(out, n)

    t_sw = np.asarray(cross) * DT * 1e12
    switched = t_sw < N_STEPS * DT * 1e12
    print(f"array {ROWS}x{COLS}: {switched.mean()*100:.1f}% switched "
          f"within {N_STEPS*DT*1e12:.0f} ps")
    ok = t_sw[switched]
    print(f"t_switch: mean {ok.mean():.0f} ps, p50 {np.percentile(ok,50):.0f}, "
          f"p99 {np.percentile(ok,99):.0f}, max {ok.max():.0f} ps")
    print(f"=> array write pulse must cover the worst cell: "
          f"{ok.max()*1.05 + 40:.0f} ps (margin + RC)")


if __name__ == "__main__":
    main()

"""Write-path study: measured write-verify statistics and the
accuracy-vs-write-energy surface (DESIGN.md §7).

Part 1 sweeps the write operating point: at a *fixed* per-attempt pulse
(the 1.0 V device-nominal x1.5 margin), dropping the drive voltage eats
the STT overdrive, so the retry scheduler pays more attempts and the
residual bit-error rate climbs — the measured (voltage x temperature)
retry/latency/energy maps a write controller schedules against.

Part 2 is the co-design trade the companion write-driver work targets
(PAPERS.md, arXiv 2602.11614): each residual-WER target buys a verify
attempt budget, the scheduler *measures* what that budget costs in write
energy/latency, and the surviving bit errors are injected into the analog
read path (``AnalogConfig.write_ber``) to score a real decode-step GEMV —
accuracy vs write energy, from transients end to end.

    PYTHONPATH=src python examples/write_path_study.py
"""
from repro.configs.registry import ARCHS
from repro.imc.mapping import write_energy_accuracy_surface
from repro.imc.write_path import WritePolicy, write_surface

VOLTAGES = (0.8, 1.0, 1.2)
TEMPS = {"afmtj": (300.0, 375.0), "mtj": (300.0,)}
N_CELLS = 128
ARCH = "gemma2-2b"
WER_TARGETS = (3e-1, 1e-1, 1e-2, 1e-4)
CAPS = dict(cap_k=256, cap_n=128, batch=4)


def main():
    print("=== Write-verify retries vs operating point "
          f"(fixed per-attempt pulse, {N_CELLS} cells) ===\n")
    for kind in ("afmtj", "mtj"):
        pol = WritePolicy(v_write=1.0, max_attempts=6)
        surf = write_surface(kind, voltages=VOLTAGES,
                             temperatures=TEMPS[kind],
                             n_cells=N_CELLS, policy=pol)
        print(f"--- {kind}  (pulse {surf.pulses[0]*1e12:.0f} ps)")
        print(f"  {'T[K]':>5} {'V':>4} {'attempts':>8} {'resid_ber':>9} "
              f"{'lat_mean[ps]':>12} {'e_mean[fJ]':>10}")
        for ti, temp in enumerate(surf.temperatures):
            for vi, v in enumerate(surf.voltages):
                print(f"  {temp:5.0f} {v:4.1f} "
                      f"{surf.attempts_mean[ti, vi, 0]:8.2f} "
                      f"{surf.residual_ber[ti, vi, 0]:9.4f} "
                      f"{surf.latency_mean[ti, vi, 0]*1e12:12.0f} "
                      f"{surf.energy_mean[ti, vi, 0]*1e15:10.1f}")
        print()

    print(f"=== Accuracy vs write energy ({ARCH} decode GEMV, afmtj, "
          "deliberately tight pulse) ===\n")
    # pulse_margin < 1: the per-attempt pulse undershoots the mean switching
    # time, so the WER-target axis actually moves the attempt budget and the
    # energy/accuracy trade is visible (at the default x1.5 margin nearly
    # every cell verifies on the first pulse).
    pol = WritePolicy(v_write=1.0, pulse_margin=0.9)
    surf = write_energy_accuracy_surface(
        ARCHS[ARCH], kind="afmtj", wer_targets=WER_TARGETS, policy=pol,
        n_cells=256, **CAPS)
    print(f"  {'wer_target':>10} {'budget':>6} {'write_ber':>9} "
          f"{'e[fJ/bit]':>9} {'t_mean[ps]':>10} {'nmse':>10} {'cosine':>8}")
    for target in sorted(surf, reverse=True):
        pt = surf[target]
        print(f"  {target:10.0e} {pt.attempts_budget:6d} "
              f"{pt.write_ber:9.1e} {pt.e_write_bit*1e15:9.1f} "
              f"{pt.t_write_mean*1e12:10.0f} {pt.report.nmse:10.2e} "
              f"{pt.report.cosine:8.5f}")
    print("\nreading the surface: each decade of residual-WER target costs "
          "~one more\nverify attempt of write energy/latency; the nmse floor "
          "at tight targets is\nthe read path's own non-ideality (ADC + IR "
          "drop), the blow-up at loose\ntargets is stuck-at-floor cells the "
          "MVM has to eat.")


if __name__ == "__main__":
    main()

"""launch.mesh / launch.sharding / runtime.xla_flags tests (DESIGN.md §14).

Device-count-dependent cases run in subprocesses whose ``XLA_FLAGS`` force
1/4/8 host devices (the flag must precede the child's first jax import);
the 8-device streaming campaign is compared bit-for-bit against this
process's single-device run — the ISSUE's multi-device acceptance pin.
"""
import os
import subprocess
import sys
import textwrap
import warnings
from pathlib import Path

import numpy as np
import pytest

from repro.launch.mesh import CampaignMesh, host_device_flag
from repro.runtime import xla_flags

REPO = Path(__file__).resolve().parents[1]
_ENV = {**os.environ, "PYTHONPATH": str(REPO / "src")}


def _forced_env(n_devices: int) -> dict:
    env = dict(_ENV)
    old = env.get("XLA_FLAGS", "").strip()
    flag = host_device_flag(n_devices)
    env["XLA_FLAGS"] = f"{old} {flag}".strip() if old else flag
    return env


def _run_child(src: str, *argv: str, env: dict, timeout: float = 560.0):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(src), *argv],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, r.stderr
    return r


# --------------------------------------------------------------- meshes
def test_host_device_flag():
    assert host_device_flag(8) == "--xla_force_host_platform_device_count=8"


def test_campaign_mesh_validation():
    m = CampaignMesh(n_devices=4)
    assert m.process_count == 1 and m.process_index == 0
    for bad in (dict(n_devices=0), dict(n_devices=1, process_count=0),
                dict(n_devices=1, process_index=2, process_count=2),
                dict(n_devices=1, claim_ttl_s=0.0),
                dict(n_devices=1, poll_s=0.0)):
        with pytest.raises(AssertionError):
            CampaignMesh(**bad)


@pytest.mark.parametrize("n_dev", [1, 4, 8])
def test_mesh_construction_forced_devices(n_dev):
    """make_local_mesh and build_campaign_mesh see exactly the forced
    device count, and the campaign mesh clamps requests to it."""
    child = textwrap.dedent("""
        import sys
        import jax
        from repro.launch.mesh import (build_campaign_mesh, data_axes,
                                       make_local_mesh)

        n = int(sys.argv[1])
        assert jax.device_count() == n, jax.devices()
        mesh = make_local_mesh()
        assert mesh.devices.shape == (n, 1)
        assert mesh.axis_names == ("data", "model")
        assert data_axes(mesh) == ("data",)
        if n % 2 == 0:
            mesh2 = make_local_mesh(model=2)
            assert mesh2.devices.shape == (n // 2, 2)

        cm = build_campaign_mesh()
        assert cm.n_devices == n and cm.process_count == 1
        assert build_campaign_mesh(devices=2 * n).n_devices == n   # clamp
        assert build_campaign_mesh(devices=1).n_devices == 1
    """)
    _run_child(child, str(n_dev), env=_forced_env(n_dev))


def test_resolve_pspec_roundtrip_four_devices():
    """Sharding-rule resolution on a real (data=2, model=2) mesh: dividing
    dims map to their mesh axes, non-dividing dims drop to replicated, and
    a device_put through the resolved spec round-trips the array."""
    child = textwrap.dedent("""
        import jax
        import numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.launch.sharding import resolve_pspec

        assert jax.device_count() == 4
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        rules = {"embed": ("data",), "ffn": ("model",), "both": ("data",
                 "model")}

        assert resolve_pspec((8, 6), ("embed", "ffn"), rules, mesh) == \\
            P("data", "model")
        # 7 % 2 != 0: the embed axis drops, ffn still shards
        assert resolve_pspec((7, 6), ("embed", "ffn"), rules, mesh) == \\
            P(None, "model")
        # multi-axis rule needs divisibility by the axis product
        assert resolve_pspec((8,), ("both",), rules, mesh) == \\
            P(("data", "model"))
        assert resolve_pspec((6,), ("both",), rules, mesh) == P(None)
        # an axis already used by another dim is not reused
        assert resolve_pspec((8, 8), ("embed", "embed"), rules, mesh) == \\
            P("data", None)

        x = np.arange(8 * 6, dtype=np.float32).reshape(8, 6)
        spec = resolve_pspec(x.shape, ("embed", "ffn"), rules, mesh)
        y = jax.device_put(x, NamedSharding(mesh, spec))
        assert len(y.sharding.device_set) == 4
        np.testing.assert_array_equal(np.asarray(y), x)   # round-trip
    """)
    _run_child(child, env=_forced_env(4))


def test_eight_device_streaming_campaign_matches_one_device(tmp_path):
    """ISSUE acceptance: an 8-host-device smoke campaign — streaming
    reduction sharded over all 8 — produces WER counts and latency
    histograms bit-identical to this process's 1-device run."""
    child = textwrap.dedent("""
        import sys
        import numpy as np
        import jax
        from repro.campaign import CampaignGrid, run_campaign
        from repro.core.params import AFMTJ_PARAMS
        from repro.launch.mesh import build_campaign_mesh

        assert jax.device_count() == 8, jax.devices()
        mesh = build_campaign_mesh()
        assert mesh.n_devices == 8
        grid = CampaignGrid(voltages=(0.6, 1.2),
                            pulse_widths=(120e-12, 250e-12),
                            temperatures=(300.0, 350.0), n_samples=16,
                            dt=0.1e-12, seed=9)
        res = run_campaign(AFMTJ_PARAMS, grid, backend="ref",
                           use_cache=False, reduce="stream", n_bins=128,
                           mesh=mesh)
        assert res.reduced
        np.savez(sys.argv[1], wer=res.wer_counts, hist=res.latency_hist)
    """)
    out = tmp_path / "eight.npz"
    _run_child(child, str(out), env=_forced_env(8))

    from repro.campaign import CampaignGrid, run_campaign
    from repro.core.params import AFMTJ_PARAMS
    grid = CampaignGrid(voltages=(0.6, 1.2), pulse_widths=(120e-12, 250e-12),
                        temperatures=(300.0, 350.0), n_samples=16,
                        dt=0.1e-12, seed=9)
    ref = run_campaign(AFMTJ_PARAMS, grid, backend="ref", use_cache=False,
                       reduce="stream", n_bins=128, devices=1)
    got = np.load(out)
    np.testing.assert_array_equal(got["wer"], ref.wer_counts)
    np.testing.assert_array_equal(got["hist"], ref.latency_hist)


# ------------------------------------------------------------ xla flags
def test_flags_for_gpu_scaling_profile():
    s = xla_flags.flags_for("gpu-scaling")
    assert "--xla_gpu_enable_latency_hiding_scheduler=true" in s
    assert "--xla_gpu_all_reduce_combine_threshold_bytes=134217728" in s
    assert len(s.split()) == len(xla_flags.PROFILES["gpu-scaling"])


def test_flags_for_host_devices_formats_n():
    assert xla_flags.flags_for("host-devices", n=8) == host_device_flag(8)


def test_flags_for_unknown_profile_raises():
    with pytest.raises(KeyError, match="unknown XLA profile"):
        xla_flags.flags_for("nope")


def test_apply_profile_merges_preserving_existing_flags():
    env = {"XLA_FLAGS": "--xla_abc=1", "OTHER": "x"}
    out = xla_flags.apply_profile("host-devices", env, n=4)
    assert out["XLA_FLAGS"] == f"--xla_abc=1 {host_device_flag(4)}"
    assert out["OTHER"] == "x"
    assert env["XLA_FLAGS"] == "--xla_abc=1"      # input env not mutated
    out2 = xla_flags.apply_profile("gpu-scaling", {})
    assert out2["XLA_FLAGS"] == xla_flags.flags_for("gpu-scaling")


def test_apply_profile_refuses_live_process():
    """jax is initialized in this test process (campaign imports), so an
    env=None apply must warn and leave XLA_FLAGS unmerged."""
    import jax

    jax.devices()                                  # ensure backend is up
    assert xla_flags.jax_initialized()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = xla_flags.apply_profile("gpu-scaling")
    assert any(issubclass(x.category, RuntimeWarning) for x in w)
    assert out.get("XLA_FLAGS", "") == os.environ.get("XLA_FLAGS", "")

"""Model-level analog accuracy (DESIGN.md §12): golden regression pins for
whole-transformer forwards routed through the analog MVM, the
weight-programming cache contract, and the linear-interception hook.

Property tests use hypothesis when installed (requirements-dev.txt) and
skip through ``_hypothesis_stub`` otherwise; every property has an executed
pinned companion, so the invariants stay enforced in the stock environment.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # property tests skip; pinned companions still run
    from _hypothesis_stub import given, settings, st

from repro.circuit.bitline import BitlineParams
from repro.core.params import CORNER_FF, CORNER_SS, CORNER_TT, VariationSpec
from repro.imc.analog_pipeline import (AnalogConfig, binary_matmul,
                                       program_weights)
from repro.imc.model_analog import (_setup, analog_model_logits,
                                    logit_metrics, model_accuracy_surface,
                                    model_forward_logits, param_tree_hash,
                                    program_weights_cached, programming_key)

BATCH, SEQ = 2, 64      # every test reuses this shape -> one compile per mode


@pytest.fixture(scope="module")
def qwen_state():
    """(cfg, params, tokens, ref_logits) for the 2-layer qwen2 smoke arch."""
    return _setup("qwen2-0.5b", True, BATCH, SEQ, 0)


@pytest.fixture(scope="module")
def qwen_surface():
    return model_accuracy_surface("qwen2-0.5b", adc_bits=(4, 6, 8),
                                  tmrs=(5.0,), batch=BATCH, seq_len=SEQ)


# --- golden regression pins --------------------------------------------------

def test_golden_kl_pin(qwen_surface):
    """The (adc_bits=8, TMR=5.0, tt, write_ber=0) qwen2 point: logits KL and
    token match pinned against the measured reference values."""
    r = next(r for r in qwen_surface if r.adc_bits == 8)
    assert r.corner == "tt" and r.write_ber == 0.0 and r.tmr == 5.0
    assert r.kl == pytest.approx(0.0155, rel=0.2)
    assert r.token_match > 0.7
    # analog perplexity stays within a few percent of the exact forward
    assert abs(np.log(r.ppl_analog / r.ppl_ref)) < 0.05


def test_kl_monotonic_in_adc_bits(qwen_surface):
    kl = {r.adc_bits: r.kl for r in qwen_surface}
    assert kl[4] > kl[6] > kl[8], kl
    match = {r.adc_bits: r.token_match for r in qwen_surface}
    assert match[8] > match[4]


def test_fake_vs_device_model_level(qwen_state, tmp_path):
    """Differential harness: the fused fake path and the per-projection
    device loop agree at the *logits* level, and the programming cache
    round-trips the device forward bit-identically."""
    cfg, params, tokens, _ = qwen_state
    acfg = AnalogConfig(adc_bits=8, tmr=5.0)
    y_dev = analog_model_logits(params, cfg, tokens, acfg, mode="device",
                                cache_dir=str(tmp_path))
    y_dev2 = analog_model_logits(params, cfg, tokens, acfg, mode="device",
                                 cache_dir=str(tmp_path))   # all cache hits
    assert np.array_equal(np.asarray(y_dev), np.asarray(y_dev2))
    y_fake = analog_model_logits(params, cfg, tokens, acfg)
    kl, match, _, _ = logit_metrics(y_dev, y_fake, tokens)
    assert abs(kl) < 1e-4 and match == 1.0, (kl, match)


# --- interception hook -------------------------------------------------------

def test_intercept_scope_and_reshape():
    """The hook sees 2D activations, tags flow through, and the context
    manager restores the previous hook on exit."""
    from repro.models.common import intercept_linears, linear

    calls = []

    def hook(x2, w, tag):
        calls.append((tag, x2.shape))
        return x2 @ w

    x, w = jnp.ones((2, 3, 4)), jnp.ones((4, 5))
    with intercept_linears(hook):
        y = linear(x, w, "t")
    assert y.shape == (2, 3, 5) and calls == [("t", (6, 4))]
    linear(x, w, "t")                       # hook gone outside the context
    assert len(calls) == 1


def test_forward_routes_every_linear(qwen_state):
    """Every projection of every layer plus the unembedding goes through
    the hook; an identity hook reproduces the reference logits."""
    cfg, params, tokens, ref_logits = qwen_state
    tags = []

    def hook(x2, w, tag):
        tags.append(tag)
        return x2 @ w

    y = model_forward_logits(params, cfg, tokens, hook)
    n_layers = cfg.n_pattern_repeats * len(cfg.pattern)
    for t in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"):
        assert tags.count(t) == n_layers, (t, tags)
    assert tags.count("unembed") == 1
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref_logits),
                               rtol=1e-5, atol=1e-4)


def test_bnn_mode_matches_manual_hook(qwen_state):
    """mode="bnn" is exactly the XNOR projection under the hook, for both
    tie conventions."""
    cfg, params, tokens, _ = qwen_state
    for tie in (1, -1):
        y_mode = analog_model_logits(params, cfg, tokens, AnalogConfig(),
                                     mode="bnn", tie=tie)
        y_hook = model_forward_logits(
            params, cfg, tokens,
            lambda x2, w, tag, t=tie: binary_matmul(x2, w, tie=t))
        np.testing.assert_allclose(np.asarray(y_mode), np.asarray(y_hook),
                                   rtol=1e-5, atol=1e-4)


# --- mapping wiring ----------------------------------------------------------

def test_mapping_model_surface(qwen_surface):
    """``mapping.accuracy_surface(model=...)`` returns model-level reports
    keyed like the projection surface."""
    from repro.configs.registry import ARCHS
    from repro.imc.mapping import accuracy_surface

    surf = accuracy_surface(ARCHS["qwen2-0.5b"], adc_bits=(8,), tmrs=(5.0,),
                            model="fake", batch=BATCH, seq_len=SEQ)
    assert set(surf) == {(8, 5.0)}
    r = surf[(8, 5.0)]
    assert r.mode == "fake" and r.arch == "qwen2-0.5b"
    ref = next(q for q in qwen_surface if q.adc_bits == 8)
    assert r.kl == pytest.approx(ref.kl, rel=1e-6)


# --- weight-programming cache: content key + round-trip ----------------------

def _tree(seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(4, 3)).astype(np.float32)
    b = rng.normal(size=(2,)).astype(np.float32)
    return a, b


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16))
def test_param_tree_hash_order_property(seed):
    a, b = _tree(seed)
    t1 = {"x": {"p": a, "q": b}, "y": [a, b]}
    t2 = {"y": [a, b], "x": {"q": b, "p": a}}
    assert param_tree_hash(t1) == param_tree_hash(t2)


def test_param_tree_hash_order_pinned():
    """Content key is stable under dict-key reordering and sensitive to
    values and to which path holds which leaf."""
    a, b = _tree(0)
    t1 = {"x": {"p": a, "q": b}, "y": [a, b]}
    t2 = {"y": [a, b], "x": {"q": b, "p": a}}
    assert param_tree_hash(t1) == param_tree_hash(t2)
    assert param_tree_hash({"x": {"p": a + 1, "q": b}, "y": [a, b]}) \
        != param_tree_hash(t1)
    assert param_tree_hash({"x": {"p": b, "q": a}, "y": [a, b]}) \
        != param_tree_hash(t1)


def _ss_d2d(sigma=0.05):
    return dataclasses.replace(CORNER_SS, sigma_r=float(sigma))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**10))
def test_crn_corner_invariance_property(seed):
    w = jnp.asarray(np.random.default_rng(seed).normal(size=(96, 40)),
                    jnp.float32)
    multi = VariationSpec(corners=(CORNER_FF, CORNER_TT, _ss_d2d()),
                          seed=seed)
    direct = VariationSpec(corners=(_ss_d2d(),), seed=seed)
    g1 = program_weights(w, "afmtj", AnalogConfig(variation=multi.at_corner(2))
                         ).g_diff
    g2 = program_weights(w, "afmtj", AnalogConfig(variation=direct)).g_diff
    assert np.array_equal(np.asarray(g1), np.asarray(g2))


def test_crn_corner_invariance_pinned():
    """D2D draws are salted by (seed, stream, param), NOT by the corner's
    position in the spec — the same corner programs the same cells whether
    it sits alone or inside a multi-corner spec (the CRN contract that
    keeps corner sweeps comparable)."""
    w = jnp.asarray(np.random.default_rng(5).normal(size=(96, 40)),
                    jnp.float32)
    multi = VariationSpec(corners=(CORNER_FF, CORNER_TT, _ss_d2d()), seed=2)
    direct = VariationSpec(corners=(_ss_d2d(),), seed=2)
    g1 = program_weights(w, "afmtj",
                         AnalogConfig(variation=multi.at_corner(2))).g_diff
    g2 = program_weights(w, "afmtj", AnalogConfig(variation=direct)).g_diff
    assert np.array_equal(np.asarray(g1), np.asarray(g2))
    # different spec seed -> different draws (the salt is live)
    g3 = program_weights(w, "afmtj", AnalogConfig(
        variation=dataclasses.replace(direct, seed=3))).g_diff
    assert not np.array_equal(np.asarray(g2), np.asarray(g3))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**10))
def test_cache_hit_identical_property(seed, tmp_path_factory):
    w = jnp.asarray(np.random.default_rng(seed).normal(size=(70, 30)),
                    jnp.float32)
    td = str(tmp_path_factory.mktemp("cache"))
    cfg = AnalogConfig(adc_bits=6, seed=seed % 7)
    a1 = program_weights_cached(w, "afmtj", cfg, cache_dir=td)
    a2 = program_weights_cached(w, "afmtj", cfg, cache_dir=td)
    assert np.array_equal(np.asarray(a1.g_diff), np.asarray(a2.g_diff))


def test_cache_hit_identical_pinned(tmp_path):
    """A hit reconstructs the exact conductance plane + calibration
    scalars the miss computed — bit-for-bit, faults and IR drop included."""
    w = jnp.asarray(np.random.default_rng(1).normal(size=(130, 70)),
                    jnp.float32)
    cfg = AnalogConfig(adc_bits=6, tmr=5.0, write_ber=0.01, seed=1)
    a1 = program_weights_cached(w, "afmtj", cfg, cache_dir=str(tmp_path))
    a2 = program_weights_cached(w, "afmtj", cfg, cache_dir=str(tmp_path))
    assert np.array_equal(np.asarray(a1.g_diff), np.asarray(a2.g_diff))
    for f in ("w_scale", "g_fs", "att_mean", "g_rms"):
        assert getattr(a1, f) == getattr(a2, f), f
    # and both equal a fresh (uncached) programming
    a3 = program_weights(w, "afmtj", cfg)
    assert np.array_equal(np.asarray(a3.g_diff), np.asarray(a2.g_diff))


def test_programming_key_axes(tmp_path):
    """Read-out knobs (adc_bits / full_scale_sigmas / v_read) reuse the
    programming; everything that changes the cells re-keys."""
    w = jnp.asarray(np.random.default_rng(2).normal(size=(64, 32)),
                    jnp.float32)
    bl = BitlineParams(rows=64)
    base = AnalogConfig(adc_bits=6)
    k0 = programming_key(w, "afmtj", base, bl)
    for ro in (dataclasses.replace(base, adc_bits=8),
               dataclasses.replace(base, full_scale_sigmas=6.0),
               dataclasses.replace(base, v_read=0.2)):
        assert programming_key(w, "afmtj", ro, bl) == k0
    for rp in (dataclasses.replace(base, tmr=5.0),
               dataclasses.replace(base, write_ber=0.01),
               dataclasses.replace(base, seed=9),
               dataclasses.replace(base, ir_drop=False)):
        assert programming_key(w, "afmtj", rp, bl) != k0
    assert programming_key(w, "mtj", base, bl) != k0
    assert programming_key(w, "afmtj", base, BitlineParams(rows=128)) != k0
    assert programming_key(w + 1, "afmtj", base, bl) != k0

"""Functional analog read path: signed ADC, shape padding, tie conventions,
differential programming exactness, nonideality ordering, and the BNN
density accounting."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.imc.analog_pipeline import (AnalogConfig, analog_matmul,
                                       binary_matmul, mvm_accuracy,
                                       program_weights)
from repro.kernels import ops, ref

REPO = Path(__file__).resolve().parents[1]


# --- satellite: signed ADC ---------------------------------------------------

def test_adc_preserves_negative_currents():
    """Regression for the clip(0,1) bug: signed bit-line currents must pass
    the ADC with a non-zero negative contribution."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    v = jax.random.normal(k1, (16, 128))            # signed drives
    g = jax.random.normal(k2, (128, 32)) * 1e-4     # signed differential G
    ideal = np.asarray(v @ g)
    out = np.asarray(ops.bitline_mac(v, g, adc_bits=6, i_max=2e-3))
    assert (out < 0).any(), "ADC zeroed every negative current"
    # negative entries must track the ideal sign, not be clipped to zero
    neg = ideal < -1e-4
    assert neg.any()
    assert np.mean(np.sign(out[neg]) == -1) > 0.99
    # and agree with the jnp oracle
    np.testing.assert_allclose(out, np.asarray(
        ref.ref_bitline_mac(v, g, adc_bits=6, i_max=2e-3)),
        rtol=1e-5, atol=2e-3 / 31 * 1.001)


def test_adc_symmetric_transfer():
    """Quantizer is odd: q(-i) == -q(i) (symmetric full scale, no 0/1 bias)."""
    from repro.kernels.bitline_mac import adc_quantize

    i = jnp.linspace(0.0, 2.0, 201)
    np.testing.assert_allclose(np.asarray(adc_quantize(-i, 5, 1.0)),
                               -np.asarray(adc_quantize(i, 5, 1.0)), atol=0)
    q = adc_quantize(jnp.asarray([-5.0, 5.0]), 5, 1.0)
    assert float(q[0]) == -1.0 and float(q[1]) == 1.0


# --- padding: non-128-multiple shapes ---------------------------------------

@pytest.mark.parametrize("shape", [(3, 200, 77), (65, 130, 190), (1, 1, 1),
                                   (129, 127, 128)])
def test_bitline_mac_padded_parity(shape):
    m, k, n = shape
    v = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    g = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 3.4e-4
    out_k = np.asarray(ops.bitline_mac(v, g))
    out_r = np.asarray(ref.ref_bitline_mac(v, g))
    assert out_k.shape == (m, n)
    np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-8)


@pytest.mark.parametrize("shape", [(3, 200, 77), (130, 190, 65)])
def test_xnor_gemm_padded_parity(shape):
    m, k, n = shape
    a = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (m, k)))
    w = jnp.sign(jax.random.normal(jax.random.PRNGKey(3), (k, n)))
    out_k = np.asarray(ops.xnor_gemm(a, w, binarize=True))
    out_r = np.asarray(ref.ref_xnor_gemm(a, w, binarize=True))
    assert out_k.shape == (m, n)
    np.testing.assert_allclose(out_k, out_r, atol=0)


# --- satellite: XNOR tie convention -----------------------------------------

@pytest.mark.parametrize("tie", [1, -1])
def test_xnor_binarize_tie(tie):
    """Even-K exact ties must land on the requested side, kernel == oracle."""
    k = 128                                   # even: a @ w can be exactly 0
    a = jnp.concatenate([jnp.ones((8, k // 2)), -jnp.ones((8, k // 2))], 1)
    w = jnp.ones((k, 16))                     # every output is an exact tie
    out_k = np.asarray(ops.xnor_gemm(a, w, binarize=True, tie=tie))
    out_r = np.asarray(ref.ref_xnor_gemm(a, w, binarize=True, tie=tie))
    assert (out_k == tie).all(), out_k
    np.testing.assert_allclose(out_k, out_r, atol=0)


def test_xnor_default_tie_matches_seed_convention():
    """Default tie=+1 keeps the seed's ``acc >= 0 -> +1`` behavior."""
    a = jnp.asarray([[1.0, -1.0]])
    w = jnp.asarray([[1.0], [1.0]])
    assert float(ops.xnor_gemm(a, w, binarize=True)[0, 0]) == 1.0


# --- tentpole: differential programming + analog MVM -------------------------

def _wx(k=200, n=150, m=7, seed=0):
    kw, kx = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kw, (k, n)) / k**0.5,
            jax.random.normal(kx, (m, k)))


def test_ideal_path_is_exact():
    """No ADC, no IR drop, no variation: the differential encoding + decode
    chain must reproduce x @ w to float tolerance (odd shapes included)."""
    w, x = _wx()
    arr = program_weights(w, "afmtj", AnalogConfig(adc_bits=0, ir_drop=False))
    y = np.asarray(analog_matmul(arr, x))
    y_ref = np.asarray(x @ w)
    np.testing.assert_allclose(y, y_ref, rtol=2e-5,
                               atol=2e-5 * np.abs(y_ref).max())


def test_programming_is_differential_and_physical():
    """Per-cell conductances stay within the device span; negative weights
    live on the negative cell (g_diff < 0 there)."""
    w, _ = _wx()
    arr = program_weights(w, "afmtj", AnalogConfig(adc_bits=0, ir_drop=False))
    sign_match = np.sign(np.asarray(arr.g_diff)) == np.sign(np.asarray(w))
    assert sign_match.mean() > 0.999
    assert np.abs(np.asarray(arr.g_diff)).max() <= arr.g_fs * (1 + 1e-6)


def test_signed_activations_through_fixed_adc():
    """Acceptance: signed activations pass the fixed ADC with a verified
    non-zero negative-current contribution in the *quantized* output."""
    w, x = _wx()
    arr = program_weights(w, "afmtj", AnalogConfig(adc_bits=6, ir_drop=False))
    y = np.asarray(analog_matmul(arr, x))
    y_ref = np.asarray(x @ w)
    assert (y < 0).sum() > 0.3 * y.size          # negatives survive the ADC
    assert np.corrcoef(y.ravel(), y_ref.ravel())[0, 1] > 0.99


def test_adc_bits_monotonic():
    w, x = _wx()
    nmse = {b: mvm_accuracy(w, x, cfg=AnalogConfig(adc_bits=b)).nmse
            for b in (4, 6, 8)}
    assert nmse[4] > nmse[6] > nmse[8], nmse


def test_higher_tmr_tolerates_variation_better():
    """At fixed D2D variation the wider conductance span (higher TMR) must
    give a lower relative error — the paper's TMR-matters claim."""
    from repro.core.params import VariationSpec

    w, x = _wx()
    var = VariationSpec.from_g_sigma(0.05)     # DESIGN.md §9 D2D spec
    lo = mvm_accuracy(w, x, cfg=AnalogConfig(adc_bits=8, tmr=0.8,
                                             variation=var))
    hi = mvm_accuracy(w, x, cfg=AnalogConfig(adc_bits=8, tmr=5.0,
                                             variation=var))
    assert hi.nmse < lo.nmse / 2, (lo.nmse, hi.nmse)


def test_ir_drop_is_column_gain_error():
    """IR drop on its own (no ADC/variation) leaves a small per-column gain
    spread after mean calibration — bounded, not catastrophic."""
    w, x = _wx()
    arr = program_weights(w, "afmtj", AnalogConfig(adc_bits=0, ir_drop=True))
    assert arr.att_mean < 1.0
    r = mvm_accuracy(w, x, cfg=AnalogConfig(adc_bits=0, ir_drop=True))
    assert r.nmse < 0.05 and r.cosine > 0.97, (r.nmse, r.cosine)


def test_bnn_mode_correlates():
    w, x = _wx()
    y = np.asarray(binary_matmul(x, w))
    y_ref = np.asarray(x @ w)
    assert np.corrcoef(y.ravel(), y_ref.ravel())[0, 1] > 0.5


# --- tentpole: fused fake-analog path vs the device path ---------------------
# The fake kernel replays programming inside the matmul tiles (DESIGN.md
# §12); these pins keep it numerically indistinguishable from the
# program_weights -> kernel_operands -> analog_matmul chain.

def _device_fake_pair(w, x, cfg, **fake_kw):
    """(device output, fake output, i_max) with the device path's exact ADC
    full scale fed to the fake kernel — isolates cell math from the
    decimal-vs-binary 2-significant-digit rounding."""
    from repro.imc.analog_pipeline import kernel_operands
    from repro.imc.model_analog import fake_analog_matmul

    arr = program_weights(w, "afmtj", cfg)
    _, i_max, _ = kernel_operands(arr, x)
    y_dev = np.asarray(analog_matmul(arr, x))
    y_fake = np.asarray(fake_analog_matmul(w, x, cfg=cfg, i_max=i_max,
                                           **fake_kw))
    return y_dev, y_fake, i_max


@pytest.mark.parametrize("shape", [(5, 200, 77), (3, 130, 190)])
@pytest.mark.parametrize("bits", [4, 6, 8])
def test_fake_analog_parity(shape, bits):
    """Odd shapes x ADC resolutions: decoded outputs agree to f32-vs-f64
    decode rounding (the only remaining difference in the chain)."""
    m, k, n = shape
    w, x = _wx(k=k, n=n, m=m, seed=bits)
    y_dev, y_fake, _ = _device_fake_pair(w, x, AnalogConfig(adc_bits=bits))
    np.testing.assert_allclose(y_fake, y_dev, rtol=1e-5,
                               atol=1e-5 * np.abs(y_dev).max())


def test_fake_analog_default_fullscale_parity():
    """With the fake path sizing its own ADC full scale (traceable
    2-significant-digit rounding vs the device's string round-trip) the
    decoded outputs still agree tightly on random data."""
    from repro.imc.model_analog import fake_analog_matmul

    w, x = _wx()
    cfg = AnalogConfig(adc_bits=6)
    y_dev = np.asarray(analog_matmul(program_weights(w, "afmtj", cfg), x))
    y_fake = np.asarray(fake_analog_matmul(w, x, cfg=cfg))
    np.testing.assert_allclose(y_fake, y_dev, rtol=1e-4,
                               atol=1e-4 * np.abs(y_dev).max())


def test_fake_analog_raw_currents_bit_equal():
    """Acceptance pin: at zero IR drop with a shared ADC full scale the
    *quantized bit-line currents* are bit-equal between the two paths."""
    from repro.imc.analog_pipeline import kernel_operands
    from repro.imc.model_analog import fake_analog_matmul

    w, x = _wx()
    cfg = AnalogConfig(adc_bits=6, ir_drop=False)
    arr = program_weights(w, "afmtj", cfg)
    v, i_max, _ = kernel_operands(arr, x)
    i_dev = np.asarray(ops.bitline_mac(v, arr.g_diff, 6, i_max=i_max))
    i_fake = np.asarray(fake_analog_matmul(w, x, cfg=cfg, i_max=i_max,
                                           decode=False))
    assert np.array_equal(i_fake, i_dev)


def test_fake_analog_signed_currents():
    """Signed activations keep their negative contributions through the
    fused quantize -> decode chain."""
    from repro.imc.model_analog import fake_analog_matmul

    w, x = _wx()
    y = np.asarray(fake_analog_matmul(w, x, cfg=AnalogConfig(adc_bits=6)))
    y_ref = np.asarray(x @ w)
    assert (y < 0).sum() > 0.3 * y.size
    assert np.corrcoef(y.ravel(), y_ref.ravel())[0, 1] > 0.99


def test_fake_analog_write_ber_parity():
    """Residual write faults draw the identical Bernoulli stream on both
    paths (same fold_in salt), so faulty cells land identically."""
    w, x = _wx(k=130, n=100, m=5)
    cfg = AnalogConfig(adc_bits=6, write_ber=0.02, seed=3)
    y_dev, y_fake, _ = _device_fake_pair(w, x, cfg)
    np.testing.assert_allclose(y_fake, y_dev, rtol=1e-5,
                               atol=1e-5 * np.abs(y_dev).max())


@pytest.mark.parametrize("corner", ["ss", "ff"])
def test_fake_analog_corner_parity(corner):
    """Systematic process corners round-trip through the access FET exactly
    as the device path's lane factors do."""
    from repro.core.params import PROCESS_CORNERS, VariationSpec

    w, x = _wx(k=130, n=100, m=5, seed=7)
    cfg = AnalogConfig(adc_bits=6, variation=VariationSpec(
        corners=(PROCESS_CORNERS[corner],)))
    y_dev, y_fake, _ = _device_fake_pair(w, x, cfg)
    np.testing.assert_allclose(y_fake, y_dev, rtol=1e-5,
                               atol=1e-5 * np.abs(y_dev).max())


def test_fake_analog_d2d_raises():
    """Per-cell D2D spreads are device-path-only; the fake path must refuse
    rather than silently drop the variation."""
    from repro.core.params import VariationSpec
    from repro.imc.model_analog import fake_analog_matmul

    w, x = _wx(k=64, n=32, m=2)
    cfg = AnalogConfig(adc_bits=6,
                       variation=VariationSpec.from_g_sigma(0.05))
    with pytest.raises(NotImplementedError):
        fake_analog_matmul(w, x, cfg=cfg)


def test_fake_kernel_matches_oracle():
    """Kernel vs jnp oracle on raw operands (odd shape, FET + fail planes
    active): the Pallas tile replay equals the whole-array reference."""
    from repro.kernels.fake_analog import (AUX_ROWS, ROW_ATT_NEG, ROW_ATT_POS,
                                           ROW_DECODE, ROW_G_AP, ROW_G_FS,
                                           ROW_G_SCALE, ROW_I_MAX,
                                           ROW_R_ACCESS, fake_analog_mac_pallas)

    m, k, n = 5, 150, 70
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    v = jax.random.normal(ks[0], (m, k)) * 0.1
    wn = jnp.tanh(jax.random.normal(ks[1], (k, n)))
    fail = jax.random.randint(ks[2], (k, n), 0, 4).astype(jnp.float32)
    att = 0.9 + 0.1 * jax.random.uniform(ks[3], (2, n))
    aux = jnp.zeros((AUX_ROWS, n), jnp.float32)
    aux = aux.at[ROW_ATT_POS].set(att[0]).at[ROW_ATT_NEG].set(att[1])
    aux = aux.at[ROW_I_MAX].set(2e-3).at[ROW_DECODE].set(1234.5)
    aux = aux.at[ROW_G_AP].set(2e-4).at[ROW_G_FS].set(3e-4)
    aux = aux.at[ROW_G_SCALE].set(1.05).at[ROW_R_ACCESS].set(1e3)
    kw = dict(adc_bits=5, apply_fet=True, use_fail=True)
    out_k = np.asarray(fake_analog_mac_pallas(v, wn, fail, aux,
                                              interpret=True, **kw))
    out_r = np.asarray(ref.ref_fake_analog(v, wn, fail, aux, **kw))
    assert out_k.shape == (m, n)
    np.testing.assert_allclose(out_k, out_r, rtol=1e-6, atol=1e-6 * 1234.5)


# --- mapping wiring ----------------------------------------------------------

def test_accuracy_surface_shape():
    from repro.imc.mapping import accuracy_surface

    surf = accuracy_surface(ARCHS["qwen2-0.5b"], adc_bits=(4, 8), tmrs=(0.8,),
                            cap_k=128, cap_n=64, batch=4)
    assert set(surf) == {(4, 0.8), (8, 0.8)}
    for r in surf.values():
        assert r.arch == "qwen2-0.5b" and 0.0 < r.cosine <= 1.0


def test_bnn_tiles_8x_fewer():
    """Satellite: 8-bit weights occupy 8 cells, binarized 1 — the BNN map
    must use exactly 8x fewer crossbar tiles."""
    from repro.imc.hierarchy import build_hierarchy
    from repro.imc.mapping import map_arch_decode

    hier = build_hierarchy("afmtj")
    for name in ("qwen2-0.5b", "gemma2-2b"):
        r = map_arch_decode(ARCHS[name], hier)
        assert r.tiles == pytest.approx(8.0 * r.tiles_bnn)
        assert r.t_imc_bnn < r.t_imc        # denser + ADC-free => faster


# --- sharded batch axis ------------------------------------------------------

def test_sharded_mvm_matches_single_device():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro.imc.analog_pipeline import AnalogConfig, program_weights, analog_matmul
kw, kx = jax.random.split(jax.random.PRNGKey(0))
w = jax.random.normal(kw, (200, 150)) / 200**0.5
x = jax.random.normal(kx, (7, 200))          # odd batch: pad + shard
arr = program_weights(w, "afmtj", AnalogConfig(adc_bits=6))
y4 = np.asarray(analog_matmul(arr, x, devices=4))
y1 = np.asarray(analog_matmul(arr, x, devices=1))
print("SHARDED_OK", np.allclose(y4, y1, rtol=1e-5, atol=1e-7))
"""
    r = subprocess.run([sys.executable, "-c", code],
                       env={**os.environ, "PYTHONPATH": str(REPO / "src")},
                       capture_output=True, text=True, timeout=300)
    assert "SHARDED_OK True" in r.stdout, r.stderr[-2000:]


# --- satellite: 3-row logic energy -------------------------------------------

def test_logic3_energy_exceeds_logic2():
    """3-row majority conducts through three cells: its per-bit energy must
    exceed the 2-row ops', and by less than the naive 2x."""
    from repro.circuit.subarray import make_subarray

    for kind in ("afmtj", "mtj"):
        tm = make_subarray(kind, rows=8, cols=4).timings
        assert tm.e_logic3_bit > tm.e_logic_bit, kind
        assert tm.e_logic3_bit < 2.0 * tm.e_logic_bit, kind

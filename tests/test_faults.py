"""Hard-fault injection and graceful degradation (DESIGN.md §13): fault-code
planes through the fused kernel, CRN pairing across repair policies, repair
semantics, the repair-capacity yield model, and cost/system charging.

The acceptance pins live here: fault-free paths stay bit-identical when a
zero-rate spec is present, kernel and oracle agree bit-for-bit on raw
currents with fault planes active, and a fault-rate sweep adds zero XLA
compiles (rates are data; the repair policy is the compile key)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.imc import faults as hf
from repro.imc.analog_pipeline import (AnalogConfig, analog_matmul,
                                       kernel_operands, program_weights)
from repro.imc.faults import (FaultSpec, REPAIR_NONE, REPAIR_SPARE,
                              REPAIR_SPARE_ECC, apply_repair,
                              column_ok_plane, fault_code_plane)
from repro.imc.model_analog import fake_analog_matmul
from repro.kernels import ops, ref
from repro.kernels.fake_analog import (FAULT_DEAD, FAULT_NEG_OFF,
                                       FAULT_NEG_ON, FAULT_POS_OFF,
                                       FAULT_POS_ON, fail_bit)


def _wx(k=200, n=150, m=7, seed=0):
    kw, kx = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kw, (k, n)) / k**0.5,
            jax.random.normal(kx, (m, k)))


# --- defect planes -----------------------------------------------------------

def test_zero_rate_plane_is_empty():
    """Uniforms live in (0, 1], so ``u <= 0`` is never true: a zero-rate
    spec draws the exactly-empty defect map."""
    code = fault_code_plane(64, 48, seed=np.uint32(0), stuck_on=0.0,
                            stuck_off=0.0, dead_row=0.0)
    col = column_ok_plane(48, seed=np.uint32(0), dead_col=0.0)
    assert np.array_equal(np.asarray(code), np.zeros((64, 48), np.float32))
    assert np.array_equal(np.asarray(col), np.ones((48,), np.float32))


def test_monotone_coupling_across_rates():
    """The u <= rate threshold test shares uniforms across rates, so the
    defective set at a lower rate is a subset of the set at a higher one
    (a defect never heals when the rate goes up)."""
    lo, hi = FaultSpec.at_rate(3e-3, seed=5), FaultSpec.at_rate(3e-2, seed=5)
    c_lo, k_lo = (np.asarray(a) for a in lo.planes(256, 128))
    c_hi, k_hi = (np.asarray(a) for a in hi.planes(256, 128))
    assert ((c_lo > 0) <= (c_hi > 0)).all()
    assert (k_hi <= k_lo).all()              # dead columns only accumulate
    assert (c_hi > 0).sum() > (c_lo > 0).sum()


def test_crn_invariance_across_policies():
    """The defect draw depends only on (seed, stream, lane) — never on the
    repair policy — and ``apply_repair`` consumes no RNG: every policy
    transforms the IDENTICAL map, and repair only ever *removes* or
    *reclassifies* defects (repaired defect positions are a subset)."""
    spec = FaultSpec.at_rate(1e-2, seed=3)
    code, col = spec.planes(256, 128)
    code2, col2 = spec.planes(256, 128)
    assert np.array_equal(np.asarray(code), np.asarray(code2))
    assert np.array_equal(np.asarray(col), np.asarray(col2))
    for pol in (REPAIR_SPARE, REPAIR_SPARE_ECC):
        rc, rk = apply_repair(code, col, pol)
        assert ((np.asarray(rc) > 0) <= (np.asarray(code) > 0)).all()
        assert (np.asarray(rk) >= np.asarray(col)).all()   # revive only


def test_apply_repair_semantics_hand_built():
    """ECC clears the first stuck pair per row, masking converts remaining
    stuck-ON shorts to dead pairs, the worst row is remapped to a spare,
    and one dead column is revived."""
    code = np.zeros((4, 4), np.float32)
    code[0, 0] = FAULT_POS_ON                 # short: ECC eats it (1st/row)
    code[1, 0] = FAULT_NEG_OFF                # ECC eats it
    code[1, 2] = FAULT_POS_ON                 # 2nd stuck in row -> masked
    code[2, :] = FAULT_DEAD                   # dead row: worst row
    col = np.array([1.0, 0.0, 1.0, 0.0], np.float32)
    pol = hf.RepairPolicy(name="t", spare_rows=1, spare_cols=1,
                          mask_pairs=True, ecc_cells_per_row=1)
    rc, rk = (np.asarray(a) for a in
              apply_repair(jnp.asarray(code), jnp.asarray(col), pol))
    assert rc[0, 0] == 0.0 and rc[1, 0] == 0.0        # ECC corrections
    assert rc[1, 2] == FAULT_DEAD                     # masked short
    assert (rc[2] == 0.0).all()                       # spare-row remap
    assert rk[1] == 1.0 and rk[3] == 0.0              # one column revived
    # REPAIR_NONE / None are strict passthroughs
    for pol0 in (None, REPAIR_NONE):
        pc, pk = apply_repair(jnp.asarray(code), jnp.asarray(col), pol0)
        assert np.array_equal(np.asarray(pc), code)
        assert np.array_equal(np.asarray(pk), col)


def test_endurance_wear_folds_into_stuck_off():
    s = FaultSpec(wear_per_cycle=1e-6, write_cycles=1e5)
    assert s.wear_rate == pytest.approx(1.0 - (1.0 - 1e-6) ** 1e5)
    assert s.stuck_off_effective == pytest.approx(s.wear_rate)
    assert s.any_faults
    both = FaultSpec(stuck_off_rate=0.01, wear_per_cycle=1e-6,
                     write_cycles=1e5)
    assert both.stuck_off_effective > max(0.01, s.wear_rate)
    assert not FaultSpec().any_faults


# --- kernel vs oracle with fault codes ---------------------------------------

def test_fault_codes_kernel_matches_oracle():
    """The full 7-bit fault alphabet (write-ber floors + stuck-at + dead)
    through the Pallas kernel equals the jnp oracle on raw operands."""
    from repro.kernels.fake_analog import (AUX_ROWS, ROW_ATT_NEG, ROW_ATT_POS,
                                           ROW_DECODE, ROW_G_AP, ROW_G_FS,
                                           ROW_G_SCALE, ROW_I_MAX,
                                           ROW_R_ACCESS, FAIL_CODE_MAX,
                                           fake_analog_mac_pallas)

    m, k, n = 5, 150, 70
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    v = jax.random.normal(ks[0], (m, k)) * 0.1
    wn = jnp.tanh(jax.random.normal(ks[1], (k, n)))
    fail = jax.random.randint(ks[2], (k, n), 0,
                              int(FAIL_CODE_MAX) + 1).astype(jnp.float32)
    aux = jnp.zeros((AUX_ROWS, n), jnp.float32)
    aux = aux.at[ROW_ATT_POS].set(0.95).at[ROW_ATT_NEG].set(0.93)
    aux = aux.at[ROW_I_MAX].set(2e-3).at[ROW_DECODE].set(1234.5)
    aux = aux.at[ROW_G_AP].set(2e-4).at[ROW_G_FS].set(3e-4)
    aux = aux.at[ROW_G_SCALE].set(1.0).at[ROW_R_ACCESS].set(1e3)
    kw = dict(adc_bits=5, apply_fet=False, use_fail=True)
    out_k = np.asarray(fake_analog_mac_pallas(v, wn, fail, aux,
                                              interpret=True, **kw))
    out_r = np.asarray(ref.ref_fake_analog(v, wn, fail, aux, **kw))
    np.testing.assert_allclose(out_k, out_r, rtol=1e-6, atol=1e-6 * 1234.5)


def test_fail_bit_decode_alphabet():
    """``fail_bit`` reads every bit of every representable code exactly."""
    bits = (1.0, 2.0, FAULT_POS_OFF, FAULT_NEG_OFF, FAULT_POS_ON,
            FAULT_NEG_ON, FAULT_DEAD)
    codes = jnp.arange(128.0)
    for b in bits:
        expect = (np.arange(128) & int(b)) > 0
        got = np.asarray(fail_bit(codes, b))
        assert np.array_equal(got, expect), b


# --- fault-free bit-identity -------------------------------------------------

def test_fake_zero_rate_spec_bit_identical():
    """Presence of an all-zero-rate spec traces the fault machinery in but
    produces the empty defect map — outputs bit-identical to faults=None."""
    w, x = _wx()
    base = AnalogConfig(adc_bits=6)
    zero = dataclasses.replace(base, faults=FaultSpec.at_rate(0.0))
    y0 = np.asarray(fake_analog_matmul(w, x, cfg=base))
    yz = np.asarray(fake_analog_matmul(w, x, cfg=zero))
    assert np.array_equal(y0, yz)


def test_device_zero_rate_spec_bit_identical():
    """Same pin on the device programming path, IR drop included (the
    live-column calibration keeps the no-fault association exactly)."""
    w, x = _wx(k=130, n=100, m=5)
    base = AnalogConfig(adc_bits=6)
    zero = dataclasses.replace(base, faults=FaultSpec.at_rate(0.0),
                               repair=REPAIR_SPARE)
    a0 = program_weights(w, "afmtj", base)
    az = program_weights(w, "afmtj", zero)
    assert np.array_equal(np.asarray(a0.g_diff), np.asarray(az.g_diff))
    assert a0.att_mean == az.att_mean
    y0 = np.asarray(analog_matmul(a0, x))
    yz = np.asarray(analog_matmul(az, x))
    assert np.array_equal(y0, yz)


# --- device vs fake parity with faults active --------------------------------

def _fault_cfg(rate=1e-2, repair=None, **kw):
    return AnalogConfig(adc_bits=6, faults=FaultSpec.at_rate(rate, seed=2),
                        repair=repair, **kw)


def test_device_fake_fault_raw_currents_bit_equal():
    """With stuck-at + dead-line planes active (no IR drop, shared full
    scale) the quantized bit-line currents are bit-equal between the device
    programming path and the fused fake kernel."""
    w, x = _wx()
    for repair in (None, REPAIR_SPARE):
        cfg = _fault_cfg(ir_drop=False, repair=repair)
        arr = program_weights(w, "afmtj", cfg)
        v, i_max, _ = kernel_operands(arr, x)
        i_dev = np.asarray(ops.bitline_mac(v, arr.g_diff, 6, i_max=i_max))
        i_fake = np.asarray(fake_analog_matmul(w, x, cfg=cfg, i_max=i_max,
                                               decode=False))
        assert np.array_equal(i_fake, i_dev), repair


def test_device_fake_fault_decoded_parity():
    """Decoded outputs with faults + repair + IR drop agree to f32 decode
    rounding — the dead-column live-mean calibration matches on both paths."""
    w, x = _wx(k=130, n=100, m=5, seed=4)
    cfg = _fault_cfg(repair=REPAIR_SPARE)
    arr = program_weights(w, "afmtj", cfg)
    _, i_max, _ = kernel_operands(arr, x)
    y_dev = np.asarray(analog_matmul(arr, x))
    y_fake = np.asarray(fake_analog_matmul(w, x, cfg=cfg, i_max=i_max))
    np.testing.assert_allclose(y_fake, y_dev, rtol=1e-5,
                               atol=1e-5 * np.abs(y_dev).max())


def test_repair_reduces_error_on_same_defect_map():
    """CRN pairing makes the comparison honest: on the identical defect
    map, spare-line repair must reduce the MVM error vs no repair."""
    w, x = _wx(k=130, n=100, m=5, seed=6)
    ideal = np.asarray(x @ w)
    y_none = np.asarray(fake_analog_matmul(w, x, cfg=_fault_cfg(3e-2)))
    y_rep = np.asarray(fake_analog_matmul(
        w, x, cfg=_fault_cfg(3e-2, repair=REPAIR_SPARE)))
    mse_none = float(np.mean((y_none - ideal) ** 2))
    mse_rep = float(np.mean((y_rep - ideal) ** 2))
    assert mse_rep < mse_none, (mse_rep, mse_none)


def test_drift_is_device_path_only():
    w, x = _wx(k=64, n=32, m=2)
    cfg = AnalogConfig(adc_bits=6,
                       faults=FaultSpec(drift_sigma=0.1))
    with pytest.raises(NotImplementedError):
        fake_analog_matmul(w, x, cfg=cfg)
    # device path: mean-preserving lognormal perturbation of the cells
    a0 = program_weights(w, "afmtj", AnalogConfig(adc_bits=6))
    ad = program_weights(w, "afmtj", cfg)
    g0, gd = np.asarray(a0.g_diff), np.asarray(ad.g_diff)
    assert not np.array_equal(g0, gd)
    assert abs(gd.mean() - g0.mean()) < 5.0 * np.abs(g0).mean() * 0.1


# --- compile discipline ------------------------------------------------------

def test_fault_rate_sweep_adds_zero_compiles():
    """Fault rates and seeds are traced data: a whole rate sweep under one
    repair policy reuses ONE executable.  Changing the policy re-keys."""
    from repro.imc.model_analog import _jitted_fake_mvm

    w, x = _wx(k=96, n=64, m=3)
    args = (6, False, False, True, False, True, True, True)
    _jitted_fake_mvm(*args, REPAIR_SPARE)._clear_cache()
    _jitted_fake_mvm(*args, None)._clear_cache()
    for r in (0.0, 1e-3, 3e-3, 1e-2):
        fake_analog_matmul(
            w, x, cfg=AnalogConfig(adc_bits=6,
                                   faults=FaultSpec.at_rate(r, seed=1),
                                   repair=REPAIR_SPARE))
    assert _jitted_fake_mvm(*args, REPAIR_SPARE)._cache_size() == 1
    assert _jitted_fake_mvm(*args, None)._cache_size() == 0


# --- repair-capacity yield + cost charging -----------------------------------

def test_repair_yield_bounds_and_ordering():
    from repro.imc.mapping import repair_yield

    for rate in (1e-4, 1e-3, 1e-2):
        f = FaultSpec.at_rate(rate)
        ys = [repair_yield(f, pol) for pol in (None, REPAIR_SPARE,
                                               REPAIR_SPARE_ECC)]
        assert all(0.0 <= y <= 1.0 for y in ys)
        assert ys[1] >= ys[0] and ys[2] >= ys[0]
    # yield falls monotonically with rate under every policy
    for pol in (None, REPAIR_SPARE):
        ys = [repair_yield(FaultSpec.at_rate(r), pol)
              for r in (1e-5, 1e-4, 1e-3, 1e-2)]
        assert all(a >= b for a, b in zip(ys, ys[1:])), (pol, ys)


def test_fault_cost_factors_inert_and_active():
    from repro.imc.mapping import fault_cost_factors

    assert fault_cost_factors(None) == (1.0, 1.0, 1.0)
    assert fault_cost_factors(FaultSpec.at_rate(0.0)) == (1.0, 1.0, 1.0)
    y, ovh, stretch = fault_cost_factors(FaultSpec.at_rate(1e-3),
                                         REPAIR_SPARE)
    assert 0.0 < y <= 1.0 and ovh > 1.0 and stretch >= ovh


def test_cost_model_fault_charging():
    """Nominal prices are bit-for-bit unchanged without faults; with them,
    no-repair stretches latency far more than spare-line repair."""
    from repro.imc.cost_model import imc_cost_model

    nom = imc_cost_model("afmtj")
    assert dataclasses.asdict(nom) == dataclasses.asdict(
        imc_cost_model("afmtj", faults=None))
    f = FaultSpec.at_rate(1e-3)
    bare = imc_cost_model("afmtj", faults=f)
    rep = imc_cost_model("afmtj", faults=f, repair=REPAIR_SPARE)
    assert bare.t_mac > nom.t_mac
    assert nom.t_mac < rep.t_mac < bare.t_mac
    assert rep.array_yield > bare.array_yield
    assert rep.e_mac > nom.e_mac          # spare/ECC area is not free


def test_evaluate_system_fault_charging():
    """Fig. 4 numbers stay bit-for-bit with defaults off; charging faults
    stretches t_imc and repair recovers most of it."""
    from repro.imc.evaluate import evaluate_system

    nom = evaluate_system("afmtj")
    nom2 = evaluate_system("afmtj", faults=None)
    for k in nom:
        assert dataclasses.asdict(nom[k]) == dataclasses.asdict(nom2[k])
        assert nom[k].array_yield == 1.0
    f = FaultSpec.at_rate(1e-3)
    bare = evaluate_system("afmtj", faults=f)
    rep = evaluate_system("afmtj", faults=f, repair=REPAIR_SPARE)
    assert bare["mac"].t_imc > nom["mac"].t_imc
    assert rep["mac"].t_imc < bare["mac"].t_imc
    assert rep["mac"].array_yield > bare["mac"].array_yield


# --- serving degradation curve -----------------------------------------------

def test_fault_slo_curve_degrades_monotonically():
    from repro.launch.simulate import fault_slo_curve

    pts = fault_slo_curve(rates=(0.0, 3e-4, 1e-3),
                          policies=(None, REPAIR_SPARE), n_requests=400)
    none = [p for p in pts if p.repair == "none"]
    spare = [p for p in pts if p.repair == "spare"]
    # same healthy starting point, monotone decay, repair extends the knee
    assert none[0].slo_attainment == spare[0].slo_attainment
    assert all(a.slo_attainment >= b.slo_attainment
               for a, b in zip(none, none[1:]))
    assert spare[-1].slo_attainment >= none[-1].slo_attainment


# --- degradation-knee reduction ----------------------------------------------

def test_degradation_knee_reduction():
    from repro.imc.model_analog import ModelAccuracyReport, degradation_knee

    def rep(rate, repair, match):
        return ModelAccuracyReport(
            arch="a", kind="afmtj", mode="fake", adc_bits=6, tmr=0.0,
            corner="tt", write_ber=0.0, kl=0.0, token_match=match,
            ppl_analog=1.0, ppl_ref=1.0, batch=1, seq_len=1,
            fault_rate=rate, repair=repair)

    reports = [rep(0.0, "none", 0.95), rep(1e-3, "none", 0.85),
               rep(1e-2, "none", 0.40),
               rep(0.0, "spare", 0.95), rep(1e-3, "spare", 0.94),
               rep(1e-2, "spare", 0.90)]
    knees = degradation_knee(reports, min_token_match=0.8)
    assert knees == {"none": 1e-3, "spare": 1e-2}

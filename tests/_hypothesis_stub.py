"""Minimal hypothesis stand-in so test modules import without the dep.

``pytest.importorskip("hypothesis")`` at module scope would skip *every*
test in the module, including the plain allclose sweeps that need no
hypothesis.  Importing these no-op shims instead makes only the property
tests skip (with a pointer to requirements-dev.txt) while everything else
still runs.
"""
import pytest


def settings(**_kw):
    return lambda f: f


def given(**_kw):
    def deco(f):
        def skipper():
            pytest.skip("hypothesis not installed — optional dev dep, "
                        "pip install -r requirements-dev.txt; the pinned "
                        "companion tests cover the same invariants "
                        "deterministically (ROADMAP.md, test hygiene)")

        skipper.__name__ = f.__name__
        return skipper

    return deco


class _Strategies:
    def __getattr__(self, name):
        return lambda *a, **k: None


st = _Strategies()

"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # property tests skip; allclose sweeps still run
    from _hypothesis_stub import given, settings, st

from repro.core import llg
from repro.core.params import AFMTJ_PARAMS
from repro.kernels import ops, ref


def _states(cells, seed=0, vmin=0.3, vmax=1.2):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    th = jax.random.uniform(k1, (cells,), minval=0.05, maxval=0.25)
    ph = jax.random.uniform(k2, (cells,), minval=0.0, maxval=6.28)
    m0 = jax.vmap(lambda t, f: llg.initial_state(AFMTJ_PARAMS, t, f))(th, ph)
    v = jnp.linspace(vmin, vmax, cells)
    return ops.pack_states(m0, v)


@pytest.mark.parametrize("cells", [512, 1024])
@pytest.mark.parametrize("n_steps", [50, 400])
def test_llg_rk4_matches_ref(cells, n_steps):
    state = _states(cells)
    out_k = ops.llg_rk4(state, AFMTJ_PARAMS, 0.1e-12, n_steps)
    out_r = ref.ref_llg_rk4(state, AFMTJ_PARAMS, 0.1e-12, n_steps)
    np.testing.assert_allclose(np.asarray(out_k[:6]), np.asarray(out_r[:6]),
                               atol=2e-5)
    # switching-step rows agree exactly
    assert np.array_equal(np.asarray(out_k[7]), np.asarray(out_r[7]))


def test_llg_rk4_param_sweep():
    """Kernel must track the oracle across device-parameter variations."""
    for alpha, bes in [(0.005, 1.0), (0.02, 0.5), (0.01, 2.0)]:
        p = dataclasses.replace(AFMTJ_PARAMS, alpha=alpha,
                                b_exchange=AFMTJ_PARAMS.b_exchange * bes)
        state = _states(512, seed=3)
        out_k = ops.llg_rk4(state, p, 0.1e-12, 100)
        out_r = ref.ref_llg_rk4(state, p, 0.1e-12, 100)
        np.testing.assert_allclose(np.asarray(out_k[:6]), np.asarray(out_r[:6]),
                                   atol=2e-5)


def test_llg_rk4_norm_invariant():
    out = ops.llg_rk4(_states(512), AFMTJ_PARAMS, 0.1e-12, 200)
    n1 = np.linalg.norm(np.asarray(out[0:3]), axis=0)
    n2 = np.linalg.norm(np.asarray(out[3:6]), axis=0)
    np.testing.assert_allclose(n1, 1.0, atol=1e-5)
    np.testing.assert_allclose(n2, 1.0, atol=1e-5)


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 128),
                                   (128, 256, 256)])
@pytest.mark.parametrize("adc_bits", [0, 4, 8])
def test_bitline_mac_matches_ref(shape, adc_bits):
    m, k, n = shape
    v = jax.random.uniform(jax.random.PRNGKey(0), (m, k))
    g = jax.random.uniform(jax.random.PRNGKey(1), (k, n)) * 3.4e-4
    out_k = ops.bitline_mac(v, g, adc_bits, i_max=0.05)
    out_r = ref.ref_bitline_mac(v, g, adc_bits, i_max=0.05)
    if adc_bits == 0:
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-5, atol=1e-8)
    else:
        # tiled-K accumulation can land a float-ulp away from the oracle at a
        # quantizer bin edge: allow <=1 LSB there, on <1% of elements
        lsb = 0.05 / (2 ** (adc_bits - 1) - 1)
        diff = np.abs(np.asarray(out_k) - np.asarray(out_r))
        assert diff.max() <= lsb * 1.001, diff.max()
        assert (diff > lsb * 1e-3).mean() < 0.01


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(128, 128, 128), (128, 512, 256)])
def test_xnor_gemm_matches_ref(shape, dtype):
    m, k, n = shape
    a = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (m, k))).astype(dtype)
    w = jnp.sign(jax.random.normal(jax.random.PRNGKey(3), (k, n))).astype(dtype)
    out_k = ops.xnor_gemm(a, w)
    out_r = ref.ref_xnor_gemm(a, w)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_xnor_popcount_identity(seed):
    """Property: pm1 dot == K - 2*popcount(xor) for random bit matrices."""
    rng = np.random.default_rng(seed)
    a_bits = rng.integers(0, 2, (16, 64))
    w_bits = rng.integers(0, 2, (16, 64))
    pm = lambda b: (2 * b - 1).astype(np.float32)
    expect = pm(a_bits) @ pm(w_bits).T
    got = ref.ref_xnor_popcount(jnp.asarray(a_bits), jnp.asarray(w_bits.T))
    np.testing.assert_allclose(np.asarray(got), expect)


def test_pack_unpack_roundtrip():
    m0 = jax.vmap(lambda t: llg.initial_state(AFMTJ_PARAMS, t, 0.1))(
        jnp.linspace(0.01, 0.3, 100))
    v = jnp.linspace(0.2, 1.0, 100)
    state = ops.pack_states(m0, v)
    assert state.shape == (8, 512)          # padded to CELL_TILE
    m, cross = ops.unpack_states(state, 100)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m0), atol=1e-6)

"""Fused campaign-engine tests (DESIGN.md §8): one-launch temperature
packing, compile budgets, shape buckets, chunked early exit.

The §8 restructure has two invariants worth pinning hard:

* **bit-compatibility** — fusing the temperature axis, bucketing lane
  counts, quantizing the compiled horizon and exiting tiles early must not
  change a single crossing step relative to the old fixed-horizon,
  one-launch-per-temperature engine;
* **compile economy** — a multi-temperature campaign costs one XLA
  compile, and a shrinking write-verify retry schedule stays within its
  shape-bucket budget instead of compiling once per round.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.campaign import (CampaignGrid, bucket_cells, pack_campaign,
                            pack_plane, run_campaign, run_ensemble)
from repro.campaign.engine import _integrate_sharded, brown_sigma
from repro.core import llg
from repro.core.params import AFMTJ_PARAMS
from repro.kernels import noise, ops, ref
from repro.kernels.llg_rk4 import CELL_TILE

TEMPS = (260.0, 300.0, 340.0)


@pytest.fixture(scope="module")
def fused_grid():
    # 0.6 V lanes mostly never cross, 1.2 V lanes all do — the fixture
    # exercises both the crossing and the sentinel paths of every reduction
    return CampaignGrid(voltages=(0.6, 1.2), pulse_widths=(120e-12, 250e-12),
                        temperatures=TEMPS, n_samples=24, dt=0.1e-12, seed=0)


@pytest.fixture(scope="module")
def fused_result(fused_grid):
    return run_campaign(AFMTJ_PARAMS, fused_grid, use_cache=False)


# ------------------------------------------------------------ shape buckets
def test_bucket_cells_power_of_two_tiles():
    assert bucket_cells(1) == CELL_TILE
    assert bucket_cells(CELL_TILE) == CELL_TILE
    assert bucket_cells(CELL_TILE + 1) == 2 * CELL_TILE
    assert bucket_cells(3 * CELL_TILE) == 4 * CELL_TILE
    assert bucket_cells(4 * CELL_TILE) == 4 * CELL_TILE
    # buckets are monotone and cover every count
    for n in (1, 100, 513, 1500, 5000):
        b = bucket_cells(n)
        assert b >= n and b % CELL_TILE == 0
        assert (b // CELL_TILE) & (b // CELL_TILE - 1) == 0  # pow2 tiles


def test_pack_campaign_layout(fused_grid):
    state, seeds, sigma, budget, spans = pack_campaign(fused_grid,
                                                       AFMTJ_PARAMS)
    n_t = len(TEMPS)
    per = state.shape[1] // n_t
    assert per == bucket_cells(fused_grid.cells)
    assert seeds.shape == sigma.shape == budget.shape == (state.shape[1],)
    assert spans == [(ti * per, ti * per + fused_grid.cells)
                     for ti in range(n_t)]
    sig = np.asarray(sigma)
    bud = np.asarray(budget)
    for ti, t in enumerate(TEMPS):
        lo = ti * per
        # the whole slice carries that temperature's Brown sigma ...
        np.testing.assert_allclose(
            sig[lo:lo + per], brown_sigma(AFMTJ_PARAMS, fused_grid.dt, t))
        # ... real lanes get the full horizon, bucket padding gets 0
        assert (bud[lo:lo + fused_grid.cells] == fused_grid.n_steps).all()
        assert (bud[lo + fused_grid.cells:lo + per] == 0.0).all()
    # hotter slices fluctuate harder
    assert sig[0] < sig[-1]


# ----------------------------------------------- fused-T bit-compatibility
def test_fused_campaign_bit_identical_to_per_temperature_launches(
        fused_grid, fused_result):
    """The pre-§8 engine: one fixed-horizon launch per temperature, Brown's
    sigma a compile-time scalar.  Reproduce it literally (pack_plane +
    scalar-sigma kernel, no budgets, no early exit) and demand the fused
    one-launch result match every crossing step bit-for-bit."""
    n_v, n_s = len(fused_grid.voltages), fused_grid.n_samples
    for ti, temp in enumerate(TEMPS):
        p_t = dataclasses.replace(AFMTJ_PARAMS, temperature=temp)
        state, seeds = pack_plane(fused_grid, p_t, ti)
        sigma = brown_sigma(AFMTJ_PARAMS, fused_grid.dt, temp)
        out = ops.llg_rk4_thermal(state, seeds, AFMTJ_PARAMS, fused_grid.dt,
                                  fused_grid.n_steps, sigma)
        old = np.asarray(out[7, :fused_grid.cells], np.float64) \
            .reshape(n_v, n_s) * fused_grid.dt
        np.testing.assert_array_equal(fused_result.crossing_time[ti], old)


def test_early_exit_and_quantization_bit_identical(fused_grid, fused_result):
    """chunk=0 disables early exit AND horizon quantization — the exact
    fixed-horizon launch.  Crossing times must agree bit-for-bit."""
    exact = run_campaign(AFMTJ_PARAMS, fused_grid, use_cache=False, chunk=0)
    np.testing.assert_array_equal(fused_result.crossing_time,
                                  exact.crossing_time)
    # the fixture grid must actually exercise both outcomes
    horizon = fused_grid.n_steps * fused_grid.dt
    assert (fused_result.crossing_time < horizon).any()
    assert (fused_result.crossing_time >= horizon).any()


def test_pipelined_launch_split_matches_single_launch(fused_grid,
                                                      fused_result):
    """max_cells_per_launch splits along temperature slices; all launches
    dispatch before the first sync and the surface is unchanged."""
    per = bucket_cells(fused_grid.cells)
    split = run_campaign(AFMTJ_PARAMS, fused_grid, use_cache=False,
                         max_cells_per_launch=per)
    assert split.n_launches == len(TEMPS)
    assert fused_result.n_launches == 1
    np.testing.assert_array_equal(split.crossing_time,
                                  fused_result.crossing_time)


# ------------------------------------------------------------ compile pins
def test_multi_temperature_campaign_compiles_once(fused_grid):
    _integrate_sharded._clear_cache()
    res = run_campaign(AFMTJ_PARAMS, fused_grid, use_cache=False)
    assert res.n_launches == 1
    assert _integrate_sharded._cache_size() == 1
    # a second campaign at different seed/temperatures reuses the compile:
    # sigma, seeds and initial states are all traced data now
    grid2 = dataclasses.replace(fused_grid, seed=7,
                                temperatures=(250.0, 310.0, 370.0))
    run_campaign(AFMTJ_PARAMS, grid2, use_cache=False)
    assert _integrate_sharded._cache_size() == 1


def test_write_verify_stays_within_bucket_compile_budget():
    """A shrinking retry schedule (640 -> ~300 -> ~140 -> ...) touches two
    shape buckets (1024, 512): compiles must stay below the round count."""
    from repro.imc.write_path import WritePolicy, write_verify

    _integrate_sharded._clear_cache()
    pol = WritePolicy(v_write=1.0, pulse=130e-12, max_attempts=3, seed=5,
                      use_cache=False)
    r = write_verify("afmtj", 640, pol)
    assert r.rounds == 3                      # short pulse: retries happen
    assert _integrate_sharded._cache_size() <= 2 < r.rounds


# ------------------------------------------------- kernel-level invariants
def _packed_states(cells, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    th = jax.random.uniform(k1, (cells,), minval=0.05, maxval=0.25)
    ph = jax.random.uniform(k2, (cells,), minval=0.0, maxval=6.28)
    m0 = jax.vmap(lambda t, f: llg.initial_state(AFMTJ_PARAMS, t, f))(th, ph)
    return ops.pack_states(m0, jnp.linspace(0.8, 1.3, cells))


def test_kernel_early_exit_crossings_bit_identical():
    """Chunked early exit must reproduce the fixed-horizon crossing row
    bit-for-bit, and leave never-crossed lanes' magnetization untouched."""
    cells, dt, n_steps = 512, 0.1e-12, 1600
    state = _packed_states(cells)
    sigma = brown_sigma(AFMTJ_PARAMS, dt)
    seeds = noise.cell_seeds(3, cells)
    fixed = ops.llg_rk4_thermal(state, seeds, AFMTJ_PARAMS, dt, n_steps,
                                sigma)
    assert (np.asarray(fixed[7]) < n_steps).any()     # crossings do occur
    for chunk in (64, 100):
        early = ops.llg_rk4_thermal(state, seeds, AFMTJ_PARAMS, dt, n_steps,
                                    sigma, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(early[7]),
                                      np.asarray(fixed[7]))
        still = np.asarray(fixed[7]) >= n_steps
        np.testing.assert_array_equal(np.asarray(early[:6])[:, still],
                                      np.asarray(fixed[:6])[:, still])


def test_kernel_per_lane_sigma_matches_ref_two_temperatures():
    """Two temperatures in one launch: the Pallas kernel and the jnp oracle
    consume identical per-lane sigma rows and identical streams."""
    cells, dt, n_steps = 512, 0.1e-12, 200
    state = _packed_states(cells, seed=1)
    seeds = noise.cell_seeds(11, cells)
    sig = np.empty(cells, np.float32)
    sig[:256] = brown_sigma(AFMTJ_PARAMS, dt, 260.0)
    sig[256:] = brown_sigma(AFMTJ_PARAMS, dt, 340.0)
    sig = jnp.asarray(sig)
    out_k = ops.llg_rk4_thermal(state, seeds, AFMTJ_PARAMS, dt, n_steps,
                                sig, chunk=32)
    out_r = ref.ref_llg_rk4(state, AFMTJ_PARAMS, dt, n_steps,
                            thermal_sigma=sig, seeds=seeds, chunk=32)
    np.testing.assert_allclose(np.asarray(out_k[:6]), np.asarray(out_r[:6]),
                               atol=2e-5)
    np.testing.assert_array_equal(np.asarray(out_k[7]),
                                  np.asarray(out_r[7]))
    # the two sigma halves must actually behave differently on identical
    # lanes: hotter lanes spread more (statistical, generous margin)
    assert float(sig[0]) < float(sig[-1])


def test_kernel_step_budget_clips_like_shorter_horizon():
    """Integrating to a quantized horizon with a per-lane budget must equal
    (after sentinel clipping) integrating exactly to the budget — the §8
    recompile-free pulse-horizon contract."""
    cells, dt = 512, 0.1e-12
    state = _packed_states(cells, seed=2)
    sigma = brown_sigma(AFMTJ_PARAMS, dt)
    seeds = noise.cell_seeds(7, cells)
    n_budget, n_static = 1500, 2048
    budget = jnp.full((cells,), float(n_budget), jnp.float32)
    quant = ops.llg_rk4_thermal(state, seeds, AFMTJ_PARAMS, dt, n_static,
                                sigma, step_budget=budget, chunk=64)
    exact = ops.llg_rk4_thermal(state, seeds, AFMTJ_PARAMS, dt, n_budget,
                                sigma)
    clipped = np.minimum(np.asarray(quant[7]), float(n_budget))
    np.testing.assert_array_equal(clipped, np.asarray(exact[7]))
    assert (clipped < n_budget).any()


# ----------------------------------------------------- engine entry points
def test_run_ensemble_chunked_crossings_match():
    n = 100
    m0 = jax.vmap(lambda t: llg.initial_state(AFMTJ_PARAMS, t, 0.2))(
        jnp.linspace(0.05, 0.15, n))
    v = jnp.linspace(0.9, 1.1, n)
    r0 = run_ensemble(AFMTJ_PARAMS, m0, v, 0.1e-12, 300, seed=0)
    r1 = run_ensemble(AFMTJ_PARAMS, m0, v, 0.1e-12, 300, seed=0, chunk=50)
    np.testing.assert_array_equal(r0.crossing_steps, r1.crossing_steps)


def test_latency_percentiles_vectorization_matches_loop(fused_result):
    """The masked-nanpercentile reduction must agree with the explicit
    per-(T, V) loop it replaced."""
    qs = (50.0, 90.0, 99.0)
    lp = fused_result.latency_percentiles(qs)
    grid = fused_result.grid
    n_t, n_v, _, _ = grid.shape
    horizon = grid.n_steps * grid.dt
    expect = np.full((n_t, n_v, len(qs)), np.nan)
    for t in range(n_t):
        for v in range(n_v):
            ct = fused_result.crossing_time[t, v]
            ok = ct < horizon
            if ok.any():
                expect[t, v] = np.percentile(ct[ok], qs)
    np.testing.assert_allclose(lp, expect)
    assert np.isfinite(lp).any()


def test_wer_margined_pulse_over_temperature_range():
    """The operating-range margin is the worst case over the corners — at
    least as long as the nominal-temperature pulse, from one fused
    launch."""
    from repro.imc.write_margin import wer_margined_pulse

    kw = dict(v_write=1.0, wer_target=5e-2, n_samples=64, use_cache=False)
    nominal = wer_margined_pulse("afmtj", **kw)
    ranged = wer_margined_pulse("afmtj", temperatures=(260.0, 300.0, 340.0),
                                **kw)
    assert ranged >= nominal

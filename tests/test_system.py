"""End-to-end system tests: train a reduced model (loss must drop), resume
from checkpoint, serve batched requests, and a subprocess mini dry-run that
exercises the production sharding rules on 8 host devices."""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]


def test_train_loss_decreases(tmp_path):
    from repro.launch.train import main

    history = main([
        "--arch", "qwen2-0.5b", "--preset", "smoke", "--steps", "100",
        "--batch", "8", "--seq", "64", "--lr", "1e-2",
        "--ckpt-dir", str(tmp_path), "--log-every", "2",
    ])
    losses = [l for _, l in history]
    assert len(losses) >= 10
    # synthetic zipfian stream: the model learns the unigram head; from the
    # ln(512)~6.2-nat start this reliably sheds >1 nat in 100 steps
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])


def test_train_resume(tmp_path):
    from repro.launch.train import main

    main(["--arch", "qwen2-0.5b", "--preset", "smoke", "--steps", "10",
          "--batch", "4", "--seq", "32", "--save-every", "5",
          "--ckpt-dir", str(tmp_path)])
    # second invocation resumes from step 10 checkpoint
    h = main(["--arch", "qwen2-0.5b", "--preset", "smoke", "--steps", "14",
              "--batch", "4", "--seq", "32", "--save-every", "5",
              "--ckpt-dir", str(tmp_path), "--log-every", "1"])
    steps = [s for s, _ in h]
    assert min(steps) >= 10, steps


def test_train_microbatched_matches_shape(tmp_path):
    from repro.launch.train import main

    h = main(["--arch", "olmoe-1b-7b", "--preset", "smoke", "--steps", "6",
              "--batch", "8", "--seq", "32", "--microbatches", "2",
              "--ckpt-dir", str(tmp_path), "--log-every", "1"])
    assert len(h) >= 3
    assert all(np.isfinite(l) for _, l in h)


def test_serve_driver():
    """Continuous batching: 5 requests through 2 slots needs slot-freeing;
    accounting must be per-request (exactly 5 served, no dead-slot tokens)."""
    from repro.launch.serve import main

    stats = main(["--arch", "qwen2-0.5b", "--requests", "5", "--batch", "2",
                  "--prompt-len", "16", "--max-new", "4"])
    assert stats["served"] == 5
    # each request's FIRST token comes out of the prefill wave; the rest are
    # decode steps — the split must be exact, not rounded up to batches
    assert stats["prefill_tokens"] == 5
    assert stats["decode_tokens"] == 5 * 4 - 5
    assert stats["generated_tokens"] == 5 * 4
    assert stats["prefills"] >= 3                # joins actually happened
    assert [len(c) for c in stats["completions"]] == [4] * 5
    # every requested technology got a simulated-clock report
    for tech in ("afmtj", "mtj", "cpu"):
        rep = stats["device"][tech]
        assert rep["sim_time_s"] > 0 and rep["energy_j"] > 0
        assert rep["ttft_p99_s"] >= rep["ttft_p50_s"] > 0


def test_serve_honors_eos():
    """A sequence emitting --eos-id frees its slot early and stops counting."""
    from repro.launch.serve import main

    probe = main(["--arch", "qwen2-0.5b", "--requests", "2", "--batch", "2",
                  "--prompt-len", "16", "--max-new", "4"])
    eos = probe["completions"][0][0]             # deterministic first token
    stats = main(["--arch", "qwen2-0.5b", "--requests", "2", "--batch", "2",
                  "--prompt-len", "16", "--max-new", "4", "--eos-id", str(eos)])
    assert stats["completions"][0] == [eos]      # finished at the EOS token
    assert stats["decode_tokens"] < probe["decode_tokens"]


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """Lower+compile a real cell pipeline on 8 host devices in a subprocess
    (the full 512-device sweep runs via repro.launch.dryrun --all)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs.base import ShapeConfig
from repro.configs.registry import smoke_config
from repro.launch import sharding as SH, steps as ST
from repro.models import model as M

cfg = smoke_config("qwen3-8b")
mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = ShapeConfig("train_4k", "train", 64, 8, microbatches=2)
SH.activation_policy(mesh, cfg, shape)
ap = M.abstract_params(cfg)
ps = SH.param_shardings(cfg, mesh, M.logical_axes(cfg), ap)
batch = ST.input_specs(cfg, shape)
bs = SH.batch_shardings(mesh, shape, batch)
fn = ST.make_train_step(cfg, shape)
aopt = jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), ap)
jit = jax.jit(fn, in_shardings=(ps, ps, ps, None, bs),
              out_shardings=(ps, ps, ps, None, None), donate_argnums=(0,1,2))
c = jit.lower(ap, aopt, aopt, jax.ShapeDtypeStruct((), jnp.int32), batch).compile()
ca = c.cost_analysis()
ca = ca[0] if isinstance(ca, list) else ca   # list-wrapped pre-jax-0.5
print("COMPILED", ca["flops"] > 0)
"""
    r = subprocess.run([sys.executable, "-c", code],
                       env={**os.environ, "PYTHONPATH": str(REPO / "src")},
                       capture_output=True, text=True, timeout=300)
    assert "COMPILED True" in r.stdout, r.stderr[-2000:]


def test_dryrun_results_valid():
    """Validate any dry-run artifacts produced so far (full table checked in
    EXPERIMENTS.md; this guards the schema + fit-in-HBM for completed cells)."""
    d = REPO / "results" / "dryrun"
    files = list(d.glob("*.json")) if d.exists() else []
    if not files:
        pytest.skip("no dry-run artifacts yet — results/dryrun/*.json are "
                    "produced by the TPU dry-run workflow (ROADMAP.md); "
                    "this test validates them when present")
    for f in files:
        r = json.loads(f.read_text())
        assert r["cost"]["flops"] > 0, f.name
        assert r["memory"]["temp_size_in_bytes"] is not None
        coll = r["collectives"]
        assert set(coll) == {"all-reduce", "all-gather", "reduce-scatter",
                             "all-to-all", "collective-permute"}

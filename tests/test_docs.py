"""Docs-consistency checks (tier-1): the numbered DESIGN.md sections that
module docstrings cite must exist, and the README's examples/benchmarks
listings must track what is actually in the tree — docs drift fails CI
instead of rotting silently."""
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def _design_sections():
    text = (ROOT / "DESIGN.md").read_text()
    return set(re.findall(r"^## §(\d+)\b", text, re.M))


def test_design_has_numbered_sections():
    assert len(_design_sections()) >= 7


def test_design_citations_resolve():
    """Every `DESIGN.md §N` cited anywhere in src/ (or tests/benchmarks/
    examples) must be a real heading — renumbering requires updating the
    citations (DESIGN.md's own ground rule)."""
    sections = _design_sections()
    dangling = {}
    for sub in ("src", "tests", "benchmarks", "examples"):
        for p in (ROOT / sub).rglob("*.py"):
            cited = set(re.findall(r"DESIGN\.md §(\d+)", p.read_text()))
            bad = cited - sections
            if bad:
                dangling[str(p.relative_to(ROOT))] = sorted(bad)
    assert not dangling, f"dangling DESIGN.md § citations: {dangling}"


def test_design_documents_read_path():
    """DESIGN.md §10 is the read-path/refresh contract `imc.read_path`,
    `circuit.senseamp` (MC mode) and `imc.evaluate` (refresh charging) all
    cite — it must exist and actually cover the three scenario families."""
    text = (ROOT / "DESIGN.md").read_text()
    m = re.search(r"^## §10\b.*?(?=^## §|\Z)", text, re.M | re.S)
    assert m, "DESIGN.md §10 (read path) missing"
    body = m.group(0).lower()
    for topic in ("disturb", "retention", "sense", "refresh"):
        assert topic in body, f"DESIGN.md §10 does not cover {topic!r}"


def test_readme_lists_every_example():
    readme = (ROOT / "README.md").read_text()
    missing = [p.name for p in sorted((ROOT / "examples").glob("*.py"))
               if p.name not in readme]
    assert not missing, f"examples absent from README.md: {missing}"


def test_readme_lists_every_bench():
    readme = (ROOT / "README.md").read_text()
    run_src = (ROOT / "benchmarks" / "run.py").read_text()
    benches = re.findall(r'^    "(\w+)": bench_\w+,$', run_src, re.M)
    assert benches, "could not parse BENCHES from benchmarks/run.py"
    missing = [b for b in benches if f"`{b}`" not in readme]
    assert not missing, f"benches absent from README.md: {missing}"

"""Substrate tests: optimizer, data pipeline, checkpointing, fault runtime,
sharding rules, elastic planning."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, make_pipeline
from repro.optim import AdamWConfig, adamw_init, adamw_update, wsd_schedule
from repro.runtime import StepWatchdog, plan_elastic_remesh
from repro.runtime.fault import FaultTolerantLoop


# ------------------------------------------------------------------ optimizer
def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    m, v = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    best = float("inf")
    for step in range(120):
        g = jax.grad(loss)(params)
        params, m, v, gn = adamw_update(params, g, m, v, jnp.asarray(step), cfg)
        best = min(best, float(loss(params)))
    assert best < 1e-2


def test_adamw_clip():
    params = {"w": jnp.zeros(3)}
    m, v = adamw_init(params)
    cfg = AdamWConfig(clip_norm=1.0)
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, _, gn = adamw_update(params, g, m, v, jnp.asarray(0), cfg)
    assert float(gn) == pytest.approx(100.0)


def test_adamw_bf16_state():
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    m, v = adamw_init(params, "bfloat16")
    assert m["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones(4, jnp.bfloat16)}
    p2, m2, v2, _ = adamw_update(params, g, m, v, jnp.asarray(0), AdamWConfig())
    assert p2["w"].dtype == jnp.bfloat16 and m2["w"].dtype == jnp.bfloat16


def test_wsd_schedule():
    assert float(wsd_schedule(0, 1.0, warmup=10, total=100)) == 0.0
    assert float(wsd_schedule(10, 1.0, warmup=10, total=100)) == pytest.approx(1.0)
    assert float(wsd_schedule(99, 1.0, warmup=10, total=100)) < 0.25


# ----------------------------------------------------------------------- data
def test_pipeline_deterministic():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=4, seed=7)
    b1 = next(make_pipeline(cfg))
    b2 = next(make_pipeline(cfg))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][..., 1:], b1["labels"][..., :-1])


def test_pipeline_rank_disjoint():
    k = dict(vocab=1000, seq_len=16, global_batch=8, host_count=2)
    b0 = next(make_pipeline(DataConfig(host_rank=0, **k)))
    b1 = next(make_pipeline(DataConfig(host_rank=1, **k)))
    assert b0["tokens"].shape == (1, 4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_pipeline_memmap(tmp_path):
    toks = np.arange(10000, dtype=np.uint16) % 500
    f = tmp_path / "tokens.bin"
    toks.tofile(f)
    cfg = DataConfig(vocab=500, seq_len=16, global_batch=2, source="memmap",
                     path=str(f))
    b = next(make_pipeline(cfg))
    assert b["tokens"].shape == (1, 2, 16)
    assert b["tokens"].max() < 500


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "nested": {"b": jnp.ones((3, 4), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    ck = Checkpointer(tmp_path)
    ck.save(7, tree, blocking=True)
    assert ck.latest_step() == 7
    out = ck.restore(7, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["nested"]["b"].dtype == jnp.bfloat16
    assert int(out["step"]) == 7


def test_checkpoint_gc_and_async(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = {"x": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    ck.wait()
    assert sorted(ck.steps()) == [3, 4]


def test_checkpoint_atomic(tmp_path):
    """A leftover .tmp dir must never be visible as a checkpoint."""
    ck = Checkpointer(tmp_path)
    (tmp_path / "step_9.tmp").mkdir()
    assert ck.latest_step() is None


# -------------------------------------------------------------------- runtime
def test_watchdog_flags_stragglers():
    wd = StepWatchdog(threshold=2.0)
    for s in range(10):
        assert not wd.observe(s, 1.0)
    assert wd.observe(10, 5.0)
    assert wd.straggler_steps == [10]
    assert not wd.observe(11, 1.0)   # average not poisoned


def test_fault_loop_resumes(tmp_path):
    """Kill the loop mid-run; a new loop resumes from the checkpoint."""
    ck = Checkpointer(tmp_path)

    calls = []

    def step_fn(state, batch):
        calls.append(1)
        return state + 1, {"loss": float(state)}

    loop = FaultTolerantLoop(ck, save_every=5)
    state, step, _ = loop.run(jnp.asarray(0), step_fn, lambda s: {}, 0, 12)
    assert int(state) == 12
    assert ck.latest_step() == 10      # saved at 5, 10
    restored = ck.restore(10, jnp.asarray(0))
    loop2 = FaultTolerantLoop(ck, save_every=5)
    state2, step2, _ = loop2.run(restored, step_fn, lambda s: {}, 10, 12)
    assert int(state2) == 12


def test_elastic_plan():
    p = plan_elastic_remesh(256)
    assert p.mesh_shape == (16, 16) and p.microbatch_scale == 1
    p = plan_elastic_remesh(192)        # lost 4 nodes worth of chips
    assert p.mesh_shape == (8, 16) and p.microbatch_scale == 2
    p = plan_elastic_remesh(15)
    assert p is None or p.mesh_shape[0] * p.mesh_shape[1] <= 15


# ------------------------------------------------------------------- sharding
def test_resolve_pspec_divisibility():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import resolve_pspec

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"vocab": ("model",), "embed": ("data",)}
    # single-device mesh: everything divides
    sp = resolve_pspec((100, 64), ("vocab", "embed"), rules, mesh)
    assert sp == P("model", "data")


def test_resolve_pspec_uneven_drops_axis():
    """Uneven shards drop the mesh axis instead of erroring — a multi-device
    property, exercised on 4 forced host devices in a subprocess (the
    in-process backend is already initialized single-device)."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
from jax.sharding import PartitionSpec as P
from repro.launch.sharding import resolve_pspec
mesh = jax.make_mesh((2, 2), ("data", "model"))
rules = {"vocab": ("model",), "embed": ("data",)}
sp_even = resolve_pspec((100, 64), ("vocab", "embed"), rules, mesh)
sp_odd = resolve_pspec((101, 64), ("vocab", "embed"), rules, mesh)
ok = sp_even == P("model", "data") and sp_odd == P(None, "data")
print("PSPEC_OK", ok, "|", sp_even, "|", sp_odd)
"""
    repo = Path(__file__).resolve().parents[1]
    r = subprocess.run([sys.executable, "-c", code],
                       env={**os.environ, "PYTHONPATH": str(repo / "src")},
                       capture_output=True, text=True, timeout=300)
    assert "PSPEC_OK True" in r.stdout, (r.stdout, r.stderr[-2000:])

"""Serving-subsystem tests: scheduler edge cases against a stub engine (no
JAX compile), cost-model pricing identities, traffic generation, the
event-driven simulator against the step-granular scheduler reference, and
the report/SLO layer.  Only the cost-model-from-hierarchy tests touch JAX;
a subprocess test pins that the whole scheduler/traffic/simulator stack
imports and runs with JAX blocked."""
import os
import subprocess
import sys
from pathlib import Path
from types import SimpleNamespace

import numpy as np
import pytest

from repro.imc.cost_model import (StepCounts, TokenCounts, TokenPrices,
                                  decode_step_counts, per_token_counts,
                                  prefill_step_counts)
from repro.launch.engine import StubEngine
from repro.launch.report import SLO, build_report
from repro.launch.scheduler import ContinuousBatchScheduler, Request
from repro.launch.simulate import simulate_serving
from repro.launch.traffic import (CHAT_OUTPUTS, CHAT_PROMPTS, LengthMixture,
                                  PoissonTraffic, Trace, mean_request_time,
                                  poisson_at_load, rate_for_load)

REPO = Path(__file__).resolve().parents[1]

# synthetic affine prices: big constant term, small position term
PRICES = TokenPrices("synthetic", t_tok=1e-6, t_pos=1e-8,
                     e_tok=1e-12, e_pos=1e-14)


def run_loop(sched, engine, now=0.0):
    """The documented serve-loop contract (see launch.scheduler)."""
    while not sched.finished:
        sched.admit(now)
        tok, _ = engine.prefill(sched.histories(), sched.frontends())
        while True:
            out = sched.commit(tok, now)
            if sched.finished or (out.freed and sched.has_waiting(now)):
                break
            tok, _ = engine.decode_step(tok, sched.slot_positions())
    return sched.stats()


def make_sched(n_slots, max_new, n_requests, prompt_len=6, eos_id=-1):
    sched = ContinuousBatchScheduler(n_slots, max_new, eos_id=eos_id)
    for rid in range(n_requests):
        sched.submit(Request(rid=rid,
                             prompt=np.arange(1, prompt_len + 1, dtype=np.int32)))
    return sched


# --------------------------------------------------------------------------
# scheduler edge cases (stub engine -- no JAX, no compile)
# --------------------------------------------------------------------------

def test_five_requests_through_two_slots_token_split():
    """The satellite accounting fix, pinned: 5 requests x 4 tokens through 2
    slots is 5 prefill-produced tokens + 15 decode tokens, never 20/0."""
    stats = run_loop(make_sched(2, 4, 5), StubEngine())
    assert stats["served"] == 5
    assert stats["prefill_tokens"] == 5
    assert stats["decode_tokens"] == 15
    assert stats["generated_tokens"] == 20
    assert stats["prefills"] >= 3                 # at least two join waves
    assert [len(c) for c in stats["completions"]] == [4] * 5


def test_queue_empties_mid_wave():
    """Fewer requests than slots: the wave runs with idle slots, and idle
    slots must contribute zero tokens to the accounting."""
    stats = run_loop(make_sched(4, 3, 3), StubEngine())
    assert stats["served"] == 3
    assert stats["prefills"] == 1                 # single wave, no re-joins
    assert stats["prefill_tokens"] == 3
    assert stats["decode_tokens"] == 3 * 2        # no dead-slot tokens


def test_eos_same_step_as_max_new_completes_once():
    """EOS arriving exactly on the max_new step must finish the request
    exactly once (no double completion, no double free)."""
    plen, cap = 4, 3
    eos = 42
    # stub emits EOS exactly when the history holds plen + cap - 1 tokens,
    # i.e. the generated token that is BOTH the EOS and the max_new-th
    engine = StubEngine(token_fn=lambda s, n: eos if n == plen + cap - 1
                        else 7)
    sched = make_sched(1, cap, 1, prompt_len=plen, eos_id=eos)
    stats = run_loop(sched, engine)
    assert stats["served"] == 1
    assert stats["completions"] == [[7, 7, eos]]
    assert stats["generated_tokens"] == cap


def test_eos_frees_slot_early():
    eos = 9
    engine = StubEngine(token_fn=lambda s, n: eos)
    stats = run_loop(make_sched(2, 5, 3, eos_id=eos), engine)
    assert stats["served"] == 3
    assert stats["completions"] == [[eos]] * 3
    assert stats["prefill_tokens"] == 3 and stats["decode_tokens"] == 0


def test_zero_request_run():
    sched = make_sched(2, 4, 0)
    assert sched.finished                          # nothing to do
    stats = run_loop(sched, StubEngine())
    assert stats["served"] == 0
    assert stats["generated_tokens"] == 0
    assert stats["completions"] == []


def test_fifo_starvation_freedom():
    """Admission must follow submission order exactly: with more requests
    than slots no late request can jump an earlier one (FIFO => no
    starvation)."""
    n = 11
    stats_sched = make_sched(3, 2, n)
    run_loop(stats_sched, StubEngine())
    assert stats_sched.admission_order == list(range(n))
    assert stats_sched.served == n


def test_submit_out_of_arrival_order_rejected():
    sched = ContinuousBatchScheduler(1, 2)
    sched.submit(Request(rid=0, prompt=np.ones(2, np.int32), arrival=5.0))
    with pytest.raises(ValueError):
        sched.submit(Request(rid=1, prompt=np.ones(2, np.int32), arrival=1.0))


def test_admission_respects_arrival_time():
    sched = ContinuousBatchScheduler(2, 2)
    sched.submit(Request(rid=0, prompt=np.ones(2, np.int32), arrival=0.0))
    sched.submit(Request(rid=1, prompt=np.ones(2, np.int32), arrival=10.0))
    assert sched.has_waiting(0.0) and not sched.finished
    joined = sched.admit(now=0.0)
    assert len(joined) == 1                       # rid 1 has not arrived yet
    assert sched.next_arrival() == 10.0


# --------------------------------------------------------------------------
# cost model: counting identities (JAX-free)
# --------------------------------------------------------------------------

def test_prefill_counts_triangle():
    tc = TokenCounts(mac_weights=10.0, kv_elems=2.0)
    c = prefill_step_counts(tc, [4, 1])
    assert c.tokens == 2
    assert c.mac_weights == 10.0 * 5
    assert c.kv_write_elems == 2.0 * 5
    assert c.kv_read_elems == 2.0 * (4 * 3 / 2)   # len-1 history adds 0


def test_decode_counts_positions():
    tc = TokenCounts(mac_weights=10.0, kv_elems=2.0)
    c = decode_step_counts(tc, [7, 3])
    assert c.tokens == 2
    assert c.mac_weights == 20.0
    assert c.kv_write_elems == 4.0
    assert c.kv_read_elems == 2.0 * 10


def test_token_prices_match_step_cost():
    """The affine coefficients must reproduce step_cost exactly: that is
    what lets the event simulator integrate in closed form."""
    from repro.imc.cost_model import DeviceCostModel

    m = DeviceCostModel(kind="synthetic", t_mac=3e-12, e_mac=1e-15,
                        t_kv_write=5e-11, e_kv_write=2e-15,
                        t_kv_read=7e-12, e_kv_read=3e-15)
    tc = TokenCounts(mac_weights=1000.0, kv_elems=16.0)
    pr = m.token_prices(tc)
    for p in (0, 1, 17, 301):
        direct = m.step_cost(decode_step_counts(tc, [p]))
        affine = pr.decode_token(p)
        assert direct.t == pytest.approx(affine.t, rel=1e-12)
        assert direct.e == pytest.approx(affine.e, rel=1e-12)
    for L in (1, 2, 33):
        direct = m.step_cost(prefill_step_counts(tc, [L]))
        affine = pr.prefill(L)
        assert direct.t == pytest.approx(affine.t, rel=1e-12)
        assert direct.e == pytest.approx(affine.e, rel=1e-12)


def test_unknown_technology_rejected():
    from repro.imc.cost_model import device_cost_model

    with pytest.raises(ValueError):
        device_cost_model("sram")


# --------------------------------------------------------------------------
# cost model from the measured hierarchy (pulls JAX)
# --------------------------------------------------------------------------

def test_afmtj_kv_writes_cheaper_than_mtj():
    """The case-study claim at the price level: KV appends ride the write
    path, where AFMTJ's picosecond switching beats MTJ's nanosecond
    writes; read-side prices stay comparable."""
    from repro.imc.cost_model import device_cost_model

    af = device_cost_model("afmtj")
    mtj = device_cost_model("mtj")
    assert af.t_kv_write < mtj.t_kv_write / 5.0
    assert af.t_kv_read == pytest.approx(mtj.t_kv_read, rel=0.5)
    tc = TokenCounts(mac_weights=1e6, kv_elems=2048.0)
    assert af.token_prices(tc).t_tok < mtj.token_prices(tc).t_tok


def test_refresh_pricing_needs_resident_bytes():
    from repro.imc.cost_model import imc_cost_model

    refresh = SimpleNamespace(interval=1e-3)
    with pytest.raises(ValueError):
        imc_cost_model("afmtj", refresh=refresh)
    priced = imc_cost_model("afmtj", refresh=refresh, resident_bytes=1e6)
    base = imc_cost_model("afmtj")
    assert priced.t_mac > base.t_mac              # scrub duty-cycle stretch
    assert priced.e_standing_rate > 0.0


def test_measured_percentile_knobs_move_prices():
    from repro.imc.cost_model import device_cost_model

    base = device_cost_model("afmtj")
    tail = device_cost_model("afmtj", write_percentile=99.0,
                             read_percentile=99.0)
    assert tail.t_kv_write >= base.t_kv_write     # p99 write is no faster


def test_per_token_counts_attention_kv():
    from repro.configs.registry import smoke_config

    cfg = smoke_config("qwen2-0.5b")
    tc = per_token_counts(cfg)
    attn_layers = sum(cfg.n_pattern_repeats for mixer, _ in cfg.pattern
                      if mixer.startswith("attn"))
    assert tc.kv_elems == 2.0 * cfg.n_kv_heads * cfg.d_head * attn_layers
    assert tc.mac_weights == float(cfg.active_param_count())


# --------------------------------------------------------------------------
# traffic
# --------------------------------------------------------------------------

def test_poisson_rate_and_determinism():
    tr = PoissonTraffic(rate=1000.0, n_requests=20000, seed=3).trace()
    emp = len(tr) / tr.arrival_s[-1]
    assert emp == pytest.approx(1000.0, rel=0.05)
    tr2 = PoissonTraffic(rate=1000.0, n_requests=20000, seed=3).trace()
    assert np.array_equal(tr.arrival_s, tr2.arrival_s)
    assert np.array_equal(tr.prompt_tokens, tr2.prompt_tokens)


def test_length_mixture_moments():
    mix = LengthMixture(((1.0, 64.0, 0.5),), lo=1, hi=100000)
    rng = np.random.default_rng(0)
    s = mix.sample(rng, 200000).astype(np.float64)
    assert s.mean() == pytest.approx(mix.mean(), rel=0.02)
    assert (s ** 2).mean() == pytest.approx(mix.mean_sq(), rel=0.05)
    assert s.min() >= 1 and s.max() <= 100000


def test_trace_roundtrip(tmp_path):
    tr = PoissonTraffic(rate=10.0, n_requests=64, seed=1).trace()
    for name in ("t.npz", "t.jsonl"):
        path = tmp_path / name
        tr.save(path)
        back = Trace.load(path)
        assert np.allclose(back.arrival_s, tr.arrival_s)
        assert np.array_equal(back.prompt_tokens, tr.prompt_tokens)
        assert np.array_equal(back.output_tokens, tr.output_tokens)


def test_rate_for_load_scales_linearly():
    r1 = rate_for_load(PRICES, 0.5, 8)
    r2 = rate_for_load(PRICES, 1.0, 8)
    assert r2 == pytest.approx(2.0 * r1, rel=1e-12)
    assert mean_request_time(PRICES, CHAT_PROMPTS, CHAT_OUTPUTS, 8) > \
        mean_request_time(PRICES, CHAT_PROMPTS, CHAT_OUTPUTS, 1)


# --------------------------------------------------------------------------
# simulator: closed-form events vs the scheduler-driven reference
# --------------------------------------------------------------------------

@pytest.mark.parametrize("rho,n_slots", [(0.5, 8), (1.5, 8), (0.8, 1),
                                         (0.8, 3)])
def test_events_match_steps(rho, n_slots):
    """The event-driven fast path must agree with the real scheduler driven
    step by step — token counts and wave counts exactly, clocks to float
    tolerance."""
    tr = poisson_at_load(PRICES, rho, 400, n_slots, seed=7).trace()
    ev = simulate_serving(PRICES, tr, n_slots=n_slots, method="events")
    st = simulate_serving(PRICES, tr, n_slots=n_slots, method="steps")
    assert ev.prefill_tokens == st.prefill_tokens == len(tr)
    assert ev.decode_tokens == st.decode_tokens
    assert ev.waves == st.waves
    assert ev.wave_tokens == st.wave_tokens
    assert ev.sim_time_s == pytest.approx(st.sim_time_s, rel=1e-9)
    assert ev.busy_s == pytest.approx(st.busy_s, rel=1e-9)
    assert ev.energy_j == pytest.approx(st.energy_j, rel=1e-9)
    np.testing.assert_allclose(ev.ttft_s, st.ttft_s, rtol=1e-9)
    fe, fs = np.isfinite(ev.tpot_s), np.isfinite(st.tpot_s)
    assert np.array_equal(fe, fs)
    np.testing.assert_allclose(ev.tpot_s[fe], st.tpot_s[fs], rtol=1e-9)


def test_saturation_blows_up_ttft():
    """Past offered load 1 the queue grows without bound; p99 TTFT must be
    orders of magnitude above the sub-critical value."""
    lo = simulate_serving(PRICES,
                          poisson_at_load(PRICES, 0.3, 2000, 8, seed=1)
                          .trace(), n_slots=8)
    hi = simulate_serving(PRICES,
                          poisson_at_load(PRICES, 3.0, 2000, 8, seed=1)
                          .trace(), n_slots=8)
    assert np.percentile(hi.ttft_s, 99) > 10 * np.percentile(lo.ttft_s, 99)
    # below capacity the device idles between arrivals; above it barely does
    # (the analytic capacity estimate is conservative, so nominal rho=3 may
    # sit just above the true knee -- utilization, not equality, is the pin)
    assert hi.busy_s / hi.sim_time_s > 0.95
    assert lo.busy_s / lo.sim_time_s < hi.busy_s / hi.sim_time_s


def test_empty_trace():
    tr = Trace(np.zeros(0), np.zeros(0, np.int64), np.zeros(0, np.int64))
    r = simulate_serving(PRICES, tr, n_slots=4)
    assert r.sim_time_s == 0.0 and r.prefill_tokens == 0


def test_decode_tokens_conservation():
    """Every output token beyond the first is a decode token."""
    tr = poisson_at_load(PRICES, 0.7, 300, 4, seed=2).trace()
    r = simulate_serving(PRICES, tr, n_slots=4)
    assert r.prefill_tokens == len(tr)
    assert r.decode_tokens == int((tr.output_tokens - 1).sum())


# --------------------------------------------------------------------------
# report / SLO
# --------------------------------------------------------------------------

def test_report_excludes_nan_tpot_but_slo_checks_ttft():
    ttft = np.array([1.0, 1.0, 100.0])
    tpot = np.array([1.0, np.nan, 1.0])       # single-token request in slot 1
    rep = build_report("x", ttft, tpot, sim_time_s=10.0, energy_j=2.0,
                       prefill_tokens=3, decode_tokens=5,
                       slo=SLO(ttft_s=2.0, tpot_s=2.0), busy_s=5.0)
    assert np.isfinite(rep.tpot_p99_s)
    assert rep.slo_attainment == pytest.approx(2.0 / 3.0)
    assert rep.utilization == pytest.approx(0.5)
    assert rep.generated_tokens == 8
    assert rep.tokens_per_joule == pytest.approx(4.0)
    assert "slo_attainment" in rep.row_dict()


def test_slo_normalized_attainable_below_capacity():
    """The policy-normalized SLO must be mostly met below capacity and
    mostly missed deep in saturation — that is the curve the case study
    sweeps."""
    slo = SLO.normalized(PRICES, CHAT_PROMPTS, CHAT_OUTPUTS, 8)
    reps = {}
    for rho in (0.5, 2.0):
        tr = poisson_at_load(PRICES, rho, 2000, 8, seed=1).trace()
        r = simulate_serving(PRICES, tr, n_slots=8)
        reps[rho] = build_report("x", r.ttft_s, r.tpot_s, r.sim_time_s,
                                 r.energy_j, r.prefill_tokens,
                                 r.decode_tokens, offered_load=rho, slo=slo)
    assert reps[0.5].slo_attainment > 0.9
    assert reps[2.0].slo_attainment < 0.5


# --------------------------------------------------------------------------
# evaluate: geometric-mean summary (satellite)
# --------------------------------------------------------------------------

def test_summarize_geomean_vs_arithmetic():
    from repro.imc.evaluate import summarize, summarize_geomean

    results = {"a": SimpleNamespace(speedup=10.0, energy_saving=10.0),
               "b": SimpleNamespace(speedup=1000.0, energy_saving=1000.0)}
    sp_a, es_a = summarize(results)
    sp_g, es_g = summarize_geomean(results)
    assert sp_a == pytest.approx(505.0)
    assert es_a == pytest.approx(505.0)
    assert sp_g == pytest.approx(100.0)
    assert es_g == pytest.approx(100.0)


# --------------------------------------------------------------------------
# the stack must work with JAX blocked (subprocess)
# --------------------------------------------------------------------------

def test_serving_stack_runs_without_jax():
    code = (
        "import sys\n"
        "sys.modules['jax'] = None\n"           # any 'import jax' now fails
        "sys.modules['jax.numpy'] = None\n"
        "import numpy as np\n"
        "from repro.imc.cost_model import TokenPrices\n"
        "from repro.launch.engine import StubEngine\n"
        "from repro.launch.scheduler import ContinuousBatchScheduler\n"
        "from repro.launch.traffic import PoissonTraffic\n"
        "from repro.launch.simulate import simulate_serving\n"
        "from repro.launch.report import build_report\n"
        "pr = TokenPrices('syn', 1e-6, 1e-8, 1e-12, 1e-14)\n"
        "tr = PoissonTraffic(rate=2000.0, n_requests=60, seed=0).trace()\n"
        "for m in ('events', 'steps'):\n"
        "    r = simulate_serving(pr, tr, n_slots=4, method=m)\n"
        "    assert r.prefill_tokens == 60\n"
        "rep = build_report('syn', r.ttft_s, r.tpot_s, r.sim_time_s,\n"
        "                   r.energy_j, r.prefill_tokens, r.decode_tokens)\n"
        "assert rep.throughput_tok_s > 0\n"
        "print('NOJAX_OK')\n"
    )
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert "NOJAX_OK" in out.stdout

"""Device-physics unit + property tests (hypothesis) for the LLG core."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # property tests skip; dynamics tests still run
    from _hypothesis_stub import given, settings, st

from repro.core import llg
from repro.core.integrator import integrate_adaptive, rk4_step
from repro.core.params import AFMTJ_PARAMS, MTJ_PARAMS

jax.config.update("jax_enable_x64", False)


def _rand_unit(seed, n_sub):
    rng = np.random.default_rng(seed)
    m = rng.standard_normal((n_sub, 3))
    return jnp.asarray(m / np.linalg.norm(m, axis=-1, keepdims=True))


# ---------------------------------------------------------------- properties
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       aj=st.floats(-0.3, 0.3),
       n_sub=st.sampled_from([1, 2]))
def test_rhs_preserves_norm(seed, aj, n_sub):
    """dm/dt must be tangent: d|m|^2/dt = 2 m . dm/dt = 0 exactly."""
    p = AFMTJ_PARAMS if n_sub == 2 else MTJ_PARAMS
    m = _rand_unit(seed, n_sub)
    dm = llg.llg_rhs(m, p, jnp.asarray(aj))
    dot = jnp.sum(m * dm, axis=-1)
    assert np.allclose(np.asarray(dot) / 1e11, 0.0, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), aj=st.floats(-0.3, 0.3))
def test_gilbert_form_satisfied(seed, aj):
    """The explicit solution must satisfy the implicit Gilbert equation:
    dm/dt = T + alpha m x dm/dt, with T the collected explicit torques."""
    p = AFMTJ_PARAMS
    m = _rand_unit(seed, 2)
    dm = llg.llg_rhs(m, p, jnp.asarray(aj))
    # rebuild T from the same fields
    b = llg.effective_field(m, p)
    pvec = llg.stt_signs(p) * llg.P_AXIS
    from repro.core.params import GAMMA
    t = (-GAMMA * jnp.cross(m, b)
         + GAMMA * aj * jnp.cross(m, jnp.cross(m, pvec))
         - GAMMA * p.beta_flt * aj * jnp.cross(m, pvec))
    lhs = dm
    rhs = t + p.alpha * jnp.cross(m, dm)
    assert np.allclose(np.asarray(lhs - rhs) / 1e11, 0.0, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(10, 200))
def test_rk4_norm_conservation(seed, steps):
    p = AFMTJ_PARAMS
    m = _rand_unit(seed, 2)
    for _ in range(3):
        m = rk4_step(lambda mm, tt: llg.llg_rhs(mm, p, 0.1), m, 0.0, 0.1e-12)
    n = jnp.linalg.norm(m, axis=-1)
    assert np.allclose(np.asarray(n), 1.0, atol=1e-6)


# ----------------------------------------------------------------- dynamics
def test_damping_relaxes_to_easy_axis():
    """No drive: a tilted AFMTJ state must relax back toward n = +z."""
    p = AFMTJ_PARAMS
    m = llg.initial_state(p, theta0=0.4, phi0=0.7)
    for _ in range(4000):
        m = rk4_step(lambda mm, tt: llg.llg_rhs(mm, p, 0.0), m, 0.0, 0.1e-12)
    nz = float(llg.order_parameter_z(m))
    assert nz > 0.99


def test_neel_antiparallelism_preserved():
    """Exchange keeps the sublattices near-antiparallel through switching."""
    p = AFMTJ_PARAMS
    m = llg.initial_state(p, theta0=0.11, phi0=0.3)
    min_anti = 1.0
    for _ in range(3000):
        aj = 0.16  # ~1V drive
        m = rk4_step(lambda mm, tt: llg.llg_rhs(mm, p, aj), m, 0.0, 0.1e-12)
        anti = -float(jnp.sum(m[0] * m[1]))
        min_anti = min(min_anti, anti)
    # canting during driven reversal reaches ~ a_J/B_E-level transients but
    # the exchange must keep the pair far from parallel alignment
    assert min_anti > 0.7, f"sublattices decoupled: m1.m2 = {-min_anti}"


def test_adaptive_matches_fixed():
    """Step-doubling adaptive RK4 agrees with 0.1 ps fixed stepping."""
    p = AFMTJ_PARAMS
    m0 = llg.initial_state(p, theta0=0.2, phi0=0.3)
    t_end = 20e-12
    m_fixed = m0
    for _ in range(200):
        m_fixed = rk4_step(lambda mm, tt: llg.llg_rhs(mm, p, 0.1), m_fixed,
                           0.0, 0.1e-12)
    tr = integrate_adaptive(m0, p, jnp.asarray(0.1), t_end, rtol=1e-8)
    assert np.allclose(np.asarray(tr.final_m), np.asarray(m_fixed), atol=1e-4)


def test_initial_state_shapes():
    assert llg.initial_state(AFMTJ_PARAMS).shape == (2, 3)
    assert llg.initial_state(MTJ_PARAMS).shape == (1, 3)
    m = llg.initial_state(AFMTJ_PARAMS, theta0=0.1)
    assert np.allclose(np.asarray(m[0]), -np.asarray(m[1]))


def test_write_error_rate_decreases_with_pulse():
    """Thermal MC: longer pulses must not increase the write-error rate."""
    from repro.core.montecarlo import write_error_rate
    w_short = float(write_error_rate(AFMTJ_PARAMS, 1.0, 120e-12, n_samples=16))
    w_long = float(write_error_rate(AFMTJ_PARAMS, 1.0, 350e-12, n_samples=16))
    assert w_long <= w_short
    assert w_long < 0.2

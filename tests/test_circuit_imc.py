"""Circuit + IMC architecture tests: analog logic truth tables, hierarchy
timings, and the paper's Fig. 4 system-level claims."""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.circuit import make_subarray
from repro.circuit.bitline import BitlineParams, bitline_settle_time, write_path_rc
from repro.circuit.senseamp import SenseAmpParams, resolve_logic, sense_delay
from repro.core.params import AFMTJ_PARAMS
from repro.imc.evaluate import evaluate_system, summarize
from repro.imc.hierarchy import build_hierarchy


@pytest.fixture(scope="module")
def sub():
    return make_subarray("afmtj", rows=8, cols=4)


@pytest.mark.parametrize("op,fn", [
    ("nand", lambda a, b: 1 - (a & b)),
    ("and", lambda a, b: a & b),
    ("or", lambda a, b: a | b),
    ("nor", lambda a, b: 1 - (a | b)),
    ("xor", lambda a, b: a ^ b),
    ("xnor", lambda a, b: 1 - (a ^ b)),
])
def test_two_row_logic_truth_table(sub, op, fn):
    """Logic emerges from device TMR + analog thresholds, not lookup."""
    for a, b in itertools.product([0, 1], [0, 1]):
        sub.write_row(0, jnp.full(4, a))
        sub.write_row(1, jnp.full(4, b))
        out = sub.logic((0, 1), op)
        assert int(out[0]) == fn(a, b), (op, a, b)


def test_majority_truth_table(sub):
    for a, b, c in itertools.product([0, 1], repeat=3):
        sub.write_row(0, jnp.full(4, a))
        sub.write_row(1, jnp.full(4, b))
        sub.write_row(2, jnp.full(4, c))
        assert int(sub.logic((0, 1, 2), "maj")[0]) == int(a + b + c >= 2)


def test_sense_delay_increases_near_reference():
    sa = SenseAmpParams()
    d_small = sense_delay(jnp.asarray(1e-7), sa)
    d_big = sense_delay(jnp.asarray(1e-4), sa)
    assert float(d_small) > float(d_big)


def test_bitline_rc_scaling():
    bl_small = BitlineParams(rows=128)
    bl_big = BitlineParams(rows=512)
    g = jnp.asarray(1.0 / AFMTJ_PARAMS.r_parallel)
    assert float(bitline_settle_time(g, bl_big)) > float(bitline_settle_time(g, bl_small))
    assert write_path_rc(bl_big) > write_path_rc(bl_small)


def test_subarray_write_dominates_for_mtj():
    a = make_subarray("afmtj").timings
    m = make_subarray("mtj").timings
    assert m.t_write > 4 * a.t_write
    assert m.e_write_bit > 4 * a.e_write_bit
    # reads/senses are device-agnostic to first order
    assert abs(m.t_read - a.t_read) / a.t_read < 0.25


@pytest.fixture(scope="module")
def results():
    return {k: evaluate_system(k) for k in ("afmtj", "mtj")}


def test_fig4_afmtj_headline(results):
    """Paper Fig. 4: 17.5x avg speedup, ~20x energy savings (+-35%)."""
    sp, es = summarize(results["afmtj"])
    assert 11.0 < sp < 24.0, sp
    assert 13.0 < es < 28.0, es


def test_fig4_mtj_baseline(results):
    """Paper: 6x / 2.3x for MTJ-based IMC (+-40%)."""
    sp, es = summarize(results["mtj"])
    assert 3.6 < sp < 8.5, sp
    assert 1.4 < es < 4.4, es


def test_fig4_bnn_largest(results):
    """bnn: 55.4x — the largest per-workload speedup."""
    r = results["afmtj"]
    assert abs(r["bnn"].speedup - 55.4) / 55.4 < 0.25
    assert r["bnn"].speedup == max(x.speedup for x in r.values())


def test_fig4_mat_add(results):
    assert abs(results["afmtj"]["mat_add"].speedup - 16.5) / 16.5 < 0.25


def test_afmtj_beats_mtj_everywhere(results):
    for name in results["afmtj"]:
        assert results["afmtj"][name].speedup > results["mtj"][name].speedup
        assert (results["afmtj"][name].energy_saving
                > results["mtj"][name].energy_saving)


def test_hierarchy_levels():
    h = build_hierarchy("afmtj")
    assert set(h.levels) == {"L1", "L2", "MM"}
    assert h.level_for_footprint(4 * 1024).spec.name == "L1"
    assert h.level_for_footprint(400 * 1024).spec.name == "L2"
    assert h.level_for_footprint(100 * 1024 * 1024).spec.name == "MM"
    # bigger levels have slower lines but more parallelism
    assert (h.levels["MM"].timings.t_read > h.levels["L1"].timings.t_read)
    assert h.levels["MM"].row_bits > h.levels["L1"].row_bits

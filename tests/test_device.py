"""Device-level tests: the paper's Fig. 3 / Table I anchors must reproduce."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.device import read_energy, simulate_read, simulate_write
from repro.core.params import AFMTJ_PARAMS, MTJ_PARAMS
from repro.core.tmr import tmr_ratio
from repro.core import llg


@pytest.fixture(scope="module")
def afmtj_1v():
    return simulate_write(AFMTJ_PARAMS, 1.0, n_steps=16000, dt=0.05e-12)


@pytest.fixture(scope="module")
def mtj_1v():
    return simulate_write(MTJ_PARAMS, 1.0, n_steps=40000, dt=0.1e-12)


def test_afmtj_write_anchor(afmtj_1v):
    """Paper Fig. 3: 164 ps / 55.7 fJ at 1.0 V (we assert within 10%)."""
    assert bool(afmtj_1v.switched)
    lat = float(afmtj_1v.write_latency)
    en = float(afmtj_1v.energy)
    assert abs(lat - 164e-12) / 164e-12 < 0.10, lat
    assert abs(en - 55.7e-15) / 55.7e-15 < 0.10, en


def test_mtj_write_anchor(mtj_1v):
    """Paper Fig. 3: ~1400 ps / ~480 fJ at 1.0 V (latency 10%, energy 30%)."""
    assert bool(mtj_1v.switched)
    lat = float(mtj_1v.write_latency)
    en = float(mtj_1v.energy)
    assert abs(lat - 1400e-12) / 1400e-12 < 0.10, lat
    assert abs(en - 480e-15) / 480e-15 < 0.30, en   # known -22% (see EXPERIMENTS.md)


def test_headline_ratios(afmtj_1v, mtj_1v):
    """Table I / abstract: ~8x lower latency, ~9x lower energy."""
    lat_ratio = float(mtj_1v.write_latency) / float(afmtj_1v.write_latency)
    en_ratio = float(mtj_1v.energy) / float(afmtj_1v.energy)
    assert 6.5 < lat_ratio < 10.5, lat_ratio
    assert 5.5 < en_ratio < 10.5, en_ratio


def test_afmtj_ps_scale_switching(afmtj_1v):
    """Table I: AFMTJ switching in the 10-500 ps regime (vs ns for MTJ)."""
    assert 10e-12 < float(afmtj_1v.t_switch) < 500e-12


def test_no_switching_below_threshold():
    r = simulate_write(AFMTJ_PARAMS, 0.1, n_steps=8000, dt=0.05e-12)
    assert not bool(r.switched)


def test_latency_monotonic_in_voltage():
    lats = []
    for v in [0.5, 0.8, 1.2]:
        r = simulate_write(AFMTJ_PARAMS, v, n_steps=16000, dt=0.05e-12)
        assert bool(r.switched)
        lats.append(float(r.write_latency))
    assert lats[0] > lats[1] > lats[2]


def test_tmr_validation():
    """Paper IIA: TMR ~ 80% validated against fabricated AFMTJs."""
    assert abs(tmr_ratio(AFMTJ_PARAMS) - 0.8) < 1e-9
    # read disturb margin: read current differential positive
    m_p = llg.initial_state(AFMTJ_PARAMS, up=True)
    m_ap = llg.initial_state(AFMTJ_PARAMS, up=False)
    i_p, r_p = simulate_read(AFMTJ_PARAMS, m_p)
    i_ap, r_ap = simulate_read(AFMTJ_PARAMS, m_ap)
    assert float(i_p) > float(i_ap)
    assert float(r_ap) / float(r_p) == pytest.approx(1.8, rel=1e-3)


def test_read_energy_small():
    assert read_energy(AFMTJ_PARAMS) < 10e-15   # reads are fJ-scale


def test_field_robustness():
    """Table I: near-zero net magnetization -> low field sensitivity.

    Apply a uniform external field (same on both sublattices) and verify the
    Neel order is far less perturbed for the AFMTJ than the MTJ macrospin."""
    from repro.core.integrator import rk4_step
    from repro.core.llg import llg_rhs, order_parameter_z

    b_ext = jnp.array([0.05, 0.0, 0.0])   # 50 mT in-plane

    def run(p):
        m = llg.initial_state(p, theta0=0.02, phi0=0.0)
        for _ in range(2000):
            m = rk4_step(
                lambda mm, tt: llg_rhs(mm, p, 0.0, jnp.broadcast_to(b_ext, mm.shape)),
                m, 0.0, 0.1e-12)
        return abs(1.0 - float(order_parameter_z(m)))

    dev_afm = run(AFMTJ_PARAMS)
    dev_mtj = run(MTJ_PARAMS)
    assert dev_afm < dev_mtj / 5.0, (dev_afm, dev_mtj)

"""Read-path scenario-family tests (DESIGN.md §10): read-disturb,
accelerated retention, sense-margin yield, and the refresh policy charged
into the system model.

The load-bearing pins:

* **offset_sigma dead-knob regression** — ``SenseAmpParams.offset_sigma``
  used to be stored and never read; now that it drives the sense MC, the
  ``offset_sigma=0`` / ``offset=None`` paths must stay *bit-identical* to
  the deterministic circuit model.
* **kernel-vs-oracle parity in the read regimes** — the campaign engine
  was only ever parity-tested in the write regime (strong over-threshold
  drive, short horizons).  Sub-threshold drive and zero-drive long-horizon
  integration hit different numerics (marginal crossings, ~10^4-step
  trajectories), so the Pallas path is pinned against ``kernels.ref``
  there too, including the log-horizon ladder and the MTJ
  single-sublattice routing.
* **one launch, one compile** per kernel-backed scenario.
* **refresh charging** — nominal Fig. 4 numbers must be bit-identical
  with the refresh knobs off, and strictly degrade with a finite scrub
  interval.
"""
import dataclasses
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.campaign.engine import _integrate_sharded
from repro.circuit.bitline import BitlineParams, multi_row_current
from repro.circuit.senseamp import (SenseAmpParams, resolve_logic,
                                    sa_offsets, sense_delay)
from repro.core.params import (AFMTJ_PARAMS, CORNER_FF, CORNER_SS, CORNER_TT,
                               VariationSpec)
from repro.imc.read_path import (DisturbModel, accumulated_disturb,
                                 read_disturb_campaign, reads_between_refresh,
                                 retention_campaign, sense_margin_yield,
                                 _censored_tau)

TT_ONLY = VariationSpec(corners=(CORNER_TT,))


# ------------------------------------------------ offset_sigma regression
def test_sa_offsets_zero_sigma_is_exact_zero():
    sa = SenseAmpParams(offset_sigma=0.0)
    assert (np.asarray(sa_offsets(sa, 257)) == 0.0).all()


def test_sa_offsets_population_and_crn():
    sa = SenseAmpParams(offset_sigma=5e-3)
    a = np.asarray(sa_offsets(sa, 4096, seed=3))
    b = np.asarray(sa_offsets(sa, 4096, seed=3))
    c = np.asarray(sa_offsets(sa, 4096, seed=4))
    np.testing.assert_array_equal(a, b)          # stateless: same seed, same pop
    assert not np.array_equal(a, c)
    assert abs(a.std() - 5e-3) / 5e-3 < 0.1
    assert abs(a.mean()) < 5e-4


def test_sense_delay_offset_none_bit_identical_to_zero_offset():
    """|di*r + 0| == |di|*r exactly in IEEE arithmetic — the offset=None
    fast path and an explicit zero offset must agree bit-for-bit."""
    sa = SenseAmpParams()
    di = jnp.asarray(np.linspace(-2e-5, 2e-5, 101), jnp.float32)
    t_none = np.asarray(sense_delay(di, sa))
    t_zero = np.asarray(sense_delay(di, sa, offset=jnp.zeros_like(di)))
    np.testing.assert_array_equal(t_none, t_zero)


@pytest.mark.parametrize("op", ["and", "nand", "or", "nor", "xor", "xnor"])
def test_resolve_logic_offset_none_bit_identical(op):
    sa, bl = SenseAmpParams(), BitlineParams()
    bits = jnp.asarray([[i >> 1 & 1, i & 1] for i in range(4)], jnp.float32)
    out0, d0 = resolve_logic(bits, op, AFMTJ_PARAMS, bl, sa)
    outz, dz = resolve_logic(bits, op, AFMTJ_PARAMS, bl, sa,
                             offset=jnp.zeros((4,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(outz))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(dz))


def test_resolve_logic_large_offset_flips_decision():
    """An offset past the reference gap is exactly the sense-yield failure
    mode: the resolved bit flips relative to the deterministic path."""
    sa, bl = SenseAmpParams(), BitlineParams()
    bits = jnp.asarray([[1.0, 1.0]])
    out0, _ = resolve_logic(bits, "and", AFMTJ_PARAMS, bl, sa)
    gap = float(multi_row_current(bits, AFMTJ_PARAMS, bl)[0]) * sa.r_trans
    big = jnp.asarray([-2.0 * gap], jnp.float32)
    out1, _ = resolve_logic(bits, "and", AFMTJ_PARAMS, bl, sa, offset=big)
    assert bool(out0[0]) and not bool(out1[0])


def test_sense_yield_deterministic_limit_is_perfect():
    """sigma_r=0 corners + offset_sigma=0 removes every noise source: the
    MC must report yield exactly 1.0 with a strictly positive margin."""
    sy = sense_margin_yield("afmtj", v_reads=(0.1,),
                            sa=SenseAmpParams(offset_sigma=0.0),
                            variation=TT_ONLY, n_samples=512)
    assert (sy.yield_surface == 1.0).all()
    assert sy.margin_min.min() > 0.0


# --------------------------------------------- kernel-vs-oracle parity
@pytest.fixture(scope="module")
def retention_pair():
    """Zero-drive campaign on the log-horizon ladder, Pallas vs the jnp
    oracle, plus the compile count of the Pallas run.  The horizon stays
    at 0.6 ns (6001 steps): strict bit-equality holds there; past ~10^4
    steps marginal crossings drift by one step (see the disturb test)."""
    kw = dict(accel_factors=(0.05,), temperatures=(300.0,),
              horizons=(0.2e-9, 0.6e-9), n_samples=32,
              variation=TT_ONLY, use_cache=False)
    _integrate_sharded._clear_cache()
    rp = retention_campaign("afmtj", backend="pallas", **kw)
    compiles = _integrate_sharded._cache_size()
    rr = retention_campaign("afmtj", backend="ref", **kw)
    return rp, rr, compiles


def test_retention_zero_drive_long_horizon_bit_equal(retention_pair):
    rp, rr, _ = retention_pair
    ctp = rp.result.crossing_time
    np.testing.assert_array_equal(ctp, rr.result.crossing_time)
    horizon = max(rp.grid.pulse_widths)
    assert (ctp < horizon).any()                 # escapes actually happened
    assert (ctp >= horizon).any()                # and the sentinel path too


def test_retention_one_launch_one_compile(retention_pair):
    rp, _, compiles = retention_pair
    assert rp.n_launches == 1
    assert compiles == 1


def test_retention_log_horizon_ladder_independent(retention_pair):
    """The log-horizon quantizer only changes the *compiled* horizon; the
    per-lane budget row stops real lanes at the true horizon, so crossing
    rows must match the unquantized (chunk=0) integration bit-for-bit."""
    from repro.campaign.engine import run_campaign

    rp, _, _ = retention_pair
    exact = run_campaign(AFMTJ_PARAMS, rp.grid, use_cache=False, chunk=0)
    np.testing.assert_array_equal(rp.result.crossing_time,
                                  exact.crossing_time)


@pytest.fixture(scope="module")
def retention_stats():
    """Two measurable acceleration rungs for the MLE/Arrhenius stack
    (same shape as the bench smoke config: both rungs flip >= min_flips
    lanes within the 1.2 ns window at n=96)."""
    from repro.campaign.grid import log_pulses

    return retention_campaign(
        "afmtj", accel_factors=(0.05, 0.10), temperatures=(300.0,),
        horizons=log_pulses(0.15e-9, 1.2e-9, per_decade=3),
        n_samples=96, variation=TT_ONLY, use_cache=False)


def test_retention_mle_and_extrapolation(retention_stats):
    """Measured escape times must order by barrier, the Arrhenius
    cross-check must land in the activated-escape band, and the pinned
    slope extrapolation must put operating retention far beyond the
    simulated horizon."""
    rp = retention_stats
    tau = rp.tau_acc[0, 0]                       # (n_accel,)
    assert rp.n_flips[0, 0].min() >= rp.min_flips
    assert tau[0] < tau[1]                       # Delta_eff 2 escapes faster
    slope, _ = rp.arrhenius_fit(0, 0)
    assert 0.3 < slope < 3.0
    assert rp.tau0(0, 0) > 0.0
    t_op = rp.worst_tau_op()
    assert t_op > 1e3                            # seconds, vs a ns horizon
    q = rp.retention_percentiles(qs=(1e-9, 1e-6))[0, 0]
    assert 0 < q[0] < q[1]                       # tighter quantile is sooner


def test_disturb_sub_threshold_crossings_match_oracle():
    """Sub-threshold drive at elevated T: marginal thermally-assisted
    crossings ~10^4 steps in.  Crossed/uncrossed sets must match the
    oracle exactly; crossing steps may land one step apart (ulp-level
    trajectory divergence between the fused kernel and the jnp scan over
    that many steps), never more."""
    kw = dict(voltages=(0.10, 0.24), pulses=(1.0e-9,),
              temperatures=(400.0,), n_samples=48, use_cache=False)
    dp = read_disturb_campaign("afmtj", backend="pallas", **kw)
    dr = read_disturb_campaign("afmtj", backend="ref", **kw)
    dt = dp.grid.dt
    sp = np.round(dp.result.crossing_time / dt)
    sr = np.round(dr.result.crossing_time / dt)
    horizon = dp.grid.n_steps
    np.testing.assert_array_equal(sp >= horizon, sr >= horizon)
    assert (sp < horizon).any()                  # disturb flips occurred
    assert np.abs(sp - sr).max() <= 1.0
    # sub-threshold bias must not disturb the low rung at this horizon
    assert (sp[0, 0] >= horizon).all()


def test_mtj_single_sublattice_path_parity():
    """MTJ campaigns route through the ref scan for both backends — the
    routing itself plus crossing extraction must agree bit-for-bit, with
    the over-threshold rung crossing and the sub-threshold rung not."""
    kw = dict(voltages=(0.2, 1.0), pulses=(2.5e-9,), temperatures=(300.0,),
              n_samples=24, use_cache=False)
    dp = read_disturb_campaign("mtj", backend="pallas", **kw)
    dr = read_disturb_campaign("mtj", backend="ref", **kw)
    ct = dp.result.crossing_time
    np.testing.assert_array_equal(ct, dr.result.crossing_time)
    horizon = 2.5e-9
    assert (ct[0, 1] < horizon).all()            # 1.0 V writes
    assert (ct[0, 0] >= horizon).all()           # 0.2 V holds


def test_disturb_campaign_one_launch_one_compile():
    _integrate_sharded._clear_cache()
    res = read_disturb_campaign("afmtj", voltages=(0.10, 0.24),
                                pulses=(0.2e-9,), temperatures=(300.0, 400.0),
                                n_samples=32, use_cache=False)
    assert res.n_launches == 1
    assert _integrate_sharded._cache_size() == 1


# --------------------------------------------------- disturb model math
def test_accumulated_disturb_and_refresh_roundtrip():
    assert accumulated_disturb(0.0, 1e9) == 0.0
    p1 = 3e-7
    assert abs(accumulated_disturb(p1, 1000) - (1 - (1 - p1) ** 1000)) < 1e-12
    n = reads_between_refresh(p1, 1e-4)
    assert abs(accumulated_disturb(p1, n) - 1e-4) / 1e-4 < 1e-9
    assert math.isinf(reads_between_refresh(0.0, 1e-9))


def test_disturb_model_suppression_shape():
    m = DisturbModel(kind="afmtj", v_c=0.2, beta=1.5, accel_factor=0.1,
                     delta_acc=4.0, tau0_acc=1e-9, voltages=(0.0,),
                     tau_meas=(1e-9,), sse=0.0)
    assert m.suppression(0.0) == 1.0
    assert m.suppression(0.25) == 0.0            # clipped above V_c
    vs = np.linspace(0.0, 0.19, 20)
    s = np.array([m.suppression(v) for v in vs])
    assert (np.diff(s) < 0).all()                # monotone suppression
    p = np.array([m.p1(v, 1e-9, 40.0, 0.25e-9) for v in vs])
    assert (np.diff(p) > 0).all()                # disturb grows with bias
    assert m.p1(0.0, 1e-9, 40.0, 0.25e-9) < 1e-15


def test_censored_tau_mle():
    # all escaped: plain mean
    tau, n = _censored_tau(np.array([1.0, 3.0]), horizon=10.0)
    assert n == 2 and tau == 2.0
    # half censored: survivors contribute their censored horizon
    tau, n = _censored_tau(np.array([2.0, 20.0]), horizon=10.0)
    assert n == 1 and tau == 12.0
    # nothing escaped
    tau, n = _censored_tau(np.array([20.0, 20.0]), horizon=10.0)
    assert n == 0 and math.isinf(tau)


# ------------------------------------------------------ sense-margin MC
@pytest.fixture(scope="module")
def sense_surface():
    return sense_margin_yield("afmtj", n_samples=2048, seed=0)


def test_sense_yield_ladders_with_read_voltage(sense_surface):
    sy = sense_surface
    y = sy.yield_surface                         # (n_corners, n_V)
    assert y.shape == (3, len(sy.v_reads))
    assert (np.diff(y, axis=1) >= 0).all()       # more bias, more margin
    v = sy.v_read_for_yield(0.999)
    assert v in sy.v_reads
    wi = int(np.argmin(y[:, -1]))
    assert y[wi, list(sy.v_reads).index(v)] >= 0.999


def test_sense_yield_target_beyond_ladder_raises(sense_surface):
    with pytest.raises(ValueError):
        sense_surface.v_read_for_yield(1.0 + 1e-9)


def test_sense_yield_nominal_trim_exposes_systematic_corner_loss():
    """Without per-corner reference trimming the r_factor=1.15 slow corner
    pushes part of its D2D tail across the nominal reference — a yield
    ceiling raising v_read cannot fix.  Corner trimming removes it."""
    kw = dict(v_reads=(0.1, 0.2), n_samples=2048, seed=0)
    trimmed = sense_margin_yield("afmtj", ref_trim="corner", **kw)
    untrimmed = sense_margin_yield("afmtj", ref_trim="nominal", **kw)
    si = list(trimmed.corner_names).index("ss")
    assert untrimmed.yield_surface[si].max() < 0.995
    assert trimmed.yield_surface[si].max() > 0.999


def test_sense_time_budget_costs_yield(sense_surface):
    tight = sense_margin_yield("afmtj", n_samples=2048, seed=0,
                               t_budget=float(sense_surface.t_sense.min()))
    assert tight.yield_surface.min() < sense_surface.yield_surface.min()


def test_measured_read_timings_thread_into_subarray():
    from repro.circuit.subarray import make_subarray
    from repro.imc.read_path import measured_read_timings

    det = make_subarray("afmtj", rows=64, cols=64)
    meas = make_subarray("afmtj", rows=64, cols=64, read_percentile=99.0,
                         sa=SenseAmpParams(offset_sigma=5e-3))
    assert det.timings.read_percentile is None
    assert det.timings.read_yield == 1.0
    assert meas.timings.read_percentile == 99.0
    assert 0.9 < meas.timings.read_yield <= 1.0
    # p99 over (corner x D2D x offset) must be slower than the nominal path
    assert meas.timings.t_read > det.timings.t_read
    # lru-cached characterization: identical args, identical object
    mr = measured_read_timings("afmtj", v_read=0.1, percentile=99.0)
    assert mr is measured_read_timings("afmtj", v_read=0.1, percentile=99.0)


# ----------------------------------------------------- refresh charging
def test_system_nominal_refresh_fields_inert():
    from repro.imc.evaluate import evaluate_system

    res = evaluate_system("afmtj")
    for r in res.values():
        assert r.t_refresh == 0.0 and r.e_refresh == 0.0
        assert math.isinf(r.refresh_interval)


def test_refresh_policy_charging_monotone():
    from repro.imc.evaluate import evaluate_system
    from repro.imc.read_path import RefreshPolicy

    def pol(interval):
        return RefreshPolicy(interval=interval, limited_by="disturb",
                             tau_retention=1e7, p1_read=1e-10,
                             reads_max=10.0, ber_budget=1e-9,
                             reads_per_cell_s=1e6)

    base = evaluate_system("afmtj")
    inert = evaluate_system("afmtj", refresh=pol(math.inf))
    for name, r in base.items():
        assert inert[name].t_imc == r.t_imc      # inf interval: bit-identical
        assert inert[name].e_imc == r.e_imc
    slow = evaluate_system("afmtj", refresh=pol(1e-4))
    fast = evaluate_system("afmtj", refresh=pol(1e-5))
    for name, r in base.items():
        assert slow[name].t_refresh > 0.0
        assert fast[name].t_refresh > slow[name].t_refresh
        assert fast[name].e_imc > slow[name].e_imc > r.e_imc
        assert slow[name].t_imc == pytest.approx(
            r.t_imc + slow[name].t_refresh)
        assert slow[name].speedup < r.speedup


def test_refresh_policy_is_hashable_pure_data():
    from repro.imc.read_path import RefreshPolicy

    p = RefreshPolicy(interval=1e-4, limited_by="retention",
                      tau_retention=1e7, p1_read=0.0, reads_max=math.inf,
                      ber_budget=1e-9, reads_per_cell_s=1e6)
    assert hash(p) == hash(dataclasses.replace(p))

"""Per-arch smoke tests (reduced configs) + decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, smoke_config
from repro.models import model as M


def _batch(cfg, key, B=2, S=16):
    k1, k2 = jax.random.split(key)
    b = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab),
    }
    if cfg.frontend_positions and not cfg.n_encoder_layers:
        b["frontend_embeds"] = jax.random.normal(
            k1, (B, cfg.frontend_positions, cfg.d_model))
    if cfg.n_encoder_layers:
        b["encoder_frames"] = jax.random.normal(
            k1, (B, cfg.frontend_positions, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_step(arch):
    """One forward/train step on CPU: finite loss, no NaNs, grads flow."""
    cfg = smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        loss, metrics = M.forward_train(p, cfg, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0.0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_serve_shapes(arch):
    cfg = smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    B, S = batch["tokens"].shape
    logits, cache = M.serve_prefill(params, cfg, batch, max_seq=S + 8 +
                                    (cfg.frontend_positions if not cfg.n_encoder_layers else 0))
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    logits2, cache = M.serve_step(params, cfg, cache, tok)
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2, dtype=np.float32)))
    assert int(cache["pos"]) == int(S + (cfg.frontend_positions
                                         if not cfg.n_encoder_layers else 0)) + 1


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "gemma2-2b", "mamba2-780m",
                                  "jamba-1.5-large-398b", "olmoe-1b-7b"])
def test_decode_matches_forward(arch):
    """Strong consistency: prefill(S) + decode(t) logits == prefill(S+1)'s
    last-token logits, position by position."""
    cfg = smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16   # multiple of the smoke SSD chunk (8)
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S + 1), 0, cfg.vocab)

    batch_s = {"tokens": toks[:, :S]}
    batch_s1 = {"tokens": toks}
    logits_s, cache = M.serve_prefill(params, cfg, batch_s, max_seq=S + 4)
    logits_dec, _ = M.serve_step(params, cfg, cache, toks[:, S:S + 1])
    logits_full, _ = M.serve_prefill(params, cfg, batch_s1, max_seq=S + 4)

    a = np.asarray(logits_dec[:, 0], dtype=np.float32)
    b = np.asarray(logits_full[:, -1], dtype=np.float32)
    np.testing.assert_allclose(a, b, atol=2e-2, rtol=2e-2)


def test_moe_single_expert_equals_dense():
    """top-1 over a single expert must equal the dense FFN with its weights."""
    import dataclasses
    from repro.configs.base import MoEConfig
    from repro.models import ffn as F

    cfg = smoke_config("olmoe-1b-7b")
    cfg = dataclasses.replace(cfg, moe=MoEConfig(num_experts=1, top_k=1,
                                                 d_expert=64))
    key = jax.random.PRNGKey(0)
    from repro.models.common import init_params as init_specs
    p = init_specs(F.moe_specs(cfg), cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_moe, aux = F.moe_ffn(p, x, cfg)
    dense_p = {"w_gate": p["w_gate"][0], "w_up": p["w_up"][0],
               "w_down": p["w_down"][0]}
    y_dense = F.dense_ffn(dense_p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_moe), np.asarray(y_dense),
                               atol=1e-4, rtol=1e-4)


def test_mamba_chunked_equals_stepwise():
    """SSD chunked forward == token-by-token recurrence (duality check)."""
    from repro.models import ssm as S
    from repro.models.common import init_params as init_specs

    cfg = smoke_config("mamba2-780m")
    p = init_specs(S.mamba_specs(cfg), cfg, jax.random.PRNGKey(0))
    B, L = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, L, cfg.d_model)) * 0.1
    y_chunked = S.mamba_forward(p, x, cfg)

    cache = S.init_mamba_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(L):
        y_t, cache = S.mamba_decode_step(p, x[:, t:t + 1], cache, cfg)
        ys.append(y_t)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_steps),
                               atol=1e-3, rtol=1e-3)


def test_local_vs_global_attention_differ():
    """gemma2's local layers must actually mask beyond the window."""
    cfg = smoke_config("gemma2-2b")   # sliding_window=8
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 16
    t1 = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    # perturb a token OUTSIDE the window of the last position
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab)
    l1, _ = M.serve_prefill(params, cfg, {"tokens": t1}, max_seq=S)
    l2, _ = M.serve_prefill(params, cfg, {"tokens": t2}, max_seq=S)
    # global layers see position 0, so logits still differ — but check the
    # masks exist by ensuring finite outputs (structural test)
    assert np.all(np.isfinite(np.asarray(l1, dtype=np.float32)))
    assert np.all(np.isfinite(np.asarray(l2, dtype=np.float32)))


def test_param_counts_match_names():
    """Declared model scale ~ parameter count (sanity for 6ND roofline)."""
    expect = {
        "gemma2-2b": (2.0e9, 3.5e9),
        "internlm2-20b": (17e9, 23e9),
        "qwen2-0.5b": (0.4e9, 0.65e9),
        "qwen3-8b": (7e9, 10e9),
        "llama4-maverick-400b-a17b": (350e9, 450e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "jamba-1.5-large-398b": (330e9, 460e9),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, (name, n)


def test_active_params_moe():
    a = ARCHS["llama4-maverick-400b-a17b"]
    act = a.active_param_count()
    assert 12e9 <= act <= 25e9, act   # "a17b"

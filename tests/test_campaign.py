"""Campaign engine tests: thermal kernel parity, WER physics, caching,
crash-safe cache writes, and crash-resumable multi-launch campaigns."""
import dataclasses
import os
import signal
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.campaign import (CampaignGrid, brown_sigma, pack_plane,
                            run_campaign, run_ensemble)
from repro.core import llg
from repro.core.params import AFMTJ_PARAMS
from repro.kernels import noise, ops, ref


# ------------------------------------------------------------ noise streams
def test_noise_stream_statistics():
    """Counter-RNG normals: ~N(0,1), decorrelated across lanes and steps."""
    seeds = noise.cell_seeds(0, 2048)
    zs = []
    for step in range(8):                       # 8 x 6 x 2048 ~ 100k draws
        d1, d2 = noise.thermal_draws(seeds, jnp.int32(step))
        zs.append(np.stack([np.asarray(c) for c in d1 + d2]))
    z = np.stack(zs)
    assert abs(z.mean()) < 0.015                # ~5 sigma of the MC error
    assert abs(z.std() - 1.0) < 0.02
    # consecutive steps must decorrelate
    r = np.corrcoef(z[0, 0], z[1, 0])[0, 1]
    assert abs(r) < 0.1


def test_noise_stream_deterministic():
    seeds = noise.cell_seeds(7, 512)
    a, _ = noise.thermal_draws(seeds, jnp.int32(11))
    b, _ = noise.thermal_draws(seeds, jnp.int32(11))
    assert all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


# ---------------------------------------------------- kernel-vs-oracle parity
def _states(cells, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    th = jax.random.uniform(k1, (cells,), minval=0.05, maxval=0.25)
    ph = jax.random.uniform(k2, (cells,), minval=0.0, maxval=6.28)
    m0 = jax.vmap(lambda t, f: llg.initial_state(AFMTJ_PARAMS, t, f))(th, ph)
    return ops.pack_states(m0, jnp.linspace(0.3, 1.2, cells))


@pytest.mark.parametrize("n_steps", [50, 200])
def test_thermal_kernel_matches_ref_exact_stream(n_steps):
    """Pallas-with-noise vs ref.py oracle at a fixed seed: the counter-RNG
    is stateless, so both consume the *identical* thermal stream and the
    trajectories must agree to float tolerance (not just statistically)."""
    cells, dt = 512, 0.1e-12
    state = _states(cells)
    sigma = brown_sigma(AFMTJ_PARAMS, dt)
    seeds = noise.cell_seeds(42, cells)
    out_k = ops.llg_rk4_thermal(state, seeds, AFMTJ_PARAMS, dt, n_steps, sigma)
    out_r = ref.ref_llg_rk4(state, AFMTJ_PARAMS, dt, n_steps,
                            thermal_sigma=sigma, seeds=seeds)
    np.testing.assert_allclose(np.asarray(out_k[:6]), np.asarray(out_r[:6]),
                               atol=2e-5)
    assert np.array_equal(np.asarray(out_k[7]), np.asarray(out_r[7]))


def test_thermal_zero_sigma_reduces_to_deterministic():
    state = _states(512, seed=2)
    out_t = ops.llg_rk4_thermal(state, noise.cell_seeds(0, 512),
                                AFMTJ_PARAMS, 0.1e-12, 100, 0.0)
    out_d = ops.llg_rk4(state, AFMTJ_PARAMS, 0.1e-12, 100)
    # the thermal kernel adds an exact 0.0 field, but XLA fuses the add
    # differently than the deterministic kernel — rounding can differ by
    # a ulp per step, so pin to a few f32 ulps rather than bit equality
    np.testing.assert_allclose(np.asarray(out_t), np.asarray(out_d),
                               rtol=0, atol=5e-7)


def test_thermal_seeds_decorrelate_lanes():
    """Same initial state on every lane + noise => lanes must diverge."""
    m0 = jnp.broadcast_to(llg.initial_state(AFMTJ_PARAMS, 0.1, 0.3), (512, 2, 3))
    state = ops.pack_states(m0, jnp.full((512,), 1.0))
    sigma = brown_sigma(AFMTJ_PARAMS, 0.1e-12)
    out = ops.llg_rk4_thermal(state, noise.cell_seeds(1, 512),
                              AFMTJ_PARAMS, 0.1e-12, 200, sigma)
    nz = np.asarray(0.5 * (out[2] - out[5]))
    assert nz.std() > 1e-3


# ------------------------------------------------------------- WER physics
@pytest.fixture(scope="module")
def campaign_result():
    grid = CampaignGrid(voltages=(0.8, 1.0, 1.2),
                        pulse_widths=(120e-12, 200e-12, 300e-12),
                        n_samples=48, dt=0.1e-12, seed=0)
    return run_campaign(AFMTJ_PARAMS, grid, use_cache=False)


def test_wer_monotone_in_pulse_and_voltage(campaign_result):
    """WER must be non-increasing along both the pulse and voltage axes."""
    w = campaign_result.wer()                      # (n_V, n_P)
    assert (np.diff(w, axis=1) <= 0).all(), f"not monotone in pulse:\n{w}"
    assert (np.diff(w, axis=0) <= 1e-9).all(), f"not monotone in voltage:\n{w}"
    # end-member sanity: strong long pulse writes reliably, weak short doesn't
    assert w[-1, -1] <= 0.05
    assert w[0, 0] >= w[-1, -1]


def test_wer_counts_unswitched_at_longest_pulse():
    """Regression: the never-crossed sentinel must exceed every grid pulse,
    or unswitched lanes are miscounted as successful writes at the longest
    pulse (WER 0.0 where the scan oracle says ~0.5)."""
    grid = CampaignGrid(voltages=(0.6,), pulse_widths=(250e-12,),
                        n_samples=32, dt=0.1e-12, seed=0)
    assert grid.n_steps * grid.dt > max(grid.pulse_widths)
    res = run_campaign(AFMTJ_PARAMS, grid, use_cache=False)
    assert res.wer()[0, -1] > 0.1, res.wer()


def test_pulse_for_wer_raises_when_unreachable():
    from repro.campaign import CampaignResult
    grid = CampaignGrid(voltages=(0.5,), pulse_widths=(50e-12,),
                        n_samples=4, dt=0.1e-12)
    never = np.full((1, 1, 4), grid.n_steps * grid.dt)   # nobody switched
    res = CampaignResult(grid=grid, backend="pallas", crossing_time=never,
                         elapsed_s=0.0)
    with pytest.raises(ValueError, match="widen"):
        res.pulse_for_wer(1e-2)


def test_pack_states_rejects_single_sublattice():
    from repro.core.params import MTJ_PARAMS
    m0 = jax.vmap(lambda t: llg.initial_state(MTJ_PARAMS, t, 0.1))(
        jnp.linspace(0.01, 0.2, 8))
    with pytest.raises(AssertionError, match="dual-sublattice"):
        ops.pack_states(m0, jnp.ones(8))


# ------------------------------------------- single-sublattice (FM/MTJ) path
def test_pack_soa_single_sublattice_layout():
    """FM states pack with m in rows 0-2, zero rows 3-5, CELL_TILE padding."""
    from repro.campaign import pack_soa
    from repro.core.params import MTJ_PARAMS
    m0 = jax.vmap(lambda t: llg.initial_state(MTJ_PARAMS, t, 0.1))(
        jnp.linspace(0.01, 0.2, 8))
    state = pack_soa(m0, jnp.linspace(0.8, 1.2, 8))
    assert state.shape[0] == 8 and state.shape[1] % 512 == 0
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(state[0:3, :8]), axis=0), 1.0, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(state[3:6]), 0.0)


def test_fm_campaign_matches_scan_statistics():
    """The engine's FM scan tile and the independently-seeded
    ``write_error_rate_scan`` baseline must agree on MTJ WER within
    Monte-Carlo error (two RNG implementations, same physics)."""
    from repro.core.montecarlo import write_error_rate, write_error_rate_scan
    from repro.core.params import MTJ_PARAMS
    pulse, n, dt = 1400e-12, 48, 0.2e-12
    w_engine = write_error_rate(MTJ_PARAMS, 1.0, pulse, n_samples=n, dt=dt)
    w_scan = float(write_error_rate_scan(MTJ_PARAMS, 1.0, pulse,
                                         n_samples=n, dt=dt))
    # binomial std at p~0.5, n=48 is ~0.07; allow ~3 sigma both ways
    assert abs(w_engine - w_scan) < 0.25, (w_engine, w_scan)


def test_fm_wer_monotone_in_pulse():
    from repro.core.params import MTJ_PARAMS
    grid = CampaignGrid(voltages=(1.0,),
                        pulse_widths=(900e-12, 1400e-12, 2000e-12),
                        n_samples=32, dt=0.2e-12, seed=0)
    res = run_campaign(MTJ_PARAMS, grid, use_cache=False)
    w = res.wer()[0]
    assert (np.diff(w) <= 0).all(), w
    assert w[0] > w[-1]           # short pulses must actually fail more


def test_wer_pulse_axis_is_postprocessing(campaign_result):
    """WER at the longest grid pulse == fraction not crossed by then."""
    ct = campaign_result.crossing_time[0]          # (n_V, n_S) at T0
    pulse = campaign_result.grid.pulse_widths[-1]
    expect = (ct > pulse).mean(axis=-1)
    np.testing.assert_allclose(campaign_result.wer()[:, -1], expect)


def test_latency_percentiles(campaign_result):
    lp = campaign_result.latency_percentiles((50.0, 99.0))
    ok = ~np.isnan(lp)
    assert ok.any()
    # p99 >= p50 wherever defined; higher voltage switches faster at p50
    assert (lp[..., 1][ok[..., 1]] >= lp[..., 0][ok[..., 0]]).all()
    p50 = lp[0, :, 0]
    assert p50[-1] <= p50[0]


def test_engine_agrees_with_scan_statistics():
    """Two independent RNG implementations of the same physics must agree
    on WER within Monte-Carlo error."""
    from repro.core.montecarlo import write_error_rate, write_error_rate_scan
    pulse, n = 200e-12, 64
    w_engine = write_error_rate(AFMTJ_PARAMS, 1.0, pulse, n_samples=n)
    w_scan = float(write_error_rate_scan(AFMTJ_PARAMS, 1.0, pulse, n_samples=n))
    # binomial std at p~0.1, n=64 is ~0.04; allow 3 sigma both ways
    assert abs(w_engine - w_scan) < 0.15, (w_engine, w_scan)


# ------------------------------------------------------------------ caching
def test_campaign_cache_roundtrip(tmp_path):
    grid = CampaignGrid(voltages=(1.0,), pulse_widths=(60e-12,),
                        n_samples=8, dt=0.1e-12, seed=3)
    r1 = run_campaign(AFMTJ_PARAMS, grid, cache_dir=str(tmp_path))
    assert not r1.from_cache
    r2 = run_campaign(AFMTJ_PARAMS, grid, cache_dir=str(tmp_path))
    assert r2.from_cache and r2.elapsed_s == 0.0
    np.testing.assert_array_equal(r1.crossing_time, r2.crossing_time)
    # any input change must miss: different device params -> new key
    p2 = dataclasses.replace(AFMTJ_PARAMS, alpha=0.02)
    r3 = run_campaign(p2, grid, cache_dir=str(tmp_path))
    assert not r3.from_cache


def test_campaign_cache_corrupt_entry_is_miss(tmp_path):
    from repro.campaign.cache import campaign_key
    grid = CampaignGrid(voltages=(1.0,), pulse_widths=(60e-12,),
                        n_samples=8, dt=0.1e-12, seed=4)
    key = campaign_key(AFMTJ_PARAMS, grid, "pallas")
    (tmp_path / f"{key}.npz").write_bytes(b"not an npz")
    r = run_campaign(AFMTJ_PARAMS, grid, cache_dir=str(tmp_path))
    assert not r.from_cache           # corrupt entry read as miss, re-run


# ------------------------------------------------- crash safety / resume
REPO = Path(__file__).resolve().parents[1]
_ENV = {**os.environ, "PYTHONPATH": str(REPO / "src")}


def _resume_grid():
    return CampaignGrid(voltages=(0.6, 1.2), pulse_widths=(120e-12, 250e-12),
                        temperatures=(300.0, 350.0, 400.0), n_samples=16,
                        dt=0.1e-12, seed=0)


def test_store_arrays_kill_mid_write_never_corrupts(tmp_path):
    """A process SIGKILLed mid-``store_arrays`` leaves only a ``.tmp``
    dropping — the atomic rename never ran, so loads stay clean misses and
    the stale-tmp sweep reclaims the disk."""
    from repro.campaign.cache import gc_stale_tmp, load_arrays, store_arrays

    child = textwrap.dedent("""
        import os, signal, sys
        import numpy as np
        from repro.campaign import cache

        def killer(f, **kw):
            f.write(b"partial write, then the lights go out")
            f.flush()
            os.kill(os.getpid(), signal.SIGKILL)

        np.savez_compressed = killer
        cache.store_arrays("deadbeef", {"a": np.ones(8)}, {},
                           cache_dir=sys.argv[1])
    """)
    r = subprocess.run([sys.executable, "-c", child, str(tmp_path)],
                       env=_ENV, capture_output=True, text=True, timeout=120)
    assert r.returncode == -signal.SIGKILL, r.stderr
    leftovers = sorted(p.name for p in tmp_path.iterdir())
    assert leftovers and all(n.endswith(".tmp") for n in leftovers), leftovers
    assert load_arrays("deadbeef", cache_dir=str(tmp_path)) is None
    # fresh droppings survive the default age guard (a live writer may own
    # them); max_age_s=0 reclaims them
    assert gc_stale_tmp(str(tmp_path)) == 0
    assert gc_stale_tmp(str(tmp_path), max_age_s=0.0) == len(leftovers)
    assert not any(tmp_path.iterdir())
    # and the store works normally afterwards
    store_arrays("deadbeef", {"a": np.arange(3.0)}, {"k": 1},
                 cache_dir=str(tmp_path))
    got = load_arrays("deadbeef", cache_dir=str(tmp_path))
    np.testing.assert_array_equal(got["a"], np.arange(3.0))
    assert not list(tmp_path.glob("*.tmp"))


def test_campaign_kill_resume_bit_identical(tmp_path):
    """Acceptance pin: a campaign SIGKILLed after its first launch resumes
    from the slice checkpoints and assembles the crossing tensor
    bit-identically to an uninterrupted run (subprocess kill, real files)."""
    from repro.campaign.grid import bucket_cells

    grid = _resume_grid()
    per = bucket_cells(grid.cells)
    child = textwrap.dedent("""
        import os, signal, sys
        from repro.campaign.engine import run_campaign
        from repro.campaign.grid import CampaignGrid, bucket_cells
        from repro.core.params import AFMTJ_PARAMS

        grid = CampaignGrid(voltages=(0.6, 1.2),
                            pulse_widths=(120e-12, 250e-12),
                            temperatures=(300.0, 350.0, 400.0),
                            n_samples=16, dt=0.1e-12, seed=0)

        def killer(i, n):
            if i == 0:
                os.kill(os.getpid(), signal.SIGKILL)

        run_campaign(AFMTJ_PARAMS, grid, backend="ref",
                     cache_dir=sys.argv[1],
                     max_cells_per_launch=bucket_cells(grid.cells),
                     on_slice_complete=killer)
    """)
    r = subprocess.run([sys.executable, "-c", child, str(tmp_path)],
                       env=_ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == -signal.SIGKILL, r.stderr
    assert list(tmp_path.glob("*.npz")), "no slice checkpoint survived"

    fresh = run_campaign(AFMTJ_PARAMS, grid, backend="ref", use_cache=False,
                         max_cells_per_launch=per)
    resumed = run_campaign(AFMTJ_PARAMS, grid, backend="ref",
                           cache_dir=str(tmp_path), max_cells_per_launch=per)
    assert not resumed.from_cache
    assert resumed.n_launches == 3 and resumed.n_resumed == 1
    np.testing.assert_array_equal(resumed.crossing_time, fresh.crossing_time)
    # slice checkpoints retired once the whole-campaign entry is durable
    cached = run_campaign(AFMTJ_PARAMS, grid, backend="ref",
                          cache_dir=str(tmp_path), max_cells_per_launch=per)
    assert cached.from_cache
    assert len(list(tmp_path.glob("*.npz"))) == 1


def test_campaign_resume_in_process_hook(tmp_path):
    """The ``on_slice_complete`` hook fires after each checkpointed launch;
    aborting through it leaves resumable state (no subprocess needed)."""
    from repro.campaign.grid import bucket_cells

    grid = _resume_grid()
    per = bucket_cells(grid.cells)

    class Abort(Exception):
        pass

    def die_after_two(i, n):
        assert n == 3
        if i == 1:
            raise Abort

    with pytest.raises(Abort):
        run_campaign(AFMTJ_PARAMS, grid, backend="ref",
                     cache_dir=str(tmp_path), max_cells_per_launch=per,
                     on_slice_complete=die_after_two)
    res = run_campaign(AFMTJ_PARAMS, grid, backend="ref",
                       cache_dir=str(tmp_path), max_cells_per_launch=per)
    assert res.n_resumed == 2 and not res.from_cache
    fresh = run_campaign(AFMTJ_PARAMS, grid, backend="ref", use_cache=False,
                         max_cells_per_launch=per)
    np.testing.assert_array_equal(res.crossing_time, fresh.crossing_time)


def test_campaign_launch_retry_bounded(monkeypatch):
    """Transient launch failures retry with backoff and still produce the
    exact result; a persistent failure raises after max_retries."""
    from repro.campaign import engine

    grid = _resume_grid()
    fresh = run_campaign(AFMTJ_PARAMS, grid, backend="ref", use_cache=False)

    real = engine._integrate_sharded
    calls = {"n": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient device loss")
        return real(*a, **kw)

    monkeypatch.setattr(engine, "_integrate_sharded", flaky)
    res = engine.run_campaign(AFMTJ_PARAMS, grid, backend="ref",
                              use_cache=False, max_retries=1,
                              retry_backoff_s=0.0)
    np.testing.assert_array_equal(res.crossing_time, fresh.crossing_time)

    def always_fails(*a, **kw):
        calls["n"] += 1
        raise RuntimeError("dead device")

    calls["n"] = 0
    monkeypatch.setattr(engine, "_integrate_sharded", always_fails)
    with pytest.raises(RuntimeError, match="dead device"):
        engine.run_campaign(AFMTJ_PARAMS, grid, backend="ref",
                            use_cache=False, max_retries=2,
                            retry_backoff_s=0.0)
    assert calls["n"] == 4          # 1 dispatch + 1 sync + 2 bounded retries


# ------------------------------------------------------------- grid/packing
def test_pack_plane_layout():
    grid = CampaignGrid(voltages=(0.5, 1.0), pulse_widths=(100e-12,),
                        n_samples=10, dt=0.1e-12)
    state, seeds = pack_plane(grid, AFMTJ_PARAMS, 0)
    assert state.shape[0] == 8 and state.shape[1] % 512 == 0
    assert seeds.shape == (state.shape[1],) and seeds.dtype == jnp.uint32
    # voltage row: sample s of voltage i at lane i*n_samples + s
    v = np.asarray(state[6, :grid.cells])
    np.testing.assert_allclose(v, np.repeat([0.5, 1.0], 10), rtol=1e-6)
    # all real lanes hold unit-norm antiparallel sublattice pairs
    m1 = np.asarray(state[0:3, :grid.cells])
    np.testing.assert_allclose(np.linalg.norm(m1, axis=0), 1.0, atol=1e-6)


def test_run_ensemble_per_cell_voltages():
    """The general entry point (array_mc_sim path): per-cell drives."""
    n = 100
    m0 = jax.vmap(lambda t: llg.initial_state(AFMTJ_PARAMS, t, 0.2))(
        jnp.linspace(0.05, 0.15, n))
    v = jnp.linspace(0.9, 1.1, n)
    res = run_ensemble(AFMTJ_PARAMS, m0, v, 0.1e-12, 300, seed=0)
    assert res.crossing_steps.shape == (n,)
    assert res.switched.dtype == bool

"""Scaling-path tests (DESIGN.md §14): streaming on-device reduction,
donated retry buffers, pad-don't-demote device planning, lockless claims,
multi-process campaign dedupe, and elastic device-count resume.

Multi-device cases run in subprocesses with
``--xla_force_host_platform_device_count`` (the flag must precede the
child's first jax import); everything the children integrate is compared
bit-for-bit against this process's single-device run.
"""
import json
import os
import signal
import subprocess
import sys
import textwrap
import time
from pathlib import Path

import numpy as np
import pytest

from repro.campaign import CampaignGrid, run_campaign
from repro.campaign.engine import (_hist_step_values, _percentiles_from_hist,
                                   _wer_threshold_steps)
from repro.campaign.grid import bucket_cells
from repro.core.params import AFMTJ_PARAMS
from repro.launch.mesh import CampaignMesh, host_device_flag
from repro.launch.sharding import plan_cell_tiles

REPO = Path(__file__).resolve().parents[1]
_ENV = {**os.environ, "PYTHONPATH": str(REPO / "src")}


def _forced_env(n_devices: int) -> dict:
    env = dict(_ENV)
    old = env.get("XLA_FLAGS", "").strip()
    flag = host_device_flag(n_devices)
    env["XLA_FLAGS"] = f"{old} {flag}".strip() if old else flag
    return env


def _grid(**kw):
    base = dict(voltages=(0.6, 1.2), pulse_widths=(120e-12, 250e-12),
                temperatures=(300.0, 350.0, 400.0), n_samples=16,
                dt=0.1e-12, seed=0)
    base.update(kw)
    return CampaignGrid(**base)


# ------------------------------------------------- streaming reduction
@pytest.fixture(scope="module")
def dense_result():
    return run_campaign(AFMTJ_PARAMS, _grid(), use_cache=False)


def test_streaming_wer_bit_identical(dense_result):
    """Acceptance pin: reduce="stream" never round-trips lane planes, yet
    the WER surface is bit-identical to the dense reduction (host-side f64
    thresholds -> exact on-device integer compares)."""
    grid = _grid()
    res = run_campaign(AFMTJ_PARAMS, grid, use_cache=False, reduce="stream")
    assert res.reduced and res.crossing_time is None
    np.testing.assert_array_equal(res.wer_surface(),
                                  dense_result.wer_surface())
    assert res.n_samples_total == dense_result.n_samples_total
    assert res.wer_counts.shape == (3, 2, 2)
    # the whole point: result transfer is O(grid points) vs O(lane plane)
    assert 0 < res.host_bytes < dense_result.host_bytes


def test_streaming_percentiles_exact_with_per_step_bins(dense_result):
    """With n_bins >= n_steps the histogram resolves single steps, so the
    sketch reconstructs np.nanpercentile's output bit-for-bit."""
    grid = _grid()
    res = run_campaign(AFMTJ_PARAMS, grid, use_cache=False, reduce="stream",
                       n_bins=4096)
    assert 4096 >= grid.n_steps
    assert res.sketch_tolerance == 0.0
    qs = (10.0, 50.0, 90.0, 99.0)
    np.testing.assert_array_equal(res.latency_percentiles(qs),
                                  dense_result.latency_percentiles(qs))


def test_streaming_sketch_within_documented_tolerance(dense_result):
    """Coarse bins trade exactness for footprint; the error must stay
    inside the two-bin-width budget ``sketch_tolerance`` documents."""
    grid = _grid()
    res = run_campaign(AFMTJ_PARAMS, grid, use_cache=False, reduce="stream",
                       n_bins=128)
    tol = res.sketch_tolerance
    assert tol == 2.0 * grid.n_steps * grid.dt / 128
    lp_d = dense_result.latency_percentiles((50.0, 99.0))
    lp_s = res.latency_percentiles((50.0, 99.0))
    assert np.isnan(lp_d).sum() == np.isnan(lp_s).sum()
    err = np.nanmax(np.abs(lp_d - lp_s))
    assert err <= tol, (err, tol)
    # WER stays bit-exact at ANY bin count, and at 128 bins the transfer
    # shrinks by well over the 4x acceptance floor (BENCH.json re-measures)
    np.testing.assert_array_equal(res.wer_surface(),
                                  dense_result.wer_surface())
    assert res.host_bytes * 4 <= dense_result.host_bytes


def test_streaming_cache_separate_from_dense(tmp_path):
    """Streaming entries live under their own derived key: a dense entry
    never satisfies a streaming request (different payload family) and
    vice versa; the second streaming call is a pure cache hit."""
    grid = _grid(seed=11)
    d1 = run_campaign(AFMTJ_PARAMS, grid, cache_dir=str(tmp_path))
    s1 = run_campaign(AFMTJ_PARAMS, grid, cache_dir=str(tmp_path),
                      reduce="stream")
    assert not s1.from_cache                 # dense entry didn't shadow
    s2 = run_campaign(AFMTJ_PARAMS, grid, cache_dir=str(tmp_path),
                      reduce="stream")
    assert s2.from_cache and s2.reduced
    np.testing.assert_array_equal(s1.wer_counts, s2.wer_counts)
    np.testing.assert_array_equal(s1.latency_hist, s2.latency_hist)
    d2 = run_campaign(AFMTJ_PARAMS, grid, cache_dir=str(tmp_path))
    assert d2.from_cache                     # dense entry still intact
    np.testing.assert_array_equal(d1.crossing_time, d2.crossing_time)


def test_streaming_variation_grid():
    """The reduced surfaces grow the leading corner axis exactly like the
    dense ones (corner-major slice layout)."""
    from repro.core.params import CORNER_SS, CORNER_TT, VariationSpec
    spec = VariationSpec(corners=(CORNER_TT, CORNER_SS), seed=7)
    grid = _grid(variation=spec, temperatures=(300.0, 350.0))
    dense = run_campaign(AFMTJ_PARAMS, grid, use_cache=False)
    res = run_campaign(AFMTJ_PARAMS, grid, use_cache=False, reduce="stream",
                       n_bins=4096)
    assert res.wer_counts.shape == (2, 2, 2, 2)
    np.testing.assert_array_equal(res.wer_surface(), dense.wer_surface())
    np.testing.assert_array_equal(res.latency_percentiles((50.0,)),
                                  dense.latency_percentiles((50.0,)))


def test_streaming_multilaunch_checkpoint_resume(tmp_path, dense_result):
    """Streaming launches checkpoint their reduced payloads under the
    ``slice-reduced-*`` kind and resume bit-identically."""
    grid = _grid()
    per = bucket_cells(grid.cells)

    class Abort(Exception):
        pass

    def die_after_two(i, n):
        assert n == 3
        if i == 1:
            raise Abort

    with pytest.raises(Abort):
        run_campaign(AFMTJ_PARAMS, grid, cache_dir=str(tmp_path),
                     max_cells_per_launch=per, reduce="stream",
                     on_slice_complete=die_after_two)
    res = run_campaign(AFMTJ_PARAMS, grid, cache_dir=str(tmp_path),
                       max_cells_per_launch=per, reduce="stream")
    assert res.n_resumed == 2 and not res.from_cache
    np.testing.assert_array_equal(res.wer_surface(),
                                  dense_result.wer_surface())


def test_wer_threshold_steps_reproduces_f64_compare():
    """The streamed threshold k is the *smallest* integer step whose f64
    time strictly exceeds the pulse — the exact dense comparison."""
    dt = 0.1e-12
    pulses = (100e-12, 123.4e-12, 250e-12, 399.9e-12)
    n_steps = 4001
    ks = _wer_threshold_steps(pulses, dt, n_steps)
    for k, pl in zip(ks, pulses):
        assert np.float64(k) * dt > pl
        assert np.float64(k - 1) * dt <= pl


def test_percentiles_from_hist_matches_numpy():
    """Per-step bins determine the sorted sample multiset, so the sketch
    percentile must equal np.percentile of the reconstructed samples."""
    rng = np.random.default_rng(0)
    n_steps = 50
    steps = rng.integers(0, n_steps, size=400)
    hist = np.bincount(steps, minlength=n_steps)[None, :]
    values = _hist_step_values(n_steps, n_steps) * 1e-12
    qs = (5.0, 50.0, 95.0)
    got = _percentiles_from_hist(hist, values, qs)[0]
    want = np.percentile(steps.astype(np.float64) * 1e-12, qs)
    np.testing.assert_array_equal(got, want)


def test_percentiles_from_hist_all_unswitched_is_nan():
    hist = np.zeros((2, 3, 8), dtype=np.int64)
    out = _percentiles_from_hist(hist, np.arange(8.0), (50.0,))
    assert np.isnan(out).all() and out.shape == (2, 3, 1)


# ------------------------------------------------------------- donation
def test_donation_deterministic_and_statistically_identical(dense_result):
    """Donated launches are deterministic run-to-run; the alias-constrained
    executable may round rare lanes' crossings one step differently than
    the default compile, so the pin is repeatability + a tight statistical
    envelope, not bit equality (see engine._integrate_donated)."""
    grid = _grid()
    d1 = run_campaign(AFMTJ_PARAMS, grid, use_cache=False, donate=True)
    d2 = run_campaign(AFMTJ_PARAMS, grid, use_cache=False, donate=True)
    np.testing.assert_array_equal(d1.crossing_time, d2.crossing_time)
    steps_don = np.round(d1.crossing_time / grid.dt)
    steps_ref = np.round(dense_result.crossing_time / grid.dt)
    diff = np.abs(steps_don - steps_ref)
    assert diff.max() <= 1.0, diff.max()
    assert (diff > 0).mean() < 0.02, (diff > 0).mean()


def test_donated_jit_consumes_input():
    """donate_argnums really donates: the state block is deleted after the
    launch (that's the memory win), and the donated jit is a distinct
    object so compile-count pins on the default path stay untouched."""
    import jax.numpy as jnp
    from repro.campaign.engine import (EARLY_EXIT_CHUNK, _integrate_donated,
                                       _integrate_sharded, _quantize_steps)
    from repro.campaign.grid import pack_campaign

    assert _integrate_donated is not _integrate_sharded
    grid = _grid(temperatures=(300.0,), n_samples=8)
    state, seeds, sigma, budget, _ = pack_campaign(grid, AFMTJ_PARAMS)
    state = jnp.array(state)                 # private copy to sacrifice
    out = _integrate_donated(
        state, seeds, sigma, budget, None, p=AFMTJ_PARAMS, dt=grid.dt,
        n_steps=_quantize_steps(grid.n_steps),
        switch_threshold=float(grid.switch_threshold), backend="ref",
        n_dev=1, chunk=EARLY_EXIT_CHUNK)
    out.block_until_ready()
    assert state.is_deleted()


def test_donation_retry_repacks_consumed_inputs(monkeypatch):
    """A retry after the donated block was consumed must re-pack instead
    of dereferencing a deleted buffer."""
    from repro.campaign import engine

    grid = _grid(temperatures=(300.0,), n_samples=8)
    real = engine._integrate_donated
    calls = {"n": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        out = real(*a, **kw)
        if calls["n"] == 1:
            # the donated input is already consumed; now fail the launch
            out.block_until_ready()
            raise RuntimeError("transient loss after donation")
        return out

    monkeypatch.setattr(engine, "_integrate_donated", flaky)
    res = engine.run_campaign(AFMTJ_PARAMS, grid, use_cache=False,
                              donate=True, max_retries=1,
                              retry_backoff_s=0.0)
    assert calls["n"] == 2
    clean = engine.run_campaign(AFMTJ_PARAMS, grid, use_cache=False,
                                donate=True)
    np.testing.assert_array_equal(res.crossing_time, clean.crossing_time)


def test_write_verify_donate_smoke():
    """The write-verify scheduler accepts the donation knob end to end and
    still writes reliably (statistical check only — donation is not under
    the bit pins)."""
    import dataclasses as _dc

    from repro.imc.write_path import WritePolicy, write_verify
    pol = WritePolicy(v_write=1.0, pulse=130e-12, max_attempts=3, seed=5,
                      use_cache=False, donate=True)
    res = write_verify("afmtj", 96, pol)
    ref = write_verify("afmtj", 96, _dc.replace(pol, donate=False))
    assert abs(res.success.mean() - ref.success.mean()) <= 0.05
    assert abs(res.attempts_mean - ref.attempts_mean) <= 0.25
    assert res.rounds == ref.rounds


# ------------------------------------------------- device planning (pad)
def test_plan_cell_tiles_units():
    assert plan_cell_tiles(4, 1) == (4, 4)
    assert plan_cell_tiles(4, 3) == (2, 6)     # pad 2 tiles, keep 3 devices
    assert plan_cell_tiles(4, 5) == (1, 5)
    assert plan_cell_tiles(4, 6) == (1, 6)
    assert plan_cell_tiles(8, 8) == (1, 8)
    assert plan_cell_tiles(1, 4) == (1, 4)
    with pytest.raises(AssertionError):
        plan_cell_tiles(0, 4)


@pytest.mark.parametrize("n_dev", [3, 5, 6])
def test_uneven_device_counts_pad_not_demote(n_dev, tmp_path):
    """Regression (pre-PR-10 ``_usable_devices``): a 2048-cell span on a
    3/5/6-device mesh must keep ALL devices (padding the lane plane) and
    produce crossing rows bit-identical to the single-device launch."""
    child = textwrap.dedent("""
        import sys
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.campaign.engine import _device_plan, run_ensemble
        from repro.core import llg
        from repro.core.params import AFMTJ_PARAMS

        n_dev = int(sys.argv[2])
        assert jax.device_count() == n_dev, jax.devices()
        got_n, plan_cols = _device_plan(2048, None)
        assert got_n == n_dev, (got_n, n_dev)       # padded, NOT demoted
        assert plan_cols % (512 * n_dev) == 0 and plan_cols >= 2048

        m0 = jax.vmap(lambda t: llg.initial_state(AFMTJ_PARAMS, t, 0.2))(
            jnp.linspace(0.05, 0.15, 2048))
        res = run_ensemble(AFMTJ_PARAMS, m0, jnp.full((2048,), 1.0),
                           0.1e-12, 200, seed=3, backend="ref")
        np.save(sys.argv[1], res.crossing_steps)
    """)
    out = tmp_path / f"steps{n_dev}.npy"
    r = subprocess.run([sys.executable, "-c", child, str(out), str(n_dev)],
                       env=_forced_env(n_dev), capture_output=True,
                       text=True, timeout=560)
    assert r.returncode == 0, r.stderr

    import jax
    import jax.numpy as jnp
    from repro.campaign.engine import run_ensemble
    from repro.core import llg
    m0 = jax.vmap(lambda t: llg.initial_state(AFMTJ_PARAMS, t, 0.2))(
        jnp.linspace(0.05, 0.15, 2048))
    ref = run_ensemble(AFMTJ_PARAMS, m0, jnp.full((2048,), 1.0),
                       0.1e-12, 200, seed=3, backend="ref")
    np.testing.assert_array_equal(np.load(out), ref.crossing_steps)


# ------------------------------------------------------ lockless claims
def test_claim_protocol(tmp_path):
    from repro.campaign import cache
    d = str(tmp_path)
    assert cache.try_claim("k1", d, owner="a")
    assert not cache.try_claim("k1", d, owner="b")   # exclusive
    age = cache.claim_age_s("k1", d)
    assert age is not None and age >= 0.0
    assert cache.claim_age_s("nope", d) is None
    # fresh claims are not stealable; stale ones are
    assert not cache.steal_claim("k1", ttl_s=60.0, cache_dir=d, owner="b")
    old = time.time() - 120.0
    os.utime(cache.claim_path("k1", d), (old, old))
    assert cache.steal_claim("k1", ttl_s=60.0, cache_dir=d, owner="b")
    assert cache.release_claim("k1", d)
    assert not cache.release_claim("k1", d)          # second unlink no-ops
    # gc sweeps only stale droppings
    cache.try_claim("k2", d)
    assert cache.gc_stale_claims(d, max_age_s=3600.0) == 0
    assert cache.gc_stale_claims(d, max_age_s=0.0) == 1
    assert cache.claim_age_s("k2", d) is None


def test_multiprocess_mesh_lone_process_completes(tmp_path):
    """A process_count=2 mesh with no peer must still finish: pass B claims
    and integrates everything the absent peer never started."""
    grid = _grid(seed=21)
    per = bucket_cells(grid.cells)
    fresh = run_campaign(AFMTJ_PARAMS, grid, backend="ref", use_cache=False,
                         max_cells_per_launch=per)
    mesh = CampaignMesh(n_devices=1, process_index=0, process_count=2,
                        claim_ttl_s=5.0, poll_s=0.01)
    res = run_campaign(AFMTJ_PARAMS, grid, backend="ref",
                       cache_dir=str(tmp_path), max_cells_per_launch=per,
                       mesh=mesh)
    assert res.n_computed == res.n_launches == 3
    np.testing.assert_array_equal(res.crossing_time, fresh.crossing_time)
    assert not list(tmp_path.glob("*.claim"))        # all claims retired
    # a late-arriving peer adopts the whole-campaign entry
    late = run_campaign(AFMTJ_PARAMS, grid, backend="ref",
                        cache_dir=str(tmp_path), max_cells_per_launch=per,
                        mesh=CampaignMesh(n_devices=1, process_index=1,
                                          process_count=2))
    assert late.from_cache and late.n_computed == 0
    np.testing.assert_array_equal(late.crossing_time, fresh.crossing_time)


def test_multiprocess_mesh_requires_cache():
    mesh = CampaignMesh(n_devices=1, process_index=0, process_count=2)
    with pytest.raises(AssertionError, match="store"):
        run_campaign(AFMTJ_PARAMS, _grid(), use_cache=False, mesh=mesh)


def test_multiprocess_dedupe_two_processes(tmp_path):
    """Acceptance pin: two concurrent processes sharing one cache dir split
    a 3-launch campaign without integrating any launch twice, and both
    assemble the crossing tensor bit-identically to a lone run.

    A file barrier releases both children together (after their jax
    imports), so the claim protocol is exercised under real concurrency.
    """
    grid = _grid(seed=33)
    per = bucket_cells(grid.cells)
    fresh = run_campaign(AFMTJ_PARAMS, grid, backend="ref", use_cache=False,
                         max_cells_per_launch=per)

    child = textwrap.dedent("""
        import hashlib, json, os, sys, time
        import numpy as np
        from repro.campaign import CampaignGrid, run_campaign
        from repro.campaign.grid import bucket_cells
        from repro.core.params import AFMTJ_PARAMS
        from repro.launch.mesh import CampaignMesh

        root, pi = sys.argv[1], int(sys.argv[2])
        grid = CampaignGrid(voltages=(0.6, 1.2),
                            pulse_widths=(120e-12, 250e-12),
                            temperatures=(300.0, 350.0, 400.0),
                            n_samples=16, dt=0.1e-12, seed=33)
        open(os.path.join(root, f"ready{pi}"), "w").close()
        while not os.path.exists(os.path.join(root, "go")):
            time.sleep(0.005)
        mesh = CampaignMesh(n_devices=1, process_index=pi, process_count=2,
                            claim_ttl_s=120.0, poll_s=0.01)
        res = run_campaign(AFMTJ_PARAMS, grid, backend="ref",
                           cache_dir=os.path.join(root, "cache"),
                           max_cells_per_launch=bucket_cells(grid.cells),
                           mesh=mesh)
        ct = (res.crossing_time if res.crossing_time is not None else None)
        json.dump({"n_computed": res.n_computed,
                   "n_launches": res.n_launches,
                   "sha": hashlib.sha256(ct.tobytes()).hexdigest()},
                  open(os.path.join(root, f"out{pi}.json"), "w"))
    """)
    procs = [subprocess.Popen(
        [sys.executable, "-c", child, str(tmp_path), str(i)],
        env=_ENV, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for i in range(2)]
    deadline = time.time() + 300
    while not all((tmp_path / f"ready{i}").exists() for i in range(2)):
        assert time.time() < deadline, "children never became ready"
        for pr in procs:
            assert pr.poll() is None, pr.communicate()[1]
        time.sleep(0.01)
    (tmp_path / "go").touch()
    errs = [pr.communicate(timeout=560)[1] for pr in procs]
    assert all(pr.returncode == 0 for pr in procs), errs

    outs = [json.load(open(tmp_path / f"out{i}.json")) for i in range(2)]
    sha = __import__("hashlib").sha256(
        fresh.crossing_time.tobytes()).hexdigest()
    assert all(o["sha"] == sha for o in outs), outs
    assert all(o["n_launches"] == 3 for o in outs)
    total = sum(o["n_computed"] for o in outs)
    assert total == 3, outs                  # every launch integrated once


# ------------------------------------------------- elastic resume (N->M)
def test_elastic_kill_at_4_resume_at_2_devices(tmp_path):
    """Acceptance pin: a campaign SIGKILLed on a 4-device mesh resumes on
    2 devices from the same slice checkpoints (keys are device-count-free)
    and assembles bit-identically to a single-device run."""
    grid = _grid(seed=44)
    per = bucket_cells(grid.cells)
    killer = textwrap.dedent("""
        import os, signal, sys
        import jax
        from repro.campaign import CampaignGrid, run_campaign
        from repro.campaign.grid import bucket_cells
        from repro.core.params import AFMTJ_PARAMS

        assert jax.device_count() == 4, jax.devices()
        grid = CampaignGrid(voltages=(0.6, 1.2),
                            pulse_widths=(120e-12, 250e-12),
                            temperatures=(300.0, 350.0, 400.0),
                            n_samples=16, dt=0.1e-12, seed=44)

        def die(i, n):
            if i == 0:
                os.kill(os.getpid(), signal.SIGKILL)

        run_campaign(AFMTJ_PARAMS, grid, backend="ref", cache_dir=sys.argv[1],
                     max_cells_per_launch=bucket_cells(grid.cells),
                     on_slice_complete=die)
    """)
    r = subprocess.run([sys.executable, "-c", killer, str(tmp_path)],
                       env=_forced_env(4), capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == -signal.SIGKILL, r.stderr
    assert list(tmp_path.glob("*.npz")), "no slice checkpoint survived"

    resumer = textwrap.dedent("""
        import sys
        import numpy as np
        import jax
        from repro.campaign import CampaignGrid, run_campaign
        from repro.campaign.grid import bucket_cells
        from repro.core.params import AFMTJ_PARAMS
        from repro.launch.mesh import build_campaign_mesh

        assert jax.device_count() == 2, jax.devices()
        mesh = build_campaign_mesh(elastic_from=4)
        assert mesh.n_devices == 2
        grid = CampaignGrid(voltages=(0.6, 1.2),
                            pulse_widths=(120e-12, 250e-12),
                            temperatures=(300.0, 350.0, 400.0),
                            n_samples=16, dt=0.1e-12, seed=44)
        res = run_campaign(AFMTJ_PARAMS, grid, backend="ref",
                           cache_dir=sys.argv[1],
                           max_cells_per_launch=bucket_cells(grid.cells),
                           mesh=mesh)
        assert res.n_resumed == 1, res.n_resumed
        np.save(sys.argv[2], res.crossing_time)
    """)
    out = tmp_path / "resumed.npy"
    r = subprocess.run(
        [sys.executable, "-c", resumer, str(tmp_path), str(out)],
        env=_forced_env(2), capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr

    fresh = run_campaign(AFMTJ_PARAMS, grid, backend="ref", use_cache=False,
                         max_cells_per_launch=per)
    np.testing.assert_array_equal(np.load(out), fresh.crossing_time)


def test_plan_campaign_devices_ladder():
    from repro.runtime.elastic import plan_campaign_devices
    full = plan_campaign_devices(8, 8)
    assert full.mesh_shape == (8,) and full.microbatch_scale == 1
    more = plan_campaign_devices(12, 8)          # extra devices: keep plan
    assert more.mesh_shape == (8,)
    degraded = plan_campaign_devices(3, 8)       # halving ladder: 8->2
    assert degraded.mesh_shape == (2,) and degraded.microbatch_scale == 4
    floor = plan_campaign_devices(0, 4)
    assert floor.mesh_shape == (1,) and floor.microbatch_scale == 4
    assert all(p.axis_names == ("cells",)
               for p in (full, more, degraded, floor))

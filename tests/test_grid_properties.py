"""Property-based + pinned invariants of the campaign packing layer
(`campaign.grid`): the quantizer ladders behind compile-cache reuse, the
SoA pack/unpack round-trip, corner-major variation layout, and the
common-random-numbers contract that keeps grown campaigns bit-comparable
to their smaller ancestors.

Property tests use hypothesis when installed (requirements-dev.txt) and
skip through ``_hypothesis_stub`` otherwise; every property also has an
executed pinned companion below it, so the invariants stay enforced in
the stock environment.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # property tests skip; pinned companions still run
    from _hypothesis_stub import given, settings, st

from repro.campaign.grid import (CampaignGrid, bucket_cells,
                                 log_horizon_bucket, log_pulses, next_pow2,
                                 pack_campaign, pack_soa, pack_variation)
from repro.core.params import (AFMTJ_PARAMS, CORNER_SS, CORNER_TT,
                               VariationSpec)
from repro.kernels.llg_rk4 import CELL_TILE


# ----------------------------------------------------- quantizer ladders
@settings(max_examples=200, deadline=None)
@given(n=st.integers(min_value=1, max_value=1 << 20))
def test_next_pow2_minimal_cover(n):
    q = next_pow2(n)
    assert q >= n and q & (q - 1) == 0
    assert q == 1 or q // 2 < n                  # minimal such power


@settings(max_examples=200, deadline=None)
@given(c=st.integers(min_value=1, max_value=1 << 16))
def test_bucket_cells_properties(c):
    b = bucket_cells(c)
    assert b >= c and b % CELL_TILE == 0
    m = b // CELL_TILE
    assert m & (m - 1) == 0                      # pow2 multiple of the tile
    assert bucket_cells(b) == b                  # idempotent (fixed point)


@settings(max_examples=200, deadline=None)
@given(n=st.integers(min_value=1, max_value=10**7),
       per_decade=st.integers(min_value=1, max_value=4))
def test_log_horizon_bucket_properties(n, per_decade):
    r = log_horizon_bucket(n, per_decade)
    assert r >= n
    assert log_horizon_bucket(r, per_decade) == r      # rungs are fixed points
    if n > 1:                                          # minimal rung
        assert log_horizon_bucket(n - 1, per_decade) <= r


def test_quantizers_monotone_pinned():
    """Executed companion: monotonicity of both ladders over a dense range
    (a non-monotone quantizer would thrash the engine's compile cache)."""
    ns = np.arange(1, 5000)
    for fn in (next_pow2, bucket_cells, log_horizon_bucket):
        vals = [fn(int(n)) for n in ns]
        assert all(b >= a for a, b in zip(vals, vals[1:])), fn.__name__


def test_log_horizon_bucket_pinned_rungs():
    """The default ladder (2 rungs/decade): 1, 3, 10, 32, 100, 316, ..."""
    assert [log_horizon_bucket(n) for n in (1, 2, 3, 4, 11, 317, 3163)] == \
        [1, 3, 3, 10, 32, 1000, 10000]
    # ~2 compiles per decade vs ~3.3 for pow2 across a retention window
    rungs = {log_horizon_bucket(n) for n in range(1, 10**5)}
    assert len(rungs) == 11


def test_log_pulses_pinned():
    ps = log_pulses(1e-10, 1e-8, per_decade=3)
    assert ps[0] == 1e-10 and abs(ps[-1] - 1e-8) < 1e-22
    assert len(ps) == 7
    assert all(b > a for a, b in zip(ps, ps[1:]))
    r = np.diff(np.log(np.asarray(ps)))
    np.testing.assert_allclose(r, r[0], rtol=1e-9)     # geometric spacing


# ------------------------------------------------- SoA pack round-trip
def _states(cells, n_sub, seed=0):
    rng = np.random.default_rng(seed)
    m = rng.normal(size=(cells, n_sub, 3))
    m /= np.linalg.norm(m, axis=-1, keepdims=True)
    return jnp.asarray(m, jnp.float32)


@pytest.mark.parametrize("n_sub", [1, 2])
@pytest.mark.parametrize("cells", [1, CELL_TILE, CELL_TILE + 1, 300])
def test_pack_soa_round_trip(n_sub, cells):
    """Rows 0-2 hold m1, rows 3-5 m2 (zeros for single-sublattice), row 6
    the drive, row 7 the crossing accumulator (zero); bucket-pad columns
    are all-zero so padded lanes carry no physics."""
    m0 = _states(cells, n_sub)
    v = jnp.asarray(np.linspace(0.1, 1.0, cells), jnp.float32)
    soa = pack_soa(m0, v)
    assert soa.shape == (8, bucket_cells(cells))
    assert soa.dtype == jnp.float32
    got = np.asarray(soa)
    np.testing.assert_array_equal(got[0:3, :cells], np.asarray(m0[:, 0]).T)
    if n_sub == 2:
        np.testing.assert_array_equal(got[3:6, :cells],
                                      np.asarray(m0[:, 1]).T)
    else:
        assert (got[3:6] == 0.0).all()
    np.testing.assert_array_equal(got[6, :cells], np.asarray(v))
    assert (got[7] == 0.0).all()
    assert (got[:, cells:] == 0.0).all()


# ------------------------------------------- variation packing layout
SIGMA0_SPEC = VariationSpec(corners=(CORNER_TT, CORNER_SS))


def _grid(**kw):
    base = dict(voltages=(0.6, 1.0), pulse_widths=(0.5e-9,),
                temperatures=(300.0,), n_samples=8, dt=0.1e-12, seed=3)
    base.update(kw)
    return CampaignGrid(**base)


def test_pack_variation_corner_major_layout():
    """spans[ci*n_T+ti] must walk corners outer, temperatures inner, and
    the lane-parameter rows must carry exactly the corner factors when the
    D2D sigmas are zero (the default corners)."""
    g = _grid(temperatures=(300.0, 400.0), variation=SIGMA0_SPEC)
    p = AFMTJ_PARAMS
    state, seeds, sigma, budget, lanes, spans = pack_variation(g, p)
    n_t = 2
    assert len(spans) == SIGMA0_SPEC.n_corners * n_t
    starts = [s for s, _ in spans]
    assert starts == sorted(starts)              # corner-major, contiguous
    assert all(e - s == g.cells for s, e in spans)
    lanes = np.asarray(lanes)
    for ci, corner in enumerate(SIGMA0_SPEC.corners):
        for ti in range(n_t):
            s, e = spans[ci * n_t + ti]
            np.testing.assert_allclose(
                lanes[0, s:e], np.float32(p.alpha * corner.alpha_factor),
                rtol=1e-6)
            np.testing.assert_allclose(
                lanes[1, s:e], np.float32(p.b_aniso * corner.b_aniso_factor),
                rtol=1e-6)
            np.testing.assert_allclose(
                lanes[2, s:e], np.float32(1.0 / corner.r_factor), rtol=1e-6)
    # budget: n_steps on real lanes, 0 on bucket padding
    budget = np.asarray(budget)
    for s, e in spans:
        assert (budget[s:e] == float(g.n_steps)).all()
    pad_mask = np.ones(budget.shape[0], bool)
    for s, e in spans:
        pad_mask[s:e] = False
    assert (budget[pad_mask] == 0.0).all()
    assert (np.asarray(sigma)[pad_mask] == 0.0).all()
    # pad lanes carry nominal physics rows, never garbage
    assert (lanes[0, pad_mask] == np.float32(p.alpha)).all()
    assert (lanes[2, pad_mask] == 1.0).all()


# ----------------------------------------------- CRN growth invariance
def test_crn_adding_temperature_keeps_slice_bit_identical():
    """Growing the fused temperature axis must not move the existing
    slice: T=(300,) packing == the T=300 block of T=(300,400) packing."""
    p = AFMTJ_PARAMS
    s1, k1, g1, b1, spans1 = pack_campaign(_grid(), p)
    s2, k2, g2, b2, spans2 = pack_campaign(
        _grid(temperatures=(300.0, 400.0)), p)
    (a0, a1), (b0, b1_) = spans1[0], spans2[0]
    assert (a0, a1) == (b0, b1_)
    np.testing.assert_array_equal(np.asarray(s1)[:, a0:a1],
                                  np.asarray(s2)[:, a0:a1])
    np.testing.assert_array_equal(np.asarray(k1)[a0:a1],
                                  np.asarray(k2)[a0:a1])
    np.testing.assert_array_equal(np.asarray(g1)[a0:a1],
                                  np.asarray(g2)[a0:a1])


def test_crn_adding_corner_keeps_first_corner_bit_identical():
    """Corner draws are salted by stream, never corner position: adding a
    corner to the spec leaves the first corner's packed block untouched
    (paired-lane corner comparisons depend on this)."""
    p = AFMTJ_PARAMS
    one = _grid(variation=VariationSpec(corners=(CORNER_TT,)))
    two = _grid(variation=SIGMA0_SPEC)
    s1, k1, g1, b1, l1, spans1 = pack_variation(one, p)
    s2, k2, g2, b2, l2, spans2 = pack_variation(two, p)
    a0, a1 = spans1[0]
    assert spans2[0] == (a0, a1)
    np.testing.assert_array_equal(np.asarray(s1)[:, a0:a1],
                                  np.asarray(s2)[:, a0:a1])
    np.testing.assert_array_equal(np.asarray(k1)[a0:a1],
                                  np.asarray(k2)[a0:a1])
    np.testing.assert_array_equal(np.asarray(l1)[:, a0:a1],
                                  np.asarray(l2)[:, a0:a1])


def test_crn_longer_pulse_changes_only_budget():
    """The pulse axis is post-processing: widening the horizon ladder must
    leave states, seeds and sigma bit-identical — only the per-lane step
    budget (and the compiled horizon it implies) grows.  This is what
    makes the retention ladder free to extend."""
    p = AFMTJ_PARAMS
    s1, k1, g1, b1, _ = pack_campaign(_grid(pulse_widths=(0.5e-9,)), p)
    s2, k2, g2, b2, _ = pack_campaign(
        _grid(pulse_widths=(0.5e-9, 2.0e-9)), p)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    assert np.asarray(b2).max() > np.asarray(b1).max()


def test_crn_seed_isolation_across_temperature_slices():
    """Distinct temperature slices must never share thermal streams (the
    fused plane would otherwise correlate T=300 and T=400 lanes)."""
    g = _grid(temperatures=(300.0, 400.0))
    _, seeds, _, _, spans = pack_campaign(g, AFMTJ_PARAMS)
    seeds = np.asarray(seeds)
    (s0, e0), (s1, e1) = spans
    assert not np.intersect1d(seeds[s0:e0], seeds[s1:e1]).size


def test_grid_pulse_axis_sorted_voltages_preserved():
    g = CampaignGrid(voltages=(1.0, 0.6), pulse_widths=(2e-9, 1e-9),
                     n_samples=4)
    assert g.pulse_widths == (1e-9, 2e-9)        # normalized ascending
    assert g.voltages == (1.0, 0.6)              # order is caller's axis
    assert g.n_steps == int(np.ceil(2e-9 / g.dt)) + 1
    assert g.cells == 8

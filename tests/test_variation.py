"""Per-lane device-variation plane tests (DESIGN.md §9).

The variation refactor has three contracts worth pinning hard:

* **kernel = oracle** — the Pallas kernel consuming per-lane alpha / B_k /
  g_scale rows must track the jnp oracle (which routes the same rows
  through the *production* ``llg.llg_rhs``) at a fixed thermal seed,
  across shapes and chunking modes;
* **corner axis is data** — a multi-corner campaign is one launch / one
  compile, per-corner crossing rows are bit-identical to separate
  single-corner launches (shared thermal streams: common random numbers),
  and changing corner values / D2D sigmas / corner count (within a total
  shape bucket) never recompiles;
* **consumers agree** — the scalar ``simulate_write`` baseline, the
  write-verify scheduler, the analog programmer and the margin solver all
  derive their corner semantics from the same ``VariationSpec``, with
  exact nominal-corner parity where the math allows it.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.campaign import (CampaignGrid, bucket_cells, pack_variation,
                            run_campaign, run_ensemble)
from repro.campaign import cache as _cache
from repro.campaign.engine import _integrate_sharded
from repro.core import llg
from repro.core.params import (AFMTJ_PARAMS, CORNER_FF, CORNER_SS, CORNER_TT,
                               MTJ_PARAMS, PROCESS_CORNERS, VariationSpec)
from repro.kernels import noise, ops, ref

SPEC3 = VariationSpec(corners=(
    CORNER_FF, CORNER_TT,
    dataclasses.replace(CORNER_SS, sigma_alpha=0.05, sigma_r=0.08)))


@pytest.fixture(scope="module")
def var_grid():
    # low-V lanes mostly never cross, high-V lanes do; three corners with
    # D2D spread on the slow one — exercises every surface reduction path
    return CampaignGrid(voltages=(0.8, 1.2), pulse_widths=(120e-12, 250e-12),
                        temperatures=(280.0, 320.0), n_samples=16, seed=0,
                        variation=SPEC3)


@pytest.fixture(scope="module")
def var_result(var_grid):
    return run_campaign(AFMTJ_PARAMS, var_grid, use_cache=False)


# ----------------------------------------------------------- spec semantics
def test_spec_hashable_and_cache_serializable():
    assert hash(SPEC3) != hash(VariationSpec())
    payload = dataclasses.asdict(SPEC3)
    json.dumps(payload)                       # cache key payload round-trips
    assert SPEC3.corner_names == ("ff", "tt", "ss")
    assert VariationSpec().is_nominal and not SPEC3.is_nominal
    assert set(PROCESS_CORNERS) == {"tt", "ss", "ff"}


def test_lane_factors_reproducible_and_corner_paired():
    c = dataclasses.replace(CORNER_SS, sigma_alpha=0.1, sigma_r=0.1)
    a = SPEC3.lane_factors(c, 256, stream=1)
    b = SPEC3.lane_factors(c, 256, stream=1)
    np.testing.assert_array_equal(a, b)       # pure function of the spec
    assert not np.array_equal(a, SPEC3.lane_factors(c, 256, stream=2))
    assert not np.array_equal(
        a, dataclasses.replace(SPEC3, seed=1).lane_factors(c, 256, stream=1))
    # common random numbers: corners share z draws — at sigma=0 factors are
    # exactly the corner centers, and two corners' draws are paired
    f_tt = SPEC3.lane_factors(CORNER_TT, 64)
    np.testing.assert_array_equal(f_tt, np.ones((4, 64)))
    f_ss = SPEC3.lane_factors(CORNER_SS, 64)
    np.testing.assert_allclose(f_ss[0], 1.15)   # sigma 0 -> center exactly


def test_lane_rows_physics():
    rows = SPEC3.lane_rows(AFMTJ_PARAMS, CORNER_SS, 32, dt=0.1e-12)
    nom = SPEC3.lane_rows(AFMTJ_PARAMS, CORNER_TT, 32, dt=0.1e-12)
    assert (rows.alpha > nom.alpha).all()       # more damping
    assert (rows.g_scale < nom.g_scale).all()   # higher RA -> less drive
    assert (rows.sigma > nom.sigma).all()       # alpha up + volume down
    assert (rows.theta0 < nom.theta0).all()     # taller barrier -> tighter
    np.testing.assert_array_equal(nom.g_scale, 1.0)
    assert rows.kernel_rows.shape == (3, 32)
    assert rows.kernel_rows.dtype == np.float32


# ------------------------------------------------- kernel-vs-oracle parity
def _packed(cells, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    th = jax.random.uniform(k1, (cells,), minval=0.05, maxval=0.25)
    ph = jax.random.uniform(k2, (cells,), minval=0.0, maxval=6.28)
    m0 = jax.vmap(lambda t, f: llg.initial_state(AFMTJ_PARAMS, t, f))(th, ph)
    return ops.pack_states(m0, jnp.linspace(0.8, 1.3, cells))


@pytest.mark.parametrize("cells,chunk", [(512, 0), (512, 32), (1024, 64)])
def test_variation_kernel_matches_ref(cells, chunk):
    """Per-lane parameter rows: the Pallas kernel and the jnp oracle consume
    identical (alpha, B_k, g_scale) rows and identical thermal streams —
    magnetization rows allclose, crossing row bit-equal, across shapes and
    early-exit modes at a fixed seed."""
    dt, n_steps = 0.1e-12, 160
    state = _packed(cells, seed=cells + chunk)
    seeds = noise.cell_seeds(11, cells)
    rng = np.random.default_rng(5)
    lp = jnp.asarray(np.stack([
        AFMTJ_PARAMS.alpha * rng.uniform(0.8, 1.2, cells),
        AFMTJ_PARAMS.b_aniso * rng.uniform(0.9, 1.1, cells),
        rng.uniform(0.8, 1.2, cells)]).astype(np.float32))
    sigma = jnp.full((cells,), 0.02, jnp.float32)
    out_k = ops.llg_rk4_thermal(state, seeds, AFMTJ_PARAMS, dt, n_steps,
                                sigma, chunk=chunk, lane_params=lp)
    out_r = ref.ref_llg_rk4(state, AFMTJ_PARAMS, dt, n_steps,
                            thermal_sigma=sigma, seeds=seeds, chunk=chunk,
                            lane_params=lp)
    np.testing.assert_allclose(np.asarray(out_k[:6]), np.asarray(out_r[:6]),
                               atol=2e-5)
    np.testing.assert_array_equal(np.asarray(out_k[7]), np.asarray(out_r[7]))
    # nominal rows reproduce the scalar-closure kernel to float tolerance
    # (different rounding of 1 + alpha^2, so allclose — not bitwise)
    lp0 = jnp.asarray(np.stack([
        np.full(cells, AFMTJ_PARAMS.alpha),
        np.full(cells, AFMTJ_PARAMS.b_aniso),
        np.ones(cells)]).astype(np.float32))
    out_v = ops.llg_rk4_thermal(state, seeds, AFMTJ_PARAMS, dt, n_steps,
                                sigma, chunk=chunk, lane_params=lp0)
    out_s = ops.llg_rk4_thermal(state, seeds, AFMTJ_PARAMS, dt, n_steps,
                                sigma, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out_v[:6]), np.asarray(out_s[:6]),
                               atol=2e-5)
    # and the varied rows actually change the dynamics
    assert not np.allclose(np.asarray(out_k[:6]), np.asarray(out_v[:6]))


# ------------------------------------------- fused corner axis bit-compat
def test_pack_variation_layout(var_grid):
    state, seeds, sigma, budget, lane_params, spans = pack_variation(
        var_grid, AFMTJ_PARAMS)
    n_c, n_t = var_grid.n_corners, len(var_grid.temperatures)
    per = state.shape[1] // (n_c * n_t)
    assert per == bucket_cells(var_grid.cells)
    assert lane_params.shape == (3, state.shape[1])
    assert spans == [(si * per, si * per + var_grid.cells)
                     for si in range(n_c * n_t)]
    seeds = np.asarray(seeds)
    for ci in range(n_c):
        for ti in range(n_t):
            lo = (ci * n_t + ti) * per
            # thermal streams are shared across corners (common random
            # numbers) and distinct across temperature slices
            np.testing.assert_array_equal(seeds[lo:lo + per],
                                          seeds[ti * per:(ti + 1) * per])
    bud = np.asarray(budget)
    assert (bud[:var_grid.cells] == var_grid.n_steps).all()
    assert (bud[var_grid.cells:per] == 0.0).all()
    # the slow corner's lanes carry a hotter Brown sigma than nominal
    sig = np.asarray(sigma)
    assert sig[2 * n_t * per] > sig[1 * n_t * per]


def test_fused_corners_bit_identical_to_single_corner_launches(var_grid,
                                                               var_result):
    """The acceptance pin: each corner's crossing rows from the fused
    (corner x T x V x S) launch equal a separate single-corner campaign at
    the same lane seeds, bit-for-bit — corners share tilt draws and
    thermal streams, so fusing the axis changes nothing but the launch
    count."""
    assert var_result.crossing_time.shape == (3, 2, 2, 16)
    assert var_result.n_launches == 1
    for ci in range(var_grid.n_corners):
        single = run_campaign(
            AFMTJ_PARAMS,
            dataclasses.replace(var_grid,
                                variation=var_grid.variation.at_corner(ci)),
            use_cache=False)
        np.testing.assert_array_equal(var_result.crossing_time[ci],
                                      single.crossing_time[0])
    # corners must actually differ (FF faster than SS at the same streams)
    lat = var_result.latency_percentiles((50.0,))
    ff, ss = lat[0, 0, 1, 0], lat[2, 0, 1, 0]
    assert np.isfinite(ff) and np.isfinite(ss) and ff < ss


def test_nominal_corner_statistically_matches_legacy_engine(var_grid):
    """An all-nominal variation campaign rides the per-lane parameter rows
    (different rounding path than the scalar closure -> chaotic divergence
    per lane), so parity with the legacy engine is statistical, not
    bitwise: WER within Monte-Carlo error, same qualitative surface."""
    nom_var = dataclasses.replace(var_grid, n_samples=64,
                                  variation=VariationSpec())
    legacy = dataclasses.replace(nom_var, variation=None)
    r_var = run_campaign(AFMTJ_PARAMS, nom_var, use_cache=False)
    r_leg = run_campaign(AFMTJ_PARAMS, legacy, use_cache=False)
    assert r_var.crossing_time.shape == (1,) + r_leg.crossing_time.shape
    w_var, w_leg = r_var.wer_surface()[0], r_leg.wer_surface()
    np.testing.assert_allclose(w_var, w_leg, atol=0.2)    # ~3 sigma @ n=64
    # 1.2 V long-pulse writes succeed, 0.8 V short-pulse writes fail, in both
    assert w_var[:, 1, 1].max() < 0.2 and w_leg[:, 1, 1].max() < 0.2
    assert w_var[:, 0, 0].min() > 0.8 and w_leg[:, 0, 0].min() > 0.8


# ------------------------------------------------------------ compile pins
def test_corner_count_and_values_do_not_enter_compile_key(var_grid):
    """One compile for a 3-corner campaign; new corner values, new D2D
    sigmas, new seeds reuse it; and a 4-corner campaign lands in the same
    total shape bucket -> still no recompile."""
    _integrate_sharded._clear_cache()
    res = run_campaign(AFMTJ_PARAMS, var_grid, use_cache=False)
    assert res.n_launches == 1
    assert _integrate_sharded._cache_size() == 1
    spec_b = VariationSpec(corners=(
        dataclasses.replace(CORNER_SS, alpha_factor=1.3, sigma_volume=0.1),
        CORNER_TT, CORNER_FF), seed=17)
    run_campaign(AFMTJ_PARAMS,
                 dataclasses.replace(var_grid, variation=spec_b, seed=3),
                 use_cache=False)
    assert _integrate_sharded._cache_size() == 1
    # 4 corners x 2 T x 512-lane slices = 4096 lanes — same pow2 total
    # bucket as 3 x 2 x 512 = 3072 -> 4096: corner count is data too
    spec_c = VariationSpec(corners=(CORNER_TT, CORNER_SS, CORNER_FF,
                                    dataclasses.replace(CORNER_SS, name="sf",
                                                        r_factor=1.3)))
    r4 = run_campaign(AFMTJ_PARAMS,
                      dataclasses.replace(var_grid, variation=spec_c),
                      use_cache=False)
    assert r4.crossing_time.shape[0] == 4
    assert _integrate_sharded._cache_size() == 1


# ------------------------------------------------------- cache v4 behavior
def test_cache_v4_migration_ignores_stale_entries(tmp_path, var_grid):
    grid = dataclasses.replace(var_grid, n_samples=8,
                               pulse_widths=(60e-12,),
                               temperatures=(300.0,))
    cache_dir = str(tmp_path)
    # a v3-keyed entry (old layout, no variation field) must never match
    v3_payload = {"v": 3, "layout": "fused-T/bucket-pow2",
                  "params": dataclasses.asdict(AFMTJ_PARAMS),
                  "grid": {"voltages": list(grid.voltages)},
                  "backend": "pallas"}
    import hashlib
    v3_key = hashlib.sha256(
        json.dumps(v3_payload, sort_keys=True, default=float).encode()
    ).hexdigest()[:32]
    _cache.store(v3_key, np.zeros((1, 2, 8)), header={}, cache_dir=cache_dir)
    v4_key = _cache.campaign_key(AFMTJ_PARAMS, grid, "pallas")
    assert v4_key != v3_key
    # a corrupt file AT the v4 key is a miss, not a crash
    (tmp_path / f"{v4_key}.npz").write_bytes(b"not an npz")
    assert _cache.load(v4_key, cache_dir) is None
    r1 = run_campaign(AFMTJ_PARAMS, grid, cache_dir=cache_dir)
    assert not r1.from_cache
    # the recomputed 4-D surface round-trips through the cache
    r2 = run_campaign(AFMTJ_PARAMS, grid, cache_dir=cache_dir)
    assert r2.from_cache
    np.testing.assert_array_equal(r1.crossing_time, r2.crossing_time)
    # a wrong-shape v4 entry (e.g. written before a grid edit) is ignored
    _cache.store(v4_key, np.zeros((2, 2, 2)), header={}, cache_dir=cache_dir)
    r3 = run_campaign(AFMTJ_PARAMS, grid, cache_dir=cache_dir)
    assert not r3.from_cache


# ---------------------------------------------------------- consumer layers
def test_run_ensemble_lane_params_drive_scale():
    """g_scale=0 removes the STT drive entirely: no lane may cross; at
    g_scale=1 (same seeds) the high-voltage lanes do."""
    n = 128
    m0 = jax.vmap(lambda t: llg.initial_state(AFMTJ_PARAMS, t, 0.2))(
        jnp.full((n,), 0.1))
    v = jnp.full((n,), 1.2)
    lp_on = np.stack([np.full(n, AFMTJ_PARAMS.alpha),
                      np.full(n, AFMTJ_PARAMS.b_aniso),
                      np.ones(n)]).astype(np.float32)
    lp_off = lp_on.copy()
    lp_off[2] = 0.0
    kw = dict(dt=0.1e-12, n_steps=1800, seed=4, chunk=64)
    r_on = run_ensemble(AFMTJ_PARAMS, m0, v, lane_params=lp_on, **kw)
    r_off = run_ensemble(AFMTJ_PARAMS, m0, v, lane_params=lp_off, **kw)
    assert r_on.switched.any()
    assert not r_off.switched.any()


def test_simulate_write_nominal_sample_parity():
    """variation=0 (the nominal-corner sample) is *exactly* the baseline:
    every factor is literally 1.0, so the scalar path and the engine agree
    on nominal-corner semantics bit-for-bit."""
    from repro.core.device import simulate_write

    s = VariationSpec().sample_device(AFMTJ_PARAMS)
    r0 = simulate_write(AFMTJ_PARAMS, 1.0, n_steps=3000, dt=0.1e-12)
    r1 = simulate_write(AFMTJ_PARAMS, 1.0, n_steps=3000, dt=0.1e-12,
                        variation=s)
    assert float(r0.t_switch) == float(r1.t_switch)
    assert float(r0.energy) == float(r1.energy)
    # the slow corner really is slower, for both device families
    for p, steps, dt in ((AFMTJ_PARAMS, 5000, 0.1e-12),):
        ss = VariationSpec(corners=(CORNER_SS,)).sample_device(p)
        r2 = simulate_write(p, 1.0, n_steps=steps, dt=dt, variation=ss)
        assert float(r2.t_switch) > float(r0.t_switch)


def test_write_verify_corner_retry_asymmetry():
    """Slow-corner devices fail the per-attempt pulse more often: the
    measured retry distribution orders FF < TT-ish < SS, with shared D2D /
    thermal streams making the comparison paired."""
    from repro.imc.write_path import WritePolicy, write_verify_corners

    pol = WritePolicy(v_write=1.0, pulse=130e-12, max_attempts=4, seed=3,
                      use_cache=False)
    out = write_verify_corners("afmtj", 192, pol,
                               VariationSpec(corners=(CORNER_FF, CORNER_SS)))
    assert set(out) == {"ff", "ss"}
    assert out["ss"].attempts_mean > out["ff"].attempts_mean
    assert out["ss"].energy_mean() > 0 and out["ff"].energy_mean() > 0
    assert out["ss"].rounds >= out["ff"].rounds >= 1


def test_write_verify_variation_rounds_stay_in_compile_budget():
    """Variation retry rounds ride the same shape-bucket + quantized-horizon
    compile economy as the nominal scheduler: a multi-round shrinking
    schedule compiles fewer graphs than it runs rounds."""
    from repro.imc.write_path import WritePolicy, write_verify

    _integrate_sharded._clear_cache()
    pol = WritePolicy(v_write=1.0, pulse=130e-12, max_attempts=3, seed=5,
                      use_cache=False,
                      variation=VariationSpec(corners=(CORNER_SS,)))
    r = write_verify("afmtj", 640, pol)
    assert r.rounds == 3
    assert _integrate_sharded._cache_size() <= 2 < r.rounds


def test_analog_g_sigma_deprecated_alias():
    """g_sigma warns and constructs the equivalent spec: bit-identical
    programmed conductances, warning-free when the spec is passed
    explicitly."""
    from repro.imc.analog_pipeline import AnalogConfig, program_weights

    w = jax.random.normal(jax.random.PRNGKey(0), (64, 48))
    with pytest.warns(DeprecationWarning, match="g_sigma is deprecated"):
        old = program_weights(w, "afmtj", AnalogConfig(g_sigma=0.05, seed=2))
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        new = program_weights(w, "afmtj", AnalogConfig(
            variation=VariationSpec.from_g_sigma(0.05, seed=2), seed=2))
    np.testing.assert_array_equal(np.asarray(old.g_diff),
                                  np.asarray(new.g_diff))
    # variation really perturbs programming vs the ideal target
    ideal = program_weights(w, "afmtj", AnalogConfig())
    assert not np.allclose(np.asarray(old.g_diff), np.asarray(ideal.g_diff))


def test_wer_margined_pulse_covers_process_corners():
    """The corner-margined pulse is the worst case over (corner x T): at
    least the nominal pulse, from one fused launch per device kind."""
    from repro.imc.write_margin import wer_margined_pulse

    kw = dict(v_write=1.0, wer_target=5e-2, n_samples=64, use_cache=False)
    nominal = wer_margined_pulse("afmtj", **kw)
    spec = VariationSpec(corners=(CORNER_FF, CORNER_SS))
    ranged = wer_margined_pulse("afmtj", variation=spec, **kw)
    assert ranged >= nominal


def test_mtj_variation_rides_the_scan_tile():
    """The single-sublattice (MTJ) engine tile honors the variation plane
    too: the slow corner's WER at a marginal pulse exceeds the fast
    corner's on the same thermal streams."""
    grid = CampaignGrid(voltages=(1.0,), pulse_widths=(1400e-12,),
                        temperatures=(300.0,), n_samples=32, dt=0.2e-12,
                        seed=1,
                        variation=VariationSpec(corners=(CORNER_FF,
                                                         CORNER_SS)))
    res = run_campaign(MTJ_PARAMS, grid, use_cache=False)
    w = res.wer_surface()                     # (2, 1, 1, 1)
    assert w.shape == (2, 1, 1, 1)
    assert w[1, 0, 0, 0] >= w[0, 0, 0, 0]
    assert w[1, 0, 0, 0] > 0.1                # slow corner misses the pulse

"""Runtime fault-tolerance tests: step watchdog EWMA clamping, SIGTERM
preemption handling (install/uninstall/context-manager), and elastic
re-meshing plans.  First coverage for ``runtime.fault`` / ``runtime.elastic``
— pure-Python modules, no JAX."""
import os
import signal

from repro.runtime.elastic import plan_elastic_remesh
from repro.runtime.fault import FaultTolerantLoop, StepWatchdog


class FakeCkpt:
    def __init__(self):
        self.saves = []
        self.waited = False

    def save(self, step, state, blocking=False):
        self.saves.append((step, blocking))

    def wait(self):
        self.waited = True


# --- watchdog ----------------------------------------------------------------

def test_watchdog_first_observation_seeds_ewma():
    wd = StepWatchdog(threshold=2.0, alpha=0.1)
    assert wd.observe(0, 5.0) is False
    assert wd.ewma == 5.0 and wd.straggler_steps == []


def test_watchdog_flags_straggler_and_clamps_ewma():
    """A 100x spike is flagged, but enters the average clamped to
    threshold*ewma — one straggler must not poison the baseline."""
    wd = StepWatchdog(threshold=2.0, alpha=0.1)
    wd.observe(0, 1.0)
    assert wd.observe(1, 100.0) is True
    assert wd.straggler_steps == [1]
    assert wd.ewma == 0.9 * 1.0 + 0.1 * 2.0          # clamped at 2x, not 100
    # the next normal step is NOT flagged against a poisoned average
    assert wd.observe(2, 1.0) is False


def test_watchdog_tracks_gradual_slowdown():
    """A gradual 1.5x drift is absorbed into the EWMA without flags."""
    wd = StepWatchdog(threshold=2.0, alpha=0.5)
    for i, dt in enumerate((1.0, 1.2, 1.4, 1.5)):
        assert wd.observe(i, dt) is False
    assert wd.ewma > 1.0


# --- preemption / SIGTERM lifecycle ------------------------------------------

def test_sigterm_uninstall_restores_previous_handler():
    """Regression: ``install_sigterm`` used to leak the handler forever —
    uninstall (and the context manager) must restore the prior disposition."""
    sentinel = lambda signum, frame: None     # noqa: E731
    prev = signal.signal(signal.SIGTERM, sentinel)
    try:
        loop = FaultTolerantLoop(FakeCkpt())
        loop.install_sigterm()
        assert signal.getsignal(signal.SIGTERM) is not sentinel
        loop.uninstall_sigterm()
        assert signal.getsignal(signal.SIGTERM) is sentinel
        loop.uninstall_sigterm()              # idempotent
        assert signal.getsignal(signal.SIGTERM) is sentinel
        with FaultTolerantLoop(FakeCkpt()):
            assert signal.getsignal(signal.SIGTERM) is not sentinel
        assert signal.getsignal(signal.SIGTERM) is sentinel
    finally:
        signal.signal(signal.SIGTERM, prev)


def test_preemption_triggers_final_blocking_checkpoint():
    """A SIGTERM mid-run flips the flag; the loop stops at the step
    boundary and writes one final *blocking* checkpoint."""
    ckpt = FakeCkpt()
    with FaultTolerantLoop(ckpt, save_every=100) as loop:
        def step_fn(state, batch):
            if state == 2:
                os.kill(os.getpid(), signal.SIGTERM)
            return state + 1, {}

        state, step, _ = loop.run(0, step_fn, lambda s: {}, start_step=0,
                                  total_steps=50)
    assert loop.preempted and step < 50
    assert ckpt.saves and ckpt.saves[-1][1] is True    # blocking final save
    assert ckpt.waited


def test_clean_run_saves_periodically_no_final_blocking():
    ckpt = FakeCkpt()
    loop = FaultTolerantLoop(ckpt, save_every=2)
    state, step, wd = loop.run(0, lambda s, b: (s + 1, {}), lambda s: {},
                               start_step=0, total_steps=6)
    assert step == 6 and state == 6 and not loop.preempted
    assert ckpt.saves == [(2, False), (4, False), (6, False)]
    assert ckpt.waited


# --- elastic re-meshing ------------------------------------------------------

def test_elastic_full_mesh_passthrough():
    plan = plan_elastic_remesh(256, model_axis=16, old_data_axis=16)
    assert plan.mesh_shape == (16, 16)
    assert plan.axis_names == ("data", "model")
    assert plan.microbatch_scale == 1
    assert plan.note == "full mesh healthy"
    multi = plan_elastic_remesh(512, model_axis=16, old_data_axis=16, pods=2)
    assert multi.mesh_shape == (2, 16, 16)
    assert multi.axis_names == ("pod", "data", "model")


def test_elastic_halves_data_axis_preserving_global_batch():
    """Losing chips halves the data axis; microbatch_scale compensates so
    the global batch (and training dynamics) are unchanged."""
    plan = plan_elastic_remesh(200, model_axis=16, old_data_axis=16)
    assert plan.mesh_shape == (8, 16)          # 128 <= 200 < 256
    assert plan.microbatch_scale == 2
    assert "degraded" in plan.note
    quarter = plan_elastic_remesh(70, model_axis=16, old_data_axis=16)
    assert quarter.mesh_shape == (4, 16) and quarter.microbatch_scale == 4
    # the product data*scale always preserves the global batch
    for n in (256, 200, 130, 70, 40, 17):
        p = plan_elastic_remesh(n, model_axis=16, old_data_axis=16)
        data = p.mesh_shape[-2]
        assert data * p.microbatch_scale == 16
        assert data * 16 <= n


def test_elastic_returns_none_when_model_axis_cannot_fit():
    assert plan_elastic_remesh(15, model_axis=16, old_data_axis=16) is None
    assert plan_elastic_remesh(0, model_axis=8, old_data_axis=4) is None

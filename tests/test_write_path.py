"""Stochastic write path tests: scheduler determinism, retry physics,
accounting invariants, and the circuit/system threading (DESIGN.md §7)."""
import dataclasses

import numpy as np
import pytest

from repro.imc.write_path import (WritePolicy, measured_write_timings,
                                  nominal_pulse, program_bits, write_verify)

# Short pulse (below the AFMTJ mean switching time at 1.0 V) so the retry
# machinery is actually exercised; shared across tests to share compiles.
PULSE = 130e-12
N = 96


def _policy(**kw):
    base = dict(v_write=1.0, pulse=PULSE, max_attempts=3, seed=5,
                use_cache=False)
    base.update(kw)
    return WritePolicy(**base)


@pytest.fixture(scope="module")
def afmtj_result():
    return write_verify("afmtj", N, _policy())


# ------------------------------------------------------------- determinism
def test_deterministic_at_fixed_seed(afmtj_result):
    r2 = write_verify("afmtj", N, _policy())
    np.testing.assert_array_equal(afmtj_result.attempts, r2.attempts)
    np.testing.assert_array_equal(afmtj_result.success, r2.success)
    np.testing.assert_array_equal(afmtj_result.crossing_time,
                                  r2.crossing_time)
    np.testing.assert_array_equal(afmtj_result.energy, r2.energy)


def test_seed_changes_the_draw(afmtj_result):
    r2 = write_verify("afmtj", N, _policy(seed=6))
    assert not np.array_equal(afmtj_result.crossing_time, r2.crossing_time)


# ------------------------------------------------------------ retry physics
def test_retries_grow_as_voltage_drops():
    """Lower drive eats the STT overdrive: at a fixed pulse the per-attempt
    WER rises, so the scheduler pays monotonically more attempts."""
    means = [write_verify("afmtj", N, _policy(v_write=v,
                                              max_attempts=4)).attempts_mean
             for v in (1.15, 1.0, 0.85)]
    assert means[0] <= means[1] <= means[2], means
    assert means[2] > means[0], means


def test_mtj_needs_more_retries_at_equal_pulse(afmtj_result):
    """At the AFMTJ's (picosecond) pulse width the FM baseline virtually
    never verifies — the retry counts carry the device asymmetry."""
    r_mtj = write_verify("mtj", N, _policy())
    assert r_mtj.attempts_mean > afmtj_result.attempts_mean
    assert r_mtj.residual_ber >= afmtj_result.residual_ber
    assert r_mtj.residual_ber > 0.9            # ~every cell fails


def test_single_pulse_wer_matches_histogram(afmtj_result):
    r = afmtj_result
    hist = r.retry_histogram()
    assert hist[0] == 0 and hist.sum() == N
    assert r.single_pulse_wer == pytest.approx(1.0 - hist[1] / N)
    # short pulse: retries must actually occur
    assert r.attempts_mean > 1.0


# ------------------------------------------------------- accounting invariants
def test_latency_and_energy_accounting(afmtj_result):
    r = afmtj_result
    pol = r.policy
    np.testing.assert_allclose(
        r.latency, r.attempts * (pol.t_rc + r.pulse + pol.t_verify))
    # two-state energy bounds per attempt: G_AP * pulse <= e <= G_P *
    # (pulse + t_rc) at v^2 (e_verify = 0 here)
    from repro.core.params import AFMTJ_PARAMS as P
    v2 = pol.v_write**2
    lo = r.attempts * v2 / P.r_antiparallel * r.pulse
    hi = r.attempts * v2 / P.r_parallel * (r.pulse + pol.t_rc) * (1 + 1e-9)
    assert (r.energy >= lo).all() and (r.energy <= hi).all()
    # crossing times are only defined for verified cells, inside the pulse
    ok = r.success
    assert np.isnan(r.crossing_time[~ok]).all()
    assert (r.crossing_time[ok] <= r.pulse).all()


def test_row_granular_stats(afmtj_result):
    r = afmtj_result
    rows = r.row_attempts(cols=8)
    assert rows.shape == (N // 8,)
    np.testing.assert_array_equal(
        rows, r.attempts.reshape(-1, 8).max(axis=1))
    assert r.row_latency_percentile(8, 100.0) == pytest.approx(
        rows.max() * r.cycle)


def test_program_bits_error_map():
    rng = np.random.default_rng(0)
    target = (rng.random((8, 8)) < 0.5).astype(np.uint8)
    res, err = program_bits(target, "afmtj", _policy(max_attempts=2))
    assert res.attempts.size == int(target.sum())
    assert err.shape == target.shape
    assert err[target == 0].sum() == 0          # unwritten cells never err
    assert err.sum() == int((~res.success).sum())


# ------------------------------------------------- circuit/system threading
def test_subarray_measured_write_path():
    from repro.circuit.subarray import make_subarray

    closed = make_subarray("afmtj", rows=8, cols=8).timings
    assert closed.write_attempts == 1.0
    assert closed.write_residual_ber == 0.0
    assert closed.write_percentile is None

    measured = make_subarray("afmtj", rows=8, cols=8,
                             write_percentile=99.0).timings
    assert measured.write_percentile == 99.0
    assert measured.write_attempts >= 1.0
    # the percentile row time covers at least one full attempt cycle and
    # sits above the closed-form single-pulse time (retry + margin tail)
    assert measured.t_write > closed.t_write
    assert measured.e_write_bit > 0.0


def test_system_result_threads_write_stats():
    from repro.circuit.subarray import make_subarray
    from repro.imc.evaluate import evaluate_workload
    from repro.imc.hierarchy import IMCHierarchy, IMCLevel, LEVELS
    from repro.imc.workloads import WORKLOADS

    sub = make_subarray("afmtj", rows=8, cols=8, write_percentile=99.0)
    hier = IMCHierarchy("afmtj", {s.name: IMCLevel(spec=s, timings=sub.timings)
                                  for s in LEVELS})
    r = evaluate_workload(WORKLOADS["mat_add"], hier)
    assert r.t_write_op == sub.timings.t_write
    assert r.write_attempts == sub.timings.write_attempts
    assert r.write_residual_ber == sub.timings.write_residual_ber


def test_evaluate_system_defaults_are_single_pulse():
    from repro.imc.evaluate import evaluate_system

    for r in evaluate_system("afmtj").values():
        assert r.write_attempts == 1.0 and r.t_write_op > 0.0


# ------------------------------------------------ read-path BER injection
def test_write_ber_degrades_analog_accuracy():
    import jax
    import jax.numpy as jnp

    from repro.imc.analog_pipeline import AnalogConfig, mvm_accuracy

    kw, kx = jax.random.split(jax.random.PRNGKey(1))
    w = jax.random.normal(kw, (64, 48), jnp.float32) / 8.0
    x = jax.random.normal(kx, (4, 64), jnp.float32)
    base = AnalogConfig(adc_bits=0, ir_drop=False)
    clean = mvm_accuracy(w, x, cfg=base)
    dirty = mvm_accuracy(w, x, cfg=dataclasses.replace(base, write_ber=0.2))
    assert clean.nmse < 1e-9                    # ideal path stays exact
    assert dirty.nmse > 100 * max(clean.nmse, 1e-12)
    assert dirty.write_ber == 0.2


def test_write_energy_accuracy_surface_tradeoff():
    from repro.configs.registry import ARCHS
    from repro.imc.mapping import write_energy_accuracy_surface

    surf = write_energy_accuracy_surface(
        ARCHS["gemma2-2b"], kind="afmtj", wer_targets=(3e-1, 1e-2),
        policy=_policy(max_attempts=1), n_cells=64,
        cap_k=64, cap_n=32, batch=2)
    loose, tight = surf[3e-1], surf[1e-2]
    assert tight.attempts_budget > loose.attempts_budget
    assert tight.write_ber < loose.write_ber
    assert tight.e_write_bit > loose.e_write_bit
    assert tight.report.nmse < loose.report.nmse


# ------------------------------------------------------------ pulse policy
def test_nominal_pulse_ordering():
    assert nominal_pulse("mtj", 1.0) > 4 * nominal_pulse("afmtj", 1.0)
    pol = WritePolicy(v_write=1.0)
    assert pol.resolved_pulse("afmtj") == pytest.approx(
        nominal_pulse("afmtj", 1.0) * pol.pulse_margin)
    explicit = WritePolicy(v_write=1.0, pulse=PULSE)
    assert explicit.resolved_pulse("afmtj") == PULSE

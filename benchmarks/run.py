"""Benchmark harness — one function per paper table/figure.

Prints ``name,value,derived`` CSV rows per benchmark plus timing, and a
modeled-vs-paper comparison where the paper reports numbers.

  table1     — Table I device comparison (TMR, switching, write energy)
  fig3       — Fig. 3 write latency/energy vs voltage, AFMTJ vs MTJ
  fig4       — Fig. 4 system speedup/energy vs CPU across 6 workloads
  validation — Sec. II-A validation (TMR ~80%, ps switching, threshold)
  archmap    — beyond-paper: 10 LM archs mapped onto the IMC hierarchy
  kernels    — Pallas kernel microbenches (interpret mode) vs jnp oracle
  mvm        — functional analog MVM (bitline/XNOR kernels) vs jnp einsum
  wer        — campaign-engine WER surface vs the per-sample scan path
  write      — stochastic write path: AFMTJ vs MTJ write-verify retries
               (measured latency/energy/retry distributions, paper 8x/9x
               write ratios from transient dynamics — DESIGN.md §7)

``--smoke`` shrinks shapes and skips steady-state warmups so CI can exercise
kernel-vs-reference parity on every push (honored by ``mvm`` and ``write``).

Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

SMOKE = False   # set by --smoke in main()


def _t(fn, *a, **k):
    t0 = time.time()
    out = fn(*a, **k)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
        out)
    return out, (time.time() - t0) * 1e6


def bench_table1():
    """Table I: MTJ vs AFMTJ characteristics."""
    from repro.core.device import simulate_write
    from repro.core.params import AFMTJ_PARAMS, MTJ_PARAMS
    from repro.core.tmr import tmr_ratio

    print("# table1: Table I device comparison")
    print("name,us_per_call,derived")
    for name, p, n, dt in [("mtj", MTJ_PARAMS, 40000, 0.1e-12),
                           ("afmtj", AFMTJ_PARAMS, 16000, 0.05e-12)]:
        r, us = _t(simulate_write, p, 1.0, n_steps=n, dt=dt)
        print(f"table1.{name}.tmr_pct,{us:.0f},{tmr_ratio(p)*100:.0f}")
        print(f"table1.{name}.switch_ps,{us:.0f},{float(r.t_switch)*1e12:.1f}")
        print(f"table1.{name}.write_fj,{us:.0f},{float(r.energy)*1e15:.1f}")
    print("# paper: MTJ TMR 80-120%, switch 1-2ns, ~300-480fJ; "
          "AFMTJ TMR up to 500% (validated ~80%), 10-100ps, 20-100fJ")


def bench_fig3():
    """Fig. 3: write latency (a) and energy (b) vs input voltage."""
    from repro.core.device import write_sweep
    from repro.core.params import (AFMTJ_PARAMS, MTJ_PARAMS,
                                   PAPER_FIG3_AFMTJ, PAPER_FIG3_MTJ)

    print("# fig3: write latency/energy vs voltage")
    print("name,us_per_call,derived")
    voltages = jnp.asarray([0.5, 0.6, 0.7, 0.8, 0.9, 1.0, 1.1, 1.2])
    out = {}
    for name, p, n, dt in [("afmtj", AFMTJ_PARAMS, 16000, 0.05e-12),
                           ("mtj", MTJ_PARAMS, 60000, 0.1e-12)]:
        r, us = _t(write_sweep, p, voltages, n_steps=n, dt=dt)
        out[name] = r
        for i, v in enumerate(np.asarray(voltages)):
            lat = float(r.write_latency[i]) * 1e12
            en = float(r.energy[i]) * 1e15
            print(f"fig3.{name}.latency_ps@{v:.1f}V,{us/8:.0f},{lat:.1f}")
            print(f"fig3.{name}.energy_fJ@{v:.1f}V,{us/8:.0f},{en:.1f}")
    for (v, lat, en), dev in [(PAPER_FIG3_AFMTJ[0], "afmtj"),
                              (PAPER_FIG3_MTJ[0], "mtj")]:
        i = int(np.argmin(np.abs(np.asarray(voltages) - v)))
        ml = float(out[dev].write_latency[i])
        me = float(out[dev].energy[i])
        print(f"# {dev}@{v}V modeled {ml*1e12:.0f}ps/{me*1e15:.1f}fJ "
              f"vs paper {lat*1e12:.0f}ps/{en*1e15:.1f}fJ "
              f"(err {100*(ml-lat)/lat:+.1f}%/{100*(me-en)/en:+.1f}%)")
    la = float(out['mtj'].write_latency[5] / out['afmtj'].write_latency[5])
    ea = float(out['mtj'].energy[5] / out['afmtj'].energy[5])
    print(f"# ratios@1.0V: latency {la:.1f}x (paper ~8x), energy {ea:.1f}x (paper ~9x)")


def bench_fig4():
    """Fig. 4: system-level speedup (a) and energy savings (b) vs CPU."""
    from repro.imc.evaluate import evaluate_system, summarize

    print("# fig4: hierarchical IMC vs ARM Cortex-A72")
    print("name,us_per_call,derived")
    paper = {"bnn": 55.4, "mat_add": 16.5}
    for kind in ("afmtj", "mtj"):
        res, us = _t(evaluate_system, kind)
        for name, r in res.items():
            print(f"fig4.{kind}.{name}.speedup,{us/6:.0f},{r.speedup:.1f}")
            print(f"fig4.{kind}.{name}.energy_saving,{us/6:.0f},{r.energy_saving:.1f}")
        sp, es = summarize(res)
        print(f"fig4.{kind}.avg.speedup,{us/6:.0f},{sp:.1f}")
        print(f"fig4.{kind}.avg.energy_saving,{us/6:.0f},{es:.1f}")
        if kind == "afmtj":
            for w, pv in paper.items():
                mv = res[w].speedup
                print(f"# afmtj {w}: modeled {mv:.1f}x vs paper {pv}x "
                      f"(err {100*(mv-pv)/pv:+.1f}%)")
            print(f"# afmtj avg: modeled {sp:.1f}x/{es:.1f}x vs paper 17.5x/19.9x")
        else:
            print(f"# mtj avg: modeled {sp:.1f}x/{es:.1f}x vs paper 6x/2.3x")


def bench_validation():
    """Sec. II-A: validation against fabricated AFMTJs."""
    from repro.core.device import simulate_write
    from repro.core.params import AFMTJ_PARAMS
    from repro.core.tmr import tmr_ratio

    print("# validation: TMR + switching-dynamics checks")
    print("name,us_per_call,derived")
    print(f"validation.tmr_pct,0,{tmr_ratio(AFMTJ_PARAMS)*100:.1f}")
    r, us = _t(simulate_write, AFMTJ_PARAMS, 1.0, n_steps=16000, dt=0.05e-12)
    ps = float(r.t_switch) * 1e12
    print(f"validation.switch_ps@1V,{us:.0f},{ps:.1f}")
    print(f"validation.ps_scale_ok,0,{int(10 < ps < 500)}")
    r_low, _ = _t(simulate_write, AFMTJ_PARAMS, 0.15, n_steps=8000, dt=0.05e-12)
    print(f"validation.below_threshold_no_switch,0,{int(not bool(r_low.switched))}")
    # intrinsic switching-latency trend (paper: 65ps@0.5V -> 20ps@1.2V)
    r05, _ = _t(simulate_write, AFMTJ_PARAMS, 0.5, n_steps=16000, dt=0.05e-12)
    r12, _ = _t(simulate_write, AFMTJ_PARAMS, 1.2, n_steps=16000, dt=0.05e-12)
    ratio = float(r05.t_switch / r12.t_switch)
    print(f"validation.intrinsic_ratio_0p5_1p2,0,{ratio:.2f}")
    print(f"# paper intrinsic ratio 65/20 = 3.25; modeled {ratio:.2f} "
          "(shape reproduced; absolute times ~3-4x paper — see EXPERIMENTS.md)")


def bench_archmap():
    """Beyond-paper: decode-step inference of the 10 archs on AFMTJ IMC."""
    from repro.configs.registry import ARCHS
    from repro.imc.mapping import map_all

    print("# archmap: LM architectures on the IMC hierarchy (per decode token)")
    print("name,us_per_call,derived")
    out, us = _t(map_all, ARCHS)
    for kind in ("afmtj", "mtj"):
        for name, r in out[kind].items():
            print(f"archmap.{kind}.{name}.speedup_vs_cpu,{us/20:.0f},{r.speedup:.1f}")
            print(f"archmap.{kind}.{name}.energy_saving,{us/20:.0f},"
                  f"{r.energy_saving:.1f}")
    a, m = out["afmtj"], out["mtj"]
    gain = np.mean([a[k].speedup / m[k].speedup for k in a])
    print(f"# afmtj-vs-mtj mean decode speedup gain: {gain:.2f}x")


def bench_kernels():
    """Pallas kernels (interpret mode) vs jnp oracle — correctness + timing."""
    from repro.core import llg
    from repro.core.params import AFMTJ_PARAMS
    from repro.kernels import ops, ref

    print("# kernels: pallas (interpret) vs ref")
    print("name,us_per_call,derived")
    th = jnp.linspace(0.05, 0.25, 512)
    m0 = jax.vmap(lambda t: llg.initial_state(AFMTJ_PARAMS, t, 0.3))(th)
    state = ops.pack_states(m0, jnp.linspace(0.3, 1.2, 512))
    for steps in (100, 400):
        (ok, uk) = _t(ops.llg_rk4, state, AFMTJ_PARAMS, 0.1e-12, steps)
        (orf, ur) = _t(ref.ref_llg_rk4, state, AFMTJ_PARAMS, 0.1e-12, steps)
        err = float(jnp.max(jnp.abs(ok[0][:6] - orf[0][:6]))) if isinstance(ok, tuple) else float(jnp.max(jnp.abs(ok[:6] - orf[:6])))
        print(f"kernels.llg_rk4.{steps}steps,{uk:.0f},maxerr={err:.1e}")
        print(f"kernels.llg_rk4_ref.{steps}steps,{ur:.0f},1")
    v = jax.random.uniform(jax.random.PRNGKey(0), (256, 512))
    g = jax.random.uniform(jax.random.PRNGKey(1), (512, 256)) * 3.4e-4
    (o1, u1) = _t(ops.bitline_mac, v, g, 6, i_max=0.05)
    (o2, u2) = _t(ref.ref_bitline_mac, v, g, 6, i_max=0.05)
    print(f"kernels.bitline_mac.256x512x256,{u1:.0f},"
          f"match={int(bool(jnp.allclose(o1, o2, rtol=1e-5)))}")
    a = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (256, 512)))
    w = jnp.sign(jax.random.normal(jax.random.PRNGKey(3), (512, 256)))
    (o3, u3) = _t(ops.xnor_gemm, a, w)
    (o4, u4) = _t(ref.ref_xnor_gemm, a, w)
    print(f"kernels.xnor_gemm.256x512x256,{u3:.0f},"
          f"match={int(bool(jnp.allclose(o3, o4)))}")


def bench_mvm():
    """Functional analog MVM: the Pallas bitline/XNOR read path vs a jnp
    einsum baseline — throughput plus kernel-vs-reference parity and output
    error vs the f32 matmul (the accuracy the closed-form model can't see).

    Shapes are deliberately NOT 128-multiples so the padding path is always
    exercised."""
    from repro.imc.analog_pipeline import (AnalogConfig, analog_matmul,
                                           binary_matmul, program_weights)
    from repro.kernels import ref
    from repro.kernels.xnor_gemm import binarize_acc

    m, k, n = (48, 200, 144) if SMOKE else (256, 1000, 520)
    print(f"# mvm: analog read path {m}x{k}x{n} "
          f"({'smoke' if SMOKE else 'full'}; pallas interpret on CPU)")
    print("name,us_per_call,derived")
    kw, kx = jax.random.split(jax.random.PRNGKey(0))
    w = jax.random.normal(kw, (k, n), jnp.float32) / (k ** 0.5)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    y_f32 = np.asarray(x @ w)

    cfg = AnalogConfig(adc_bits=6)
    arr = program_weights(w, "afmtj", cfg)
    einsum = jax.jit(lambda a, b: jnp.einsum("mk,kn->mn", a, b))
    if not SMOKE:   # steady-state: warm both compiles out of the timings
        analog_matmul(arr, x).block_until_ready()
        einsum(x, w).block_until_ready()
    y_a, us_a = _t(analog_matmul, arr, x)
    mse = float(np.mean((np.asarray(y_a) - y_f32) ** 2))
    print(f"mvm.analog.adc6,{us_a:.0f},nmse={mse/np.mean(y_f32**2):.2e}")

    # parity: the kernel output must match the jnp oracle on the exact
    # operands analog_matmul fed the kernel
    from repro.imc.analog_pipeline import kernel_operands
    from repro.kernels.ops import bitline_mac
    v, i_max, _ = kernel_operands(arr, x)
    ok = np.allclose(np.asarray(bitline_mac(v, arr.g_diff, 6, i_max=i_max)),
                     np.asarray(ref.ref_bitline_mac(v, arr.g_diff, 6,
                                                    i_max=i_max)),
                     rtol=1e-5, atol=i_max / 31 * 1.001)
    print(f"mvm.analog.kernel_vs_ref,0,match={int(ok)}")

    (y_e, us_e) = _t(einsum, x, w)
    print(f"mvm.einsum_f32,{us_e:.0f},baseline")
    print(f"mvm.analog_over_einsum,0,{us_a/max(us_e,1e-9):.1f}")

    y_b, us_b = _t(binary_matmul, x, w)
    mse_b = float(np.mean((np.asarray(y_b) - y_f32) ** 2))
    print(f"mvm.bnn.xnor,{us_b:.0f},nmse={mse_b/np.mean(y_f32**2):.2e}")
    from repro.kernels.ops import xnor_gemm
    xb, wb = binarize_acc(x, 1), binarize_acc(w, 1)
    ok_b = np.array_equal(np.asarray(xnor_gemm(xb, wb)),
                          np.asarray(ref.ref_xnor_gemm(xb, wb)))
    print(f"mvm.bnn.kernel_vs_ref,0,match={int(ok_b)}")
    print("# analog path adds programming+ADC on top of the matmul; on TPU "
          "the kernel runs compiled (interpret-mode timings are CPU-only)")


def bench_wer():
    """Campaign engine: WER(voltage, pulse) surface through the Pallas
    thermal kernel, vs the per-sample scan path in core/montecarlo.py —
    the reliability spec a write controller binds against."""
    from repro.campaign import CampaignGrid, run_campaign
    from repro.core.montecarlo import write_error_rate_scan
    from repro.core.params import AFMTJ_PARAMS
    from repro.imc.write_margin import wer_margined_pulse

    voltages = (0.6, 0.8, 1.0, 1.2)
    pulses = tuple(x * 1e-12 for x in (100, 150, 200, 250, 300, 350, 400))
    n_samples = 128                       # 4 V x 128 S fills one CELL_TILE
    grid = CampaignGrid(voltages=voltages, pulse_widths=pulses,
                        n_samples=n_samples, dt=0.1e-12, seed=0)
    print("# wer: campaign engine WER(V, pulse) surface "
          f"({len(voltages)}V x {len(pulses)}P x {n_samples}S, "
          f"{grid.n_steps} steps)")
    print("name,us_per_call,derived")

    # steady-state comparison: warm the engine AND every scan pulse width
    # (pulse_s is a jit static, so each pulse is its own compile — excluded
    # here; note that in real campaigns the scan path pays that recompile
    # per pulse point while the engine never does)
    warm = CampaignGrid(voltages=voltages, pulse_widths=pulses,
                        n_samples=n_samples, dt=0.1e-12, seed=1)
    run_campaign(AFMTJ_PARAMS, warm, use_cache=False)
    for pl_ in pulses:
        write_error_rate_scan(AFMTJ_PARAMS, 1.0, pl_,
                              n_samples=32).block_until_ready()

    res, us_engine = _t(lambda: run_campaign(AFMTJ_PARAMS, grid,
                                             use_cache=False))
    wer = res.wer()
    for i, v in enumerate(voltages):
        for j in (0, 3, 6):               # print a readable subset
            print(f"wer.afmtj.{v:.1f}V.{pulses[j]*1e12:.0f}ps,"
                  f"{us_engine/res.n_samples_total:.0f},{wer[i, j]:.3f}")

    # scan baseline: producing the same pulse axis takes one integration
    # per (V, pulse) point — time the 1.0 V row, 32 samples each, warmed
    us_scan_total, scan_runs = 0.0, 0
    for pl_ in pulses:
        w, us = _t(write_error_rate_scan, AFMTJ_PARAMS, 1.0, pl_,
                   n_samples=32)
        us_scan_total += us / 32          # us per sample at this pulse
        scan_runs += 1
        if pl_ in (pulses[0], pulses[3], pulses[6]):
            print(f"wer.scan.1.0V.{pl_*1e12:.0f}ps,{us/32:.0f},{float(w):.3f}")

    # per *sample of the full surface*: one engine sample covers every
    # pulse width (first-crossing post-processing); a scan sample must be
    # re-integrated once per pulse point
    us_engine_per = us_engine / res.n_samples_total
    us_scan_per = us_scan_total           # summed over the pulse axis
    print(f"wer.engine.us_per_sample,{us_engine_per:.0f},"
          f"{res.n_samples_total}")
    print(f"wer.scan.us_per_sample,{us_scan_per:.0f},{scan_runs * 32}")
    print(f"# engine {us_engine_per:.0f} us/sample (all {len(pulses)} "
          f"pulses) vs scan {us_scan_per:.0f} us/sample (re-integrated per "
          f"pulse, steady-state) -> {us_scan_per/us_engine_per:.1f}x fewer "
          "us per sample (target >= 5x)")

    pulse = wer_margined_pulse("afmtj", 1.0, wer_target=1e-2, n_samples=128)
    print(f"wer.margin_pulse_ps@1V.wer1e-2,0,{pulse*1e12:.0f}")
    print("# mean intrinsic t_sw ~123ps; the WER<=1e-2 pulse covers the "
          "thermal tail the IMC controller schedules against")


def bench_write():
    """Stochastic write path: write-verify retry programming at 1.0 V,
    AFMTJ vs MTJ — the paper's headline write ratios (~8x latency, ~9x
    energy) reproduced from thermal LLG transients + retries instead of
    the deterministic single-pulse constants.  Full mode additionally
    reruns the Fig. 4 system comparison with the measured p99 row write
    time threaded through the pipelined stage model."""
    from repro.imc.write_path import WritePolicy, write_verify

    n_cells = 64 if SMOKE else 1024
    max_att = 4 if SMOKE else 8
    print(f"# write: write-verify retry path @1.0V, {n_cells} cells, "
          f"<= {max_att} attempts ({'smoke' if SMOKE else 'full'})")
    print("name,us_per_call,derived")
    res = {}
    for kind in ("afmtj", "mtj"):
        pol = WritePolicy(v_write=1.0, max_attempts=max_att, seed=0)
        r, us = _t(lambda k=kind, p=pol: write_verify(k, n_cells, p))
        res[kind] = r
        hist = "/".join(str(int(c)) for c in r.retry_histogram()[1:])
        print(f"write.{kind}.pulse_ps,{us:.0f},{r.pulse*1e12:.0f}")
        print(f"write.{kind}.single_pulse_wer,0,{r.single_pulse_wer:.3f}")
        print(f"write.{kind}.attempts_mean,0,{r.attempts_mean:.2f}")
        print(f"write.{kind}.retry_hist,0,{hist}")
        print(f"write.{kind}.latency_mean_ps,0,{r.latency.mean()*1e12:.0f}")
        print(f"write.{kind}.latency_p99_ps,0,"
              f"{r.latency_percentile(99.0)*1e12:.0f}")
        print(f"write.{kind}.energy_mean_fj,0,{r.energy_mean()*1e15:.1f}")
        print(f"write.{kind}.residual_ber,0,{r.residual_ber:.4f}")

    la = res["mtj"].latency.mean() / res["afmtj"].latency.mean()
    ea = res["mtj"].energy_mean() / res["afmtj"].energy_mean()
    print(f"write.ratio.latency,0,{la:.1f}")
    print(f"write.ratio.energy,0,{ea:.1f}")
    print(f"write.ratio_ok,0,{int(5.0 < la < 13.0 and 5.0 < ea < 13.0)}")
    print("# paper @1.0V: ~8x latency, ~9x energy (Fig. 3 anchors; see "
          "EXPERIMENTS.md §Write-path for documented deviations)")

    # equal-pulse retry asymmetry: at the AFMTJ's pulse the MTJ virtually
    # never verifies — the retry counts, not the nominal pulse, carry the
    # device difference (pins the CI marker below)
    tp = WritePolicy(v_write=1.0).resolved_pulse("afmtj")
    pol_eq = WritePolicy(v_write=1.0, pulse=tp, max_attempts=3, seed=0)
    r_a, _ = _t(lambda: write_verify("afmtj", n_cells, pol_eq))
    r_m, _ = _t(lambda: write_verify("mtj", n_cells, pol_eq))
    print(f"write.equal_pulse.afmtj_attempts,0,{r_a.attempts_mean:.2f}")
    print(f"write.equal_pulse.mtj_attempts,0,{r_m.attempts_mean:.2f}")
    print(f"write.equal_pulse_retries_ok,0,"
          f"{int(r_m.attempts_mean > r_a.attempts_mean)}")

    if SMOKE:
        return
    # Fig. 4 with the measured p99 row write time in the pipelined stage
    # model (SystemResult.t_write_op / .write_attempts thread it through):
    # MTJ retry inflation widens the AFMTJ advantage on write-heavy loads.
    from repro.imc.evaluate import evaluate_system, summarize

    for kind in ("afmtj", "mtj"):
        sys_n, us_n = _t(evaluate_system, kind)
        sys_p, us_p = _t(lambda k=kind: evaluate_system(
            k, write_percentile=99.0))
        sp_n, es_n = summarize(sys_n)
        sp_p, es_p = summarize(sys_p)
        r0 = sys_p["mat_add"]
        print(f"write.fig4.{kind}.avg_speedup_nominal,{us_n:.0f},{sp_n:.1f}")
        print(f"write.fig4.{kind}.avg_speedup_p99,{us_p:.0f},{sp_p:.1f}")
        print(f"write.fig4.{kind}.avg_energy_saving_p99,0,{es_p:.1f}")
        print(f"write.fig4.{kind}.mat_add_t_write_op_ps,0,"
              f"{r0.t_write_op*1e12:.0f}")
        print(f"write.fig4.{kind}.mat_add_write_attempts,0,"
              f"{r0.write_attempts:.2f}")


BENCHES = {
    "table1": bench_table1,
    "fig3": bench_fig3,
    "fig4": bench_fig4,
    "validation": bench_validation,
    "archmap": bench_archmap,
    "kernels": bench_kernels,
    "mvm": bench_mvm,
    "wer": bench_wer,
    "write": bench_write,
}


def main() -> None:
    global SMOKE
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(BENCHES), default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes, no steady-state warmup (CI parity run)")
    args = ap.parse_args()
    SMOKE = args.smoke
    names = [args.only] if args.only else list(BENCHES)
    t0 = time.time()
    for n in names:
        print(f"\n=== {n} " + "=" * (60 - len(n)))
        BENCHES[n]()
    print(f"\ntotal {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

"""Benchmark harness — one function per paper table/figure.

Prints ``name,value,derived`` CSV rows per benchmark plus timing, and a
modeled-vs-paper comparison where the paper reports numbers.

  table1     — Table I device comparison (TMR, switching, write energy)
  fig3       — Fig. 3 write latency/energy vs voltage, AFMTJ vs MTJ
  fig4       — Fig. 4 system speedup/energy vs CPU across 6 workloads
  validation — Sec. II-A validation (TMR ~80%, ps switching, threshold)
  archmap    — beyond-paper: 10 LM archs mapped onto the IMC hierarchy
  kernels    — Pallas kernel microbenches (interpret mode) vs jnp oracle
  mvm        — functional analog MVM (bitline/XNOR kernels) vs jnp einsum
  wer        — fused multi-temperature campaign (one launch, one compile)
               vs the old per-temperature-loop engine semantics and the
               per-sample scan path (DESIGN.md §8)
  write      — stochastic write path: AFMTJ vs MTJ write-verify retries
               (measured latency/energy/retry distributions, paper 8x/9x
               write ratios from transient dynamics — DESIGN.md §7), plus
               the retry-rounds-vs-XLA-compiles pin (§8)
  variation  — process-corner variation campaign (DESIGN.md §9): the
               (corner x T x V x S) grid as ONE launch / ONE compile,
               corner values rerun compile-free, per-corner WER/latency
               rows, corner-margined write pulse
  read       — read-path scenario family (DESIGN.md §10): sub-threshold
               read-disturb surfaces, accelerated-barrier retention with
               Arrhenius cross-check, sense-margin yield MC, and (full
               mode) the measured refresh policy charged into Fig. 4
  model      — model-level analog accuracy (DESIGN.md §12): whole
               transformer forwards through the analog MVM, the fused
               fake-analog speedup pin vs the per-projection device loop,
               BNN variant, and the logits-KL surface over adc_bits
  fault      — hard-fault injection + graceful degradation (DESIGN.md
               §13): accuracy/SLO vs fault rate x repair policy with the
               repair knee, the masks-are-data compile pin, repair-capacity
               yield, and the crash-resumable campaign check

``--smoke`` shrinks shapes and skips steady-state warmups so CI can exercise
kernel-vs-reference parity on every push (honored by ``mvm``, ``wer``,
``write``, ``variation``, ``read``, ``model`` and ``fault``).

``--json PATH`` additionally writes every emitted row to a machine-readable
BENCH.json: ``{name, value, units, wall_us, cold_us}`` per row plus run
metadata.  Warm rows come from a second (post-compile) call where the bench
uses ``_t_split``; ``cold_us`` then records the first call, compile
included — the split the perf trajectory in EXPERIMENTS.md tracks.

Usage: PYTHONPATH=src python -m benchmarks.run [--only A[,B...]] [--smoke]
       [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

SMOKE = False   # set by --smoke in main()
RECORDS = []    # BENCH.json rows, appended by emit()


def emit(name, us, derived, units: str = "", cold_us=None):
    """One benchmark data row: print the CSV line and record it for
    ``--json``.  ``us`` is the warm wall-clock of the measured call (0 for
    derived/secondary quantities); ``cold_us`` the compile-included first
    call where the bench measured one."""
    print(f"{name},{us:.0f},{derived}")
    try:
        value = float(derived)
    except (TypeError, ValueError):
        value = str(derived)
    RECORDS.append({"name": name, "value": value, "units": units,
                    "wall_us": float(us),
                    "cold_us": None if cold_us is None else float(cold_us)})


def _block(out):
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
        out)
    return out


def _t(fn, *a, **k):
    """Single timed call — compile time folds into the number (cold)."""
    t0 = time.time()
    out = _block(fn(*a, **k))
    return out, (time.time() - t0) * 1e6


def _t_split(fn, *a, **k):
    """Cold/warm timing split: first call (compile included), then a second
    identical call (steady state).  Returns (out, warm_us, cold_us)."""
    _, cold = _t(fn, *a, **k)
    out, warm = _t(fn, *a, **k)
    return out, warm, cold


def bench_table1():
    """Table I: MTJ vs AFMTJ characteristics."""
    from repro.core.device import simulate_write
    from repro.core.params import AFMTJ_PARAMS, MTJ_PARAMS
    from repro.core.tmr import tmr_ratio

    print("# table1: Table I device comparison")
    print("name,us_per_call,derived")
    for name, p, n, dt in [("mtj", MTJ_PARAMS, 40000, 0.1e-12),
                           ("afmtj", AFMTJ_PARAMS, 16000, 0.05e-12)]:
        r, us = _t(simulate_write, p, 1.0, n_steps=n, dt=dt)
        emit(f"table1.{name}.tmr_pct", us, f"{tmr_ratio(p)*100:.0f}", "%")
        emit(f"table1.{name}.switch_ps", us,
             f"{float(r.t_switch)*1e12:.1f}", "ps")
        emit(f"table1.{name}.write_fj", us, f"{float(r.energy)*1e15:.1f}", "fJ")
    print("# paper: MTJ TMR 80-120%, switch 1-2ns, ~300-480fJ; "
          "AFMTJ TMR up to 500% (validated ~80%), 10-100ps, 20-100fJ")


def bench_fig3():
    """Fig. 3: write latency (a) and energy (b) vs input voltage."""
    from repro.core.device import write_sweep
    from repro.core.params import (AFMTJ_PARAMS, MTJ_PARAMS,
                                   PAPER_FIG3_AFMTJ, PAPER_FIG3_MTJ)

    print("# fig3: write latency/energy vs voltage")
    print("name,us_per_call,derived")
    voltages = jnp.asarray([0.5, 0.6, 0.7, 0.8, 0.9, 1.0, 1.1, 1.2])
    out = {}
    for name, p, n, dt in [("afmtj", AFMTJ_PARAMS, 16000, 0.05e-12),
                           ("mtj", MTJ_PARAMS, 60000, 0.1e-12)]:
        r, us = _t(write_sweep, p, voltages, n_steps=n, dt=dt)
        out[name] = r
        for i, v in enumerate(np.asarray(voltages)):
            lat = float(r.write_latency[i]) * 1e12
            en = float(r.energy[i]) * 1e15
            emit(f"fig3.{name}.latency_ps@{v:.1f}V", us / 8, f"{lat:.1f}", "ps")
            emit(f"fig3.{name}.energy_fJ@{v:.1f}V", us / 8, f"{en:.1f}", "fJ")
    for (v, lat, en), dev in [(PAPER_FIG3_AFMTJ[0], "afmtj"),
                              (PAPER_FIG3_MTJ[0], "mtj")]:
        i = int(np.argmin(np.abs(np.asarray(voltages) - v)))
        ml = float(out[dev].write_latency[i])
        me = float(out[dev].energy[i])
        print(f"# {dev}@{v}V modeled {ml*1e12:.0f}ps/{me*1e15:.1f}fJ "
              f"vs paper {lat*1e12:.0f}ps/{en*1e15:.1f}fJ "
              f"(err {100*(ml-lat)/lat:+.1f}%/{100*(me-en)/en:+.1f}%)")
    la = float(out['mtj'].write_latency[5] / out['afmtj'].write_latency[5])
    ea = float(out['mtj'].energy[5] / out['afmtj'].energy[5])
    emit("fig3.ratio.latency@1.0V", 0, f"{la:.1f}", "x")
    emit("fig3.ratio.energy@1.0V", 0, f"{ea:.1f}", "x")
    print(f"# ratios@1.0V: latency {la:.1f}x (paper ~8x), energy {ea:.1f}x (paper ~9x)")


def bench_fig4():
    """Fig. 4: system-level speedup (a) and energy savings (b) vs CPU."""
    from repro.imc.evaluate import evaluate_system, summarize

    print("# fig4: hierarchical IMC vs ARM Cortex-A72")
    print("name,us_per_call,derived")
    paper = {"bnn": 55.4, "mat_add": 16.5}
    for kind in ("afmtj", "mtj"):
        res, us = _t(evaluate_system, kind)
        for name, r in res.items():
            emit(f"fig4.{kind}.{name}.speedup", us / 6, f"{r.speedup:.1f}", "x")
            emit(f"fig4.{kind}.{name}.energy_saving", us / 6,
                 f"{r.energy_saving:.1f}", "x")
        sp, es = summarize(res)
        emit(f"fig4.{kind}.avg.speedup", us / 6, f"{sp:.1f}", "x")
        emit(f"fig4.{kind}.avg.energy_saving", us / 6, f"{es:.1f}", "x")
        if kind == "afmtj":
            for w, pv in paper.items():
                mv = res[w].speedup
                print(f"# afmtj {w}: modeled {mv:.1f}x vs paper {pv}x "
                      f"(err {100*(mv-pv)/pv:+.1f}%)")
            print(f"# afmtj avg: modeled {sp:.1f}x/{es:.1f}x vs paper 17.5x/19.9x")
        else:
            print(f"# mtj avg: modeled {sp:.1f}x/{es:.1f}x vs paper 6x/2.3x")


def bench_validation():
    """Sec. II-A: validation against fabricated AFMTJs."""
    from repro.core.device import simulate_write
    from repro.core.params import AFMTJ_PARAMS
    from repro.core.tmr import tmr_ratio

    print("# validation: TMR + switching-dynamics checks")
    print("name,us_per_call,derived")
    emit("validation.tmr_pct", 0, f"{tmr_ratio(AFMTJ_PARAMS)*100:.1f}", "%")
    r, us = _t(simulate_write, AFMTJ_PARAMS, 1.0, n_steps=16000, dt=0.05e-12)
    ps = float(r.t_switch) * 1e12
    emit("validation.switch_ps@1V", us, f"{ps:.1f}", "ps")
    emit("validation.ps_scale_ok", 0, int(10 < ps < 500))
    r_low, _ = _t(simulate_write, AFMTJ_PARAMS, 0.15, n_steps=8000, dt=0.05e-12)
    emit("validation.below_threshold_no_switch", 0,
         int(not bool(r_low.switched)))
    # intrinsic switching-latency trend (paper: 65ps@0.5V -> 20ps@1.2V)
    r05, _ = _t(simulate_write, AFMTJ_PARAMS, 0.5, n_steps=16000, dt=0.05e-12)
    r12, _ = _t(simulate_write, AFMTJ_PARAMS, 1.2, n_steps=16000, dt=0.05e-12)
    ratio = float(r05.t_switch / r12.t_switch)
    emit("validation.intrinsic_ratio_0p5_1p2", 0, f"{ratio:.2f}", "x")
    print(f"# paper intrinsic ratio 65/20 = 3.25; modeled {ratio:.2f} "
          "(shape reproduced; absolute times ~3-4x paper — see EXPERIMENTS.md)")


def bench_archmap():
    """Beyond-paper: decode-step inference of the 10 archs on AFMTJ IMC."""
    from repro.configs.registry import ARCHS
    from repro.imc.mapping import map_all

    print("# archmap: LM architectures on the IMC hierarchy (per decode token)")
    print("name,us_per_call,derived")
    out, us = _t(map_all, ARCHS)
    for kind in ("afmtj", "mtj"):
        for name, r in out[kind].items():
            emit(f"archmap.{kind}.{name}.speedup_vs_cpu", us / 20,
                 f"{r.speedup:.1f}", "x")
            emit(f"archmap.{kind}.{name}.energy_saving", us / 20,
                 f"{r.energy_saving:.1f}", "x")
    a, m = out["afmtj"], out["mtj"]
    gain = np.mean([a[k].speedup / m[k].speedup for k in a])
    emit("archmap.afmtj_vs_mtj.mean_decode_gain", 0, f"{gain:.2f}", "x")
    print(f"# afmtj-vs-mtj mean decode speedup gain: {gain:.2f}x")


def bench_kernels():
    """Pallas kernels (interpret mode) vs jnp oracle — correctness + timing."""
    from repro.core import llg
    from repro.core.params import AFMTJ_PARAMS
    from repro.kernels import ops, ref

    print("# kernels: pallas (interpret) vs ref")
    print("name,us_per_call,derived")
    th = jnp.linspace(0.05, 0.25, 512)
    m0 = jax.vmap(lambda t: llg.initial_state(AFMTJ_PARAMS, t, 0.3))(th)
    state = ops.pack_states(m0, jnp.linspace(0.3, 1.2, 512))
    for steps in (100, 400):
        (ok, uk) = _t(ops.llg_rk4, state, AFMTJ_PARAMS, 0.1e-12, steps)
        (orf, ur) = _t(ref.ref_llg_rk4, state, AFMTJ_PARAMS, 0.1e-12, steps)
        err = float(jnp.max(jnp.abs(ok[0][:6] - orf[0][:6]))) if isinstance(ok, tuple) else float(jnp.max(jnp.abs(ok[:6] - orf[:6])))
        emit(f"kernels.llg_rk4.{steps}steps", uk, f"maxerr={err:.1e}")
        emit(f"kernels.llg_rk4_ref.{steps}steps", ur, 1)
    v = jax.random.uniform(jax.random.PRNGKey(0), (256, 512))
    g = jax.random.uniform(jax.random.PRNGKey(1), (512, 256)) * 3.4e-4
    (o1, u1) = _t(ops.bitline_mac, v, g, 6, i_max=0.05)
    (o2, u2) = _t(ref.ref_bitline_mac, v, g, 6, i_max=0.05)
    emit("kernels.bitline_mac.256x512x256", u1,
         f"match={int(bool(jnp.allclose(o1, o2, rtol=1e-5)))}")
    a = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (256, 512)))
    w = jnp.sign(jax.random.normal(jax.random.PRNGKey(3), (512, 256)))
    (o3, u3) = _t(ops.xnor_gemm, a, w)
    (o4, u4) = _t(ref.ref_xnor_gemm, a, w)
    emit("kernels.xnor_gemm.256x512x256", u3,
         f"match={int(bool(jnp.allclose(o3, o4)))}")


def bench_mvm():
    """Functional analog MVM: the Pallas bitline/XNOR read path vs a jnp
    einsum baseline — throughput plus kernel-vs-reference parity and output
    error vs the f32 matmul (the accuracy the closed-form model can't see).

    Shapes are deliberately NOT 128-multiples so the padding path is always
    exercised."""
    from repro.imc.analog_pipeline import (AnalogConfig, analog_matmul,
                                           binary_matmul, program_weights)
    from repro.kernels import ref
    from repro.kernels.xnor_gemm import binarize_acc

    m, k, n = (48, 200, 144) if SMOKE else (256, 1000, 520)
    print(f"# mvm: analog read path {m}x{k}x{n} "
          f"({'smoke' if SMOKE else 'full'}; pallas interpret on CPU)")
    print("name,us_per_call,derived")
    kw, kx = jax.random.split(jax.random.PRNGKey(0))
    w = jax.random.normal(kw, (k, n), jnp.float32) / (k ** 0.5)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    y_f32 = np.asarray(x @ w)

    cfg = AnalogConfig(adc_bits=6)
    arr = program_weights(w, "afmtj", cfg)
    einsum = jax.jit(lambda a, b: jnp.einsum("mk,kn->mn", a, b))
    if SMOKE:   # one timed call each — parity is what CI is after
        y_a, us_a = _t(analog_matmul, arr, x)
        us_a_cold = None
    else:       # steady state, with the compile cost split out
        y_a, us_a, us_a_cold = _t_split(analog_matmul, arr, x)
    mse = float(np.mean((np.asarray(y_a) - y_f32) ** 2))
    emit("mvm.analog.adc6", us_a, f"nmse={mse/np.mean(y_f32**2):.2e}",
         cold_us=us_a_cold)

    # parity: the kernel output must match the jnp oracle on the exact
    # operands analog_matmul fed the kernel
    from repro.imc.analog_pipeline import kernel_operands
    from repro.kernels.ops import bitline_mac
    v, i_max, _ = kernel_operands(arr, x)
    ok = np.allclose(np.asarray(bitline_mac(v, arr.g_diff, 6, i_max=i_max)),
                     np.asarray(ref.ref_bitline_mac(v, arr.g_diff, 6,
                                                    i_max=i_max)),
                     rtol=1e-5, atol=i_max / 31 * 1.001)
    emit("mvm.analog.kernel_vs_ref", 0, f"match={int(ok)}")

    if SMOKE:
        y_e, us_e = _t(einsum, x, w)
        us_e_cold = None
    else:
        y_e, us_e, us_e_cold = _t_split(einsum, x, w)
    emit("mvm.einsum_f32", us_e, "baseline", cold_us=us_e_cold)
    emit("mvm.analog_over_einsum", 0, f"{us_a/max(us_e,1e-9):.1f}", "x")

    y_b, us_b = _t(binary_matmul, x, w)
    mse_b = float(np.mean((np.asarray(y_b) - y_f32) ** 2))
    emit("mvm.bnn.xnor", us_b, f"nmse={mse_b/np.mean(y_f32**2):.2e}")
    from repro.kernels.ops import xnor_gemm
    xb, wb = binarize_acc(x, 1), binarize_acc(w, 1)
    ok_b = np.array_equal(np.asarray(xnor_gemm(xb, wb)),
                          np.asarray(ref.ref_xnor_gemm(xb, wb)))
    emit("mvm.bnn.kernel_vs_ref", 0, f"match={int(ok_b)}")
    print("# analog path adds programming+ADC on top of the matmul; on TPU "
          "the kernel runs compiled (interpret-mode timings are CPU-only)")


def bench_wer():
    """Fused-temperature campaign engine: the whole (T x V x S) reliability
    grid rides ONE kernel launch with ONE compile (per-lane Brown sigma +
    chunked early exit, DESIGN.md §8), measured against

    * the old engine semantics — a per-temperature loop of fixed-horizon
      launches, each synced before the next is dispatched (and, in the
      removed sigma-as-compile-time-scalar engine, each temperature also
      paid its own XLA compile — the cold column is the honest comparison
      there), and
    * (full mode) the per-sample scan path in core/montecarlo.py.

    Smoke mode shrinks the grid but keeps >= 3 temperature points so CI
    exercises the fused-T path on every push."""
    from repro.campaign import CampaignGrid, run_campaign
    from repro.campaign.engine import _integrate_sharded
    from repro.core.params import AFMTJ_PARAMS
    from repro.imc.write_margin import wer_margined_pulse

    temps = (260.0, 300.0, 340.0)
    if SMOKE:
        voltages, n_samples = (1.0, 1.2), 256
        pulses = tuple(x * 1e-12 for x in (150, 250, 350))
    else:
        voltages, n_samples = (0.8, 1.0, 1.2), 512
        pulses = tuple(x * 1e-12 for x in (100, 150, 200, 250, 300, 350, 400))

    def mk(t):
        return CampaignGrid(voltages=voltages, pulse_widths=pulses,
                            temperatures=t, n_samples=n_samples,
                            dt=0.1e-12, seed=0)

    grid, singles = mk(temps), [mk((t,)) for t in temps]
    print(f"# wer: fused (T x V x S) campaign {len(temps)}T x "
          f"{len(voltages)}V x {n_samples}S, {len(pulses)} pulses, "
          f"{grid.n_steps} steps ({'smoke' if SMOKE else 'full'})")
    print("name,us_per_call,derived")

    # fused: one launch / one compile for the whole plane
    _integrate_sharded._clear_cache()
    res, us_fused, us_fused_cold = _t_split(
        lambda: run_campaign(AFMTJ_PARAMS, grid, use_cache=False))
    compiles = _integrate_sharded._cache_size()
    n = res.n_samples_total
    emit("wer.fused.temperature_points", 0, len(temps))
    emit("wer.fused.launches", 0, res.n_launches)
    emit("wer.fused.xla_compiles", 0, compiles)
    emit("wer.fused_one_launch_ok", 0,
         int(res.n_launches == 1 and compiles == 1))
    emit("wer.fused.us_per_sample", us_fused / n, n, "us/sample",
         cold_us=us_fused_cold / n)

    # old engine semantics: one fixed-horizon launch per temperature,
    # host-synced before the next dispatch (chunk=0 disables early exit
    # and horizon quantization — exactly the pre-fusion integration)
    def per_t_loop():
        return [run_campaign(AFMTJ_PARAMS, g, use_cache=False, chunk=0)
                for g in singles]

    _, us_loop, us_loop_cold = _t_split(per_t_loop)
    emit("wer.per_t_loop.us_per_sample", us_loop / n, n, "us/sample",
         cold_us=us_loop_cold / n)
    emit("wer.fused_over_per_t_loop", 0, f"{us_loop/us_fused:.2f}", "x")
    print(f"# fused {us_fused/n:.0f} us/sample (1 launch, {compiles} "
          f"compile) vs per-T loop {us_loop/n:.0f} us/sample "
          f"({len(temps)} launches) -> {us_loop/us_fused:.2f}x")

    wer = res.wer_surface()                       # (T, V, P)
    for ti in (0, len(temps) - 1):
        for j in (0, len(pulses) - 1):
            emit(f"wer.afmtj.{temps[ti]:.0f}K.{voltages[0]:.1f}V."
                 f"{pulses[j]*1e12:.0f}ps", us_fused / n,
                 f"{wer[ti, 0, j]:.3f}")

    if SMOKE:
        return

    # scan baseline: producing the same pulse axis takes one integration
    # per (V, pulse) point — time the 1.0 V row, 32 samples each, warmed
    from repro.core.montecarlo import write_error_rate_scan
    for pl_ in pulses:
        write_error_rate_scan(AFMTJ_PARAMS, 1.0, pl_,
                              n_samples=32).block_until_ready()
    us_scan_total, scan_runs = 0.0, 0
    for pl_ in pulses:
        w, us = _t(write_error_rate_scan, AFMTJ_PARAMS, 1.0, pl_,
                   n_samples=32)
        us_scan_total += us / 32          # us per sample at this pulse
        scan_runs += 1
        if pl_ in (pulses[0], pulses[-1]):
            emit(f"wer.scan.1.0V.{pl_*1e12:.0f}ps", us / 32, f"{float(w):.3f}")

    # per *sample of the full surface*: one engine sample covers every
    # pulse width (first-crossing post-processing); a scan sample must be
    # re-integrated once per pulse point
    emit("wer.engine.us_per_sample", us_fused / n, n, "us/sample")
    emit("wer.scan.us_per_sample", us_scan_total, scan_runs * 32, "us/sample")
    print(f"# engine {us_fused/n:.0f} us/sample (all {len(pulses)} "
          f"pulses) vs scan {us_scan_total:.0f} us/sample (re-integrated per "
          f"pulse, steady-state) -> {us_scan_total/(us_fused/n):.1f}x fewer "
          "us per sample (target >= 5x)")

    pulse = wer_margined_pulse("afmtj", 1.0, wer_target=1e-2, n_samples=128)
    emit("wer.margin_pulse_ps@1V.wer1e-2", 0, f"{pulse*1e12:.0f}", "ps")
    # operating-range margin: worst case over the corner temperatures, one
    # fused launch for the whole (T x ladder) grid
    pulse_rng = wer_margined_pulse("afmtj", 1.0, wer_target=1e-2,
                                   n_samples=128, temperatures=temps)
    emit("wer.margin_pulse_ps@1V.wer1e-2.range", 0,
         f"{pulse_rng*1e12:.0f}", "ps")
    print("# mean intrinsic t_sw ~123ps; the WER<=1e-2 pulse covers the "
          "thermal tail the IMC controller schedules against (range = "
          f"worst case over {temps[0]:.0f}-{temps[-1]:.0f} K)")


def bench_write():
    """Stochastic write path: write-verify retry programming at 1.0 V,
    AFMTJ vs MTJ — the paper's headline write ratios (~8x latency, ~9x
    energy) reproduced from thermal LLG transients + retries instead of
    the deterministic single-pulse constants.  Also pins the §8 compile
    economics: a shrinking multi-round retry schedule stays within its
    shape-bucket compile budget (fewer XLA compiles than rounds).  Full
    mode additionally reruns the Fig. 4 system comparison with the
    measured p99 row write time threaded through the pipelined stage
    model."""
    from repro.campaign.engine import _integrate_sharded
    from repro.imc.write_path import WritePolicy, write_verify

    n_cells = 64 if SMOKE else 1024
    max_att = 4 if SMOKE else 8
    print(f"# write: write-verify retry path @1.0V, {n_cells} cells, "
          f"<= {max_att} attempts ({'smoke' if SMOKE else 'full'})")
    print("name,us_per_call,derived")
    res = {}
    for kind in ("afmtj", "mtj"):
        pol = WritePolicy(v_write=1.0, max_attempts=max_att, seed=0)
        r, us = _t(lambda k=kind, p=pol: write_verify(k, n_cells, p))
        res[kind] = r
        hist = "/".join(str(int(c)) for c in r.retry_histogram()[1:])
        emit(f"write.{kind}.pulse_ps", us, f"{r.pulse*1e12:.0f}", "ps")
        emit(f"write.{kind}.single_pulse_wer", 0, f"{r.single_pulse_wer:.3f}")
        emit(f"write.{kind}.attempts_mean", 0, f"{r.attempts_mean:.2f}")
        emit(f"write.{kind}.retry_hist", 0, hist)
        emit(f"write.{kind}.latency_mean_ps", 0,
             f"{r.latency.mean()*1e12:.0f}", "ps")
        emit(f"write.{kind}.latency_p99_ps", 0,
             f"{r.latency_percentile(99.0)*1e12:.0f}", "ps")
        emit(f"write.{kind}.energy_mean_fj", 0, f"{r.energy_mean()*1e15:.1f}",
             "fJ")
        emit(f"write.{kind}.residual_ber", 0, f"{r.residual_ber:.4f}")

    la = res["mtj"].latency.mean() / res["afmtj"].latency.mean()
    ea = res["mtj"].energy_mean() / res["afmtj"].energy_mean()
    emit("write.ratio.latency", 0, f"{la:.1f}", "x")
    emit("write.ratio.energy", 0, f"{ea:.1f}", "x")
    emit("write.ratio_ok", 0, int(5.0 < la < 13.0 and 5.0 < ea < 13.0))
    print("# paper @1.0V: ~8x latency, ~9x energy (Fig. 3 anchors; see "
          "EXPERIMENTS.md §Write-path for documented deviations)")

    # equal-pulse retry asymmetry: at the AFMTJ's pulse the MTJ virtually
    # never verifies — the retry counts, not the nominal pulse, carry the
    # device difference (pins the CI marker below)
    tp = WritePolicy(v_write=1.0).resolved_pulse("afmtj")
    pol_eq = WritePolicy(v_write=1.0, pulse=tp, max_attempts=3, seed=0)
    r_a, _ = _t(lambda: write_verify("afmtj", n_cells, pol_eq))
    r_m, _ = _t(lambda: write_verify("mtj", n_cells, pol_eq))
    emit("write.equal_pulse.afmtj_attempts", 0, f"{r_a.attempts_mean:.2f}")
    emit("write.equal_pulse.mtj_attempts", 0, f"{r_m.attempts_mean:.2f}")
    emit("write.equal_pulse_retries_ok", 0,
         int(r_m.attempts_mean > r_a.attempts_mean))

    # recompile-free retry rounds: a schedule whose still-unwritten set
    # shrinks 640 -> ~300 -> ~130 -> ... lands on two shape buckets (1024,
    # 512), so XLA compiles stay below the round count (DESIGN.md §8)
    _integrate_sharded._clear_cache()
    pol_c = WritePolicy(v_write=1.0, pulse=130e-12, max_attempts=4, seed=1,
                        use_cache=False)
    r_c, us_c = _t(lambda: write_verify("afmtj", 640, pol_c))
    compiles = _integrate_sharded._cache_size()
    emit("write.retry.rounds", us_c, r_c.rounds)
    emit("write.retry.xla_compiles", 0, compiles)
    emit("write.compiles_lt_rounds_ok", 0, int(compiles < r_c.rounds))
    print(f"# {r_c.rounds} retry rounds over a shrinking cell set -> "
          f"{compiles} XLA compiles (shape buckets; pre-§8 engine paid "
          "one compile per distinct round shape)")

    if SMOKE:
        return
    # Fig. 4 with the measured p99 row write time in the pipelined stage
    # model (SystemResult.t_write_op / .write_attempts thread it through):
    # MTJ retry inflation widens the AFMTJ advantage on write-heavy loads.
    from repro.imc.evaluate import evaluate_system, summarize

    for kind in ("afmtj", "mtj"):
        sys_n, us_n = _t(evaluate_system, kind)
        sys_p, us_p = _t(lambda k=kind: evaluate_system(
            k, write_percentile=99.0))
        sp_n, es_n = summarize(sys_n)
        sp_p, es_p = summarize(sys_p)
        r0 = sys_p["mat_add"]
        emit(f"write.fig4.{kind}.avg_speedup_nominal", us_n, f"{sp_n:.1f}", "x")
        emit(f"write.fig4.{kind}.avg_speedup_p99", us_p, f"{sp_p:.1f}", "x")
        emit(f"write.fig4.{kind}.avg_energy_saving_p99", 0, f"{es_p:.1f}", "x")
        emit(f"write.fig4.{kind}.mat_add_t_write_op_ps", 0,
             f"{r0.t_write_op*1e12:.0f}", "ps")
        emit(f"write.fig4.{kind}.mat_add_write_attempts", 0,
             f"{r0.write_attempts:.2f}")


def bench_variation():
    """Process-corner variation campaign (DESIGN.md §9): the whole
    (corner x T x V x S) reliability grid — per-lane alpha/B_k/g_scale
    rows on the kernel's variation plane — rides ONE launch with ONE
    compile, corner values/sigmas/seeds rerun compile-free, and the
    margined write pulse widens to cover the worst (corner, T) cell.
    Smoke mode shortens the pulse ladder but keeps the full
    3 corners x 3 T x 3 V x 256 samples plane so CI pins the one-launch
    corner axis on every push."""
    import dataclasses

    from repro.campaign import CampaignGrid, run_campaign
    from repro.campaign.engine import _integrate_sharded
    from repro.core.params import (AFMTJ_PARAMS, CORNER_FF, CORNER_SS,
                                   CORNER_TT, VariationSpec)
    from repro.imc.write_margin import wer_margined_pulse

    corners = (CORNER_FF, CORNER_TT,
               dataclasses.replace(CORNER_SS, sigma_alpha=0.05, sigma_r=0.05))
    spec = VariationSpec(corners=corners)
    temps = (260.0, 300.0, 340.0)
    voltages = (0.8, 1.0, 1.2)
    n_samples = 256
    pulses = tuple(x * 1e-12 for x in
                   ((150, 250) if SMOKE else (100, 150, 200, 250, 300, 350)))
    grid = CampaignGrid(voltages=voltages, pulse_widths=pulses,
                        temperatures=temps, n_samples=n_samples,
                        dt=0.1e-12, seed=0, variation=spec)
    print(f"# variation: fused (C x T x V x S) campaign {len(corners)}C x "
          f"{len(temps)}T x {len(voltages)}V x {n_samples}S, "
          f"{len(pulses)} pulses, {grid.n_steps} steps "
          f"({'smoke' if SMOKE else 'full'})")
    print("name,us_per_call,derived")

    _integrate_sharded._clear_cache()
    if SMOKE:    # one timed call — the compile pins are what CI is after
        res, us = _t(lambda: run_campaign(AFMTJ_PARAMS, grid,
                                          use_cache=False))
        us_cold = None
    else:
        res, us, us_cold = _t_split(
            lambda: run_campaign(AFMTJ_PARAMS, grid, use_cache=False))
    compiles = _integrate_sharded._cache_size()
    n = res.n_samples_total
    emit("variation.corners", 0, len(corners))
    emit("variation.launches", 0, res.n_launches)
    emit("variation.xla_compiles", 0, compiles)
    emit("variation_one_launch_ok", 0,
         int(res.n_launches == 1 and compiles == 1))
    emit("variation.us_per_sample", us / n, n, "us/sample",
         cold_us=None if us_cold is None else us_cold / n)

    # corner VALUES are data: different factors, D2D sigmas and seed reuse
    # the compile (the CI grep on this is the §9 regression tripwire)
    spec_b = VariationSpec(corners=(
        dataclasses.replace(CORNER_SS, alpha_factor=1.25, sigma_r=0.1),
        CORNER_TT, CORNER_FF), seed=11)
    _, us_b = _t(lambda: run_campaign(
        AFMTJ_PARAMS, dataclasses.replace(grid, variation=spec_b, seed=4),
        use_cache=False))
    emit("variation.corner_values_rerun_compiles", us_b,
         _integrate_sharded._cache_size())
    emit("variation_corner_values_data_ok", 0,
         int(_integrate_sharded._cache_size() == compiles))

    # per-corner WER / switched-latency rows at 1.0 V, worst temperature,
    # on the ~250 ps rung (the nominal WER<=1e-2 margin pulse) — the rung
    # where the corners actually separate
    wer = res.wer_surface()                       # (C, T, V, P)
    lat = res.latency_percentiles((50.0, 99.0))   # (C, T, V, 2)
    vi = 1
    pi = min(range(len(pulses)), key=lambda i: abs(pulses[i] - 250e-12))
    for ci, c in enumerate(corners):
        wr = wer[ci, :, vi, pi].max()
        emit(f"variation.{c.name}.wer@1.0V.{pulses[pi]*1e12:.0f}ps", 0,
             f"{wr:.3f}")
        p50 = np.nanmax(lat[ci, :, vi, 0])
        emit(f"variation.{c.name}.latency_p50_ps@1.0V", 0,
             f"{p50*1e12:.0f}", "ps")
    # the slow corner must actually be the reliability binder
    emit("variation_corner_ordering_ok", 0,
         int(wer[2, :, vi, pi].max() >= wer[0, :, vi, pi].max()))

    if SMOKE:
        return
    # corner-margined write pulse: worst (corner, T) cell, one fused launch
    kw = dict(v_write=1.0, wer_target=1e-2, n_samples=128, use_cache=False)
    p_nom = wer_margined_pulse("afmtj", **kw)
    p_cor = wer_margined_pulse("afmtj", temperatures=temps,
                               variation=VariationSpec(
                                   corners=(CORNER_FF, CORNER_TT,
                                            CORNER_SS)), **kw)
    emit("variation.margin_pulse_ps@1V.nominal", 0, f"{p_nom*1e12:.0f}", "ps")
    emit("variation.margin_pulse_ps@1V.corners", 0, f"{p_cor*1e12:.0f}", "ps")
    emit("variation_margin_covers_corners_ok", 0, int(p_cor >= p_nom))
    print(f"# WER<=1e-2 pulse: nominal {p_nom*1e12:.0f} ps -> worst "
          f"(corner, T) {p_cor*1e12:.0f} ps (the margin the companion "
          "paper's variation-resilient drivers schedule)")


def bench_read():
    """Read-path scenario family (DESIGN.md §10): read-disturb, accelerated
    retention and sense-margin yield through the fused campaign engine —
    each kernel-backed scenario is ONE launch with ONE compile (the
    ``read_one_launch_ok`` pin CI greps), the sense MC is closed-form.
    Full mode additionally derives the retention+disturb refresh policy and
    reruns the Fig. 4 comparison with the scrub overhead charged."""
    import dataclasses

    from repro.campaign.engine import _integrate_sharded
    from repro.campaign.grid import log_pulses
    from repro.core.params import CORNER_TT, VariationSpec
    from repro.imc.read_path import (fit_disturb_model, read_disturb_campaign,
                                     reads_between_refresh,
                                     retention_campaign, sense_margin_yield)

    if SMOKE:
        d_kw = dict(voltages=(0.10, 0.24), pulses=(0.2e-9, 2.0e-9),
                    temperatures=(300.0, 400.0), n_samples=128)
        r_kw = dict(accel_factors=(0.05, 0.10), temperatures=(300.0,),
                    horizons=log_pulses(0.15e-9, 1.2e-9, per_decade=3),
                    n_samples=96,
                    variation=VariationSpec(corners=(CORNER_TT,)))
        n_sense = 2048
    else:
        d_kw, r_kw, n_sense = {}, {}, 4096
    print(f"# read: disturb + retention + sense-margin scenarios "
          f"({'smoke' if SMOKE else 'full'})")
    print("name,us_per_call,derived")

    # --- read-disturb: sub-threshold pulses, one fused (V x P x T x S) grid
    _integrate_sharded._clear_cache()
    dres, us_d = _t(lambda: read_disturb_campaign("afmtj", use_cache=False,
                                                  **d_kw))
    c_d = _integrate_sharded._cache_size()
    emit("read.disturb.launches", us_d, dres.n_launches)
    emit("read.disturb.xla_compiles", 0, c_d)
    v_hi, t_hi = len(dres.grid.voltages) - 1, len(dres.grid.temperatures) - 1
    p_lo = dres.p1(v_index=0, p_index=-1, t_index=t_hi)
    p_hi = dres.p1(v_index=v_hi, p_index=-1, t_index=t_hi)
    emit(f"read.disturb.p1@{dres.grid.voltages[0]:.2f}V", 0, f"{p_lo:.4f}")
    emit(f"read.disturb.p1@{dres.grid.voltages[v_hi]:.2f}V", 0, f"{p_hi:.4f}")
    emit("read.disturb.onset_ok", 0, int(p_hi > p_lo))

    # accelerated disturb model: Delta_eff(V) on a barrier-scaled corner,
    # extrapolated to the operating barrier
    model, us_f = _t(lambda: fit_disturb_model(
        "afmtj", use_cache=False,
        **({"n_samples": 128, "horizon": 2.5e-9} if SMOKE else {})))
    emit("read.disturb.fit.v_c_V", us_f, f"{model.v_c:.3f}", "V")
    emit("read.disturb.fit.beta", 0, f"{model.beta:.2f}")
    p1_op = model.p1(0.05, 0.5e-9, 40.0, 0.25e-9)
    emit("read.disturb.p1@0.05V.delta40", 0, f"{p1_op:.2e}")
    emit("read.disturb.reads_per_1e-9_budget", 0,
         f"{reads_between_refresh(p1_op, 1e-9):.1f}")

    # --- retention: accelerated-barrier corners, log-horizon ladder,
    # ONE fused launch, Arrhenius cross-check + pinned-slope extrapolation
    _integrate_sharded._clear_cache()
    rres, us_r = _t(lambda: retention_campaign("afmtj", use_cache=False,
                                               **r_kw))
    c_r = _integrate_sharded._cache_size()
    emit("read.retention.launches", us_r, rres.result.n_launches)
    emit("read.retention.xla_compiles", 0, c_r)
    emit("read.retention.flips_total", 0, int(rres.n_flips.sum()))
    slope, _ = rres.arrhenius_fit(0, 0)
    emit("read.retention.arrhenius_slope", 0, f"{slope:.2f}")
    tau_op = rres.tau_op()
    for ci, c in enumerate(rres.spec.corners):
        emit(f"read.retention.{c.name}.tau_op_s", 0,
             f"{np.nanmin(tau_op[ci]):.2e}", "s")
    emit("read.retention.worst_tau_op_s", 0, f"{rres.worst_tau_op():.2e}", "s")
    emit("read_one_launch_ok", 0,
         int(dres.n_launches == 1 and c_d == 1
             and rres.result.n_launches == 1 and c_r == 1))

    # --- sense-margin yield: closed-form (D2D x SA-offset) MC per corner
    sy, us_s = _t(lambda: sense_margin_yield("afmtj", n_samples=n_sense))
    for ci, name in enumerate(sy.corner_names):
        emit(f"read.sense_yield.{name}@{sy.v_reads[0]:.2f}V", us_s,
             f"{sy.yield_surface[ci, 0]:.4f}")
    v99 = sy.v_read_for_yield(0.999)
    emit("read.sense_yield.v_read_for_0.999", 0, f"{v99:.2f}", "V")
    emit("read.sense_yield.t_sense_p99_ps", 0,
         f"{sy.t_sense.max()*1e12:.1f}", "ps")
    emit("read.sense_yield.margin_min_mV", 0,
         f"{sy.margin_min.min()*1e3:.2f}", "mV")

    if SMOKE:
        return
    # refresh policy from the measured physics, charged into Fig. 4
    from repro.imc.evaluate import evaluate_system, summarize
    from repro.imc.read_path import derive_refresh_policy

    pol, us_p = _t(lambda: derive_refresh_policy("afmtj"))
    emit("read.refresh.interval_s", us_p, f"{pol.interval:.2e}", "s")
    emit("read.refresh.limited_by", 0, pol.limited_by)
    emit("read.refresh.reads_max", 0, f"{pol.reads_max:.1f}")
    base, _ = _t(evaluate_system, "afmtj")
    wref, _ = _t(lambda: evaluate_system("afmtj", refresh=pol))
    sp0, es0 = summarize(base)
    sp1, es1 = summarize(wref)
    emit("read.refresh.fig4.avg_speedup_nominal", 0, f"{sp0:.1f}", "x")
    emit("read.refresh.fig4.avg_speedup_refresh", 0, f"{sp1:.1f}", "x")
    emit("read.refresh.fig4.avg_energy_saving_refresh", 0, f"{es1:.1f}", "x")
    r = wref["mat_add"]
    emit("read.refresh.fig4.mat_add_t_refresh_frac", 0,
         f"{r.t_refresh/r.t_imc:.3f}")
    print(f"# scrub every {pol.interval*1e6:.1f} us ({pol.limited_by}-"
          f"limited): avg speedup {sp0:.1f}x -> {sp1:.1f}x with refresh "
          "charged (the non-volatility tax the closed-form model ignores)")


def bench_serve():
    """Serving case study (DESIGN.md §11): Poisson traffic through the
    continuous-batching policy with every token priced in simulated device
    time — p99 TTFT / per-token latency, tokens/joule, and SLO attainment
    at a fixed offered load, per technology.  Full mode serves 1e6 requests
    per technology through the event-driven simulator (closed-form decode
    segments — no model forwards) with the measured p99 write/read
    percentile prices; smoke keeps the same pipeline at 20k requests and
    nominal prices.  A small engine-integrated serve (real jitted forwards)
    anchors the token accounting the simulator's counts must match."""
    from repro.configs.registry import ARCHS
    from repro.imc.cost_model import device_cost_model, per_token_counts
    from repro.launch.report import SLO, build_report
    from repro.launch.simulate import simulate_serving
    from repro.launch.traffic import (CHAT_OUTPUTS, CHAT_PROMPTS,
                                      poisson_at_load)

    arch = "qwen2-0.5b"
    n_requests = 20_000 if SMOKE else 1_000_000
    n_slots, rho = 8, 0.8
    knobs = {} if SMOKE else {"write_percentile": 99.0,
                              "read_percentile": 99.0}
    print(f"# serve: {arch} serving study, {n_requests} Poisson requests "
          f"per technology at offered load {rho} "
          f"({'smoke, nominal prices' if SMOKE else 'full, p99 prices'})")
    print("name,us_per_call,derived")
    tc = per_token_counts(ARCHS[arch])       # full arch: counts only, no jit
    p99_tpot = {}
    for tech in ("afmtj", "mtj", "cpu"):
        prices = device_cost_model(tech, **({} if tech == "cpu" else knobs)
                                   ).token_prices(tc)
        trace = poisson_at_load(prices, rho, n_requests, n_slots,
                                seed=11).trace()
        slo = SLO.normalized(prices, CHAT_PROMPTS, CHAT_OUTPUTS, n_slots)
        res, us = _t(lambda: simulate_serving(prices, trace,
                                              n_slots=n_slots))
        rep = build_report(tech, res.ttft_s, res.tpot_s, res.sim_time_s,
                           res.energy_j, res.prefill_tokens,
                           res.decode_tokens, offered_load=rho, slo=slo,
                           busy_s=res.busy_s)
        p99_tpot[tech] = rep.tpot_p99_s
        emit(f"serve.{tech}.requests", us, rep.n_requests)
        emit(f"serve.{tech}.ttft_p99_s", 0, f"{rep.ttft_p99_s:.4e}", "s")
        emit(f"serve.{tech}.tpot_p99_s", 0, f"{rep.tpot_p99_s:.4e}", "s")
        emit(f"serve.{tech}.throughput_tok_s", 0,
             f"{rep.throughput_tok_s:.4e}", "tok/s")
        emit(f"serve.{tech}.tokens_per_joule", 0,
             f"{rep.tokens_per_joule:.4e}", "tok/J")
        emit(f"serve.{tech}.slo_attainment", 0,
             f"{rep.slo_attainment:.4f}")
        emit(f"serve.{tech}.utilization", 0, f"{rep.utilization:.4f}")
        print(f"# {tech}: served {rep.n_requests} requests in "
              f"{res.sim_time_s:.3e} simulated s ({us/1e6:.1f} wall s), "
              f"{res.waves} prefill waves")
    # the case-study comparison: every generated token pays the KV append
    # on the write path, so MTJ's slow writes surface in the p99 tail
    emit("serve.afmtj_beats_mtj_p99_ok", 0,
         int(p99_tpot["afmtj"] < p99_tpot["mtj"]))
    emit("serve.afmtj_beats_cpu_p99_ok", 0,
         int(p99_tpot["afmtj"] < p99_tpot["cpu"]))

    # engine-integrated anchor: real jitted forwards, same accounting
    from repro.launch.serve import main as serve_main

    stats, us_e = _t(lambda: serve_main(
        ["--arch", arch, "--requests", "5", "--batch", "2",
         "--prompt-len", "16", "--max-new", "4"]))
    emit("serve.engine.generated_tokens", us_e, stats["generated_tokens"])
    emit("serve.engine.token_split_ok", 0,
         int(stats["prefill_tokens"] == stats["served"] == 5
             and stats["prefill_tokens"] + stats["decode_tokens"]
             == stats["generated_tokens"]))
    emit("serve.engine.afmtj_beats_mtj_ok", 0,
         int(stats["device"]["afmtj"]["tpot_p99_s"]
             < stats["device"]["mtj"]["tpot_p99_s"]))


def bench_model():
    """Model-level analog accuracy (DESIGN.md §12): whole transformer
    forwards routed through the analog MVM via the linear-interception
    hook — the fused fake-analog throughput pin vs the per-projection
    device loop (the ``model_fakeanalog_speedup_ok`` marker CI greps),
    fake-vs-device model-level parity, the BNN variant, and the
    logits-KL / token-match surface over adc_bits.  Smoke caps the study
    at ONE 2-layer smoke arch; full mode adds the second architecture."""
    import tempfile

    from repro.imc.analog_pipeline import AnalogConfig
    from repro.imc.model_analog import (_setup, analog_model_logits,
                                        logit_metrics, model_accuracy_surface)

    archs = ("qwen2-0.5b",) if SMOKE else ("qwen2-0.5b", "gemma2-2b")
    batch, seq_len = (1, 32) if SMOKE else (2, 64)
    print(f"# model: analog-routed transformer forwards ({', '.join(archs)} "
          f"smoke configs, batch={batch}, seq={seq_len}, "
          f"{'smoke' if SMOKE else 'full'})")
    print("name,us_per_call,derived")

    # --- throughput pin: one whole-forward through the fused fake-analog
    # kernel vs the per-projection device loop (programming cache warm, so
    # the loop pays only npz loads + per-projection host syncs — the
    # steady-state floor of the device path).  Always measured on the smoke
    # shape: the pin is defined on the smoke surface (ISSUE acceptance) and
    # a fixed shape keeps the BENCH.json trajectory comparable across modes.
    arch = archs[0]
    acfg = AnalogConfig(adc_bits=8, tmr=5.0)
    cfg, params, tokens, ref_logits = _setup(arch, True, 1, 32, 0)

    def fake():
        return analog_model_logits(params, cfg, tokens, acfg)

    y_f, us_fake, us_fake_cold = _t_split(fake)
    _, us_f2 = _t(fake)
    us_fake = min(us_fake, us_f2)
    with tempfile.TemporaryDirectory() as td:
        def device():
            return analog_model_logits(params, cfg, tokens, acfg,
                                       mode="device", cache_dir=td)

        y_d, us_dev, us_dev_cold = _t_split(device)
        _, us_d2 = _t(device)
        us_dev = min(us_dev, us_d2)
    emit("model.fake.us_per_forward", us_fake, f"{us_fake:.0f}", "us",
         cold_us=us_fake_cold)
    emit("model.device.us_per_forward", us_dev, f"{us_dev:.0f}", "us",
         cold_us=us_dev_cold)
    kl_fd, match_fd, _, _ = logit_metrics(y_d, y_f, tokens)
    emit("model.fake_vs_device.kl", 0, f"{kl_fd:.2e}")
    emit("model.fake_vs_device.token_match", 0, f"{match_fd:.3f}")
    speedup = us_dev / max(us_fake, 1e-9)
    emit("model.fakeanalog.speedup", 0, f"{speedup:.1f}", "x")
    emit("model_fakeanalog_speedup_ok", 0,
         int(speedup >= 10.0 and kl_fd < 1e-4))
    print(f"# fake {us_fake:.0f} us vs device loop {us_dev:.0f} us per "
          f"forward -> {speedup:.1f}x (target >= 10x), model-level "
          f"KL {kl_fd:.1e}")

    # --- BNN variant: every linear through the XNOR popcount path
    y_b, us_b = _t(lambda: analog_model_logits(params, cfg, tokens, acfg,
                                               mode="bnn"))
    kl_b, match_b, _, _ = logit_metrics(ref_logits, y_b, tokens)
    emit("model.bnn.kl", us_b, f"{kl_b:.3f}")
    emit("model.bnn.token_match", 0, f"{match_b:.3f}")

    # --- accuracy surface: logits KL / token match vs adc_bits at TMR 5
    for a in archs:
        reports, us_s = _t(lambda a=a: model_accuracy_surface(
            a, adc_bits=(4, 6, 8), tmrs=(5.0,), batch=batch,
            seq_len=seq_len))
        for r in reports:
            emit(f"model.accuracy.{a}.kl.adc{r.adc_bits}", us_s / 3,
                 f"{r.kl:.4f}")
            emit(f"model.accuracy.{a}.token_match.adc{r.adc_bits}", 0,
                 f"{r.token_match:.3f}")
        kls = [r.kl for r in reports]
        emit(f"model.accuracy.{a}.kl_monotone_ok", 0,
             int(kls[0] >= kls[1] >= kls[2]))
    print("# KL(ref || analog) shrinks monotonically with ADC resolution; "
          "the adc8 qwen2 point is the golden pin in tests/test_model_analog.py")


def bench_fault():
    """Hard-fault injection and graceful degradation (DESIGN.md §13):
    model KL / token-match degradation curves vs fault rate x repair
    policy (with the knee where remapping stops saving accuracy), the
    masks-are-data compile pin (a whole rate sweep shares one XLA
    executable per policy — ``fault_masks_data_ok``), repair-capacity
    yield, serving SLO attainment under faults, and the crash-resumable
    campaign check (``campaign_resume_ok``).  Smoke shrinks the model
    shape and request counts; the curve shapes are identical."""
    import tempfile

    from repro.imc.analog_pipeline import AnalogConfig
    from repro.imc.faults import (FaultSpec, REPAIR_SPARE, REPAIR_SPARE_ECC)
    from repro.imc.mapping import fault_cost_factors
    from repro.imc.model_analog import (_default_interpret, _fake_faults_mode,
                                        _jitted_fake_forward, _setup,
                                        _systematic_g_scale, degradation_knee,
                                        model_degradation_curves)
    from repro.launch.simulate import fault_slo_curve

    arch = "qwen2-0.5b"
    batch, seq_len = (1, 32) if SMOKE else (2, 64)
    rates = (0.0, 3e-3, 1e-2, 3e-2) if SMOKE else (0.0, 1e-3, 3e-3, 1e-2,
                                                   3e-2)
    policies = (None, REPAIR_SPARE)
    print(f"# fault: stuck-at/endurance fault planes through the analog "
          f"stack ({arch} smoke config, batch={batch}, seq={seq_len}, "
          f"{'smoke' if SMOKE else 'full'})")
    print("name,us_per_call,derived")

    # --- graceful-degradation curves: accuracy vs rate x repair policy
    reports, us_c = _t(lambda: model_degradation_curves(
        arch, rates=rates, policies=policies, batch=batch, seq_len=seq_len))
    by_pol = {}
    for r in reports:
        by_pol.setdefault(r.repair, []).append(r)
        tag = f"fault.model.{r.repair}.r{r.fault_rate:g}"
        emit(f"{tag}.kl", us_c / len(reports), f"{r.kl:.4f}")
        emit(f"{tag}.token_match", 0, f"{r.token_match:.3f}")
    mono = all(
        all(a.kl <= b.kl + 1e-9 and a.token_match >= b.token_match - 1e-9
            for a, b in zip(rs, rs[1:]))
        for rs in by_pol.values())
    emit("fault.kl_monotone_ok", 0, int(mono))
    # knee threshold relative to the fault-free accuracy: the smoke model's
    # absolute token match is low, but "how far can faults push before we
    # lose 20% of the healthy accuracy" is shape-independent
    bar = 0.8 * by_pol["none"][0].token_match
    knees = degradation_knee(reports, min_token_match=bar)
    for pol, knee in sorted(knees.items()):
        emit(f"fault.knee.{pol}", 0, f"{knee:g}")
    top_none = by_pol["none"][-1]
    top_spare = by_pol[REPAIR_SPARE.name][-1]
    emit("fault.repair_extends_knee_ok", 0,
         int(knees[REPAIR_SPARE.name] > knees["none"]
             or top_spare.kl < top_none.kl))
    print(f"# spare-row/col remap holds token match >= {bar:.2f} out to "
          f"rate {knees[REPAIR_SPARE.name]:g} vs {knees['none']:g} bare, "
          f"and top-rate KL {top_spare.kl:.2f} vs {top_none.kl:.2f}")

    # --- the tentpole pin: fault masks are data, not compile keys — the
    # whole rate sweep above compiled ONE executable per repair policy
    compiles = []
    cfg, *_ = _setup(arch, True, batch, seq_len, 0)
    for pol in policies:
        acfg = AnalogConfig(adc_bits=6, seed=0,
                            faults=FaultSpec.at_rate(1e-3, seed=0),
                            repair=pol)
        apply_fet, _ = _systematic_g_scale(acfg)
        fn = _jitted_fake_forward(cfg, 6, apply_fet, False, acfg.ir_drop,
                                  _default_interpret(),
                                  _fake_faults_mode(acfg), pol)
        compiles.append(fn._cache_size())
    emit("fault.compiles_per_policy", 0, max(compiles))
    emit("fault_masks_data_ok", 0, int(all(c == 1 for c in compiles)))

    # --- repair-capacity yield at a fixed defect rate
    spec = FaultSpec.at_rate(1e-3, seed=0)
    for name, pol in (("none", None), ("spare", REPAIR_SPARE),
                      ("spare_ecc", REPAIR_SPARE_ECC)):
        y, ovh, stretch = fault_cost_factors(spec, pol)
        emit(f"fault.yield.{name}", 0, f"{y:.3e}")
        emit(f"fault.cell_overhead.{name}", 0, f"{ovh:.3f}")
    print("# without spares one stuck pair condemns a row — array yield "
          "collapses; 8+8 spares recover it for ~7% cell overhead")

    # --- serving: SLO attainment vs fault rate (held offered load/trace)
    n_req = 600 if SMOKE else 4000
    slo_rates = (0.0, 1e-4, 3e-4, 1e-3)
    pts, us_s = _t(lambda: fault_slo_curve(
        "afmtj", rates=slo_rates, policies=policies, n_requests=n_req))
    slo_by_pol = {}
    for p in pts:
        slo_by_pol.setdefault(p.repair, []).append(p)
        emit(f"fault.slo.{p.repair}.r{p.fault_rate:g}",
             us_s / len(pts), f"{p.slo_attainment:.4f}")
    slo_mono = all(
        all(a.slo_attainment >= b.slo_attainment - 1e-9
            for a, b in zip(ps, ps[1:]))
        for ps in slo_by_pol.values())
    spare_holds = (slo_by_pol[REPAIR_SPARE.name][-1].slo_attainment
                   >= slo_by_pol["none"][-1].slo_attainment)
    emit("fault.slo_monotone_ok", 0, int(slo_mono and spare_holds))

    # --- crash-resumable campaigns: abort after the first launch, resume
    # from the slice checkpoints, assemble bit-identically
    from repro.campaign.engine import run_campaign
    from repro.campaign.grid import CampaignGrid, bucket_cells
    from repro.core.params import AFMTJ_PARAMS

    grid = CampaignGrid(voltages=(0.6, 1.2), pulse_widths=(120e-12,),
                        temperatures=(300.0, 350.0), n_samples=16,
                        dt=0.1e-12, seed=0)
    per = bucket_cells(grid.cells)

    class _Abort(Exception):
        pass

    def die_early(i, n):
        if i == 0:
            raise _Abort

    fresh, us_fresh = _t(lambda: run_campaign(
        AFMTJ_PARAMS, grid, backend="ref", use_cache=False,
        max_cells_per_launch=per))
    with tempfile.TemporaryDirectory() as td:
        try:
            run_campaign(AFMTJ_PARAMS, grid, backend="ref", cache_dir=td,
                         max_cells_per_launch=per, on_slice_complete=die_early)
        except _Abort:
            pass
        resumed, us_res = _t(lambda: run_campaign(
            AFMTJ_PARAMS, grid, backend="ref", cache_dir=td,
            max_cells_per_launch=per))
    identical = bool(np.array_equal(np.asarray(resumed.crossing_time),
                                    np.asarray(fresh.crossing_time)))
    emit("fault.resume.n_resumed", us_res, resumed.n_resumed)
    emit("fault.resume.fresh_us", us_fresh, f"{us_fresh:.0f}", "us")
    emit("campaign_resume_ok", 0,
         int(identical and resumed.n_resumed >= 1 and not resumed.from_cache))
    print(f"# killed after launch 1/{resumed.n_launches}: resume skipped "
          f"{resumed.n_resumed} checkpointed slice(s) "
          f"({us_res/1e6:.2f}s vs {us_fresh/1e6:.2f}s fresh), "
          f"crossing tensor bit-identical={identical}")


# child process for the device-count scaling rows: forced host devices must
# be in XLA_FLAGS before the child's first jax import, so wall-clock and
# lane-plan numbers come from subprocesses; the parent compares WER hashes
# across device counts (the bit-identity half of scaling_monotone_ok)
_SCALE_CHILD = """
import hashlib, json, sys, time
import numpy as np
import jax
from repro.campaign import CampaignGrid, bucket_cells, run_campaign
from repro.campaign.engine import _device_plan
from repro.core.params import AFMTJ_PARAMS

n_dev, n_samples = int(sys.argv[1]), int(sys.argv[2])
assert jax.device_count() == n_dev, jax.devices()
grid = CampaignGrid(voltages=(0.6, 1.2), pulse_widths=(20e-12, 40e-12),
                    temperatures=(300.0,), n_samples=n_samples,
                    dt=0.1e-12, seed=0)
kw = dict(backend="ref", use_cache=False, reduce="stream", n_bins=128)
run_campaign(AFMTJ_PARAMS, grid, **kw)              # compile
t0 = time.time()
res = run_campaign(AFMTJ_PARAMS, grid, **kw)
us = (time.time() - t0) * 1e6
_, plan_cols = _device_plan(bucket_cells(grid.cells), None)
print(json.dumps({
    "us_per_sample": us / res.n_samples_total,
    "lanes_per_dev": plan_cols // n_dev,
    "wer_sha": hashlib.sha256(res.wer_counts.tobytes()).hexdigest()}))
"""

# child for the donated-retry peak-memory rows: a full write-verify retry
# schedule (the donation use case) with ru_maxrss as the peak-RSS meter —
# measured in a fresh process so the parent's own allocations don't mask it
_DONATE_CHILD = """
import json, resource, sys
from repro.imc.write_path import WritePolicy, write_verify

pol = WritePolicy(v_write=1.0, pulse=130e-12, max_attempts=4, seed=1,
                  use_cache=False, donate=bool(int(sys.argv[1])))
res = write_verify("afmtj", int(sys.argv[2]), pol)
print(json.dumps({
    "peak_bytes": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024,
    "rounds": res.rounds,
    "residual_ber": res.residual_ber}))
"""


def bench_scale():
    """Scaling path (DESIGN.md §14): streaming on-device reduction vs the
    dense host round-trip (the >= 4x transfer pin), donated retry buffers
    (peak-RSS rows), device-count scaling on forced host devices
    (per-device lane plans + WER bit-identity — the deterministic half of
    scaling on a wall-clock-less CI box), and the XLA tuning profile
    applied to a child environment.  Ends with the stale-droppings GC
    sweep over the default cache dir."""
    import hashlib
    import json as _json
    import subprocess
    import sys as _sys

    from repro.campaign import CampaignGrid, run_campaign
    from repro.campaign import cache as _cache
    from repro.core.params import AFMTJ_PARAMS
    from repro.launch.mesh import host_device_flag
    from repro.runtime import xla_flags

    import os as _os

    n_bins = 128
    if SMOKE:
        grid = CampaignGrid(voltages=(0.6, 1.2),
                            pulse_widths=(120e-12, 250e-12),
                            temperatures=(300.0, 350.0, 400.0),
                            n_samples=64, dt=0.1e-12, seed=0)
    else:
        # the full wer-bench grid — the ISSUE's >= 4x transfer pin is
        # measured at exactly the grid the WER surfaces ship from
        grid = CampaignGrid(voltages=(0.8, 1.0, 1.2),
                            pulse_widths=tuple(x * 1e-12 for x in
                                               (100, 150, 200, 250, 300,
                                                350, 400)),
                            temperatures=(260.0, 300.0, 340.0),
                            n_samples=512, dt=0.1e-12, seed=0)
    print(f"# scale: streaming/donation/mesh scaling, "
          f"{len(grid.temperatures)}T x {len(grid.voltages)}V x "
          f"{grid.n_samples}S, {grid.n_steps} steps, {n_bins} hist bins "
          f"({'smoke' if SMOKE else 'full'})")
    print("name,us_per_call,derived")

    # --- streaming on-device reduction vs the dense lane-plane round-trip
    dense, us_d = _t(lambda: run_campaign(AFMTJ_PARAMS, grid,
                                          use_cache=False))
    stream, us_s = _t(lambda: run_campaign(AFMTJ_PARAMS, grid,
                                           use_cache=False, reduce="stream",
                                           n_bins=n_bins))
    n = dense.n_samples_total
    ratio = dense.host_bytes / max(stream.host_bytes, 1)
    wer_same = bool(np.array_equal(stream.wer_surface(),
                                   dense.wer_surface()))
    lp_d = dense.latency_percentiles((50.0, 99.0))
    lp_s = stream.latency_percentiles((50.0, 99.0))
    with np.errstate(invalid="ignore"):
        lat_err = float(np.nanmax(np.abs(lp_d - lp_s))) if np.isfinite(
            lp_d).any() else 0.0
    lat_ok = (lat_err <= stream.sketch_tolerance
              and np.isnan(lp_d).sum() == np.isnan(lp_s).sum())
    emit("scale.dense.peak_bytes", us_d, dense.host_bytes, "B")
    emit("scale.streaming.peak_bytes", us_s, stream.host_bytes, "B")
    emit("scale.streaming.transfer_reduction", 0, f"{ratio:.1f}", "x")
    emit("scale.streaming.latency_err_s", 0, f"{lat_err:.2e}", "s")
    emit("scale.streaming.us_per_sample", us_s / n, n, "us/sample")
    emit("streaming_reduction_ok", 0,
         int(ratio >= 4.0 and wer_same and lat_ok))
    print(f"# dense moves {dense.host_bytes} B to host vs streaming "
          f"{stream.host_bytes} B ({ratio:.1f}x, target >= 4x); WER "
          f"bit-identical={wer_same}, latency err {lat_err:.2e} s within "
          f"{stream.sketch_tolerance:.2e} s sketch tolerance")

    env = dict(_os.environ)
    env.setdefault("PYTHONPATH", "src")

    def _child(code, *argv, extra_env=None):
        e = dict(env) if extra_env is None else {**env, **extra_env}
        r = subprocess.run([_sys.executable, "-c", code, *argv], env=e,
                           capture_output=True, text=True, timeout=560)
        assert r.returncode == 0, r.stderr
        return _json.loads(r.stdout.strip().splitlines()[-1])

    # --- donated retry buffers: peak RSS of a full write-verify schedule
    cells = 256 if SMOKE else 640
    plain = _child(_DONATE_CHILD, "0", str(cells))
    donated = _child(_DONATE_CHILD, "1", str(cells))
    emit("scale.nodonation.peak_bytes", 0, plain["peak_bytes"], "B")
    emit("scale.donation.peak_bytes", 0, donated["peak_bytes"], "B")
    emit("scale.donation.rounds", 0, donated["rounds"])
    print(f"# peak RSS over {donated['rounds']} retry rounds: "
          f"{plain['peak_bytes']/1e6:.0f} MB undonated vs "
          f"{donated['peak_bytes']/1e6:.0f} MB donated (CPU RSS is a loose "
          "proxy; on an accelerator donation halves device residency of "
          "the state block)")

    # --- device-count scaling: forced host devices in child processes.
    # One host CPU gives no wall-clock speedup, so the CI-stable marker is
    # deterministic: per-device lane plans monotone non-increasing AND the
    # WER counts bit-identical at every device count.
    scale_samples = 512 if SMOKE else 2048
    rows = {}
    for n_dev in (1, 2, 4, 8):
        rows[n_dev] = _child(
            _SCALE_CHILD, str(n_dev), str(scale_samples),
            extra_env={"XLA_FLAGS": (env.get("XLA_FLAGS", "") + " "
                                     + host_device_flag(n_dev)).strip()})
        emit(f"scale.devices{n_dev}.us_per_sample", 0,
             f"{rows[n_dev]['us_per_sample']:.2f}", "us/sample")
        emit(f"scale.devices{n_dev}.lanes_per_dev", 0,
             rows[n_dev]["lanes_per_dev"])
    lanes = [rows[d]["lanes_per_dev"] for d in (1, 2, 4, 8)]
    shas = {rows[d]["wer_sha"] for d in (1, 2, 4, 8)}
    emit("scaling_monotone_ok", 0,
         int(all(a >= b for a, b in zip(lanes, lanes[1:]))
             and len(shas) == 1))
    print(f"# lanes/device {lanes} across 1/2/4/8 forced host devices, "
          f"WER bit-identical across all counts={len(shas) == 1}")

    # --- XLA tuning profile: same 1-device child, baseline env vs the
    # gpu-scaling profile merged in (flags parse and no-op on CPU — the
    # before/after pair is the honest CPU-CI reading; on a GPU fleet the
    # tuned row is where the profile earns its place)
    base = _child(_SCALE_CHILD, "1", str(scale_samples))
    tuned_env = xla_flags.apply_profile("gpu-scaling", env)
    tuned = _child(_SCALE_CHILD, "1", str(scale_samples),
                   extra_env={"XLA_FLAGS": tuned_env["XLA_FLAGS"]})
    emit("scale.xla.baseline.us_per_sample", 0,
         f"{base['us_per_sample']:.2f}", "us/sample")
    emit("scale.xla.tuned.us_per_sample", 0,
         f"{tuned['us_per_sample']:.2f}", "us/sample")
    emit("scale.xla.profile_flags", 0,
         len(xla_flags.PROFILES["gpu-scaling"]))
    emit("scale.xla.wer_identical_ok", 0,
         int(base["wer_sha"] == tuned["wer_sha"]))

    # --- teardown: sweep stale droppings (tmp files from SIGKILLed stores,
    # claim files from dead peers) out of the default cache dir
    n_tmp = _cache.gc_stale_tmp()
    n_claims = _cache.gc_stale_claims()
    emit("scale.gc.stale_tmp", 0, n_tmp)
    emit("scale.gc.stale_claims", 0, n_claims)


BENCHES = {
    "table1": bench_table1,
    "fig3": bench_fig3,
    "fig4": bench_fig4,
    "validation": bench_validation,
    "archmap": bench_archmap,
    "kernels": bench_kernels,
    "mvm": bench_mvm,
    "wer": bench_wer,
    "write": bench_write,
    "variation": bench_variation,
    "read": bench_read,
    "serve": bench_serve,
    "model": bench_model,
    "fault": bench_fault,
    "scale": bench_scale,
}


def main() -> None:
    global SMOKE
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names "
                         f"(choices: {','.join(sorted(BENCHES))})")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes, no steady-state warmup (CI parity run)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write every emitted row + run metadata to PATH "
                         "(BENCH.json)")
    args = ap.parse_args()
    SMOKE = args.smoke
    if args.only:
        names = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in names if n not in BENCHES]
        if unknown:
            ap.error(f"unknown benchmark(s) {unknown}; "
                     f"choices: {sorted(BENCHES)}")
    else:
        names = list(BENCHES)
    from repro.runtime.fault import StepWatchdog

    # per-bench wall-time watchdog: a bench that blows past 3x the running
    # average usually means an accidental full-mode shape or a compile
    # regression — flag it in the log (and BENCH.json meta) instead of
    # letting it hide inside the total
    wd = StepWatchdog(threshold=3.0, alpha=0.5)
    t0 = time.time()
    for i, n in enumerate(names):
        print(f"\n=== {n} " + "=" * (60 - len(n)))
        tb = time.time()
        BENCHES[n]()
        if wd.observe(i, time.time() - tb):
            print(f"# watchdog: bench '{n}' took "
                  f"{time.time() - tb:.1f}s, >3x the running average")
    total = time.time() - t0
    print(f"\ntotal {total:.1f}s")
    if args.json:
        payload = {
            "meta": {
                "benches": names,
                "smoke": SMOKE,
                "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "jax": jax.__version__,
                "total_s": round(total, 3),
                "straggler_benches": [names[i] for i in wd.straggler_steps],
                "unix_time": int(time.time()),
            },
            "benchmarks": RECORDS,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {len(RECORDS)} rows to {args.json}")


if __name__ == "__main__":
    main()

"""Assemble EXPERIMENTS.md §Dry-run table from results/dryrun/*.json."""
import json, sys
from pathlib import Path
REPO = Path(__file__).resolve().parents[1]
rows = []
for f in sorted((REPO/"results"/"dryrun").glob("*.json")):
    r = json.loads(f.read_text())
    mem = (r["memory"]["argument_size_in_bytes"] + r["memory"]["temp_size_in_bytes"]) / 1e9
    coll = sum(v["bytes"] for v in r["collectives"].values())/1e9
    rows.append((r["arch"], r["shape"], r["mesh"], r["n_devices"], mem,
                 r.get("flops_audit_per_device", 0)/1e12, coll,
                 r["t_compile_s"]))
order = ["gemma2-2b","internlm2-20b","qwen2-0.5b","qwen3-8b","qwen2-vl-2b",
         "llama4-maverick-400b-a17b","olmoe-1b-7b","seamless-m4t-large-v2",
         "mamba2-780m","jamba-1.5-large-398b"]
rows.sort(key=lambda r: (order.index(r[0]), r[1], r[2]))
print("| arch | shape | mesh | chips | bytes/dev (GB) | TFLOPs/dev | coll GB/dev | compile (s) |")
print("|---|---|---|---|---|---|---|---|")
for a,s,m,n,mem,fl,c,tc in rows:
    print(f"| {a} | {s} | {m} | {n} | {mem:.2f} | {fl:.2f} | {c:.1f} | {tc:.0f} |")

import dataclasses, math, sys
sys.path.insert(0, "src")
exec(open("tools/fit_system2.py").read().split("best = None")[0])
best = None
for n_scale in [0.2, 0.25, 0.35]:
    for bnn_instr in [0.6, 0.8, 1.0]:
        wl_sets = sized_workloads(n_scale, bnn_instr)
        for c in [0.03e-15]:
            for tau in [20e-12, 25e-12, 30e-12]:
                for actives in [(2,4,16),(2,4,12),(2,6,16)]:
                    for eps in [0.2, 0.3, 0.5]:
                        for e_dram in [0.3e-9, 0.5e-9, 0.8e-9]:
                            for e_instr in [15e-12, 20e-12, 30e-12]:
                                cpu = CPUModel(e_dram_line=e_dram, e_instr=e_instr)
                                out = {}
                                for kind in ["afmtj", "mtj"]:
                                    h = build(kind, c, tau, actives, eps)
                                    res = {n: evaluate_workload(w, h, cpu) for n, w in wl_sets.items()}
                                    sp, es = summarize(res)
                                    out[kind] = (res, sp, es)
                                vals = dict(
                                    bnn=out["afmtj"][0]["bnn"].speedup,
                                    mat_add=out["afmtj"][0]["mat_add"].speedup,
                                    avg=out["afmtj"][1], e_avg=out["afmtj"][2],
                                    mtj_avg=out["mtj"][1], mtj_e=out["mtj"][2])
                                s = score(vals)
                                if best is None or s < best[0]:
                                    best = (s, dict(n_scale=n_scale, bnn_instr=bnn_instr, c=c, tau=tau,
                                                    act=actives, eps=eps, e_dram=e_dram, e_instr=e_instr), vals)
print("BEST score", best[0]); print(best[1])
for k, v in best[2].items(): print(f"  {k:8s} {v:8.1f} (target {TARGETS[k]})")
# print the full per-workload table at the optimum
cfg = best[1]
cpu = CPUModel(e_dram_line=cfg["e_dram"], e_instr=cfg["e_instr"])
wl = sized_workloads(cfg["n_scale"], cfg["bnn_instr"])
for kind in ["afmtj", "mtj"]:
    h = build(kind, cfg["c"], cfg["tau"], cfg["act"], cfg["eps"])
    res = {n: evaluate_workload(w, h, cpu) for n, w in wl.items()}
    print(f"--- {kind}")
    for n, r in res.items():
        print(f"  {n:14s} {r.speedup:7.1f}x  {r.energy_saving:7.1f}x")

"""Perf-hillclimb driver: re-lower one cell under a sharding variant and diff
its roofline terms against the recorded baseline.

  python tools/hillclimb.py --arch qwen2-0.5b --shape train_4k \
      --env REPRO_ATTN_DP_ARCHS=qwen2-0.5b --tag attn_dp

Results land in results/perf/<arch>__<shape>__<tag>.json; the baseline is
read from results/dryrun/.  (Each run is a subprocess because the dry-run
pins 512 host devices at import.)
"""
import argparse
import json
import os
import shutil
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--env", action="append", default=[])
    args = ap.parse_args()

    base_f = REPO / "results" / "dryrun" / f"{args.arch}__{args.shape}__pod.json"
    base = json.loads(base_f.read_text())

    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    for kv in args.env:
        k, v = kv.split("=", 1)
        env[k] = v

    # run the variant into a scratch copy of the results dir
    perf_dir = REPO / "results" / "perf"
    perf_dir.mkdir(parents=True, exist_ok=True)
    bak = base_f.with_suffix(".json.bak")
    shutil.copy(base_f, bak)
    try:
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
             "--shape", args.shape, "--mesh", "pod", "--force"],
            env=env, capture_output=True, text=True, timeout=3000)
        if f"[ ok ]" not in r.stdout:
            print(r.stdout[-2000:])
            print(r.stderr[-3000:])
            sys.exit(1)
        variant = json.loads(base_f.read_text())
    finally:
        shutil.move(bak, base_f)

    out = perf_dir / f"{args.arch}__{args.shape}__{args.tag}.json"
    variant["variant_env"] = args.env
    out.write_text(json.dumps(variant, indent=1))

    from repro.launch.roofline import analyze

    b, v = analyze(base), analyze(variant)
    print(f"{'term':12s} {'baseline':>12s} {'variant':>12s} {'delta':>8s}")
    for k in ("t_compute", "t_memory", "t_collective", "roofline_frac"):
        d = (v[k] - b[k]) / max(abs(b[k]), 1e-12) * 100
        print(f"{k:12s} {b[k]:12.4g} {v[k]:12.4g} {d:+7.1f}%")
    print(f"dominant: {b['dominant']} -> {v['dominant']}")
    cb = {k: x['bytes'] for k, x in b['collectives'].items()}
    cv = {k: x['bytes'] for k, x in v['collectives'].items()}
    print("collective bytes/dev:", {k: f"{cb[k]/1e9:.2f}->{cv[k]/1e9:.2f}GB"
                                    for k in cb if cb[k] or cv[k]})


if __name__ == "__main__":
    main()

import dataclasses, itertools, math, sys
sys.path.insert(0, "src")
from repro.circuit.bitline import BitlineParams
from repro.circuit.senseamp import SenseAmpParams
from repro.circuit.subarray import make_subarray
from repro.imc.hierarchy import IMCHierarchy, IMCLevel, LevelSpec
from repro.imc.cpu_model import CPUModel
from repro.imc.evaluate import evaluate_workload, summarize
from repro.imc.workloads import WORKLOADS

import functools
@functools.lru_cache(maxsize=None)
def build(kind, c_per_cell, tau, actives, e_periph_scale):
    levels = {}
    specs = [
        LevelSpec("L1", 32*1024, 256, 256, actives[0], 1.0, 6e-12*e_periph_scale),
        LevelSpec("L2", 1024*1024, 256, 256, actives[1], 1.3, 9e-12*e_periph_scale),
        LevelSpec("MM", 8*1024**3, 512, 512, actives[2], 2.0, 18e-12*e_periph_scale),
    ]
    sa = SenseAmpParams(tau_latch=tau, t_setup=20e-12)
    for spec in specs:
        bl = BitlineParams(c_per_cell=c_per_cell*spec.c_per_cell_scale, rows=spec.rows)
        sub = make_subarray(kind, rows=spec.rows, cols=spec.cols, v_write=1.0, bl=bl, sa=sa)
        levels[spec.name] = IMCLevel(spec=spec, timings=sub.timings)
    return IMCHierarchy(kind=kind, levels=levels)

TARGETS = dict(bnn=55.4, mat_add=16.5, avg=17.5, e_avg=19.9, mtj_avg=6.0, mtj_e=2.3)
def score(vals):
    return sum(abs(math.log(vals[k]/t)) for k, t in TARGETS.items())

def sized_workloads(n_scale, bnn_instr):
    out = {}
    for name, w in WORKLOADS.items():
        n = max(1, int(w.n_elems * n_scale))
        fp = max(1, int(w.footprint_bytes * n_scale))
        kw = dict(n_elems=n, footprint_bytes=fp)
        if name == "bnn":
            kw.update(cpu_instrs_per_elem=bnn_instr)
        out[name] = dataclasses.replace(w, **kw)
    return out

best = None
for n_scale in [1.0, 0.25]:
    for bnn_instr in [0.5, 1.0, 2.0, 4.0]:
        wl_sets = sized_workloads(n_scale, bnn_instr)
        for c in [0.03e-15, 0.06e-15]:
            for tau in [15e-12, 25e-12]:
                for actives in [(2,4,16),(2,8,32),(4,16,64)]:
                    for eps in [0.3, 1.0, 3.0]:
                        for e_dram in [0.5e-9, 2e-9, 15e-9]:
                            for e_instr in [20e-12, 40e-12, 65e-12]:
                                cpu = CPUModel(e_dram_line=e_dram, e_instr=e_instr)
                                out = {}
                                for kind in ["afmtj", "mtj"]:
                                    h = build(kind, c, tau, actives, eps)
                                    res = {n: evaluate_workload(w, h, cpu) for n, w in wl_sets.items()}
                                    sp, es = summarize(res)
                                    out[kind] = (res, sp, es)
                                vals = dict(
                                    bnn=out["afmtj"][0]["bnn"].speedup,
                                    mat_add=out["afmtj"][0]["mat_add"].speedup,
                                    avg=out["afmtj"][1], e_avg=out["afmtj"][2],
                                    mtj_avg=out["mtj"][1], mtj_e=out["mtj"][2])
                                s = score(vals)
                                if best is None or s < best[0]:
                                    best = (s, dict(n_scale=n_scale, bnn_instr=bnn_instr, c=c, tau=tau,
                                                    act=actives, eps=eps, e_dram=e_dram, e_instr=e_instr), vals)
print("BEST score", best[0]); print(best[1])
for k, v in best[2].items(): print(f"  {k:8s} {v:8.1f} (target {TARGETS[k]})")
